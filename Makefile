# HP-GNN build entry points.
#
# The rust crate builds and trains with zero external dependencies (pure-
# Rust reference backend).  `make artifacts` is only needed for the
# optional PJRT path (`--features xla`): it AOT-lowers the JAX/Pallas
# model to HLO text and writes the manifest the runtime validates against.

ARTIFACTS ?= rust/artifacts

.PHONY: build test check-xla fmt artifacts clean-artifacts

build:
	cargo build --release

test:
	cargo test -q

# The PJRT path must keep compiling even without an XLA install.
check-xla:
	cargo check --features xla

fmt:
	cargo fmt --check

# Requires a python environment with jax (build time only; the rust
# runtime never invokes python).
artifacts:
	cd python && python3 -m compile.aot --out $(abspath $(ARTIFACTS))

clean-artifacts:
	rm -rf $(ARTIFACTS)
