# HP-GNN build entry points.
#
# The rust crate builds and trains with zero external dependencies (pure-
# Rust reference backend).  `make artifacts` is only needed for the
# optional PJRT path (`--features xla`): it AOT-lowers the JAX/Pallas
# model to HLO text and writes the manifest the runtime validates against.

ARTIFACTS ?= rust/artifacts
# bench-hotpath / bench-serve: full (default) or smoke (shrunk request
# counts — what CI runs to validate the JSON output shapes).
BENCH_PROFILE ?= full
BENCH_OUT ?= $(abspath BENCH_hotpath.json)
SERVE_OUT ?= $(abspath BENCH_serve.json)

.PHONY: build test lint lint-baseline check-xla fmt artifacts clean-artifacts bench-hotpath bench-serve

build:
	cargo build --release

test:
	cargo test -q

# In-repo static analysis: machine-checks the determinism (D1-D3),
# serving-robustness (R1-R3), lock-order (C1), and hot-path allocation
# (A1) contracts over rust/src, ratcheted against lint_baseline.json.
# Nonzero exit on any fresh finding or stale baseline entry; see README
# "Static analysis" for rules, pragmas, and the baseline workflow.
lint:
	cargo run -q --release --bin hp-gnn -- lint --baseline lint_baseline.json

# Regenerate the accepted-findings baseline after burning down (or
# deliberately accepting) findings.  Commit the resulting file.
lint-baseline:
	cargo run -q --release --bin hp-gnn -- lint --baseline lint_baseline.json --update-baseline

# The PJRT path must keep compiling even without an XLA install.
check-xla:
	cargo check --features xla

fmt:
	cargo fmt --check

# Train-step throughput anchor: times the reference executor's kernel
# layer against the scalar pre-kernel baseline and writes the result to
# BENCH_hotpath.json (schema documented in README "Performance").
bench-hotpath:
	HOTPATH_PROFILE=$(BENCH_PROFILE) HOTPATH_OUT=$(BENCH_OUT) cargo bench --bench hotpath

# Serving load generator: closed- and open-loop load against the
# inference server, written to BENCH_serve.json (schema in README
# "Serving").  Asserts the micro-batching acceptance claim.
bench-serve:
	SERVE_PROFILE=$(BENCH_PROFILE) SERVE_OUT=$(SERVE_OUT) cargo bench --bench serve

# Requires a python environment with jax (build time only; the rust
# runtime never invokes python).
artifacts:
	cd python && python3 -m compile.aot --out $(abspath $(ARTIFACTS))

clean-artifacts:
	rm -rf $(ARTIFACTS)
