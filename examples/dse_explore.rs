//! Design-space exploration walkthrough (paper §5 / Table 5).
//!
//! Sweeps the DSE engine over every (sampler × model × dataset) workload
//! of the paper's evaluation and prints the chosen (m, n), predicted
//! throughput and per-die resource utilization — plus, for one workload,
//! the full feasible grid so the throughput landscape is visible.
//!
//! ```text
//! cargo run --release --offline --example dse_explore
//! ```

use hp_gnn::accel::AccelConfig;
use hp_gnn::dse::{explore, DseProblem};
use hp_gnn::graph::datasets;
use hp_gnn::layout::LayoutOptions;
use hp_gnn::perf::{estimate, BatchGeometry, KappaEstimator, ModelShape, ResourceCoefficients};
use hp_gnn::util::si;

fn problem(ds: &datasets::DatasetSpec, sampler: &str, sage: bool) -> DseProblem {
    let geom = match sampler {
        "NS" => BatchGeometry::neighbor_capped(1024, &[10, 25], ds.nodes),
        _ => {
            let kappa = KappaEstimator::from_stats(ds.nodes, ds.edges);
            BatchGeometry::subgraph(2750, 2, &kappa)
        }
    };
    DseProblem {
        geom,
        model: ModelShape { feat: vec![ds.f0, 256, ds.f2], sage_concat: sage },
        layout: LayoutOptions::all(),
        coeff: ResourceCoefficients::default(),
        t_sampling_single: None,
    }
}

fn main() -> anyhow::Result<()> {
    // Boards come from the named registry — the same lookup
    // `PlatformParameters(board=…)` and the JSON `platform` key use.
    let platform = hp_gnn::accel::platform::by_board("xilinx-U250")
        .expect("xilinx-U250 is registered");

    println!("== DSE results (paper Table 5 analog) ==");
    println!(
        "{:<14} {:>10} {:>8} {:>12} {:>6} {:>6} {:>6} {:>6}",
        "workload", "(m, n)", "dataset", "NVTPS", "DSP%", "LUT%", "URAM%", "BRAM%"
    );
    for (sampler, model, sage) in
        [("NS", "GCN", false), ("NS", "SAGE", true), ("SS", "GCN", false), ("SS", "SAGE", true)]
    {
        for ds in &datasets::ALL {
            let r = explore(&platform, &problem(ds, sampler, sage));
            println!(
                "{:<14} {:>10} {:>8} {:>12} {:>5.0}% {:>5.0}% {:>5.0}% {:>5.0}%",
                format!("{sampler}-{model}"),
                format!("({}, {})", r.config.m, r.config.n),
                ds.key,
                si(r.nvtps),
                r.utilization.dsp * 100.0,
                r.utilization.lut * 100.0,
                r.utilization.uram * 100.0,
                r.utilization.bram * 100.0,
            );
        }
    }

    // The landscape for one workload: every feasible grid point.
    println!("\n== feasible grid, NS-GCN on Reddit (throughput per candidate) ==");
    let prob = problem(&datasets::REDDIT, "NS", false);
    let mut n = 1usize;
    while n <= 32 {
        let mut row = format!("n={n:<3}");
        let mut dim = 1usize;
        while dim * dim <= 4096 {
            let config = AccelConfig { n, m: dim * dim };
            let util = hp_gnn::perf::utilization(
                &platform,
                &prob.coeff,
                &config,
                &prob.geom,
                &prob.model,
            );
            if util.fits() {
                let e = estimate(&platform, &config, &prob.geom, &prob.model, prob.layout);
                row.push_str(&format!(" m={}:{:>8}", config.m, si(e.nvtps(&prob.geom, 0.0))));
            }
            dim *= 2;
        }
        println!("{row}");
        n *= 2;
    }
    println!("\n(paper picks (256, 4) for NS/SS-GCN/NS-SAGE and (256, 8) for SS-SAGE)");

    // The same workload across every registered board: the registry makes
    // cross-platform what-ifs a one-liner.
    println!("\n== NS-GCN on Reddit across the board registry ==");
    for name in hp_gnn::accel::platform::board_names() {
        let board = hp_gnn::accel::platform::by_board(name).expect("registered board");
        let r = explore(&board, &problem(&datasets::REDDIT, "NS", false));
        println!(
            "  {name:<14} ({} dies, {:>6.1} GB/s): (m, n) = ({}, {}) -> {:>8} NVTPS",
            board.dies,
            board.total_bw_gbps(),
            r.config.m,
            r.config.n,
            si(r.nvtps),
        );
    }
    Ok(())
}
