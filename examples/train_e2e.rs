//! End-to-end driver (the repo's mandated validation workload).
//!
//! Trains both GNN models on a Flickr-statistics synthetic graph with
//! neighbor sampling for a few hundred steps, proving all three layers
//! compose: rust sampling + layout + padding → runtime train step →
//! weights threaded through → loss descends.  The run is driven through a
//! `TrainingSession`: progress arrives via `on_step`/`on_eval` hooks,
//! validation interleaves with training, a mid-run `HPGNNS01` snapshot is
//! written, and (for GCN) a fresh session resumed from that snapshot must
//! reproduce the remaining loss curve bit-exactly.  Also runs the
//! cycle-level accelerator simulator per batch and reports the simulated
//! CPU-FPGA NVTPS next to the functional (this-host) throughput.
//!
//! ```text
//! cargo run --release --offline --example train_e2e [-- --steps 300]
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use hp_gnn::api::{HpGnn, SamplerSpec, TrainingSpec, Workspace};
use hp_gnn::util::cli::Args;
use hp_gnn::util::si;

fn main() -> anyhow::Result<()> {
    let args = Args::new("train_e2e", "end-to-end training driver")
        .flag("steps", "300", "training iterations per model")
        .flag("lr", "0.08", "learning rate")
        .flag("scale", "0.05", "Flickr scale factor")
        .flag("seed", "7", "seed")
        .parse()?;

    let ws = Workspace::open(std::path::Path::new("artifacts"))?;
    let steps = args.usize("steps");

    for model in ["GCN", "SAGE"] {
        println!("=== {model} / neighbor sampling / Flickr@{} ===", args.get("scale"));
        let spec = HpGnn::init()
            .platform_board("xilinx-U250")?
            .gnn_computation(model)?
            .gnn_parameters(vec![256]) // ns_small geometry: f = [500, 256, 7]
            .sampler(SamplerSpec::Neighbor { targets: 32, budgets: vec![5, 10] })
            .seed(args.usize("seed") as u64)
            .load_dataset("FL", args.f64("scale"), args.usize("seed") as u64)?
            .training(TrainingSpec {
                steps,
                lr: args.f32("lr"),
                simulate: true,
                ..Default::default()
            })
            .spec()?;
        let design = ws.design(&spec)?;
        println!(
            "design: artifact={} accel=(m={}, n={}) predicted {} NVTPS",
            design.geometry,
            design.accel.config.m,
            design.accel.config.n,
            si(design.accel.nvtps)
        );

        let t = hp_gnn::util::stats::Timer::start();
        let mut session = design.session()?; // training.lr/simulate from the spec
        let stride = (steps / 20).max(1);
        session.on_step(move |r| {
            if r.step % stride == 0 {
                println!("  {:>4}: {:.4}", r.step, r.loss);
            }
        });
        session.on_eval(|ev| {
            println!(
                "  eval @ step {}: {:.1}% accuracy over {} held-out targets",
                ev.step,
                ev.report.accuracy() * 100.0,
                ev.report.total
            );
        });

        // First half, then a full-state snapshot, then the second half —
        // with a mid-run validation pass in between.
        let half = steps / 2;
        session.run_for(half)?;
        let ckpt = std::env::temp_dir()
            .join(format!("hpgnn-e2e-{}-{}.ckpt", model.to_lowercase(), std::process::id()));
        session.save(&ckpt)?;
        session.evaluate(3)?;
        session.run_for(steps - half)?;

        // Held-out accuracy via the forward (inference) artifact.
        let eval = session.evaluate(5)?;
        let report = session.finish();
        let wall = t.secs();
        let m = &report.metrics;

        let (head, tail) = m
            .loss_drop()
            .ok_or_else(|| anyhow::anyhow!("run too short for a loss trend"))?;
        println!(
            "summary: loss {head:.4} -> {tail:.4} | {} steps in {wall:.1}s \
             (compile {:.1}s) | exec {:.1} ms/step | prep {:.1} ms/batch",
            m.losses.len(),
            report.compile_s,
            m.t_execute.mean() * 1e3,
            m.t_sampling.mean() * 1e3,
        );
        println!(
            "throughput: functional {} NVTPS (this host) | simulated CPU-FPGA {} NVTPS",
            si(m.functional_nvtps()),
            si(m.simulated_nvtps(design.sampler_threads()).unwrap_or(0.0)),
        );
        anyhow::ensure!(tail < head, "{model}: loss did not descend ({head} -> {tail})");
        println!(
            "eval: {:.1}% accuracy over {} held-out targets ({} classes -> {:.1}% chance)",
            eval.accuracy() * 100.0,
            eval.total,
            design.graph.num_classes,
            100.0 / design.graph.num_classes as f64,
        );

        // Preemption drill (GCN only, to bound runtime): a fresh session
        // resumed from the mid-run snapshot must replay steps half..steps
        // bit-exactly — same RNG cursor, same weights, same loss curve.
        if model == "GCN" {
            let mut resumed = design.resume_session(&ckpt)?;
            anyhow::ensure!(resumed.current_step() == half, "snapshot step mismatch");
            resumed.run_for(steps - half)?;
            anyhow::ensure!(
                resumed.metrics().losses == m.losses[half..],
                "resumed session diverged from the uninterrupted run"
            );
            println!("resume check OK: steps {half}..{steps} reproduced bit-exactly");
        }
        let _ = std::fs::remove_file(&ckpt);
        println!();
    }
    println!("train_e2e OK — both models converged");
    Ok(())
}
