//! End-to-end driver (the repo's mandated validation workload).
//!
//! Trains both GNN models on a Flickr-statistics synthetic graph with
//! neighbor sampling for a few hundred steps, proving all three layers
//! compose: rust sampling + layout + padding → AOT Pallas/JAX train step
//! via PJRT → weights threaded through → loss descends.  Also runs the
//! cycle-level accelerator simulator per batch and reports the simulated
//! CPU-FPGA NVTPS next to the functional (this-host) throughput.
//!
//! ```text
//! cargo run --release --offline --example train_e2e [-- --steps 300]
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use hp_gnn::api::{HpGnn, SamplerSpec};
use hp_gnn::runtime::Runtime;
use hp_gnn::util::cli::Args;
use hp_gnn::util::si;

fn main() -> anyhow::Result<()> {
    let args = Args::new("train_e2e", "end-to-end training driver")
        .flag("steps", "300", "training iterations per model")
        .flag("lr", "0.08", "learning rate")
        .flag("scale", "0.05", "Flickr scale factor")
        .flag("seed", "7", "seed")
        .parse()?;

    let runtime = Runtime::auto(std::path::Path::new("artifacts"))?;
    let steps = args.usize("steps");

    for model in ["GCN", "SAGE"] {
        println!("=== {model} / neighbor sampling / Flickr@{} ===", args.get("scale"));
        let design = HpGnn::init()
            .platform_board("xilinx-U250")?
            .gnn_computation(model)?
            .gnn_parameters(vec![256]) // ns_small geometry: f = [500, 256, 7]
            .sampler(SamplerSpec::Neighbor { targets: 32, budgets: vec![5, 10] })
            .seed(args.usize("seed") as u64)
            .load_dataset("FL", args.f64("scale"), args.usize("seed") as u64)?
            .generate_design(&runtime)?;
        println!(
            "design: artifact={} accel=(m={}, n={}) predicted {} NVTPS",
            design.geometry,
            design.accel.config.m,
            design.accel.config.n,
            si(design.accel.nvtps)
        );

        let t = hp_gnn::util::stats::Timer::start();
        let report = design.start_training(&runtime, steps, args.f32("lr"), true)?;
        let wall = t.secs();
        let m = &report.metrics;

        // Loss curve, decimated to ~20 points.
        println!("loss curve (step: loss):");
        let stride = (m.losses.len() / 20).max(1);
        for (i, loss) in m.losses.iter().enumerate() {
            if i % stride == 0 || i + 1 == m.losses.len() {
                println!("  {i:>4}: {loss:.4}");
            }
        }
        let (head, tail) = m
            .loss_drop()
            .ok_or_else(|| anyhow::anyhow!("run too short for a loss trend"))?;
        println!(
            "summary: loss {head:.4} -> {tail:.4} | {} steps in {wall:.1}s \
             (compile {:.1}s) | exec {:.1} ms/step | prep {:.1} ms/batch",
            m.losses.len(),
            report.compile_s,
            m.t_execute.mean() * 1e3,
            m.t_sampling.mean() * 1e3,
        );
        println!(
            "throughput: functional {} NVTPS (this host) | simulated CPU-FPGA {} NVTPS",
            si(m.functional_nvtps()),
            si(m.simulated_nvtps(design.accel.sampler_threads.unwrap_or(2)).unwrap_or(0.0)),
        );
        anyhow::ensure!(tail < head, "{model}: loss did not descend ({head} -> {tail})");

        // Held-out accuracy via the forward (inference) artifact.
        let sampler = design.abstraction.sampler.build();
        let cfg = hp_gnn::coordinator::TrainConfig {
            lr: args.f32("lr"),
            ..hp_gnn::coordinator::TrainConfig::quick(
                design.abstraction.model,
                &design.geometry,
                0,
            )
        };
        let eval = hp_gnn::coordinator::evaluate(
            &runtime,
            &design.graph,
            sampler.as_ref(),
            &cfg,
            &report.final_weights,
            5,
            0xe5a1,
        )?;
        println!(
            "eval: {:.1}% accuracy over {} held-out targets ({} classes -> {:.1}% chance)\n",
            eval.accuracy() * 100.0,
            eval.total,
            design.graph.num_classes,
            100.0 / design.graph.num_classes as f64,
        );
    }
    println!("train_e2e OK — both models converged");
    Ok(())
}
