//! Quickstart — the paper's Listing 1 as a rust program.
//!
//! ```text
//! cargo run --release --offline --example quickstart
//! ```
//!
//! Lowers the Table 1 builder calls into a declarative [`ProgramSpec`],
//! opens a [`Workspace`] (which owns the runtime — no `&Runtime`
//! threading), designs a 2-layer GCN with neighbor sampling on a small
//! synthetic graph, prints the generated-design report (the analog of the
//! paper's Listing 3), then opens a [`TrainingSession`] — step-at-a-time
//! control, `on_step`/`on_eval` progress hooks, interleaved validation,
//! and a full-state checkpoint that a later process can `--resume` from.

use hp_gnn::api::{HpGnn, SamplerSpec, TrainingSpec, Workspace};

fn main() -> anyhow::Result<()> {
    // Init() + PlatformParameters(board='xilinx-U250') + GNN_Computation +
    // GNN_Parameters + Sampler + LoadInputGraph, lowered into one spec.
    let spec = HpGnn::init()
        .platform_board("xilinx-U250")?
        .gnn_computation("GCN")?
        .gnn_parameters(vec![8]) // hidden dim (tiny geometry: f = [16, 8, 4])
        .sampler(SamplerSpec::Neighbor { targets: 4, budgets: vec![5, 3] })
        .load_input_graph({
            // A small synthetic graph with the tiny geometry's dims.
            let mut g = hp_gnn::graph::generator::with_min_degree(
                hp_gnn::graph::generator::rmat(2_000, 16_000, Default::default(), 1),
                1,
                2,
            );
            g.feat_dim = 16;
            g.num_classes = 4;
            g.name = "quickstart".into();
            g
        })
        .training(TrainingSpec { lr: 0.1, simulate: true, ..Default::default() })
        .spec()?;

    // GenerateDesign(): DSE + artifact selection + thread sizing, through
    // the runtime-owning workspace.
    let ws = Workspace::open(std::path::Path::new("artifacts"))?;
    let design = ws.design(&spec)?;
    println!("{}\n", design.explain());

    // Start_training(), session style: the caller owns the loop.  The
    // session picks up training.lr / training.simulate from the spec.
    println!("== training ==");
    let mut session = design.session()?;
    session.on_step(|r| {
        if (r.step + 1) % 20 == 0 {
            println!("  step {:>3}: loss {:.4}", r.step, r.loss);
        }
    });
    session.on_eval(|ev| {
        println!(
            "  eval @ step {}: {:.1}% accuracy over {} held-out targets",
            ev.step,
            ev.report.accuracy() * 100.0,
            ev.report.total
        );
    });

    // Train, validate mid-run, checkpoint, train some more.
    session.run_for(30)?;
    session.evaluate(2)?;
    let ckpt = std::env::temp_dir().join("hpgnn-quickstart.ckpt");
    session.save(&ckpt)?;
    session.run_for(30)?;
    session.evaluate(2)?;
    let report = session.finish();

    println!("\n{}", report.metrics.to_json(2).pretty());
    if let Some((head, tail)) = report.metrics.loss_drop() {
        println!(
            "\nloss descended {head:.4} -> {tail:.4} over {} steps",
            report.metrics.losses.len()
        );
    }

    // A fresh session resumed from the snapshot continues at step 30 and
    // replays the exact batch stream the first session saw (same RNG
    // cursor), so its losses match the uninterrupted run bit-exactly.
    let mut resumed = design.resume_session(&ckpt)?;
    resumed.run_for(30)?;
    assert_eq!(
        resumed.metrics().losses,
        report.metrics.losses[30..].to_vec(),
        "resumed session diverged from the uninterrupted run"
    );
    println!("resume check OK: steps 30..60 reproduced bit-exactly from {ckpt:?}");
    let _ = std::fs::remove_file(&ckpt);
    Ok(())
}
