//! Quickstart — the paper's Listing 1 as a rust program.
//!
//! ```text
//! cargo run --release --offline --example quickstart
//! ```
//!
//! Builds a design for a 2-layer GCN with neighbor sampling on a small
//! synthetic Flickr-statistics graph, prints the generated design (the
//! analog of the paper's generated host program + accelerator config),
//! trains briefly, and reports the loss curve.

use hp_gnn::api::{HpGnn, SamplerSpec};
use hp_gnn::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // Init() + PlatformParameters(board='xilinx-U250')
    let runtime = Runtime::auto(std::path::Path::new("artifacts"))?;

    // GNN_Parameters + GNN_Computation + Sampler + LoadInputGraph
    let design = HpGnn::init()
        .platform_board("xilinx-U250")?
        .gnn_computation("GCN")?
        .gnn_parameters(vec![8]) // hidden dim (tiny geometry: f = [16, 8, 4])
        .sampler(SamplerSpec::Neighbor { targets: 4, budgets: vec![5, 3] })
        .load_input_graph({
            // A small synthetic graph with the tiny geometry's dims.
            let mut g = hp_gnn::graph::generator::with_min_degree(
                hp_gnn::graph::generator::rmat(2_000, 16_000, Default::default(), 1),
                1,
                2,
            );
            g.feat_dim = 16;
            g.num_classes = 4;
            g.name = "quickstart".into();
            g
        })
        // GenerateDesign(): DSE + artifact selection + thread sizing.
        .generate_design(&runtime)?;

    println!("== generated design ==\n{}\n", design.to_json().pretty());

    // Start_training(): Algorithm 2 with sampling overlapped.
    let report = design.start_training(&runtime, 60, 0.1, /*simulate=*/ true)?;
    let m = &report.metrics;
    println!("== training ==");
    println!("{}", m.to_json(2).pretty());
    if let Some((head, tail)) = m.loss_drop() {
        println!("\nloss descended {head:.4} -> {tail:.4} over {} steps", m.losses.len());
    }
    Ok(())
}
