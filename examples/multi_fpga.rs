//! Multi-FPGA scaling study — the paper's §8 future work ("extend our
//! framework to multi-FPGA platforms by exploiting model parallelism"),
//! built on the analytic performance model.
//!
//! Prints data-parallel and model-parallel scaling curves for the Reddit
//! NS-GCN workload over 1–8 U250 boards, annotating what binds each point.
//!
//! ```text
//! cargo run --release --offline --example multi_fpga
//! ```

use hp_gnn::accel::AccelConfig;
use hp_gnn::layout::LayoutOptions;
use hp_gnn::perf::{data_parallel, estimate, model_parallel, BatchGeometry, ModelShape, MultiFpga};
use hp_gnn::util::si;

fn main() {
    // Resolve the board through the named registry (same lookup as the
    // builder's PlatformParameters and the JSON `platform` key).
    let platform = hp_gnn::accel::platform::by_board("xilinx-U250")
        .expect("xilinx-U250 is registered");
    let geom = BatchGeometry::neighbor_capped(1024, &[10, 25], 232_965);
    let model = ModelShape { feat: vec![602, 256, 41], sage_concat: false };
    let single = estimate(
        &platform,
        &AccelConfig::paper_default(),
        &geom,
        &model,
        LayoutOptions::all(),
    );
    // Measured on this host: ~2.2 ms to sample one paper-parameter NS
    // batch single-threaded (hotpath bench), 16 sampler threads.
    let t_sampling = 2.2e-3;
    let threads = 16;

    println!("Reddit NS-GCN, (m, n) = (256, 4) per die, 4 dies per board\n");
    println!(
        "{:<8} {:>18} {:>12} {:>18} {:>12}",
        "boards", "data-parallel", "bound by", "model-parallel", "bound by"
    );
    for boards in [1usize, 2, 4, 8] {
        let dp = data_parallel(
            &single,
            &geom,
            &model,
            &platform,
            MultiFpga::pcie(boards),
            t_sampling,
            threads,
        );
        let mp = model_parallel(&single, &geom, &model, MultiFpga::pcie(boards));
        println!(
            "{:<8} {:>14} NVTPS {:>12} {:>14} NVTPS {:>12}",
            boards,
            si(dp.nvtps),
            dp.bottleneck,
            si(mp.nvtps),
            mp.bottleneck
        );
    }
    println!(
        "\nData parallelism scales near-linearly until the host sampler pool \
         saturates;\nmodel parallelism of a 2-layer GNN caps at the slowest \
         layer stage — matching\nthe conventional wisdom the paper's future-work \
         plan implies."
    );
}
