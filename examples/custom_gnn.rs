//! Custom GNN layer via user-defined functions (paper Listing 2).
//!
//! The paper's customization point is the Scatter/Gather/Update UDF
//! triple.  The aggregate hardware template is value-agnostic
//! (`msg.val = edge.val * feat[edge.src]`, `v_ft[msg.dst] += msg.val`), so
//! a *custom Scatter UDF is a custom edge-value function* — it runs on the
//! stock compiled artifacts with no re-synthesis.  This example defines a
//! symmetric heat-kernel-style edge weight (neither GCN's norm nor SAGE's
//! mean), trains with it, and verifies it learns.
//!
//! ```text
//! cargo run --release --offline --example custom_gnn
//! ```

use std::sync::Arc;

use hp_gnn::api::Workspace;
use hp_gnn::coordinator::{train, TrainConfig};
use hp_gnn::graph::generator;
use hp_gnn::sampler::neighbor::NeighborSampler;
use hp_gnn::sampler::values::GnnModel;

fn main() -> anyhow::Result<()> {
    // The workspace owns the runtime; the low-level train() entry point
    // borrows it for UDF experiments below the ProgramSpec surface.
    let ws = Workspace::open(std::path::Path::new("artifacts"))?;
    let runtime = ws.runtime();

    let mut g = generator::with_min_degree(
        generator::rmat(3_000, 24_000, Default::default(), 5),
        1,
        6,
    );
    g.feat_dim = 16;
    g.num_classes = 4;

    // --- the custom Scatter UDF (Listing 2's `Scatter(edge, feat, msg)`).
    // Heat-kernel-ish weight: exp(-|deg(u) - deg(v)| / 8), self loop 1.0.
    // Degree-similar neighbors contribute more.
    let custom_values: hp_gnn::coordinator::trainer::ValueFn = Arc::new(|g, mb| {
        mb.edges
            .iter()
            .map(|layer| {
                layer
                    .iter()
                    .map(|e| {
                        if e.src == e.dst {
                            1.0
                        } else {
                            let du = g.degree(e.src) as f32;
                            let dv = g.degree(e.dst) as f32;
                            (-(du - dv).abs() / 8.0).exp() / (dv + 1.0)
                        }
                    })
                    .collect()
            })
            .collect()
    });

    let sampler = NeighborSampler::new(4, vec![5, 3]);
    let mut cfg = TrainConfig::quick(GnnModel::Gcn, "tiny", 120);
    cfg.lr = 0.1;
    cfg.value_fn = Some(custom_values);

    println!("training custom layer (heat-kernel Scatter UDF, sum Gather, ReLU Update)...");
    let report = train(runtime, &g, &sampler, &cfg)?;
    let m = &report.metrics;
    let (head, tail) = m
        .loss_drop()
        .ok_or_else(|| anyhow::anyhow!("run too short"))?;
    let stride = (m.losses.len() / 12).max(1);
    for (i, loss) in m.losses.iter().enumerate() {
        if i % stride == 0 || i + 1 == m.losses.len() {
            println!("  step {i:>4}: loss {loss:.4}");
        }
    }
    println!("custom layer loss: {head:.4} -> {tail:.4}");
    anyhow::ensure!(tail < head, "custom layer failed to learn");

    // Contrast with the stock GCN normalization on the same batches.
    let mut stock = TrainConfig::quick(GnnModel::Gcn, "tiny", 120);
    stock.lr = 0.1;
    let stock_report = train(runtime, &g, &sampler, &stock)?;
    let (shead, stail) = stock_report.metrics.loss_drop().unwrap();
    println!("stock GCN loss:    {shead:.4} -> {stail:.4}");
    println!("custom_gnn OK — UDF layer trains end to end on stock artifacts");
    Ok(())
}
