//! Serving walkthrough: train → checkpoint → serve → hot-swap.
//!
//! 1. Lower a builder program (with a `serving` section!) into a spec and
//!    design it through a [`Workspace`].
//! 2. Train briefly through a session and snapshot it (`HPGNNS01`) —
//!    serving accepts those directly.
//! 3. Start an inference server (worker pool + micro-batcher + cache) and
//!    answer "classify vertex v" requests.
//! 4. Keep training, save the improved weights, and hot-swap them into
//!    the live server — the versioned cache invalidates itself.
//!
//! Run: `cargo run --release --example serve`

use hp_gnn::api::{HpGnn, SamplerSpec, ServingSpec, TrainingSpec, Workspace};
use hp_gnn::graph::generator;

fn main() -> anyhow::Result<()> {
    let ws = Workspace::reference();

    // A graph matching the builtin "tiny" geometry (f = [16, 8, 4]).
    let mut graph = generator::with_min_degree(
        generator::rmat(400, 3200, Default::default(), 5),
        1,
        6,
    );
    graph.feat_dim = 16;
    graph.num_classes = 4;
    graph.name = "serve-demo".to_string();

    // The serving knobs live in the same declarative spec as everything
    // else — a JSON user program expresses the identical section.
    let spec = HpGnn::init()
        .platform_board("xilinx-U250")?
        .gnn_computation("gcn")?
        .gnn_parameters(vec![8])
        .sampler(SamplerSpec::Neighbor { targets: 4, budgets: vec![5, 3] })
        .load_input_graph(graph)
        .training(TrainingSpec { lr: 0.05, ..Default::default() })
        .serving(ServingSpec { workers: 2, cache: true, max_wait_us: 200, ..Default::default() })
        .spec()?;
    let design = ws.design(&spec)?;
    println!("design geometry: {}", design.geometry);

    // --- 1+2: train a few dozen steps, snapshot the session. ------------
    let dir = std::env::temp_dir().join(format!("hpgnn-serve-example-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let ckpt = dir.join("model.ckpt");
    let mut session = design.session()?;
    session.run_for(40)?;
    session.save(&ckpt)?;
    println!(
        "trained 40 steps (loss {:.4} -> {:.4}), snapshot at {ckpt:?}",
        session.metrics().losses.first().unwrap(),
        session.metrics().losses.last().unwrap()
    );

    // --- 3: serve (knobs from the spec's serving section). --------------
    let server = design.server_from(&ckpt)?;
    let vertices = [3u32, 57, 123, 388];
    for pred in server.classify(&vertices)?.iter() {
        println!(
            "vertex {:>3} -> class {} (logits {:?})",
            pred.vertex,
            pred.label.expect("finite logits"),
            pred.logits
        );
    }
    // Repeat queries hit the cache instead of re-running the kernels.
    server.classify(&vertices)?;
    let m = server.metrics();
    println!(
        "after 2 rounds: {} requests, {} cache hits / {} misses, {} forward batches",
        m.requests, m.cache_hits, m.cache_misses, m.batches
    );
    assert_eq!(m.cache_hits as usize, vertices.len(), "second round must hit");

    // --- 4: hot-swap newer weights into the live server. ----------------
    session.run_for(40)?;
    let improved = dir.join("improved.bin");
    session.finish().final_weights.save(&improved)?; // HPGNNW01 also accepted
    let before = server.classify_one(vertices[0])?;
    server.reload_weights(&improved)?;
    let after = server.classify_one(vertices[0])?;
    assert_ne!(before.logits, after.logits, "new weights must change the logits");
    println!("hot-swapped {improved:?}; vertex {} re-scored under the new model", vertices[0]);

    println!("serving metrics:\n{}", server.metrics().to_json().pretty());
    server.shutdown();
    println!("serve example OK");
    Ok(())
}
