//! API-compatible stub of the `xla` (xla_extension / PJRT) bindings.
//!
//! The hp-gnn crate's `--features xla` backend is written against the
//! small API surface below.  This stub lets that path *type-check and
//! build* on machines without an XLA toolchain: every operation that
//! would need the real runtime returns an error at runtime
//! (`PjRtClient::cpu()` fails first, so no stub executable is ever
//! constructed).  To actually execute HLO artifacts, replace this path
//! dependency with a real `xla` crate exposing the same API.

use std::fmt;
use std::path::Path;

/// Error type standing in for the bindings' status codes.
pub struct Error(String);

impl Error {
    fn stub() -> Error {
        Error(
            "xla stub: built without a real XLA/PJRT runtime — rebuild with the \
             xla_extension bindings to execute HLO artifacts"
                .to_string(),
        )
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

/// Element types the hp-gnn ABI moves across the boundary.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// Host-side tensor value.  The stub tracks only the element count — real
/// payloads never exist because execution is unavailable.
#[derive(Debug)]
pub struct Literal {
    len: usize,
}

impl Literal {
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { len: data.len() }
    }

    pub fn scalar(_value: f32) -> Literal {
        Literal { len: 1 }
    }

    pub fn element_count(&self) -> usize {
        self.len
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let want: i64 = dims.iter().product();
        if want as usize != self.len {
            return Err(Error(format!("reshape {:?} on {} elements", dims, self.len)));
        }
        Ok(Literal { len: self.len })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        Err(Error::stub())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(Error::stub())
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        Err(Error::stub())
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// Device-resident result buffer.
#[derive(Debug)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::stub())
    }
}

/// Compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::stub())
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::stub())
    }

    pub fn platform_name(&self) -> &'static str {
        "stub"
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::stub())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literals_track_element_counts() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.element_count(), 6);
        assert_eq!(l.reshape(&[2, 3]).unwrap().element_count(), 6);
        assert!(l.reshape(&[4, 4]).is_err());
        assert_eq!(Literal::scalar(1.0).element_count(), 1);
    }

    #[test]
    fn runtime_entry_points_fail_loudly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nope.hlo.txt").is_err());
        let msg = format!("{:?}", PjRtClient::cpu().unwrap_err());
        assert!(msg.contains("stub"), "{msg}");
    }
}
