//! Table 6 — throughput improvement from the data-layout optimizations
//! (RMT, RRA) on a two-layer NS-GCN, per dataset.
//!
//! Real sampled edge streams at the paper's sampler parameters are
//! replayed through the cycle-level accelerator simulator under the three
//! layout settings; the paper's measured NVTPS is printed alongside.
//!
//! Run: `cargo bench --offline --bench table6_ablation`

use hp_gnn::graph::datasets;
use hp_gnn::layout::LayoutOptions;
use hp_gnn::repro::{self, paper, EvalSampler};
use hp_gnn::sampler::values::GnnModel;
use hp_gnn::util::bench::BenchSet;
use hp_gnn::util::si;

fn main() {
    let mut set = BenchSet::new("Table 6 — RMT/RRA ablation (NS-GCN)");
    let config = repro::table5_config(EvalSampler::Ns, GnnModel::Gcn);
    const BATCHES: usize = 3;

    println!(
        "{:<4} {:>24} {:>24} {:>24} {:>12}",
        "ds", "baseline (paper|ours)", "+RMT (paper|ours)", "+RMT+RRA (paper|ours)", "improv ours"
    );
    for (i, ds) in datasets::ALL.iter().enumerate() {
        let g = repro::scaled_instance(ds, 100 + i as u64);
        let run = |layout| {
            repro::simulate_workload(
                &g,
                ds,
                GnnModel::Gcn,
                EvalSampler::Ns,
                layout,
                &config,
                BATCHES,
                7,
            )
            .nvtps
        };
        let base = run(LayoutOptions::none());
        let rmt = run(LayoutOptions { rmt: true, rra: false });
        let all = run(LayoutOptions::all());
        let (key, pbase, prmt, pall) = paper::TABLE6[i];
        assert_eq!(key, ds.key);
        println!(
            "{:<4} {:>24} {:>24} {:>24} {:>11.0}%",
            ds.key,
            format!("{} | {}", si(pbase), si(base)),
            format!("{} | {}", si(prmt), si(rmt)),
            format!("{} | {}", si(pall), si(all)),
            (all / base - 1.0) * 100.0,
        );
        set.row(&format!("{} baseline", ds.key), base, "NVTPS");
        set.row(&format!("{} +RMT", ds.key), rmt, "NVTPS");
        set.row(&format!("{} +RMT+RRA", ds.key), all, "NVTPS");

        // Shape assertions: each optimization helps, like the paper.
        assert!(rmt > base, "{}: RMT did not help ({rmt:.3e} vs {base:.3e})", ds.key);
        assert!(all >= rmt, "{}: RRA regressed ({all:.3e} vs {rmt:.3e})", ds.key);
        let improv = all / base - 1.0;
        assert!(
            (0.03..3.0).contains(&improv),
            "{}: combined improvement {improv:.2} out of plausible band (paper: 25-57%)",
            ds.key
        );
    }
    println!("\n(paper improvements: FL 57%, RD 43%, YP 25%, AP 26%)");
    set.persist();
    println!("table6_ablation OK");
}
