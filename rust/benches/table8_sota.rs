//! Table 8 — comparison with state-of-the-art GNN training accelerators
//! (GraphACT on a U250, Rubik ASIC) on SS-SAGE workloads.
//!
//! GraphACT and Rubik are modeled from the specs Table 8 publishes (see
//! `baselines::sota` for the formulas and the §7 architectural arguments
//! they encode); our number is the cycle-level simulation on real streams.
//!
//! Run: `cargo bench --offline --bench table8_sota`

use hp_gnn::accel::Platform;
use hp_gnn::baselines::sota;
use hp_gnn::graph::datasets;
use hp_gnn::layout::LayoutOptions;
use hp_gnn::perf::{BatchGeometry, ModelShape};
use hp_gnn::repro::{self, paper, EvalSampler};
use hp_gnn::sampler::values::GnnModel;
use hp_gnn::util::bench::BenchSet;
use hp_gnn::util::si;

fn main() {
    let mut set = BenchSet::new("Table 8 — vs GraphACT and Rubik (SS-SAGE)");
    let platform = Platform::alveo_u250();

    println!(
        "{:<4} {:>22} {:>22} {:>22} {:>10}",
        "ds", "GraphACT (paper|ours)", "Rubik (paper|ours)", "this work (paper|ours)", "speedup"
    );
    for (i, &(key, p_ga, p_ru, p_ours)) in paper::TABLE8.iter().enumerate() {
        let ds = datasets::by_key(key).unwrap();
        let g = repro::scaled_instance(&ds, 300 + i as u64);
        let kappa = repro::fitted_kappa_fullscale(&g, &ds);
        let geom = BatchGeometry::subgraph(2750, 2, &kappa);
        let shape = ModelShape { feat: vec![ds.f0, 256, ds.f2], sage_concat: true };

        let ga = sota::graphact_nvtps(&platform, &geom, &shape);
        let ru = sota::rubik_nvtps(&geom, &shape);
        let ours = repro::simulate_workload(
            &g,
            &ds,
            GnnModel::Sage,
            EvalSampler::Ss,
            LayoutOptions::all(),
            &repro::table5_config(EvalSampler::Ss, GnnModel::Sage),
            3,
            13,
        )
        .nvtps;

        println!(
            "{:<4} {:>22} {:>22} {:>22} {:>9.2}x",
            key,
            format!("{} | {}", si(p_ga), si(ga)),
            match p_ru {
                Some(p) => format!("{} | {}", si(p), si(ru)),
                None => format!("N/A | {}", si(ru)),
            },
            format!("{} | {}", si(p_ours), si(ours)),
            ours / ga,
        );
        set.row(&format!("{key} graphact"), ga, "NVTPS");
        set.row(&format!("{key} rubik"), ru, "NVTPS");
        set.row(&format!("{key} ours"), ours, "NVTPS");

        // Shape: ours > rubik > graphact (paper's ordering on RD),
        // and the speedup over GraphACT is the headline comparison.
        assert!(ours > ga, "{key}: must beat GraphACT ({ours:.3e} vs {ga:.3e})");
        assert!(ours > ru, "{key}: must beat Rubik ({ours:.3e} vs {ru:.3e})");
        if p_ru.is_some() {
            assert!(ru > ga, "{key}: Rubik should beat GraphACT like the paper");
        }
        let speedup = ours / ga;
        // RD (dense, the paper's headline row) must land near the paper's
        // 4.45x; YP's synthetic instance under-densifies (avg degree 9.7 at
        // 0.38% sampling fraction), compressing the gap, so only the
        // ordering is asserted there.
        let band = if key == "RD" { 2.0..12.0 } else { 1.02..12.0 };
        assert!(
            band.contains(&speedup),
            "{key}: speedup {speedup:.2} outside band {band:?}"
        );
    }
    println!("\n(paper: 4.45x over GraphACT on RD, 3.61x on YP; 3.4x over Rubik)");
    set.persist();
    println!("table8_sota OK");
}
