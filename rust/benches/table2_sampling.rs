//! Table 2 — mini-batch size models per sampling method.
//!
//! Validates the closed forms (|B^l|, |E^l|) that drive the DSE engine
//! against *empirical* batches drawn by the real samplers, and times the
//! samplers themselves (the t_sampling input of Eq. 5).
//!
//! Run: `cargo bench --offline --bench table2_sampling`

use hp_gnn::graph::datasets;
use hp_gnn::perf::{BatchGeometry, KappaEstimator};
use hp_gnn::repro;
use hp_gnn::sampler::{neighbor::NeighborSampler, subgraph::SubgraphSampler, Sampler};
use hp_gnn::util::bench::{Bench, BenchSet};
use hp_gnn::util::rng::Pcg64;

fn main() {
    let mut set = BenchSet::new("Table 2 — batch geometry closed forms vs sampled batches");
    let ds = datasets::FLICKR;
    let g = repro::scaled_instance(&ds, 42);
    println!(
        "instance: {} ({} vertices, {} edges, scale {})\n",
        g.name,
        g.num_vertices(),
        g.num_edges(),
        repro::sim_scale(&ds)
    );

    // ---- neighbor sampling: closed form is exact worst case; empirical
    // batches must stay within it and near the dedup-capped estimate.
    let ns = NeighborSampler::paper_default();
    let worst = BatchGeometry::neighbor(1024, &[10, 25]);
    let capped = BatchGeometry::neighbor_capped(1024, &[10, 25], g.num_vertices());
    let mut rng = Pcg64::seed_from_u64(1);
    let mut obs = vec![0usize; 3];
    let mut obs_e = vec![0usize; 2];
    const RUNS: usize = 5;
    for _ in 0..RUNS {
        let mb = ns.sample(&g, &mut rng);
        for l in 0..3 {
            obs[l] += mb.layers[l].len();
        }
        for l in 0..2 {
            obs_e[l] += mb.edges[l].len();
        }
    }
    println!("NS (|V^t|=1024, NS=[25,10]):");
    for l in 0..3 {
        let mean = obs[l] / RUNS;
        println!(
            "  |B^{l}|: worst-case {} | dedup-capped model {} | sampled mean {}",
            worst.b[l], capped.b[l], mean
        );
        assert!(mean <= worst.b[l], "closed form violated at layer {l}");
        set.row(&format!("NS |B^{l}| sampled/model"), mean as f64 / capped.b[l] as f64, "x");
    }
    for l in 0..2 {
        let mean = obs_e[l] / RUNS;
        println!(
            "  |E^{}|: worst-case {} | sampled mean {}",
            l + 1,
            worst.e[l],
            mean
        );
        assert!(mean <= worst.e[l]);
    }

    // ---- subgraph sampling: κ fitted from probes predicts edge counts.
    let kappa_fit = KappaEstimator::fit(&g, &[500, 1000, 2000, 2750], 7);
    let kappa_stats = KappaEstimator::from_stats(g.num_vertices(), g.num_edges());
    // Measure with the same degree-capped sampler the κ fit probes with
    // (the evaluation workloads' R-MAT hub correction — see
    // sampler::subgraph::NodeProbability::DegreeCapped).
    let mut ss = SubgraphSampler::paper_default();
    ss.probability = hp_gnn::sampler::subgraph::NodeProbability::DegreeCapped(3.0);
    let mut rng = Pcg64::seed_from_u64(2);
    let mut edges = 0usize;
    for _ in 0..RUNS {
        edges += ss.sample(&g, &mut rng).edges[0].len();
    }
    let measured = edges as f64 / RUNS as f64;
    let pred_fit = BatchGeometry::subgraph(2750, 2, &kappa_fit).e[0] as f64;
    let pred_stats = BatchGeometry::subgraph(2750, 2, &kappa_stats).e[0] as f64;
    println!("\nSS (SB=2750): |E^l| measured {measured:.0} | κ-fit {pred_fit:.0} | κ-stats {pred_stats:.0}");
    set.row("SS |E| kappa-fit / measured", pred_fit / measured, "x");
    set.row("SS |E| kappa-stats / measured", pred_stats / measured, "x");
    assert!(
        pred_fit / measured < 2.5 && measured / pred_fit < 2.5,
        "fitted kappa off by >2.5x"
    );

    // ---- sampler wall-clock (the t_sampling the DSE engine sizes
    // threads against).
    let b = Bench::default();
    let mut rng = Pcg64::seed_from_u64(3);
    let m = b.run("NS sample one batch", || ns.sample(&g, &mut rng));
    let v = BatchGeometry::neighbor_capped(1024, &[10, 25], g.num_vertices()).vertices_traversed();
    set.push(m, Some((v as f64, "verts/batch")));
    let mut rng = Pcg64::seed_from_u64(4);
    let m = b.run("SS sample one batch", || ss.sample(&g, &mut rng));
    set.push(m, Some((2750.0 * 3.0, "verts/batch")));

    set.persist();
    println!("\ntable2_sampling OK");
}
