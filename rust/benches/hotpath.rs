//! Hot-path benchmarks: host pipeline stages + the executed training step.
//!
//! Two sections:
//!
//! * **Host pipeline stages** (full profile only) — times every host-side
//!   stage of the training pipeline in isolation (sampling, edge values,
//!   layout, padding, feature synthesis, simulator, executed CPU
//!   baseline) so the perf pass can attack the top bottleneck.
//! * **Train-step executor** — times one full `adam_step` on the
//!   reference backend: the pre-kernel scalar executor as the baseline,
//!   then the tiled kernel layer at several thread counts.  Results are
//!   written to `BENCH_hotpath.json` (see the README "Performance"
//!   section for the schema) — the repo's perf-trajectory anchor.
//!
//! Run: `make bench-hotpath` (repo root) or
//! `cargo bench --bench hotpath`.  Environment knobs:
//!
//! * `HOTPATH_PROFILE=full|smoke` — smoke runs one iteration on a tiny
//!   geometry (CI uses it to keep the JSON shape from rotting).
//! * `HOTPATH_OUT=<path>` — where to write `BENCH_hotpath.json`
//!   (default: current directory).

use hp_gnn::accel::{simulate_batch, AccelConfig, Platform, SimOptions};
use hp_gnn::graph::datasets;
use hp_gnn::layout::pad::{pad, EdgeOverflow, PaddedBatch};
use hp_gnn::layout::{index_batch, Geometry, LayoutOptions};
use hp_gnn::repro;
use hp_gnn::runtime::manifest::{Kind, Manifest};
use hp_gnn::runtime::weights::AdamState;
use hp_gnn::runtime::{inputs, Backend, ReferenceBackend, Tensor, WeightState};
use hp_gnn::sampler::values::{attach_values, GnnModel};
use hp_gnn::sampler::{neighbor::NeighborSampler, Sampler};
use hp_gnn::util::bench::{black_box, Bench, BenchSet};
use hp_gnn::util::json::Json;
use hp_gnn::util::rng::Pcg64;
use hp_gnn::util::threadpool::default_threads;

fn main() {
    let profile = std::env::var("HOTPATH_PROFILE").unwrap_or_else(|_| "full".to_string());
    let out_path =
        std::env::var("HOTPATH_OUT").unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    if profile != "smoke" {
        host_pipeline_stages();
    }
    train_step_bench(&profile, &out_path);
}

/// Times every host-side stage of the training pipeline in isolation.
fn host_pipeline_stages() {
    let mut set = BenchSet::new("hotpath — host pipeline stages");
    let b = Bench::default();
    let ds = datasets::FLICKR;
    let g = repro::scaled_instance(&ds, 17);
    println!("instance: {} vertices, {} edges\n", g.num_vertices(), g.num_edges());

    // Paper-parameter NS batch (the heavy case).
    let sampler = NeighborSampler::paper_default();
    let mut rng = Pcg64::seed_from_u64(1);
    let m = b.run("sample (NS 1024x[25,10])", || black_box(sampler.sample(&g, &mut rng)));
    set.push(m, None);

    let mb = sampler.sample(&g, &mut Pcg64::seed_from_u64(2));
    println!(
        "batch: layers {:?}, edges {:?}",
        mb.layers.iter().map(|l| l.len()).collect::<Vec<_>>(),
        mb.edges.iter().map(|e| e.len()).collect::<Vec<_>>()
    );
    let m = b.run("attach_values gcn", || black_box(attach_values(&g, &mb, GnnModel::Gcn)));
    set.push(m, None);
    let m = b.run("attach_values sage", || black_box(attach_values(&g, &mb, GnnModel::Sage)));
    set.push(m, None);

    let vals = attach_values(&g, &mb, GnnModel::Gcn);
    let m = b.run("index_batch (RMT+RRA)", || {
        black_box(index_batch(&mb, &vals, LayoutOptions::all()))
    });
    set.push(m, None);
    let m = b.run("index_batch (baseline)", || {
        black_box(index_batch(&mb, &vals, LayoutOptions::none()))
    });
    set.push(m, None);

    let ib = index_batch(&mb, &vals, LayoutOptions::all());
    // Geometry big enough for this batch.
    let geom = Geometry {
        name: "bench".into(),
        b: mb.layers.iter().map(|l| l.len().next_multiple_of(64)).rev().collect::<Vec<_>>()
            .into_iter()
            .rev()
            .collect(),
        e: mb.edges.iter().map(|e| e.len().next_multiple_of(64)).collect(),
        f: vec![ds.f0, 256, ds.f2],
    };
    let labels = vec![0u8; mb.layers[2].len()];
    let m = b.run("pad to geometry", || {
        black_box(pad(&ib, &labels, &geom, EdgeOverflow::TruncateKeepSelf).unwrap())
    });
    set.push(m, None);

    let l0_labels = datasets::synth_labels(&mb.layers[0], ds.f2, 3, g.num_vertices());
    let m = b.run("synth_features (B^0 x 500)", || {
        black_box(datasets::synth_features(&mb.layers[0], &l0_labels, ds.f0, ds.f2, 3))
    });
    set.push(m, None);

    let platform = Platform::alveo_u250();
    let config = AccelConfig::paper_default();
    let m = b.run("simulate_batch (cycle-level)", || {
        black_box(simulate_batch(&platform, &config, &ib, &[ds.f0, 256, ds.f2], SimOptions::default()))
    });
    set.push(m, None);

    // Executed CPU training step (the Table 7 anchor) at reduced dims.
    let feats = vec![0.1f32; ib.layers[0].len() * 64];
    let quick = Bench::quick();
    let m = quick.run("executed CPU step (f=64)", || {
        black_box(hp_gnn::baselines::cpu::execute_batch(&ib, &[64, 32, 8], &feats, 4))
    });
    set.push(m, None);

    set.persist();
}

/// One timed configuration of the train-step executor.
struct StepRun {
    label: String,
    threads: usize,
    step_s: f64,
}

fn train_step_bench(profile: &str, out_path: &str) {
    let mut set = BenchSet::new("hotpath — train-step executor (reference backend)");
    let smoke = profile == "smoke";
    // Default bench geometry: the builtin ns_medium batch (paper-scale
    // feature dims); smoke shrinks to tiny for a sub-second CI check.
    let geom_name = if smoke { "tiny" } else { "ns_medium" };
    let manifest = Manifest::builtin();
    let spec = manifest
        .find(GnnModel::Gcn, geom_name, Kind::AdamStep)
        .expect("builtin role")
        .clone();
    let geom = spec.geometry.clone();
    let batch = PaddedBatch::synthetic(&geom, 42);
    let weights = WeightState::init_glorot(&spec.weight_shapes, 7);
    let adam = AdamState::zeros(&spec.weight_shapes);
    let mut rng = Pcg64::seed_from_u64(11);
    let features: Vec<f32> =
        (0..geom.b[0] * geom.f[0]).map(|_| rng.f32_range(-0.5, 0.5)).collect();
    let lits = inputs::build_inputs_opt(&spec, &batch, &features, &weights, 0.01, Some(&adam))
        .expect("bench inputs");
    println!(
        "geometry {}: b {:?}, e {:?}, f {:?} ({} host threads)\n",
        geom.name,
        geom.b,
        geom.e,
        geom.f,
        default_threads()
    );

    let bench = if smoke {
        Bench { warmup: 0, min_samples: 1, max_samples: 1, min_time_s: 0.0 }
    } else {
        Bench { warmup: 1, min_samples: 3, max_samples: 12, min_time_s: 0.8 }
    };
    let mut time_backend = |label: &str, threads: usize, backend: ReferenceBackend| -> StepRun {
        let exe = backend.compile(&manifest, &spec).expect("compile");
        let m = bench.run(label, || -> Vec<Tensor> { black_box(exe.run(&lits).unwrap()) });
        let run = StepRun { label: label.to_string(), threads, step_s: m.median_s };
        set.push(m, Some((1.0 / run.step_s, "steps/s")));
        run
    };

    let baseline = time_backend(
        "scalar baseline (pre-kernel executor)",
        1,
        ReferenceBackend::scalar_baseline(),
    );
    let thread_counts: &[usize] = if smoke { &[1, 2, 8] } else { &[1, 2, 4, 8] };
    let runs: Vec<StepRun> = thread_counts
        .iter()
        .map(|&t| {
            time_backend(
                &format!("tiled kernels, {t} thread(s)"),
                t,
                ReferenceBackend::with_threads(t),
            )
        })
        .collect();
    set.persist();

    // Per-stage breakdown: trace one step at the widest thread count and
    // aggregate span totals (kernel flop/byte counts ride along).  Traced
    // and untraced runs are bit-identical — tracing only observes time —
    // so this does not perturb the timed measurements above.
    hp_gnn::obs::trace::enable();
    let traced = ReferenceBackend::with_threads(*thread_counts.last().unwrap())
        .compile(&manifest, &spec)
        .expect("compile traced");
    black_box(traced.run(&lits).unwrap());
    let trace = hp_gnn::obs::trace::disable();
    let stage_json = |t: &hp_gnn::obs::trace::StageTotal| {
        Json::obj(vec![
            ("calls", Json::num(t.calls as f64)),
            ("total_s", Json::num(t.total_s)),
            ("flops", Json::num(t.flops)),
            ("bytes", Json::num(t.bytes)),
        ])
    };
    let stages = Json::Obj(
        trace
            .stage_totals()
            .iter()
            .map(|((cat, name), t)| (format!("{cat}/{name}"), stage_json(t)))
            .collect(),
    );

    // --- BENCH_hotpath.json: the perf-trajectory anchor. ---
    let samples = geom.b[geom.layers()] as f64; // target vertices per step
    let run_json = |r: &StepRun| {
        Json::obj(vec![
            ("label", Json::str(r.label.clone())),
            ("threads", Json::num(r.threads as f64)),
            ("step_s", Json::num(r.step_s)),
            ("steps_per_s", Json::num(1.0 / r.step_s)),
            ("samples_per_s", Json::num(samples / r.step_s)),
            ("speedup_vs_baseline", Json::num(baseline.step_s / r.step_s)),
        ])
    };
    let doc = Json::obj(vec![
        ("bench", Json::str("hotpath-train-step")),
        ("schema_version", Json::num(2.0)),
        ("profile", Json::str(profile)),
        ("model", Json::str("gcn")),
        ("optimizer", Json::str("adam")),
        ("host_parallelism", Json::num(default_threads() as f64)),
        (
            "geometry",
            Json::obj(vec![
                ("name", Json::str(geom.name.clone())),
                ("b", Json::arr(geom.b.iter().map(|&x| Json::num(x as f64)).collect())),
                ("e", Json::arr(geom.e.iter().map(|&x| Json::num(x as f64)).collect())),
                ("f", Json::arr(geom.f.iter().map(|&x| Json::num(x as f64)).collect())),
            ]),
        ),
        ("baseline", run_json(&baseline)),
        ("runs", Json::arr(runs.iter().map(run_json).collect())),
        ("stages", stages),
    ]);
    std::fs::write(out_path, doc.pretty()).expect("write BENCH_hotpath.json");

    // Self-validate the written file so the harness can't silently rot.
    let text = std::fs::read_to_string(out_path).expect("read back");
    let parsed = Json::parse(&text).expect("BENCH_hotpath.json must parse");
    for key in ["bench", "profile", "geometry", "host_parallelism", "baseline", "runs", "stages"] {
        parsed.get(key).unwrap_or_else(|e| panic!("missing {key}: {e:?}"));
    }
    let runs_arr = parsed.get("runs").unwrap().as_arr().expect("runs array");
    assert!(!runs_arr.is_empty(), "runs must not be empty");
    for r in runs_arr {
        assert!(r.get("step_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(r.get("threads").unwrap().as_usize().unwrap() >= 1);
        assert!(r.get("samples_per_s").unwrap().as_f64().unwrap() > 0.0);
    }
    assert!(parsed.get("baseline").unwrap().get("step_s").unwrap().as_f64().unwrap() > 0.0);
    let Json::Obj(stage_map) = parsed.get("stages").unwrap() else {
        panic!("stages must be an object");
    };
    assert!(
        stage_map.keys().any(|k| k.starts_with("kernel/")),
        "traced step must record kernel stages"
    );
    for (k, v) in stage_map {
        assert!(v.get("calls").unwrap().as_f64().unwrap() >= 1.0, "{k}: calls");
        assert!(v.get("total_s").unwrap().as_f64().unwrap() >= 0.0, "{k}: total_s");
        v.get("flops").unwrap_or_else(|e| panic!("{k} missing flops: {e:?}"));
        v.get("bytes").unwrap_or_else(|e| panic!("{k} missing bytes: {e:?}"));
    }
    println!("\nwrote {out_path} (validated, {} runs)", runs_arr.len());

    if let Some(best) = runs
        .iter()
        .min_by(|a, b| a.step_s.partial_cmp(&b.step_s).unwrap())
    {
        println!(
            "best: {} — {:.1} ms/step, {:.2}x vs scalar baseline",
            best.label,
            best.step_s * 1e3,
            baseline.step_s / best.step_s
        );
    }
    println!("\nhotpath OK");
}
