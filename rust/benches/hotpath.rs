//! Hot-path micro-benchmarks for the §Perf optimization pass.
//!
//! Times every host-side stage of the training pipeline in isolation
//! (sampling, edge values, layout, padding, feature synthesis, simulator,
//! executed CPU baseline) so the perf pass can attack the top bottleneck
//! and record before/after in EXPERIMENTS.md §Perf.
//!
//! Run: `cargo bench --offline --bench hotpath`

use hp_gnn::accel::{simulate_batch, AccelConfig, Platform, SimOptions};
use hp_gnn::graph::datasets;
use hp_gnn::layout::pad::{pad, EdgeOverflow};
use hp_gnn::layout::{index_batch, Geometry, LayoutOptions};
use hp_gnn::repro;
use hp_gnn::sampler::values::{attach_values, GnnModel};
use hp_gnn::sampler::{neighbor::NeighborSampler, Sampler};
use hp_gnn::util::bench::{black_box, Bench, BenchSet};
use hp_gnn::util::rng::Pcg64;

fn main() {
    let mut set = BenchSet::new("hotpath — host pipeline stages");
    let b = Bench::default();
    let ds = datasets::FLICKR;
    let g = repro::scaled_instance(&ds, 17);
    println!("instance: {} vertices, {} edges\n", g.num_vertices(), g.num_edges());

    // Paper-parameter NS batch (the heavy case).
    let sampler = NeighborSampler::paper_default();
    let mut rng = Pcg64::seed_from_u64(1);
    let m = b.run("sample (NS 1024x[25,10])", || black_box(sampler.sample(&g, &mut rng)));
    set.push(m, None);

    let mb = sampler.sample(&g, &mut Pcg64::seed_from_u64(2));
    println!(
        "batch: layers {:?}, edges {:?}",
        mb.layers.iter().map(|l| l.len()).collect::<Vec<_>>(),
        mb.edges.iter().map(|e| e.len()).collect::<Vec<_>>()
    );
    let m = b.run("attach_values gcn", || black_box(attach_values(&g, &mb, GnnModel::Gcn)));
    set.push(m, None);
    let m = b.run("attach_values sage", || black_box(attach_values(&g, &mb, GnnModel::Sage)));
    set.push(m, None);

    let vals = attach_values(&g, &mb, GnnModel::Gcn);
    let m = b.run("index_batch (RMT+RRA)", || {
        black_box(index_batch(&mb, &vals, LayoutOptions::all()))
    });
    set.push(m, None);
    let m = b.run("index_batch (baseline)", || {
        black_box(index_batch(&mb, &vals, LayoutOptions::none()))
    });
    set.push(m, None);

    let ib = index_batch(&mb, &vals, LayoutOptions::all());
    // Geometry big enough for this batch.
    let geom = Geometry {
        name: "bench".into(),
        b: mb.layers.iter().map(|l| l.len().next_multiple_of(64)).rev().collect::<Vec<_>>()
            .into_iter()
            .rev()
            .collect(),
        e: mb.edges.iter().map(|e| e.len().next_multiple_of(64)).collect(),
        f: vec![ds.f0, 256, ds.f2],
    };
    let labels = vec![0u8; mb.layers[2].len()];
    let m = b.run("pad to geometry", || {
        black_box(pad(&ib, &labels, &geom, EdgeOverflow::TruncateKeepSelf).unwrap())
    });
    set.push(m, None);

    let l0_labels = datasets::synth_labels(&mb.layers[0], ds.f2, 3, g.num_vertices());
    let m = b.run("synth_features (B^0 x 500)", || {
        black_box(datasets::synth_features(&mb.layers[0], &l0_labels, ds.f0, ds.f2, 3))
    });
    set.push(m, None);

    let platform = Platform::alveo_u250();
    let config = AccelConfig::paper_default();
    let m = b.run("simulate_batch (cycle-level)", || {
        black_box(simulate_batch(&platform, &config, &ib, &[ds.f0, 256, ds.f2], SimOptions::default()))
    });
    set.push(m, None);

    // Executed CPU training step (the Table 7 anchor) at reduced dims.
    let feats = vec![0.1f32; ib.layers[0].len() * 64];
    let quick = Bench::quick();
    let m = quick.run("executed CPU step (f=64)", || {
        black_box(hp_gnn::baselines::cpu::execute_batch(&ib, &[64, 32, 8], &feats, 4))
    });
    set.push(m, None);

    set.persist();
    println!("\nhotpath OK");
}
