//! Table 5 — resource utilization and parallelism chosen by the DSE
//! engine, per workload, side by side with the paper's published values.
//!
//! Run: `cargo bench --offline --bench table5_dse`

use hp_gnn::accel::Platform;
use hp_gnn::dse::{explore, DseProblem};
use hp_gnn::graph::datasets;
use hp_gnn::layout::LayoutOptions;
use hp_gnn::perf::{BatchGeometry, KappaEstimator, ModelShape, ResourceCoefficients};
use hp_gnn::repro::paper;
use hp_gnn::sampler::values::GnnModel;
use hp_gnn::util::bench::BenchSet;

fn main() {
    let mut set = BenchSet::new("Table 5 — DSE-chosen configuration + utilization");
    let platform = Platform::alveo_u250();
    // The paper reports one Table 5 column per workload; Reddit dims are
    // the representative middle case.
    let ds = datasets::REDDIT;

    println!(
        "{:<10} {:>14} {:>14} {:>22} {:>22}",
        "workload", "(m,n) paper", "(m,n) ours", "LUT/DSP paper", "LUT/DSP ours"
    );
    for (i, (sampler, model)) in
        [("NS", GnnModel::Gcn), ("NS", GnnModel::Sage), ("SS", GnnModel::Gcn), ("SS", GnnModel::Sage)]
            .into_iter()
            .enumerate()
    {
        let geom = match sampler {
            "NS" => BatchGeometry::neighbor_capped(1024, &[10, 25], ds.nodes),
            _ => {
                let kappa = KappaEstimator::from_stats(ds.nodes, ds.edges);
                BatchGeometry::subgraph(2750, 2, &kappa)
            }
        };
        let r = explore(
            &platform,
            &DseProblem {
                geom,
                model: ModelShape {
                    feat: vec![ds.f0, 256, ds.f2],
                    sage_concat: model == GnnModel::Sage,
                },
                layout: LayoutOptions::all(),
                coeff: ResourceCoefficients::default(),
                t_sampling_single: None,
            },
        );
        let (wl, pm, pn) = paper::TABLE5_CONFIG[i];
        let (_, plut, pdsp, puram, pbram) = paper::TABLE5_UTIL[i];
        println!(
            "{:<10} {:>14} {:>14} {:>22} {:>22}",
            wl,
            format!("({pm}, {pn})"),
            format!("({}, {})", r.config.m, r.config.n),
            format!("{:.0}% / {:.0}%", plut * 100.0, pdsp * 100.0),
            format!("{:.0}% / {:.0}%", r.utilization.lut * 100.0, r.utilization.dsp * 100.0),
        );
        println!(
            "{:<10} {:>14} {:>14} {:>22} {:>22}",
            "",
            "",
            "",
            format!("URAM {:.0}% BRAM {:.0}%", puram * 100.0, pbram * 100.0),
            format!(
                "URAM {:.0}% BRAM {:.0}%",
                r.utilization.uram * 100.0,
                r.utilization.bram * 100.0
            ),
        );
        set.row(&format!("{wl} m"), r.config.m as f64, "MACs");
        set.row(&format!("{wl} n"), r.config.n as f64, "PEs");
        set.row(&format!("{wl} dsp"), r.utilization.dsp, "frac");
        set.row(&format!("{wl} lut"), r.utilization.lut, "frac");

        // Shape assertions: m matches the paper exactly; utilization in
        // the same band; everything feasible.
        assert_eq!(r.config.m, pm, "{wl}: m disagrees with Table 5");
        assert!(r.utilization.fits());
        assert!((r.utilization.dsp - pdsp).abs() < 0.25, "{wl}: DSP far from paper");
    }
    println!(
        "\nNote: our analytic model is update-kernel-bound for these dims, so n ties \
         and the tie-break picks the smallest aggregation time (paper picks n=4/8; \
         see EXPERIMENTS.md §Table5)."
    );
    set.persist();
    println!("table5_dse OK");
}
