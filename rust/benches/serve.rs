//! Serving load generator: closed- and open-loop load against the
//! inference server, written to a self-validated `BENCH_serve.json`
//! (schema documented in the README "Serving" section).
//!
//! * **closed loop** — C client threads, each issuing blocking
//!   single-vertex requests back to back; measures sustainable
//!   throughput and the latency distribution under full load.
//! * **open loop** (full profile) — requests dispatched on a fixed
//!   arrival schedule regardless of completion, bounded by a client
//!   pool; measures latency at an offered rate below saturation.
//!
//! The run matrix pins the acceptance claim: `workers=4, max_batch>=64`
//! must sustain strictly higher closed-loop throughput than
//! `workers=1, max_batch=1` — micro-batch coalescing amortizes the
//! geometry-padded forward kernel, worker replicas add parallelism.  A
//! determinism cross-check asserts the two configurations serve
//! bit-identical logits.
//!
//! * **HTTP SLO trajectory** (both profiles) — an open-loop *network*
//!   load generator drives the real socket (`net::HttpServer` +
//!   `POST /v1/classify`) at fixed offered rates from below to ≥2× the
//!   measured saturation, on a deliberately shallow request queue.
//!   Past saturation the server must shed (`429` + `Retry-After`)
//!   rather than queue unboundedly, and the p99 of *accepted* requests
//!   must stay bounded — the admission-control acceptance claim,
//!   persisted as the `http` object in `BENCH_serve.json`.
//!
//! Run: `make bench-serve` or `cargo bench --bench serve`.  Knobs:
//!
//! * `SERVE_PROFILE=full|smoke` — smoke shrinks the request counts and
//!   skips the in-process open-loop section (CI's JSON-shape check).
//! * `SERVE_OUT=<path>` — where to write `BENCH_serve.json`.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use hp_gnn::graph::store::DynamicGraph;
use hp_gnn::graph::{generator, Graph};
use hp_gnn::net::{api_router, HttpClient, HttpOptions, HttpServer};
use hp_gnn::runtime::{Kind, Runtime, WeightState};
use hp_gnn::sampler::neighbor::NeighborSampler;
use hp_gnn::sampler::values::GnnModel;
use hp_gnn::serve::{ServeConfig, Server};
use hp_gnn::util::json::Json;
use hp_gnn::util::rng::Pcg64;
use hp_gnn::util::stats::Summary;

struct LoadResult {
    mode: &'static str,
    workers: usize,
    max_batch: usize,
    cache: bool,
    clients: usize,
    requests: usize,
    elapsed_s: f64,
    throughput_rps: f64,
    latency_p50_s: Option<f64>,
    latency_p95_s: Option<f64>,
    latency_p99_s: Option<f64>,
    latency_mean_s: Option<f64>,
    batches: u64,
    mean_batch_occupancy: Option<f64>,
    cache_hits: u64,
    queue_wait: StageDist,
    coalesce: StageDist,
    infer: StageDist,
}

/// Per-stage serving-time distribution, pulled from the metrics
/// histograms after a load run (queue wait, coalesce window, inference).
struct StageDist {
    count: u64,
    total_s: f64,
    mean_s: Option<f64>,
    p95_s: Option<f64>,
}

impl StageDist {
    fn from_snapshot(h: &hp_gnn::obs::HistogramSnapshot) -> StageDist {
        StageDist {
            count: h.count(),
            total_s: h.sum,
            mean_s: (h.count() > 0).then(|| h.mean()),
            p95_s: h.percentile(95.0),
        }
    }
}

fn main() {
    let profile = std::env::var("SERVE_PROFILE").unwrap_or_else(|_| "full".to_string());
    let out_path = std::env::var("SERVE_OUT").unwrap_or_else(|_| "BENCH_serve.json".to_string());
    let smoke = profile == "smoke";

    // Serving stack on the built-in "tiny" geometry: padded-kernel cost is
    // fixed per forward invocation, which is exactly what micro-batching
    // amortizes; tiny keeps the unbatched baseline affordable.
    let graph = Arc::new(bench_graph());
    let sampler = NeighborSampler::new(4, vec![5, 3]);
    let rt = Runtime::reference();
    let exe = rt.compile_role(GnnModel::Gcn, "tiny", Kind::Forward).expect("builtin role");
    let weights = WeightState::init_glorot(&exe.spec.weight_shapes, 7);

    let requests = if smoke { 128 } else { 512 };
    let clients = 8;

    // Closed-loop matrix: the acceptance pair plus a cache-on run.
    let mut runs = Vec::new();
    let baseline = closed_loop(&rt, &graph, &sampler, &weights, 1, 1, false, clients, requests);
    report(&baseline);
    runs.push(baseline);
    let batched = closed_loop(&rt, &graph, &sampler, &weights, 4, 64, false, clients, requests);
    report(&batched);
    runs.push(batched);
    let cached = closed_loop(&rt, &graph, &sampler, &weights, 4, 64, true, clients, requests);
    report(&cached);
    runs.push(cached);

    // Open loop at half the batched configuration's measured capacity.
    if !smoke {
        let rate = runs[1].throughput_rps * 0.5;
        let open = open_loop(&rt, &graph, &sampler, &weights, 4, 64, rate, 256);
        report(&open);
        runs.push(open);
    }

    // Acceptance: coalescing + replicas must beat the unbatched single
    // worker, and both configurations must serve identical logits.
    let speedup = runs[1].throughput_rps / runs[0].throughput_rps;
    assert!(
        speedup > 1.0,
        "workers=4/max_batch=64 ({:.0} rps) must beat workers=1/max_batch=1 ({:.0} rps)",
        runs[1].throughput_rps,
        runs[0].throughput_rps
    );
    println!("\ncoalescing speedup: {speedup:.2}x");
    let determinism = determinism_check(&rt, &graph, &sampler, &weights);
    println!("determinism check: {determinism}");

    // SLO trajectory over the real socket (runs in both profiles: CI's
    // smoke validates the recorded shape AND the shedding claim).
    let http = http_slo(&rt, &graph, &sampler, &weights, smoke);

    write_json(&out_path, &profile, &graph, &runs, speedup, determinism, &http);
}

/// Admission-control knobs of the HTTP SLO run: a deliberately shallow
/// queue so the sweep reaches the shedding regime quickly.
const HTTP_QUEUE_DEPTH: usize = 8;

struct HttpSloPoint {
    offered_rps: f64,
    requests: usize,
    accepted: usize,
    shed: usize,
    elapsed_s: f64,
    latency: Summary,
}

struct HttpSlo {
    saturation_rps: f64,
    points: Vec<HttpSloPoint>,
}

/// One classify request over an existing keep-alive connection,
/// reconnecting once if the server side closed it.  Returns the status.
fn http_classify(client: &mut Option<HttpClient>, addr: &str, vertex: u32) -> u16 {
    let body = Json::obj(vec![("vertex", Json::num(vertex as f64))]);
    for _ in 0..2 {
        if client.is_none() {
            *client = Some(HttpClient::connect(addr).expect("connect load generator"));
        }
        if let Some(c) = client.as_mut() {
            match c.request("POST", "/v1/classify", Some(&body)) {
                Ok(resp) => {
                    if resp.status == 429 {
                        assert!(
                            resp.header("retry-after").is_some(),
                            "shed responses must carry Retry-After"
                        );
                    }
                    return resp.status;
                }
                Err(_) => *client = None, // stale connection: reconnect once
            }
        }
    }
    panic!("load generator could not reach {addr}");
}

fn http_slo(
    rt: &Runtime,
    graph: &Arc<Graph>,
    sampler: &NeighborSampler,
    weights: &WeightState,
    smoke: bool,
) -> HttpSlo {
    let cfg = ServeConfig {
        workers: 4,
        max_batch: 64,
        max_wait: Duration::from_micros(25),
        queue_depth: HTTP_QUEUE_DEPTH,
        ..ServeConfig::default()
    };
    let srv = Arc::new(
        Server::start(
            rt,
            DynamicGraph::fixed(Arc::clone(graph)),
            Arc::new(sampler.clone()),
            cfg,
            weights.clone(),
        )
        .expect("server start"),
    );
    let router = Arc::new(api_router(Arc::clone(&srv)));
    let http = HttpServer::bind(
        "127.0.0.1:0",
        router,
        HttpOptions { workers: 8, log: false, ..HttpOptions::default() },
    )
    .expect("bind load-generator socket");
    let addr = http.addr().to_string();

    // Closed-loop saturation over the socket: 8 keep-alive clients
    // hammering single-vertex requests; sheds don't count as service.
    let sat_requests = if smoke { 256 } else { 768 };
    let sat_clients = 8;
    let accepted = Arc::new(Mutex::new(0usize));
    let t = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..sat_clients {
            let addr = addr.clone();
            let graph = Arc::clone(graph);
            let accepted = Arc::clone(&accepted);
            scope.spawn(move || {
                let mut client = None;
                let mut ok = 0usize;
                let mut i = c;
                while i < sat_requests {
                    if http_classify(&mut client, &addr, request_vertex(&graph, i)) == 200 {
                        ok += 1;
                    }
                    i += sat_clients;
                }
                *accepted.lock().unwrap() += ok;
            });
        }
    });
    let sat_elapsed = t.elapsed().as_secs_f64();
    let sat_accepted = *accepted.lock().unwrap();
    assert!(sat_accepted > 0, "saturation probe served nothing");
    let saturation_rps = sat_accepted as f64 / sat_elapsed.max(1e-12);
    println!(
        "\nhttp saturation: {saturation_rps:.0} accepted req/s \
         ({sat_accepted}/{sat_requests} over {sat_elapsed:.3}s, queue_depth={HTTP_QUEUE_DEPTH})"
    );

    // Open-loop sweep: fixed arrival schedules from half to ≥2× (full:
    // 3×) the measured saturation.
    let multipliers: &[f64] = if smoke { &[0.5, 2.0] } else { &[0.5, 1.0, 1.5, 2.0, 3.0] };
    let window_s = if smoke { 0.8 } else { 1.5 };
    let pool = 32; // outstanding-request bound (open-loop approximation)
    let mut points = Vec::new();
    for &mult in multipliers {
        let offered_rps = saturation_rps * mult;
        let requests =
            ((offered_rps * window_s) as usize).clamp(64, if smoke { 1500 } else { 6000 });
        let interval = Duration::from_secs_f64(1.0 / offered_rps.max(1.0));
        let tally = Arc::new(Mutex::new((0usize, 0usize, Summary::new())));
        let start = Instant::now();
        std::thread::scope(|scope| {
            for c in 0..pool {
                let addr = addr.clone();
                let graph = Arc::clone(graph);
                let tally = Arc::clone(&tally);
                scope.spawn(move || {
                    let mut client = None;
                    let (mut ok, mut shed) = (0usize, 0usize);
                    let mut lat = Summary::new();
                    let mut i = c;
                    while i < requests {
                        let due = start + interval * i as u32;
                        let now = Instant::now();
                        if due > now {
                            std::thread::sleep(due - now);
                        }
                        let t0 = Instant::now();
                        match http_classify(&mut client, &addr, request_vertex(&graph, i)) {
                            200 => {
                                ok += 1;
                                lat.add(t0.elapsed().as_secs_f64());
                            }
                            429 => shed += 1,
                            other => panic!("unexpected status {other}"),
                        }
                        i += pool;
                    }
                    let mut guard = tally.lock().unwrap();
                    guard.0 += ok;
                    guard.1 += shed;
                    guard.2.merge(&lat);
                });
            }
        });
        let elapsed_s = start.elapsed().as_secs_f64();
        let (ok, shed, latency) = {
            let guard = tally.lock().unwrap();
            (guard.0, guard.1, guard.2.clone())
        };
        let point = HttpSloPoint {
            offered_rps,
            requests,
            accepted: ok,
            shed,
            elapsed_s,
            latency,
        };
        println!(
            "http open loop  offered {:>7.0} rps ({mult:.1}x)  {:>5} req  accepted {:>5}  \
             shed {:>5} ({:>5.1}%)  p50 {:>8.1}us  p99 {:>8.1}us",
            point.offered_rps,
            point.requests,
            point.accepted,
            point.shed,
            100.0 * point.shed as f64 / point.requests as f64,
            point.latency.percentile(50.0).unwrap_or(f64::NAN) * 1e6,
            point.latency.percentile(99.0).unwrap_or(f64::NAN) * 1e6,
        );
        points.push(point);
    }
    http.shutdown();
    drop(addr);
    Arc::into_inner(srv).expect("all clients joined").shutdown();

    // Acceptance: past 2× saturation the server sheds instead of
    // queueing, and accepted-request p99 stays bounded by the shallow
    // queue (not by the offered backlog).
    let over = points
        .iter()
        .filter(|p| p.offered_rps >= 2.0 * saturation_rps - 1e-9)
        .collect::<Vec<_>>();
    assert!(!over.is_empty(), "sweep must include an offered rate >= 2x saturation");
    for p in over {
        assert!(p.accepted > 0, "overload must still serve admitted requests");
        assert!(
            p.shed > 0,
            "offered {:.0} rps >= 2x saturation ({saturation_rps:.0} rps) must shed",
            p.offered_rps
        );
        let p99 = p.latency.percentile(99.0).expect("accepted latency samples");
        assert!(
            p99 < 0.5,
            "accepted p99 {p99:.3}s unbounded under overload — admission control broken"
        );
    }
    HttpSlo { saturation_rps, points }
}

fn bench_graph() -> Graph {
    let mut g = generator::with_min_degree(
        generator::rmat(2000, 16_000, Default::default(), 21),
        1,
        22,
    );
    g.feat_dim = 16;
    g.num_classes = 4;
    g.name = "serve-bench".to_string();
    g
}

fn server(
    rt: &Runtime,
    graph: &Arc<Graph>,
    sampler: &NeighborSampler,
    weights: &WeightState,
    workers: usize,
    max_batch: usize,
    cache: bool,
) -> Server {
    // The coalescing deadline must stay well under the kernel cost, or
    // the batched configuration pays more in waiting than it saves in
    // amortization (tiny-geometry forwards run in tens of microseconds).
    let cfg = ServeConfig {
        workers,
        max_batch,
        max_wait: Duration::from_micros(25),
        cache,
        ..ServeConfig::default()
    };
    Server::start(
        rt,
        DynamicGraph::fixed(Arc::clone(graph)),
        Arc::new(sampler.clone()),
        cfg,
        weights.clone(),
    )
    .expect("server start")
}

/// Deterministic request stream `i -> vertex` shared by every run, drawn
/// from a pool with repeats so the cache run has hits to find.
fn request_vertex(graph: &Graph, i: usize) -> u32 {
    let pool = 256.min(graph.num_vertices());
    let mut rng = Pcg64::seed_from_u64(0x10ad ^ i as u64);
    (rng.index(pool)) as u32
}

#[allow(clippy::too_many_arguments)]
fn closed_loop(
    rt: &Runtime,
    graph: &Arc<Graph>,
    sampler: &NeighborSampler,
    weights: &WeightState,
    workers: usize,
    max_batch: usize,
    cache: bool,
    clients: usize,
    requests: usize,
) -> LoadResult {
    let srv = Arc::new(server(rt, graph, sampler, weights, workers, max_batch, cache));
    let t = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let srv = Arc::clone(&srv);
            let graph = Arc::clone(graph);
            scope.spawn(move || {
                // Client c issues requests c, c+clients, c+2*clients, ...
                let mut i = c;
                while i < requests {
                    srv.classify_one(request_vertex(&graph, i)).expect("classify");
                    i += clients;
                }
            });
        }
    });
    let elapsed_s = t.elapsed().as_secs_f64();
    finish("closed", srv, workers, max_batch, cache, clients, requests, elapsed_s)
}

#[allow(clippy::too_many_arguments)]
fn open_loop(
    rt: &Runtime,
    graph: &Arc<Graph>,
    sampler: &NeighborSampler,
    weights: &WeightState,
    workers: usize,
    max_batch: usize,
    rate_rps: f64,
    requests: usize,
) -> LoadResult {
    let srv = Arc::new(server(rt, graph, sampler, weights, workers, max_batch, false));
    let clients = 16; // outstanding-request bound
    let interval = Duration::from_secs_f64(1.0 / rate_rps.max(1.0));
    let start = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let srv = Arc::clone(&srv);
            let graph = Arc::clone(graph);
            scope.spawn(move || {
                let mut i = c;
                while i < requests {
                    // Fixed arrival schedule: request i fires at i*interval
                    // no matter how long earlier requests took.
                    let due = start + interval * i as u32;
                    let now = Instant::now();
                    if due > now {
                        std::thread::sleep(due - now);
                    }
                    srv.classify_one(request_vertex(&graph, i)).expect("classify");
                    i += clients;
                }
            });
        }
    });
    let elapsed_s = start.elapsed().as_secs_f64();
    finish("open", srv, workers, max_batch, false, clients, requests, elapsed_s)
}

#[allow(clippy::too_many_arguments)]
fn finish(
    mode: &'static str,
    srv: Arc<Server>,
    workers: usize,
    max_batch: usize,
    cache: bool,
    clients: usize,
    requests: usize,
    elapsed_s: f64,
) -> LoadResult {
    let m = srv.metrics();
    let result = LoadResult {
        mode,
        workers,
        max_batch,
        cache,
        clients,
        requests,
        elapsed_s,
        throughput_rps: requests as f64 / elapsed_s.max(1e-12),
        latency_p50_s: m.latency_p50_s(),
        latency_p95_s: m.latency_p95_s(),
        latency_p99_s: m.latency_p99_s(),
        latency_mean_s: (m.latency.count() > 0).then(|| m.latency.mean()),
        batches: m.batches,
        mean_batch_occupancy: m.mean_occupancy(),
        cache_hits: m.cache_hits,
        queue_wait: StageDist::from_snapshot(&m.queue_wait),
        coalesce: StageDist::from_snapshot(&m.coalesce),
        infer: StageDist::from_snapshot(&m.exec),
    };
    Arc::into_inner(srv).expect("all clients joined").shutdown();
    result
}

fn report(r: &LoadResult) {
    println!(
        "{:>6} loop  workers={} max_batch={:<3} cache={:<5} clients={:<2} \
         {:>5} req in {:>7.3}s  {:>8.0} req/s  p50 {:>8.1}us  p99 {:>8.1}us  \
         occupancy {:.1}",
        r.mode,
        r.workers,
        r.max_batch,
        r.cache,
        r.clients,
        r.requests,
        r.elapsed_s,
        r.throughput_rps,
        r.latency_p50_s.unwrap_or(f64::NAN) * 1e6,
        r.latency_p99_s.unwrap_or(f64::NAN) * 1e6,
        r.mean_batch_occupancy.unwrap_or(f64::NAN),
    );
}

/// Serve the same vertices under the two acceptance configurations and
/// assert bit-identical logits (the serving determinism invariant).
fn determinism_check(
    rt: &Runtime,
    graph: &Arc<Graph>,
    sampler: &NeighborSampler,
    weights: &WeightState,
) -> &'static str {
    let verts: Vec<u32> = (0..16).map(|i| request_vertex(graph, i * 13)).collect();
    let mut distinct: Vec<u32> = verts.clone();
    distinct.sort_unstable();
    distinct.dedup();
    let a = server(rt, graph, sampler, weights, 1, 1, false);
    let singles: Vec<Vec<f32>> = distinct
        .iter()
        .map(|&v| a.classify_one(v).expect("solo classify").logits.clone())
        .collect();
    a.shutdown();
    let b = server(rt, graph, sampler, weights, 4, 64, false);
    let bulk = b.classify(&distinct).expect("bulk classify");
    b.shutdown();
    for (j, p) in bulk.iter().enumerate() {
        assert_eq!(
            p.logits, singles[j],
            "vertex {} served different logits under coalescing",
            distinct[j]
        );
    }
    "bit-identical"
}

fn opt_num(x: Option<f64>) -> Json {
    x.map(Json::num).unwrap_or(Json::Null)
}

fn stage_json(s: &StageDist) -> Json {
    Json::obj(vec![
        ("count", Json::num(s.count as f64)),
        ("total_s", Json::num(s.total_s)),
        ("mean_s", opt_num(s.mean_s)),
        ("p95_s", opt_num(s.p95_s)),
    ])
}

fn write_json(
    out_path: &str,
    profile: &str,
    graph: &Graph,
    runs: &[LoadResult],
    speedup: f64,
    determinism: &str,
    http: &HttpSlo,
) {
    let run_json = |r: &LoadResult| {
        Json::obj(vec![
            ("mode", Json::str(r.mode)),
            ("workers", Json::num(r.workers as f64)),
            ("max_batch", Json::num(r.max_batch as f64)),
            ("cache", Json::Bool(r.cache)),
            ("clients", Json::num(r.clients as f64)),
            ("requests", Json::num(r.requests as f64)),
            ("elapsed_s", Json::num(r.elapsed_s)),
            ("throughput_rps", Json::num(r.throughput_rps)),
            (
                "latency_s",
                Json::obj(vec![
                    ("mean", opt_num(r.latency_mean_s)),
                    ("p50", opt_num(r.latency_p50_s)),
                    ("p95", opt_num(r.latency_p95_s)),
                    ("p99", opt_num(r.latency_p99_s)),
                ]),
            ),
            ("batches", Json::num(r.batches as f64)),
            ("mean_batch_occupancy", opt_num(r.mean_batch_occupancy)),
            ("cache_hits", Json::num(r.cache_hits as f64)),
            (
                "stages",
                Json::obj(vec![
                    ("queue_wait_s", stage_json(&r.queue_wait)),
                    ("coalesce_s", stage_json(&r.coalesce)),
                    ("infer_s", stage_json(&r.infer)),
                ]),
            ),
        ])
    };
    let doc = Json::obj(vec![
        ("bench", Json::str("serve-loadgen")),
        ("schema_version", Json::num(2.0)),
        ("profile", Json::str(profile)),
        ("model", Json::str("gcn")),
        ("geometry", Json::str("tiny")),
        (
            "graph",
            Json::obj(vec![
                ("vertices", Json::num(graph.num_vertices() as f64)),
                ("edges", Json::num(graph.num_edges() as f64)),
            ]),
        ),
        ("coalescing_speedup", Json::num(speedup)),
        ("determinism", Json::str(determinism)),
        ("runs", Json::arr(runs.iter().map(run_json).collect())),
        (
            "http",
            Json::obj(vec![
                ("queue_depth", Json::num(HTTP_QUEUE_DEPTH as f64)),
                ("saturation_rps", Json::num(http.saturation_rps)),
                (
                    "slo",
                    Json::arr(
                        http.points
                            .iter()
                            .map(|p| {
                                Json::obj(vec![
                                    ("offered_rps", Json::num(p.offered_rps)),
                                    ("requests", Json::num(p.requests as f64)),
                                    ("accepted", Json::num(p.accepted as f64)),
                                    ("shed", Json::num(p.shed as f64)),
                                    (
                                        "shed_rate",
                                        Json::num(p.shed as f64 / p.requests.max(1) as f64),
                                    ),
                                    (
                                        "achieved_rps",
                                        Json::num(p.accepted as f64 / p.elapsed_s.max(1e-12)),
                                    ),
                                    (
                                        "latency_s",
                                        Json::obj(vec![
                                            ("p50", opt_num(p.latency.percentile(50.0))),
                                            ("p95", opt_num(p.latency.percentile(95.0))),
                                            ("p99", opt_num(p.latency.percentile(99.0))),
                                        ]),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
    ]);
    std::fs::write(out_path, doc.pretty()).expect("write BENCH_serve.json");

    // Self-validate the written file so the schema can't silently rot.
    let text = std::fs::read_to_string(out_path).expect("read back");
    let parsed = Json::parse(&text).expect("BENCH_serve.json must parse");
    for key in ["bench", "profile", "geometry", "coalescing_speedup", "determinism", "runs"] {
        parsed.get(key).unwrap_or_else(|e| panic!("missing {key}: {e:?}"));
    }
    assert_eq!(parsed.get("bench").unwrap().as_str().unwrap(), "serve-loadgen");
    let runs_arr = parsed.get("runs").unwrap().as_arr().expect("runs array");
    assert!(runs_arr.len() >= 2, "need the acceptance pair");
    let find = |workers: f64, max_batch: f64| {
        runs_arr
            .iter()
            .find(|r| {
                r.get("mode").unwrap().as_str().unwrap() == "closed"
                    && r.get("workers").unwrap().as_f64().unwrap() == workers
                    && r.get("max_batch").unwrap().as_f64().unwrap() == max_batch
            })
            .unwrap_or_else(|| panic!("no closed-loop run with workers={workers}"))
    };
    let base = find(1.0, 1.0).get("throughput_rps").unwrap().as_f64().unwrap();
    let batched = find(4.0, 64.0).get("throughput_rps").unwrap().as_f64().unwrap();
    assert!(batched > base, "persisted acceptance violated: {batched} <= {base}");
    for r in runs_arr {
        assert!(r.get("throughput_rps").unwrap().as_f64().unwrap() > 0.0);
        assert!(r.get("elapsed_s").unwrap().as_f64().unwrap() > 0.0);
        let st = r.get("stages").unwrap_or_else(|e| panic!("run missing stages: {e:?}"));
        for stage in ["queue_wait_s", "coalesce_s", "infer_s"] {
            let d = st.get(stage).unwrap_or_else(|e| panic!("missing stage {stage}: {e:?}"));
            assert!(d.get("count").unwrap().as_f64().unwrap() >= 0.0, "{stage}: count");
            assert!(d.get("total_s").unwrap().as_f64().unwrap() >= 0.0, "{stage}: total_s");
        }
    }
    // The batched acceptance run must have actually timed inference.
    let batched_stages = find(4.0, 64.0).get("stages").unwrap();
    assert!(
        batched_stages.get("infer_s").unwrap().get("count").unwrap().as_f64().unwrap() > 0.0,
        "batched run recorded no inference stage"
    );
    assert_eq!(parsed.get("determinism").unwrap().as_str().unwrap(), "bit-identical");

    // The persisted SLO trajectory must carry the admission-control
    // acceptance: shedding past 2x saturation, bounded accepted p99.
    let http_json = parsed.get("http").expect("http section");
    let sat = http_json.get("saturation_rps").unwrap().as_f64().unwrap();
    assert!(sat > 0.0, "saturation must be positive");
    let slo = http_json.get("slo").unwrap().as_arr().expect("slo array");
    assert!(!slo.is_empty(), "slo trajectory must have points");
    let mut over_saturated = 0;
    for p in slo {
        for key in ["offered_rps", "requests", "accepted", "shed", "shed_rate", "achieved_rps"] {
            assert!(p.get(key).unwrap().as_f64().unwrap() >= 0.0, "bad {key}");
        }
        let lat = p.get("latency_s").unwrap();
        let offered = p.get("offered_rps").unwrap().as_f64().unwrap();
        if offered >= 2.0 * sat - 1e-9 {
            over_saturated += 1;
            assert!(p.get("shed").unwrap().as_f64().unwrap() > 0.0, "no shed past 2x");
            let p99 = lat.get("p99").unwrap().as_f64().expect("accepted p99");
            assert!(p99 < 0.5, "persisted accepted p99 {p99}s unbounded");
        }
    }
    assert!(over_saturated >= 1, "trajectory must reach 2x saturation");
    println!(
        "\nwrote {out_path} (validated, {} runs + {}-point SLO trajectory)\nserve OK",
        runs_arr.len(),
        slo.len()
    );
}
