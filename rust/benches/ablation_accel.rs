//! Ablation of the simulator's microarchitectural design choices —
//! the knobs DESIGN.md calls out that are *not* part of the paper's DSE
//! space (which sweeps only m and n).  Each row isolates one knob on the
//! same FL NS-GCN batch.
//!
//! Run: `cargo bench --offline --bench ablation_accel`

use hp_gnn::accel::device::FeaturePlacement;
use hp_gnn::accel::{simulate_batch, AccelConfig, Platform, SimOptions};
use hp_gnn::graph::datasets;
use hp_gnn::layout::{index_batch, IndexedBatch, LayoutOptions};
use hp_gnn::repro;
use hp_gnn::sampler::values::{attach_values, GnnModel};
use hp_gnn::sampler::{neighbor::NeighborSampler, Sampler};
use hp_gnn::util::bench::BenchSet;
use hp_gnn::util::rng::Pcg64;
use hp_gnn::util::si;

fn batch(g: &hp_gnn::graph::Graph) -> IndexedBatch {
    let mb = NeighborSampler::paper_default().sample(g, &mut Pcg64::seed_from_u64(5));
    let vals = attach_values(g, &mb, GnnModel::Gcn);
    index_batch(&mb, &vals, LayoutOptions::all())
}

fn main() {
    let mut set = BenchSet::new("accelerator design-choice ablations (FL, NS-GCN)");
    let ds = datasets::FLICKR;
    let g = repro::scaled_instance(&ds, 77);
    let ib = batch(&g);
    let verts = ib.vertices_traversed();
    let feat = [ds.f0, 256, ds.f2];
    let cfg = AccelConfig::paper_default();

    let nvtps = |platform: &Platform, opts: SimOptions| {
        let t = simulate_batch(platform, &cfg, &ib, &feat, opts);
        t.nvtps(verts, 0.0)
    };
    let base_platform = Platform::alveo_u250();
    let base = nvtps(&base_platform, SimOptions::default());
    set.row("baseline (raw=4, lanes=16, dies=4, local)", base, "NVTPS");

    // RAW-resolver pipeline depth: deeper accumulators stall more on
    // repeated destinations.
    for depth in [0u64, 16, 64] {
        let v = nvtps(&base_platform, SimOptions { raw_depth: depth, ..Default::default() });
        set.row(&format!("raw_depth={depth}"), v, "NVTPS");
        if depth > 4 {
            assert!(v <= base * 1.001, "deeper RAW pipeline cannot be faster");
        }
    }

    // Scatter-PE lane width (the paper's 16): wider lanes shorten flits.
    for lanes in [8usize, 32, 64] {
        let v = nvtps(&base_platform, SimOptions { lanes, ..Default::default() });
        set.row(&format!("lanes={lanes}"), v, "NVTPS");
    }
    let narrow = nvtps(&base_platform, SimOptions { lanes: 8, ..Default::default() });
    let wide = nvtps(&base_platform, SimOptions { lanes: 64, ..Default::default() });
    assert!(wide >= narrow, "wider lanes must not slow aggregation");

    // Die count (Fig. 7 replication) at fixed per-die config.
    for dies in [1usize, 2, 8] {
        let mut p = Platform::alveo_u250();
        p.dies = dies;
        let v = nvtps(&p, SimOptions::default());
        set.row(&format!("dies={dies}"), v, "NVTPS");
    }
    let mut one_die = Platform::alveo_u250();
    one_die.dies = 1;
    assert!(
        base > nvtps(&one_die, SimOptions::default()) * 1.5,
        "4-die replication must clearly beat 1 die"
    );

    // Cross-channel interconnect efficiency (vendor all-to-all quality).
    for eff in [0.5f64, 1.0] {
        let mut p = Platform::alveo_u250();
        p.cross_channel_efficiency = eff;
        let v = nvtps(&p, SimOptions::default());
        set.row(&format!("xchannel_eff={eff}"), v, "NVTPS");
    }

    // Feature placement (DistributeData): PCIe streaming for huge graphs.
    let streamed = nvtps(
        &base_platform,
        SimOptions { placement: FeaturePlacement::HostStreamed, ..Default::default() },
    );
    set.row("placement=host-streamed", streamed, "NVTPS");
    assert!(streamed < base, "PCIe streaming must cost throughput");

    println!(
        "\nbaseline {} NVTPS; knobs move throughput as annotated above",
        si(base)
    );
    set.persist();
    println!("ablation_accel OK");
}
