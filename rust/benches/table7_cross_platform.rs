//! Table 7 — cross-platform training throughput (CPU, CPU-GPU, CPU-FPGA)
//! for 2 samplers × 2 models × 4 datasets.
//!
//! * CPU column: analytic PyG/3990x model (plus one *executed* rust CPU
//!   measurement on the Flickr instance as a sanity anchor).
//! * CPU-GPU column: analytic A100 model (no GPU in this environment),
//!   including the OoM rule that reproduces the paper's two OoM cells.
//! * CPU-FPGA column: cycle-level simulation of real sampled edge streams
//!   with the Table 5 configuration.
//!
//! Run: `cargo bench --offline --bench table7_cross_platform`

use hp_gnn::baselines::{cpu, gpu, Calibration};
use hp_gnn::graph::datasets;
use hp_gnn::layout::{index_batch, LayoutOptions};
use hp_gnn::perf::{BatchGeometry, ModelShape};
use hp_gnn::repro::{self, paper, EvalSampler};
use hp_gnn::sampler::values::{attach_values, GnnModel};
use hp_gnn::util::bench::BenchSet;
use hp_gnn::util::rng::Pcg64;
use hp_gnn::util::si;

fn paper_geom(
    ds: &datasets::DatasetSpec,
    g: &hp_gnn::graph::Graph,
    sampler: EvalSampler,
) -> BatchGeometry {
    match sampler {
        EvalSampler::Ns => BatchGeometry::neighbor_capped(1024, &[10, 25], ds.nodes),
        EvalSampler::Ss => {
            // κ fitted on the instance, rescaled to full-dataset size
            // (from_stats underestimates heavy-tail density >10x).
            let kappa = repro::fitted_kappa_fullscale(g, ds);
            BatchGeometry::subgraph(2750, 2, &kappa)
        }
    }
}

fn main() {
    let mut set = BenchSet::new("Table 7 — cross-platform throughput");
    let platform = hp_gnn::accel::Platform::alveo_u250();
    let cal = Calibration::default();
    let a100 = gpu::GpuSpec::a100();
    const BATCHES: usize = 2;

    // Scaled instances are shared across the 4 workloads per dataset.
    let instances: Vec<_> = datasets::ALL
        .iter()
        .enumerate()
        .map(|(i, ds)| (ds, repro::scaled_instance(ds, 200 + i as u64)))
        .collect();

    println!(
        "{:<8} {:<3} {:>20} {:>20} {:>20} {:>8}",
        "workload", "ds", "CPU (paper|ours)", "GPU (paper|ours)", "FPGA (paper|ours)", "F/G ours"
    );
    let mut row_idx = 0;
    let mut speedup_cpu = Vec::new();
    let mut speedup_gpu = Vec::new();
    for (sampler, model) in [
        (EvalSampler::Ns, GnnModel::Gcn),
        (EvalSampler::Ns, GnnModel::Sage),
        (EvalSampler::Ss, GnnModel::Gcn),
        (EvalSampler::Ss, GnnModel::Sage),
    ] {
        for (ds, g) in &instances {
            let geom = paper_geom(ds, g, sampler);
            let shape = ModelShape {
                feat: vec![ds.f0, 256, ds.f2],
                sage_concat: model == GnnModel::Sage,
            };
            // CPU column (analytic, paper-scale geometry).
            let cpu_nvtps = cpu::model_nvtps(&platform.host, &geom, &shape, &cal);
            // CPU-GPU column.
            let gpu_out = gpu::model_nvtps(
                &a100,
                ds,
                &geom,
                &shape,
                sampler == EvalSampler::Ss,
                &cal,
            );
            // CPU-FPGA column: simulated from real streams.
            let config = repro::table5_config(sampler, model);
            let fpga = repro::simulate_workload(
                g,
                ds,
                model,
                sampler,
                LayoutOptions::all(),
                &config,
                BATCHES,
                11,
            );

            let (wl, dskey, pcpu, pgpu, pfpga) = paper::TABLE7[row_idx];
            assert_eq!(dskey, ds.key);
            let gpu_str = match (pgpu, gpu_out) {
                (Some(p), gpu::GpuOutcome::Nvtps(o)) => format!("{} | {}", si(p), si(o)),
                (None, gpu::GpuOutcome::OutOfMemory) => "OoM | OoM".to_string(),
                (p, o) => format!("{p:?} | {o:?} (MISMATCH)"),
            };
            println!(
                "{:<8} {:<3} {:>20} {:>20} {:>20} {:>8}",
                wl,
                ds.key,
                format!("{} | {}", si(pcpu), si(cpu_nvtps)),
                gpu_str,
                format!("{} | {}", si(pfpga), si(fpga.nvtps)),
                match gpu_out {
                    gpu::GpuOutcome::Nvtps(o) => format!("{:.1}x", fpga.nvtps / o),
                    _ => "-".into(),
                }
            );
            set.row(&format!("{wl}/{} cpu", ds.key), cpu_nvtps, "NVTPS");
            set.row(&format!("{wl}/{} fpga", ds.key), fpga.nvtps, "NVTPS");

            // Shape assertions (who wins).
            assert!(fpga.nvtps > cpu_nvtps, "{wl}/{}: FPGA must beat CPU", ds.key);
            speedup_cpu.push(fpga.nvtps / cpu_nvtps);
            if let gpu::GpuOutcome::Nvtps(o) = gpu_out {
                assert!(o > cpu_nvtps, "{wl}/{}: GPU must beat CPU", ds.key);
                assert!(fpga.nvtps > o * 0.5, "{wl}/{}: FPGA collapsed vs GPU", ds.key);
                speedup_gpu.push(fpga.nvtps / o);
            }
            // OoM cells must match the paper exactly.
            assert_eq!(
                pgpu.is_none(),
                matches!(gpu_out, gpu::GpuOutcome::OutOfMemory),
                "{wl}/{}: OoM mismatch",
                ds.key
            );
            row_idx += 1;
        }
    }

    // Executed-CPU sanity anchor (real rust training math, Flickr scale).
    let (ds, g) = &instances[0];
    let s = EvalSampler::Ns.build();
    let mut rng = Pcg64::seed_from_u64(5);
    let mb = s.sample(g, &mut rng);
    let vals = attach_values(g, &mb, GnnModel::Gcn);
    let ib = index_batch(&mb, &vals, LayoutOptions::all());
    let feats = vec![0.1f32; ib.layers[0].len() * ds.f0];
    let (t, _) = cpu::execute_batch(&ib, &[ds.f0, 256, ds.f2], &feats, 4);
    let executed = ib.vertices_traversed() as f64 / t;
    println!(
        "\nexecuted rust CPU anchor (FL, NS-GCN, this host): {} NVTPS \
         (paper's PyG/3990x: 265.5K)",
        si(executed)
    );
    set.row("executed-cpu FL NS-GCN", executed, "NVTPS");

    let avg_cpu = speedup_cpu.iter().sum::<f64>() / speedup_cpu.len() as f64;
    let avg_gpu = speedup_gpu.iter().sum::<f64>() / speedup_gpu.len() as f64;
    println!(
        "average CPU-FPGA speedup: over CPU {avg_cpu:.1}x (paper {}), over GPU {avg_gpu:.2}x (paper {})",
        paper::AVG_SPEEDUP_OVER_CPU,
        paper::AVG_SPEEDUP_OVER_GPU
    );
    set.row("avg speedup over cpu", avg_cpu, "x");
    set.row("avg speedup over gpu", avg_gpu, "x");
    assert!(avg_cpu > 5.0, "FPGA speedup over CPU collapsed: {avg_cpu:.1}");
    assert!(avg_gpu > 0.8, "FPGA should at least match GPU on average: {avg_gpu:.2}");
    set.persist();
    println!("table7_cross_platform OK");
}
