//! Cross-module property tests (no artifacts needed): randomized
//! invariants that the per-module unit tests don't cover.

use hp_gnn::accel::aggregate::AggregateSim;
use hp_gnn::graph::generator;
use hp_gnn::layout::pad::{pad, EdgeOverflow};
use hp_gnn::layout::{index_batch, Geometry, LayoutOptions};
use hp_gnn::sampler::values::{attach_values, GnnModel};
use hp_gnn::sampler::{neighbor::NeighborSampler, subgraph::SubgraphSampler, Sampler};
use hp_gnn::util::json::Json;
use hp_gnn::util::prop::Runner;
use hp_gnn::util::rng::Pcg64;

#[test]
fn property_padding_preserves_real_prefix() {
    Runner::new(24, 0xa11).run(
        |rng| {
            let n = 100 + rng.index(400);
            let seed = rng.next_u64();
            let targets = 1 + rng.index(6);
            (n, seed, targets)
        },
        |&(n, seed, targets)| {
            let g = generator::with_min_degree(
                generator::uniform(n, n * 6, true, seed),
                1,
                seed ^ 1,
            );
            let s = NeighborSampler::new(targets, vec![4, 3]);
            let mb = s.sample(&g, &mut Pcg64::seed_from_u64(seed ^ 2));
            let vals = attach_values(&g, &mb, GnnModel::Gcn);
            let ib = index_batch(&mb, &vals, LayoutOptions::all());
            let geom = Geometry {
                name: "p".into(),
                b: vec![
                    mb.layers[0].len() + 7,
                    mb.layers[1].len() + 5,
                    mb.layers[2].len() + 3,
                ],
                e: vec![ib.layer_edges[0].src.len() + 9, ib.layer_edges[1].src.len() + 2],
                f: vec![8, 4, 2],
            };
            let labels = vec![1u8; mb.layers[2].len()];
            let pb = pad(&ib, &labels, &geom, EdgeOverflow::Error).map_err(|e| e.to_string())?;
            // Real prefix intact, padding zeroed.
            for l in 0..2 {
                for i in 0..pb.real_e[l] {
                    if pb.src[l][i] as u32 != ib.layer_edges[l].src[i]
                        || pb.dst[l][i] as u32 != ib.layer_edges[l].dst[i]
                        || pb.val[l][i] != ib.layer_edges[l].val[i]
                    {
                        return Err(format!("layer {l} edge {i} mutated by padding"));
                    }
                }
                for i in pb.real_e[l]..geom.e[l] {
                    if pb.val[l][i] != 0.0 {
                        return Err(format!("layer {l} pad slot {i} has nonzero value"));
                    }
                }
            }
            let real_t = pb.real_b[2];
            if pb.mask[..real_t].iter().any(|&m| m != 1.0)
                || pb.mask[real_t..].iter().any(|&m| m != 0.0)
            {
                return Err("mask does not split real/pad targets".into());
            }
            Ok(())
        },
    );
}

#[test]
fn property_aggregate_sim_monotone_in_edges() {
    // Appending edges to a stream never reduces simulated cycles.
    Runner::new(32, 0xa22).run(
        |rng| {
            let e = 8 + rng.index(256);
            let extra = 1 + rng.index(64);
            let n_out = 4 + rng.index(60);
            let n_pe = 1usize << rng.index(4);
            let seed = rng.next_u64();
            (e, extra, n_out, n_pe, seed)
        },
        |&(e, extra, n_out, n_pe, seed)| {
            let mut rng = Pcg64::seed_from_u64(seed);
            let mk = |rng: &mut Pcg64, count: usize| {
                (0..count)
                    .map(|_| (rng.index(200) as u32, rng.index(n_out) as u32))
                    .unzip::<u32, u32, Vec<u32>, Vec<u32>>()
            };
            let (mut src, mut dst) = mk(&mut rng, e);
            let sim = AggregateSim { n: n_pe, lanes: 16, raw_depth: 4 };
            let short = sim.run(&src, &dst, 64);
            let (s2, d2) = mk(&mut rng, extra);
            src.extend(s2);
            dst.extend(d2);
            let long = sim.run(&src, &dst, 64);
            if long.cycles < short.cycles {
                return Err(format!(
                    "cycles decreased with more edges: {} -> {}",
                    short.cycles, long.cycles
                ));
            }
            if long.loads < short.loads {
                return Err("loads decreased with more edges".into());
            }
            Ok(())
        },
    );
}

#[test]
fn property_json_round_trip_fuzz() {
    // parse(pretty(v)) == v for randomly generated documents.
    fn gen_value(rng: &mut Pcg64, depth: usize) -> Json {
        match if depth == 0 { rng.index(4) } else { rng.index(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.index(2) == 0),
            2 => {
                // Exact-in-f64 numbers (round-trip must be identity).
                Json::num((rng.index(2_000_001) as f64 - 1e6) / 4.0)
            }
            3 => {
                let len = rng.index(12);
                let s: String = (0..len)
                    .map(|_| {
                        let c = rng.index(96) as u8 + 32;
                        c as char
                    })
                    .collect();
                Json::str(s)
            }
            4 => Json::arr((0..rng.index(5)).map(|_| gen_value(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.index(5))
                    .map(|i| (format!("k{i}"), gen_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    Runner::new(200, 0xa33).run(
        |rng| gen_value(rng, 3),
        |v| {
            let pretty = Json::parse(&v.pretty()).map_err(|e| e.to_string())?;
            let compact = Json::parse(&v.compact()).map_err(|e| e.to_string())?;
            if &pretty != v || &compact != v {
                return Err(format!("round trip changed value: {v}"));
            }
            Ok(())
        },
    );
}

#[test]
fn property_subgraph_edges_scale_with_budget() {
    // Bigger budgets induce at least as many edges (same graph, same seed).
    Runner::new(16, 0xa44).run(
        |rng| (400 + rng.index(800), rng.next_u64(), 16 + rng.index(64)),
        |&(n, seed, sb)| {
            let g = generator::rmat(n, n * 8, Default::default(), seed);
            let small = SubgraphSampler::new(sb, 1).sample(&g, &mut Pcg64::seed_from_u64(3));
            let big = SubgraphSampler::new(sb * 2, 1).sample(&g, &mut Pcg64::seed_from_u64(3));
            if big.edges[0].len() < small.edges[0].len() {
                return Err(format!(
                    "edges shrank with bigger budget: {} -> {}",
                    small.edges[0].len(),
                    big.edges[0].len()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn property_layout_semantics_invariant_under_options() {
    // For random batches, positional aggregation results are identical
    // across all four layout-option combinations.
    Runner::new(16, 0xa55).run(
        |rng| (200 + rng.index(300), rng.next_u64()),
        |&(n, seed)| {
            let g = generator::with_min_degree(
                generator::rmat(n, n * 8, Default::default(), seed),
                1,
                seed ^ 1,
            );
            let s = NeighborSampler::new(6, vec![4, 3]);
            let mb = s.sample(&g, &mut Pcg64::seed_from_u64(seed ^ 2));
            let vals = attach_values(&g, &mb, GnnModel::Sage);
            let aggregate = |opts| {
                let ib = index_batch(&mb, &vals, opts);
                let mut acc = vec![0.0f64; mb.layers[1].len()];
                let l = &ib.layer_edges[0];
                for ((&s, &d), &v) in l.src.iter().zip(&l.dst).zip(&l.val) {
                    acc[d as usize] += v as f64 * (s as f64 + 1.0);
                }
                acc
            };
            let reference = aggregate(LayoutOptions::none());
            for opts in [
                LayoutOptions { rmt: true, rra: false },
                LayoutOptions { rmt: false, rra: true },
                LayoutOptions::all(),
            ] {
                let got = aggregate(opts);
                for (a, b) in reference.iter().zip(&got) {
                    if (a - b).abs() > 1e-9 {
                        return Err(format!("layout {opts:?} changed semantics"));
                    }
                }
            }
            Ok(())
        },
    );
}
