//! Out-of-core store identity: training and serving mounted from a packed
//! `HPGNNG02` store are bit-identical to the same run on the in-RAM graph,
//! across every backing mode; edge-stream ingest is snapshot-isolated; and
//! the `graph.path` program spec drives the same loss curve end to end.

use std::path::PathBuf;
use std::sync::Arc;

use hp_gnn::api::{program, HpGnn, SamplerSpec, TrainingSpec, Workspace};
use hp_gnn::coordinator::{TrainConfig, TrainingSession};
use hp_gnn::graph::store::{pack, BackingMode, DynamicGraph, GraphStore};
use hp_gnn::graph::{generator, Graph, GraphAccess};
use hp_gnn::runtime::{Kind, Runtime, WeightState};
use hp_gnn::sampler::neighbor::NeighborSampler;
use hp_gnn::sampler::values::GnnModel;
use hp_gnn::sampler::Sampler;
use hp_gnn::serve::{ServeConfig, Server};

fn tiny_graph() -> Graph {
    let mut g = generator::with_min_degree(
        generator::rmat(400, 3200, Default::default(), 31),
        1,
        30,
    );
    g.feat_dim = 16;
    g.num_classes = 4;
    g.name = "store-identity".to_string();
    g
}

fn temp_store(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hpgnn-store-id-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{tag}.hpg"))
}

fn losses(rt: &Runtime, graph: Arc<dyn GraphAccess>, steps: usize) -> Vec<u32> {
    let sampler: Arc<dyn Sampler> = Arc::new(NeighborSampler::new(4, vec![5, 3]));
    let cfg = TrainConfig::quick(GnnModel::Gcn, "tiny", 0);
    let mut s = TrainingSession::new(rt, graph, sampler, cfg).unwrap();
    s.run_for(steps).unwrap();
    s.finish().metrics.losses.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn training_from_the_store_matches_in_ram_bit_for_bit() {
    let g = tiny_graph();
    let path = temp_store("train");
    // Tiny chunks force multi-chunk neighbor reads through every backing.
    pack(&g, &path, 0, 512).unwrap();
    let rt = Runtime::reference();
    let want = losses(&rt, Arc::new(g), 6);
    assert_eq!(want.len(), 6);
    for mode in [
        BackingMode::Auto,
        BackingMode::Mmap,
        BackingMode::Pread,
        BackingMode::Resident,
    ] {
        let store = match GraphStore::open_with(&path, mode) {
            Ok(s) => s,
            // Mmap may be unavailable in a constrained sandbox; Auto
            // already covered its fallback.
            Err(_) if mode == BackingMode::Mmap => continue,
            Err(e) => panic!("open {mode:?}: {e}"),
        };
        let got = losses(&rt, Arc::new(store), 6);
        assert_eq!(want, got, "loss curve must be bit-identical under {mode:?}");
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn served_logits_from_the_store_match_in_ram_bit_for_bit() {
    let g = tiny_graph();
    let path = temp_store("serve");
    pack(&g, &path, 0, 512).unwrap();
    let rt = Runtime::reference();
    let cfg = ServeConfig::default();
    let exe = rt.compile_role(cfg.model, &cfg.geometry, Kind::Forward).unwrap();
    let weights = WeightState::init_glorot(&exe.spec.weight_shapes, 3);
    let vertices = [2u32, 48, 77, 123, 199];

    let ram = Server::start(
        &rt,
        DynamicGraph::from_graph(g),
        Arc::new(NeighborSampler::new(4, vec![5, 3])),
        cfg.clone(),
        weights.clone(),
    )
    .unwrap();
    let want: Vec<Vec<u32>> = vertices
        .iter()
        .map(|&v| {
            ram.classify_one(v).unwrap().logits.iter().map(|x| x.to_bits()).collect()
        })
        .collect();
    ram.shutdown();

    let store = GraphStore::open(&path).unwrap();
    let srv = Server::start(
        &rt,
        DynamicGraph::fixed(Arc::new(store)),
        Arc::new(NeighborSampler::new(4, vec![5, 3])),
        cfg,
        weights,
    )
    .unwrap();
    for (&v, want) in vertices.iter().zip(&want) {
        let got: Vec<u32> =
            srv.classify_one(v).unwrap().logits.iter().map(|x| x.to_bits()).collect();
        assert_eq!(want, &got, "served logits must be bit-identical for vertex {v}");
    }
    srv.shutdown();
    std::fs::remove_file(&path).ok();
}

#[test]
fn ingest_over_a_store_is_snapshot_isolated_and_compacts() {
    let g = tiny_graph();
    let path = temp_store("ingest");
    pack(&g, &path, 0, 512).unwrap();
    let store = GraphStore::open(&path).unwrap();
    let dg = DynamicGraph::fixed(Arc::new(store));

    let s0 = dg.snapshot();
    let before: Vec<u32> = s0.neighbors(7).iter().copied().collect();
    let v1 = dg.ingest(&[(7, 9), (9, 7)]).unwrap();
    assert_eq!(v1, 1);

    // The pinned snapshot still answers from the topology it pinned...
    assert_eq!(s0.neighbors(7).iter().copied().collect::<Vec<u32>>(), before);
    assert_eq!(s0.version(), 0);
    // ...while a fresh snapshot sees the merged neighbor list.
    let s1 = dg.snapshot();
    assert_eq!(s1.version(), 1);
    assert_eq!(s1.degree(7), before.len() + 1);
    assert!(s1.neighbors(7).iter().any(|&n| n == 9));

    // Compaction folds the delta back to disk through the same packer;
    // reopening reproduces the merged topology and keeps the version.
    let path2 = temp_store("compacted");
    let (stats, swapped) = dg.compact_to(&path2).unwrap();
    assert!(swapped, "no racing ingest, so the base must swap");
    assert_eq!(stats.num_edges, g.num_edges() + 2);
    let re = GraphStore::open(&path2).unwrap();
    assert_eq!(GraphAccess::version(&re), 1);
    assert_eq!(
        re.neighbors(7).iter().copied().collect::<Vec<u32>>(),
        s1.neighbors(7).iter().copied().collect::<Vec<u32>>()
    );

    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&path2).ok();
}

#[test]
fn program_with_graph_path_trains_identically_to_inline() {
    let g = tiny_graph();
    let path = temp_store("program");
    pack(&g, &path, 0, 512).unwrap();
    let ws = Workspace::reference();

    // The same program twice: once over the in-RAM graph, once mounted
    // from the packed store via graph.path.
    let inline_spec = HpGnn::init()
        .platform_board("xilinx-U250")
        .unwrap()
        .gnn_computation("gcn")
        .unwrap()
        .gnn_parameters(vec![8])
        .sampler(SamplerSpec::Neighbor { targets: 4, budgets: vec![5, 3] })
        .load_input_graph(g)
        .training(TrainingSpec { steps: 4, lr: 0.1, ..Default::default() })
        .spec()
        .unwrap();
    let store_spec = program::parse_program(&format!(
        r#"{{
          "platform": "xilinx-U250",
          "model": {{"computation": "GCN", "hidden": [8]}},
          "sampler": {{"type": "NeighborSampler", "budgets": [5, 3], "targets": 4}},
          "graph": {{"path": {:?}}},
          "training": {{"steps": 4, "lr": 0.1}}
        }}"#,
        path.to_str().unwrap()
    ))
    .unwrap();
    assert!(store_spec.validate().is_empty(), "{}", store_spec.validate());

    let mut curves = Vec::new();
    for spec in [&inline_spec, &store_spec] {
        let design = ws.design(spec).unwrap();
        let mut session = design.session().unwrap();
        session.run_for(4).unwrap();
        let bits: Vec<u32> =
            session.finish().metrics.losses.iter().map(|x| x.to_bits()).collect();
        curves.push(bits);
    }
    assert_eq!(curves[0], curves[1], "graph.path must reproduce the in-RAM loss curve");

    // validate() diagnoses a missing store with a path-anchored hint.
    let missing = store_spec
        .to_json()
        .unwrap()
        .pretty()
        .replace(path.to_str().unwrap(), "/no/such/store.hpg");
    let spec = program::parse_program(&missing).unwrap();
    let d = spec.validate();
    assert!(d.iter().any(|x| x.path == "graph.path"), "{d}");

    std::fs::remove_file(&path).ok();
}
