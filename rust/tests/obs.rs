//! Observability acceptance: tracing observes the pipeline, it never
//! perturbs it.
//!
//! * A traced training run produces a bit-identical loss curve to an
//!   untraced one, and a traced serving stack returns bit-identical
//!   logits — the core contract that lets `--trace` ship on by default
//!   in perf investigations.
//! * The recorded trace is well-formed: matched B/E pairs per thread,
//!   non-decreasing timestamps, flop/byte args on kernel spans, and a
//!   Chrome `trace_event` JSON document that round-trips the parser.
//!
//! Tracing is process-global state, so every test here serializes on
//! [`TRACE_LOCK`].

use std::sync::{Arc, Mutex, MutexGuard};

use hp_gnn::coordinator::{TrainConfig, TrainingSession};
use hp_gnn::graph::store::DynamicGraph;
use hp_gnn::graph::{generator, Graph};
use hp_gnn::obs::trace::{self, Phase, Trace};
use hp_gnn::runtime::{Kind, Runtime, WeightState};
use hp_gnn::sampler::neighbor::NeighborSampler;
use hp_gnn::sampler::values::GnnModel;
use hp_gnn::sampler::Sampler;
use hp_gnn::serve::{ServeConfig, Server};
use hp_gnn::util::json::Json;

/// Tracing enable/disable is process-global; tests take this first.
static TRACE_LOCK: Mutex<()> = Mutex::new(());

fn trace_lock() -> MutexGuard<'static, ()> {
    TRACE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn world(seed: u64) -> (Arc<Graph>, Arc<dyn Sampler>, TrainConfig) {
    let mut g = generator::with_min_degree(
        generator::rmat(400, 3200, Default::default(), seed),
        1,
        seed ^ 1,
    );
    g.feat_dim = 16;
    g.num_classes = 4;
    let sampler: Arc<dyn Sampler> = Arc::new(NeighborSampler::new(4, vec![5, 3]));
    (Arc::new(g), sampler, TrainConfig::quick(GnnModel::Gcn, "tiny", 0))
}

fn train_losses(steps: usize) -> Vec<f32> {
    let rt = Runtime::reference();
    let (graph, sampler, cfg) = world(55);
    let mut s = TrainingSession::new(&rt, graph, sampler, cfg).unwrap();
    s.run_for(steps).unwrap();
    s.finish().metrics.losses
}

/// Matched B/E pairs per thread, non-decreasing `ts`, args only where
/// they belong.  Returns the number of matched pairs.
fn assert_well_formed(trace: &Trace) -> usize {
    assert!(!trace.events.is_empty(), "trace recorded nothing");
    assert_eq!(trace.dropped, 0, "tiny runs must not hit the buffer cap");
    let mut stacks: std::collections::BTreeMap<u64, Vec<(&str, &str)>> = Default::default();
    let mut last_ts: std::collections::BTreeMap<u64, f64> = Default::default();
    let mut pairs = 0;
    for e in &trace.events {
        let prev = last_ts.entry(e.tid).or_insert(0.0);
        assert!(e.ts_us >= *prev, "ts regressed on tid {}: {} < {prev}", e.tid, e.ts_us);
        *prev = e.ts_us;
        match e.ph {
            Phase::B => stacks.entry(e.tid).or_default().push((e.cat, e.name)),
            Phase::E => {
                let top = stacks.get_mut(&e.tid).and_then(|s| s.pop());
                assert_eq!(top, Some((e.cat, e.name)), "unmatched E on tid {}", e.tid);
                pairs += 1;
            }
        }
    }
    for (tid, stack) in &stacks {
        assert!(stack.is_empty(), "tid {tid} left open spans: {stack:?}");
    }
    pairs
}

#[test]
fn traced_training_is_bit_identical_and_the_trace_is_well_formed() {
    let _guard = trace_lock();
    let want = train_losses(4);
    assert_eq!(want.len(), 4);

    trace::enable();
    let got = train_losses(4);
    let trace = trace::disable();

    for (i, (a, b)) in want.iter().zip(&got).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "loss {i} diverged under tracing");
    }

    let pairs = assert_well_formed(&trace);
    assert!(pairs > 0);

    // Kernel spans carry flop/byte counts; the pipeline stages show up.
    let kernel_b = trace
        .events
        .iter()
        .find(|e| e.cat == "kernel" && e.ph == Phase::B)
        .expect("a traced step must record kernel spans");
    for key in ["flops", "bytes"] {
        assert!(
            kernel_b.args.iter().any(|&(k, _)| k == key),
            "kernel span {} missing {key} arg",
            kernel_b.name
        );
    }
    let totals = trace.stage_totals();
    for stage in [("pipeline", "sample"), ("pipeline", "layout"), ("pipeline", "pad")] {
        let key = (stage.0.to_string(), stage.1.to_string());
        let t = totals.get(&key).unwrap_or_else(|| panic!("no {stage:?} stage"));
        assert!(t.calls >= 1 && t.total_s >= 0.0);
    }
    assert!(
        totals.keys().any(|(cat, _)| cat == "optimizer"),
        "training must record optimizer spans"
    );

    // The Chrome export round-trips our own parser with one object per
    // recorded event.
    let doc = trace.to_chrome_json().pretty();
    let parsed = Json::parse(&doc).expect("chrome trace must parse");
    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), trace.events.len());
    for e in events.iter().take(32) {
        for key in ["name", "cat", "ph", "ts", "pid", "tid"] {
            e.get(key).unwrap_or_else(|err| panic!("event missing {key}: {err:?}"));
        }
    }
}

#[test]
fn traced_serving_returns_bit_identical_logits() {
    let _guard = trace_lock();
    let serve_logits = || -> Vec<Vec<f32>> {
        let rt = Runtime::reference();
        let cfg = ServeConfig::default();
        let exe = rt.compile_role(cfg.model, &cfg.geometry, Kind::Forward).unwrap();
        let weights = WeightState::init_glorot(&exe.spec.weight_shapes, 3);
        let (graph, _, _) = world(55);
        let sampler = Arc::new(NeighborSampler::new(4, vec![5, 3]));
        let server = Server::start(&rt, DynamicGraph::fixed(graph), sampler, cfg, weights).unwrap();
        let out = [2u32, 48, 77, 123, 199]
            .iter()
            .map(|&v| server.classify_one(v).unwrap().logits.clone())
            .collect();
        server.shutdown();
        out
    };

    let want = serve_logits();
    trace::enable();
    let got = serve_logits();
    let trace = trace::disable();

    assert_eq!(want.len(), got.len());
    for (v, (a, b)) in want.iter().zip(&got).enumerate() {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.to_bits(), y.to_bits(), "vertex {v} logits diverged under tracing");
        }
    }

    // The serving trace is well-formed and records the serve stages.
    assert_well_formed(&trace);
    for name in ["request", "infer", "coalesce"] {
        assert!(
            trace.events.iter().any(|e| e.cat == "serve" && e.name == name && e.ph == Phase::B),
            "serving trace missing serve/{name}"
        );
    }
}
