//! Session checkpoint/resume acceptance: a run snapshotted mid-stream and
//! resumed from disk — in a fresh runtime, and through the `hp-gnn` CLI in
//! a fresh *process* — reproduces the uninterrupted run's loss sequence
//! bit-exactly on the reference backend.  Plus rejection paths for the
//! `HPGNNS01` snapshot format (corruption, wrong magic, wrong geometry,
//! optimizer mismatch).

use std::sync::Arc;

use hp_gnn::coordinator::{trainer::Optimizer, TrainConfig, TrainingSession};
use hp_gnn::graph::{generator, Graph};
use hp_gnn::runtime::Runtime;
use hp_gnn::sampler::neighbor::NeighborSampler;
use hp_gnn::sampler::values::GnnModel;
use hp_gnn::sampler::Sampler;

/// The "process state" a resume has to rebuild from scratch: graph,
/// sampler, config.  Everything is a pure function of the seed, exactly as
/// it would be after a restart.
fn world(seed: u64) -> (Arc<Graph>, Arc<dyn Sampler>, TrainConfig) {
    let mut g = generator::with_min_degree(
        generator::rmat(400, 3200, Default::default(), seed),
        1,
        seed ^ 1,
    );
    g.feat_dim = 16;
    g.num_classes = 4;
    let sampler: Arc<dyn Sampler> = Arc::new(NeighborSampler::new(4, vec![5, 3]));
    (Arc::new(g), sampler, TrainConfig::quick(GnnModel::Gcn, "tiny", 0))
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("hpgnn-resume-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn losses_of(rt: &Runtime, cfg: &TrainConfig, steps: usize) -> Vec<f32> {
    let (graph, sampler, _) = world(55);
    let mut s = TrainingSession::new(rt, graph, sampler, cfg.clone()).unwrap();
    s.run_for(steps).unwrap();
    let report = s.finish();
    report.metrics.losses
}

#[test]
fn resumed_run_reproduces_uninterrupted_losses_bit_exactly() {
    for optimizer in [Optimizer::Sgd, Optimizer::Adam] {
        // Uninterrupted reference run: 12 steps in one session.
        let (_, _, mut cfg) = world(55);
        cfg.optimizer = optimizer;
        let rt = Runtime::reference();
        let want = losses_of(&rt, &cfg, 12);
        assert_eq!(want.len(), 12);

        // Interrupted run: 6 steps, snapshot, drop everything.
        let dir = temp_dir("bitexact");
        let path = dir.join(format!("{optimizer:?}.ckpt"));
        {
            let (graph, sampler, _) = world(55);
            let mut s = TrainingSession::new(&rt, graph, sampler, cfg.clone()).unwrap();
            s.run_for(6).unwrap();
            s.save(&path).unwrap();
            let prefix = s.finish().metrics.losses;
            assert_eq!(prefix, want[..6].to_vec(), "{optimizer:?} prefix diverged");
        }
        drop(rt);

        // "Fresh process": a brand-new runtime and freshly rebuilt graph /
        // sampler / config, with only the snapshot carried over.
        let rt2 = Runtime::reference();
        let (graph, sampler, _) = world(55);
        let mut resumed = TrainingSession::resume(&rt2, graph, sampler, cfg, &path).unwrap();
        assert_eq!(resumed.current_step(), 6);
        resumed.run_for(6).unwrap();
        assert_eq!(
            resumed.metrics().losses,
            want[6..].to_vec(),
            "{optimizer:?} resume is not bit-exact"
        );
    }
}

#[test]
fn snapshot_rejects_corruption_and_mismatches() {
    let rt = Runtime::reference();
    let (graph, sampler, cfg) = world(55);
    let dir = temp_dir("reject");
    let path = dir.join("s.ckpt");
    {
        let mut s =
            TrainingSession::new(&rt, Arc::clone(&graph), Arc::clone(&sampler), cfg.clone())
                .unwrap();
        s.run_for(2).unwrap();
        s.save(&path).unwrap();
    }

    // Geometry mismatch: the snapshot is shaped for "tiny".
    let mut other = cfg.clone();
    other.geometry = "ns_small".to_string();
    let err =
        TrainingSession::resume(&rt, Arc::clone(&graph), Arc::clone(&sampler), other, &path)
            .unwrap_err()
            .to_string();
    assert!(err.contains("geometry"), "{err}");

    // Optimizer mismatch: SGD snapshot cannot seed an Adam session.
    let mut adam = cfg.clone();
    adam.optimizer = Optimizer::Adam;
    let err = TrainingSession::resume(&rt, Arc::clone(&graph), Arc::clone(&sampler), adam, &path)
        .unwrap_err()
        .to_string();
    assert!(err.contains("Adam"), "{err}");

    // Seed mismatch: the resumed stream would not be the checkpointed one.
    let mut reseeded = cfg.clone();
    reseeded.seed ^= 1;
    let err =
        TrainingSession::resume(&rt, Arc::clone(&graph), Arc::clone(&sampler), reseeded, &path)
            .unwrap_err()
            .to_string();
    assert!(err.contains("seed"), "{err}");

    // Sampler mismatch: different fan-out, different stream.
    let fatter: Arc<dyn Sampler> = Arc::new(NeighborSampler::new(8, vec![5, 3]));
    let err = TrainingSession::resume(&rt, Arc::clone(&graph), fatter, cfg.clone(), &path)
        .unwrap_err()
        .to_string();
    assert!(err.contains("sampler"), "{err}");

    // Graph mismatch: checkpointed weights must not continue on a graph
    // the stream never saw.
    let other_graph = {
        let mut g = generator::with_min_degree(
            generator::rmat(500, 4000, Default::default(), 55),
            1,
            54,
        );
        g.feat_dim = 16;
        g.num_classes = 4;
        Arc::new(g)
    };
    let err = TrainingSession::resume(&rt, other_graph, Arc::clone(&sampler), cfg.clone(), &path)
        .unwrap_err()
        .to_string();
    assert!(err.contains("graph"), "{err}");

    // Truncation anywhere fails loudly.
    let bytes = std::fs::read(&path).unwrap();
    let cut = dir.join("cut.ckpt");
    for end in [bytes.len() - 3, bytes.len() / 2, 12] {
        std::fs::write(&cut, &bytes[..end]).unwrap();
        assert!(
            TrainingSession::resume(
                &rt,
                Arc::clone(&graph),
                Arc::clone(&sampler),
                cfg.clone(),
                &cut
            )
            .is_err(),
            "accepted a {end}-byte prefix"
        );
    }

    // A weights-only HPGNNW01 file is not a session snapshot; the error
    // names both formats.
    let wpath = dir.join("w.bin");
    {
        let s = TrainingSession::new(&rt, Arc::clone(&graph), Arc::clone(&sampler), cfg.clone())
            .unwrap();
        s.weights().save(&wpath).unwrap();
    }
    let err = TrainingSession::resume(&rt, graph, sampler, cfg, &wpath)
        .unwrap_err()
        .to_string();
    assert!(err.contains("HPGNNS01"), "{err}");
}

// ---- CLI end-to-end: checkpoint in one process, resume in another ------

fn write_program(path: &std::path::Path, steps: usize, eval_every: usize) {
    let program = format!(
        r#"{{
  "platform": "xilinx-U250",
  "model": {{"computation": "GCN", "hidden": [256]}},
  "sampler": {{"type": "NeighborSampler", "budgets": [5, 10], "targets": 32}},
  "graph": {{"dataset": "FL", "scale": 0.004, "seed": 3}},
  "training": {{"steps": {steps}, "lr": 0.1, "eval_every": {eval_every}, "eval_batches": 1}}
}}"#
    );
    std::fs::write(path, program).unwrap();
}

#[test]
fn cli_run_resume_and_eval_every_end_to_end() {
    let exe = env!("CARGO_BIN_EXE_hp-gnn");
    let dir = temp_dir("cli");
    let ckpt = dir.join("cli.ckpt");
    let first = dir.join("first.json");
    let full = dir.join("full.json");
    write_program(&first, 4, 0);
    write_program(&full, 8, 2);

    // Process 1: train 4 steps, write the session snapshot.
    let out = std::process::Command::new(exe)
        .args(["run", first.to_str().unwrap(), "--checkpoint", ckpt.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "first run failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(ckpt.exists(), "no snapshot written");

    // Process 2: resume toward the full 8-step program, with periodic
    // evaluation from the program's training.eval_every.
    let out = std::process::Command::new(exe)
        .args(["run", full.to_str().unwrap(), "--resume", ckpt.to_str().unwrap()])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "resume run failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(stdout.contains("resumed at step 4"), "{stdout}");
    assert!(stdout.contains("eval @ step 6"), "{stdout}");
    assert!(stdout.contains("eval @ step 8"), "{stdout}");
}

#[test]
fn cli_unknown_subcommand_fails_and_help_succeeds() {
    let exe = env!("CARGO_BIN_EXE_hp-gnn");

    let out = std::process::Command::new(exe).arg("frobnicate").output().unwrap();
    assert!(!out.status.success(), "unknown subcommand must exit nonzero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown subcommand") && stderr.contains("SUBCOMMANDS"), "{stderr}");

    let out = std::process::Command::new(exe).output().unwrap();
    assert!(!out.status.success(), "bare invocation must exit nonzero");

    let out = std::process::Command::new(exe).arg("help").output().unwrap();
    assert!(out.status.success(), "`hp-gnn help` must exit 0");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("SUBCOMMANDS"));
    // Every subcommand the dispatcher knows must be in the overview.
    for sub in ["run", "train", "serve", "validate", "explain", "dse", "simulate", "info"] {
        assert!(stdout.contains(sub), "help output misses {sub:?}: {stdout}");
    }
}

#[test]
fn cli_validate_prints_every_diagnostic_and_explain_reports() {
    let exe = env!("CARGO_BIN_EXE_hp-gnn");
    let dir = temp_dir("validate");

    // A clean program validates with exit 0 and an "ok" summary line.
    let good = dir.join("good.json");
    write_program(&good, 4, 0);
    let out = std::process::Command::new(exe)
        .args(["validate", good.to_str().unwrap()])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "validate failed on a clean program: {stdout}");
    assert!(stdout.contains("ok"), "{stdout}");
    assert!(stdout.contains("geometry"), "{stdout}");

    // Three independent mistakes -> all three paths in one invocation,
    // nonzero exit.
    let bad = dir.join("bad.json");
    std::fs::write(
        &bad,
        r#"{
  "platform": "stratix-10",
  "model": {"computation": "GCN", "hidden": [256, 256]},
  "sampler": {"type": "NeighborSampler", "budgets": [], "targets": 32},
  "graph": {"dataset": "FL", "scale": 0.004},
  "training": {"steps": 4, "lr": 0.1}
}"#,
    )
    .unwrap();
    let out = std::process::Command::new(exe)
        .args(["validate", bad.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success(), "validate must exit nonzero on a broken program");
    let stdout = String::from_utf8_lossy(&out.stdout);
    for path in ["platform", "model.hidden", "sampler.budgets"] {
        assert!(stdout.contains(path), "validate output misses {path:?}:\n{stdout}");
    }
    assert!(stdout.contains("3 problems"), "{stdout}");

    // `explain` prints the Listing-3 report + the rerunnable program JSON.
    let out = std::process::Command::new(exe)
        .args(["explain", good.to_str().unwrap()])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "explain failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    for needle in ["generated design", "artifact:", "utilization:", "placement:", "\"program\""] {
        assert!(stdout.contains(needle), "explain output misses {needle:?}:\n{stdout}");
    }
}
