//! Socket-level coverage of the HTTP serving frontend.
//!
//! The determinism invariant crosses the wire here: logits served over
//! `POST /v1/classify` must be *byte-identical* to in-process
//! `Server::classify_one` — possible because f32→f64 widening is exact
//! and the JSON writer emits shortest-round-trip decimals.  Plus:
//! admission control sheds with `429` + `Retry-After` when the bounded
//! queue fills, `/v1/reload` hot-swaps weights and bumps the reported
//! version, route errors are `Diagnostic`-shaped, and the
//! `hp-gnn serve --listen` CLI serves the same API end to end.

use std::sync::Arc;
use std::time::Duration;

use hp_gnn::graph::store::DynamicGraph;
use hp_gnn::graph::{generator, GraphAccess, Vid};
use hp_gnn::net::{api_router, HttpClient, HttpOptions, HttpServer};
use hp_gnn::runtime::{Kind, Runtime, WeightState};
use hp_gnn::sampler::neighbor::NeighborSampler;
use hp_gnn::sampler::{MiniBatch, Sampler};
use hp_gnn::serve::{ServeConfig, Server};
use hp_gnn::util::json::Json;
use hp_gnn::util::rng::Pcg64;

fn tiny_graph() -> Arc<DynamicGraph> {
    let mut g = generator::with_min_degree(
        generator::rmat(400, 3200, Default::default(), 31),
        1,
        30,
    );
    g.feat_dim = 16;
    g.num_classes = 4;
    g.name = "net-http".to_string();
    DynamicGraph::from_graph(g)
}

fn start_server(cfg: ServeConfig, weight_seed: u64) -> Arc<Server> {
    let rt = Runtime::reference();
    let exe = rt.compile_role(cfg.model, &cfg.geometry, Kind::Forward).unwrap();
    let weights = WeightState::init_glorot(&exe.spec.weight_shapes, weight_seed);
    Arc::new(
        Server::start(
            &rt,
            tiny_graph(),
            Arc::new(NeighborSampler::new(4, vec![5, 3])),
            cfg,
            weights,
        )
        .unwrap(),
    )
}

fn bind(server: &Arc<Server>) -> HttpServer {
    HttpServer::bind(
        "127.0.0.1:0",
        Arc::new(api_router(Arc::clone(server))),
        HttpOptions { log: false, ..HttpOptions::default() },
    )
    .unwrap()
}

/// The logits array of prediction `i` in a classify response, bit-cast
/// back to f32 exactly as a client would reconstruct them.
fn wire_logits(resp: &Json, i: usize) -> Vec<f32> {
    resp.get("predictions").unwrap().as_arr().unwrap()[i]
        .get("logits")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect()
}

#[test]
fn served_logits_are_byte_identical_to_in_process_classify() {
    let server = start_server(ServeConfig::default(), 3);
    let http = bind(&server);
    let mut client = HttpClient::connect(&http.addr().to_string()).unwrap();

    let vertices: Vec<Vid> = vec![2, 48, 77, 123, 199];
    let truth: Vec<Vec<f32>> = vertices
        .iter()
        .map(|&v| server.classify_one(v).unwrap().logits.clone())
        .collect();

    // Single-vertex requests.
    for (&v, want) in vertices.iter().zip(&truth) {
        let resp = client
            .request(
                "POST",
                "/v1/classify",
                Some(&Json::obj(vec![("vertex", Json::num(v as f64))])),
            )
            .unwrap();
        assert_eq!(resp.status, 200);
        let body = resp.json().unwrap();
        let got = wire_logits(&body, 0);
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(want) {
            assert_eq!(a.to_bits(), b.to_bits(), "logits drifted over the wire");
        }
    }

    // One bulk request: same bytes, input order preserved.
    let bulk = client
        .request(
            "POST",
            "/v1/classify",
            Some(&Json::obj(vec![(
                "vertices",
                Json::arr(vertices.iter().map(|&v| Json::num(v as f64)).collect()),
            )])),
        )
        .unwrap();
    assert_eq!(bulk.status, 200);
    let body = bulk.json().unwrap();
    for (i, want) in truth.iter().enumerate() {
        let got = wire_logits(&body, i);
        for (a, b) in got.iter().zip(want) {
            assert_eq!(a.to_bits(), b.to_bits(), "bulk logits drifted (vertex {i})");
        }
    }

    // healthz and metrics describe the same server.
    let health = client.request("GET", "/healthz", None).unwrap();
    assert_eq!(health.status, 200);
    let h = health.json().unwrap();
    assert_eq!(h.get("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(h.get("workers").unwrap().as_usize().unwrap(), server.num_workers());
    assert_eq!(
        h.get("weight_version").unwrap().as_usize().unwrap() as u64,
        server.weight_version()
    );

    let metrics = client.request("GET", "/metrics.json", None).unwrap().json().unwrap();
    assert!(metrics.get("requests").unwrap().as_usize().unwrap() >= vertices.len());
    assert_eq!(metrics.get("shed_requests").unwrap().as_usize().unwrap(), 0);
    assert_eq!(metrics.get("queue_depth").unwrap().as_usize().unwrap(), 0);
    metrics.get("latency_s").unwrap().get("p99").unwrap();

    // GET /metrics without an Accept preference serves the Prometheus
    // text exposition for the same counters.
    let prom = client.request("GET", "/metrics", None).unwrap();
    assert_eq!(prom.status, 200);
    assert!(
        prom.header("content-type").unwrap().starts_with("text/plain; version=0.0.4"),
        "exposition content type: {:?}",
        prom.header("content-type")
    );
    let text = String::from_utf8(prom.body.clone()).unwrap();
    assert!(text.contains("# TYPE hpgnn_serve_requests_total counter"), "{text}");
    assert!(text.contains("# TYPE hpgnn_serve_request_latency_seconds histogram"), "{text}");
    let sample = text
        .lines()
        .find(|l| l.starts_with("hpgnn_serve_requests_total "))
        .expect("requests_total sample");
    let served: f64 = sample.rsplit(' ').next().unwrap().parse().unwrap();
    assert!(served >= vertices.len() as f64, "{sample}");

    drop(client);
    http.shutdown();
}

/// NeighborSampler wrapper that makes every target-directed sample slow,
/// so a tiny queue fills deterministically under concurrent requests.
#[derive(Clone)]
struct SlowSampler(NeighborSampler);

impl Sampler for SlowSampler {
    fn num_layers(&self) -> usize {
        self.0.num_layers()
    }
    fn clone_box(&self) -> Box<dyn Sampler> {
        Box::new(self.clone())
    }
    fn sample(&self, g: &dyn GraphAccess, rng: &mut Pcg64) -> MiniBatch {
        self.0.sample(g, rng)
    }
    fn sample_targets(
        &self,
        g: &dyn GraphAccess,
        targets: &[Vid],
        rng: &mut Pcg64,
    ) -> anyhow::Result<MiniBatch> {
        std::thread::sleep(Duration::from_millis(40));
        self.0.sample_targets(g, targets, rng)
    }
    fn name(&self) -> String {
        self.0.name()
    }
    fn expected_layer_sizes(&self, g: &dyn GraphAccess) -> Vec<usize> {
        self.0.expected_layer_sizes(g)
    }
    fn expected_edge_counts(&self, g: &dyn GraphAccess) -> Vec<usize> {
        self.0.expected_edge_counts(g)
    }
}

#[test]
fn full_queue_sheds_with_429_and_retry_after() {
    // One slow worker, no coalescing, a one-slot queue: total pipeline
    // capacity is ~4 items, so 10 concurrent requests must shed.
    let rt = Runtime::reference();
    let cfg = ServeConfig {
        workers: 1,
        max_batch: 1,
        max_wait: Duration::ZERO,
        queue_depth: 1,
        ..ServeConfig::default()
    };
    let exe = rt.compile_role(cfg.model, &cfg.geometry, Kind::Forward).unwrap();
    let weights = WeightState::init_glorot(&exe.spec.weight_shapes, 3);
    let server = Arc::new(
        Server::start(
            &rt,
            tiny_graph(),
            Arc::new(SlowSampler(NeighborSampler::new(4, vec![5, 3]))),
            cfg,
            weights,
        )
        .unwrap(),
    );
    let http = bind(&server);
    let addr = http.addr().to_string();

    let clients = 10;
    let mut handles = Vec::new();
    for c in 0..clients {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = HttpClient::connect(&addr).unwrap();
            let resp = client
                .request(
                    "POST",
                    "/v1/classify",
                    Some(&Json::obj(vec![("vertex", Json::num((c * 17 % 400) as f64))])),
                )
                .unwrap();
            let retry_after = resp.header("retry-after").map(|v| v.to_string());
            let body = resp.json().unwrap();
            (resp.status, retry_after, body)
        }));
    }
    let outcomes: Vec<(u16, Option<String>, Json)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();

    let served = outcomes.iter().filter(|(s, _, _)| *s == 200).count();
    let shed = outcomes.iter().filter(|(s, _, _)| *s == 429).count();
    assert_eq!(served + shed, clients, "only 200 or 429 expected: {outcomes:?}");
    assert!(served > 0, "admitted requests must still be answered");
    assert!(shed > 0, "10 concurrent requests into a ~4-item pipeline must shed");
    for (status, retry_after, body) in &outcomes {
        if *status == 429 {
            assert_eq!(retry_after.as_deref(), Some("1"), "429 must carry Retry-After");
            let err = &body.get("errors").unwrap().as_arr().unwrap()[0];
            assert_eq!(err.get("path").unwrap().as_str().unwrap(), "serving.queue");
        }
    }

    // The shed counter agrees with what clients observed, and nothing
    // is left in flight.
    let mut client = HttpClient::connect(&addr).unwrap();
    let metrics = client.request("GET", "/metrics.json", None).unwrap().json().unwrap();
    assert_eq!(metrics.get("shed_requests").unwrap().as_usize().unwrap(), shed);
    assert_eq!(metrics.get("queue_depth").unwrap().as_usize().unwrap(), 0);
    assert_eq!(metrics.get("requests").unwrap().as_usize().unwrap(), served);

    drop(client);
    http.shutdown();
}

#[test]
fn reload_bumps_the_reported_weight_version_and_changes_logits() {
    let server = start_server(ServeConfig::default(), 3);
    let http = bind(&server);
    let mut client = HttpClient::connect(&http.addr().to_string()).unwrap();

    // Different weights on disk, same shapes.
    let rt = Runtime::reference();
    let cfg = ServeConfig::default();
    let exe = rt.compile_role(cfg.model, &cfg.geometry, Kind::Forward).unwrap();
    let other = WeightState::init_glorot(&exe.spec.weight_shapes, 99);
    let dir = std::env::temp_dir().join(format!("hpgnn-net-http-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("rollout.bin");
    other.save(&path).unwrap();

    let v0 = client
        .request("GET", "/healthz", None)
        .unwrap()
        .json()
        .unwrap()
        .get("weight_version")
        .unwrap()
        .as_usize()
        .unwrap();
    let before = server.classify_one(42).unwrap().logits.clone();

    let resp = client
        .request(
            "POST",
            "/v1/reload",
            Some(&Json::obj(vec![("checkpoint", Json::str(path.to_str().unwrap()))])),
        )
        .unwrap();
    assert_eq!(resp.status, 200, "{:?}", resp.json());
    let body = resp.json().unwrap();
    assert!(body.get("reloaded").unwrap().as_bool().unwrap());
    let v1 = body.get("weight_version").unwrap().as_usize().unwrap();
    assert!(v1 > v0, "reload must bump the weight version ({v0} -> {v1})");

    // healthz agrees, and the server now answers under the new weights.
    let h = client.request("GET", "/healthz", None).unwrap().json().unwrap();
    assert_eq!(h.get("weight_version").unwrap().as_usize().unwrap(), v1);
    let after = server.classify_one(42).unwrap().logits.clone();
    assert_ne!(before, after, "new weights must change the logits");

    // A bogus rollout is a 409 and leaves the version untouched.
    let resp = client
        .request(
            "POST",
            "/v1/reload",
            Some(&Json::obj(vec![("checkpoint", Json::str("/no/such/file.bin"))])),
        )
        .unwrap();
    assert_eq!(resp.status, 409);
    let h = client.request("GET", "/healthz", None).unwrap().json().unwrap();
    assert_eq!(h.get("weight_version").unwrap().as_usize().unwrap(), v1);

    drop(client);
    http.shutdown();
}

#[test]
fn ingest_bumps_the_graph_version_over_http() {
    let server =
        start_server(ServeConfig { cache: true, workers: 1, ..ServeConfig::default() }, 3);
    let http = bind(&server);
    let mut client = HttpClient::connect(&http.addr().to_string()).unwrap();

    let g0 = client
        .request("GET", "/healthz", None)
        .unwrap()
        .json()
        .unwrap()
        .get("graph_version")
        .unwrap()
        .as_usize()
        .unwrap();

    // classify reports the graph version it answered under.
    let resp = client
        .request("POST", "/v1/classify", Some(&Json::obj(vec![("vertex", Json::num(42.0))])))
        .unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(
        resp.json().unwrap().get("graph_version").unwrap().as_usize().unwrap(),
        g0
    );

    // Insert three edges: the version bumps and every surface agrees.
    let edges = Json::parse(r#"{"edges": [[42, 7], [42, 9], [7, 42]]}"#).unwrap();
    let resp = client.request("POST", "/v1/ingest", Some(&edges)).unwrap();
    assert_eq!(resp.status, 200, "{:?}", resp.json());
    let body = resp.json().unwrap();
    assert_eq!(body.get("ingested").unwrap().as_usize().unwrap(), 3);
    let g1 = body.get("graph_version").unwrap().as_usize().unwrap();
    assert_eq!(g1, g0 + 1);
    let h = client.request("GET", "/healthz", None).unwrap().json().unwrap();
    assert_eq!(h.get("graph_version").unwrap().as_usize().unwrap(), g1);
    let m = client.request("GET", "/metrics.json", None).unwrap().json().unwrap();
    assert_eq!(m.get("graph_version").unwrap().as_usize().unwrap(), g1);
    assert_eq!(m.get("ingest_edges").unwrap().as_usize().unwrap(), 3);

    // A malformed edge is a Diagnostic-shaped 400 anchored at its index.
    let resp = client
        .request("POST", "/v1/ingest", Some(&Json::parse(r#"{"edges": [[1]]}"#).unwrap()))
        .unwrap();
    assert_eq!(resp.status, 400);
    let err = resp.json().unwrap();
    let errors = err.get("errors").unwrap().as_arr().unwrap();
    assert_eq!(errors[0].get("path").unwrap().as_str().unwrap(), "body.edges[0]");

    // An out-of-range endpoint is a 409 conflict; the version holds.
    let resp = client
        .request("POST", "/v1/ingest", Some(&Json::parse(r#"{"edges": [[0, 4000]]}"#).unwrap()))
        .unwrap();
    assert_eq!(resp.status, 409);
    let h = client.request("GET", "/healthz", None).unwrap().json().unwrap();
    assert_eq!(h.get("graph_version").unwrap().as_usize().unwrap(), g1);

    drop(client);
    http.shutdown();
}

#[test]
fn route_and_body_errors_are_diagnostic_shaped() {
    let server = start_server(ServeConfig::default(), 3);
    let http = bind(&server);
    let mut client = HttpClient::connect(&http.addr().to_string()).unwrap();

    // 404 with the route listing in the hint.
    let resp = client.request("GET", "/nope", None).unwrap();
    assert_eq!(resp.status, 404);
    let err = resp.json().unwrap();
    let first = err.get("errors").unwrap().as_arr().unwrap()[0].clone();
    assert_eq!(first.get("path").unwrap().as_str().unwrap(), "/nope");
    assert!(first.get("hint").unwrap().as_str().unwrap().contains("POST /v1/classify"));

    // 405 with Allow.
    let resp = client.request("DELETE", "/healthz", None).unwrap();
    assert_eq!(resp.status, 405);
    assert_eq!(resp.header("allow"), Some("GET"));

    // Classify body mistakes are 400s that name the bad key.
    for (body, expect_path) in [
        (Json::obj(vec![]), "body"),
        (Json::obj(vec![("vertices", Json::arr(vec![]))]), "body.vertices"),
        (Json::obj(vec![("vertx", Json::num(1.0))]), "body.vertx"),
        (
            Json::obj(vec![
                ("vertex", Json::num(1.0)),
                ("vertices", Json::arr(vec![Json::num(2.0)])),
            ]),
            "body",
        ),
    ] {
        let resp = client.request("POST", "/v1/classify", Some(&body)).unwrap();
        assert_eq!(resp.status, 400, "{}", body.compact());
        let err = resp.json().unwrap();
        let first = &err.get("errors").unwrap().as_arr().unwrap()[0];
        assert_eq!(first.get("path").unwrap().as_str().unwrap(), expect_path);
    }

    drop(client);
    http.shutdown();
}

// ---- CLI end-to-end: hp-gnn serve --listen over a real socket ----------

/// Kills the serving child even when an assertion fails mid-test.
struct ChildGuard(std::process::Child);

impl Drop for ChildGuard {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

#[test]
fn cli_serve_listen_serves_the_http_api_end_to_end() {
    use std::io::BufRead;

    let exe = env!("CARGO_BIN_EXE_hp-gnn");
    let dir = std::env::temp_dir().join(format!("hpgnn-listen-e2e-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let weights = dir.join("weights.bin");

    let out = std::process::Command::new(exe)
        .args(["train", "--dataset", "FL", "--scale", "0.004", "--steps", "2"])
        .args(["--save", weights.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "train failed: {}", String::from_utf8_lossy(&out.stderr));

    let child = std::process::Command::new(exe)
        .args(["serve", "--checkpoint", weights.to_str().unwrap()])
        .args(["--dataset", "FL", "--scale", "0.004", "--listen", "127.0.0.1:0"])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .unwrap();
    let mut child = ChildGuard(child);

    // The CLI prints "listening on http://ADDR" once the socket is up.
    let stdout = child.0.stdout.take().unwrap();
    let mut lines = std::io::BufReader::new(stdout).lines();
    let addr = loop {
        match lines.next() {
            Some(Ok(line)) => {
                if let Some(rest) = line.strip_prefix("listening on http://") {
                    break rest.trim().to_string();
                }
            }
            other => panic!("server exited before listening: {other:?}"),
        }
    };

    let mut client = HttpClient::connect(&addr).unwrap();
    let health = client.request("GET", "/healthz", None).unwrap();
    assert_eq!(health.status, 200);
    assert_eq!(
        health.json().unwrap().get("status").unwrap().as_str().unwrap(),
        "ok"
    );

    let resp = client
        .request(
            "POST",
            "/v1/classify",
            Some(&Json::obj(vec![("vertex", Json::num(3.0))])),
        )
        .unwrap();
    assert_eq!(resp.status, 200);
    let body = resp.json().unwrap();
    let preds = body.get("predictions").unwrap().as_arr().unwrap();
    assert_eq!(preds.len(), 1);
    assert_eq!(preds[0].get("vertex").unwrap().as_usize().unwrap(), 3);
    assert!(!preds[0].get("logits").unwrap().as_arr().unwrap().is_empty());

    let metrics = client.request("GET", "/metrics.json", None).unwrap().json().unwrap();
    assert!(metrics.get("requests").unwrap().as_usize().unwrap() >= 1);
    metrics.get("shed_requests").unwrap();
    metrics.get("queue_depth").unwrap();

    let prom = client.request("GET", "/metrics", None).unwrap();
    assert_eq!(prom.status, 200);
    let text = String::from_utf8(prom.body.clone()).unwrap();
    assert!(text.contains("# TYPE hpgnn_serve_requests_total counter"), "{text}");

    drop(client);
    // ChildGuard kills the serving process on drop.
}
