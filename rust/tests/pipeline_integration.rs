//! Full-framework integration: user program JSON → parser → builder →
//! GenerateDesign → Start_training, plus pipeline-behaviour checks
//! (overlap, backpressure) that unit tests can't see.
//!
//! Runs on the reference backend by default; with `--features xla` it
//! requires `make artifacts` and skips cleanly otherwise.

use hp_gnn::api::program::parse_program;
use hp_gnn::api::{HpGnn, SamplerSpec, Workspace};
use hp_gnn::coordinator::{train, TrainConfig};
use hp_gnn::runtime::Runtime;
use hp_gnn::sampler::values::GnnModel;

#[cfg(feature = "xla")]
fn runtime() -> Option<Runtime> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json")
        .exists()
        .then(|| Runtime::load(&dir).expect("runtime"))
}

#[cfg(not(feature = "xla"))]
fn runtime() -> Option<Runtime> {
    Some(Runtime::reference())
}

fn tiny_graph(seed: u64) -> hp_gnn::graph::Graph {
    let mut g = hp_gnn::graph::generator::with_min_degree(
        hp_gnn::graph::generator::rmat(1500, 12_000, Default::default(), seed),
        1,
        seed ^ 1,
    );
    g.feat_dim = 16;
    g.num_classes = 4;
    g
}

#[test]
fn user_program_end_to_end() {
    let Some(rt) = runtime() else { return };
    let program = r#"{
      "platform": "xilinx-U250",
      "model": {"computation": "GCN", "hidden": [8]},
      "sampler": {"type": "NeighborSampler", "budgets": [5, 3], "targets": 4},
      "graph": {"dataset": "FL", "scale": 0.004, "seed": 3},
      "training": {"steps": 10, "lr": 0.1, "simulate": true}
    }"#;
    // The FL dataset has f0=500/7 classes, which matches no tiny-geometry
    // artifact dims... so this program resolves to the ns-class geometry
    // only if dims match.  FL dims == ns_small dims (500/256/7): use the
    // matching hidden size.
    let program = program.replace("\"hidden\": [8]", "\"hidden\": [256]");
    let program = program.replace(
        r#""budgets": [5, 3], "targets": 4"#,
        r#""budgets": [5, 10], "targets": 32"#,
    );
    let spec = parse_program(&program).unwrap();
    // Session knobs default off when the program omits them.
    assert_eq!(spec.training.eval_every, 0);
    assert!(spec.training.checkpoint.is_none());
    // The workspace owns the runtime; the design binds to it.
    let ws = Workspace::with_runtime(rt);
    let design = ws.design(&spec).unwrap();
    assert_eq!(design.geometry, "ns_small");
    // Start_training() takes steps/lr/simulate from the program itself.
    let report = design.start_training().unwrap();
    assert_eq!(report.metrics.losses.len(), 10);
    assert!(report.metrics.simulated_nvtps(2).unwrap() > 0.0);
    // Generated-design dump: a "design" summary with the DSE outcome plus
    // the embedded "program", which re-parses to the exact same spec.
    let dump = design.to_json();
    let summary = dump.get("design").unwrap();
    assert!(summary.get("accel_m_macs").unwrap().as_f64().unwrap() >= 64.0);
    assert_eq!(summary.get("artifact_geometry").unwrap().as_str().unwrap(), "ns_small");
    let embedded = dump.get("program").unwrap().pretty();
    let reparsed = hp_gnn::api::ProgramSpec::from_json(&embedded).unwrap();
    assert_eq!(reparsed, design.spec, "design JSON must embed a round-trippable program");
}

#[test]
fn builder_selects_smallest_fitting_geometry() {
    let Some(rt) = runtime() else { return };
    // 4-target NS batch with tiny dims -> must pick "tiny", not a bigger
    // geometry.
    let design = HpGnn::init()
        .platform_board("xilinx-U250")
        .unwrap()
        .gnn_computation("gcn")
        .unwrap()
        .gnn_parameters(vec![8])
        .sampler(SamplerSpec::Neighbor { targets: 4, budgets: vec![5, 3] })
        .load_input_graph(tiny_graph(5))
        .generate_design(&rt)
        .unwrap();
    assert_eq!(design.geometry, "tiny");
}

#[test]
fn oversized_sampler_has_no_geometry() {
    let Some(rt) = runtime() else { return };
    let err = HpGnn::init()
        .platform_board("xilinx-U250")
        .unwrap()
        .gnn_computation("gcn")
        .unwrap()
        .gnn_parameters(vec![8])
        .sampler(SamplerSpec::Neighbor { targets: 4096, budgets: vec![20, 20] })
        .load_input_graph(tiny_graph(6))
        .generate_design(&rt)
        .unwrap_err()
        .to_string();
    assert!(err.contains("no artifact geometry fits"), "{err}");
}

#[test]
fn sampler_overlap_hides_preparation() {
    // With >1 producer thread, mean iteration wall time must be below
    // (prep + exec) — i.e. the pipeline actually overlaps.  Tiny geometry
    // prep is cheap, so amplify it with more steps and assert weakly.
    let Some(rt) = runtime() else { return };
    let g = tiny_graph(7);
    let sampler = hp_gnn::sampler::neighbor::NeighborSampler::new(4, vec![5, 3]);
    let mut cfg = TrainConfig::quick(GnnModel::Gcn, "tiny", 30);
    cfg.sampler_threads = 4;
    let report = train(&rt, &g, &sampler, &cfg).unwrap();
    let m = &report.metrics;
    let serial = m.t_sampling.mean() + m.t_execute.mean();
    assert!(
        m.t_iteration.mean() < serial * 1.05,
        "pipeline not overlapping: iter {:.4}ms vs serial {:.4}ms",
        m.t_iteration.mean() * 1e3,
        serial * 1e3
    );
}

#[test]
fn multi_dataset_multi_model_matrix_trains() {
    // The "framework" claim: every (model, sampler-kind) combination runs
    // through the same session API with no special-casing.
    let Some(rt) = runtime() else { return };
    for model in ["gcn", "sage"] {
        for (spec, steps) in [
            (SamplerSpec::Neighbor { targets: 4, budgets: vec![5, 3] }, 6usize),
            (SamplerSpec::Subgraph { budget: 4, layers: 2 }, 4),
        ] {
            let design = HpGnn::init()
                .platform_board("xilinx-U250")
                .unwrap()
                .gnn_computation(model)
                .unwrap()
                .gnn_parameters(vec![8])
                .sampler(spec.clone())
                .load_input_graph(tiny_graph(8))
                .generate_design(&rt)
                .unwrap();
            let mut session = design.session(&rt, 0.05, false).unwrap();
            let seen = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
            let sink = std::sync::Arc::clone(&seen);
            session.on_step(move |_| {
                sink.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            });
            session.run_for(steps).unwrap();
            let report = session.finish();
            assert_eq!(
                report.metrics.losses.len(),
                steps,
                "{model} with {spec:?} did not complete"
            );
            assert_eq!(seen.load(std::sync::atomic::Ordering::Relaxed), steps);
            assert!(report.metrics.losses.iter().all(|l| l.is_finite()));
        }
    }
}

#[test]
fn distribute_data_places_features_by_capacity() {
    use hp_gnn::accel::device::FeaturePlacement;
    let Some(rt) = runtime() else { return };
    // Flickr's full feature matrix (89,250 x 500 f32 = 178 MB) fits in
    // 64 GB of FPGA DDR -> FpgaLocal.
    let design = HpGnn::init()
        .platform_board("xilinx-U250")
        .unwrap()
        .gnn_computation("gcn")
        .unwrap()
        .gnn_parameters(vec![256])
        .sampler(SamplerSpec::Neighbor { targets: 32, budgets: vec![5, 10] })
        .load_dataset("FL", 0.01, 1)
        .unwrap()
        .generate_design(&rt)
        .unwrap();
    assert_eq!(design.placement, FeaturePlacement::FpgaLocal);
    assert_eq!(
        design
            .to_json()
            .get("design")
            .unwrap()
            .get("feature_placement")
            .unwrap()
            .as_str()
            .unwrap(),
        "fpga-local"
    );

    // A board with tiny DDR forces host streaming.
    let mut small_board = hp_gnn::accel::Platform::alveo_u250();
    small_board.ddr_bytes = 1 << 20; // 1 MiB
    let design = HpGnn::init()
        .platform(small_board)
        .gnn_computation("gcn")
        .unwrap()
        .gnn_parameters(vec![256])
        .sampler(SamplerSpec::Neighbor { targets: 32, budgets: vec![5, 10] })
        .load_dataset("FL", 0.01, 1)
        .unwrap()
        .generate_design(&rt)
        .unwrap();
    assert_eq!(design.placement, FeaturePlacement::HostStreamed);

    // Explicit override wins.
    let design = HpGnn::init()
        .platform_board("xilinx-U250")
        .unwrap()
        .gnn_computation("gcn")
        .unwrap()
        .gnn_parameters(vec![256])
        .sampler(SamplerSpec::Neighbor { targets: 32, budgets: vec![5, 10] })
        .load_dataset("FL", 0.01, 1)
        .unwrap()
        .distribute_data(FeaturePlacement::HostStreamed)
        .generate_design(&rt)
        .unwrap();
    assert_eq!(design.placement, FeaturePlacement::HostStreamed);
}
