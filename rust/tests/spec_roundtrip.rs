//! The two contracts the declarative API redesign rests on:
//!
//! 1. **Round-trip** — for every serializable [`ProgramSpec`],
//!    `from_json(to_json(spec)) == spec` (property-style over randomly
//!    generated builder programs, every sampler variant × graph kind ×
//!    board × optional sections).
//! 2. **Full-pass diagnostics** — a program with several independent
//!    mistakes reports *all* of them, each at its JSON path, in one pass.

use std::path::{Path, PathBuf};

use hp_gnn::api::{
    GraphSpec, HpGnn, ProgramSpec, SamplerSpec, ServingSpec, TrainingSpec, Workspace,
};
use hp_gnn::util::prop::Runner;
use hp_gnn::util::rng::Pcg64;

const BOARDS: &[&str] = &["xilinx-U250", "xilinx-U280"];
const MODELS: &[&str] = &["gcn", "sage", "gin"];
const DATASETS: &[&str] = &["FL", "RD", "YP", "AP"];

/// A random program, built through the [`HpGnn`] builder like a user
/// would, with every optional section flipped on or off independently.
fn random_spec(rng: &mut Pcg64) -> ProgramSpec {
    let layers = 2 + rng.index(2); // 2..=3
    let sampler = match rng.index(3) {
        0 => SamplerSpec::Neighbor {
            targets: 1 + rng.index(64),
            budgets: (0..layers).map(|_| 1 + rng.index(16)).collect(),
        },
        1 => SamplerSpec::Subgraph { budget: 1 + rng.index(512), layers },
        _ => SamplerSpec::Layerwise {
            targets: 1 + rng.index(64),
            sizes: (0..layers).map(|_| 1 + rng.index(64)).collect(),
        },
    };
    let hidden: Vec<usize> = (0..layers - 1).map(|_| 1 + rng.index(256)).collect();
    let graph_seed = rng.below(1 << 20);

    let mut builder = HpGnn::init()
        .platform_board(BOARDS[rng.index(BOARDS.len())])
        .unwrap()
        .gnn_computation(MODELS[rng.index(MODELS.len())])
        .unwrap()
        .gnn_parameters(hidden)
        .sampler(sampler)
        .layout(hp_gnn::layout::LayoutOptions {
            rmt: rng.index(2) == 0,
            rra: rng.index(2) == 0,
        })
        .training(TrainingSpec {
            steps: rng.index(1000),
            lr: (1 + rng.index(1000)) as f32 / 997.0,
            simulate: rng.index(2) == 0,
            eval_every: rng.index(20),
            eval_batches: 1 + rng.index(4),
            checkpoint: (rng.index(2) == 0).then(|| PathBuf::from("run.ckpt")),
            checkpoint_every: rng.index(20),
        });
    builder = if rng.index(4) == 0 {
        builder.load_edge_list(Path::new("edges.txt"), 1 + rng.index(64), 2 + rng.index(9))
    } else {
        builder
            .load_dataset(
                DATASETS[rng.index(DATASETS.len())],
                (1 + rng.index(1000)) as f64 / 1000.0,
                graph_seed,
            )
            .unwrap()
    };
    if rng.index(2) == 0 {
        builder = builder.seed(rng.below(1 << 20));
    }
    if rng.index(2) == 0 {
        builder = builder.serving(ServingSpec {
            checkpoint: (rng.index(2) == 0).then(|| PathBuf::from("model.bin")),
            workers: 1 + rng.index(8),
            max_batch: rng.index(128),
            max_wait_us: rng.below(10_000),
            queue_depth: 1 + rng.index(4096),
            cache: rng.index(2) == 0,
            listen: (rng.index(2) == 0)
                .then(|| format!("127.0.0.1:{}", rng.index(65536))),
        });
    }
    if rng.index(4) == 0 {
        builder = builder.distribute_data(if rng.index(2) == 0 {
            hp_gnn::accel::device::FeaturePlacement::FpgaLocal
        } else {
            hp_gnn::accel::device::FeaturePlacement::HostStreamed
        });
    }
    let mut spec = builder.spec().expect("all required pieces are set");
    // load_dataset always records a structure seed; sometimes drop it to
    // cover the "top-level only" and "neither" seed configurations too.
    if rng.index(3) == 0 {
        if let GraphSpec::Dataset { seed, .. } = &mut spec.graph {
            *seed = None;
        }
    }
    spec
}

#[test]
fn builder_specs_round_trip_through_json() {
    Runner::new(128, 0x5bec).run(random_spec, |spec| {
        let json = spec
            .to_json()
            .map_err(|e| format!("to_json failed: {e}"))?;
        // pretty and compact must parse back to the identical spec.
        for text in [json.pretty(), json.compact()] {
            let again = ProgramSpec::from_json(&text)
                .map_err(|d| format!("re-parse failed:\n{d}\n--- emitted:\n{text}"))?;
            if &again != spec {
                return Err(format!("round-trip mismatch:\n{again:#?}\n--- vs\n{spec:#?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn round_trip_preserves_seed_resolution() {
    // The *resolved* seeds — not just the fields — must survive the trip,
    // since they are what training/serving actually key on.
    Runner::new(64, 0x5eed).run(random_spec, |spec| {
        let text = spec.to_json().map_err(|e| e.to_string())?.pretty();
        let again = ProgramSpec::from_json(&text).map_err(|d| d.to_string())?;
        if again.resolved_seed() != spec.resolved_seed() {
            return Err(format!(
                "resolved seed drifted: {} -> {}",
                spec.resolved_seed(),
                again.resolved_seed()
            ));
        }
        if again.structure_seed() != spec.structure_seed() {
            return Err("structure seed drifted".to_string());
        }
        Ok(())
    });
}

/// Three independent mistakes in three different sections — all three
/// paths must come back from a single validation pass.
#[test]
fn program_with_three_mistakes_reports_all_three_paths() {
    let text = r#"{
      "platform": "stratix-10",
      "model": {"computation": "GCN", "hidden": [8, 8]},
      "sampler": {"type": "NeighborSampler", "budgets": [], "targets": 4},
      "graph": {"dataset": "FL", "scale": 0.005},
      "training": {"steps": 5, "lr": 0.1}
    }"#;
    let spec = ProgramSpec::from_json(text).expect("syntactically fine");
    let d = spec.validate();
    let paths: Vec<&str> = d.iter().map(|x| x.path.as_str()).collect();
    assert!(paths.contains(&"platform"), "missing platform diagnostic: {paths:?}");
    assert!(paths.contains(&"model.hidden"), "missing model.hidden diagnostic: {paths:?}");
    assert!(paths.contains(&"sampler.budgets"), "missing sampler.budgets diagnostic: {paths:?}");
    assert!(d.len() >= 3, "{d}");
    // The unknown-board diagnostic enumerates the registry.
    let board = d.iter().find(|x| x.path == "platform").unwrap();
    let hint = board.hint.as_deref().unwrap_or_default();
    assert!(hint.contains("xilinx-U250") && hint.contains("xilinx-U280"), "{hint}");
    // And the whole set surfaces through the design path as one error.
    let err = Workspace::reference().design(&spec).unwrap_err().to_string();
    assert!(
        err.contains("platform") && err.contains("model.hidden") && err.contains("sampler.budgets"),
        "{err}"
    );
}

#[test]
fn parse_stage_also_collects_across_sections() {
    // Unknown keys in two different sections + a type error in a third:
    // one parse, three diagnostics.
    let text = r#"{
      "platform": "xilinx-U250",
      "model": {"computation": "GCN", "hiddne": [8]},
      "sampler": {"type": "NeighborSampler", "budgets": [5, 3], "targets": 4, "budgte": 1},
      "graph": {"dataset": "FL", "scale": "tiny"},
      "training": {"steps": 5, "lr": 0.1}
    }"#;
    let d = ProgramSpec::from_json(text).unwrap_err();
    let paths: Vec<&str> = d.iter().map(|x| x.path.as_str()).collect();
    assert!(paths.contains(&"model.hiddne"), "{paths:?}");
    assert!(paths.contains(&"sampler.budgte"), "{paths:?}");
    assert!(paths.contains(&"graph.scale"), "{paths:?}");
    // The typo'd `hidden` is *also* reported as missing.
    assert!(paths.contains(&"model.hidden"), "{paths:?}");
}

#[test]
fn seed_conflict_diagnostic_and_precedence() {
    let text = r#"{
      "platform": "xilinx-U250",
      "model": {"computation": "GCN", "hidden": [8]},
      "sampler": {"type": "NeighborSampler", "budgets": [5, 3], "targets": 4},
      "graph": {"dataset": "FL", "scale": 0.005, "seed": 3},
      "seed": 9,
      "training": {"steps": 5, "lr": 0.1}
    }"#;
    let spec = ProgramSpec::from_json(text).unwrap();
    assert_eq!(spec.resolved_seed(), 9, "top-level seed drives training");
    assert_eq!(spec.structure_seed(), 3, "graph.seed drives structure");
    let d = spec.validate();
    assert!(d.iter().any(|x| x.path == "seed"), "conflict must be diagnosed: {d}");
    // Removing the conflict clears the diagnostic either way.
    let same = text.replace("\"seed\": 9,", "\"seed\": 3,");
    assert!(ProgramSpec::from_json(&same).unwrap().validate().is_empty());
    let top_only = text.replace("\"scale\": 0.005, \"seed\": 3", "\"scale\": 0.005");
    let spec = ProgramSpec::from_json(&top_only).unwrap();
    assert!(spec.validate().is_empty());
    assert_eq!(spec.resolved_seed(), 9);
    assert_eq!(spec.structure_seed(), 9, "top-level seed backfills structure");
}

#[test]
fn workspace_design_honors_serving_section() {
    // A spec with a serving section resolves the serve config from the
    // program (the CLI path layers flag overrides on the same struct).
    let mut g = hp_gnn::graph::generator::with_min_degree(
        hp_gnn::graph::generator::rmat(400, 3200, Default::default(), 5),
        1,
        6,
    );
    g.feat_dim = 16;
    g.num_classes = 4;
    let spec = HpGnn::init()
        .platform_board("xilinx-U250")
        .unwrap()
        .gnn_computation("gcn")
        .unwrap()
        .gnn_parameters(vec![8])
        .sampler(SamplerSpec::Neighbor { targets: 4, budgets: vec![5, 3] })
        .load_input_graph(g)
        .serving(ServingSpec { workers: 3, max_batch: 7, cache: true, ..Default::default() })
        .spec()
        .unwrap();
    let ws = Workspace::reference();
    let design = ws.design(&spec).unwrap();
    let cfg = design.serve_config();
    assert_eq!(cfg.workers, 3);
    assert_eq!(cfg.max_batch, 7);
    assert!(cfg.cache);
    // No checkpoint in the section -> .server() says what is missing.
    let err = design.server().unwrap_err().to_string();
    assert!(err.contains("serving.checkpoint"), "{err}");
}
