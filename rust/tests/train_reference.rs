//! Reference-backend acceptance tests: end-to-end training on a clean
//! machine (no XLA, no prebuilt artifacts), and numerical parity of the
//! executor against the `python/compile/kernels/ref.py` kernel oracles
//! transcribed to rust on a fixed batch.

use hp_gnn::coordinator::{train, TrainConfig};
use hp_gnn::graph::generator;
use hp_gnn::layout::pad::{pad, EdgeOverflow, PaddedBatch};
use hp_gnn::layout::{index_batch, LayoutOptions};
use hp_gnn::runtime::{inputs, Kind, Runtime, WeightState};
use hp_gnn::sampler::neighbor::NeighborSampler;
use hp_gnn::sampler::values::{attach_values, GnnModel};
use hp_gnn::sampler::Sampler;
use hp_gnn::util::rng::Pcg64;

fn tiny_graph(seed: u64) -> hp_gnn::graph::Graph {
    let mut g = generator::with_min_degree(
        generator::rmat(500, 4000, Default::default(), seed),
        1,
        seed ^ 1,
    );
    g.feat_dim = 16;
    g.num_classes = 4;
    g
}

#[test]
fn quick_config_trains_20_steps_with_decreasing_finite_loss() {
    let rt = Runtime::reference();
    assert_eq!(rt.backend_name(), "reference");
    let g = tiny_graph(41);
    let sampler = NeighborSampler::new(4, vec![5, 3]);
    let mut cfg = TrainConfig::quick(GnnModel::Gcn, "tiny", 25);
    cfg.lr = 0.1;
    let report = train(&rt, &g, &sampler, &cfg).unwrap();
    assert_eq!(report.metrics.losses.len(), 25);
    assert!(report.metrics.losses.iter().all(|l| l.is_finite()));
    let (head, tail) = report.metrics.loss_drop().unwrap();
    assert!(
        tail < head,
        "loss did not descend on the reference backend: {head:.4} -> {tail:.4} \
         ({:?})",
        report.metrics.losses
    );
    assert!(report.final_weights.l2_norm() > 0.0);
}

/// A deterministic padded batch + features on the tiny geometry.
fn fixed_batch(
    model: GnnModel,
    geom: &hp_gnn::layout::Geometry,
) -> (PaddedBatch, Vec<f32>) {
    let g = tiny_graph(77);
    let sampler = NeighborSampler::new(4, vec![5, 3]);
    let mb = sampler.sample(&g, &mut Pcg64::seed_from_u64(3));
    let vals = attach_values(&g, &mb, model);
    let ib = index_batch(&mb, &vals, LayoutOptions::all());
    let labels: Vec<u8> = (0..mb.layers[2].len()).map(|i| (i % 4) as u8).collect();
    let padded = pad(&ib, &labels, geom, EdgeOverflow::Error).unwrap();
    let mut rng = Pcg64::seed_from_u64(9);
    let features: Vec<f32> = (0..geom.b[0] * geom.f[0])
        .map(|_| rng.f32_range(-1.0, 1.0))
        .collect();
    (padded, features)
}

/// `ref.py aggregate_ref`: `out[v] = sum_{e: dst[e]==v} val[e] * x[src[e]]`.
fn aggregate_ref(x: &[f32], f: usize, src: &[i32], dst: &[i32], val: &[f32], num_out: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; num_out * f];
    for e in 0..src.len() {
        let (s, d) = (src[e] as usize, dst[e] as usize);
        for j in 0..f {
            out[d * f + j] += val[e] * x[s * f + j];
        }
    }
    out
}

/// `ref.py update_ref`: `sigma(a @ w + b)`.
fn update_ref(a: &[f32], rows: usize, fin: usize, w: &[f32], b: &[f32], fout: usize, relu: bool) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * fout];
    for i in 0..rows {
        for j in 0..fout {
            let mut z = b[j];
            for k in 0..fin {
                z += a[i * fin + k] * w[k * fout + j];
            }
            out[i * fout + j] = if relu { z.max(0.0) } else { z };
        }
    }
    out
}

/// `ref.py` gcn_layer_ref / sage_layer_ref stacked per model.py's forward.
fn forward_ref(
    model: GnnModel,
    geom: &hp_gnn::layout::Geometry,
    batch: &PaddedBatch,
    features: &[f32],
    weights: &WeightState,
) -> Vec<f32> {
    let ll = geom.layers();
    let mut h = features.to_vec();
    for l in 0..ll {
        let fin = geom.f[l];
        let fout = geom.f[l + 1];
        let rows = geom.b[l + 1];
        let agg = aggregate_ref(&h, fin, &batch.src[l], &batch.dst[l], &batch.val[l], rows);
        let (a, fin_cat) = if model == GnnModel::Sage {
            let mut cat = vec![0.0f32; rows * 2 * fin];
            for i in 0..rows {
                let s = batch.self_idx[l][i] as usize;
                cat[i * 2 * fin..i * 2 * fin + fin].copy_from_slice(&h[s * fin..(s + 1) * fin]);
                cat[i * 2 * fin + fin..(i + 1) * 2 * fin]
                    .copy_from_slice(&agg[i * fin..(i + 1) * fin]);
            }
            (cat, 2 * fin)
        } else {
            (agg, fin)
        };
        let w = &weights.tensors[2 * l].1;
        let b = &weights.tensors[2 * l + 1].1;
        h = update_ref(&a, rows, fin_cat, w, b, fout, l + 1 < ll);
    }
    h
}

/// `model.masked_xent`: mean softmax cross-entropy over unmasked targets.
fn masked_xent_ref(logits: &[f32], labels: &[i32], mask: &[f32], classes: usize) -> f32 {
    let denom: f32 = mask.iter().sum::<f32>().max(1.0);
    let mut loss = 0.0f32;
    for i in 0..labels.len() {
        let row = &logits[i * classes..(i + 1) * classes];
        let max = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
        let lse = max + row.iter().map(|&x| (x - max).exp()).sum::<f32>().ln();
        loss -= (row[labels[i] as usize] - lse) * mask[i];
    }
    loss / denom
}

#[test]
fn reference_backend_matches_ref_py_semantics_on_fixed_batch() {
    let rt = Runtime::reference();
    for model in [GnnModel::Gcn, GnnModel::Sage] {
        let fwd = rt.compile_role(model, "tiny", Kind::Forward).unwrap();
        let geom = fwd.spec.geometry.clone();
        let (padded, features) = fixed_batch(model, &geom);
        let weights = WeightState::init_glorot(&fwd.spec.weight_shapes, 23);

        // Forward parity: executor logits vs the ref.py transcription.
        let lits = inputs::build_inputs(&fwd.spec, &padded, &features, &weights, 0.0).unwrap();
        let outs = fwd.run(&lits).unwrap();
        let got = outs[0].f32_data().unwrap();
        let want = forward_ref(model, &geom, &padded, &features, &weights);
        assert_eq!(got.len(), want.len());
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 * b.abs().max(1.0),
                "{model:?} logit {i}: executor {a} vs ref.py {b}"
            );
        }

        // Loss parity through the train-step artifact on the same batch.
        let ts = rt.compile_role(model, "tiny", Kind::TrainStep).unwrap();
        let lits = inputs::build_inputs(&ts.spec, &padded, &features, &weights, 0.05).unwrap();
        let outs = ts.run(&lits).unwrap();
        let loss = outs[0].scalar().unwrap();
        let want_loss =
            masked_xent_ref(&want, &padded.labels, &padded.mask, geom.num_classes());
        assert!(
            (loss - want_loss).abs() <= 1e-4 * want_loss.abs().max(1.0),
            "{model:?} loss: executor {loss} vs ref.py {want_loss}"
        );

        // The SGD update moved every weight tensor (lr > 0, real grads).
        let mut updated = weights.clone();
        updated.update_from(&outs[1..]).unwrap();
        assert_ne!(updated.tensors[0].1, weights.tensors[0].1);
    }
}
