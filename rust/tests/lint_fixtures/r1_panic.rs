//! Seeded R1 violation: a panicking unwrap on a serving request path.

pub fn first_logit(logits: &[f32]) -> f32 {
    *logits.first().unwrap()
}
