//! Seeded R1 violation: a panicking unwrap inside the training driver.

pub fn drive(logits: &[f32]) -> f32 {
    *logits.first().unwrap()
}
