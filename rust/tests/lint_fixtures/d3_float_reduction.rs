//! Seeded D3 violation: an ad-hoc float reduction bypassing kernels::.

pub fn mean_activation(xs: &[f32]) -> f32 {
    let total = xs.iter().copied().sum::<f32>();
    total / xs.len().max(1) as f32
}
