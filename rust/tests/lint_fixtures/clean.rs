//! Clean fixture: contracted (D1/D2) sampler code that trips no rule —
//! membership tests on a `HashSet` are the blessed idiom.

use std::collections::HashSet;

pub fn dedup_frontier(frontier: &[u32]) -> Vec<u32> {
    let mut seen = HashSet::new();
    frontier.iter().copied().filter(|v| seen.insert(*v)).collect()
}
