//! Seeded R2 violation: unchecked size arithmetic on header counts.

pub fn load_row_region(n_rows: usize, row_bytes: usize) -> usize {
    n_rows * row_bytes
}
