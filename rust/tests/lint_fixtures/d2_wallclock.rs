//! Seeded D2 violation: a wall-clock read inside a sampler step path.

pub fn jitter_seed(base: u64) -> u64 {
    let t = std::time::Instant::now();
    base ^ (t.elapsed().subsec_nanos() as u64)
}
