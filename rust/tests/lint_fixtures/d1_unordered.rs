//! Seeded D1 violation for the lint fixture tests: a sampler helper
//! that iterates a `HashMap`, leaking hash order into its output.

use std::collections::HashMap;

pub fn degree_histogram(edges: &[(u32, u32)]) -> Vec<(u32, usize)> {
    let mut degree: HashMap<u32, usize> = HashMap::new();
    for &(src, _) in edges {
        *degree.entry(src).or_insert(0) += 1;
    }
    degree.iter().map(|(v, d)| (*v, *d)).collect()
}
