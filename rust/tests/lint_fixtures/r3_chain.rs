//! Seeded R3 violation: a panic two hops below the serving entrypoint.
//! `classify` is an R3 root; the reachable `.unwrap()` lives in a free
//! helper the textual R1 rule could never have connected to it.

pub struct Server;

impl Server {
    pub fn classify(&self, raw: &[u8]) -> Vec<f32> {
        self.lookup(raw)
    }

    fn lookup(&self, raw: &[u8]) -> Vec<f32> {
        decode(raw)
    }
}

fn decode(raw: &[u8]) -> Vec<f32> {
    let head = raw.first().unwrap();
    vec![*head as f32]
}
