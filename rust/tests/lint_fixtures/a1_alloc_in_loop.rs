//! Seeded A1 violation: an allocation inside a kernel loop body.  The
//! `with_capacity` prologue above the loop is the blessed idiom and
//! must stay unflagged.

pub fn gather_rows(src: &[f32], idx: &[usize], width: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(idx.len() * width);
    for &i in idx {
        let row = src[i * width..(i + 1) * width].to_vec();
        out.extend_from_slice(&row);
    }
    out
}
