//! Pragma fixture: a stale allow that suppresses nothing.

pub fn add(a: u32, b: u32) -> u32 {
    // lint:allow(D2): stale justification left behind by a refactor
    a + b
}
