//! Seeded C1 violation: two locks acquired in both orders across
//! functions — the classic AB/BA deadlock shape.

use std::sync::Mutex;

pub struct Shared {
    pub queue: Mutex<Vec<u32>>,
    pub stats: Mutex<u64>,
}

pub fn drain(s: &Shared) -> usize {
    let q = s.queue.lock().unwrap();
    let st = s.stats.lock().unwrap();
    q.len() + *st as usize
}

pub fn report(s: &Shared) -> usize {
    let st = s.stats.lock().unwrap();
    let q = s.queue.lock().unwrap();
    *st as usize + q.len()
}
