//! Pragma fixture: a justified, audited D2 suppression.

pub fn walltime_probe() -> std::time::Duration {
    // lint:allow(D2): measurement-only probe; never reaches batch outputs
    let start = std::time::Instant::now();
    start.elapsed()
}
