//! Thread-count invariance of the kernel layer, end to end.
//!
//! The kernels in `runtime/kernels/` promise bit-identical results at
//! every thread count (they never tile the reduction dimension — see the
//! module docs).  The unit property suites assert that per kernel against
//! the naive-loop oracles; this file asserts the composed guarantee:
//!
//! * every executor output (forward / train_step / adam_step, GCN and
//!   SAGE) is bit-identical between the scalar pre-kernel baseline and
//!   the tiled kernels at threads ∈ {1, 2, 8}, on a geometry large
//!   enough that workers really spawn;
//! * a training session's loss curve is bit-equal between
//!   `compute_threads = 1` and `compute_threads = 8`.

use std::sync::Arc;

use hp_gnn::coordinator::{TrainConfig, TrainingSession};
use hp_gnn::graph::generator;
use hp_gnn::layout::pad::PaddedBatch;
use hp_gnn::layout::Geometry;
use hp_gnn::runtime::manifest::{spec_for, Kind, Manifest};
use hp_gnn::runtime::weights::AdamState;
use hp_gnn::runtime::{inputs, Backend, ReferenceBackend, Runtime, Tensor, WeightState};
use hp_gnn::sampler::neighbor::NeighborSampler;
use hp_gnn::sampler::values::GnnModel;
use hp_gnn::util::rng::Pcg64;

/// Big enough that every dense/sparse kernel clears the sequential-
/// dispatch threshold, odd enough (non-power-of-two rows) to exercise
/// ragged tiles.
fn parity_geom() -> Geometry {
    Geometry {
        name: "kernel_parity".into(),
        b: vec![600, 130, 33],
        e: vec![2100, 520],
        f: vec![96, 64, 8],
    }
}

fn run_config(
    backend: ReferenceBackend,
    model: GnnModel,
    kind: Kind,
    geom: &Geometry,
) -> Vec<Tensor> {
    let spec = spec_for(model, kind, geom);
    let exe = backend.compile(&Manifest::builtin(), &spec).unwrap();
    let batch = PaddedBatch::synthetic(geom, 5);
    let weights = WeightState::init_glorot(&spec.weight_shapes, 23);
    let adam = (kind == Kind::AdamStep).then(|| AdamState::zeros(&spec.weight_shapes));
    let mut rng = Pcg64::seed_from_u64(9);
    let features: Vec<f32> =
        (0..geom.b[0] * geom.f[0]).map(|_| rng.f32_range(-1.0, 1.0)).collect();
    let lits =
        inputs::build_inputs_opt(&spec, &batch, &features, &weights, 0.05, adam.as_ref()).unwrap();
    exe.run(&lits).unwrap()
}

#[test]
fn executor_outputs_are_bit_identical_across_thread_counts() {
    let geom = parity_geom();
    for model in [GnnModel::Gcn, GnnModel::Sage] {
        for kind in [Kind::Forward, Kind::TrainStep, Kind::AdamStep] {
            let baseline = run_config(ReferenceBackend::scalar_baseline(), model, kind, &geom);
            for threads in [1usize, 2, 8] {
                let got = run_config(ReferenceBackend::with_threads(threads), model, kind, &geom);
                assert_eq!(
                    got, baseline,
                    "{model:?}/{kind:?} at {threads} threads diverged from the scalar baseline"
                );
            }
        }
    }
}

fn loss_curve(compute_threads: usize) -> Vec<f32> {
    let rt = Runtime::reference();
    let mut g = generator::with_min_degree(
        generator::rmat(400, 3200, Default::default(), 31),
        1,
        30,
    );
    g.feat_dim = 16;
    g.num_classes = 4;
    let mut cfg = TrainConfig::quick(GnnModel::Gcn, "tiny", 0);
    cfg.compute_threads = compute_threads;
    let mut s = TrainingSession::new(
        &rt,
        Arc::new(g),
        Arc::new(NeighborSampler::new(4, vec![5, 3])),
        cfg,
    )
    .unwrap();
    s.run_for(8).unwrap();
    s.finish().metrics.losses
}

#[test]
fn session_loss_curve_is_bit_equal_between_1_and_n_compute_threads() {
    let one = loss_curve(1);
    let eight = loss_curve(8);
    assert_eq!(one.len(), 8);
    assert_eq!(one, eight, "loss curve depends on compute_threads");
}
