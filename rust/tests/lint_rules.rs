//! Fixture-driven tests for the `hp-gnn lint` contract rules.
//!
//! Each fixture under `lint_fixtures/` seeds exactly one violation (or
//! exercises the pragma machinery); the tests pin rule id, path, and
//! line, so the scanner cannot silently stop seeing a pattern.  The
//! final tests lint the real `rust/src` tree — the repo must stay
//! delta-clean against the committed `lint_baseline.json`, which is
//! exactly what `make lint` / CI enforce.
//!
//! Fixture files live in a subdirectory so cargo does not compile them
//! as test targets (several would not build — that is the point).

use hp_gnn::lint::baseline::{diff, Baseline};
use hp_gnn::lint::{lint_source, lint_tree, Finding, RuleId};

/// Run `lint_source` and insist the fixture seeds exactly one finding.
fn only_finding(rel: &str, text: &str) -> Finding {
    let mut findings = lint_source(rel, text);
    assert_eq!(findings.len(), 1, "expected exactly one finding, got {findings:?}");
    findings.pop().unwrap()
}

#[test]
fn d1_fixture_flags_hashmap_iteration() {
    let f = only_finding(
        "sampler/d1_unordered.rs",
        include_str!("lint_fixtures/d1_unordered.rs"),
    );
    assert_eq!(f.rule, Some(RuleId::D1));
    assert_eq!(f.path, "sampler/d1_unordered.rs");
    assert_eq!(f.line, 11, "the `degree.iter()` line: {}", f.reason);
    assert!(f.reason.contains("degree"), "{}", f.reason);
}

#[test]
fn d2_fixture_flags_wallclock_read() {
    let f = only_finding(
        "sampler/d2_wallclock.rs",
        include_str!("lint_fixtures/d2_wallclock.rs"),
    );
    assert_eq!(f.rule, Some(RuleId::D2));
    assert_eq!(f.path, "sampler/d2_wallclock.rs");
    assert_eq!(f.line, 4, "the `Instant::now()` line: {}", f.reason);
}

#[test]
fn d3_fixture_flags_adhoc_float_sum() {
    let f = only_finding(
        "runtime/tensor.rs",
        include_str!("lint_fixtures/d3_float_reduction.rs"),
    );
    assert_eq!(f.rule, Some(RuleId::D3));
    assert_eq!(f.line, 4, "the `.sum::<f32>()` line: {}", f.reason);
    assert!(f.reason.contains("sum::<f32>"), "{}", f.reason);
}

#[test]
fn r1_fixture_flags_unwrap_in_contracted_function() {
    // R1 is now function-scoped to the training driver; the old serve/
    // binding was replaced by the transitive R3.
    let f = only_finding(
        "coordinator/session.rs",
        include_str!("lint_fixtures/r1_panic.rs"),
    );
    assert_eq!(f.rule, Some(RuleId::R1));
    assert_eq!(f.line, 4, "the `.unwrap()` line: {}", f.reason);
    assert!(f.reason.contains(".unwrap"), "{}", f.reason);
}

#[test]
fn r2_fixture_flags_unchecked_loader_multiply() {
    let f = only_finding("graph/io.rs", include_str!("lint_fixtures/r2_overflow.rs"));
    assert_eq!(f.rule, Some(RuleId::R2));
    assert_eq!(f.line, 4, "the `n_rows * row_bytes` line: {}", f.reason);
    assert!(f.reason.contains("checked_mul"), "{}", f.reason);
}

#[test]
fn r3_fixture_flags_reachable_panic_with_call_chain() {
    let f = only_finding("serve/server.rs", include_str!("lint_fixtures/r3_chain.rs"));
    assert_eq!(f.rule, Some(RuleId::R3));
    assert_eq!(f.line, 18, "the `.unwrap()` line in `decode`: {}", f.reason);
    assert!(
        f.reason.contains("Server::classify → Server::lookup → decode"),
        "the shortest root-to-panic chain must be printed: {}",
        f.reason
    );
}

#[test]
fn c1_fixture_flags_the_ab_ba_lock_cycle_once() {
    let f = only_finding(
        "coordinator/locks.rs",
        include_str!("lint_fixtures/c1_lock_cycle.rs"),
    );
    assert_eq!(f.rule, Some(RuleId::C1));
    assert_eq!(f.line, 13, "anchored at the first cycle edge: {}", f.reason);
    assert!(f.reason.contains("cycle"), "{}", f.reason);
    assert!(
        f.reason.contains("queue") && f.reason.contains("stats"),
        "both locks of the cycle must be named: {}",
        f.reason
    );
}

#[test]
fn a1_fixture_flags_loop_alloc_but_not_the_prologue() {
    let f = only_finding(
        "runtime/kernels/a1_alloc.rs",
        include_str!("lint_fixtures/a1_alloc_in_loop.rs"),
    );
    assert_eq!(f.rule, Some(RuleId::A1));
    assert_eq!(f.line, 8, "the `.to_vec()` line inside the loop: {}", f.reason);
    assert!(f.reason.contains(".to_vec()"), "{}", f.reason);
}

#[test]
fn clean_fixture_produces_no_findings() {
    let findings = lint_source("sampler/clean.rs", include_str!("lint_fixtures/clean.rs"));
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn pragma_with_reason_suppresses_the_finding() {
    let findings = lint_source(
        "sampler/pragma_allowed.rs",
        include_str!("lint_fixtures/pragma_allowed.rs"),
    );
    assert!(findings.is_empty(), "a justified pragma must suppress: {findings:?}");
}

#[test]
fn unused_pragma_is_itself_a_finding() {
    let f = only_finding(
        "sampler/pragma_unused.rs",
        include_str!("lint_fixtures/pragma_unused.rs"),
    );
    assert_eq!(f.rule, None, "pragma problems carry no rule: {f:?}");
    assert_eq!(f.line, 4, "anchored at the pragma itself: {}", f.reason);
    assert!(f.reason.contains("P2 unused-pragma"), "{}", f.reason);
}

#[test]
fn graph_store_is_bound_to_r2_and_d1() {
    // The contract table binds the out-of-core store to the loader
    // (R2) and determinism (D1) contracts...
    let bound: Vec<_> = hp_gnn::lint::CONTRACTS
        .iter()
        .filter(|c| c.prefix == "graph/store/")
        .map(|c| c.rule)
        .collect();
    assert!(bound.contains(&RuleId::R2), "graph/store/ must owe R2: {bound:?}");
    assert!(bound.contains(&RuleId::D1), "graph/store/ must owe D1: {bound:?}");
    // ...and the bindings actually fire: the same seeded violations the
    // fixtures pin elsewhere are findings under graph/store/ too.
    let f = only_finding(
        "graph/store/format.rs",
        include_str!("lint_fixtures/r2_overflow.rs"),
    );
    assert_eq!(f.rule, Some(RuleId::R2));
    let f = only_finding(
        "graph/store/snapshot.rs",
        include_str!("lint_fixtures/d1_unordered.rs"),
    );
    assert_eq!(f.rule, Some(RuleId::D1));
}

#[test]
fn fixtures_cover_every_contract_rule() {
    // The eight seeded fixtures above demonstrate D1, D2, D3, R1, R2,
    // R3, C1, A1 — keep this inventory in sync so adding a rule forces
    // a fixture.
    assert_eq!(RuleId::ALL.len(), 8);
}

#[test]
fn fingerprints_are_stable_and_baselines_round_trip() {
    let findings = lint_source("serve/server.rs", include_str!("lint_fixtures/r3_chain.rs"));
    assert_eq!(findings.len(), 1);
    assert_eq!(findings[0].fingerprint.len(), 16, "{:?}", findings[0].fingerprint);

    let base = Baseline::from_findings(&findings);
    let round = Baseline::parse(&base.to_json().pretty()).expect("baseline JSON round-trips");
    assert_eq!(round.entries, base.entries);
    assert!(diff(&findings, &round).is_clean(), "a finding is clean against its own baseline");

    // The ratchet's two failure modes: a fresh finding, and a stale entry.
    let empty = Baseline { entries: Vec::new() };
    let d = diff(&findings, &empty);
    assert_eq!(d.fresh.len(), 1, "unbaselined findings are fresh");
    let d = diff(&[], &base);
    assert_eq!(d.stale.len(), 1, "fixed findings leave stale entries behind");
}

#[test]
fn the_repo_tree_is_delta_clean_against_the_committed_baseline() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    let report = lint_tree(&root).expect("lint_tree over the real repo");
    assert!(report.files_scanned > 30, "only scanned {} files", report.files_scanned);

    let text = std::fs::read_to_string(root.join("lint_baseline.json"))
        .expect("committed lint_baseline.json");
    let base = Baseline::parse(&text).expect("parse committed baseline");
    let d = diff(&report.findings, &base);
    assert!(
        d.is_clean(),
        "rust/src must stay delta-clean against lint_baseline.json \
         (fix, lint:allow with a reason, or `make lint-baseline`): \
         fresh={:?} stale={:?}\n{}",
        d.fresh,
        d.stale,
        report.into_diagnostics()
    );
}

#[test]
fn the_real_callgraph_is_substantial_and_mostly_resolved() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    let report = lint_tree(&root).expect("lint_tree over the real repo");
    assert!(report.stats.functions > 100, "functions: {}", report.stats.functions);
    assert!(report.edge_count > 100, "edges: {}", report.edge_count);
    assert!(
        report.stats.resolution_pct() >= 80.0,
        "call resolution fell below the 80% floor: {:.1}% of {} calls \
         (resolved {} / external {} / ambiguous {})",
        report.stats.resolution_pct(),
        report.stats.calls,
        report.stats.resolved,
        report.stats.external,
        report.stats.ambiguous
    );
}
