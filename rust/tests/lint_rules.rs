//! Fixture-driven tests for the `hp-gnn lint` contract rules.
//!
//! Each fixture under `lint_fixtures/` seeds exactly one violation (or
//! exercises the pragma machinery); the tests pin rule id, path, and
//! line, so the scanner cannot silently stop seeing a pattern.  The
//! final test lints the real `rust/src` tree — the repo itself must stay
//! clean, which is exactly what `make lint` / CI enforce.
//!
//! Fixture files live in a subdirectory so cargo does not compile them
//! as test targets (several would not build — that is the point).

use hp_gnn::lint::{lint_source, lint_tree, Finding, RuleId};

/// Run `lint_source` and insist the fixture seeds exactly one finding.
fn only_finding(rel: &str, text: &str) -> Finding {
    let mut findings = lint_source(rel, text);
    assert_eq!(findings.len(), 1, "expected exactly one finding, got {findings:?}");
    findings.pop().unwrap()
}

#[test]
fn d1_fixture_flags_hashmap_iteration() {
    let f = only_finding(
        "sampler/d1_unordered.rs",
        include_str!("lint_fixtures/d1_unordered.rs"),
    );
    assert_eq!(f.rule, Some(RuleId::D1));
    assert_eq!(f.path, "sampler/d1_unordered.rs");
    assert_eq!(f.line, 11, "the `degree.iter()` line: {}", f.reason);
    assert!(f.reason.contains("degree"), "{}", f.reason);
}

#[test]
fn d2_fixture_flags_wallclock_read() {
    let f = only_finding(
        "sampler/d2_wallclock.rs",
        include_str!("lint_fixtures/d2_wallclock.rs"),
    );
    assert_eq!(f.rule, Some(RuleId::D2));
    assert_eq!(f.path, "sampler/d2_wallclock.rs");
    assert_eq!(f.line, 4, "the `Instant::now()` line: {}", f.reason);
}

#[test]
fn d3_fixture_flags_adhoc_float_sum() {
    let f = only_finding(
        "runtime/tensor.rs",
        include_str!("lint_fixtures/d3_float_reduction.rs"),
    );
    assert_eq!(f.rule, Some(RuleId::D3));
    assert_eq!(f.line, 4, "the `.sum::<f32>()` line: {}", f.reason);
    assert!(f.reason.contains("sum::<f32>"), "{}", f.reason);
}

#[test]
fn r1_fixture_flags_unwrap_in_serving_path() {
    let f = only_finding("serve/r1_panic.rs", include_str!("lint_fixtures/r1_panic.rs"));
    assert_eq!(f.rule, Some(RuleId::R1));
    assert_eq!(f.line, 4, "the `.unwrap()` line: {}", f.reason);
    assert!(f.reason.contains(".unwrap"), "{}", f.reason);
}

#[test]
fn r2_fixture_flags_unchecked_loader_multiply() {
    let f = only_finding("graph/io.rs", include_str!("lint_fixtures/r2_overflow.rs"));
    assert_eq!(f.rule, Some(RuleId::R2));
    assert_eq!(f.line, 4, "the `n_rows * row_bytes` line: {}", f.reason);
    assert!(f.reason.contains("checked_mul"), "{}", f.reason);
}

#[test]
fn clean_fixture_produces_no_findings() {
    let findings = lint_source("sampler/clean.rs", include_str!("lint_fixtures/clean.rs"));
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn pragma_with_reason_suppresses_the_finding() {
    let findings = lint_source(
        "sampler/pragma_allowed.rs",
        include_str!("lint_fixtures/pragma_allowed.rs"),
    );
    assert!(findings.is_empty(), "a justified pragma must suppress: {findings:?}");
}

#[test]
fn unused_pragma_is_itself_a_finding() {
    let f = only_finding(
        "sampler/pragma_unused.rs",
        include_str!("lint_fixtures/pragma_unused.rs"),
    );
    assert_eq!(f.rule, None, "pragma problems carry no rule: {f:?}");
    assert_eq!(f.line, 4, "anchored at the pragma itself: {}", f.reason);
    assert!(f.reason.contains("P2 unused-pragma"), "{}", f.reason);
}

#[test]
fn fixtures_cover_every_contract_rule() {
    // The five seeded fixtures above demonstrate D1, D2, D3, R1, R2 —
    // keep this inventory in sync so adding a rule forces a fixture.
    assert_eq!(RuleId::ALL.len(), 5);
}

#[test]
fn the_repo_tree_is_lint_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    let report = lint_tree(&root).expect("lint_tree over the real repo");
    assert!(report.files_scanned > 30, "only scanned {} files", report.files_scanned);
    assert!(
        report.is_clean(),
        "rust/src must stay lint-clean (fix or lint:allow with a reason):\n{}",
        report.into_diagnostics()
    );
}
