//! Cross-validation: the analytic performance model (Eq. 4–9, what the
//! DSE engine sweeps) against the cycle-level simulator (what the
//! experiment benches run) on the same sampled batches.
//!
//! The two implementations share no timing code; agreement within a small
//! factor is evidence both encode the paper's microarchitecture.

use hp_gnn::accel::{simulate_batch, AccelConfig, Platform, SimOptions};
use hp_gnn::graph::datasets;
use hp_gnn::layout::{index_batch, LayoutOptions};
use hp_gnn::perf::{estimate, BatchGeometry, ModelShape};
use hp_gnn::sampler::values::{attach_values, GnnModel};
use hp_gnn::sampler::{neighbor::NeighborSampler, Sampler};
use hp_gnn::util::rng::Pcg64;

fn setup(seed: u64) -> (hp_gnn::graph::Graph, datasets::DatasetSpec) {
    let ds = datasets::FLICKR;
    (ds.scale(0.2).instantiate(seed), ds)
}

/// Run both paths on the same batch; return (analytic t_gnn, simulated
/// t_gnn).
fn both(
    g: &hp_gnn::graph::Graph,
    ds: &datasets::DatasetSpec,
    config: &AccelConfig,
    layout: LayoutOptions,
    sage: bool,
    seed: u64,
) -> (f64, f64) {
    let platform = Platform::alveo_u250();
    let sampler = NeighborSampler::new(256, vec![10, 25]);
    let mb = sampler.sample(g, &mut Pcg64::seed_from_u64(seed));
    let model = if sage { GnnModel::Sage } else { GnnModel::Gcn };
    let vals = attach_values(g, &mb, model);
    let ib = index_batch(&mb, &vals, layout);
    let feat = vec![ds.f0, 256, ds.f2];

    let sim = simulate_batch(
        &platform,
        config,
        &ib,
        &feat,
        SimOptions { sage_concat: sage, ..Default::default() },
    );

    // Analytic model fed the *actual* batch shape (so the comparison
    // isolates the timing formulas, not the geometry estimators).
    let geom = BatchGeometry {
        b: mb.layers.iter().map(|l| l.len()).collect(),
        e: mb.edges.iter().map(|e| e.len()).collect(),
    };
    let est = estimate(
        &platform,
        config,
        &geom,
        &ModelShape { feat, sage_concat: sage },
        layout,
    );
    (est.t_gnn, sim.t_gnn)
}

#[test]
fn analytic_tracks_simulator_within_2x_optimized_layout() {
    let (g, ds) = setup(1);
    for (sage, seed) in [(false, 10), (true, 11)] {
        let (analytic, simulated) =
            both(&g, &ds, &AccelConfig::paper_default(), LayoutOptions::all(), sage, seed);
        let ratio = analytic / simulated;
        assert!(
            (0.5..2.0).contains(&ratio),
            "sage={sage}: analytic {analytic:.6} vs simulated {simulated:.6} (ratio {ratio:.2})"
        );
    }
}

#[test]
fn both_models_agree_rmt_helps() {
    let (g, ds) = setup(2);
    let cfg = AccelConfig::paper_default();
    let (a_base, s_base) = both(&g, &ds, &cfg, LayoutOptions::none(), false, 20);
    let (a_all, s_all) = both(&g, &ds, &cfg, LayoutOptions::all(), false, 20);
    assert!(a_all < a_base, "analytic: layout opts should reduce t_gnn");
    assert!(s_all < s_base, "simulator: layout opts should reduce t_gnn");
}

#[test]
fn both_models_agree_on_config_scaling() {
    // Quadrupling the MAC array must not slow either model, and the two
    // must move in the same direction.
    let (g, ds) = setup(3);
    let small = AccelConfig { n: 4, m: 64 };
    let big = AccelConfig { n: 4, m: 1024 };
    let (a_small, s_small) = both(&g, &ds, &small, LayoutOptions::all(), false, 30);
    let (a_big, s_big) = both(&g, &ds, &big, LayoutOptions::all(), false, 30);
    assert!(a_big <= a_small);
    assert!(s_big <= s_small);
}

#[test]
fn sage_costs_more_than_gcn_in_both() {
    let (g, ds) = setup(4);
    let cfg = AccelConfig::paper_default();
    let (a_gcn, s_gcn) = both(&g, &ds, &cfg, LayoutOptions::all(), false, 40);
    let (a_sage, s_sage) = both(&g, &ds, &cfg, LayoutOptions::all(), true, 40);
    assert!(a_sage > a_gcn);
    assert!(s_sage > s_gcn);
}
