//! Serving-path determinism and end-to-end coverage.
//!
//! The repo's determinism invariant — results bit-identical at every
//! thread count — extends to serving: a served vertex's logits must be
//! bit-identical to the evaluator's forward path (`serve::infer`, the
//! exact code `evaluate_with` runs over a sampled batch) no matter how
//! many workers serve it, whether the cache is on, or how requests
//! coalesce into micro-batches.  Plus: the CLI answers requests from
//! checkpoints written by `hp-gnn train` (both formats).

use std::sync::Arc;
use std::time::Duration;

use hp_gnn::graph::store::DynamicGraph;
use hp_gnn::graph::{generator, Graph};
use hp_gnn::runtime::{Kind, Runtime, WeightState};
use hp_gnn::sampler::neighbor::NeighborSampler;
use hp_gnn::sampler::Sampler;
use hp_gnn::serve::infer::{self, InferOptions};
use hp_gnn::serve::{vertex_rng, ServeConfig, Server};

fn tiny_graph() -> Graph {
    let mut g = generator::with_min_degree(
        generator::rmat(400, 3200, Default::default(), 31),
        1,
        30,
    );
    g.feat_dim = 16;
    g.num_classes = 4;
    g.name = "parity".to_string();
    g
}

fn infer_options(cfg: &ServeConfig) -> InferOptions {
    InferOptions {
        model: cfg.model,
        layout: cfg.layout,
        overflow: cfg.overflow,
        seed: cfg.seed,
        value_fn: None,
    }
}

/// Ground truth for one vertex: the evaluator's forward path run over the
/// same per-vertex sampled batch the server draws.
fn solo_logits(
    rt: &Runtime,
    g: &Graph,
    sampler: &NeighborSampler,
    weights: &WeightState,
    cfg: &ServeConfig,
    v: u32,
) -> Vec<f32> {
    let exe = rt.compile_role(cfg.model, &cfg.geometry, Kind::Forward).unwrap();
    let mb = sampler
        .sample_targets(g, &[v], &mut vertex_rng(cfg.infer_seed, v))
        .unwrap();
    let opts = infer_options(cfg);
    let ib = infer::index_minibatch(g, &mb, &opts);
    let inf = infer::infer_indexed(&exe, g, &opts, weights, &ib).unwrap();
    assert_eq!(inf.real_targets, 1);
    inf.row(0).to_vec()
}

#[test]
fn served_logits_bit_identical_across_workers_cache_and_coalescing() {
    let rt = Runtime::reference();
    let graph = Arc::new(tiny_graph());
    let sampler = NeighborSampler::new(4, vec![5, 3]);
    let base = ServeConfig::default();
    let exe = rt.compile_role(base.model, &base.geometry, Kind::Forward).unwrap();
    let weights = WeightState::init_glorot(&exe.spec.weight_shapes, 3);

    let vertices: Vec<u32> = vec![2, 48, 77, 123, 199, 256, 311, 388];
    let truth: Vec<Vec<f32>> = vertices
        .iter()
        .map(|&v| solo_logits(&rt, &graph, &sampler, &weights, &base, v))
        .collect();

    for workers in [1usize, 4] {
        for cache in [false, true] {
            let cfg = ServeConfig {
                workers,
                cache,
                max_wait: Duration::from_millis(2),
                ..base.clone()
            };
            let server = Server::start(
                &rt,
                DynamicGraph::fixed(Arc::clone(&graph)),
                Arc::new(sampler.clone()),
                cfg,
                weights.clone(),
            )
            .unwrap();
            // Coalescing pattern 1: one request per vertex (batches form
            // from whatever the batcher happens to coalesce).
            for (v, want) in vertices.iter().zip(&truth) {
                let p = server.classify_one(*v).unwrap();
                assert_eq!(
                    &p.logits, want,
                    "vertex {v} drifted (workers={workers}, cache={cache}, singles)"
                );
                assert_eq!(p.label, infer::argmax(want));
            }
            // Coalescing pattern 2: one bulk request spanning several
            // micro-batches (tiny's target capacity is 4 < 8 vertices).
            for (p, want) in server.classify(&vertices).unwrap().iter().zip(&truth) {
                assert_eq!(
                    &p.logits, want,
                    "vertex {} drifted (workers={workers}, cache={cache}, bulk)",
                    p.vertex
                );
            }
            server.shutdown();
        }
    }
}

#[test]
fn unbatched_and_zero_wait_configurations_agree_with_truth() {
    let rt = Runtime::reference();
    let graph = Arc::new(tiny_graph());
    let sampler = NeighborSampler::new(4, vec![5, 3]);
    let base = ServeConfig::default();
    let exe = rt.compile_role(base.model, &base.geometry, Kind::Forward).unwrap();
    let weights = WeightState::init_glorot(&exe.spec.weight_shapes, 9);
    let vertices = [5u32, 60, 245];
    let truth: Vec<Vec<f32>> = vertices
        .iter()
        .map(|&v| solo_logits(&rt, &graph, &sampler, &weights, &base, v))
        .collect();
    for (max_batch, max_wait) in [(1usize, Duration::from_millis(1)), (64, Duration::ZERO)] {
        let cfg = ServeConfig { max_batch, max_wait, ..base.clone() };
        let server = Server::start(
            &rt,
            DynamicGraph::fixed(Arc::clone(&graph)),
            Arc::new(sampler.clone()),
            cfg,
            weights.clone(),
        )
        .unwrap();
        for (p, want) in server.classify(&vertices).unwrap().iter().zip(&truth) {
            assert_eq!(&p.logits, want, "max_batch={max_batch} drifted");
        }
        server.shutdown();
    }
}

// ---- CLI end-to-end: train writes a checkpoint, serve answers from it --

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("hpgnn-serve-cli-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The "vertex N: class C" lines of a serve run's stdout.
fn vertex_lines(stdout: &str) -> Vec<String> {
    stdout
        .lines()
        .filter(|l| l.trim_start().starts_with("vertex "))
        .map(|l| l.trim().to_string())
        .collect()
}

#[test]
fn cli_serve_answers_from_both_checkpoint_formats_deterministically() {
    let exe = env!("CARGO_BIN_EXE_hp-gnn");
    let dir = temp_dir("e2e");
    let weights = dir.join("weights.bin");
    let snapshot = dir.join("session.ckpt");

    // Train on a small synthetic instance; write BOTH artifact kinds:
    // final weights (--save, HPGNNW01) and a session snapshot
    // (--checkpoint, HPGNNS01).
    let out = std::process::Command::new(exe)
        .args(["train", "--dataset", "FL", "--scale", "0.004", "--steps", "2"])
        .args(["--save", weights.to_str().unwrap()])
        .args(["--checkpoint", snapshot.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "train failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(weights.exists() && snapshot.exists());

    let serve = |ckpt: &std::path::Path, extra: &[&str]| {
        let mut args =
            vec!["serve", "--checkpoint", ckpt.to_str().unwrap(), "--dataset", "FL"];
        args.extend_from_slice(&["--scale", "0.004", "--vertices", "3,17,42"]);
        args.extend_from_slice(extra);
        let out = std::process::Command::new(exe).args(&args).output().unwrap();
        assert!(
            out.status.success(),
            "serve failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        String::from_utf8_lossy(&out.stdout).to_string()
    };

    // HPGNNW01 weights, unbatched single worker.
    let a = serve(&weights, &["--workers", "1", "--max-batch", "1"]);
    let lines_a = vertex_lines(&a);
    assert_eq!(lines_a.len(), 3, "one answer line per vertex:\n{a}");
    assert!(lines_a.iter().all(|l| l.contains("class")), "{a}");

    // Same checkpoint, coalescing worker pool: answers must be
    // bit-identical (the printed logits include full float repr).
    let b = serve(&weights, &["--workers", "4", "--max-batch", "64", "--cache"]);
    assert_eq!(lines_a, vertex_lines(&b), "serving answers depend on batching");

    // HPGNNS01 session snapshot: same weights, same answers.
    let c = serve(&snapshot, &[]);
    assert_eq!(lines_a, vertex_lines(&c), "session snapshot served different answers");

    // Program-driven serve: a JSON user program whose `serving` section
    // names the checkpoint and the coalescing knobs drives `hp-gnn serve`
    // end to end — and answers bit-identically to the flag path.
    let prog = dir.join("serve.json");
    std::fs::write(
        &prog,
        format!(
            r#"{{
  "platform": "xilinx-U250",
  "model": {{"computation": "gcn", "hidden": [256]}},
  "sampler": {{"type": "NeighborSampler", "targets": 32, "budgets": [5, 10]}},
  "graph": {{"dataset": "FL", "scale": 0.004}},
  "seed": 7,
  "training": {{"steps": 2, "lr": 0.05}},
  "serving": {{"checkpoint": "{}", "workers": 4, "max_batch": 64, "cache": true}}
}}"#,
            weights.to_str().unwrap()
        ),
    )
    .unwrap();
    let out = std::process::Command::new(exe)
        .args(["serve", prog.to_str().unwrap(), "--vertices", "3,17,42"])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "program-driven serve failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let d = String::from_utf8_lossy(&out.stdout).to_string();
    assert_eq!(lines_a, vertex_lines(&d), "program-driven serving diverged from flags");
    assert!(d.contains("4 workers"), "serving section must set the pool size:\n{d}");
}
