//! Integration: the full runtime round-trip and the training coordinator
//! on the tiny geometry.  Under default features everything runs on the
//! always-available pure-Rust reference backend; with `--features xla`
//! the suite reverts to the artifact-gated AOT→PJRT path (skipping
//! cleanly when `make artifacts` hasn't run), making it the rust-side
//! owner of the HLO-text interchange contract.

use hp_gnn::coordinator::{train, TrainConfig};
use hp_gnn::graph::generator;
use hp_gnn::layout::pad::{pad, EdgeOverflow};
use hp_gnn::layout::{index_batch, LayoutOptions};
use hp_gnn::runtime::{inputs, Kind, Runtime, WeightState};
use hp_gnn::sampler::neighbor::NeighborSampler;
use hp_gnn::sampler::values::{attach_values, GnnModel};
use hp_gnn::sampler::Sampler;
use hp_gnn::util::rng::Pcg64;

/// Fresh runtime per test — the xla client is single-threaded (Rc-based),
/// so it cannot live in a shared static.  Tiny-geometry compiles are fast.
#[cfg(feature = "xla")]
fn runtime() -> Option<Runtime> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json")
        .exists()
        .then(|| Runtime::load(&dir).expect("runtime"))
}

#[cfg(not(feature = "xla"))]
fn runtime() -> Option<Runtime> {
    Some(Runtime::reference())
}

fn tiny_graph() -> hp_gnn::graph::Graph {
    let mut g = generator::with_min_degree(
        generator::rmat(400, 3200, Default::default(), 91),
        1,
        92,
    );
    g.feat_dim = 16;
    g.num_classes = 4;
    g
}

#[test]
fn forward_artifact_executes_with_correct_shapes() {
    let Some(rt) = runtime() else { return };
    let rt = &rt;
    let exe = rt.compile_role(GnnModel::Gcn, "tiny", Kind::Forward).unwrap();
    let geom = exe.spec.geometry.clone();

    let g = tiny_graph();
    let sampler = NeighborSampler::new(4, vec![5, 3]);
    let mut rng = Pcg64::seed_from_u64(1);
    let mb = sampler.sample(&g, &mut rng);
    let vals = attach_values(&g, &mb, GnnModel::Gcn);
    let ib = index_batch(&mb, &vals, LayoutOptions::all());
    let labels = vec![0u8; mb.layers[2].len()];
    let padded = pad(&ib, &labels, &geom, EdgeOverflow::Error).unwrap();

    let weights = WeightState::init_glorot(&exe.spec.weight_shapes, 3);
    let feats = vec![0.25f32; geom.b[0] * geom.f[0]];
    let lits = inputs::build_inputs(&exe.spec, &padded, &feats, &weights, 0.0).unwrap();
    let outs = exe.run(&lits).unwrap();
    assert_eq!(outs.len(), 1, "forward returns logits only");
    let logits = outs[0].f32_data().unwrap();
    assert_eq!(logits.len(), geom.b[2] * geom.num_classes());
    assert!(logits.iter().all(|x| x.is_finite()));
}

#[test]
fn train_step_loss_decreases_gcn() {
    let Some(rt) = runtime() else { return };
    let rt = &rt;
    let g = tiny_graph();
    let sampler = NeighborSampler::new(4, vec![5, 3]);
    let mut cfg = TrainConfig::quick(GnnModel::Gcn, "tiny", 40);
    cfg.lr = 0.1;
    let report = train(rt, &g, &sampler, &cfg).unwrap();
    assert_eq!(report.metrics.losses.len(), 40);
    let (head, tail) = report.metrics.loss_drop().unwrap();
    assert!(
        tail < head,
        "loss did not descend: head {head:.4} tail {tail:.4} ({:?})",
        &report.metrics.losses
    );
    assert!(report.metrics.functional_nvtps() > 0.0);
}

#[test]
fn train_step_loss_decreases_sage() {
    let Some(rt) = runtime() else { return };
    let rt = &rt;
    let g = tiny_graph();
    let sampler = NeighborSampler::new(4, vec![5, 3]);
    let mut cfg = TrainConfig::quick(GnnModel::Sage, "tiny", 40);
    cfg.lr = 0.1;
    cfg.seed = 11;
    let report = train(rt, &g, &sampler, &cfg).unwrap();
    let (head, tail) = report.metrics.loss_drop().unwrap();
    assert!(tail < head, "sage loss did not descend: {head:.4} -> {tail:.4}");
}

#[test]
fn training_is_deterministic_per_seed() {
    let Some(rt) = runtime() else { return };
    let rt = &rt;
    let g = tiny_graph();
    let sampler = NeighborSampler::new(4, vec![5, 3]);
    let mut cfg = TrainConfig::quick(GnnModel::Gcn, "tiny", 6);
    cfg.sampler_threads = 1; // multi-producer interleave is seed-stable only per thread
    let a = train(rt, &g, &sampler, &cfg).unwrap();
    let b = train(rt, &g, &sampler, &cfg).unwrap();
    assert_eq!(a.metrics.losses, b.metrics.losses);
}

#[test]
fn layout_options_do_not_change_training_numerics() {
    // The paper's central claim about RMT/RRA: timing-only.  Same seed,
    // same batches — the executed losses must be bit-identical across
    // layout settings (aggregation is order-invariant in f32 here because
    // the kernel accumulates in a fixed dst-major replay... in practice
    // XLA's reduction order is fixed by the HLO, so losses match to f32
    // round-off; we assert tight closeness).
    let Some(rt) = runtime() else { return };
    let rt = &rt;
    let g = tiny_graph();
    let sampler = NeighborSampler::new(4, vec![5, 3]);
    let mut base_cfg = TrainConfig::quick(GnnModel::Gcn, "tiny", 5);
    base_cfg.sampler_threads = 1;
    base_cfg.layout = LayoutOptions::none();
    let mut opt_cfg = base_cfg.clone();
    opt_cfg.layout = LayoutOptions::all();
    let a = train(rt, &g, &sampler, &base_cfg).unwrap();
    let b = train(rt, &g, &sampler, &opt_cfg).unwrap();
    for (x, y) in a.metrics.losses.iter().zip(&b.metrics.losses) {
        assert!(
            (x - y).abs() < 2e-3 * x.abs().max(1.0),
            "layout changed numerics: {x} vs {y}"
        );
    }
}

#[test]
fn simulation_attaches_accelerator_timing() {
    let Some(rt) = runtime() else { return };
    let rt = &rt;
    let g = tiny_graph();
    let sampler = NeighborSampler::new(4, vec![5, 3]);
    let mut cfg = TrainConfig::quick(GnnModel::Gcn, "tiny", 4);
    cfg.simulate = Some((
        hp_gnn::accel::Platform::alveo_u250(),
        hp_gnn::accel::AccelConfig::paper_default(),
    ));
    let report = train(rt, &g, &sampler, &cfg).unwrap();
    let sim = report.metrics.simulated_nvtps(cfg.sampler_threads).unwrap();
    assert!(sim > 0.0);
    assert!(report.metrics.t_gnn_sim.mean() > 0.0);
}

#[test]
fn subgraph_sampler_trains_with_truncation() {
    let Some(rt) = runtime() else { return };
    let rt = &rt;
    let mut g = generator::rmat(600, 9000, Default::default(), 93);
    g.feat_dim = 16;
    g.num_classes = 4;
    // Tiny geometry is an NS shape; SS batches share the vertex set, so we
    // need b0 == b1 == b2 — use the NS geometry bounds as caps instead by
    // sampling few vertices.
    let sampler = hp_gnn::sampler::subgraph::SubgraphSampler::new(4, 2);
    let cfg = TrainConfig::quick(GnnModel::Gcn, "tiny", 6);
    let report = train(rt, &g, &sampler, &cfg).unwrap();
    assert_eq!(report.metrics.losses.len(), 6);
    assert!(report.metrics.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn mismatched_sampler_depth_is_rejected() {
    let Some(rt) = runtime() else { return };
    let rt = &rt;
    let g = tiny_graph();
    let sampler = NeighborSampler::new(4, vec![5]); // 1 layer vs 2-layer artifact
    let cfg = TrainConfig::quick(GnnModel::Gcn, "tiny", 2);
    assert!(train(rt, &g, &sampler, &cfg).is_err());
}

#[test]
fn gin_trains_on_the_gcn_template() {
    // GIN resolves to the GCN artifact family with (1+ε) self-loop values.
    let Some(rt) = runtime() else { return };
    let rt = &rt;
    let g = tiny_graph();
    let sampler = NeighborSampler::new(4, vec![5, 3]);
    let mut cfg = TrainConfig::quick(GnnModel::Gin, "tiny", 40);
    cfg.lr = 0.05;
    let report = train(rt, &g, &sampler, &cfg).unwrap();
    let (head, tail) = report.metrics.loss_drop().unwrap();
    assert!(tail < head, "GIN loss did not descend: {head:.4} -> {tail:.4}");
    // And its losses differ from plain GCN on the same seed (different
    // edge values -> different computation).
    let gcn = train(rt, &g, &sampler, &TrainConfig { lr: 0.05, ..TrainConfig::quick(GnnModel::Gcn, "tiny", 40) }).unwrap();
    assert_ne!(report.metrics.losses, gcn.metrics.losses);
}

#[test]
fn adam_optimizer_trains_and_differs_from_sgd() {
    let Some(rt) = runtime() else { return };
    let rt = &rt;
    let g = tiny_graph();
    let sampler = NeighborSampler::new(4, vec![5, 3]);
    let mut adam_cfg = TrainConfig::quick(GnnModel::Gcn, "tiny", 40);
    adam_cfg.optimizer = hp_gnn::coordinator::trainer::Optimizer::Adam;
    adam_cfg.lr = 0.01;
    adam_cfg.sampler_threads = 1;
    let adam = train(rt, &g, &sampler, &adam_cfg).unwrap();
    let (head, tail) = adam.metrics.loss_drop().unwrap();
    assert!(tail < head, "adam loss did not descend: {head:.4} -> {tail:.4}");

    let mut sgd_cfg = adam_cfg.clone();
    sgd_cfg.optimizer = hp_gnn::coordinator::trainer::Optimizer::Sgd;
    let sgd = train(rt, &g, &sampler, &sgd_cfg).unwrap();
    // Same batches, same init, different update rule -> different losses
    // after step 0 (step 0 loss is pre-update, identical).
    assert!((adam.metrics.losses[0] - sgd.metrics.losses[0]).abs() < 1e-6);
    assert_ne!(adam.metrics.losses[5..], sgd.metrics.losses[5..]);
}

#[test]
fn trained_model_beats_chance_on_eval() {
    let Some(rt) = runtime() else { return };
    let rt = &rt;
    let g = tiny_graph();
    let sampler = NeighborSampler::new(4, vec![5, 3]);
    let mut cfg = TrainConfig::quick(GnnModel::Sage, "tiny", 120);
    cfg.lr = 0.1;
    cfg.seed = 21;
    let report = train(rt, &g, &sampler, &cfg).unwrap();
    let eval =
        hp_gnn::coordinator::evaluate(rt, &g, &sampler, &cfg, &report.final_weights, 8, 999)
            .unwrap();
    // 4 classes -> chance is 0.25; the trained SAGE model must beat it
    // clearly on held-out batches.
    assert!(
        eval.accuracy() > 0.5,
        "accuracy {:.3} ({}/{})",
        eval.accuracy(),
        eval.correct,
        eval.total
    );
    // Untrained weights hover near chance.
    let fresh = hp_gnn::runtime::WeightState::init_glorot(
        &rt.manifest.find(GnnModel::Sage, "tiny", hp_gnn::runtime::Kind::TrainStep)
            .unwrap()
            .weight_shapes,
        5,
    );
    let base = hp_gnn::coordinator::evaluate(rt, &g, &sampler, &cfg, &fresh, 8, 999).unwrap();
    assert!(base.accuracy() < eval.accuracy());
}

#[test]
fn checkpoint_resume_preserves_behaviour() {
    let Some(rt) = runtime() else { return };
    let rt = &rt;
    let g = tiny_graph();
    let sampler = NeighborSampler::new(4, vec![5, 3]);
    let mut cfg = TrainConfig::quick(GnnModel::Gcn, "tiny", 30);
    cfg.lr = 0.1;
    let report = train(rt, &g, &sampler, &cfg).unwrap();
    let dir = std::env::temp_dir().join(format!("hpgnn-it-ckpt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.bin");
    report.final_weights.save(&path).unwrap();
    let loaded = hp_gnn::runtime::WeightState::load(&path).unwrap();
    // Saved and reloaded weights evaluate identically.
    let a = hp_gnn::coordinator::evaluate(rt, &g, &sampler, &cfg, &report.final_weights, 3, 7)
        .unwrap();
    let b = hp_gnn::coordinator::evaluate(rt, &g, &sampler, &cfg, &loaded, 3, 7).unwrap();
    assert_eq!(a.correct, b.correct);
    assert_eq!(a.total, b.total);
}
