//! `hp-gnn` — the leader binary.
//!
//! Every subcommand drives the same declarative [`ProgramSpec`] through an
//! [`api::Workspace`](hp_gnn::api::Workspace) — whether the spec came from
//! a JSON user program (`run`/`serve`/`validate`/`explain`) or from flags
//! lowered through the [`HpGnn`] builder (`train`/`serve`/`dse`).
//!
//! Subcommands:
//!
//! * `run <program.json>` — execute a user program (paper Listing 1) as a
//!   training session (`--resume` continues from a session snapshot).
//! * `train` — train a model on a synthetic Table 4 dataset.
//! * `serve [program.json]` — serve vertex-classification requests from a
//!   checkpoint (flags, or the program's `serving` section).
//! * `validate <program.json>` — parse + design-check a program, printing
//!   **every** diagnostic (no training).
//! * `explain <program.json>` — print the generated-design report
//!   (Listing 3): artifact geometry, DSE config, utilization, placement.
//! * `graph pack` — pack a graph into an `HPGNNG02` out-of-core store
//!   (`graph info` probes one); training/serving mount it via `graph.path`.
//! * `dse` — run the design space exploration engine (Table 5 rows).
//! * `lint` — statically check the determinism / serving-robustness
//!   contracts over `rust/src` (rules D1–D3, R1–R2).
//! * `simulate` — simulate one mini-batch on the accelerator model.
//! * `info` — list artifacts, boards and platform description.
//! * `help` — this overview.
//!
//! Run `hp-gnn <subcommand> --help` for flags.

use std::path::{Path, PathBuf};

use hp_gnn::accel::{AccelConfig, SimOptions};
use hp_gnn::api::{program, GraphSpec, HpGnn, ProgramSpec, SamplerSpec, TrainingSpec, Workspace};
use hp_gnn::coordinator::{trainer::Optimizer, TrainingSession};
use hp_gnn::dse::explore;
use hp_gnn::graph::datasets;
use hp_gnn::layout::{index_batch, LayoutOptions};
use hp_gnn::sampler::values::{attach_values, GnnModel};
use hp_gnn::sampler::Sampler;
use hp_gnn::util::cli::Args;
use hp_gnn::util::rng::Pcg64;
use hp_gnn::util::si;

const USAGE: &str = "hp-gnn — HP-GNN training framework (FPGA '22 reproduction)\n\n\
     SUBCOMMANDS:\n  run <program.json>   execute a user program as a training session\n  \
     train                train on a synthetic dataset\n  \
     serve [program.json] serve vertex-classification requests from a checkpoint\n  \
     validate <program.json>  parse + design-check a program, print every diagnostic\n  \
     explain <program.json>   print the generated-design report (Listing 3)\n  \
     graph pack           pack a graph into an HPGNNG02 out-of-core store\n  \
     graph info <store>   probe a packed store header\n  \
     dse                  design space exploration (Table 5)\n  \
     lint                 check the determinism/serving-robustness contracts\n  \
     simulate             accelerator simulation of one batch\n  \
     info                 artifacts + platform info\n  \
     help                 print this overview\n\n\
     Run `hp-gnn <subcommand> --help` for flags.";

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let sub = if argv.is_empty() { String::new() } else { argv.remove(0) };
    let result = match sub.as_str() {
        "run" => cmd_run(argv),
        "train" => cmd_train(argv),
        "serve" => cmd_serve(argv),
        "validate" => cmd_validate(argv),
        "explain" => cmd_explain(argv),
        "graph" => cmd_graph(argv),
        "dse" => cmd_dse(argv),
        "lint" => cmd_lint(argv),
        "simulate" => cmd_simulate(argv),
        "info" => cmd_info(argv),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            return;
        }
        other => {
            // A missing or unknown subcommand is a usage error: usage goes
            // to stderr and the exit code is nonzero so scripts notice.
            if other.is_empty() {
                eprintln!("error: no subcommand given\n\n{USAGE}");
            } else {
                eprintln!("error: unknown subcommand {other:?}\n\n{USAGE}");
            }
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_flag(args: Args) -> Args {
    args.flag("artifacts", "artifacts", "artifact directory (make artifacts)")
}

/// Session-control flags shared by `run` and `train`.  The cadence flags
/// default to "unset" (empty) so `run` can distinguish "not given" from an
/// explicit `0` that disables a program-configured cadence.
fn session_flags(args: Args) -> Args {
    args.flag("resume", "", "resume from an HPGNNS01 session snapshot")
        .flag("eval-every", "", "evaluate on held-out batches every N steps (0 = off)")
        .flag("checkpoint", "", "session snapshot path (written per --checkpoint-every + at end)")
        .flag("checkpoint-every", "", "snapshot every N steps (0 = final snapshot only)")
}

/// An optional usize flag: empty string (the default) means "not given".
fn opt_usize_flag(args: &Args, name: &str) -> anyhow::Result<Option<usize>> {
    match args.get(name) {
        "" => Ok(None),
        s => Ok(Some(
            s.parse::<usize>()
                .map_err(|e| anyhow::anyhow!("--{name} wants an unsigned integer: {e}"))?,
        )),
    }
}

/// Read + parse a required `<program.json>` positional.
fn read_program(args: &Args, usage: &str) -> anyhow::Result<(String, String)> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: {usage}"))?;
    Ok((path.clone(), std::fs::read_to_string(path)?))
}

/// Progress hooks shared by `run` and `train`: decimated step lines plus
/// one line per evaluation.
fn install_progress_hooks(session: &mut TrainingSession<'_>, total_steps: usize) {
    let stride = (total_steps / 10).max(1);
    session.on_step(move |r| {
        if (r.step + 1) % stride == 0 {
            println!("step {:>5}: loss {:.4}", r.step, r.loss);
        }
    });
    session.on_eval(|ev| {
        println!(
            "eval @ step {}: {:.1}% accuracy ({}/{} targets)",
            ev.step,
            ev.report.accuracy() * 100.0,
            ev.report.correct,
            ev.report.total
        );
    });
}

fn cmd_run(argv: Vec<String>) -> anyhow::Result<()> {
    let args = session_flags(artifacts_flag(Args::new(
        "hp-gnn run",
        "execute a user program as a training session",
    )))
    .flag("eval-batches", "", "override training.eval_batches")
    .parse_from(argv)?;
    let (_, text) = read_program(&args, "hp-gnn run <program.json>")?;
    let mut spec = program::parse_program(&text)?;
    // Given CLI flags override the program's training section (an
    // explicit 0 disables a program-configured cadence).
    if let Some(v) = opt_usize_flag(&args, "eval-every")? {
        spec.training.eval_every = v;
    }
    if let Some(v) = opt_usize_flag(&args, "eval-batches")? {
        spec.training.eval_batches = v;
    }
    if !args.get("checkpoint").is_empty() {
        spec.training.checkpoint = Some(PathBuf::from(args.get("checkpoint")));
    }
    if let Some(v) = opt_usize_flag(&args, "checkpoint-every")? {
        spec.training.checkpoint_every = v;
    }

    let ws = Workspace::open(Path::new(args.get("artifacts")))?;
    let design = ws.design(&spec)?;
    println!("{}\n", design.explain());

    let mut session = if args.get("resume").is_empty() {
        design.session()?
    } else {
        let s = design.resume_session(Path::new(args.get("resume")))?;
        println!("resumed at step {}", s.current_step());
        s
    };
    let t = &design.spec.training;
    session.set_step_limit(t.steps);
    install_progress_hooks(&mut session, t.steps);
    session.drive(
        t.steps,
        t.eval_every,
        t.eval_batches,
        t.checkpoint.as_deref(),
        t.checkpoint_every,
    )?;
    if let Some(path) = &t.checkpoint {
        println!(
            "checkpoint: wrote session snapshot to {path:?} at step {}",
            session.current_step()
        );
    }
    let threads = session.config().sampler_threads;
    let report = session.finish();
    println!("training report:\n{}", report.metrics.to_json(threads).pretty());
    Ok(())
}

fn cmd_train(argv: Vec<String>) -> anyhow::Result<()> {
    let args = session_flags(artifacts_flag(
        Args::new("hp-gnn train", "train a GNN on a synthetic Table 4 dataset")
            .flag("board", "xilinx-U250", "board name (see `hp-gnn info` for the registry)")
            .flag("model", "gcn", "gcn | sage | gin")
            .flag("dataset", "FL", "FL | RD | YP | AP")
            .flag("scale", "0.01", "dataset scale factor (0, 1]")
            .flag("sampler", "ns", "ns | ss")
            .flag("targets", "32", "NS target vertices per batch")
            .flag("budgets", "5,10", "NS fan-outs per layer (comma separated)")
            .flag("budget", "256", "SS subgraph budget")
            .flag("steps", "50", "training iterations (total, including a resumed prefix)")
            .flag("lr", "0.05", "learning rate")
            .flag("seed", "7", "PRNG seed")
            .flag("threads", "2", "sampler threads")
            .flag(
                "compute-threads",
                "",
                "kernel worker threads for the executor (default: all cores)",
            )
            .flag("optimizer", "sgd", "sgd | adam")
            .flag("save", "", "Save_model(): final weights path (empty = no save)")
            .flag("eval-batches", "", "held-out eval batches (also run once after training)")
            .flag(
                "trace",
                "",
                "write a Chrome trace_event JSON profile to this path \
                 (load in chrome://tracing or Perfetto)",
            )
            .switch("simulate", "attach accelerator-simulator timing")
            .switch("no-rmt", "disable the RMT layout optimization")
            .switch("no-rra", "disable the RRA layout optimization"),
    ))
    .parse_from(argv)?;

    let sampler = match args.get("sampler") {
        "ns" => SamplerSpec::Neighbor {
            targets: args.usize("targets"),
            budgets: args
                .get("budgets")
                .split(',')
                .map(|b| b.trim().parse())
                .collect::<Result<Vec<usize>, _>>()?,
        },
        "ss" => SamplerSpec::Subgraph { budget: args.usize("budget"), layers: 2 },
        other => anyhow::bail!("unknown sampler {other:?} (ns|ss)"),
    };
    let layout = LayoutOptions { rmt: !args.on("no-rmt"), rra: !args.on("no-rra") };
    let trace_path =
        (!args.get("trace").is_empty()).then(|| PathBuf::from(args.get("trace")));
    if trace_path.is_some() {
        hp_gnn::obs::trace::enable();
    }
    let steps = args.usize("steps");
    let seed = args.usize("seed") as u64;
    let spec = HpGnn::init()
        .platform_board(args.get("board"))?
        .gnn_computation(args.get("model"))?
        .gnn_parameters(vec![256])
        .sampler(sampler)
        .layout(layout)
        .seed(seed)
        .load_dataset(args.get("dataset"), args.f64("scale"), seed)?
        .training(TrainingSpec {
            steps,
            lr: args.f32("lr"),
            simulate: args.on("simulate"),
            ..Default::default()
        })
        .spec()?;

    let ws = Workspace::open(Path::new(args.get("artifacts")))?;
    let design = ws.design(&spec)?;
    println!("{}\n", design.explain());

    let mut cfg = design.train_config(steps, args.f32("lr"), args.on("simulate"));
    cfg.sampler_threads = args.usize("threads");
    if let Some(v) = opt_usize_flag(&args, "compute-threads")? {
        cfg.compute_threads = v.max(1);
    }
    cfg.optimizer = match args.get("optimizer") {
        "sgd" => Optimizer::Sgd,
        "adam" => Optimizer::Adam,
        other => anyhow::bail!("unknown optimizer {other:?} (sgd|adam)"),
    };
    let mut session = if args.get("resume").is_empty() {
        design.session_with_config(cfg)?
    } else {
        let s = design.resume_session_with_config(cfg, Path::new(args.get("resume")))?;
        println!("resumed at step {}", s.current_step());
        s
    };
    session.set_step_limit(steps);
    install_progress_hooks(&mut session, steps);
    let checkpoint = (!args.get("checkpoint").is_empty())
        .then(|| PathBuf::from(args.get("checkpoint")));
    let eval_batches = opt_usize_flag(&args, "eval-batches")?.unwrap_or(0);
    let eval_every = opt_usize_flag(&args, "eval-every")?.unwrap_or(0);
    let start_step = session.current_step();
    session.drive(
        steps,
        eval_every,
        if eval_batches > 0 { eval_batches } else { 2 },
        checkpoint.as_deref(),
        opt_usize_flag(&args, "checkpoint-every")?.unwrap_or(0),
    )?;
    if let Some(path) = &checkpoint {
        println!(
            "checkpoint: wrote session snapshot to {path:?} at step {}",
            session.current_step()
        );
    }
    // Final held-out eval, unless the periodic cadence just ran one at
    // the last step (the eval stream is fixed, so it would be identical).
    // A resume that was already past `steps` ran no periodic evals.
    let periodic_ran_final = eval_every > 0 && steps % eval_every == 0 && start_step < steps;
    if eval_batches > 0 && !periodic_ran_final {
        session.evaluate(eval_batches)?;
    }

    let threads = session.config().sampler_threads;
    let report = session.finish();
    let m = &report.metrics;
    println!("training report:\n{}", m.to_json(threads).pretty());
    if let Some((head, tail)) = m.loss_drop() {
        println!("loss: {head:.4} -> {tail:.4}");
    }
    if !args.get("save").is_empty() {
        let path = PathBuf::from(args.get("save"));
        report.final_weights.save(&path)?;
        println!("Save_model(): wrote weights to {path:?}");
    }
    if let Some(path) = &trace_path {
        let trace = hp_gnn::obs::trace::disable();
        trace.write(path)?;
        println!(
            "trace: wrote {} events to {path:?} ({} spans dropped at the buffer cap)",
            trace.events.len(),
            trace.dropped
        );
    }
    Ok(())
}

fn cmd_serve(argv: Vec<String>) -> anyhow::Result<()> {
    let args = artifacts_flag(
        Args::new(
            "hp-gnn serve",
            "serve vertex-classification requests from a trained checkpoint \
             (give a program.json with a serving section, or flags)",
        )
        .flag(
            "checkpoint",
            "",
            "HPGNNW01 weights or HPGNNS01 snapshot (required unless the program's \
             serving section names one)",
        )
        .flag("board", "xilinx-U250", "board name (flag mode; must match training)")
        .flag("model", "gcn", "gcn | sage | gin (flag mode; must match training)")
        .flag("dataset", "FL", "FL | RD | YP | AP (flag mode; must match training)")
        .flag("scale", "0.01", "dataset scale factor (0, 1] (flag mode; must match training)")
        .flag("targets", "32", "NS target vertices (flag mode; sizes the artifact geometry)")
        .flag("budgets", "5,10", "NS fan-outs per layer (flag mode; comma separated)")
        .flag("seed", "7", "PRNG seed (flag mode; must match training for feature synthesis)")
        .flag("workers", "", "forward-executor replicas (default: program value or 2)")
        .flag("max-batch", "", "micro-batch coalescing cap (0 = geometry target capacity)")
        .flag("max-wait-us", "", "micro-batch deadline in microseconds (default 200)")
        .flag("requests", "64", "self-driven demo requests when --vertices is empty")
        .flag("vertices", "", "comma-separated vertex ids to classify (one line each)")
        .flag(
            "listen",
            "",
            "serve the HTTP API on host:port (0 port = ephemeral) and block; \
             overrides the program's serving.listen",
        )
        .flag(
            "trace",
            "",
            "write a Chrome trace_event JSON profile to this path \
             (demo/vertex modes; written after the server drains)",
        )
        .switch("cache", "enable the versioned logits cache for repeat vertices"),
    )
    .parse_from(argv)?;
    let trace_path =
        (!args.get("trace").is_empty()).then(|| PathBuf::from(args.get("trace")));
    if trace_path.is_some() {
        hp_gnn::obs::trace::enable();
    }

    let spec = if let Some(path) = args.positional.first() {
        program::parse_program(&std::fs::read_to_string(path)?)?
    } else {
        let seed = args.usize("seed") as u64;
        HpGnn::init()
            .platform_board(args.get("board"))?
            .gnn_computation(args.get("model"))?
            .gnn_parameters(vec![256])
            .sampler(SamplerSpec::Neighbor {
                targets: args.usize("targets"),
                budgets: args
                    .get("budgets")
                    .split(',')
                    .map(|b| b.trim().parse())
                    .collect::<Result<Vec<usize>, _>>()?,
            })
            .seed(seed)
            .load_dataset(args.get("dataset"), args.f64("scale"), seed)?
            .spec()?
    };

    // The program's serving section is the baseline; given flags override.
    let mut serving = spec.serving.clone().unwrap_or_default();
    if let Some(v) = opt_usize_flag(&args, "workers")? {
        serving.workers = v.max(1);
    }
    if let Some(v) = opt_usize_flag(&args, "max-batch")? {
        serving.max_batch = v;
    }
    if let Some(v) = opt_usize_flag(&args, "max-wait-us")? {
        serving.max_wait_us = v as u64;
    }
    if args.on("cache") {
        serving.cache = true;
    }
    if !args.get("checkpoint").is_empty() {
        serving.checkpoint = Some(PathBuf::from(args.get("checkpoint")));
    }
    if !args.get("listen").is_empty() {
        serving.listen = Some(args.get("listen").to_string());
    }
    let listen = serving.listen.clone();
    let checkpoint = serving.checkpoint.clone().ok_or_else(|| {
        anyhow::anyhow!(
            "no checkpoint to serve: give --checkpoint <file> (weights from `hp-gnn train \
             --save` or a session snapshot from `--checkpoint`), or name one in the \
             program's serving section"
        )
    })?;
    let mut spec = spec;
    spec.serving = Some(serving);

    let ws = Workspace::open(Path::new(args.get("artifacts")))?;
    let design = ws.design(&spec)?;
    let server = design.server_from(&checkpoint)?;
    println!(
        "serving {} on geometry {} ({} workers, max batch {}, cache {})",
        design.abstraction.model.as_str(),
        server.geometry().name,
        server.num_workers(),
        server.max_batch(),
        if design.spec.serving.as_ref().is_some_and(|s| s.cache) { "on" } else { "off" },
    );

    if let Some(addr) = listen {
        // HTTP mode: bind the network frontend and serve until killed.
        let server = std::sync::Arc::new(server);
        let router = std::sync::Arc::new(hp_gnn::net::api_router(std::sync::Arc::clone(&server)));
        let http = hp_gnn::net::HttpServer::bind(&addr, router, Default::default())?;
        // Tests and CI parse this exact line for the resolved port.
        println!("listening on http://{}", http.addr());
        http.join();
        return Ok(());
    }

    if !args.get("vertices").is_empty() {
        let vertices: Vec<u32> = args
            .get("vertices")
            .split(',')
            .map(|v| v.trim().parse())
            .collect::<Result<_, _>>()?;
        for pred in server.classify(&vertices)?.iter() {
            match pred.label {
                Some(label) => println!(
                    "vertex {:>8}: class {label} (logits {:?})",
                    pred.vertex, pred.logits
                ),
                None => println!("vertex {:>8}: no prediction (NaN logits)", pred.vertex),
            }
        }
    } else {
        // Self-driven demo load: closed-loop single-vertex requests over
        // a random vertex stream (repeat vertices exercise the cache).
        let n = args.usize("requests");
        let num_vertices = design.graph.num_vertices();
        let mut rng = Pcg64::seed_from_u64(design.seed ^ 0x10ad);
        let pool: Vec<u32> = (0..(num_vertices / 4).clamp(1, 512))
            .map(|_| rng.index(num_vertices) as u32)
            .collect();
        let t = hp_gnn::util::stats::Timer::start();
        for _ in 0..n {
            let v = pool[rng.index(pool.len())];
            server.classify_one(v)?;
        }
        let secs = t.secs();
        println!(
            "served {n} requests in {:.3}s ({:.0} req/s)",
            secs,
            n as f64 / secs.max(1e-12)
        );
    }
    println!("serving metrics:\n{}", server.metrics().to_json().pretty());
    server.shutdown();
    if let Some(path) = &trace_path {
        let trace = hp_gnn::obs::trace::disable();
        trace.write(path)?;
        println!(
            "trace: wrote {} events to {path:?} ({} spans dropped at the buffer cap)",
            trace.events.len(),
            trace.dropped
        );
    }
    Ok(())
}

fn cmd_validate(argv: Vec<String>) -> anyhow::Result<()> {
    let args = artifacts_flag(Args::new(
        "hp-gnn validate",
        "parse + design-check a user program, printing every diagnostic",
    ))
    .parse_from(argv)?;
    let (path, text) = read_program(&args, "hp-gnn validate <program.json>")?;

    // Parse-stage problems (syntax, unknown keys, wrong types)...
    let spec = match ProgramSpec::from_json(&text) {
        Ok(spec) => spec,
        Err(diags) => print_diags_and_exit(&path, &diags),
    };
    // ...then a full semantic pass over the whole spec...
    let diags = spec.validate();
    if !diags.is_empty() {
        print_diags_and_exit(&path, &diags);
    }
    // ...then the design-feasibility check (board resolution + artifact
    // geometry), sized from statistics — a full-scale dataset program
    // validates without being materialized.
    let ws = Workspace::open(Path::new(args.get("artifacts")))?;
    match spec.design_check(ws.runtime()) {
        Err(e) => {
            println!("{path}: design check failed: {e:#}");
            std::process::exit(1);
        }
        Ok(geometry) => {
            println!(
                "{path}: ok — artifact geometry {geometry}, seed {}{}",
                spec.resolved_seed(),
                if spec.serving.is_some() { ", serving section present" } else { "" },
            );
        }
    }
    Ok(())
}

/// Print every diagnostic (the `Diagnostics` Display renders the full
/// list, one line each) and exit 1 (`hp-gnn validate`).
fn print_diags_and_exit(path: &str, diags: &hp_gnn::api::Diagnostics) -> ! {
    println!("{path}: {diags}");
    std::process::exit(1)
}

fn cmd_explain(argv: Vec<String>) -> anyhow::Result<()> {
    let args = artifacts_flag(Args::new(
        "hp-gnn explain",
        "print the generated-design report (Listing 3) for a user program",
    ))
    .parse_from(argv)?;
    let (_, text) = read_program(&args, "hp-gnn explain <program.json>")?;
    let spec = program::parse_program(&text)?;
    let ws = Workspace::open(Path::new(args.get("artifacts")))?;
    let design = ws.design(&spec)?;
    println!("{}", design.explain());
    println!("\nas JSON (rerunnable program + design summary):\n{}", design.to_json().pretty());
    Ok(())
}

fn cmd_graph(mut argv: Vec<String>) -> anyhow::Result<()> {
    let verb = if argv.is_empty() { String::new() } else { argv.remove(0) };
    match verb.as_str() {
        "pack" => cmd_graph_pack(argv),
        "info" => cmd_graph_info(argv),
        "" => anyhow::bail!("usage: hp-gnn graph <pack | info> (see `hp-gnn graph pack --help`)"),
        other => anyhow::bail!("unknown graph verb {other:?} (pack | info)"),
    }
}

/// `hp-gnn graph pack` — materialize a graph and write it as an
/// `HPGNNG02` out-of-core store.  Training and serving then mount it via
/// `graph.path` without holding the topology in RAM; the pack → open
/// round trip reproduces sampling bit-for-bit.
fn cmd_graph_pack(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::new(
        "hp-gnn graph pack",
        "pack a graph into an HPGNNG02 out-of-core store (mount with graph.path)",
    )
    .flag("dataset", "", "FL | RD | YP | AP (synthetic Table 4 graph)")
    .flag("scale", "0.01", "dataset scale factor (0, 1]")
    .flag("seed", "1", "graph-structure seed (must match the training program's)")
    .flag("edge-list", "", "pack an edge-list file instead of a dataset")
    .flag("feat-dim", "256", "feature dim an edge list does not carry")
    .flag("num-classes", "8", "class count an edge list does not carry")
    .flag("out", "", "store path to write (required)")
    .flag("chunk-edges", "", "edges per on-disk chunk (default 65536)")
    .flag("graph-version", "0", "version stamped into the store header")
    .parse_from(argv)?;

    let out = args.get("out");
    anyhow::ensure!(!out.is_empty(), "--out <path> is required");
    let seed = args.usize("seed") as u64;
    let spec = match (args.get("dataset"), args.get("edge-list")) {
        ("", "") => anyhow::bail!("give --dataset <key> or --edge-list <file>"),
        (ds, "") => {
            anyhow::ensure!(datasets::by_key(ds).is_some(), "unknown dataset {ds:?}");
            GraphSpec::Dataset { key: ds.to_string(), scale: args.f64("scale"), seed: Some(seed) }
        }
        ("", el) => GraphSpec::EdgeList {
            path: PathBuf::from(el),
            feat_dim: args.usize("feat-dim"),
            num_classes: args.usize("num-classes"),
            seed: None,
        },
        _ => anyhow::bail!("give either --dataset or --edge-list, not both"),
    };
    let chunk_edges = match opt_usize_flag(&args, "chunk-edges")? {
        Some(c) => c as u64,
        None => hp_gnn::graph::store::DEFAULT_CHUNK_EDGES,
    };
    let (graph, _) = spec.materialize(seed)?;
    let out = PathBuf::from(out);
    let stats = hp_gnn::graph::store::pack(
        graph.as_ref(),
        &out,
        args.usize("graph-version") as u64,
        chunk_edges,
    )?;
    println!(
        "packed {}: {} vertices, {} edges, {} chunks, {} bytes -> {}",
        graph.graph_name(),
        stats.num_vertices,
        stats.num_edges,
        stats.num_chunks,
        stats.bytes_written,
        out.display(),
    );
    Ok(())
}

/// `hp-gnn graph info <store>` — probe a packed store's header (no mmap,
/// no neighbor scan) and print its identity.
fn cmd_graph_info(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::new("hp-gnn graph info", "probe a packed HPGNNG02 store header")
        .parse_from(argv)?;
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: hp-gnn graph info <store>"))?;
    let meta = hp_gnn::graph::store::probe(Path::new(path))?;
    println!(
        "{path}: {} |V|={} |E|={} f0={} classes={} version={} chunks={} ({} edges/chunk), \
         {} bytes",
        if meta.name.is_empty() { "<unnamed>" } else { &meta.name },
        meta.num_vertices,
        meta.num_edges,
        meta.feat_dim,
        meta.num_classes,
        meta.graph_version,
        meta.num_chunks,
        meta.chunk_edges,
        meta.file_len,
    );
    Ok(())
}

fn cmd_dse(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::new("hp-gnn dse", "design space exploration (paper Table 5)")
        .flag("board", "xilinx-U250", "board name (see `hp-gnn info` for the registry)")
        .flag("model", "gcn", "gcn | sage | gin")
        .flag("dataset", "FL", "FL | RD | YP | AP")
        .flag("sampler", "ns", "ns | ss")
        .parse_from(argv)?;
    let sampler = match args.get("sampler") {
        "ns" => SamplerSpec::Neighbor { targets: 1024, budgets: vec![10, 25] },
        "ss" => SamplerSpec::Subgraph { budget: 2750, layers: 2 },
        other => anyhow::bail!("unknown sampler {other:?} (ns|ss)"),
    };
    // The same spec path as every other subcommand; dse never materializes
    // the graph — the DSE problem is sized from the published statistics.
    let spec = HpGnn::init()
        .platform_board(args.get("board"))?
        .gnn_computation(args.get("model"))?
        .gnn_parameters(vec![256])
        .sampler(sampler)
        .load_dataset(args.get("dataset"), 1.0, 1)?
        .spec()?;
    let (platform, problem) = spec.dse_problem()?;
    let r = explore(&platform, &problem);
    println!(
        "{}-{} on {} ({}): (m, n) = ({}, {}), predicted {} NVTPS, \
         DSP {:.0}% LUT {:.0}% URAM {:.0}% BRAM {:.0}% ({} candidates)",
        args.get("sampler").to_uppercase(),
        spec.model.computation.as_str().to_uppercase(),
        args.get("dataset"),
        platform.name,
        r.config.m,
        r.config.n,
        si(r.nvtps),
        r.utilization.dsp * 100.0,
        r.utilization.lut * 100.0,
        r.utilization.uram * 100.0,
        r.utilization.bram * 100.0,
        r.evaluated,
    );
    Ok(())
}

fn cmd_lint(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::new(
        "hp-gnn lint",
        "statically check the determinism (D1-D3), serving-robustness (R1-R3), \
         lock-order (C1), and hot-path allocation (A1) contracts over rust/src \
         (rules + contract table: README \"Static analysis\")",
    )
    .flag("root", ".", "repository root (the directory containing rust/src)")
    .flag("format", "text", "output format: text | json | sarif")
    .flag(
        "baseline",
        "",
        "ratchet file (e.g. lint_baseline.json): fail only on findings not in it, \
         and on stale entries (regenerate via `make lint-baseline`)",
    )
    .switch("update-baseline", "rewrite the --baseline file from the current findings")
    .switch("json", "shorthand for --format json")
    .parse_from(argv)?;

    let report = hp_gnn::lint::lint_tree(Path::new(args.get("root")))?;
    let format = if args.on("json") { "json" } else { args.get("format") };

    // Ratchet: with a baseline, only the delta decides pass/fail and the
    // non-text formats show only unbaselined findings.
    let baseline_path = args.get("baseline").to_string();
    let (shown, delta) = if baseline_path.is_empty() {
        (report.findings.clone(), None)
    } else if args.on("update-baseline") {
        let base = hp_gnn::lint::baseline::Baseline::from_findings(&report.findings);
        std::fs::write(&baseline_path, base.to_json().pretty() + "\n")?;
        println!(
            "lint: wrote {} accepted finding{} to {baseline_path}",
            base.entries.len(),
            if base.entries.len() == 1 { "" } else { "s" },
        );
        return Ok(());
    } else {
        let text = std::fs::read_to_string(&baseline_path)
            .map_err(|e| anyhow::anyhow!("lint: cannot read baseline {baseline_path}: {e}"))?;
        let base = hp_gnn::lint::baseline::Baseline::parse(&text)
            .map_err(|e| anyhow::anyhow!("lint: {e}"))?;
        let delta = hp_gnn::lint::baseline::diff(&report.findings, &base);
        let shown: Vec<_> =
            delta.fresh.iter().map(|&i| report.findings[i].clone()).collect();
        (shown, Some(delta))
    };

    let failed = match &delta {
        Some(d) => !d.is_clean(),
        None => !report.is_clean(),
    };

    match format {
        "json" => println!("{}", report.to_json().pretty()),
        "sarif" => println!("{}", hp_gnn::lint::sarif::sarif(&shown).pretty()),
        "text" => {
            if !shown.is_empty() {
                let partial = hp_gnn::lint::Report {
                    findings: shown.clone(),
                    ..Default::default()
                };
                let diags = partial.into_diagnostics();
                println!(
                    "lint: {} problem{} in rust/src ({} files scanned{})",
                    diags.len(),
                    if diags.len() == 1 { "" } else { "s" },
                    report.files_scanned,
                    if delta.is_some() { ", baseline applied" } else { "" },
                );
                for d in diags.iter() {
                    println!("  - {d}");
                }
            }
            if let Some(d) = &delta {
                for e in &d.stale {
                    println!(
                        "  - {}: baseline entry {} ({}) no longer found — the debt \
                         shrank; run `make lint-baseline` to lock it in",
                        e.path, e.fingerprint, e.rule,
                    );
                }
            }
            if !failed {
                println!(
                    "lint: {} files clean ({} contract bindings; callgraph {} fns, \
                     {} edges, {:.1}% of {} calls resolved{})",
                    report.files_scanned,
                    hp_gnn::lint::CONTRACTS.len(),
                    report.stats.functions,
                    report.edge_count,
                    report.stats.resolution_pct(),
                    report.stats.calls,
                    match &delta {
                        Some(_) => format!(
                            "; {} accepted baseline finding{}",
                            report.findings.len(),
                            if report.findings.len() == 1 { "" } else { "s" },
                        ),
                        None => String::new(),
                    },
                );
            }
        }
        other => anyhow::bail!("lint: unknown --format {other:?} (text | json | sarif)"),
    }
    if failed {
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_simulate(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::new("hp-gnn simulate", "simulate one mini-batch on the accelerator")
        .flag("board", "xilinx-U250", "board name (see `hp-gnn info` for the registry)")
        .flag("model", "gcn", "gcn | sage")
        .flag("dataset", "FL", "FL | RD | YP | AP")
        .flag("scale", "0.05", "dataset scale factor")
        .flag("targets", "1024", "NS targets")
        .flag("budgets", "10,25", "NS budgets")
        .flag("n", "4", "scatter/gather PE pairs per die")
        .flag("m", "256", "MACs per die")
        .flag("seed", "7", "seed")
        .switch("no-rmt", "disable RMT")
        .switch("no-rra", "disable RRA")
        .parse_from(argv)?;
    let ds = datasets::by_key(args.get("dataset"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let g = ds.scale(args.f64("scale")).instantiate(args.usize("seed") as u64);
    let model = GnnModel::parse(args.get("model"))?;
    let budgets: Vec<usize> = args
        .get("budgets")
        .split(',')
        .map(|b| b.trim().parse())
        .collect::<Result<_, _>>()?;
    let sampler =
        hp_gnn::sampler::neighbor::NeighborSampler::new(args.usize("targets"), budgets);
    let mb = sampler.sample(&g, &mut Pcg64::seed_from_u64(args.usize("seed") as u64));
    let vals = attach_values(&g, &mb, model);
    let layout = LayoutOptions { rmt: !args.on("no-rmt"), rra: !args.on("no-rra") };
    let ib = index_batch(&mb, &vals, layout);
    let platform =
        hp_gnn::api::PlatformSpec::Board(args.get("board").to_string()).resolve()?;
    let config = AccelConfig { n: args.usize("n"), m: args.usize("m") };
    let timing = hp_gnn::accel::simulate_batch(
        &platform,
        &config,
        &ib,
        &[ds.f0, 256, ds.f2],
        SimOptions { sage_concat: model == GnnModel::Sage, ..Default::default() },
    );
    println!(
        "batch: {} vertices, layers {:?}",
        ib.vertices_traversed(),
        mb.layers.iter().map(|l| l.len()).collect::<Vec<_>>()
    );
    for (l, t) in timing.fp_layers.iter().enumerate() {
        println!(
            "  layer {}: load {:.3} ms, compute {:.3} ms, update {:.3} ms",
            l + 1,
            t.t_load * 1e3,
            t.t_compute * 1e3,
            t.t_update * 1e3
        );
    }
    println!(
        "t_FP {:.3} ms, t_BP {:.3} ms, t_GNN {:.3} ms -> {} NVTPS",
        timing.t_fp * 1e3,
        timing.t_bp * 1e3,
        timing.t_gnn * 1e3,
        si(timing.nvtps(ib.vertices_traversed(), 0.0)),
    );
    Ok(())
}

fn cmd_info(argv: Vec<String>) -> anyhow::Result<()> {
    let args = artifacts_flag(Args::new("hp-gnn info", "artifacts + platform info"))
        .parse_from(argv)?;
    println!("boards:");
    for name in hp_gnn::accel::platform::board_names() {
        let p = hp_gnn::accel::platform::by_board(name).expect("registered board");
        println!(
            "  {name}: {} dies, {} DSP/die, {} LUT/die, {:.2} GB/s/channel, {} MHz",
            p.dies,
            p.dsp_per_die,
            p.lut_per_die,
            p.bw_per_channel_gbps,
            p.freq_hz / 1e6
        );
    }
    match hp_gnn::runtime::Runtime::auto(std::path::Path::new(args.get("artifacts"))) {
        Ok(rt) => {
            println!("backend: {}", rt.backend_name());
            println!("artifacts:");
            for name in rt.manifest.names() {
                let spec = rt.manifest.get(name)?;
                println!(
                    "  {name}: geometry b={:?} e={:?} f={:?}",
                    spec.geometry.b, spec.geometry.e, spec.geometry.f
                );
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    println!("datasets (Table 4):");
    for ds in datasets::ALL {
        println!(
            "  {} ({}): |V|={} |E|={} f=[{}, 256, {}]",
            ds.key, ds.name, ds.nodes, ds.edges, ds.f0, ds.f2
        );
    }
    Ok(())
}
