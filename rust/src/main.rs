//! `hp-gnn` — the leader binary.
//!
//! Subcommands:
//!
//! * `run <program.json>` — execute a user program (paper Listing 1) as a
//!   training session (`--resume` continues from a session snapshot).
//! * `train` — train a model on a synthetic Table 4 dataset.
//! * `serve` — serve vertex-classification requests from a checkpoint.
//! * `dse` — run the design space exploration engine (Table 5 rows).
//! * `simulate` — simulate one mini-batch on the accelerator model.
//! * `info` — list artifacts and platform description.
//! * `help` — this overview.
//!
//! Run `hp-gnn <subcommand> --help` for flags.

use std::path::{Path, PathBuf};

use hp_gnn::accel::{AccelConfig, Platform, SimOptions};
use hp_gnn::api::{program, HpGnn, SamplerSpec};
use hp_gnn::coordinator::{trainer::Optimizer, TrainingSession};
use hp_gnn::dse::{explore, DseProblem};
use hp_gnn::graph::datasets;
use hp_gnn::layout::{index_batch, LayoutOptions};
use hp_gnn::perf::{ModelShape, ResourceCoefficients};
use hp_gnn::runtime::Runtime;
use hp_gnn::sampler::values::{attach_values, GnnModel};
use hp_gnn::sampler::Sampler;
use hp_gnn::util::cli::Args;
use hp_gnn::util::rng::Pcg64;
use hp_gnn::util::si;

const USAGE: &str = "hp-gnn — HP-GNN training framework (FPGA '22 reproduction)\n\n\
     SUBCOMMANDS:\n  run <program.json>   execute a user program as a training session\n  \
     train                train on a synthetic dataset\n  \
     serve                serve vertex-classification requests from a checkpoint\n  \
     dse                  design space exploration (Table 5)\n  \
     simulate             accelerator simulation of one batch\n  \
     info                 artifacts + platform info\n  \
     help                 print this overview\n\n\
     Run `hp-gnn <subcommand> --help` for flags.";

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let sub = if argv.is_empty() { String::new() } else { argv.remove(0) };
    let result = match sub.as_str() {
        "run" => cmd_run(argv),
        "train" => cmd_train(argv),
        "serve" => cmd_serve(argv),
        "dse" => cmd_dse(argv),
        "simulate" => cmd_simulate(argv),
        "info" => cmd_info(argv),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            return;
        }
        other => {
            // A missing or unknown subcommand is a usage error: usage goes
            // to stderr and the exit code is nonzero so scripts notice.
            if other.is_empty() {
                eprintln!("error: no subcommand given\n\n{USAGE}");
            } else {
                eprintln!("error: unknown subcommand {other:?}\n\n{USAGE}");
            }
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_flag(args: Args) -> Args {
    args.flag("artifacts", "artifacts", "artifact directory (make artifacts)")
}

/// Session-control flags shared by `run` and `train`.  The cadence flags
/// default to "unset" (empty) so `run` can distinguish "not given" from an
/// explicit `0` that disables a program-configured cadence.
fn session_flags(args: Args) -> Args {
    args.flag("resume", "", "resume from an HPGNNS01 session snapshot")
        .flag("eval-every", "", "evaluate on held-out batches every N steps (0 = off)")
        .flag("checkpoint", "", "session snapshot path (written per --checkpoint-every + at end)")
        .flag("checkpoint-every", "", "snapshot every N steps (0 = final snapshot only)")
}

/// An optional usize flag: empty string (the default) means "not given".
fn opt_usize_flag(args: &Args, name: &str) -> anyhow::Result<Option<usize>> {
    match args.get(name) {
        "" => Ok(None),
        s => Ok(Some(
            s.parse::<usize>()
                .map_err(|e| anyhow::anyhow!("--{name} wants an unsigned integer: {e}"))?,
        )),
    }
}

/// Drive `session` until `total_steps` global steps have executed,
/// evaluating every `eval_every` steps and snapshotting every
/// `checkpoint_every` steps (plus a final snapshot) when configured.
fn run_session(
    session: &mut TrainingSession<'_>,
    total_steps: usize,
    eval_every: usize,
    eval_batches: usize,
    checkpoint: Option<&Path>,
    checkpoint_every: usize,
) -> anyhow::Result<()> {
    let mut last_saved = None;
    while session.current_step() < total_steps {
        session.step()?;
        let done = session.current_step();
        if eval_every > 0 && done % eval_every == 0 {
            session.evaluate(eval_batches)?;
        }
        if let Some(path) = checkpoint {
            if checkpoint_every > 0 && done % checkpoint_every == 0 {
                session.save(path)?;
                last_saved = Some(done);
            }
        }
    }
    if let Some(path) = checkpoint {
        // Final snapshot, unless the periodic cadence just wrote it.
        if last_saved != Some(session.current_step()) {
            session.save(path)?;
        }
        println!(
            "checkpoint: wrote session snapshot to {path:?} at step {}",
            session.current_step()
        );
    }
    Ok(())
}

/// Progress hooks shared by `run` and `train`: decimated step lines plus
/// one line per evaluation.
fn install_progress_hooks(session: &mut TrainingSession<'_>, total_steps: usize) {
    let stride = (total_steps / 10).max(1);
    session.on_step(move |r| {
        if (r.step + 1) % stride == 0 {
            println!("step {:>5}: loss {:.4}", r.step, r.loss);
        }
    });
    session.on_eval(|ev| {
        println!(
            "eval @ step {}: {:.1}% accuracy ({}/{} targets)",
            ev.step,
            ev.report.accuracy() * 100.0,
            ev.report.correct,
            ev.report.total
        );
    });
}

fn cmd_run(argv: Vec<String>) -> anyhow::Result<()> {
    let args = session_flags(artifacts_flag(Args::new(
        "hp-gnn run",
        "execute a user program as a training session",
    )))
    .flag("eval-batches", "", "override training.eval_batches")
    .parse_from(argv)?;
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: hp-gnn run <program.json>"))?;
    let text = std::fs::read_to_string(path)?;
    let (builder, mut params) = program::parse_program(&text)?;
    // Given CLI flags override the program's training section (an
    // explicit 0 disables a program-configured cadence).
    if let Some(v) = opt_usize_flag(&args, "eval-every")? {
        params.eval_every = v;
    }
    if let Some(v) = opt_usize_flag(&args, "eval-batches")? {
        params.eval_batches = v;
    }
    if !args.get("checkpoint").is_empty() {
        params.checkpoint = Some(PathBuf::from(args.get("checkpoint")));
    }
    if let Some(v) = opt_usize_flag(&args, "checkpoint-every")? {
        params.checkpoint_every = v;
    }

    let runtime = Runtime::auto(Path::new(args.get("artifacts")))?;
    let design = builder.generate_design(&runtime)?;
    println!("generated design:\n{}", design.to_json().pretty());

    let mut session = if args.get("resume").is_empty() {
        design.session(&runtime, params.lr, params.simulate)?
    } else {
        let s = design.resume_session(
            &runtime,
            params.lr,
            params.simulate,
            Path::new(args.get("resume")),
        )?;
        println!("resumed at step {}", s.current_step());
        s
    };
    session.set_step_limit(params.steps);
    install_progress_hooks(&mut session, params.steps);
    run_session(
        &mut session,
        params.steps,
        params.eval_every,
        params.eval_batches,
        params.checkpoint.as_deref(),
        params.checkpoint_every,
    )?;
    let threads = session.config().sampler_threads;
    let report = session.finish();
    println!("training report:\n{}", report.metrics.to_json(threads).pretty());
    Ok(())
}

fn cmd_train(argv: Vec<String>) -> anyhow::Result<()> {
    let args = session_flags(artifacts_flag(
        Args::new("hp-gnn train", "train a GNN on a synthetic Table 4 dataset")
            .flag("model", "gcn", "gcn | sage")
            .flag("dataset", "FL", "FL | RD | YP | AP")
            .flag("scale", "0.01", "dataset scale factor (0, 1]")
            .flag("sampler", "ns", "ns | ss")
            .flag("targets", "32", "NS target vertices per batch")
            .flag("budgets", "5,10", "NS fan-outs per layer (comma separated)")
            .flag("budget", "256", "SS subgraph budget")
            .flag("steps", "50", "training iterations (total, including a resumed prefix)")
            .flag("lr", "0.05", "learning rate")
            .flag("seed", "7", "PRNG seed")
            .flag("threads", "2", "sampler threads")
            .flag(
                "compute-threads",
                "",
                "kernel worker threads for the executor (default: all cores)",
            )
            .flag("optimizer", "sgd", "sgd | adam")
            .flag("save", "", "Save_model(): final weights path (empty = no save)")
            .flag("eval-batches", "", "held-out eval batches (also run once after training)")
            .switch("simulate", "attach accelerator-simulator timing")
            .switch("no-rmt", "disable the RMT layout optimization")
            .switch("no-rra", "disable the RRA layout optimization"),
    ))
    .parse_from(argv)?;

    let runtime = Runtime::auto(Path::new(args.get("artifacts")))?;
    let sampler = match args.get("sampler") {
        "ns" => SamplerSpec::Neighbor {
            targets: args.usize("targets"),
            budgets: args
                .get("budgets")
                .split(',')
                .map(|b| b.trim().parse())
                .collect::<Result<Vec<usize>, _>>()?,
        },
        "ss" => SamplerSpec::Subgraph { budget: args.usize("budget"), layers: 2 },
        other => anyhow::bail!("unknown sampler {other:?} (ns|ss)"),
    };
    let layout = LayoutOptions { rmt: !args.on("no-rmt"), rra: !args.on("no-rra") };
    let design = HpGnn::init()
        .platform_board("xilinx-U250")?
        .gnn_computation(args.get("model"))?
        .gnn_parameters(vec![256])
        .sampler(sampler)
        .layout(layout)
        .seed(args.usize("seed") as u64)
        .load_dataset(args.get("dataset"), args.f64("scale"), args.usize("seed") as u64)?
        .generate_design(&runtime)?;
    println!("generated design:\n{}", design.to_json().pretty());

    let steps = args.usize("steps");
    let mut cfg = design.train_config(steps, args.f32("lr"), args.on("simulate"));
    cfg.sampler_threads = args.usize("threads");
    if let Some(v) = opt_usize_flag(&args, "compute-threads")? {
        cfg.compute_threads = v.max(1);
    }
    cfg.optimizer = match args.get("optimizer") {
        "sgd" => Optimizer::Sgd,
        "adam" => Optimizer::Adam,
        other => anyhow::bail!("unknown optimizer {other:?} (sgd|adam)"),
    };
    let graph = std::sync::Arc::clone(&design.graph);
    let boxed: std::sync::Arc<dyn Sampler> =
        std::sync::Arc::from(design.abstraction.sampler.build());
    let mut session = if args.get("resume").is_empty() {
        TrainingSession::new(&runtime, graph, boxed, cfg)?
    } else {
        let s = TrainingSession::resume(
            &runtime,
            graph,
            boxed,
            cfg,
            Path::new(args.get("resume")),
        )?;
        println!("resumed at step {}", s.current_step());
        s
    };
    session.set_step_limit(steps);
    install_progress_hooks(&mut session, steps);
    let checkpoint = (!args.get("checkpoint").is_empty())
        .then(|| PathBuf::from(args.get("checkpoint")));
    let eval_batches = opt_usize_flag(&args, "eval-batches")?.unwrap_or(0);
    let eval_every = opt_usize_flag(&args, "eval-every")?.unwrap_or(0);
    let start_step = session.current_step();
    run_session(
        &mut session,
        steps,
        eval_every,
        if eval_batches > 0 { eval_batches } else { 2 },
        checkpoint.as_deref(),
        opt_usize_flag(&args, "checkpoint-every")?.unwrap_or(0),
    )?;
    // Final held-out eval, unless the periodic cadence just ran one at
    // the last step (the eval stream is fixed, so it would be identical).
    // A resume that was already past `steps` ran no periodic evals.
    let periodic_ran_final = eval_every > 0 && steps % eval_every == 0 && start_step < steps;
    if eval_batches > 0 && !periodic_ran_final {
        session.evaluate(eval_batches)?;
    }

    let threads = session.config().sampler_threads;
    let report = session.finish();
    let m = &report.metrics;
    println!("training report:\n{}", m.to_json(threads).pretty());
    if let Some((head, tail)) = m.loss_drop() {
        println!("loss: {head:.4} -> {tail:.4}");
    }
    if !args.get("save").is_empty() {
        let path = PathBuf::from(args.get("save"));
        report.final_weights.save(&path)?;
        println!("Save_model(): wrote weights to {path:?}");
    }
    Ok(())
}

fn cmd_serve(argv: Vec<String>) -> anyhow::Result<()> {
    let args = artifacts_flag(
        Args::new(
            "hp-gnn serve",
            "serve vertex-classification requests from a trained checkpoint",
        )
        .flag("checkpoint", "", "HPGNNW01 weights or HPGNNS01 session snapshot (required)")
        .flag("model", "gcn", "gcn | sage (must match training)")
        .flag("dataset", "FL", "FL | RD | YP | AP (must match training)")
        .flag("scale", "0.01", "dataset scale factor (0, 1] (must match training)")
        .flag("targets", "32", "NS target vertices (sizes the artifact geometry)")
        .flag("budgets", "5,10", "NS fan-outs per layer (comma separated)")
        .flag("seed", "7", "PRNG seed (must match training for feature synthesis)")
        .flag("workers", "2", "forward-executor replicas in the worker pool")
        .flag("max-batch", "0", "micro-batch coalescing cap (0 = geometry target capacity)")
        .flag("max-wait-us", "200", "micro-batch deadline in microseconds")
        .flag("requests", "64", "self-driven demo requests when --vertices is empty")
        .flag("vertices", "", "comma-separated vertex ids to classify (one line each)")
        .switch("cache", "enable the versioned logits cache for repeat vertices"),
    )
    .parse_from(argv)?;
    anyhow::ensure!(
        !args.get("checkpoint").is_empty(),
        "usage: hp-gnn serve --checkpoint <file> (weights from `hp-gnn train --save` \
         or a session snapshot from `--checkpoint`)"
    );

    let runtime = Runtime::auto(Path::new(args.get("artifacts")))?;
    // Rebuild the training-time design (same dataset, sampler and
    // geometry selection) so the served model sees the inputs it learned.
    let seed = args.usize("seed") as u64;
    let design = HpGnn::init()
        .platform_board("xilinx-U250")?
        .gnn_computation(args.get("model"))?
        .gnn_parameters(vec![256])
        .sampler(SamplerSpec::Neighbor {
            targets: args.usize("targets"),
            budgets: args
                .get("budgets")
                .split(',')
                .map(|b| b.trim().parse())
                .collect::<Result<Vec<usize>, _>>()?,
        })
        .seed(seed)
        .load_dataset(args.get("dataset"), args.f64("scale"), seed)?
        .generate_design(&runtime)?;

    let mut cfg = design.serve_config();
    cfg.workers = args.usize("workers").max(1);
    cfg.max_batch = args.usize("max-batch");
    cfg.max_wait = std::time::Duration::from_micros(args.usize("max-wait-us") as u64);
    cfg.cache = args.on("cache");
    let server = design.server(&runtime, cfg, Path::new(args.get("checkpoint")))?;
    println!(
        "serving {} on geometry {} ({} workers, max batch {}, cache {})",
        args.get("model"),
        server.geometry().name,
        server.num_workers(),
        server.max_batch(),
        if args.on("cache") { "on" } else { "off" },
    );

    if !args.get("vertices").is_empty() {
        let vertices: Vec<u32> = args
            .get("vertices")
            .split(',')
            .map(|v| v.trim().parse())
            .collect::<Result<_, _>>()?;
        for pred in server.classify(&vertices)?.iter() {
            match pred.label {
                Some(label) => println!(
                    "vertex {:>8}: class {label} (logits {:?})",
                    pred.vertex, pred.logits
                ),
                None => println!("vertex {:>8}: no prediction (NaN logits)", pred.vertex),
            }
        }
    } else {
        // Self-driven demo load: closed-loop single-vertex requests over
        // a random vertex stream (repeat vertices exercise the cache).
        let n = args.usize("requests");
        let num_vertices = design.graph.num_vertices();
        let mut rng = Pcg64::seed_from_u64(seed ^ 0x10ad);
        let pool: Vec<u32> = (0..(num_vertices / 4).clamp(1, 512))
            .map(|_| rng.index(num_vertices) as u32)
            .collect();
        let t = hp_gnn::util::stats::Timer::start();
        for _ in 0..n {
            let v = pool[rng.index(pool.len())];
            server.classify_one(v)?;
        }
        let secs = t.secs();
        println!(
            "served {n} requests in {:.3}s ({:.0} req/s)",
            secs,
            n as f64 / secs.max(1e-12)
        );
    }
    println!("serving metrics:\n{}", server.metrics().to_json().pretty());
    server.shutdown();
    Ok(())
}

fn cmd_dse(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::new("hp-gnn dse", "design space exploration (paper Table 5)")
        .flag("model", "gcn", "gcn | sage")
        .flag("dataset", "FL", "FL | RD | YP | AP")
        .flag("sampler", "ns", "ns | ss")
        .parse_from(argv)?;
    let ds = datasets::by_key(args.get("dataset"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let model = GnnModel::parse(args.get("model"))?;
    let geom = match args.get("sampler") {
        "ns" => hp_gnn::perf::BatchGeometry::neighbor_capped(1024, &[10, 25], ds.nodes),
        "ss" => {
            let kappa = hp_gnn::perf::KappaEstimator::from_stats(ds.nodes, ds.edges);
            hp_gnn::perf::BatchGeometry::subgraph(2750, 2, &kappa)
        }
        other => anyhow::bail!("unknown sampler {other:?}"),
    };
    let platform = Platform::alveo_u250();
    let r = explore(
        &platform,
        &DseProblem {
            geom: geom.clone(),
            model: ModelShape {
                feat: vec![ds.f0, 256, ds.f2],
                sage_concat: model == GnnModel::Sage,
            },
            layout: LayoutOptions::all(),
            coeff: ResourceCoefficients::default(),
            t_sampling_single: None,
        },
    );
    println!(
        "{}-{} on {}: (m, n) = ({}, {}), predicted {} NVTPS, \
         DSP {:.0}% LUT {:.0}% URAM {:.0}% BRAM {:.0}% ({} candidates)",
        args.get("sampler").to_uppercase(),
        model.as_str().to_uppercase(),
        ds.key,
        r.config.m,
        r.config.n,
        si(r.nvtps),
        r.utilization.dsp * 100.0,
        r.utilization.lut * 100.0,
        r.utilization.uram * 100.0,
        r.utilization.bram * 100.0,
        r.evaluated,
    );
    Ok(())
}

fn cmd_simulate(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::new("hp-gnn simulate", "simulate one mini-batch on the accelerator")
        .flag("model", "gcn", "gcn | sage")
        .flag("dataset", "FL", "FL | RD | YP | AP")
        .flag("scale", "0.05", "dataset scale factor")
        .flag("targets", "1024", "NS targets")
        .flag("budgets", "10,25", "NS budgets")
        .flag("n", "4", "scatter/gather PE pairs per die")
        .flag("m", "256", "MACs per die")
        .flag("seed", "7", "seed")
        .switch("no-rmt", "disable RMT")
        .switch("no-rra", "disable RRA")
        .parse_from(argv)?;
    let ds = datasets::by_key(args.get("dataset"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let g = ds.scale(args.f64("scale")).instantiate(args.usize("seed") as u64);
    let model = GnnModel::parse(args.get("model"))?;
    let budgets: Vec<usize> = args
        .get("budgets")
        .split(',')
        .map(|b| b.trim().parse())
        .collect::<Result<_, _>>()?;
    let sampler =
        hp_gnn::sampler::neighbor::NeighborSampler::new(args.usize("targets"), budgets);
    let mb = sampler.sample(&g, &mut Pcg64::seed_from_u64(args.usize("seed") as u64));
    let vals = attach_values(&g, &mb, model);
    let layout = LayoutOptions { rmt: !args.on("no-rmt"), rra: !args.on("no-rra") };
    let ib = index_batch(&mb, &vals, layout);
    let platform = Platform::alveo_u250();
    let config = AccelConfig { n: args.usize("n"), m: args.usize("m") };
    let timing = hp_gnn::accel::simulate_batch(
        &platform,
        &config,
        &ib,
        &[ds.f0, 256, ds.f2],
        SimOptions { sage_concat: model == GnnModel::Sage, ..Default::default() },
    );
    println!(
        "batch: {} vertices, layers {:?}",
        ib.vertices_traversed(),
        mb.layers.iter().map(|l| l.len()).collect::<Vec<_>>()
    );
    for (l, t) in timing.fp_layers.iter().enumerate() {
        println!(
            "  layer {}: load {:.3} ms, compute {:.3} ms, update {:.3} ms",
            l + 1,
            t.t_load * 1e3,
            t.t_compute * 1e3,
            t.t_update * 1e3
        );
    }
    println!(
        "t_FP {:.3} ms, t_BP {:.3} ms, t_GNN {:.3} ms -> {} NVTPS",
        timing.t_fp * 1e3,
        timing.t_bp * 1e3,
        timing.t_gnn * 1e3,
        si(timing.nvtps(ib.vertices_traversed(), 0.0)),
    );
    Ok(())
}

fn cmd_info(argv: Vec<String>) -> anyhow::Result<()> {
    let args = artifacts_flag(Args::new("hp-gnn info", "artifacts + platform info"))
        .parse_from(argv)?;
    let platform = Platform::alveo_u250();
    println!(
        "platform: {} — {} dies, {} DSP/die, {} LUT/die, {:.2} GB/s/channel, {} MHz",
        platform.name,
        platform.dies,
        platform.dsp_per_die,
        platform.lut_per_die,
        platform.bw_per_channel_gbps,
        platform.freq_hz / 1e6
    );
    match Runtime::auto(std::path::Path::new(args.get("artifacts"))) {
        Ok(rt) => {
            println!("backend: {}", rt.backend_name());
            println!("artifacts:");
            for name in rt.manifest.names() {
                let spec = rt.manifest.get(name)?;
                println!(
                    "  {name}: geometry b={:?} e={:?} f={:?}",
                    spec.geometry.b, spec.geometry.e, spec.geometry.f
                );
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    println!("datasets (Table 4):");
    for ds in datasets::ALL {
        println!(
            "  {} ({}): |V|={} |E|={} f=[{}, 256, {}]",
            ds.key, ds.name, ds.nodes, ds.edges, ds.f0, ds.f2
        );
    }
    Ok(())
}
