//! PJRT execution backend (`--features xla`): load HLO-text artifacts,
//! compile once, run per batch.
//!
//! HLO *text* (not serialized protos — jax ≥ 0.5 emits 64-bit instruction
//! ids that xla_extension 0.5.1 rejects) is parsed into an
//! `HloModuleProto`, compiled on the CPU PJRT client, and executed with
//! `Literal` inputs.  Python never runs on this path.
//!
//! The in-repo `xla-stub` crate satisfies this module's API so the
//! feature always type-checks; link a real `xla` crate (xla_extension
//! bindings) to execute.  All ABI validation happens upstream in
//! [`super::Executable::run`], so this module only converts between
//! [`Tensor`] and `xla::Literal`.

use super::backend::{Backend, Executor};
use super::manifest::{ArtifactSpec, DType, Manifest, TensorSpec};
use super::tensor::Tensor;

/// PJRT CPU client, shared by every executable it compiles.
pub struct XlaBackend {
    client: xla::PjRtClient,
}

impl XlaBackend {
    pub fn new() -> anyhow::Result<XlaBackend> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        log::info!(
            "PJRT up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(XlaBackend { client })
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn compile(
        &self,
        manifest: &Manifest,
        spec: &ArtifactSpec,
    ) -> anyhow::Result<Box<dyn Executor>> {
        let path = manifest.hlo_path(spec);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", spec.name))?;
        Ok(Box::new(XlaExecutor { exe, spec: spec.clone() }))
    }
}

struct XlaExecutor {
    exe: xla::PjRtLoadedExecutable,
    spec: ArtifactSpec,
}

impl Executor for XlaExecutor {
    fn run(&self, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        // Tensor -> Literal costs one extra input copy per batch compared
        // to the pre-trait path that built Literals directly; acceptable
        // until the PJRT backend is exercised at ns_medium scale, where a
        // borrowed-payload Tensor would pay off.
        let literals = inputs
            .iter()
            .zip(&self.spec.inputs)
            .map(|(t, s)| to_literal(t, s))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing {}: {e:?}", self.spec.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of {}: {e:?}", self.spec.name))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("decomposing result of {}: {e:?}", self.spec.name))?;
        // Every output in this ABI is f32 (loss, logits, weights, adam
        // state); consumers only rely on flat element counts, so outputs
        // are returned rank-1.
        parts
            .into_iter()
            .map(|lit| {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("output readback: {e:?}"))?;
                Tensor::f32(vec![data.len()], data)
            })
            .collect()
    }
}

/// Build the spec-shaped `Literal` for one ABI slot.
fn to_literal(t: &Tensor, spec: &TensorSpec) -> anyhow::Result<xla::Literal> {
    let flat = match (t, spec.dtype) {
        (Tensor::F32 { data, .. }, DType::F32) => xla::Literal::vec1(data),
        (Tensor::I32 { data, .. }, DType::I32) => xla::Literal::vec1(data),
        _ => anyhow::bail!("{}: tensor/spec dtype mismatch", spec.name),
    };
    if spec.shape.is_empty() {
        // Rank-0 ABI slots (lr, step) are passed as true scalars.
        let v = t.f32_data()?;
        return Ok(xla::Literal::scalar(v[0]));
    }
    if spec.shape.len() <= 1 {
        return Ok(flat);
    }
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    flat.reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshaping {}: {e:?}", spec.name))
}
