//! Runtime: pluggable execution of the training artifacts.
//!
//! One [`Runtime`] per process: a [`Manifest`] (artifact registry + ABI)
//! plus a [`Backend`].  The default backend is the pure-Rust
//! [`reference`] executor — a CPU implementation of the train-step /
//! adam-step / forward semantics that needs no compiled artifacts, so the
//! whole crate trains end to end on a clean machine.  Building with
//! `--features xla` swaps in the PJRT path (`xla` module): HLO-text
//! artifacts produced by `make artifacts`, compiled once per (model ×
//! geometry × kind) and driven every iteration with inputs assembled by
//! [`inputs::build_inputs`].

pub mod backend;
pub mod executor;
pub mod inputs;
pub mod kernels;
pub mod manifest;
pub mod reference;
pub mod tensor;
pub mod weights;
#[cfg(feature = "xla")]
pub mod xla;

pub use backend::{Backend, ExecOptions, Executor};
pub use executor::{Executable, Runtime};
pub use manifest::{ArtifactSpec, Kind, Manifest};
pub use reference::ReferenceBackend;
pub use tensor::Tensor;
pub use weights::{load_weights_any, Checkpoint, WeightState};
