//! Runtime: PJRT-backed execution of the AOT artifacts.
//!
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`, exactly the /opt/xla-example/load_hlo
//! wiring.  One compiled executable per (model × geometry × kind); the
//! coordinator drives it every iteration with inputs assembled by
//! [`inputs::build_inputs`].

pub mod executor;
pub mod inputs;
pub mod manifest;
pub mod weights;

pub use executor::{Executable, Runtime};
pub use manifest::{ArtifactSpec, Kind, Manifest};
pub use weights::WeightState;
