//! Assembling the positional input list for an artifact from a padded
//! mini-batch, features, weights and the learning rate.
//!
//! The ABI order is defined by `python/compile/model.example_args` and
//! recorded in the manifest:
//!
//! ```text
//! x0, labels, mask, [src_l dst_l val_l]*, [self_idx_l]* (SAGE),
//! [w_l b_l]*, lr (train only), [m_* v_* step] (adam only)
//! ```

use super::manifest::{ArtifactSpec, DType, Kind, TensorSpec};
use super::tensor::Tensor;
use super::weights::{AdamState, WeightState};
use crate::layout::pad::PaddedBatch;
use crate::sampler::values::GnnModel;

/// Build the full positional input list.  `features` is the padded
/// `b[0] × f[0]` row-major input feature matrix.
pub fn build_inputs(
    spec: &ArtifactSpec,
    batch: &PaddedBatch,
    features: &[f32],
    weights: &WeightState,
    lr: f32,
) -> anyhow::Result<Vec<Tensor>> {
    build_inputs_opt(spec, batch, features, weights, lr, None)
}

/// `build_inputs` plus the trailing Adam state for `adam_step` artifacts.
pub fn build_inputs_opt(
    spec: &ArtifactSpec,
    batch: &PaddedBatch,
    features: &[f32],
    weights: &WeightState,
    lr: f32,
    adam: Option<&AdamState>,
) -> anyhow::Result<Vec<Tensor>> {
    let geom = &spec.geometry;
    anyhow::ensure!(
        batch.geom == *geom,
        "batch geometry {:?} != artifact geometry {:?}",
        batch.geom.name,
        geom.name
    );
    anyhow::ensure!(
        features.len() == geom.b[0] * geom.f[0],
        "features: {} elements, want {}x{}",
        features.len(),
        geom.b[0],
        geom.f[0]
    );
    let ll = geom.layers();
    anyhow::ensure!(
        weights.tensors.len() == 2 * ll,
        "weights: {} tensors for {ll} layers",
        weights.tensors.len()
    );

    let mut out = Vec::with_capacity(spec.inputs.len());
    let mut it = spec.inputs.iter();
    let mut next = |name: &str| {
        it.next()
            .filter(|s| s.name == name)
            .ok_or_else(|| anyhow::anyhow!("ABI mismatch at {name}"))
    };

    out.push(tensor_f32(next("x0")?, features)?);
    out.push(tensor_i32(next("labels")?, &batch.labels)?);
    out.push(tensor_f32(next("mask")?, &batch.mask)?);
    for l in 1..=ll {
        out.push(tensor_i32(next(&format!("src{l}"))?, &batch.src[l - 1])?);
        out.push(tensor_i32(next(&format!("dst{l}"))?, &batch.dst[l - 1])?);
        out.push(tensor_f32(next(&format!("val{l}"))?, &batch.val[l - 1])?);
    }
    if spec.model == GnnModel::Sage {
        for l in 1..=ll {
            out.push(tensor_i32(next(&format!("self_idx{l}"))?, &batch.self_idx[l - 1])?);
        }
    }
    for l in 1..=ll {
        let (wshape, wdata) = &weights.tensors[2 * (l - 1)];
        let wspec = next(&format!("w{l}"))?;
        anyhow::ensure!(wspec.shape == *wshape, "w{l} shape mismatch");
        out.push(tensor_f32(wspec, wdata)?);
        let (_bshape, bdata) = &weights.tensors[2 * (l - 1) + 1];
        out.push(tensor_f32(next(&format!("b{l}"))?, bdata)?);
    }
    if matches!(spec.kind, Kind::TrainStep | Kind::AdamStep) {
        let _ = next("lr")?;
        out.push(Tensor::scalar_f32(lr));
    }
    if spec.kind == Kind::AdamStep {
        let st = adam.ok_or_else(|| anyhow::anyhow!("adam_step needs AdamState"))?;
        for l in 1..=ll {
            out.push(tensor_f32(next(&format!("m_w{l}"))?, &st.m[2 * (l - 1)].1)?);
            out.push(tensor_f32(next(&format!("m_b{l}"))?, &st.m[2 * (l - 1) + 1].1)?);
        }
        for l in 1..=ll {
            out.push(tensor_f32(next(&format!("v_w{l}"))?, &st.v[2 * (l - 1)].1)?);
            out.push(tensor_f32(next(&format!("v_b{l}"))?, &st.v[2 * (l - 1) + 1].1)?);
        }
        let _ = next("step")?;
        out.push(Tensor::scalar_f32(st.step));
    }
    anyhow::ensure!(it.next().is_none(), "unconsumed ABI inputs");
    Ok(out)
}

/// Build the spec-shaped f32 [`Tensor`] for one ABI slot from raw data.
pub fn tensor_f32(spec: &TensorSpec, data: &[f32]) -> anyhow::Result<Tensor> {
    anyhow::ensure!(spec.dtype == DType::F32, "{} is not f32", spec.name);
    anyhow::ensure!(
        data.len() == spec.elements(),
        "{}: {} elements for shape {:?}",
        spec.name,
        data.len(),
        spec.shape
    );
    Tensor::f32(spec.shape.clone(), data.to_vec())
}

pub fn tensor_i32(spec: &TensorSpec, data: &[i32]) -> anyhow::Result<Tensor> {
    anyhow::ensure!(spec.dtype == DType::I32, "{} is not i32", spec.name);
    anyhow::ensure!(
        data.len() == spec.elements(),
        "{}: {} elements for shape {:?}",
        spec.name,
        data.len(),
        spec.shape
    );
    Tensor::i32(spec.shape.clone(), data.to_vec())
}

/// Pad a real feature matrix (per-vertex rows for `real_rows`) up to the
/// geometry's `b[0]` rows with zeros.
pub fn pad_features(real: &[f32], real_rows: usize, geom_rows: usize, feat: usize) -> Vec<f32> {
    assert_eq!(real.len(), real_rows * feat, "feature matrix shape");
    assert!(real_rows <= geom_rows, "more rows than geometry allows");
    let mut out = Vec::with_capacity(geom_rows * feat);
    out.extend_from_slice(real);
    out.resize(geom_rows * feat, 0.0);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_features_zero_fills() {
        let real = vec![1.0f32; 2 * 3];
        let padded = pad_features(&real, 2, 5, 3);
        assert_eq!(padded.len(), 15);
        assert_eq!(&padded[..6], &real[..]);
        assert!(padded[6..].iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic(expected = "feature matrix shape")]
    fn pad_features_validates_shape() {
        pad_features(&[1.0; 5], 2, 4, 3);
    }

    #[test]
    fn tensor_builders_enforce_spec() {
        let spec = TensorSpec { name: "x".into(), shape: vec![2, 2], dtype: DType::F32 };
        assert!(tensor_f32(&spec, &[0.0; 4]).is_ok());
        assert!(tensor_f32(&spec, &[0.0; 3]).is_err());
        assert!(tensor_i32(&spec, &[0; 4]).is_err(), "dtype mismatch");
    }
}
