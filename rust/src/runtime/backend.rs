//! The pluggable execution backend contract.
//!
//! A [`Backend`] turns one manifest [`ArtifactSpec`] into an [`Executor`]
//! that runs the artifact's semantics on positional [`Tensor`] inputs.
//! Two implementations exist:
//!
//! * [`super::reference::ReferenceBackend`] — the default: a pure-Rust CPU
//!   implementation of the train-step / adam-step / forward semantics
//!   (mirror of `python/compile/kernels/ref.py` + `python/compile/model.py`),
//!   requiring no compiled artifacts and no external libraries.
//! * `XlaBackend` (`--features xla`) — the PJRT path: loads the AOT HLO
//!   text artifact named by the spec and executes it on the XLA CPU client.
//!
//! The coordinator, API layer, examples and benches only see
//! [`super::Runtime`] / [`super::Executable`], so they run unchanged on
//! either backend.

use super::manifest::{ArtifactSpec, Manifest};
use super::tensor::Tensor;

/// An execution engine that can instantiate manifest artifacts.
pub trait Backend {
    /// Human-readable backend name ("reference", "xla").
    fn name(&self) -> &'static str;

    /// Instantiate one artifact.  `manifest` provides artifact file paths
    /// for backends that load compiled objects; the reference backend
    /// executes straight from the spec.
    fn compile(&self, manifest: &Manifest, spec: &ArtifactSpec) -> anyhow::Result<Box<dyn Executor>>;
}

/// A compiled (or interpreted) artifact ready to run.
///
/// Implementations receive inputs already validated against the manifest
/// ABI by [`super::Executable::run`] — count, per-input element count and
/// dtype all match the spec.
pub trait Executor {
    fn run(&self, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>>;
}
