//! The pluggable execution backend contract.
//!
//! A [`Backend`] turns one manifest [`ArtifactSpec`] into an [`Executor`]
//! that runs the artifact's semantics on positional [`Tensor`] inputs.
//! Two implementations exist:
//!
//! * [`super::reference::ReferenceBackend`] — the default: a pure-Rust CPU
//!   implementation of the train-step / adam-step / forward semantics
//!   (mirror of `python/compile/kernels/ref.py` + `python/compile/model.py`),
//!   requiring no compiled artifacts and no external libraries.
//! * `XlaBackend` (`--features xla`) — the PJRT path: loads the AOT HLO
//!   text artifact named by the spec and executes it on the XLA CPU client.
//!
//! The coordinator, API layer, examples and benches only see
//! [`super::Runtime`] / [`super::Executable`], so they run unchanged on
//! either backend.

use super::manifest::{ArtifactSpec, Manifest};
use super::tensor::Tensor;

/// Per-compilation execution options a caller may request from a
/// [`Backend`].  Backends honor what applies to them and ignore the rest
/// (the PJRT path has no host kernel layer, so it ignores
/// `compute_threads`).
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecOptions {
    /// Worker threads for host-side compute kernels (the reference
    /// executor's [`super::kernels`] layer).  `None` keeps the backend's
    /// own default; results are bit-identical at every setting.
    pub compute_threads: Option<usize>,
}

/// An execution engine that can instantiate manifest artifacts.
pub trait Backend {
    /// Human-readable backend name ("reference", "xla").
    fn name(&self) -> &'static str;

    /// Instantiate one artifact.  `manifest` provides artifact file paths
    /// for backends that load compiled objects; the reference backend
    /// executes straight from the spec.
    fn compile(&self, manifest: &Manifest, spec: &ArtifactSpec) -> anyhow::Result<Box<dyn Executor>>;

    /// [`compile`](Backend::compile) with caller-requested [`ExecOptions`].
    /// The default implementation ignores the options.
    fn compile_opts(
        &self,
        manifest: &Manifest,
        spec: &ArtifactSpec,
        opts: &ExecOptions,
    ) -> anyhow::Result<Box<dyn Executor>> {
        let _ = opts;
        self.compile(manifest, spec)
    }
}

/// A compiled (or interpreted) artifact ready to run.
///
/// Implementations receive inputs already validated against the manifest
/// ABI by [`super::Executable::run`] — count, per-input element count and
/// dtype all match the spec.
///
/// `Send` is part of the contract: the serving subsystem moves one
/// executor replica into each worker thread of its pool.
pub trait Executor: Send {
    fn run(&self, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>>;
}
