//! Backend-neutral tensor values crossing the runtime ABI boundary.
//!
//! Every executor — the pure-Rust reference backend and the feature-gated
//! PJRT/XLA backend — consumes and produces [`Tensor`]s.  The type is a
//! deliberately small shape-carrying value: row-major data plus dims,
//! no strides, no views, two dtypes (the whole manifest ABI is f32/i32).

use super::manifest::DType;

/// A dense row-major tensor (f32 or i32).
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    /// Build an f32 tensor, validating that `data` fills `shape` exactly.
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> anyhow::Result<Tensor> {
        let want: usize = shape.iter().product();
        anyhow::ensure!(
            data.len() == want,
            "tensor data has {} elements for shape {shape:?}",
            data.len()
        );
        Ok(Tensor::F32 { shape, data })
    }

    /// Build an i32 tensor, validating that `data` fills `shape` exactly.
    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> anyhow::Result<Tensor> {
        let want: usize = shape.iter().product();
        anyhow::ensure!(
            data.len() == want,
            "tensor data has {} elements for shape {shape:?}",
            data.len()
        );
        Ok(Tensor::I32 { shape, data })
    }

    /// A rank-0 (scalar) f32 tensor.
    pub fn scalar_f32(value: f32) -> Tensor {
        Tensor::F32 { shape: Vec::new(), data: vec![value] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32 { .. } => DType::F32,
            Tensor::I32 { .. } => DType::I32,
        }
    }

    pub fn element_count(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    /// Borrow the f32 payload; errors on an i32 tensor.
    pub fn f32_data(&self) -> anyhow::Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            Tensor::I32 { .. } => anyhow::bail!("expected an f32 tensor, got i32"),
        }
    }

    /// Borrow the i32 payload; errors on an f32 tensor.
    pub fn i32_data(&self) -> anyhow::Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            Tensor::F32 { .. } => anyhow::bail!("expected an i32 tensor, got f32"),
        }
    }

    /// The single element of a rank-0/rank-1 f32 tensor (loss, step, ...).
    pub fn scalar(&self) -> anyhow::Result<f32> {
        let data = self.f32_data()?;
        anyhow::ensure!(
            data.len() == 1,
            "expected a scalar, got {} elements",
            data.len()
        );
        Ok(data[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_validate_shape() {
        assert!(Tensor::f32(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::i32(vec![4], vec![1, 2, 3, 4]).is_ok());
        assert!(Tensor::i32(vec![4], vec![1]).is_err());
    }

    #[test]
    fn scalar_round_trip() {
        let t = Tensor::scalar_f32(2.5);
        assert_eq!(t.shape(), &[] as &[usize]);
        assert_eq!(t.element_count(), 1);
        assert_eq!(t.scalar().unwrap(), 2.5);
    }

    #[test]
    fn dtype_accessors_are_strict() {
        let f = Tensor::f32(vec![2], vec![1.0, 2.0]).unwrap();
        let i = Tensor::i32(vec![2], vec![1, 2]).unwrap();
        assert_eq!(f.dtype(), DType::F32);
        assert_eq!(i.dtype(), DType::I32);
        assert!(f.i32_data().is_err());
        assert!(i.f32_data().is_err());
        assert_eq!(f.f32_data().unwrap(), &[1.0, 2.0]);
        assert_eq!(i.i32_data().unwrap(), &[1, 2]);
    }

    #[test]
    fn scalar_rejects_vectors() {
        let v = Tensor::f32(vec![2], vec![1.0, 2.0]).unwrap();
        assert!(v.scalar().is_err());
    }
}
