//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime.
//!
//! `make artifacts` writes `artifacts/manifest.json` describing every
//! compiled HLO module: its input ABI (ordered names/shapes/dtypes),
//! output names, weight shapes and mini-batch geometry.  The runtime
//! refuses to feed an executable anything that disagrees with this file.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::layout::Geometry;
use crate::sampler::values::GnnModel;
use crate::util::json::Json;

/// Element type of a tensor input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> anyhow::Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => anyhow::bail!("unsupported dtype {other:?}"),
        }
    }
}

/// One input tensor of an artifact's ABI.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Artifact kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    TrainStep,
    /// Train step with Adam state threaded through (extra m/v/step I/O).
    AdamStep,
    Forward,
}

impl Kind {
    pub fn as_str(&self) -> &'static str {
        match self {
            Kind::TrainStep => "train_step",
            Kind::AdamStep => "adam_step",
            Kind::Forward => "forward",
        }
    }
}

/// One compiled HLO module's metadata.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub model: GnnModel,
    pub kind: Kind,
    pub geometry: Geometry,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<String>,
    /// Per-layer (W shape, b shape).
    pub weight_shapes: Vec<(Vec<usize>, Vec<usize>)>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    by_name: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// The built-in artifact registry: every (model × geometry × kind)
    /// combination from `python/compile/geometry.py`'s catalog, with the
    /// exact ABI `python/compile/aot.py` would record.  This is what the
    /// reference backend runs from — no `make artifacts` required.
    pub fn builtin() -> Manifest {
        let mut by_name = BTreeMap::new();
        for geom in builtin_geometries() {
            for model in [GnnModel::Gcn, GnnModel::Sage] {
                for kind in [Kind::TrainStep, Kind::AdamStep, Kind::Forward] {
                    let spec = spec_for(model, kind, &geom);
                    by_name.insert(spec.name.clone(), spec);
                }
            }
        }
        Manifest { dir: PathBuf::from("<builtin>"), by_name }
    }

    /// Build a manifest from explicit specs (tests, custom geometries).
    pub fn from_specs(specs: Vec<ArtifactSpec>) -> anyhow::Result<Manifest> {
        let mut by_name = BTreeMap::new();
        for spec in specs {
            anyhow::ensure!(
                by_name.insert(spec.name.clone(), spec).is_none(),
                "duplicate artifact name"
            );
        }
        Ok(Manifest { dir: PathBuf::from("<custom>"), by_name })
    }

    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e}; run `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> anyhow::Result<Manifest> {
        let doc = Json::parse(text)?;
        anyhow::ensure!(
            doc.get("version")?.as_usize()? == 1,
            "unsupported manifest version"
        );
        let mut by_name = BTreeMap::new();
        for entry in doc.get("artifacts")?.as_arr()? {
            let spec = Self::parse_entry(entry)?;
            anyhow::ensure!(
                by_name.insert(spec.name.clone(), spec).is_none(),
                "duplicate artifact name"
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), by_name })
    }

    fn parse_entry(entry: &Json) -> anyhow::Result<ArtifactSpec> {
        let name = entry.get("name")?.as_str()?.to_string();
        let kind = match entry.get("kind")?.as_str()? {
            "train_step" => Kind::TrainStep,
            "adam_step" => Kind::AdamStep,
            "forward" => Kind::Forward,
            other => anyhow::bail!("artifact {name}: unknown kind {other:?}"),
        };
        let gs = entry.get("geometry_spec")?;
        let geometry = Geometry {
            name: entry.get("geometry")?.as_str()?.to_string(),
            b: gs.get("b")?.usize_list()?,
            e: gs.get("e")?.usize_list()?,
            f: gs.get("f")?.usize_list()?,
        };
        geometry.validate()?;
        let inputs = entry
            .get("inputs")?
            .as_arr()?
            .iter()
            .map(|i| {
                Ok(TensorSpec {
                    name: i.get("name")?.as_str()?.to_string(),
                    shape: i.get("shape")?.usize_list()?,
                    dtype: DType::parse(i.get("dtype")?.as_str()?)?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let outputs = entry
            .get("outputs")?
            .as_arr()?
            .iter()
            .map(|o| Ok(o.as_str()?.to_string()))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let weight_shapes = entry
            .get("weight_shapes")?
            .as_arr()?
            .iter()
            .map(|w| Ok((w.get("w")?.usize_list()?, w.get("b")?.usize_list()?)))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(ArtifactSpec {
            name,
            file: entry.get("file")?.as_str()?.to_string(),
            model: GnnModel::parse(entry.get("model")?.as_str()?)?,
            kind,
            geometry,
            inputs,
            outputs,
            weight_shapes,
        })
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&ArtifactSpec> {
        self.by_name
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name:?} not in manifest; have: {:?}", self.names()))
    }

    /// Find by (model, geometry, kind) — the lookup the coordinator uses.
    /// Models resolve through `artifact_key()` (GIN shares the GCN
    /// template; its edge values are runtime inputs).
    pub fn find(&self, model: GnnModel, geometry: &str, kind: Kind) -> anyhow::Result<&ArtifactSpec> {
        let key = model.artifact_key();
        self.by_name
            .values()
            .find(|a| a.model.as_str() == key && a.geometry.name == geometry && a.kind == kind)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no artifact for ({}, {geometry}, {kind:?}); run `make artifacts`",
                    model.as_str()
                )
            })
    }

    pub fn names(&self) -> Vec<&str> {
        self.by_name.keys().map(|s| s.as_str()).collect()
    }

    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

/// The static geometry catalog (mirror of `python/compile/geometry.py`;
/// once artifacts exist, `loads_repo_manifest_when_built` diffs every
/// builtin spec against the manifest aot.py wrote, so drift fails tests).
fn builtin_geometries() -> Vec<Geometry> {
    let g = |name: &str, b: &[usize], e: &[usize], f: &[usize]| Geometry {
        name: name.to_string(),
        b: b.to_vec(),
        e: e.to_vec(),
        f: f.to_vec(),
    };
    vec![
        g("tiny", &[96, 16, 4], &[96, 16], &[16, 8, 4]),
        g("ns_small", &[2112, 352, 32], &[2112, 352], &[500, 256, 7]),
        g("ss_small", &[256, 256, 256], &[2048, 2048], &[500, 256, 7]),
        g("ns_medium", &[8448, 1408, 128], &[8448, 1408], &[500, 256, 7]),
    ]
}

/// Per-layer `(W shape, b shape)` — `model.weight_shapes` in rust.  SAGE
/// doubles fan-in for the `h_v || mean(neigh)` concat.
fn weight_shapes(model: GnnModel, geom: &Geometry) -> Vec<(Vec<usize>, Vec<usize>)> {
    let sage = model == GnnModel::Sage;
    (0..geom.layers())
        .map(|l| {
            let fin = geom.f[l] * if sage { 2 } else { 1 };
            (vec![fin, geom.f[l + 1]], vec![geom.f[l + 1]])
        })
        .collect()
}

/// Synthesize the ArtifactSpec that `python/compile/aot.py` records for
/// one (model, kind, geometry) — same name, same ABI order, same outputs.
pub fn spec_for(model: GnnModel, kind: Kind, geom: &Geometry) -> ArtifactSpec {
    assert!(
        matches!(model, GnnModel::Gcn | GnnModel::Sage),
        "artifacts exist per artifact family; resolve GIN via artifact_key() first"
    );
    let ll = geom.layers();
    let name = format!("{}_{}_{}", model.as_str(), geom.name, kind.as_str());

    let mut inputs = Vec::new();
    let mut add = |name: String, shape: Vec<usize>, dtype: DType| {
        inputs.push(TensorSpec { name, shape, dtype });
    };
    add("x0".into(), vec![geom.b[0], geom.f[0]], DType::F32);
    add("labels".into(), vec![geom.b[ll]], DType::I32);
    add("mask".into(), vec![geom.b[ll]], DType::F32);
    for l in 1..=ll {
        add(format!("src{l}"), vec![geom.e[l - 1]], DType::I32);
        add(format!("dst{l}"), vec![geom.e[l - 1]], DType::I32);
        add(format!("val{l}"), vec![geom.e[l - 1]], DType::F32);
    }
    if model == GnnModel::Sage {
        for l in 1..=ll {
            add(format!("self_idx{l}"), vec![geom.b[l]], DType::I32);
        }
    }
    let shapes = weight_shapes(model, geom);
    for (l, (wshape, bshape)) in shapes.iter().enumerate() {
        add(format!("w{}", l + 1), wshape.clone(), DType::F32);
        add(format!("b{}", l + 1), bshape.clone(), DType::F32);
    }
    if matches!(kind, Kind::TrainStep | Kind::AdamStep) {
        add("lr".into(), vec![], DType::F32);
    }
    if kind == Kind::AdamStep {
        for (l, (wshape, bshape)) in shapes.iter().enumerate() {
            add(format!("m_w{}", l + 1), wshape.clone(), DType::F32);
            add(format!("m_b{}", l + 1), bshape.clone(), DType::F32);
        }
        for (l, (wshape, bshape)) in shapes.iter().enumerate() {
            add(format!("v_w{}", l + 1), wshape.clone(), DType::F32);
            add(format!("v_b{}", l + 1), bshape.clone(), DType::F32);
        }
        add("step".into(), vec![], DType::F32);
    }

    let mut outputs = Vec::new();
    match kind {
        Kind::Forward => outputs.push("logits".to_string()),
        Kind::TrainStep | Kind::AdamStep => {
            outputs.push("loss".to_string());
            for l in 1..=ll {
                outputs.push(format!("w{l}"));
                outputs.push(format!("b{l}"));
            }
            if kind == Kind::AdamStep {
                for l in 1..=ll {
                    outputs.push(format!("m_w{l}"));
                    outputs.push(format!("m_b{l}"));
                }
                for l in 1..=ll {
                    outputs.push(format!("v_w{l}"));
                    outputs.push(format!("v_b{l}"));
                }
                outputs.push("step".to_string());
            }
        }
    }

    ArtifactSpec {
        file: format!("{name}.hlo.txt"),
        name,
        model,
        kind,
        geometry: geom.clone(),
        inputs,
        outputs,
        weight_shapes: shapes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "gcn_tiny_train_step", "file": "gcn_tiny_train_step.hlo.txt",
         "model": "gcn", "geometry": "tiny", "kind": "train_step",
         "inputs": [
            {"name": "x0", "shape": [96, 16], "dtype": "f32"},
            {"name": "labels", "shape": [4], "dtype": "i32"},
            {"name": "lr", "shape": [], "dtype": "f32"}
         ],
         "outputs": ["loss", "w1", "b1"],
         "weight_shapes": [{"w": [16, 8], "b": [8]}, {"w": [8, 4], "b": [4]}],
         "geometry_spec": {"b": [96, 16, 4], "e": [96, 16], "f": [16, 8, 4],
                           "layers": 2, "num_classes": 4}}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let a = m.get("gcn_tiny_train_step").unwrap();
        assert_eq!(a.kind, Kind::TrainStep);
        assert_eq!(a.model, GnnModel::Gcn);
        assert_eq!(a.geometry.b, vec![96, 16, 4]);
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[1].dtype, DType::I32);
        assert_eq!(a.inputs[2].shape, Vec::<usize>::new());
        assert_eq!(a.inputs[2].elements(), 1);
        assert_eq!(a.weight_shapes[0].0, vec![16, 8]);
        assert_eq!(m.hlo_path(a), Path::new("/tmp/a/gcn_tiny_train_step.hlo.txt"));
    }

    #[test]
    fn find_by_role() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert!(m.find(GnnModel::Gcn, "tiny", Kind::TrainStep).is_ok());
        assert!(m.find(GnnModel::Sage, "tiny", Kind::TrainStep).is_err());
        assert!(m.find(GnnModel::Gcn, "tiny", Kind::Forward).is_err());
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_unknown_dtype() {
        let bad = SAMPLE.replace("\"f32\"", "\"f16\"");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn builtin_covers_all_roles() {
        let m = Manifest::builtin();
        for geom in ["tiny", "ns_small", "ss_small", "ns_medium"] {
            for model in [GnnModel::Gcn, GnnModel::Sage] {
                for kind in [Kind::TrainStep, Kind::AdamStep, Kind::Forward] {
                    let spec = m.find(model, geom, kind).unwrap();
                    spec.geometry.validate().unwrap();
                    assert_eq!(spec.inputs.first().unwrap().name, "x0");
                }
            }
        }
        // GIN resolves onto the GCN family.
        assert!(m.find(GnnModel::Gin, "tiny", Kind::TrainStep).is_ok());
    }

    #[test]
    fn builtin_tiny_abi_matches_aot_contract() {
        // The sample manifest above is a trimmed copy of what aot.py wrote
        // for the tiny geometry; the synthesized spec must agree with the
        // full contract on everything the sample pins.
        let m = Manifest::builtin();
        let a = m.get("gcn_tiny_train_step").unwrap();
        assert_eq!(a.kind, Kind::TrainStep);
        assert_eq!(a.geometry.b, vec![96, 16, 4]);
        assert_eq!(a.inputs[0].shape, vec![96, 16]);
        assert_eq!(a.inputs[1].name, "labels");
        assert_eq!(a.inputs[1].dtype, DType::I32);
        assert_eq!(a.weight_shapes[0].0, vec![16, 8]);
        assert_eq!(a.outputs, vec!["loss", "w1", "b1", "w2", "b2"]);
        let last = a.inputs.last().unwrap();
        assert_eq!(last.name, "lr");
        assert_eq!(last.shape, Vec::<usize>::new());

        // SAGE doubles fan-in and appends self_idx gathers.
        let s = m.get("sage_tiny_train_step").unwrap();
        assert_eq!(s.weight_shapes[0].0, vec![32, 8]);
        assert!(s.inputs.iter().any(|i| i.name == "self_idx1"));

        // Adam threads m/v/step through both directions of the ABI.
        let ad = m.get("gcn_tiny_adam_step").unwrap();
        assert_eq!(ad.inputs.last().unwrap().name, "step");
        assert_eq!(ad.outputs.last().unwrap(), "step");
        assert_eq!(ad.outputs.len(), 1 + 3 * 4 + 1);

        // Forward drops lr and returns logits only.
        let f = m.get("gcn_tiny_forward").unwrap();
        assert!(f.inputs.iter().all(|i| i.name != "lr"));
        assert_eq!(f.outputs, vec!["logits"]);
    }

    #[test]
    fn from_specs_rejects_duplicates() {
        let geom = builtin_geometries().remove(0);
        let a = spec_for(GnnModel::Gcn, Kind::Forward, &geom);
        assert!(Manifest::from_specs(vec![a.clone()]).is_ok());
        assert!(Manifest::from_specs(vec![a.clone(), a]).is_err());
    }

    #[test]
    fn loads_repo_manifest_when_built() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this checkout
        }
        let m = Manifest::load(&dir).unwrap();
        let a = m.find(GnnModel::Gcn, "tiny", Kind::TrainStep).unwrap();
        assert_eq!(a.inputs.first().unwrap().name, "x0");
        assert_eq!(a.outputs.first().unwrap(), "loss");
        // The builtin catalog must agree with what aot.py actually wrote:
        // any drift between geometry.py and builtin_geometries()/spec_for()
        // fails here once artifacts exist.
        let builtin = Manifest::builtin();
        for name in builtin.names() {
            let Ok(loaded) = m.get(name) else { continue };
            let b = builtin.get(name).unwrap();
            assert_eq!(loaded.geometry, b.geometry, "{name}: geometry drift");
            assert_eq!(loaded.outputs, b.outputs, "{name}: outputs drift");
            assert_eq!(loaded.weight_shapes, b.weight_shapes, "{name}: weight-shape drift");
            let abi = |s: &ArtifactSpec| -> Vec<(String, Vec<usize>, DType)> {
                s.inputs
                    .iter()
                    .map(|i| (i.name.clone(), i.shape.clone(), i.dtype))
                    .collect()
            };
            assert_eq!(abi(loaded), abi(b), "{name}: input ABI drift");
        }
    }
}
