//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime.
//!
//! `make artifacts` writes `artifacts/manifest.json` describing every
//! compiled HLO module: its input ABI (ordered names/shapes/dtypes),
//! output names, weight shapes and mini-batch geometry.  The runtime
//! refuses to feed an executable anything that disagrees with this file.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::layout::Geometry;
use crate::sampler::values::GnnModel;
use crate::util::json::Json;

/// Element type of a tensor input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> anyhow::Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => anyhow::bail!("unsupported dtype {other:?}"),
        }
    }
}

/// One input tensor of an artifact's ABI.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Artifact kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    TrainStep,
    /// Train step with Adam state threaded through (extra m/v/step I/O).
    AdamStep,
    Forward,
}

/// One compiled HLO module's metadata.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub model: GnnModel,
    pub kind: Kind,
    pub geometry: Geometry,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<String>,
    /// Per-layer (W shape, b shape).
    pub weight_shapes: Vec<(Vec<usize>, Vec<usize>)>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    by_name: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e}; run `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> anyhow::Result<Manifest> {
        let doc = Json::parse(text)?;
        anyhow::ensure!(
            doc.get("version")?.as_usize()? == 1,
            "unsupported manifest version"
        );
        let mut by_name = BTreeMap::new();
        for entry in doc.get("artifacts")?.as_arr()? {
            let spec = Self::parse_entry(entry)?;
            anyhow::ensure!(
                by_name.insert(spec.name.clone(), spec).is_none(),
                "duplicate artifact name"
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), by_name })
    }

    fn parse_entry(entry: &Json) -> anyhow::Result<ArtifactSpec> {
        let name = entry.get("name")?.as_str()?.to_string();
        let kind = match entry.get("kind")?.as_str()? {
            "train_step" => Kind::TrainStep,
            "adam_step" => Kind::AdamStep,
            "forward" => Kind::Forward,
            other => anyhow::bail!("artifact {name}: unknown kind {other:?}"),
        };
        let gs = entry.get("geometry_spec")?;
        let geometry = Geometry {
            name: entry.get("geometry")?.as_str()?.to_string(),
            b: gs.get("b")?.usize_list()?,
            e: gs.get("e")?.usize_list()?,
            f: gs.get("f")?.usize_list()?,
        };
        geometry.validate()?;
        let inputs = entry
            .get("inputs")?
            .as_arr()?
            .iter()
            .map(|i| {
                Ok(TensorSpec {
                    name: i.get("name")?.as_str()?.to_string(),
                    shape: i.get("shape")?.usize_list()?,
                    dtype: DType::parse(i.get("dtype")?.as_str()?)?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let outputs = entry
            .get("outputs")?
            .as_arr()?
            .iter()
            .map(|o| Ok(o.as_str()?.to_string()))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let weight_shapes = entry
            .get("weight_shapes")?
            .as_arr()?
            .iter()
            .map(|w| Ok((w.get("w")?.usize_list()?, w.get("b")?.usize_list()?)))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(ArtifactSpec {
            name,
            file: entry.get("file")?.as_str()?.to_string(),
            model: GnnModel::parse(entry.get("model")?.as_str()?)?,
            kind,
            geometry,
            inputs,
            outputs,
            weight_shapes,
        })
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&ArtifactSpec> {
        self.by_name
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name:?} not in manifest; have: {:?}", self.names()))
    }

    /// Find by (model, geometry, kind) — the lookup the coordinator uses.
    /// Models resolve through `artifact_key()` (GIN shares the GCN
    /// template; its edge values are runtime inputs).
    pub fn find(&self, model: GnnModel, geometry: &str, kind: Kind) -> anyhow::Result<&ArtifactSpec> {
        let key = model.artifact_key();
        self.by_name
            .values()
            .find(|a| a.model.as_str() == key && a.geometry.name == geometry && a.kind == kind)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no artifact for ({}, {geometry}, {kind:?}); run `make artifacts`",
                    model.as_str()
                )
            })
    }

    pub fn names(&self) -> Vec<&str> {
        self.by_name.keys().map(|s| s.as_str()).collect()
    }

    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "gcn_tiny_train_step", "file": "gcn_tiny_train_step.hlo.txt",
         "model": "gcn", "geometry": "tiny", "kind": "train_step",
         "inputs": [
            {"name": "x0", "shape": [96, 16], "dtype": "f32"},
            {"name": "labels", "shape": [4], "dtype": "i32"},
            {"name": "lr", "shape": [], "dtype": "f32"}
         ],
         "outputs": ["loss", "w1", "b1"],
         "weight_shapes": [{"w": [16, 8], "b": [8]}, {"w": [8, 4], "b": [4]}],
         "geometry_spec": {"b": [96, 16, 4], "e": [96, 16], "f": [16, 8, 4],
                           "layers": 2, "num_classes": 4}}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let a = m.get("gcn_tiny_train_step").unwrap();
        assert_eq!(a.kind, Kind::TrainStep);
        assert_eq!(a.model, GnnModel::Gcn);
        assert_eq!(a.geometry.b, vec![96, 16, 4]);
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[1].dtype, DType::I32);
        assert_eq!(a.inputs[2].shape, Vec::<usize>::new());
        assert_eq!(a.inputs[2].elements(), 1);
        assert_eq!(a.weight_shapes[0].0, vec![16, 8]);
        assert_eq!(m.hlo_path(a), Path::new("/tmp/a/gcn_tiny_train_step.hlo.txt"));
    }

    #[test]
    fn find_by_role() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert!(m.find(GnnModel::Gcn, "tiny", Kind::TrainStep).is_ok());
        assert!(m.find(GnnModel::Sage, "tiny", Kind::TrainStep).is_err());
        assert!(m.find(GnnModel::Gcn, "tiny", Kind::Forward).is_err());
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_unknown_dtype() {
        let bad = SAMPLE.replace("\"f32\"", "\"f16\"");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn loads_repo_manifest_when_built() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this checkout
        }
        let m = Manifest::load(&dir).unwrap();
        let a = m.find(GnnModel::Gcn, "tiny", Kind::TrainStep).unwrap();
        assert_eq!(a.inputs.first().unwrap().name, "x0");
        assert_eq!(a.outputs.first().unwrap(), "loss");
    }
}
