//! Elementwise and row-wise kernels: ReLU, masked softmax cross-entropy,
//! and the SGD/Adam update rules.
//!
//! Elementwise ops are trivially deterministic under chunked parallelism
//! (each element is written once, by one thread).  The softmax loss keeps
//! the scalar reduction order: per-row terms are computed row-parallel,
//! then folded sequentially in ascending row order — the exact `loss -=
//! term` sequence of the scalar loop.

use super::{par_row_tiles, Kernels, MIN_PAR_WORK};
use crate::util::threadpool::par_map;

/// `max(x, 0)` — hidden-layer activation.
pub fn relu(z: &[f32], kp: &Kernels) -> Vec<f32> {
    let _sp = crate::obs::span_with("kernel", "relu", || {
        vec![("flops", z.len() as f64), ("bytes", 4.0 * 2.0 * z.len() as f64)]
    });
    let threads = if kp.naive { 1 } else { kp.threads };
    let mut out = vec![0.0f32; z.len()];
    par_row_tiles(threads, z.len(), 1, z.len(), &mut out, |r0, r1, tile| {
        for (o, &x) in tile.iter_mut().zip(&z[r0..r1]) {
            *o = x.max(0.0);
        }
    });
    out
}

/// ReLU backward: zero `dz` wherever the cached pre-activation `z <= 0`.
pub fn relu_mask_inplace(dz: &mut [f32], z: &[f32], kp: &Kernels) {
    let _sp = crate::obs::span_with("kernel", "relu_mask", || {
        vec![("flops", z.len() as f64), ("bytes", 4.0 * 2.0 * z.len() as f64)]
    });
    debug_assert_eq!(dz.len(), z.len());
    let threads = if kp.naive { 1 } else { kp.threads };
    let n = dz.len();
    par_row_tiles(threads, n, 1, n, dz, |r0, r1, tile| {
        for (g, &zv) in tile.iter_mut().zip(&z[r0..r1]) {
            if zv <= 0.0 {
                *g = 0.0;
            }
        }
    });
}

/// Masked softmax cross-entropy (model.masked_xent) and its gradient
/// w.r.t. the logits: mean over unmasked rows, `dlogits = mask · (p -
/// onehot) / denom`.  Row-parallel; the loss fold runs sequentially over
/// rows ascending.
pub fn masked_xent(
    logits: &[f32],
    labels: &[i32],
    mask: &[f32],
    classes: usize,
    kp: &Kernels,
) -> (f32, Vec<f32>) {
    let rows = labels.len();
    let _sp = crate::obs::span_with("kernel", "masked_xent", || {
        vec![
            ("flops", 6.0 * rows as f64 * classes as f64),
            ("bytes", 4.0 * 2.0 * rows as f64 * classes as f64),
        ]
    });
    let denom: f32 = mask.iter().sum::<f32>().max(1.0);

    if kp.naive {
        // The pre-kernel scalar loop, verbatim.
        let mut loss = 0.0f32;
        let mut dlogits = vec![0.0f32; rows * classes];
        for i in 0..rows {
            let row = &logits[i * classes..(i + 1) * classes];
            let drow = &mut dlogits[i * classes..(i + 1) * classes];
            loss -= xent_row(row, labels[i], mask[i], denom, drow);
        }
        return (loss / denom, dlogits);
    }

    let mut dlogits = vec![0.0f32; rows * classes];
    let mut terms = vec![0.0f32; rows];
    // ~6 scalar ops (incl. one exp) per logit.
    let work = rows * classes * 6;
    let threads = kp.threads.max(1).min(rows.max(1));
    if threads == 1 || work < MIN_PAR_WORK {
        for i in 0..rows {
            let row = &logits[i * classes..(i + 1) * classes];
            let drow = &mut dlogits[i * classes..(i + 1) * classes];
            terms[i] = xent_row(row, labels[i], mask[i], denom, drow);
        }
    } else {
        let per = rows.div_ceil(threads);
        let tiles: Vec<((usize, &mut [f32]), &mut [f32])> = dlogits
            .chunks_mut(per * classes)
            .enumerate()
            .zip(terms.chunks_mut(per))
            .collect();
        par_map(threads, tiles, |((t, dtile), ttile)| {
            let r0 = t * per;
            for (r, term) in ttile.iter_mut().enumerate() {
                let i = r0 + r;
                let row = &logits[i * classes..(i + 1) * classes];
                let drow = &mut dtile[r * classes..(r + 1) * classes];
                *term = xent_row(row, labels[i], mask[i], denom, drow);
            }
        });
    }
    // Sequential fold in row order — bit-identical to the scalar loop.
    let mut loss = 0.0f32;
    for &t in &terms {
        loss -= t;
    }
    (loss / denom, dlogits)
}

/// One row of the loss: returns the (pre-negation) loss term and fills
/// the gradient row when the mask is nonzero.
#[inline]
fn xent_row(row: &[f32], label: i32, mask: f32, denom: f32, drow: &mut [f32]) -> f32 {
    let max = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
    let lse = max + row.iter().map(|&x| (x - max).exp()).sum::<f32>().ln();
    let y = label as usize;
    let term = (row[y] - lse) * mask;
    if mask != 0.0 {
        for (j, g) in drow.iter_mut().enumerate() {
            let p = (row[j] - lse).exp();
            let onehot = if j == y { 1.0 } else { 0.0 };
            *g = mask * (p - onehot) / denom;
        }
    }
    term
}

/// SGD: `p' = p - lr · g`.
pub fn sgd_update(p: &[f32], g: &[f32], lr: f32, kp: &Kernels) -> Vec<f32> {
    let _sp = crate::obs::span_with("optimizer", "sgd_update", || {
        vec![("flops", 2.0 * p.len() as f64), ("bytes", 4.0 * 3.0 * p.len() as f64)]
    });
    debug_assert_eq!(p.len(), g.len());
    let threads = if kp.naive { 1 } else { kp.threads };
    let mut out = vec![0.0f32; p.len()];
    par_row_tiles(threads, p.len(), 1, p.len() * 2, &mut out, |r0, r1, tile| {
        for (i, o) in (r0..r1).zip(tile.iter_mut()) {
            *o = p[i] - lr * g[i];
        }
    });
    out
}

/// Adam step inputs shared across all parameter tensors of one step.
#[derive(Debug, Clone, Copy)]
pub struct AdamParams {
    pub lr: f32,
    pub b1: f32,
    pub b2: f32,
    pub eps: f32,
    /// `1 - b1^t` for the step's bias correction.
    pub bias1: f32,
    /// `1 - b2^t`.
    pub bias2: f32,
}

/// Adam: returns `(p', m', v')` for one parameter tensor.
pub fn adam_update(
    p: &[f32],
    g: &[f32],
    m0: &[f32],
    v0: &[f32],
    ap: &AdamParams,
    kp: &Kernels,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let n = p.len();
    let _sp = crate::obs::span_with("optimizer", "adam_update", || {
        vec![("flops", 10.0 * n as f64), ("bytes", 4.0 * 7.0 * n as f64)]
    });
    debug_assert!(g.len() == n && m0.len() == n && v0.len() == n);
    let mut np = vec![0.0f32; n];
    let mut nm = vec![0.0f32; n];
    let mut nv = vec![0.0f32; n];
    let scalar = |i: usize, np: &mut f32, nm: &mut f32, nv: &mut f32| {
        let m = ap.b1 * m0[i] + (1.0 - ap.b1) * g[i];
        let v = ap.b2 * v0[i] + (1.0 - ap.b2) * g[i] * g[i];
        let mhat = m / ap.bias1;
        let vhat = v / ap.bias2;
        *np = p[i] - ap.lr * mhat / (vhat.sqrt() + ap.eps);
        *nm = m;
        *nv = v;
    };
    let threads = if kp.naive { 1 } else { kp.threads.max(1).min(n.max(1)) };
    // ~10 scalar ops (incl. sqrt + divides) per element.
    if threads == 1 || n * 10 < MIN_PAR_WORK {
        for i in 0..n {
            let (mut pv, mut mv, mut vv) = (0.0, 0.0, 0.0);
            scalar(i, &mut pv, &mut mv, &mut vv);
            np[i] = pv;
            nm[i] = mv;
            nv[i] = vv;
        }
    } else {
        let per = n.div_ceil(threads);
        let tiles: Vec<((usize, &mut [f32]), (&mut [f32], &mut [f32]))> = np
            .chunks_mut(per)
            .enumerate()
            .zip(nm.chunks_mut(per).zip(nv.chunks_mut(per)))
            .collect();
        par_map(threads, tiles, |((t, ptile), (mtile, vtile))| {
            let r0 = t * per;
            for r in 0..ptile.len() {
                scalar(r0 + r, &mut ptile[r], &mut mtile[r], &mut vtile[r]);
            }
        });
    }
    (np, nm, nv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn relu_and_mask_match_scalar_across_threads() {
        let mut rng = Pcg64::seed_from_u64(31);
        let z: Vec<f32> = (0..4097).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let dz0: Vec<f32> = (0..4097).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let want_relu: Vec<f32> = z.iter().map(|&x| x.max(0.0)).collect();
        let mut want_dz = dz0.clone();
        for (g, &zv) in want_dz.iter_mut().zip(&z) {
            if zv <= 0.0 {
                *g = 0.0;
            }
        }
        for threads in [1, 2, 8] {
            let kp = Kernels::with_threads(threads);
            assert_eq!(relu(&z, &kp), want_relu);
            let mut dz = dz0.clone();
            relu_mask_inplace(&mut dz, &z, &kp);
            assert_eq!(dz, want_dz);
        }
    }

    #[test]
    fn masked_xent_matches_naive_bitwise_across_threads() {
        let mut rng = Pcg64::seed_from_u64(32);
        for (rows, classes) in [(1usize, 2usize), (7, 3), (33, 5), (1024, 16)] {
            let logits: Vec<f32> =
                (0..rows * classes).map(|_| rng.f32_range(-4.0, 4.0)).collect();
            let labels: Vec<i32> = (0..rows).map(|_| rng.index(classes) as i32).collect();
            let mask: Vec<f32> =
                (0..rows).map(|i| if i % 3 == 0 { 0.0 } else { 1.0 }).collect();
            let (want_loss, want_d) =
                masked_xent(&logits, &labels, &mask, classes, &Kernels::scalar_baseline());
            for threads in [1, 2, 8] {
                let kp = Kernels::with_threads(threads);
                let (loss, d) = masked_xent(&logits, &labels, &mask, classes, &kp);
                assert_eq!(loss.to_bits(), want_loss.to_bits(), "{rows}x{classes} t={threads}");
                assert_eq!(d, want_d);
            }
        }
    }

    #[test]
    fn updates_match_scalar_across_threads() {
        let mut rng = Pcg64::seed_from_u64(33);
        let n = 40_000; // above the sequential threshold
        let p: Vec<f32> = (0..n).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let g: Vec<f32> = (0..n).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let m0: Vec<f32> = (0..n).map(|_| rng.f32_range(-0.1, 0.1)).collect();
        let v0: Vec<f32> = (0..n).map(|_| rng.f32_range(0.0, 0.1)).collect();
        let ap = AdamParams {
            lr: 0.05,
            b1: 0.9,
            b2: 0.999,
            eps: 1e-8,
            bias1: 1.0 - 0.9f32.powf(1.0),
            bias2: 1.0 - 0.999f32.powf(1.0),
        };
        let base = Kernels::scalar_baseline();
        let want_sgd = sgd_update(&p, &g, 0.1, &base);
        let (wp, wm, wv) = adam_update(&p, &g, &m0, &v0, &ap, &base);
        for threads in [1, 2, 8] {
            let kp = Kernels::with_threads(threads);
            assert_eq!(sgd_update(&p, &g, 0.1, &kp), want_sgd);
            let (ap_, am, av) = adam_update(&p, &g, &m0, &v0, &ap, &kp);
            assert_eq!(ap_, wp);
            assert_eq!(am, wm);
            assert_eq!(av, wv);
        }
    }
}
