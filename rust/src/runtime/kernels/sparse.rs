//! Fused CSR aggregate kernels (SpMM over the per-layer COO
//! `src/dst/val` triples) and the GraphSAGE gather/concat/scatter.
//!
//! The executor's batches arrive as COO edge triples.  [`group_edges`]
//! buckets them into CSR rows **stably** — within a row, edges keep their
//! original COO order — so the row-parallel kernels accumulate each
//! output element in exactly the order the scalar COO loop does (the
//! module invariant in [`super`]).  Edges with `val == 0.0` are padding
//! and contribute nothing, as in the scalar loops.

use super::{par_row_tiles, runs_sequential, Kernels};

/// COO edges grouped by one endpoint: `edges[row_ptr[r]..row_ptr[r+1]]`
/// are the original edge indices whose key is `r`, in COO order.
pub struct Csr {
    pub row_ptr: Vec<usize>,
    pub edges: Vec<u32>,
}

impl Csr {
    pub fn rows(&self) -> usize {
        self.row_ptr.len() - 1
    }
}

/// Stable counting-sort of edge indices by `keys[e]` (a `dst` column for
/// the forward aggregate, `src` for its transpose, `self_idx` for the
/// SAGE scatter).  Callers guarantee `0 <= keys[e] < rows` — the executor
/// validates index bounds when parsing the ABI inputs.
pub fn group_edges(keys: &[i32], rows: usize) -> Csr {
    let mut row_ptr = vec![0usize; rows + 1];
    for &k in keys {
        row_ptr[k as usize + 1] += 1;
    }
    for r in 0..rows {
        row_ptr[r + 1] += row_ptr[r];
    }
    let mut cursor = row_ptr.clone();
    let mut edges = vec![0u32; keys.len()];
    for (e, &k) in keys.iter().enumerate() {
        edges[cursor[k as usize]] = e as u32;
        cursor[k as usize] += 1;
    }
    Csr { row_ptr, edges }
}

/// Fused CSR aggregate: `out[group[e]] += val[e] · x[gather[e]]` over all
/// edges, `out` sized `rows × f`.  The gathered row `gather[e]` is read
/// at `x[gather[e] * x_stride + x_off ..][..f]`, so the same kernel runs
/// the forward aggregate (`group = dst`, `gather = src`, `x_stride = f`,
/// `x_off = 0`) and the backward one (`group = src`, `gather = dst`,
/// reading the aggregate half of a `dcat` row).
#[allow(clippy::too_many_arguments)]
pub fn aggregate(
    rows: usize,
    f: usize,
    group: &[i32],
    gather: &[i32],
    val: &[f32],
    x: &[f32],
    x_stride: usize,
    x_off: usize,
    kp: &Kernels,
) -> Vec<f32> {
    let _sp = crate::obs::span_with("kernel", "aggregate", || {
        vec![
            ("flops", 2.0 * group.len() as f64 * f as f64),
            ("bytes", 4.0 * (2.0 * group.len() as f64 * f as f64 + rows as f64 * f as f64)),
        ]
    });
    let work = group.len() * f + rows; // one axpy per edge
    if kp.naive || runs_sequential(kp.threads, rows, work) {
        // The scalar COO loop is bit-identical (module invariant) and
        // skips the CSR grouping a sequential run would never amortize.
        return naive_aggregate(rows, f, group, gather, val, x, x_stride, x_off);
    }
    let csr = group_edges(group, rows);
    let mut out = vec![0.0f32; rows * f];
    par_row_tiles(kp.threads, rows, f, work, &mut out, |r0, r1, tile| {
        for r in r0..r1 {
            let orow = &mut tile[(r - r0) * f..(r - r0 + 1) * f];
            for &e in &csr.edges[csr.row_ptr[r]..csr.row_ptr[r + 1]] {
                let e = e as usize;
                let v = val[e];
                if v == 0.0 {
                    continue; // padding edge
                }
                let s = gather[e] as usize;
                let xrow = &x[s * x_stride + x_off..s * x_stride + x_off + f];
                for (o, &xv) in orow.iter_mut().zip(xrow) {
                    *o += v * xv;
                }
            }
        }
    });
    out
}

/// Scalar oracle for [`aggregate`] — the pre-kernel COO loop, edges in
/// original order.
#[allow(clippy::too_many_arguments)]
pub fn naive_aggregate(
    rows: usize,
    f: usize,
    group: &[i32],
    gather: &[i32],
    val: &[f32],
    x: &[f32],
    x_stride: usize,
    x_off: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * f];
    for ((&g, &s), &v) in group.iter().zip(gather).zip(val) {
        if v == 0.0 {
            continue;
        }
        let (g, s) = (g as usize, s as usize);
        let xrow = &x[s * x_stride + x_off..s * x_stride + x_off + f];
        let orow = &mut out[g * f..(g + 1) * f];
        for j in 0..f {
            orow[j] += v * xrow[j];
        }
    }
    out
}

/// SAGE concat backward: `out[idx[i]] += x[i · x_stride ..][..f]` for
/// every row `i` (the self half of each `dcat` row scattered back to the
/// previous layer).  Row-parallel over `out`; per output row the
/// contributing `i` are visited ascending, matching the scalar loop.
pub fn scatter_add_rows(
    out: &mut [f32],
    rows: usize,
    f: usize,
    idx: &[i32],
    x: &[f32],
    x_stride: usize,
    kp: &Kernels,
) {
    let _sp = crate::obs::span_with("kernel", "scatter_add_rows", || {
        vec![
            ("flops", idx.len() as f64 * f as f64),
            ("bytes", 4.0 * 2.0 * idx.len() as f64 * f as f64),
        ]
    });
    let work = idx.len() * f + rows;
    if kp.naive || runs_sequential(kp.threads, rows, work) {
        for (i, &s) in idx.iter().enumerate() {
            let xrow = &x[i * x_stride..i * x_stride + f];
            let orow = &mut out[s as usize * f..(s as usize + 1) * f];
            for j in 0..f {
                orow[j] += xrow[j];
            }
        }
        return;
    }
    let csr = group_edges(idx, rows);
    par_row_tiles(kp.threads, rows, f, work, out, |r0, r1, tile| {
        for r in r0..r1 {
            let orow = &mut tile[(r - r0) * f..(r - r0 + 1) * f];
            for &i in &csr.edges[csr.row_ptr[r]..csr.row_ptr[r + 1]] {
                let xrow = &x[i as usize * x_stride..i as usize * x_stride + f];
                for (o, &xv) in orow.iter_mut().zip(xrow) {
                    *o += xv;
                }
            }
        }
    });
}

/// SAGE concat forward: `cat[i] = h[self_idx[i]] ‖ agg[i]` (`rows ×
/// 2·f_in`).  Pure copies — bit-exact at any thread count trivially.
pub fn gather_concat(
    h: &[f32],
    f_in: usize,
    self_idx: &[i32],
    agg: &[f32],
    rows: usize,
    kp: &Kernels,
) -> Vec<f32> {
    let _sp = crate::obs::span_with("kernel", "gather_concat", || {
        vec![("flops", 0.0), ("bytes", 4.0 * 4.0 * rows as f64 * f_in as f64)]
    });
    let mut cat = vec![0.0f32; rows * 2 * f_in];
    let threads = if kp.naive { 1 } else { kp.threads };
    par_row_tiles(threads, rows, 2 * f_in, rows * 2 * f_in, &mut cat, |r0, r1, tile| {
        for i in r0..r1 {
            let s = self_idx[i] as usize;
            let row = &mut tile[(i - r0) * 2 * f_in..(i - r0 + 1) * 2 * f_in];
            row[..f_in].copy_from_slice(&h[s * f_in..(s + 1) * f_in]);
            row[f_in..].copy_from_slice(&agg[i * f_in..(i + 1) * f_in]);
        }
    });
    cat
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Random COO triples with empty rows, repeated rows and padding
    /// (val == 0) edges.
    fn coo(
        rng: &mut Pcg64,
        edges: usize,
        rows_out: usize,
        rows_in: usize,
    ) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
        let group: Vec<i32> = (0..edges).map(|_| rng.index(rows_out) as i32).collect();
        let gather: Vec<i32> = (0..edges).map(|_| rng.index(rows_in) as i32).collect();
        let val: Vec<f32> = (0..edges)
            .map(|e| if e % 5 == 0 { 0.0 } else { rng.f32_range(-1.0, 1.0) })
            .collect();
        (group, gather, val)
    }

    #[test]
    fn aggregate_matches_naive_bitwise_across_threads() {
        let mut rng = Pcg64::seed_from_u64(21);
        // The last two cases clear MIN_PAR_WORK, so the CSR row-parallel
        // path (not the sequential naive fallback) is what's compared.
        for (edges, rows_out, rows_in, f) in [
            (0, 4, 4, 3),
            (1, 1, 1, 1),
            (37, 9, 13, 5),
            (400, 31, 17, 8),
            (4000, 3, 64, 33),
            (5000, 129, 257, 40),
        ] {
            let (group, gather, val) = coo(&mut rng, edges, rows_out, rows_in);
            let x: Vec<f32> = (0..rows_in * f).map(|_| rng.f32_range(-1.0, 1.0)).collect();
            let want = naive_aggregate(rows_out, f, &group, &gather, &val, &x, f, 0);
            for threads in [1, 2, 8] {
                let kp = Kernels::with_threads(threads);
                let got = aggregate(rows_out, f, &group, &gather, &val, &x, f, 0, &kp);
                assert_eq!(got, want, "edges={edges} rows={rows_out} f={f} t={threads}");
            }
        }
    }

    #[test]
    fn strided_offset_gather_matches_naive() {
        // The backward form: gather the second half of wider rows, with
        // enough work that the parallel CSR path runs.
        let mut rng = Pcg64::seed_from_u64(22);
        let (f, stride, off) = (24usize, 51usize, 27usize);
        let (group, gather, val) = coo(&mut rng, 3000, 10, 6);
        let x: Vec<f32> = (0..6 * stride).map(|_| rng.f32_range(-2.0, 2.0)).collect();
        let want = naive_aggregate(10, f, &group, &gather, &val, &x, stride, off);
        for threads in [1, 2, 8] {
            let kp = Kernels::with_threads(threads);
            assert_eq!(aggregate(10, f, &group, &gather, &val, &x, stride, off, &kp), want);
        }
    }

    #[test]
    fn group_edges_is_stable() {
        let keys = vec![2, 0, 2, 1, 2, 0];
        let csr = group_edges(&keys, 4);
        assert_eq!(csr.row_ptr, vec![0, 2, 3, 6, 6]);
        assert_eq!(csr.edges, vec![1, 5, 3, 0, 2, 4]); // COO order within rows
        assert_eq!(csr.rows(), 4);
    }

    #[test]
    fn scatter_add_rows_matches_sequential_loop() {
        // rows_in × f clears MIN_PAR_WORK so the grouped parallel path runs.
        let mut rng = Pcg64::seed_from_u64(23);
        let (rows_out, rows_in, f, stride) = (9usize, 4000usize, 20usize, 23usize);
        let idx: Vec<i32> = (0..rows_in).map(|_| rng.index(rows_out) as i32).collect();
        let x: Vec<f32> = (0..rows_in * stride).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let base: Vec<f32> = (0..rows_out * f).map(|_| rng.f32_range(-1.0, 1.0)).collect();

        let mut want = base.clone();
        scatter_add_rows(&mut want, rows_out, f, &idx, &x, stride, &Kernels::scalar_baseline());
        for threads in [1, 2, 8] {
            let mut got = base.clone();
            let kp = Kernels::with_threads(threads);
            scatter_add_rows(&mut got, rows_out, f, &idx, &x, stride, &kp);
            assert_eq!(got, want, "t={threads}");
        }
    }

    #[test]
    fn gather_concat_layout() {
        let h = vec![1.0, 2.0, 3.0, 4.0]; // two rows of f_in=2
        let agg = vec![9.0, 8.0, 7.0, 6.0];
        let cat = gather_concat(&h, 2, &[1, 0], &agg, 2, &Kernels::with_threads(2));
        assert_eq!(cat, vec![3.0, 4.0, 9.0, 8.0, 1.0, 2.0, 7.0, 6.0]);
    }
}
