//! Blocked, cache-tiled dense matmul kernels (plus the transposed
//! variants backprop needs).
//!
//! All matrices are row-major f32.  Every tiled kernel is bit-identical
//! to its `naive_*` oracle at every thread count: parallelism partitions
//! output rows only, and cache blocks over a reduction dimension are
//! visited in ascending order, so each output element sees the exact
//! accumulation sequence of the scalar loop (see the module invariant in
//! [`super`]).

use super::{par_row_tiles, Kernels};

/// Reduction-dimension cache block for [`matmul_bias`]: `K_BLOCK` rows of
/// `W` (`K_BLOCK × n` f32) stay hot while a tile of output rows streams
/// past.  Blocks are visited in ascending `k` order — order-preserving.
const K_BLOCK: usize = 128;

/// `Z[m×n] = A[m×k] @ W[k×n] + bias[n]` — the layer Update template.
///
/// Zero entries of `A` are skipped (ReLU-sparse activations, zero
/// padding); the bias is added after the full accumulation, matching the
/// scalar loop.
pub fn matmul_bias(
    a: &[f32],
    w: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
    kp: &Kernels,
) -> Vec<f32> {
    let _sp = crate::obs::span_with("kernel", "matmul_bias", || {
        let (mf, kf, nf) = (m as f64, k as f64, n as f64);
        vec![
            ("flops", 2.0 * mf * kf * nf),
            ("bytes", 4.0 * (mf * kf + kf * nf + nf + mf * nf)),
        ]
    });
    if kp.naive {
        return naive_matmul_bias(a, w, bias, m, k, n);
    }
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(bias.len(), n);
    let mut out = vec![0.0f32; m * n];
    par_row_tiles(kp.threads, m, n, 2 * m * k * n, &mut out, |r0, r1, tile| {
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + K_BLOCK).min(k);
            for i in r0..r1 {
                let arow = &a[i * k + k0..i * k + k1];
                let zrow = &mut tile[(i - r0) * n..(i - r0 + 1) * n];
                for (dk, &av) in arow.iter().enumerate() {
                    if av != 0.0 {
                        let wrow = &w[(k0 + dk) * n..(k0 + dk + 1) * n];
                        for (z, &wv) in zrow.iter_mut().zip(wrow) {
                            *z += av * wv;
                        }
                    }
                }
            }
            k0 = k1;
        }
        for i in r0..r1 {
            let zrow = &mut tile[(i - r0) * n..(i - r0 + 1) * n];
            for (z, &bv) in zrow.iter_mut().zip(bias) {
                *z += bv;
            }
        }
    });
    out
}

/// Scalar oracle for [`matmul_bias`] — the pre-kernel Update loop.
pub fn naive_matmul_bias(
    a: &[f32],
    w: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let zrow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av != 0.0 {
                let wrow = &w[kk * n..(kk + 1) * n];
                for j in 0..n {
                    zrow[j] += av * wrow[j];
                }
            }
        }
        for j in 0..n {
            zrow[j] += bias[j];
        }
    }
    out
}

/// `G[k×n] = A[m×k]ᵀ @ B[m×n]` — the weight gradient `dW = catᵀ @ dz`.
///
/// The reduction runs over the `m` batch rows in ascending order; threads
/// partition the `k` output rows.  Zero entries of `A` are skipped.
pub fn matmul_at_b(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, kp: &Kernels) -> Vec<f32> {
    let _sp = crate::obs::span_with("kernel", "matmul_at_b", || {
        vec![
            ("flops", 2.0 * m as f64 * k as f64 * n as f64),
            ("bytes", 4.0 * (m as f64 * k as f64 + m as f64 * n as f64 + k as f64 * n as f64)),
        ]
    });
    if kp.naive {
        return naive_matmul_at_b(a, b, m, k, n);
    }
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    let mut out = vec![0.0f32; k * n];
    par_row_tiles(kp.threads, k, n, 2 * m * k * n, &mut out, |r0, r1, tile| {
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let brow = &b[i * n..(i + 1) * n];
            for kk in r0..r1 {
                let av = arow[kk];
                if av != 0.0 {
                    let orow = &mut tile[(kk - r0) * n..(kk - r0 + 1) * n];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
        }
    });
    out
}

/// Scalar oracle for [`matmul_at_b`] — the pre-kernel `dW` loop.
pub fn naive_matmul_at_b(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; k * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av != 0.0 {
                let orow = &mut out[kk * n..(kk + 1) * n];
                for j in 0..n {
                    orow[j] += av * brow[j];
                }
            }
        }
    }
    out
}

/// `G[m×k] = A[m×n] @ B[k×n]ᵀ` — the input gradient `dcat = dz @ Wᵀ`.
///
/// `B` is transposed once up front so the inner loop is an order-
/// preserving axpy (the scalar oracle's dot product, reduction over `n`
/// ascending, but vectorizable); threads partition the `m` output rows.
/// No zero-skip here: the scalar dot loop never had one, and keeping the
/// exact same multiply/add sequence preserves oracle bit-identity even
/// for non-finite operands (`0 · ∞ = NaN` must surface identically).
pub fn matmul_a_bt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, kp: &Kernels) -> Vec<f32> {
    let _sp = crate::obs::span_with("kernel", "matmul_a_bt", || {
        vec![
            ("flops", 2.0 * m as f64 * k as f64 * n as f64),
            ("bytes", 4.0 * (m as f64 * n as f64 + k as f64 * n as f64 + m as f64 * k as f64)),
        ]
    });
    if kp.naive {
        return naive_matmul_a_bt(a, b, m, n, k);
    }
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    let mut bt = vec![0.0f32; n * k];
    for kk in 0..k {
        for j in 0..n {
            bt[j * k + kk] = b[kk * n + j];
        }
    }
    let mut out = vec![0.0f32; m * k];
    par_row_tiles(kp.threads, m, k, 2 * m * k * n, &mut out, |r0, r1, tile| {
        for i in r0..r1 {
            let arow = &a[i * n..(i + 1) * n];
            let orow = &mut tile[(i - r0) * k..(i - r0 + 1) * k];
            for (j, &av) in arow.iter().enumerate() {
                let btrow = &bt[j * k..(j + 1) * k];
                for (o, &bv) in orow.iter_mut().zip(btrow) {
                    *o += av * bv;
                }
            }
        }
    });
    out
}

/// Scalar oracle for [`matmul_a_bt`] — the pre-kernel `dcat` dot loop.
pub fn naive_matmul_a_bt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * k];
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        let orow = &mut out[i * k..(i + 1) * k];
        for kk in 0..k {
            let brow = &b[kk * n..(kk + 1) * n];
            let mut acc = 0.0f32;
            for j in 0..n {
                acc += arow[j] * brow[j];
            }
            orow[kk] = acc;
        }
    }
    out
}

/// `s[n] = Σ_i A[i][·]` — the bias gradient `db` (column sums, reduction
/// over rows ascending; threads partition columns).
pub fn col_sums(a: &[f32], m: usize, n: usize, kp: &Kernels) -> Vec<f32> {
    let _sp = crate::obs::span_with("kernel", "col_sums", || {
        vec![
            ("flops", m as f64 * n as f64),
            ("bytes", 4.0 * (m as f64 * n as f64 + n as f64)),
        ]
    });
    if kp.naive {
        return naive_col_sums(a, m, n);
    }
    debug_assert_eq!(a.len(), m * n);
    let mut out = vec![0.0f32; n];
    par_row_tiles(kp.threads, n, 1, m * n, &mut out, |c0, c1, tile| {
        for i in 0..m {
            let arow = &a[i * n..(i + 1) * n];
            for c in c0..c1 {
                tile[c - c0] += arow[c];
            }
        }
    });
    out
}

/// Scalar oracle for [`col_sums`].
pub fn naive_col_sums(a: &[f32], m: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    for i in 0..m {
        for j in 0..n {
            out[j] += a[i * n + j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Odd shapes: non-multiple-of-tile dims, single rows/cols, a dim of 1.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (1, 7, 3),
        (5, 8, 13),
        (33, 17, 9),
        (64, 1, 2),
        (7, 129, 5),
        (130, 300, 31),
        (2, 257, 1),
    ];

    fn randn(rng: &mut Pcg64, len: usize, zero_every: usize) -> Vec<f32> {
        (0..len)
            .map(|i| {
                if zero_every > 0 && i % zero_every == 0 {
                    0.0
                } else {
                    rng.f32_range(-1.5, 1.5)
                }
            })
            .collect()
    }

    #[test]
    fn matmul_bias_matches_naive_bitwise_across_threads() {
        let mut rng = Pcg64::seed_from_u64(7);
        for &(m, k, n) in SHAPES {
            let a = randn(&mut rng, m * k, 3); // zeros exercise the skip path
            let w = randn(&mut rng, k * n, 0);
            let bias = randn(&mut rng, n, 0);
            let want = naive_matmul_bias(&a, &w, &bias, m, k, n);
            for threads in [1, 2, 8] {
                let kp = Kernels::with_threads(threads);
                let got = matmul_bias(&a, &w, &bias, m, k, n, &kp);
                assert_eq!(got, want, "({m},{k},{n}) threads={threads}");
            }
        }
    }

    #[test]
    fn matmul_at_b_matches_naive_bitwise_across_threads() {
        let mut rng = Pcg64::seed_from_u64(8);
        for &(m, k, n) in SHAPES {
            let a = randn(&mut rng, m * k, 4);
            let b = randn(&mut rng, m * n, 0);
            let want = naive_matmul_at_b(&a, &b, m, k, n);
            for threads in [1, 2, 8] {
                let kp = Kernels::with_threads(threads);
                assert_eq!(matmul_at_b(&a, &b, m, k, n, &kp), want, "({m},{k},{n}) t={threads}");
            }
        }
    }

    #[test]
    fn matmul_a_bt_matches_naive_bitwise_across_threads() {
        let mut rng = Pcg64::seed_from_u64(9);
        for &(m, n, k) in SHAPES {
            let a = randn(&mut rng, m * n, 5);
            let b = randn(&mut rng, k * n, 0);
            let want = naive_matmul_a_bt(&a, &b, m, n, k);
            for threads in [1, 2, 8] {
                let kp = Kernels::with_threads(threads);
                assert_eq!(matmul_a_bt(&a, &b, m, n, k, &kp), want, "({m},{n},{k}) t={threads}");
            }
        }
    }

    #[test]
    fn col_sums_matches_naive_bitwise_across_threads() {
        let mut rng = Pcg64::seed_from_u64(10);
        for &(m, n, _) in SHAPES {
            let a = randn(&mut rng, m * n, 0);
            let want = naive_col_sums(&a, m, n);
            for threads in [1, 2, 8] {
                let kp = Kernels::with_threads(threads);
                assert_eq!(col_sums(&a, m, n, &kp), want, "({m},{n}) t={threads}");
            }
        }
    }

    #[test]
    fn forced_parallel_path_is_still_bitwise_equal() {
        // A shape big enough to clear MIN_PAR_WORK, so workers really spawn.
        let (m, k, n) = (256, 96, 64);
        let mut rng = Pcg64::seed_from_u64(11);
        let a = randn(&mut rng, m * k, 2);
        let w = randn(&mut rng, k * n, 0);
        let bias = randn(&mut rng, n, 0);
        let want = naive_matmul_bias(&a, &w, &bias, m, k, n);
        for threads in [2, 3, 8] {
            let kp = Kernels::with_threads(threads);
            assert_eq!(matmul_bias(&a, &w, &bias, m, k, n, &kp), want, "t={threads}");
        }
    }
}
