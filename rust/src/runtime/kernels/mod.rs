//! CPU compute kernels for the reference executor.
//!
//! The dense and sparse math that used to live inline in
//! [`super::reference`] as single-threaded scalar triple-loops, extracted
//! into first-class kernels (the CPU mirror of how GNNBuilder/GenGNN treat
//! the aggregate/update stages as tiled hardware kernels):
//!
//! * [`dense`] — blocked, cache-tiled dense matmul plus the transposed
//!   variants backprop needs (`AᵀB` for weight gradients, `ABᵀ` for input
//!   gradients) and column sums for bias gradients.
//! * [`sparse`] — the fused CSR aggregate kernel: the per-layer COO
//!   `src/dst/val` triples are grouped into CSR rows once per call, then
//!   SpMM runs row-parallel (forward `out[dst] += val · x[src]` and its
//!   transpose for backprop), plus the GraphSAGE gather/concat/scatter.
//! * [`elementwise`] — ReLU (forward + mask), masked softmax
//!   cross-entropy, and the SGD/Adam update rules.
//!
//! # Deterministic reduction order — the invariant
//!
//! Every kernel here produces **bit-identical** f32 results at every
//! thread count, and bit-identical to its scalar `naive_*` oracle.  The
//! rule that makes this hold: **parallelism and cache tiles only ever
//! partition output rows, never the reduction dimension.**  Each output
//! element is accumulated by exactly one thread, in the same order the
//! scalar loop uses (ascending `k` for matmuls, original edge order for
//! aggregates, ascending row index for column sums).  Cache blocking over
//! a reduction dimension is allowed only because blocks are visited in
//! ascending order, which preserves the per-element accumulation
//! sequence.  Combined with the pure-`(seed, k)` batch design from the
//! session layer, this keeps training loss curves invariant to
//! [`Kernels::threads`] — asserted by `rust/tests/kernel_parity.rs`.
//!
//! Zero operands are skipped only where the scalar loops always skipped
//! them (padding edges in the aggregates, zero activations in
//! `matmul_bias`/`matmul_at_b`); `matmul_a_bt` performs every
//! multiply/add like its dot-product oracle, so each tiled/naive pair
//! executes the identical f32 operation sequence — bit-identity holds
//! even for non-finite operands.
//!
//! Workers are scoped threads spawned per kernel call
//! ([`crate::util::threadpool::run_jobs`]); `MIN_PAR_WORK` gates small
//! problems onto the sequential path, and on bench-scale geometries the
//! spawn cost is ~1% of a step.  A persistent worker pool would shave
//! that residual and is the natural next perf increment.

pub mod dense;
pub mod elementwise;
pub mod sparse;

use crate::util::threadpool::{default_threads, par_map};

/// Kernel dispatch policy: how many worker threads row-parallel kernels
/// may use, and whether to bypass the tiled kernels entirely.
#[derive(Debug, Clone, Copy)]
pub struct Kernels {
    /// Worker threads for row-parallel dispatch (`1` = fully sequential).
    /// Results are bit-identical at every setting; this is purely a
    /// throughput knob.
    pub threads: usize,
    /// Run the scalar `naive_*` loops instead of the tiled kernels — the
    /// pre-kernel executor, kept as the measured perf baseline for
    /// `benches/hotpath.rs` and as the oracle for the property suite.
    pub naive: bool,
}

impl Default for Kernels {
    /// All available cores, tiled kernels.
    fn default() -> Kernels {
        Kernels { threads: default_threads(), naive: false }
    }
}

impl Kernels {
    pub fn with_threads(threads: usize) -> Kernels {
        Kernels { threads: threads.max(1), naive: false }
    }

    /// The scalar pre-kernel baseline (see [`Kernels::naive`]).
    pub fn scalar_baseline() -> Kernels {
        Kernels { threads: 1, naive: true }
    }
}

/// Don't spawn workers for kernels below this many scalar operations —
/// thread startup would dominate (the tiny test geometries stay on the
/// sequential path; results are identical either way).
const MIN_PAR_WORK: usize = 64 * 1024;

/// Whether a dispatch of `total_work` scalar ops over `rows` rows would
/// run on the caller's thread.  Kernels with a setup cost that only pays
/// off under parallelism (the CSR grouping in [`sparse`]) consult this to
/// fall back to their scalar oracle instead — bit-identical by the module
/// invariant, and no wasted work.
pub(crate) fn runs_sequential(threads: usize, rows: usize, total_work: usize) -> bool {
    threads.max(1).min(rows.max(1)) == 1 || total_work < MIN_PAR_WORK
}

/// Row-parallel dispatch: split `out` (`rows × width`, row-major) into
/// per-thread tiles of whole rows and run `body(row_start, row_end,
/// tile)` on each.  `total_work` is the kernel's scalar-op estimate,
/// used to skip thread dispatch for small problems.  Each output row is
/// written by exactly one worker, so any `body` that processes one row's
/// reduction sequentially keeps the deterministic-order invariant.
pub(crate) fn par_row_tiles<F>(
    threads: usize,
    rows: usize,
    width: usize,
    total_work: usize,
    out: &mut [f32],
    body: F,
) where
    F: Fn(usize, usize, &mut [f32]) + Send + Sync,
{
    debug_assert_eq!(out.len(), rows * width);
    if rows == 0 || width == 0 {
        return;
    }
    let threads = threads.max(1).min(rows);
    if runs_sequential(threads, rows, total_work) {
        body(0, rows, out);
        return;
    }
    let rows_per = rows.div_ceil(threads);
    let tiles: Vec<(usize, &mut [f32])> =
        out.chunks_mut(rows_per * width).enumerate().collect();
    par_map(threads, tiles, |(t, tile)| {
        let start = t * rows_per;
        body(start, start + tile.len() / width, tile);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_row_tiles_covers_every_row_once() {
        for threads in [1, 2, 3, 8] {
            for rows in [1usize, 2, 7, 64, 129] {
                let width = 3;
                let mut out = vec![0.0f32; rows * width];
                // Force the parallel path with an inflated work estimate.
                par_row_tiles(threads, rows, width, usize::MAX, &mut out, |r0, r1, tile| {
                    assert_eq!(tile.len(), (r1 - r0) * width);
                    for r in r0..r1 {
                        for c in 0..width {
                            tile[(r - r0) * width + c] += (r * width + c) as f32;
                        }
                    }
                });
                let want: Vec<f32> = (0..rows * width).map(|i| i as f32).collect();
                assert_eq!(out, want, "threads={threads} rows={rows}");
            }
        }
    }

    #[test]
    fn empty_output_is_a_noop() {
        let mut out: Vec<f32> = Vec::new();
        par_row_tiles(4, 0, 5, usize::MAX, &mut out, |_, _, _| panic!("no rows"));
    }

    #[test]
    fn small_work_stays_sequential() {
        // Can't observe threads directly; assert the body runs exactly once
        // over the whole range.
        let calls = std::sync::atomic::AtomicUsize::new(0);
        let mut out = vec![0.0f32; 8 * 2];
        par_row_tiles(8, 8, 2, 1, &mut out, |r0, r1, _| {
            calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            assert_eq!((r0, r1), (0, 8));
        });
        assert_eq!(calls.load(std::sync::atomic::Ordering::Relaxed), 1);
    }
}
