//! PJRT execution: load HLO-text artifacts, compile once, run per batch.
//!
//! Follows the reference wiring in /opt/xla-example/load_hlo: HLO *text*
//! (not serialized protos — jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects) is parsed into an `HloModuleProto`,
//! compiled on the CPU PJRT client, and executed with `Literal` inputs.
//! Python never runs on this path.

use std::path::Path;

use super::manifest::{ArtifactSpec, DType, Manifest};

/// Process-wide PJRT client + artifact registry.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
}

impl Runtime {
    /// Load the manifest in `artifacts_dir` and bring up the CPU client.
    pub fn load(artifacts_dir: &Path) -> anyhow::Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        log::info!(
            "PJRT up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime { client, manifest })
    }

    /// Compile one artifact (slow — once per process per artifact).
    pub fn compile(&self, name: &str) -> anyhow::Result<Executable> {
        let spec = self.manifest.get(name)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let t = crate::util::stats::Timer::start();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        log::info!("compiled {name} in {:.2}s", t.secs());
        Ok(Executable { exe, spec })
    }

    /// Compile the artifact for a (model, geometry, kind) role.
    pub fn compile_role(
        &self,
        model: crate::sampler::values::GnnModel,
        geometry: &str,
        kind: super::manifest::Kind,
    ) -> anyhow::Result<Executable> {
        let name = self.manifest.find(model, geometry, kind)?.name.clone();
        self.compile(&name)
    }
}

/// A compiled artifact, ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub spec: ArtifactSpec,
}

impl Executable {
    /// Execute with positional inputs; returns the decomposed output tuple.
    ///
    /// Validates input count and per-input element counts against the
    /// manifest ABI before touching PJRT (shape bugs surface as rust
    /// errors, not XLA crashes).
    pub fn run(&self, inputs: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            inputs.len() == self.spec.inputs.len(),
            "{}: got {} inputs, ABI wants {}",
            self.spec.name,
            inputs.len(),
            self.spec.inputs.len()
        );
        for (lit, spec) in inputs.iter().zip(&self.spec.inputs) {
            anyhow::ensure!(
                lit.element_count() == spec.elements(),
                "{}: input {} has {} elements, ABI wants {} {:?}",
                self.spec.name,
                spec.name,
                lit.element_count(),
                spec.elements(),
                spec.shape,
            );
        }
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("executing {}: {e:?}", self.spec.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of {}: {e:?}", self.spec.name))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("decomposing result of {}: {e:?}", self.spec.name))?;
        anyhow::ensure!(
            parts.len() == self.spec.outputs.len(),
            "{}: got {} outputs, manifest says {}",
            self.spec.name,
            parts.len(),
            self.spec.outputs.len()
        );
        Ok(parts)
    }
}

/// Build a `Literal` for one ABI slot from raw data.
pub fn literal_f32(spec: &TensorSpecRef, data: &[f32]) -> anyhow::Result<xla::Literal> {
    anyhow::ensure!(spec.dtype == DType::F32, "{} is not f32", spec.name);
    shape_literal(spec, xla::Literal::vec1(data))
}

pub fn literal_i32(spec: &TensorSpecRef, data: &[i32]) -> anyhow::Result<xla::Literal> {
    anyhow::ensure!(spec.dtype == DType::I32, "{} is not i32", spec.name);
    shape_literal(spec, xla::Literal::vec1(data))
}

pub fn literal_scalar_f32(value: f32) -> xla::Literal {
    xla::Literal::scalar(value)
}

type TensorSpecRef = super::manifest::TensorSpec;

fn shape_literal(spec: &TensorSpecRef, flat: xla::Literal) -> anyhow::Result<xla::Literal> {
    anyhow::ensure!(
        flat.element_count() == spec.elements(),
        "{}: {} elements for shape {:?}",
        spec.name,
        flat.element_count(),
        spec.shape
    );
    if spec.shape.len() <= 1 {
        return Ok(flat);
    }
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    flat.reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshaping {}: {e:?}", spec.name))
}
