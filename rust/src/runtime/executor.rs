//! Backend-agnostic runtime: artifact registry + executable instantiation.
//!
//! [`Runtime`] pairs a [`Manifest`] (which artifacts exist, with what ABI)
//! with a [`Backend`] (how to run them).  The default backend is the
//! pure-Rust [`reference`](super::reference) executor, which needs neither
//! compiled artifacts nor external libraries; building with
//! `--features xla` switches [`Runtime::load`] to the PJRT path that
//! executes the AOT HLO artifacts (`make artifacts`).

use std::path::Path;

use super::backend::{Backend, ExecOptions, Executor};
use super::manifest::{ArtifactSpec, Manifest};
use super::reference::ReferenceBackend;
use super::tensor::Tensor;

/// Process-wide backend + artifact registry.
pub struct Runtime {
    backend: Box<dyn Backend>,
    pub manifest: Manifest,
}

impl Runtime {
    /// The zero-dependency default: built-in artifact catalog executed by
    /// the pure-Rust reference backend.  Works on a clean machine.
    pub fn reference() -> Runtime {
        Runtime {
            backend: Box::new(ReferenceBackend::default()),
            manifest: Manifest::builtin(),
        }
    }

    /// Load the manifest in `artifacts_dir` and bring up the default
    /// backend for this build (reference; PJRT under `--features xla`).
    pub fn load(artifacts_dir: &Path) -> anyhow::Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Runtime { backend: default_backend()?, manifest })
    }

    /// `load(dir)` when a manifest exists there, else [`Runtime::reference`]
    /// — what the CLI and examples use so they run out of the box.
    pub fn auto(artifacts_dir: &Path) -> anyhow::Result<Runtime> {
        if artifacts_dir.join("manifest.json").exists() {
            Self::load(artifacts_dir)
        } else {
            // Surface the substitution: with the xla feature on, silently
            // ignoring a typo'd artifacts dir would mask which backend ran.
            if cfg!(feature = "xla") {
                log::warn!(
                    "no manifest.json in {artifacts_dir:?}; falling back to the \
                     built-in reference runtime (run `make artifacts`?)"
                );
            } else {
                log::info!(
                    "no manifest.json in {artifacts_dir:?}; using the built-in \
                     reference runtime"
                );
            }
            Ok(Self::reference())
        }
    }

    /// Pair an explicit manifest with an explicit backend.
    pub fn with_backend(manifest: Manifest, backend: Box<dyn Backend>) -> Runtime {
        Runtime { backend, manifest }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Instantiate one artifact (slow on compiled backends — once per
    /// process per artifact).
    pub fn compile(&self, name: &str) -> anyhow::Result<Executable> {
        self.compile_with(name, &ExecOptions::default())
    }

    /// [`compile`](Runtime::compile) with caller-requested execution
    /// options (e.g. the kernel thread count from
    /// `TrainConfig::compute_threads`).
    pub fn compile_with(&self, name: &str, opts: &ExecOptions) -> anyhow::Result<Executable> {
        let spec = self.manifest.get(name)?.clone();
        let t = crate::util::stats::Timer::start();
        let exec = self.backend.compile_opts(&self.manifest, &spec, opts)?;
        log::info!("[{}] compiled {name} in {:.2}s", self.backend.name(), t.secs());
        Ok(Executable { exec, spec })
    }

    /// Instantiate the artifact for a (model, geometry, kind) role.
    pub fn compile_role(
        &self,
        model: crate::sampler::values::GnnModel,
        geometry: &str,
        kind: super::manifest::Kind,
    ) -> anyhow::Result<Executable> {
        self.compile_role_with(model, geometry, kind, &ExecOptions::default())
    }

    /// [`compile_role`](Runtime::compile_role) with execution options.
    pub fn compile_role_with(
        &self,
        model: crate::sampler::values::GnnModel,
        geometry: &str,
        kind: super::manifest::Kind,
        opts: &ExecOptions,
    ) -> anyhow::Result<Executable> {
        let name = self.manifest.find(model, geometry, kind)?.name.clone();
        self.compile_with(&name, opts)
    }
}

/// An instantiated artifact, ready to execute on any backend.
pub struct Executable {
    exec: Box<dyn Executor>,
    pub spec: ArtifactSpec,
}

impl Executable {
    /// Execute with positional inputs; returns the decomposed output tuple.
    ///
    /// Validates input count, dtypes and per-input element counts against
    /// the manifest ABI before touching the backend (shape bugs surface as
    /// rust errors, not backend crashes), and the output count after.
    pub fn run(&self, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        anyhow::ensure!(
            inputs.len() == self.spec.inputs.len(),
            "{}: got {} inputs, ABI wants {}",
            self.spec.name,
            inputs.len(),
            self.spec.inputs.len()
        );
        for (t, spec) in inputs.iter().zip(&self.spec.inputs) {
            anyhow::ensure!(
                t.dtype() == spec.dtype,
                "{}: input {} is {:?}, ABI wants {:?}",
                self.spec.name,
                spec.name,
                t.dtype(),
                spec.dtype
            );
            anyhow::ensure!(
                t.shape() == spec.shape,
                "{}: input {} has shape {:?}, ABI wants {:?}",
                self.spec.name,
                spec.name,
                t.shape(),
                spec.shape,
            );
            anyhow::ensure!(
                t.element_count() == spec.elements(),
                "{}: input {} has {} elements, ABI wants {} {:?}",
                self.spec.name,
                spec.name,
                t.element_count(),
                spec.elements(),
                spec.shape,
            );
        }
        let outs = self.exec.run(inputs)?;
        anyhow::ensure!(
            outs.len() == self.spec.outputs.len(),
            "{}: got {} outputs, manifest says {}",
            self.spec.name,
            outs.len(),
            self.spec.outputs.len()
        );
        Ok(outs)
    }
}

fn default_backend() -> anyhow::Result<Box<dyn Backend>> {
    #[cfg(feature = "xla")]
    {
        Ok(Box::new(super::xla::XlaBackend::new()?))
    }
    #[cfg(not(feature = "xla"))]
    {
        Ok(Box::new(ReferenceBackend::default()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Kind;
    use crate::sampler::values::GnnModel;

    #[test]
    fn reference_runtime_compiles_every_builtin_role() {
        let rt = Runtime::reference();
        assert_eq!(rt.backend_name(), "reference");
        for geom in ["tiny", "ns_small", "ss_small", "ns_medium"] {
            for model in [GnnModel::Gcn, GnnModel::Sage, GnnModel::Gin] {
                for kind in [Kind::TrainStep, Kind::AdamStep, Kind::Forward] {
                    rt.compile_role(model, geom, kind).unwrap();
                }
            }
        }
    }

    #[test]
    fn run_validates_abi_before_execution() {
        let rt = Runtime::reference();
        let exe = rt.compile_role(GnnModel::Gcn, "tiny", Kind::Forward).unwrap();
        // Wrong arity.
        let err = exe.run(&[]).unwrap_err().to_string();
        assert!(err.contains("inputs"), "{err}");
        // Right arity, wrong dtype in slot 0 (x0 must be f32).
        let mut bad: Vec<Tensor> = exe
            .spec
            .inputs
            .iter()
            .map(|s| match s.dtype {
                crate::runtime::manifest::DType::F32 => {
                    Tensor::f32(s.shape.clone(), vec![0.0; s.elements()]).unwrap()
                }
                crate::runtime::manifest::DType::I32 => {
                    Tensor::i32(s.shape.clone(), vec![0; s.elements()]).unwrap()
                }
            })
            .collect();
        bad[0] = Tensor::i32(vec![96, 16], vec![0; 96 * 16]).unwrap();
        let err = exe.run(&bad).unwrap_err().to_string();
        assert!(err.contains("x0"), "{err}");
    }

    #[test]
    fn unknown_role_is_a_clean_error() {
        let rt = Runtime::reference();
        assert!(rt.compile_role(GnnModel::Gcn, "nope", Kind::Forward).is_err());
    }
}
