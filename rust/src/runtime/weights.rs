//! Model weight state, resident in rust between iterations.
//!
//! The paper keeps W^l in FPGA on-chip buffers across the batch; here the
//! weights live as host `Vec<f32>` tensors that are threaded through the
//! train-step executable (inputs `w1, b1, ...` -> outputs `w1, b1, ...`).

use crate::util::rng::Pcg64;

/// Flat [W1, b1, W2, b2, ...] parameter list.
#[derive(Debug, Clone)]
pub struct WeightState {
    /// (shape, row-major data) per tensor, ordered per the manifest ABI.
    pub tensors: Vec<(Vec<usize>, Vec<f32>)>,
}

impl WeightState {
    /// Glorot-uniform init matching `python/compile/model.init_params`
    /// semantics (exact values differ — jax PRNG vs PCG — but tests pin
    /// the distributional properties).
    pub fn init_glorot(weight_shapes: &[(Vec<usize>, Vec<usize>)], seed: u64) -> WeightState {
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut tensors = Vec::with_capacity(weight_shapes.len() * 2);
        for (wshape, bshape) in weight_shapes {
            let fan_in = wshape[0] as f32;
            let fan_out = wshape[1] as f32;
            let limit = (6.0 / (fan_in + fan_out)).sqrt();
            let count: usize = wshape.iter().product();
            let w: Vec<f32> = (0..count).map(|_| rng.f32_range(-limit, limit)).collect();
            tensors.push((wshape.clone(), w));
            let bcount: usize = bshape.iter().product();
            tensors.push((bshape.clone(), vec![0.0; bcount]));
        }
        WeightState { tensors }
    }

    pub fn num_params(&self) -> usize {
        self.tensors.iter().map(|(_, d)| d.len()).sum()
    }

    /// Replace all tensors from the train-step outputs (post-`loss` slots).
    pub fn update_from(&mut self, outputs: &[crate::runtime::Tensor]) -> anyhow::Result<()> {
        anyhow::ensure!(
            outputs.len() == self.tensors.len(),
            "weight update: {} outputs for {} tensors",
            outputs.len(),
            self.tensors.len()
        );
        for (t, (shape, data)) in outputs.iter().zip(self.tensors.iter_mut()) {
            let got = t.f32_data().map_err(|e| anyhow::anyhow!("weight readback: {e}"))?;
            anyhow::ensure!(
                got.len() == data.len(),
                "weight tensor {shape:?}: got {} elements",
                got.len()
            );
            data.copy_from_slice(got);
        }
        Ok(())
    }

    /// `Save_model()` (paper Table 1): write the weights to a binary
    /// checkpoint (magic, tensor count, per-tensor dims + f32 LE data).
    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        use std::io::Write;
        atomic_write(path, |w| {
            w.write_all(b"HPGNNW01")?;
            write_tensors(w, &self.tensors)
        })
    }

    /// Load a checkpoint written by [`save`]; validates magic and shapes.
    pub fn load(path: &std::path::Path) -> anyhow::Result<WeightState> {
        let bytes = std::fs::read(path)?;
        anyhow::ensure!(bytes.len() >= 16, "checkpoint too short");
        anyhow::ensure!(&bytes[..8] == b"HPGNNW01", "bad checkpoint magic");
        let mut off = 8usize;
        let tensors = read_tensors(&bytes, &mut off)?;
        anyhow::ensure!(off == bytes.len(), "trailing bytes in checkpoint");
        Ok(WeightState { tensors })
    }

    /// L2 norm over all parameters (training-progress diagnostic).
    pub fn l2_norm(&self) -> f64 {
        self.tensors
            .iter()
            .flat_map(|(_, d)| d.iter())
            .map(|&x| (x as f64) * (x as f64))
            // lint:allow(D3): log-line diagnostic only; never feeds the oracle-pinned output path
            .sum::<f64>()
            .sqrt()
    }
}

/// Adam optimizer state (first/second moments + step), threaded through
/// the `adam_step` artifact exactly like the weights.
#[derive(Debug, Clone)]
pub struct AdamState {
    /// m then v, each ordered like `WeightState::tensors`.
    pub m: Vec<(Vec<usize>, Vec<f32>)>,
    pub v: Vec<(Vec<usize>, Vec<f32>)>,
    pub step: f32,
}

impl AdamState {
    pub fn zeros(weight_shapes: &[(Vec<usize>, Vec<usize>)]) -> AdamState {
        let mut tensors = Vec::with_capacity(weight_shapes.len() * 2);
        for (wshape, bshape) in weight_shapes {
            tensors.push((wshape.clone(), vec![0.0; wshape.iter().product()]));
            tensors.push((bshape.clone(), vec![0.0; bshape.iter().product()]));
        }
        AdamState { m: tensors.clone(), v: tensors, step: 0.0 }
    }

    /// Consume the trailing outputs of an adam_step execution:
    /// `[m..., v..., step]`.
    pub fn update_from(&mut self, outputs: &[crate::runtime::Tensor]) -> anyhow::Result<()> {
        let n = self.m.len();
        anyhow::ensure!(
            outputs.len() == 2 * n + 1,
            "adam state update: {} outputs for {} tensors",
            outputs.len(),
            n
        );
        for (t, (_, data)) in outputs[..n].iter().zip(self.m.iter_mut()) {
            let got = t.f32_data().map_err(|e| anyhow::anyhow!("m readback: {e}"))?;
            anyhow::ensure!(got.len() == data.len(), "m element count");
            data.copy_from_slice(got);
        }
        for (t, (_, data)) in outputs[n..2 * n].iter().zip(self.v.iter_mut()) {
            let got = t.f32_data().map_err(|e| anyhow::anyhow!("v readback: {e}"))?;
            anyhow::ensure!(got.len() == data.len(), "v element count");
            data.copy_from_slice(got);
        }
        self.step = outputs[2 * n]
            .scalar()
            .map_err(|e| anyhow::anyhow!("step readback: {e}"))?;
        Ok(())
    }
}

/// Extract a [`WeightState`] from a checkpoint of either format: a
/// weights-only `HPGNNW01` file ([`WeightState::save`], the CLI's
/// `--save`) or a full `HPGNNS01` session snapshot ([`Checkpoint::save`]),
/// whose embedded weight tensors are returned and whose optimizer/RNG
/// state is ignored.  This is what inference-side consumers (the serving
/// subsystem, `hp-gnn serve --checkpoint`) load: serving doesn't care
/// which kind of artifact training produced.
pub fn load_weights_any(path: &std::path::Path) -> anyhow::Result<WeightState> {
    match checkpoint_magic(path)? {
        CheckpointKind::Weights => WeightState::load(path),
        CheckpointKind::Session => Ok(Checkpoint::load(path)?.weights),
    }
}

/// Which checkpoint format a file's magic declares.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CheckpointKind {
    /// `HPGNNW01` — weights only.
    Weights,
    /// `HPGNNS01` — full session snapshot.
    Session,
}

/// Read `path`'s 8-byte magic and classify the checkpoint format; errors
/// on anything that is neither.
pub fn checkpoint_magic(path: &std::path::Path) -> anyhow::Result<CheckpointKind> {
    let mut magic = [0u8; 8];
    {
        use std::io::Read;
        let mut f = std::fs::File::open(path)?;
        f.read_exact(&mut magic)
            .map_err(|_| anyhow::anyhow!("checkpoint too short"))?;
    }
    match &magic {
        b"HPGNNW01" => Ok(CheckpointKind::Weights),
        m if m == SESSION_MAGIC => Ok(CheckpointKind::Session),
        other => anyhow::bail!(
            "unrecognized checkpoint magic {:?} (want HPGNNW01 weights or an \
             HPGNNS01 session snapshot)",
            String::from_utf8_lossy(other)
        ),
    }
}

// ---- shared binary tensor-list encoding (HPGNNW01 / HPGNNS01) ----------

/// Write-then-rename: `write` fills a sibling `<path>.tmp`, which is
/// flushed, fsynced, and renamed over `path` — a crash or full disk
/// mid-save (the exact preemption checkpoints exist for) can therefore
/// never clobber the previous good checkpoint with a truncated one.
fn atomic_write(
    path: &std::path::Path,
    write: impl FnOnce(&mut std::io::BufWriter<std::fs::File>) -> anyhow::Result<()>,
) -> anyhow::Result<()> {
    use std::io::Write;
    let mut tmp_name = path.as_os_str().to_owned();
    tmp_name.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp_name);
    let result = (|| -> anyhow::Result<()> {
        let mut w = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        write(&mut w)?;
        w.flush()?;
        let file = w
            .into_inner()
            .map_err(|e| anyhow::anyhow!("checkpoint flush: {e}"))?;
        file.sync_all()?;
        Ok(())
    })();
    if let Err(e) = result {
        let _ = std::fs::remove_file(&tmp); // don't leave a truncated file
        return Err(e);
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Write a `(shape, data)` tensor list: u64 count, then per tensor u64
/// rank, u64 dims, f32 LE data.  The byte layout is exactly the HPGNNW01
/// body, reused by the HPGNNS01 session snapshot.
fn write_tensors<W: std::io::Write>(
    w: &mut W,
    tensors: &[(Vec<usize>, Vec<f32>)],
) -> anyhow::Result<()> {
    w.write_all(&(tensors.len() as u64).to_le_bytes())?;
    for (shape, data) in tensors {
        w.write_all(&(shape.len() as u64).to_le_bytes())?;
        for &d in shape {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        for &x in data {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    Ok(())
}

fn read_u64(bytes: &[u8], off: &mut usize) -> anyhow::Result<u64> {
    anyhow::ensure!(*off + 8 <= bytes.len(), "truncated checkpoint");
    let v = u64::from_le_bytes(bytes[*off..*off + 8].try_into().unwrap());
    *off += 8;
    Ok(v)
}

/// Inverse of [`write_tensors`]; validates plausibility bounds so corrupt
/// files fail loudly instead of allocating absurd buffers.
fn read_tensors(bytes: &[u8], off: &mut usize) -> anyhow::Result<Vec<(Vec<usize>, Vec<f32>)>> {
    let count = read_u64(bytes, off)? as usize;
    anyhow::ensure!(count <= 1024, "implausible tensor count {count}");
    let mut tensors = Vec::with_capacity(count);
    for _ in 0..count {
        let ndims = read_u64(bytes, off)? as usize;
        anyhow::ensure!(ndims <= 8, "implausible rank {ndims}");
        let mut shape = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            shape.push(read_u64(bytes, off)? as usize);
        }
        // Checked product: corrupt dims must error, not overflow.
        let elems: usize = shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .ok_or_else(|| anyhow::anyhow!("implausible tensor shape {shape:?}"))?;
        let nbytes = elems
            .checked_mul(4)
            .ok_or_else(|| anyhow::anyhow!("implausible tensor shape {shape:?}"))?;
        anyhow::ensure!(nbytes <= bytes.len() - *off, "truncated tensor data");
        let mut data = Vec::with_capacity(elems);
        for i in 0..elems {
            let s = *off + i * 4;
            data.push(f32::from_le_bytes(bytes[s..s + 4].try_into().unwrap()));
        }
        *off += elems * 4;
        tensors.push((shape, data));
    }
    Ok(tensors)
}

fn write_str<W: std::io::Write>(w: &mut W, s: &str) -> anyhow::Result<()> {
    // Mirror read_str's cap: a name save accepts must be loadable again.
    anyhow::ensure!(s.len() <= 256, "checkpoint string too long: {s:?}");
    w.write_all(&(s.len() as u64).to_le_bytes())?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn read_str(bytes: &[u8], off: &mut usize) -> anyhow::Result<String> {
    let len = read_u64(bytes, off)? as usize;
    anyhow::ensure!(len <= 256, "implausible string length {len}");
    anyhow::ensure!(*off + len <= bytes.len(), "truncated string");
    let s = std::str::from_utf8(&bytes[*off..*off + len])
        .map_err(|_| anyhow::anyhow!("non-utf8 string in checkpoint"))?
        .to_string();
    *off += len;
    Ok(s)
}

/// Full training-session snapshot — the `HPGNNS01` format, extending the
/// `HPGNNW01` weight checkpoint with everything a
/// [`TrainingSession`](crate::coordinator::TrainingSession) needs to
/// resume bit-exactly: the optimizer state, the step counter, the RNG
/// cursor (`seed`; batch `k` is a pure function of `(seed, k)`), and the
/// sampler/graph identity the stream was drawn from.
///
/// Layout: magic `HPGNNS01`, u64 step, u64 seed, length-prefixed model,
/// geometry, sampler, and graph strings, u8 Adam flag, the weight tensor
/// list, and — when the flag is set — the Adam `m`/`v` tensor lists plus
/// the f32 Adam step.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Global step the snapshot was taken at (== batches consumed).
    pub step: u64,
    /// Training seed; together with `step` this is the RNG cursor.
    pub seed: u64,
    /// `GnnModel::as_str()` of the training model.
    pub model: String,
    /// Artifact geometry name the weights are shaped for.
    pub geometry: String,
    /// `Sampler::name()` of the training sampler (parameters included) —
    /// a different sampler would replay a different batch stream.
    pub sampler: String,
    /// Training-graph fingerprint (name + |V| + |E|), same rationale.
    pub graph: String,
    pub weights: WeightState,
    pub adam: Option<AdamState>,
}

const SESSION_MAGIC: &[u8; 8] = b"HPGNNS01";

impl Checkpoint {
    /// Atomically write the snapshot (write-then-rename): an interrupted
    /// save leaves any previous snapshot at `path` intact.
    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        use std::io::Write;
        atomic_write(path, |w| {
            w.write_all(SESSION_MAGIC)?;
            w.write_all(&self.step.to_le_bytes())?;
            w.write_all(&self.seed.to_le_bytes())?;
            write_str(w, &self.model)?;
            write_str(w, &self.geometry)?;
            write_str(w, &self.sampler)?;
            write_str(w, &self.graph)?;
            w.write_all(&[self.adam.is_some() as u8])?;
            write_tensors(w, &self.weights.tensors)?;
            if let Some(adam) = &self.adam {
                write_tensors(w, &adam.m)?;
                write_tensors(w, &adam.v)?;
                w.write_all(&adam.step.to_le_bytes())?;
            }
            Ok(())
        })
    }

    /// Load and structurally validate a snapshot; semantic validation
    /// (model/geometry/shape agreement) happens at session resume.
    pub fn load(path: &std::path::Path) -> anyhow::Result<Checkpoint> {
        let bytes = std::fs::read(path)?;
        anyhow::ensure!(bytes.len() >= 8, "checkpoint too short");
        anyhow::ensure!(
            &bytes[..8] == SESSION_MAGIC,
            "bad session checkpoint magic (want HPGNNS01; HPGNNW01 files hold \
             weights only — load them with WeightState::load)"
        );
        let mut off = 8usize;
        let step = read_u64(&bytes, &mut off)?;
        let seed = read_u64(&bytes, &mut off)?;
        let model = read_str(&bytes, &mut off)?;
        let geometry = read_str(&bytes, &mut off)?;
        let sampler = read_str(&bytes, &mut off)?;
        let graph = read_str(&bytes, &mut off)?;
        anyhow::ensure!(off < bytes.len(), "truncated checkpoint");
        let has_adam = bytes[off];
        off += 1;
        anyhow::ensure!(has_adam <= 1, "corrupt Adam flag {has_adam}");
        let weights = WeightState { tensors: read_tensors(&bytes, &mut off)? };
        let adam = if has_adam == 1 {
            let m = read_tensors(&bytes, &mut off)?;
            let v = read_tensors(&bytes, &mut off)?;
            anyhow::ensure!(off + 4 <= bytes.len(), "truncated Adam step");
            let step = f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
            off += 4;
            anyhow::ensure!(
                m.len() == weights.tensors.len() && v.len() == weights.tensors.len(),
                "Adam moment count {}/{} does not match {} weight tensors",
                m.len(),
                v.len(),
                weights.tensors.len()
            );
            Some(AdamState { m, v, step })
        } else {
            None
        };
        anyhow::ensure!(off == bytes.len(), "trailing bytes in checkpoint");
        Ok(Checkpoint { step, seed, model, geometry, sampler, graph, weights, adam })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shapes() -> Vec<(Vec<usize>, Vec<usize>)> {
        vec![(vec![16, 8], vec![8]), (vec![8, 4], vec![4])]
    }

    #[test]
    fn init_sizes_and_order() {
        let w = WeightState::init_glorot(&shapes(), 1);
        assert_eq!(w.tensors.len(), 4);
        assert_eq!(w.tensors[0].0, vec![16, 8]);
        assert_eq!(w.tensors[0].1.len(), 128);
        assert_eq!(w.tensors[1].1, vec![0.0; 8]);
        assert_eq!(w.num_params(), 128 + 8 + 32 + 4);
    }

    #[test]
    fn glorot_bounds_and_spread() {
        let w = WeightState::init_glorot(&shapes(), 2);
        let limit = (6.0f32 / 24.0).sqrt();
        let data = &w.tensors[0].1;
        assert!(data.iter().all(|x| x.abs() <= limit));
        let spread = data.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        assert!(spread > limit * 0.8, "init suspiciously narrow: {spread}");
        // Non-degenerate: mean near zero.
        let mean: f32 = data.iter().sum::<f32>() / data.len() as f32;
        assert!(mean.abs() < limit * 0.2);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = WeightState::init_glorot(&shapes(), 3);
        let b = WeightState::init_glorot(&shapes(), 3);
        let c = WeightState::init_glorot(&shapes(), 4);
        assert_eq!(a.tensors[0].1, b.tensors[0].1);
        assert_ne!(a.tensors[0].1, c.tensors[0].1);
    }

    #[test]
    fn adam_state_zeros_match_weight_layout() {
        let st = AdamState::zeros(&shapes());
        assert_eq!(st.m.len(), 4);
        assert_eq!(st.m[0].1.len(), 128);
        assert!(st.m.iter().all(|(_, d)| d.iter().all(|&x| x == 0.0)));
        assert_eq!(st.step, 0.0);
    }

    #[test]
    fn l2_norm_positive() {
        let w = WeightState::init_glorot(&shapes(), 5);
        assert!(w.l2_norm() > 0.0);
    }

    #[test]
    fn checkpoint_round_trip() {
        let w = WeightState::init_glorot(&shapes(), 6);
        let dir = std::env::temp_dir().join(format!("hpgnn-ckpt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        w.save(&path).unwrap();
        let w2 = WeightState::load(&path).unwrap();
        assert_eq!(w.tensors, w2.tensors);
    }

    fn demo_checkpoint(adam: bool) -> Checkpoint {
        Checkpoint {
            step: 17,
            seed: 42,
            model: "gcn".into(),
            geometry: "tiny".into(),
            sampler: "NS(t=4, budgets=[5, 3])".into(),
            graph: "demo |V|=400 |E|=3200".into(),
            weights: WeightState::init_glorot(&shapes(), 8),
            adam: adam.then(|| AdamState::zeros(&shapes())),
        }
    }

    #[test]
    fn session_snapshot_round_trip() {
        let dir = std::env::temp_dir().join(format!("hpgnn-snap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for adam in [false, true] {
            let snap = demo_checkpoint(adam);
            let path = dir.join(format!("s-{adam}.ckpt"));
            snap.save(&path).unwrap();
            // Saving again over an existing snapshot is the periodic-
            // checkpoint path: must succeed and leave no temp file.
            snap.save(&path).unwrap();
            let mut tmp = path.as_os_str().to_owned();
            tmp.push(".tmp");
            assert!(!std::path::Path::new(&tmp).exists(), "temp file left behind");
            let back = Checkpoint::load(&path).unwrap();
            assert_eq!(back.step, 17);
            assert_eq!(back.seed, 42);
            assert_eq!(back.model, "gcn");
            assert_eq!(back.geometry, "tiny");
            assert_eq!(back.sampler, "NS(t=4, budgets=[5, 3])");
            assert_eq!(back.graph, "demo |V|=400 |E|=3200");
            assert_eq!(back.weights.tensors, snap.weights.tensors);
            assert_eq!(back.adam.is_some(), adam);
            if let (Some(a), Some(b)) = (&back.adam, &snap.adam) {
                assert_eq!(a.m, b.m);
                assert_eq!(a.v, b.v);
                assert_eq!(a.step, b.step);
            }
        }
    }

    #[test]
    fn session_snapshot_rejects_corruption() {
        let dir = std::env::temp_dir().join(format!("hpgnn-snap2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.ckpt");
        demo_checkpoint(true).save(&path).unwrap();
        // Truncation anywhere in the file fails loudly.
        let bytes = std::fs::read(&path).unwrap();
        for cut in [bytes.len() - 2, bytes.len() / 2, 9] {
            std::fs::write(&path, &bytes[..cut]).unwrap();
            assert!(Checkpoint::load(&path).is_err(), "accepted {cut}-byte prefix");
        }
        // Trailing garbage fails too.
        let mut padded = bytes.clone();
        padded.extend_from_slice(&[0, 1, 2]);
        std::fs::write(&path, &padded).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        // A weights-only HPGNNW01 file is not a session snapshot.
        let wpath = dir.join("w.bin");
        demo_checkpoint(false).weights.save(&wpath).unwrap();
        let err = Checkpoint::load(&wpath).unwrap_err().to_string();
        assert!(err.contains("HPGNNS01"), "{err}");
    }

    #[test]
    fn load_weights_any_round_trips_both_formats() {
        let dir = std::env::temp_dir().join(format!("hpgnn-any-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // HPGNNW01: weights-only checkpoint.
        let w = WeightState::init_glorot(&shapes(), 11);
        let wpath = dir.join("weights.bin");
        w.save(&wpath).unwrap();
        assert_eq!(load_weights_any(&wpath).unwrap().tensors, w.tensors);
        // HPGNNS01: full session snapshot — only the weights come back.
        let snap = demo_checkpoint(true);
        let spath = dir.join("session.ckpt");
        snap.save(&spath).unwrap();
        assert_eq!(load_weights_any(&spath).unwrap().tensors, snap.weights.tensors);
        // Neither magic: a clean error naming both accepted formats.
        let bad = dir.join("bad.bin");
        std::fs::write(&bad, b"NOTMAGIC and then some").unwrap();
        let err = load_weights_any(&bad).unwrap_err().to_string();
        assert!(err.contains("HPGNNW01") && err.contains("HPGNNS01"), "{err}");
        // Too short for any magic.
        std::fs::write(&bad, b"HP").unwrap();
        assert!(load_weights_any(&bad).is_err());
    }

    #[test]
    fn checkpoint_rejects_corruption() {
        let w = WeightState::init_glorot(&shapes(), 7);
        let dir = std::env::temp_dir().join(format!("hpgnn-ckpt2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        w.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.truncate(bytes.len() - 2);
        std::fs::write(&path, &bytes).unwrap();
        assert!(WeightState::load(&path).is_err());
        std::fs::write(&path, b"WRONGMAG rest").unwrap();
        assert!(WeightState::load(&path).is_err());
    }
}
