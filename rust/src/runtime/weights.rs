//! Model weight state, resident in rust between iterations.
//!
//! The paper keeps W^l in FPGA on-chip buffers across the batch; here the
//! weights live as host `Vec<f32>` tensors that are threaded through the
//! train-step executable (inputs `w1, b1, ...` -> outputs `w1, b1, ...`).

use crate::util::rng::Pcg64;

/// Flat [W1, b1, W2, b2, ...] parameter list.
#[derive(Debug, Clone)]
pub struct WeightState {
    /// (shape, row-major data) per tensor, ordered per the manifest ABI.
    pub tensors: Vec<(Vec<usize>, Vec<f32>)>,
}

impl WeightState {
    /// Glorot-uniform init matching `python/compile/model.init_params`
    /// semantics (exact values differ — jax PRNG vs PCG — but tests pin
    /// the distributional properties).
    pub fn init_glorot(weight_shapes: &[(Vec<usize>, Vec<usize>)], seed: u64) -> WeightState {
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut tensors = Vec::with_capacity(weight_shapes.len() * 2);
        for (wshape, bshape) in weight_shapes {
            let fan_in = wshape[0] as f32;
            let fan_out = wshape[1] as f32;
            let limit = (6.0 / (fan_in + fan_out)).sqrt();
            let count: usize = wshape.iter().product();
            let w: Vec<f32> = (0..count).map(|_| rng.f32_range(-limit, limit)).collect();
            tensors.push((wshape.clone(), w));
            let bcount: usize = bshape.iter().product();
            tensors.push((bshape.clone(), vec![0.0; bcount]));
        }
        WeightState { tensors }
    }

    pub fn num_params(&self) -> usize {
        self.tensors.iter().map(|(_, d)| d.len()).sum()
    }

    /// Replace all tensors from the train-step outputs (post-`loss` slots).
    pub fn update_from(&mut self, outputs: &[crate::runtime::Tensor]) -> anyhow::Result<()> {
        anyhow::ensure!(
            outputs.len() == self.tensors.len(),
            "weight update: {} outputs for {} tensors",
            outputs.len(),
            self.tensors.len()
        );
        for (t, (shape, data)) in outputs.iter().zip(self.tensors.iter_mut()) {
            let got = t.f32_data().map_err(|e| anyhow::anyhow!("weight readback: {e}"))?;
            anyhow::ensure!(
                got.len() == data.len(),
                "weight tensor {shape:?}: got {} elements",
                got.len()
            );
            data.copy_from_slice(got);
        }
        Ok(())
    }

    /// `Save_model()` (paper Table 1): write the weights to a binary
    /// checkpoint (magic, tensor count, per-tensor dims + f32 LE data).
    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        use std::io::Write;
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(b"HPGNNW01")?;
        w.write_all(&(self.tensors.len() as u64).to_le_bytes())?;
        for (shape, data) in &self.tensors {
            w.write_all(&(shape.len() as u64).to_le_bytes())?;
            for &d in shape {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            for &x in data {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    /// Load a checkpoint written by [`save`]; validates magic and shapes.
    pub fn load(path: &std::path::Path) -> anyhow::Result<WeightState> {
        let bytes = std::fs::read(path)?;
        anyhow::ensure!(bytes.len() >= 16, "checkpoint too short");
        anyhow::ensure!(&bytes[..8] == b"HPGNNW01", "bad checkpoint magic");
        let mut off = 8usize;
        let u64_at = |bytes: &[u8], off: &mut usize| -> anyhow::Result<u64> {
            anyhow::ensure!(*off + 8 <= bytes.len(), "truncated checkpoint");
            let v = u64::from_le_bytes(bytes[*off..*off + 8].try_into().unwrap());
            *off += 8;
            Ok(v)
        };
        let count = u64_at(&bytes, &mut off)? as usize;
        anyhow::ensure!(count <= 1024, "implausible tensor count {count}");
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let ndims = u64_at(&bytes, &mut off)? as usize;
            anyhow::ensure!(ndims <= 8, "implausible rank {ndims}");
            let mut shape = Vec::with_capacity(ndims);
            for _ in 0..ndims {
                shape.push(u64_at(&bytes, &mut off)? as usize);
            }
            let elems: usize = shape.iter().product();
            anyhow::ensure!(off + elems * 4 <= bytes.len(), "truncated tensor data");
            let mut data = Vec::with_capacity(elems);
            for i in 0..elems {
                let s = off + i * 4;
                data.push(f32::from_le_bytes(bytes[s..s + 4].try_into().unwrap()));
            }
            off += elems * 4;
            tensors.push((shape, data));
        }
        anyhow::ensure!(off == bytes.len(), "trailing bytes in checkpoint");
        Ok(WeightState { tensors })
    }

    /// L2 norm over all parameters (training-progress diagnostic).
    pub fn l2_norm(&self) -> f64 {
        self.tensors
            .iter()
            .flat_map(|(_, d)| d.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }
}

/// Adam optimizer state (first/second moments + step), threaded through
/// the `adam_step` artifact exactly like the weights.
#[derive(Debug, Clone)]
pub struct AdamState {
    /// m then v, each ordered like `WeightState::tensors`.
    pub m: Vec<(Vec<usize>, Vec<f32>)>,
    pub v: Vec<(Vec<usize>, Vec<f32>)>,
    pub step: f32,
}

impl AdamState {
    pub fn zeros(weight_shapes: &[(Vec<usize>, Vec<usize>)]) -> AdamState {
        let mut tensors = Vec::with_capacity(weight_shapes.len() * 2);
        for (wshape, bshape) in weight_shapes {
            tensors.push((wshape.clone(), vec![0.0; wshape.iter().product()]));
            tensors.push((bshape.clone(), vec![0.0; bshape.iter().product()]));
        }
        AdamState { m: tensors.clone(), v: tensors, step: 0.0 }
    }

    /// Consume the trailing outputs of an adam_step execution:
    /// `[m..., v..., step]`.
    pub fn update_from(&mut self, outputs: &[crate::runtime::Tensor]) -> anyhow::Result<()> {
        let n = self.m.len();
        anyhow::ensure!(
            outputs.len() == 2 * n + 1,
            "adam state update: {} outputs for {} tensors",
            outputs.len(),
            n
        );
        for (t, (_, data)) in outputs[..n].iter().zip(self.m.iter_mut()) {
            let got = t.f32_data().map_err(|e| anyhow::anyhow!("m readback: {e}"))?;
            anyhow::ensure!(got.len() == data.len(), "m element count");
            data.copy_from_slice(got);
        }
        for (t, (_, data)) in outputs[n..2 * n].iter().zip(self.v.iter_mut()) {
            let got = t.f32_data().map_err(|e| anyhow::anyhow!("v readback: {e}"))?;
            anyhow::ensure!(got.len() == data.len(), "v element count");
            data.copy_from_slice(got);
        }
        self.step = outputs[2 * n]
            .scalar()
            .map_err(|e| anyhow::anyhow!("step readback: {e}"))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shapes() -> Vec<(Vec<usize>, Vec<usize>)> {
        vec![(vec![16, 8], vec![8]), (vec![8, 4], vec![4])]
    }

    #[test]
    fn init_sizes_and_order() {
        let w = WeightState::init_glorot(&shapes(), 1);
        assert_eq!(w.tensors.len(), 4);
        assert_eq!(w.tensors[0].0, vec![16, 8]);
        assert_eq!(w.tensors[0].1.len(), 128);
        assert_eq!(w.tensors[1].1, vec![0.0; 8]);
        assert_eq!(w.num_params(), 128 + 8 + 32 + 4);
    }

    #[test]
    fn glorot_bounds_and_spread() {
        let w = WeightState::init_glorot(&shapes(), 2);
        let limit = (6.0f32 / 24.0).sqrt();
        let data = &w.tensors[0].1;
        assert!(data.iter().all(|x| x.abs() <= limit));
        let spread = data.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        assert!(spread > limit * 0.8, "init suspiciously narrow: {spread}");
        // Non-degenerate: mean near zero.
        let mean: f32 = data.iter().sum::<f32>() / data.len() as f32;
        assert!(mean.abs() < limit * 0.2);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = WeightState::init_glorot(&shapes(), 3);
        let b = WeightState::init_glorot(&shapes(), 3);
        let c = WeightState::init_glorot(&shapes(), 4);
        assert_eq!(a.tensors[0].1, b.tensors[0].1);
        assert_ne!(a.tensors[0].1, c.tensors[0].1);
    }

    #[test]
    fn adam_state_zeros_match_weight_layout() {
        let st = AdamState::zeros(&shapes());
        assert_eq!(st.m.len(), 4);
        assert_eq!(st.m[0].1.len(), 128);
        assert!(st.m.iter().all(|(_, d)| d.iter().all(|&x| x == 0.0)));
        assert_eq!(st.step, 0.0);
    }

    #[test]
    fn l2_norm_positive() {
        let w = WeightState::init_glorot(&shapes(), 5);
        assert!(w.l2_norm() > 0.0);
    }

    #[test]
    fn checkpoint_round_trip() {
        let w = WeightState::init_glorot(&shapes(), 6);
        let dir = std::env::temp_dir().join(format!("hpgnn-ckpt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        w.save(&path).unwrap();
        let w2 = WeightState::load(&path).unwrap();
        assert_eq!(w.tensors, w2.tensors);
    }

    #[test]
    fn checkpoint_rejects_corruption() {
        let w = WeightState::init_glorot(&shapes(), 7);
        let dir = std::env::temp_dir().join(format!("hpgnn-ckpt2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        w.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.truncate(bytes.len() - 2);
        std::fs::write(&path, &bytes).unwrap();
        assert!(WeightState::load(&path).is_err());
        std::fs::write(&path, b"WRONGMAG rest").unwrap();
        assert!(WeightState::load(&path).is_err());
    }
}
