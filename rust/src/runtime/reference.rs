//! The pure-Rust reference backend — the crate's default executor.
//!
//! A CPU implementation of the artifact semantics defined by
//! `python/compile/model.py` on top of the `python/compile/kernels/ref.py`
//! kernel oracles:
//!
//! * **forward** (Algorithm 1): per layer, `Aggregate` (`out[dst] +=
//!   val * x[src]`, zero-valued padding edges contribute nothing), the
//!   GraphSAGE `h_v || mean(neigh)` concat where applicable, then the
//!   fused `Update` (`act(a @ W + b)`, ReLU on hidden layers, identity on
//!   the output layer).
//! * **loss**: mean softmax cross-entropy over unmasked target vertices.
//! * **train_step / adam_step**: hand-derived backprop through the same
//!   two templates in reverse (exactly how the paper schedules BP on the
//!   accelerator), then an SGD or Adam (b1=0.9, b2=0.999, eps=1e-8)
//!   update with the learning rate as a runtime input.
//!
//! The math itself lives in [`super::kernels`]: blocked, cache-tiled
//! dense matmuls, the fused CSR aggregate (SpMM over the per-layer
//! `src/dst/val` triples), and the elementwise/update ops, all dispatched
//! row-parallel over [`crate::util::threadpool::par_map`].  Results are
//! deterministic and **bit-identical at every thread count** (kernels
//! never tile the reduction dimension — see the invariant in
//! [`super::kernels`]), so `cargo test` exercises real training end to
//! end on a clean machine and the loss curve is independent of the
//! [`ReferenceBackend::with_threads`] knob.  The PJRT path (`--features
//! xla`) runs the identical ABI from compiled HLO.

use super::backend::{Backend, ExecOptions, Executor};
use super::kernels::elementwise::AdamParams;
use super::kernels::{dense, elementwise, sparse, Kernels};
use super::manifest::{ArtifactSpec, Kind, Manifest, TensorSpec};
use super::tensor::Tensor;
use crate::sampler::values::GnnModel;

const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// The default backend: interprets artifact specs directly, executing
/// the math on the [`super::kernels`] layer.
///
/// The kernel thread count defaults to every available core
/// ([`crate::util::threadpool::default_threads`]); `with_threads(1)`
/// reproduces the fully sequential behavior bit-exactly (as does any
/// other thread count — the knob only changes throughput).
#[derive(Debug, Clone, Copy, Default)]
pub struct ReferenceBackend {
    policy: Kernels,
}

impl ReferenceBackend {
    /// Kernel-layer worker threads for every executor this backend
    /// compiles (clamped to ≥ 1).
    pub fn with_threads(threads: usize) -> ReferenceBackend {
        ReferenceBackend { policy: Kernels::with_threads(threads) }
    }

    /// The pre-kernel scalar executor: single-threaded naive loops,
    /// bit-identical semantics.  Kept as the measured perf baseline for
    /// `benches/hotpath.rs`.
    pub fn scalar_baseline() -> ReferenceBackend {
        ReferenceBackend { policy: Kernels::scalar_baseline() }
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn compile(
        &self,
        _manifest: &Manifest,
        spec: &ArtifactSpec,
    ) -> anyhow::Result<Box<dyn Executor>> {
        // No artifact files needed — the spec (geometry + ABI) is the
        // whole program.  Validate what run() will rely on once, here.
        spec.geometry.validate()?;
        let ll = spec.geometry.layers();
        anyhow::ensure!(
            spec.weight_shapes.len() == ll,
            "{}: {} weight shapes for {ll} layers",
            spec.name,
            spec.weight_shapes.len()
        );
        let sage = spec.model == GnnModel::Sage;
        for (l, (wshape, bshape)) in spec.weight_shapes.iter().enumerate() {
            let fin = spec.geometry.f[l] * if sage { 2 } else { 1 };
            let fout = spec.geometry.f[l + 1];
            anyhow::ensure!(
                wshape == &vec![fin, fout] && bshape == &vec![fout],
                "{}: layer {} weight shapes {wshape:?}/{bshape:?} do not match \
                 geometry dims ({fin}, {fout}) — the reference backend only \
                 executes the stock GCN/SAGE templates",
                spec.name,
                l + 1
            );
        }
        Ok(Box::new(ReferenceExecutor { spec: spec.clone(), kernels: self.policy }))
    }

    fn compile_opts(
        &self,
        manifest: &Manifest,
        spec: &ArtifactSpec,
        opts: &ExecOptions,
    ) -> anyhow::Result<Box<dyn Executor>> {
        let mut be = *self;
        if let Some(t) = opts.compute_threads {
            be.policy.threads = t.max(1);
        }
        be.compile(manifest, spec)
    }
}

/// One instantiated artifact, interpreting its spec per batch.
pub struct ReferenceExecutor {
    spec: ArtifactSpec,
    kernels: Kernels,
}

impl Executor for ReferenceExecutor {
    fn run(&self, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        let kp = &self.kernels;
        let batch = parse_inputs(&self.spec, inputs)?;
        let fwd = forward(&self.spec, &batch, kp)?;
        match self.spec.kind {
            Kind::Forward => {
                let geom = &self.spec.geometry;
                let nt = geom.b[geom.layers()];
                Ok(vec![Tensor::f32(vec![nt, geom.num_classes()], fwd.logits)?])
            }
            Kind::TrainStep => {
                let (loss, grads) = loss_and_grads(&self.spec, &batch, &fwd, kp)?;
                let mut out = Vec::with_capacity(1 + batch.params.len());
                out.push(Tensor::scalar_f32(loss));
                for (i, g) in grads.iter().enumerate() {
                    let new = elementwise::sgd_update(batch.params[i].data, g, batch.lr, kp);
                    out.push(Tensor::f32(batch.params[i].shape.clone(), new)?);
                }
                Ok(out)
            }
            Kind::AdamStep => {
                let (loss, grads) = loss_and_grads(&self.spec, &batch, &fwd, kp)?;
                let adam = batch
                    .adam
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("adam_step ABI missing m/v/step inputs"))?;
                let t = adam.step + 1.0;
                let ap = AdamParams {
                    lr: batch.lr,
                    b1: ADAM_B1,
                    b2: ADAM_B2,
                    eps: ADAM_EPS,
                    bias1: 1.0 - ADAM_B1.powf(t),
                    bias2: 1.0 - ADAM_B2.powf(t),
                };
                let n = batch.params.len();
                let mut new_p = Vec::with_capacity(n);
                let mut new_m = Vec::with_capacity(n);
                let mut new_v = Vec::with_capacity(n);
                for i in 0..n {
                    let (pi, mi, vi) = elementwise::adam_update(
                        batch.params[i].data,
                        &grads[i],
                        adam.m[i],
                        adam.v[i],
                        &ap,
                        kp,
                    );
                    new_p.push(pi);
                    new_m.push(mi);
                    new_v.push(vi);
                }
                let mut out = Vec::with_capacity(2 + 3 * n);
                out.push(Tensor::scalar_f32(loss));
                for (i, pi) in new_p.into_iter().enumerate() {
                    out.push(Tensor::f32(batch.params[i].shape.clone(), pi)?);
                }
                for (i, mi) in new_m.into_iter().enumerate() {
                    out.push(Tensor::f32(batch.params[i].shape.clone(), mi)?);
                }
                for (i, vi) in new_v.into_iter().enumerate() {
                    out.push(Tensor::f32(batch.params[i].shape.clone(), vi)?);
                }
                out.push(Tensor::scalar_f32(t));
                Ok(out)
            }
        }
    }
}

/// One parameter tensor (shape + borrowed data).
struct Param<'a> {
    shape: Vec<usize>,
    data: &'a [f32],
}

struct AdamView<'a> {
    m: Vec<&'a [f32]>,
    v: Vec<&'a [f32]>,
    step: f32,
}

/// The flat ABI input list, split back into named groups (the rust analog
/// of `model._unpack`).
struct BatchView<'a> {
    x0: &'a [f32],
    labels: &'a [i32],
    mask: &'a [f32],
    src: Vec<&'a [i32]>,
    dst: Vec<&'a [i32]>,
    val: Vec<&'a [f32]>,
    /// Per layer (SAGE only; empty for GCN-family artifacts).
    self_idx: Vec<&'a [i32]>,
    /// Flat `[W1, b1, ..., WL, bL]`.
    params: Vec<Param<'a>>,
    lr: f32,
    adam: Option<AdamView<'a>>,
}

struct Cursor<'a> {
    spec: &'a ArtifactSpec,
    inputs: &'a [Tensor],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn next(&mut self, name: &str) -> anyhow::Result<(&'a TensorSpec, &'a Tensor)> {
        let i = self.pos;
        let s = self.spec.inputs.get(i).ok_or_else(|| {
            anyhow::anyhow!("{}: ABI exhausted looking for {name}", self.spec.name)
        })?;
        anyhow::ensure!(
            s.name == name,
            "{}: ABI slot {i} is {:?}, expected {name:?}",
            self.spec.name,
            s.name
        );
        let t = self.inputs.get(i).ok_or_else(|| {
            anyhow::anyhow!("{}: missing input for ABI slot {name}", self.spec.name)
        })?;
        self.pos += 1;
        Ok((s, t))
    }

    fn next_f32(&mut self, name: &str) -> anyhow::Result<&'a [f32]> {
        let (_, t) = self.next(name)?;
        t.f32_data()
    }

    fn next_i32(&mut self, name: &str) -> anyhow::Result<&'a [i32]> {
        let (_, t) = self.next(name)?;
        t.i32_data()
    }
}

fn parse_inputs<'a>(spec: &'a ArtifactSpec, inputs: &'a [Tensor]) -> anyhow::Result<BatchView<'a>> {
    let geom = &spec.geometry;
    let ll = geom.layers();
    let mut cur = Cursor { spec, inputs, pos: 0 };

    let x0 = cur.next_f32("x0")?;
    let labels = cur.next_i32("labels")?;
    let mask = cur.next_f32("mask")?;
    let mut src = Vec::with_capacity(ll);
    let mut dst = Vec::with_capacity(ll);
    let mut val = Vec::with_capacity(ll);
    for l in 1..=ll {
        src.push(cur.next_i32(&format!("src{l}"))?);
        dst.push(cur.next_i32(&format!("dst{l}"))?);
        val.push(cur.next_f32(&format!("val{l}"))?);
    }
    let mut self_idx = Vec::new();
    if spec.model == GnnModel::Sage {
        for l in 1..=ll {
            self_idx.push(cur.next_i32(&format!("self_idx{l}"))?);
        }
    }
    let mut params = Vec::with_capacity(2 * ll);
    for l in 1..=ll {
        let (ws, wt) = cur.next(&format!("w{l}"))?;
        params.push(Param { shape: ws.shape.clone(), data: wt.f32_data()? });
        let (bs, bt) = cur.next(&format!("b{l}"))?;
        params.push(Param { shape: bs.shape.clone(), data: bt.f32_data()? });
    }
    let lr = match spec.kind {
        Kind::TrainStep | Kind::AdamStep => {
            let data = cur.next_f32("lr")?;
            anyhow::ensure!(data.len() == 1, "lr must be a scalar");
            data[0]
        }
        Kind::Forward => 0.0,
    };
    let adam = if spec.kind == Kind::AdamStep {
        let mut m = Vec::with_capacity(2 * ll);
        for l in 1..=ll {
            m.push(cur.next_f32(&format!("m_w{l}"))?);
            m.push(cur.next_f32(&format!("m_b{l}"))?);
        }
        let mut v = Vec::with_capacity(2 * ll);
        for l in 1..=ll {
            v.push(cur.next_f32(&format!("v_w{l}"))?);
            v.push(cur.next_f32(&format!("v_b{l}"))?);
        }
        let step = cur.next_f32("step")?;
        anyhow::ensure!(step.len() == 1, "step must be a scalar");
        Some(AdamView { m, v, step: step[0] })
    } else {
        None
    };
    anyhow::ensure!(
        cur.pos == spec.inputs.len(),
        "{}: {} unconsumed ABI inputs",
        spec.name,
        spec.inputs.len() - cur.pos
    );

    // Index bounds — padding points at row 0, which is always valid.
    for l in 0..ll {
        let (b_in, b_out) = (geom.b[l] as i32, geom.b[l + 1] as i32);
        anyhow::ensure!(
            src[l].iter().all(|&s| (0..b_in).contains(&s)),
            "layer {}: src index out of range 0..{b_in}",
            l + 1
        );
        anyhow::ensure!(
            dst[l].iter().all(|&d| (0..b_out).contains(&d)),
            "layer {}: dst index out of range 0..{b_out}",
            l + 1
        );
        if let Some(si) = self_idx.get(l) {
            anyhow::ensure!(
                si.iter().all(|&s| (0..b_in).contains(&s)),
                "layer {}: self_idx out of range 0..{b_in}",
                l + 1
            );
        }
    }
    let classes = geom.num_classes() as i32;
    anyhow::ensure!(
        labels.iter().all(|&y| (0..classes).contains(&y)),
        "labels out of range 0..{classes}"
    );

    Ok(BatchView { x0, labels, mask, src, dst, val, self_idx, params, lr, adam })
}

/// Per-layer forward cache: what the backward pass needs.
struct LayerCache {
    /// Update input (`[self || agg]` for SAGE, `agg` for GCN), rows ×
    /// cat_cols row-major.
    cat: Vec<f32>,
    cat_cols: usize,
    /// Pre-activation `cat @ W + b`, rows × f_out.
    z: Vec<f32>,
}

struct ForwardPass {
    layers: Vec<LayerCache>,
    /// Output-layer activations (`b[L] × classes`).
    logits: Vec<f32>,
}

fn forward(spec: &ArtifactSpec, batch: &BatchView, kp: &Kernels) -> anyhow::Result<ForwardPass> {
    let geom = &spec.geometry;
    let ll = geom.layers();
    let sage = spec.model == GnnModel::Sage;
    let mut layers = Vec::with_capacity(ll);
    let mut h: Vec<f32> = batch.x0.to_vec();
    for l in 0..ll {
        let f_in = geom.f[l];
        let f_out = geom.f[l + 1];
        let rows = geom.b[l + 1];

        // Aggregate: out[dst] += val * h[src]  (ref.py aggregate_ref) —
        // the fused CSR SpMM kernel, grouped by destination row.
        let agg = sparse::aggregate(
            rows,
            f_in,
            batch.dst[l],
            batch.src[l],
            batch.val[l],
            &h,
            f_in,
            0,
            kp,
        );

        // SAGE concat: h_v || mean-aggregate (ref.py sage_layer_ref).
        let (cat, cat_cols) = if sage {
            (sparse::gather_concat(&h, f_in, batch.self_idx[l], &agg, rows, kp), 2 * f_in)
        } else {
            (agg, f_in)
        };

        // Update: z = cat @ W + b, then ReLU on hidden layers.
        let w = batch.params[2 * l].data;
        let b = batch.params[2 * l + 1].data;
        let z = dense::matmul_bias(&cat, w, b, rows, cat_cols, f_out, kp);
        let relu = l + 1 < ll;
        h = if relu { elementwise::relu(&z, kp) } else { z.clone() };
        layers.push(LayerCache { cat, cat_cols, z });
    }
    Ok(ForwardPass { layers, logits: h })
}

/// Backprop through the layer stack; returns `(loss, [dW1, db1, ...])`.
fn loss_and_grads(
    spec: &ArtifactSpec,
    batch: &BatchView,
    fwd: &ForwardPass,
    kp: &Kernels,
) -> anyhow::Result<(f32, Vec<Vec<f32>>)> {
    let geom = &spec.geometry;
    let ll = geom.layers();
    let sage = spec.model == GnnModel::Sage;
    let (loss, dlogits) =
        elementwise::masked_xent(&fwd.logits, batch.labels, batch.mask, geom.num_classes(), kp);

    let mut grads: Vec<Vec<f32>> = vec![Vec::new(); batch.params.len()];
    let mut dh = dlogits; // gradient w.r.t. layer l's output, rows b[l+1]
    for l in (0..ll).rev() {
        let cache = &fwd.layers[l];
        let rows = geom.b[l + 1];
        let f_in = geom.f[l];
        let f_out = geom.f[l + 1];
        let ck = cache.cat_cols;

        // Through the activation: hidden layers are ReLU, output is id.
        let mut dz = dh;
        if l + 1 < ll {
            elementwise::relu_mask_inplace(&mut dz, &cache.z, kp);
        }

        // dW = cat^T @ dz, db = column sums of dz.
        let w = batch.params[2 * l].data;
        grads[2 * l] = dense::matmul_at_b(&cache.cat, &dz, rows, ck, f_out, kp);
        grads[2 * l + 1] = dense::col_sums(&dz, rows, f_out, kp);

        if l == 0 {
            break; // no gradient consumer below the input features
        }

        // dcat = dz @ W^T, then scatter back through concat + aggregate.
        let dcat = dense::matmul_a_bt(&dz, w, rows, f_out, ck, kp);

        // Aggregate backward: dprev[src] += val * dagg[dst] — the same
        // fused CSR kernel, grouped by source row this time.
        let dagg_off = if sage { f_in } else { 0 };
        let mut dprev = sparse::aggregate(
            geom.b[l],
            f_in,
            batch.src[l],
            batch.dst[l],
            batch.val[l],
            &dcat,
            ck,
            dagg_off,
            kp,
        );
        // Concat backward (SAGE): dprev[self_idx[i]] += dself[i].
        if sage {
            sparse::scatter_add_rows(&mut dprev, geom.b[l], f_in, batch.self_idx[l], &dcat, ck, kp);
        }
        dh = dprev;
    }
    Ok((loss, grads))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::pad::PaddedBatch;
    use crate::layout::Geometry;
    use crate::runtime::inputs::build_inputs_opt;
    use crate::runtime::manifest::spec_for;
    use crate::runtime::weights::{AdamState, WeightState};

    fn micro_geom() -> Geometry {
        Geometry {
            name: "micro".into(),
            b: vec![4, 3, 2],
            e: vec![5, 4],
            f: vec![3, 2, 2],
        }
    }

    /// A fully-populated micro batch with one padding edge per layer and
    /// one padding target.
    fn micro_batch(geom: &Geometry) -> PaddedBatch {
        PaddedBatch {
            geom: geom.clone(),
            // Layer 1: 4 sources -> 3 destinations (last edge is padding).
            src: vec![vec![0, 1, 2, 3, 0], vec![0, 1, 2, 0]],
            dst: vec![vec![0, 0, 1, 2, 0], vec![0, 1, 0, 0]],
            val: vec![
                vec![1.0, 0.5, 2.0, 1.5, 0.0],
                vec![1.0, 0.25, 0.75, 0.0],
            ],
            self_idx: vec![vec![0, 1, 2], vec![0, 1]],
            labels: vec![1, 0],
            mask: vec![1.0, 1.0],
            real_b: vec![4, 3, 2],
            real_e: vec![4, 3],
            vertices_traversed: 9,
        }
    }

    fn features(geom: &Geometry) -> Vec<f32> {
        (0..geom.b[0] * geom.f[0])
            .map(|i| ((i as f32) * 0.37).sin() * 0.5)
            .collect()
    }

    fn run_spec(
        model: GnnModel,
        kind: Kind,
        weights: &WeightState,
        adam: Option<&AdamState>,
        lr: f32,
    ) -> Vec<Tensor> {
        let geom = micro_geom();
        let spec = spec_for(model, kind, &geom);
        let exe = ReferenceBackend::default()
            .compile(&Manifest::builtin(), &spec)
            .unwrap();
        let batch = micro_batch(&geom);
        let lits =
            build_inputs_opt(&spec, &batch, &features(&geom), weights, lr, adam).unwrap();
        exe.run(&lits).unwrap()
    }

    /// Dense re-implementation of the GCN forward path (adjacency-matrix
    /// formulation — a different code path than the gather/scatter
    /// executor) for parity checking.
    fn dense_gcn_logits(weights: &WeightState) -> Vec<f32> {
        let geom = micro_geom();
        let batch = micro_batch(&geom);
        let x0 = features(&geom);
        let mut h = x0;
        let mut f_in = geom.f[0];
        for l in 0..2 {
            let rows = geom.b[l + 1];
            // A[d][s] = sum of vals on (s, d) edges.
            let mut a = vec![0.0f32; rows * geom.b[l]];
            for ((&s, &d), &v) in batch.src[l].iter().zip(&batch.dst[l]).zip(&batch.val[l]) {
                a[d as usize * geom.b[l] + s as usize] += v;
            }
            let f_out = geom.f[l + 1];
            let w = &weights.tensors[2 * l].1;
            let b = &weights.tensors[2 * l + 1].1;
            let mut out = vec![0.0f32; rows * f_out];
            for i in 0..rows {
                // agg = A[i] @ h, then z = agg @ W + b.
                let mut agg = vec![0.0f32; f_in];
                for s in 0..geom.b[l] {
                    for j in 0..f_in {
                        agg[j] += a[i * geom.b[l] + s] * h[s * f_in + j];
                    }
                }
                for j in 0..f_out {
                    let mut z = b[j];
                    for k in 0..f_in {
                        z += agg[k] * w[k * f_out + j];
                    }
                    out[i * f_out + j] = if l == 0 { z.max(0.0) } else { z };
                }
            }
            h = out;
            f_in = f_out;
        }
        h
    }

    #[test]
    fn forward_matches_dense_reference() {
        let geom = micro_geom();
        let spec = spec_for(GnnModel::Gcn, Kind::Forward, &geom);
        let weights = WeightState::init_glorot(&spec.weight_shapes, 42);
        let outs = run_spec(GnnModel::Gcn, Kind::Forward, &weights, None, 0.0);
        assert_eq!(outs.len(), 1);
        let logits = outs[0].f32_data().unwrap();
        let dense = dense_gcn_logits(&weights);
        assert_eq!(logits.len(), dense.len());
        for (a, b) in logits.iter().zip(&dense) {
            assert!((a - b).abs() < 1e-5, "gather/scatter {a} vs dense {b}");
        }
    }

    /// Hand-picked weights whose hidden pre-activations sit ≥ 0.13 from
    /// the ReLU kink on the micro batch (verified numerically), so the
    /// finite-difference probes below never cross an activation boundary.
    fn fixed_weights(model: GnnModel) -> WeightState {
        let (w1, w2) = if model == GnnModel::Sage {
            (
                vec![0.6, -0.4, 0.5, 0.3, -0.2, 0.7, 0.4, -0.6, -0.5, 0.2, 0.3, 0.5],
                vec![0.8, -0.5, -0.3, 0.6, 0.45, -0.25, -0.35, 0.55],
            )
        } else {
            (vec![0.6, -0.4, 0.5, 0.3, -0.2, 0.7], vec![0.8, -0.5, -0.3, 0.6])
        };
        let (r1, r2) = if model == GnnModel::Sage { (6, 4) } else { (3, 2) };
        WeightState {
            tensors: vec![
                (vec![r1, 2], w1),
                (vec![2], vec![0.3, -0.2]),
                (vec![r2, 2], w2),
                (vec![2], vec![0.1, -0.1]),
            ],
        }
    }

    #[test]
    fn train_step_gradients_match_finite_differences() {
        for model in [GnnModel::Gcn, GnnModel::Sage] {
            let weights = fixed_weights(model);
            let lr = 1.0;
            let outs = run_spec(model, Kind::TrainStep, &weights, None, lr);
            let loss0 = outs[0].scalar().unwrap();
            assert!(loss0.is_finite());
            // Cross-checked against an independent python transcription of
            // model.py on the same batch.
            let want = if model == GnnModel::Sage { 0.64887 } else { 0.82056 };
            assert!(
                (loss0 - want).abs() < 1e-3,
                "{model:?} loss {loss0} != python reference {want}"
            );

            // Extract the executor's gradient from the SGD update.
            let grad_of = |t: usize, i: usize| -> f32 {
                let new = outs[1 + t].f32_data().unwrap();
                (weights.tensors[t].1[i] - new[i]) / lr
            };
            // Central finite differences through the loss output.
            let eps = 5e-3f32;
            for (t, i) in [(0usize, 0usize), (0, 3), (1, 1), (2, 2), (3, 0)] {
                let mut up = weights.clone();
                up.tensors[t].1[i] += eps;
                let mut dn = weights.clone();
                dn.tensors[t].1[i] -= eps;
                let lu = run_spec(model, Kind::TrainStep, &up, None, lr)[0]
                    .scalar()
                    .unwrap();
                let ld = run_spec(model, Kind::TrainStep, &dn, None, lr)[0]
                    .scalar()
                    .unwrap();
                let fd = (lu - ld) / (2.0 * eps);
                let an = grad_of(t, i);
                assert!(
                    (fd - an).abs() <= 0.02 * an.abs().max(fd.abs()) + 2e-3,
                    "{model:?} param {t}[{i}]: analytic {an} vs fd {fd}"
                );
            }
        }
    }

    #[test]
    fn masked_rows_do_not_affect_loss_or_grads() {
        let geom = micro_geom();
        let spec = spec_for(GnnModel::Gcn, Kind::TrainStep, &geom);
        let weights = WeightState::init_glorot(&spec.weight_shapes, 9);
        let exe = ReferenceBackend::default()
            .compile(&Manifest::builtin(), &spec)
            .unwrap();
        let batch = micro_batch(&geom);
        let mut masked = batch.clone();
        masked.mask = vec![1.0, 0.0];
        masked.labels = vec![1, 0];
        let mut masked_wild = masked.clone();
        masked_wild.labels = vec![1, 1]; // masked label may be anything

        let run = |b: &PaddedBatch| {
            let lits =
                build_inputs_opt(&spec, b, &features(&geom), &weights, 0.1, None).unwrap();
            exe.run(&lits).unwrap()
        };
        let a = run(&masked);
        let b = run(&masked_wild);
        assert_eq!(a[0].scalar().unwrap(), b[0].scalar().unwrap());
        for t in 1..a.len() {
            assert_eq!(a[t], b[t], "masked target leaked into param {t}");
        }
    }

    #[test]
    fn adam_step_matches_manual_formula() {
        let geom = micro_geom();
        let spec_sgd = spec_for(GnnModel::Gcn, Kind::TrainStep, &geom);
        let weights = WeightState::init_glorot(&spec_sgd.weight_shapes, 11);
        let lr = 0.05f32;

        // Recover the gradient from an SGD step with lr=1.
        let sgd = run_spec(GnnModel::Gcn, Kind::TrainStep, &weights, None, 1.0);
        let adam0 = AdamState::zeros(&spec_sgd.weight_shapes);
        let adam = run_spec(GnnModel::Gcn, Kind::AdamStep, &weights, Some(&adam0), lr);

        // Same batch, same weights -> identical loss.
        assert_eq!(sgd[0].scalar().unwrap(), adam[0].scalar().unwrap());
        let n = weights.tensors.len();
        assert_eq!(adam.len(), 2 + 3 * n);
        assert_eq!(adam[1 + 3 * n].scalar().unwrap(), 1.0, "step counter");

        for t in 0..n {
            let g: Vec<f32> = weights.tensors[t]
                .1
                .iter()
                .zip(sgd[1 + t].f32_data().unwrap())
                .map(|(&p, &np)| p - np)
                .collect();
            let new_p = adam[1 + t].f32_data().unwrap();
            let new_m = adam[1 + n + t].f32_data().unwrap();
            let new_v = adam[1 + 2 * n + t].f32_data().unwrap();
            for i in 0..g.len() {
                let m = (1.0 - ADAM_B1) * g[i];
                let v = (1.0 - ADAM_B2) * g[i] * g[i];
                assert!((new_m[i] - m).abs() < 1e-6);
                assert!((new_v[i] - v).abs() < 1e-7);
                let mhat = m / (1.0 - ADAM_B1);
                let vhat = v / (1.0 - ADAM_B2);
                let want = weights.tensors[t].1[i] - lr * mhat / (vhat.sqrt() + ADAM_EPS);
                assert!(
                    (new_p[i] - want).abs() < 1e-5,
                    "param {t}[{i}]: {} vs {want}",
                    new_p[i]
                );
            }
        }
    }

    #[test]
    fn execution_is_deterministic() {
        let geom = micro_geom();
        let spec = spec_for(GnnModel::Sage, Kind::TrainStep, &geom);
        let weights = WeightState::init_glorot(&spec.weight_shapes, 13);
        let a = run_spec(GnnModel::Sage, Kind::TrainStep, &weights, None, 0.1);
        let b = run_spec(GnnModel::Sage, Kind::TrainStep, &weights, None, 0.1);
        assert_eq!(a, b);
    }

    #[test]
    fn compile_rejects_mismatched_weight_shapes() {
        let geom = micro_geom();
        let mut spec = spec_for(GnnModel::Gcn, Kind::TrainStep, &geom);
        spec.weight_shapes[0].0 = vec![5, 2];
        assert!(ReferenceBackend::default().compile(&Manifest::builtin(), &spec).is_err());
    }
}
