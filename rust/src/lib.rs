//! # HP-GNN — high-throughput GNN training on a CPU-"FPGA" platform
//!
//! Reproduction of *HP-GNN: Generating High Throughput GNN Training
//! Implementation on CPU-FPGA Heterogeneous Platform* (Lin, Zhang,
//! Prasanna — FPGA '22) as a three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the host program: samplers, data layout
//!   (RMT/RRA), DSE engine, training coordinator, plus a cycle-level
//!   simulator of the paper's FPGA accelerator (we have no Alveo U250).
//! * **Layer 2 (python/compile, build time)** — the GNN fwd/bwd compute
//!   graph in JAX, AOT-lowered to HLO text (`make artifacts`).
//! * **Layer 1 (python/compile/kernels, build time)** — the aggregate /
//!   update hardware templates as Pallas kernels.
//!
//! At runtime the rust binary is self-contained: it loads the HLO
//! artifacts once via the PJRT CPU client ([`runtime`]) and drives
//! training (Algorithm 2) with sampling overlapped against execution
//! ([`coordinator`]).  See DESIGN.md for the paper-to-module map and
//! EXPERIMENTS.md for reproduced tables.

pub mod accel;
pub mod api;
pub mod baselines;
pub mod coordinator;
pub mod dse;
pub mod graph;
pub mod layout;
pub mod perf;
pub mod repro;
pub mod runtime;
pub mod sampler;
pub mod util;
