//! # HP-GNN — high-throughput GNN training on a CPU-"FPGA" platform
//!
//! Reproduction of *HP-GNN: Generating High Throughput GNN Training
//! Implementation on CPU-FPGA Heterogeneous Platform* (Lin, Zhang,
//! Prasanna — FPGA '22) as a three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the host program: samplers, data layout
//!   (RMT/RRA), DSE engine, training coordinator, plus a cycle-level
//!   simulator of the paper's FPGA accelerator (we have no Alveo U250).
//! * **Layer 2 (python/compile, build time)** — the GNN fwd/bwd compute
//!   graph in JAX, AOT-lowered to HLO text (`make artifacts`).
//! * **Layer 1 (python/compile/kernels, build time)** — the aggregate /
//!   update hardware templates as Pallas kernels.
//!
//! At runtime the rust binary is self-contained: execution goes through a
//! pluggable [`runtime`] backend.  The default is a pure-Rust reference
//! executor implementing the exact train-step semantics (no artifacts or
//! external libraries needed); `--features xla` swaps in the PJRT CPU
//! client running the AOT HLO artifacts.  Either way the [`coordinator`]
//! drives training (Algorithm 2) with sampling overlapped against
//! execution.  See README.md for the two-backend story and the
//! build/verify commands.

pub mod accel;
pub mod api;
pub mod baselines;
pub mod coordinator;
pub mod dse;
pub mod graph;
pub mod layout;
pub mod lint;
pub mod net;
pub mod obs;
pub mod perf;
pub mod repro;
pub mod runtime;
pub mod sampler;
pub mod serve;
pub mod util;
