//! Training metrics: loss curve, stage timings, NVTPS accounting.

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Accumulated over a training run by the coordinator.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub losses: Vec<f32>,
    /// Per-batch host sampling+layout+padding time (producer side).
    pub t_sampling: Summary,
    /// Per-batch PJRT execution time (consumer side).
    pub t_execute: Summary,
    /// Per-iteration wall time of the pipelined loop.
    pub t_iteration: Summary,
    /// Simulated accelerator t_GNN per batch (if simulation enabled).
    pub t_gnn_sim: Summary,
    /// Σ |B^l| per batch.
    pub vertices: Vec<usize>,
}

impl Metrics {
    /// Functional throughput of this host (vertices / wall second).
    pub fn functional_nvtps(&self) -> f64 {
        let total_v: usize = self.vertices.iter().sum();
        let total_t = self.t_iteration.mean() * self.t_iteration.count() as f64;
        if total_t <= 0.0 {
            return 0.0;
        }
        total_v as f64 / total_t
    }

    /// Simulated CPU-FPGA throughput (Eq. 4/5): vertices over
    /// max(simulated t_GNN, effective per-batch sampling time).
    pub fn simulated_nvtps(&self, sampler_threads: usize) -> Option<f64> {
        if self.t_gnn_sim.count() == 0 {
            return None;
        }
        let mean_v =
            self.vertices.iter().sum::<usize>() as f64 / self.vertices.len().max(1) as f64;
        let t_sampling_eff = self.t_sampling.mean() / sampler_threads.max(1) as f64;
        Some(mean_v / self.t_gnn_sim.mean().max(t_sampling_eff))
    }

    /// First/last smoothed loss — the e2e driver's convergence check.
    pub fn loss_drop(&self) -> Option<(f32, f32)> {
        if self.losses.len() < 8 {
            return None;
        }
        let k = (self.losses.len() / 5).max(1);
        let head: f32 = self.losses[..k].iter().sum::<f32>() / k as f32;
        let tail: f32 = self.losses[self.losses.len() - k..].iter().sum::<f32>() / k as f32;
        Some((head, tail))
    }

    /// JSON dump for EXPERIMENTS.md and the metrics endpoint.
    pub fn to_json(&self, sampler_threads: usize) -> Json {
        let mut pairs = vec![
            ("steps", Json::num(self.losses.len() as f64)),
            ("functional_nvtps", Json::num(self.functional_nvtps())),
            ("t_sampling_mean_s", Json::num(self.t_sampling.mean())),
            ("t_execute_mean_s", Json::num(self.t_execute.mean())),
            ("t_iteration_mean_s", Json::num(self.t_iteration.mean())),
            (
                "loss_first",
                self.losses.first().map(|&l| Json::num(l as f64)).unwrap_or(Json::Null),
            ),
            (
                "loss_last",
                self.losses.last().map(|&l| Json::num(l as f64)).unwrap_or(Json::Null),
            ),
        ];
        if let Some(nvtps) = self.simulated_nvtps(sampler_threads) {
            pairs.push(("simulated_nvtps", Json::num(nvtps)));
            pairs.push(("t_gnn_sim_mean_s", Json::num(self.t_gnn_sim.mean())));
        }
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn functional_nvtps_counts_all_vertices() {
        let mut m = Metrics::default();
        for _ in 0..4 {
            m.vertices.push(100);
            m.t_iteration.add(0.5);
        }
        assert!((m.functional_nvtps() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn simulated_nvtps_uses_thread_scaled_sampling() {
        let mut m = Metrics::default();
        m.vertices.push(1000);
        m.t_gnn_sim.add(0.001);
        m.t_sampling.add(0.008);
        // 1 thread: sampling bound (0.008) -> 125K; 8 threads: t_gnn bound
        // (0.001) -> 1M.
        assert!((m.simulated_nvtps(1).unwrap() - 125_000.0).abs() < 1.0);
        assert!((m.simulated_nvtps(8).unwrap() - 1_000_000.0).abs() < 1.0);
        assert!(Metrics::default().simulated_nvtps(1).is_none());
    }

    #[test]
    fn loss_drop_smooths_ends() {
        let mut m = Metrics::default();
        m.losses = (0..20).map(|i| 2.0 - 0.05 * i as f32).collect();
        let (head, tail) = m.loss_drop().unwrap();
        assert!(head > tail);
        assert!(Metrics { losses: vec![1.0; 3], ..Default::default() }.loss_drop().is_none());
    }

    #[test]
    fn json_dump_has_core_fields() {
        let mut m = Metrics::default();
        m.losses = vec![2.0, 1.0];
        m.vertices = vec![10, 10];
        m.t_iteration.add(0.1);
        m.t_iteration.add(0.1);
        let j = m.to_json(2);
        assert!(j.get("functional_nvtps").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(j.get("steps").unwrap().as_usize().unwrap(), 2);
    }
}
