//! Resumable, observable training sessions — the control plane over
//! Algorithm 2.
//!
//! [`TrainingSession`] replaces the fire-and-forget `train()` loop with a
//! pull-based object: it compiles the artifact, spawns and owns the
//! producer pipeline (sampling → edge values → RMT/RRA layout → padding →
//! feature synthesis on `sampler_threads` host threads), and hands control
//! of the consumer side to the caller one [`step`](TrainingSession::step)
//! at a time.  Validation ([`evaluate`](TrainingSession::evaluate)),
//! progress observation (the [`on_step`](TrainingSession::on_step) /
//! [`on_eval`](TrainingSession::on_eval) event hooks) and full-state
//! checkpointing ([`save`](TrainingSession::save) /
//! [`resume`](TrainingSession::resume), the `HPGNNS01` [`Checkpoint`]
//! format) interleave freely with training.
//!
//! # Determinism and the RNG cursor
//!
//! The batch for global step `k` is a pure function of `(seed, k)`: every
//! producer thread claims step indices from a shared counter and seeds a
//! fresh [`Pcg64`] per batch via [`batch_rng`].  The consumer reorders
//! arrivals back into step order, so the executed batch stream — and hence
//! the loss curve — is bit-identical regardless of `sampler_threads` or
//! producer scheduling.  A [`Checkpoint`] therefore only needs `(seed,
//! step)` as its RNG cursor: resuming restarts the producers at `step` and
//! replays the exact stream the uninterrupted run would have seen.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::eval::{self, EvalReport};
use super::metrics::Metrics;
use super::trainer::{Optimizer, TrainConfig, TrainReport};
use crate::accel::{self, SimOptions};
use crate::graph::{datasets, GraphAccess};
use crate::layout::pad::{pad, PaddedBatch};
use crate::layout::{index_batch, Geometry, IndexedBatch};
use crate::runtime::weights::AdamState;
use crate::runtime::{inputs, Checkpoint, Executable, Kind, Runtime, WeightState};
use crate::sampler::values::{attach_values, GnnModel};
use crate::sampler::Sampler;
use crate::util::rng::{Pcg64, SplitMix64};
use crate::util::stats::Timer;
use crate::util::sync::lock_unpoisoned;

/// Salt mixed into `cfg.seed` for evaluation sampling, so held-out batches
/// never collide with a training step's stream.
const EVAL_SEED_SALT: u64 = 0xe5a1;

/// The per-step batch RNG: batch `step` of a run seeded with `seed` is a
/// pure function of `(seed, step)` — the session's checkpointable RNG
/// cursor.  The step index is whitened through SplitMix64 so consecutive
/// steps land in unrelated Pcg64 streams.
pub fn batch_rng(seed: u64, step: u64) -> Pcg64 {
    let mix = SplitMix64 { state: step ^ 0x9e37_79b9_7f4a_7c15 }.next();
    Pcg64::seed_from_u64(seed ^ mix)
}

/// What one executed training step looked like — the payload of
/// [`TrainingSession::step`] and the `on_step` hook.
#[derive(Debug, Clone)]
pub struct StepReport {
    /// Global step index (resumed sessions continue the original count).
    pub step: usize,
    pub loss: f32,
    /// Producer-side preparation time for this batch (seconds).
    pub prep_s: f64,
    /// Backend execution time (seconds).
    pub exec_s: f64,
    /// Per-stage breakdown of `prep_s`.
    pub stages: StepStages,
    /// Simulated accelerator t_GNN, when `cfg.simulate` is set.
    pub t_gnn_sim: Option<f64>,
}

/// Producer-side per-stage timings of one prepared batch (seconds).
/// Timings are observational only — nothing downstream branches on them
/// (the traced-vs-untraced bit-identity contract).
#[derive(Debug, Clone, Copy, Default)]
pub struct StepStages {
    /// Sampler draw (`Sampler::sample`).
    pub sample_s: f64,
    /// Edge-value attachment (GCN norms / SAGE / GIN / custom UDF).
    pub values_s: f64,
    /// Positional layout (`index_batch`, RMT/RRA).
    pub layout_s: f64,
    /// Padding to the artifact geometry.
    pub pad_s: f64,
    /// Feature/label synthesis + feature padding.
    pub features_s: f64,
}

/// Payload of the `on_eval` hook.
#[derive(Debug, Clone)]
pub struct EvalEvent {
    /// Global step the evaluation ran at.
    pub step: usize,
    pub report: EvalReport,
}

/// One prepared batch traveling producer → consumer, tagged with its
/// global step index for in-order consumption.
struct Prepared {
    padded: PaddedBatch,
    features: Vec<f32>,
    indexed: IndexedBatch,
    prep_s: f64,
    stages: StepStages,
}

/// Producer throttle: step claims may run at most [`CLAIM_WINDOW`] ×
/// `sampler_threads` ahead of the consumer.  Without it, one straggler
/// batch lets every other producer race arbitrarily far ahead while the
/// consumer parks their arrivals in `pending` — each a full padded batch —
/// so the reorder buffer (and resident memory) would be unbounded.
struct ClaimWindow {
    consumed: Mutex<usize>,
    advanced: Condvar,
    /// Exclusive upper bound on steps worth preparing (`usize::MAX` =
    /// open-ended).  Claims at or beyond it park until shutdown, so a
    /// fixed-length run ([`train`](super::trainer::train)) doesn't pay
    /// for prefetched batches it will never consume.
    limit: AtomicUsize,
}

/// Claim-ahead budget per producer thread (× `sampler_threads` total).
const CLAIM_WINDOW: usize = 4;

/// Identity string for the training graph, stored in checkpoints so a
/// resume against a different graph fails instead of silently training
/// checkpointed weights on a stream they never saw.  The serving
/// subsystem reuses it to reject a snapshot served over the wrong graph.
pub(crate) fn graph_fingerprint(g: &dyn GraphAccess) -> String {
    // Truncate by bytes (on a char boundary): the checkpoint string
    // encoding caps at 256 bytes and the counts need room too.
    let mut name = g.graph_name().to_string();
    if name.len() > 128 {
        let mut cut = 128;
        while !name.is_char_boundary(cut) {
            cut -= 1;
        }
        name.truncate(cut);
    }
    let mut fp = format!("{name} |V|={} |E|={}", g.num_vertices(), g.num_edges());
    // Version suffix only for evolved graphs, so checkpoints from static
    // runs keep their pre-store fingerprints (backward compatible).
    if g.version() > 0 {
        fp.push_str(&format!(" v={}", g.version()));
    }
    fp
}

/// A live training run: owned producer threads, weights/optimizer state,
/// metrics, and pull-based control.  Construct via
/// [`TrainingSession::new`], [`TrainingSession::resume`], or
/// [`crate::api::GeneratedDesign::session`].
pub struct TrainingSession<'rt> {
    runtime: &'rt Runtime,
    graph: Arc<dyn GraphAccess>,
    sampler: Arc<dyn Sampler>,
    cfg: TrainConfig,
    exe: Executable,
    /// Forward artifact for [`evaluate`](Self::evaluate), compiled once on
    /// first use (a PJRT compile per eval would dominate `eval_every`).
    forward: Option<Executable>,
    geom: Geometry,
    weights: WeightState,
    adam: Option<AdamState>,
    metrics: Metrics,
    /// Next global step to execute (== steps executed since the seed
    /// origin, including any checkpointed prefix).
    step: usize,
    /// Set when a step failed: step `self.step`'s batch was consumed but
    /// not executed, and no producer will regenerate it, so further
    /// stepping would hang — fail fast instead.
    failed: bool,
    compile_s: f64,
    /// Out-of-order arrivals waiting for their turn (bounded by the
    /// producers' [`ClaimWindow`]).
    pending: BTreeMap<usize, Prepared>,
    rx: Option<mpsc::Receiver<(usize, anyhow::Result<Prepared>)>>,
    stop: Arc<AtomicBool>,
    window: Arc<ClaimWindow>,
    producers: Vec<JoinHandle<()>>,
    step_hooks: Vec<Box<dyn FnMut(&StepReport)>>,
    eval_hooks: Vec<Box<dyn FnMut(&EvalEvent)>>,
}

impl<'rt> TrainingSession<'rt> {
    /// Compile the artifact for `cfg`, starting from freshly initialized
    /// weights at step 0.  The producer pipeline spawns lazily at the
    /// first [`step`](Self::step).
    pub fn new(
        runtime: &'rt Runtime,
        graph: Arc<dyn GraphAccess>,
        sampler: Arc<dyn Sampler>,
        cfg: TrainConfig,
    ) -> anyhow::Result<TrainingSession<'rt>> {
        Self::with_state(runtime, graph, sampler, cfg, None)
    }

    /// Rebuild a session from a [`Checkpoint`] written by
    /// [`save`](TrainingSession::save): weights, Adam state, and the RNG
    /// cursor are restored, and the producers restart at the checkpointed
    /// step, so the loss sequence continues bit-exactly where the
    /// snapshotted run left off (reference backend).
    pub fn resume(
        runtime: &'rt Runtime,
        graph: Arc<dyn GraphAccess>,
        sampler: Arc<dyn Sampler>,
        cfg: TrainConfig,
        checkpoint: &Path,
    ) -> anyhow::Result<TrainingSession<'rt>> {
        let snap = Checkpoint::load(checkpoint)?;
        Self::with_state(runtime, graph, sampler, cfg, Some(snap))
    }

    fn with_state(
        runtime: &'rt Runtime,
        graph: Arc<dyn GraphAccess>,
        sampler: Arc<dyn Sampler>,
        cfg: TrainConfig,
        snapshot: Option<Checkpoint>,
    ) -> anyhow::Result<TrainingSession<'rt>> {
        let compile_t = Timer::start();
        let kind = match cfg.optimizer {
            Optimizer::Sgd => Kind::TrainStep,
            Optimizer::Adam => Kind::AdamStep,
        };
        let exe = runtime.compile_role_with(cfg.model, &cfg.geometry, kind, &cfg.exec_options())?;
        let compile_s = compile_t.secs();
        let geom = exe.spec.geometry.clone();
        anyhow::ensure!(
            geom.layers() == sampler.num_layers(),
            "sampler has {} layers, artifact geometry {} has {}",
            sampler.num_layers(),
            geom.name,
            geom.layers()
        );

        let (weights, adam, start_step) = match snapshot {
            None => {
                let weights = WeightState::init_glorot(&exe.spec.weight_shapes, cfg.seed);
                let adam = (cfg.optimizer == Optimizer::Adam)
                    .then(|| AdamState::zeros(&exe.spec.weight_shapes));
                (weights, adam, 0usize)
            }
            Some(snap) => {
                anyhow::ensure!(
                    snap.model == cfg.model.as_str(),
                    "checkpoint was trained with model {:?}, session uses {:?}",
                    snap.model,
                    cfg.model.as_str()
                );
                anyhow::ensure!(
                    snap.geometry == geom.name,
                    "checkpoint geometry {:?} does not match session geometry {:?}",
                    snap.geometry,
                    geom.name
                );
                anyhow::ensure!(
                    snap.weights.tensors.len() == exe.spec.weight_shapes.len() * 2,
                    "checkpoint has {} weight tensors, artifact wants {}",
                    snap.weights.tensors.len(),
                    exe.spec.weight_shapes.len() * 2
                );
                for (l, (wshape, bshape)) in exe.spec.weight_shapes.iter().enumerate() {
                    anyhow::ensure!(
                        &snap.weights.tensors[2 * l].0 == wshape,
                        "checkpoint w{} shape {:?} does not match artifact shape {:?}",
                        l + 1,
                        snap.weights.tensors[2 * l].0,
                        wshape
                    );
                    anyhow::ensure!(
                        &snap.weights.tensors[2 * l + 1].0 == bshape,
                        "checkpoint b{} shape {:?} does not match artifact shape {:?}",
                        l + 1,
                        snap.weights.tensors[2 * l + 1].0,
                        bshape
                    );
                }
                match (cfg.optimizer, &snap.adam) {
                    (Optimizer::Adam, None) => {
                        anyhow::bail!("checkpoint has no Adam state but the optimizer is Adam")
                    }
                    (Optimizer::Sgd, Some(_)) => {
                        anyhow::bail!("checkpoint carries Adam state but the optimizer is SGD")
                    }
                    _ => {}
                }
                if let Some(st) = &snap.adam {
                    anyhow::ensure!(
                        st.m.len() == snap.weights.tensors.len()
                            && st.v.len() == snap.weights.tensors.len(),
                        "checkpoint Adam state has {}/{} moment tensors for {} weights",
                        st.m.len(),
                        st.v.len(),
                        snap.weights.tensors.len()
                    );
                    // Shapes too: a corrupt moment tensor must fail here,
                    // not poison the session at its first step.
                    for (i, (wshape, _)) in snap.weights.tensors.iter().enumerate() {
                        anyhow::ensure!(
                            st.m[i].0 == *wshape && st.v[i].0 == *wshape,
                            "checkpoint Adam moment {i} shape {:?}/{:?} does not match \
                             weight shape {:?}",
                            st.m[i].0,
                            st.v[i].0,
                            wshape
                        );
                    }
                }
                // The RNG cursor is (seed, step): a different session seed
                // would replay a different batch stream (and a different
                // graph, when both derive from one seed) under the
                // checkpointed weights — reject rather than silently
                // diverge from the bit-exact-resume guarantee.
                anyhow::ensure!(
                    snap.seed == cfg.seed,
                    "checkpoint was trained with seed {} but the session uses seed {}",
                    snap.seed,
                    cfg.seed
                );
                // The stream is a function of (graph, sampler, seed, step):
                // all of them must match for the resume to be the
                // checkpointed run's continuation.
                anyhow::ensure!(
                    snap.sampler == sampler.name(),
                    "checkpoint was trained with sampler {:?}, session uses {:?}",
                    snap.sampler,
                    sampler.name()
                );
                anyhow::ensure!(
                    snap.graph == graph_fingerprint(graph.as_ref()),
                    "checkpoint graph {:?} does not match session graph {:?}",
                    snap.graph,
                    graph_fingerprint(graph.as_ref())
                );
                (snap.weights, snap.adam, snap.step as usize)
            }
        };

        Ok(TrainingSession {
            runtime,
            graph,
            sampler,
            cfg,
            exe,
            forward: None,
            geom,
            weights,
            adam,
            metrics: Metrics::default(),
            step: start_step,
            failed: false,
            compile_s,
            pending: BTreeMap::new(),
            rx: None,
            stop: Arc::new(AtomicBool::new(false)),
            window: Arc::new(ClaimWindow {
                consumed: Mutex::new(start_step),
                advanced: Condvar::new(),
                limit: AtomicUsize::new(usize::MAX),
            }),
            producers: Vec::new(),
            step_hooks: Vec::new(),
            eval_hooks: Vec::new(),
        })
    }

    /// Spawn the producer pipeline.  Deferred to the first
    /// [`step`](Self::step) so a [`set_step_limit`](Self::set_step_limit)
    /// issued right after construction is in force before any claim is
    /// made, and eval-/save-only sessions never spawn threads.
    fn spawn_producers(&mut self) {
        debug_assert!(self.rx.is_none() && self.producers.is_empty());
        let threads = self.cfg.sampler_threads.max(1);
        let cap = CLAIM_WINDOW * threads;
        let counter = Arc::new(AtomicUsize::new(self.step));
        *lock_unpoisoned(&self.window.consumed) = self.step;
        let (tx, rx) = mpsc::sync_channel::<(usize, anyhow::Result<Prepared>)>(2 * threads);
        let feat_dim = self.geom.f[0];
        let num_classes = self.geom.num_classes();
        for _ in 0..threads {
            let tx = tx.clone();
            let graph = Arc::clone(&self.graph);
            let sampler = Arc::clone(&self.sampler);
            let cfg = self.cfg.clone();
            let geom = self.geom.clone();
            let counter = Arc::clone(&counter);
            let stop = Arc::clone(&self.stop);
            let window = Arc::clone(&self.window);
            self.producers.push(std::thread::spawn(move || loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let k = counter.fetch_add(1, Ordering::Relaxed);
                // Throttle: stay within the claim window of the consumer
                // and under the step limit (timeout guards a notify
                // racing the wait).
                {
                    let mut consumed = lock_unpoisoned(&window.consumed);
                    while !stop.load(Ordering::Relaxed)
                        && (k >= *consumed + cap
                            || k >= window.limit.load(Ordering::Relaxed))
                    {
                        let (guard, _timeout) = window
                            .advanced
                            .wait_timeout(consumed, std::time::Duration::from_millis(50))
                            .unwrap_or_else(std::sync::PoisonError::into_inner);
                        consumed = guard;
                    }
                }
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let t = Timer::start();
                let mut rng = batch_rng(cfg.seed, k as u64);
                let item = prepare_batch(
                    graph.as_ref(),
                    sampler.as_ref(),
                    &cfg,
                    &geom,
                    feat_dim,
                    num_classes,
                    &mut rng,
                )
                .map(|(padded, features, indexed, stages)| Prepared {
                    padded,
                    features,
                    indexed,
                    prep_s: t.secs(),
                    stages,
                });
                if tx.send((k, item)).is_err() {
                    break; // session finished or dropped
                }
            }));
        }
        drop(tx);
        self.rx = Some(rx);
    }

    /// Cap the global step the producers will prepare for.  A caller that
    /// knows the run length up front (the `train()` wrapper, a CLI run
    /// with `training.steps`) sets this so producers don't prefetch
    /// batches past the end that `finish()` would discard.  Stepping at
    /// or beyond the limit is an error (the batch was never prepared).
    pub fn set_step_limit(&self, limit: usize) {
        self.window.limit.store(limit, Ordering::Relaxed);
        self.window.advanced.notify_all();
    }

    /// Register a hook fired after every executed step (replaces the old
    /// `log_every` knob — install a hook that filters on `report.step`).
    pub fn on_step(&mut self, hook: impl FnMut(&StepReport) + 'static) {
        self.step_hooks.push(Box::new(hook));
    }

    /// Register a hook fired after every [`evaluate`](Self::evaluate) call.
    pub fn on_eval(&mut self, hook: impl FnMut(&EvalEvent) + 'static) {
        self.eval_hooks.push(Box::new(hook));
    }

    /// Execute one training step (Algorithm 2's consumer side): wait for
    /// this step's prepared batch, run the train-step artifact, thread the
    /// weights (and Adam state) through, record metrics, fire hooks.
    ///
    /// A step error is not retryable: the failed step's batch is gone from
    /// the pipeline, so the session is poisoned and every later call
    /// errors immediately (instead of blocking on a batch that will never
    /// arrive).  Recover by resuming a new session from the last snapshot.
    pub fn step(&mut self) -> anyhow::Result<StepReport> {
        anyhow::ensure!(
            !self.failed,
            "session failed at step {}; resume a new session from the last checkpoint",
            self.step
        );
        match self.step_inner() {
            Ok(report) => Ok(report),
            Err(e) => {
                self.failed = true;
                Err(e)
            }
        }
    }

    fn step_inner(&mut self) -> anyhow::Result<StepReport> {
        let iter_t = Timer::start();
        let k = self.step;
        let limit = self.window.limit.load(Ordering::Relaxed);
        anyhow::ensure!(
            k < limit,
            "step {k} is beyond the session's step limit {limit} \
             (raise it with set_step_limit before running further)"
        );
        if self.rx.is_none() {
            self.spawn_producers();
        }
        let prepared = self.next_prepared(k)?;
        let exec_t = Timer::start();
        let lits = inputs::build_inputs_opt(
            &self.exe.spec,
            &prepared.padded,
            &prepared.features,
            &self.weights,
            self.cfg.lr,
            self.adam.as_ref(),
        )?;
        let outs = self.exe.run(&lits)?;
        let loss = outs[0]
            .scalar()
            .map_err(|e| anyhow::anyhow!("loss readback: {e}"))?;
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {k}: {loss}");
        let nparams = self.weights.tensors.len();
        self.weights.update_from(&outs[1..1 + nparams])?;
        if let Some(st) = self.adam.as_mut() {
            st.update_from(&outs[1 + nparams..])?;
        }
        let exec_s = exec_t.secs();

        self.metrics.losses.push(loss);
        self.metrics.t_sampling.add(prepared.prep_s);
        self.metrics.t_execute.add(exec_s);
        self.metrics.vertices.push(prepared.padded.vertices_traversed);

        let mut t_gnn_sim = None;
        if let Some((platform, accel_cfg)) = &self.cfg.simulate {
            let sim = accel::simulate_batch(
                platform,
                accel_cfg,
                &prepared.indexed,
                &self.geom.f,
                SimOptions {
                    sage_concat: self.cfg.model == GnnModel::Sage,
                    ..Default::default()
                },
            );
            self.metrics.t_gnn_sim.add(sim.t_gnn);
            t_gnn_sim = Some(sim.t_gnn);
        }
        self.metrics.t_iteration.add(iter_t.secs());
        self.step += 1;
        // Advance the producers' claim window.
        *lock_unpoisoned(&self.window.consumed) = self.step;
        self.window.advanced.notify_all();

        let report = StepReport {
            step: k,
            loss,
            prep_s: prepared.prep_s,
            exec_s,
            stages: prepared.stages,
            t_gnn_sim,
        };
        let mut hooks = std::mem::take(&mut self.step_hooks);
        for hook in &mut hooks {
            hook(&report);
        }
        self.step_hooks = hooks;
        Ok(report)
    }

    /// Run `steps` consecutive training steps.
    pub fn run_for(&mut self, steps: usize) -> anyhow::Result<()> {
        for _ in 0..steps {
            self.step()?;
        }
        Ok(())
    }

    /// The generated host program's main loop: train until `total_steps`
    /// *global* steps have executed (a resumed session trains only the
    /// remainder), evaluating on `eval_batches` held-out batches every
    /// `eval_every` steps and snapshotting to `checkpoint` every
    /// `checkpoint_every` steps — plus a final snapshot, unless the
    /// periodic cadence just wrote one at the last step.  Both `hp-gnn
    /// run` and `hp-gnn train` sit on this; progress arrives through the
    /// [`on_step`](Self::on_step)/[`on_eval`](Self::on_eval) hooks.
    pub fn drive(
        &mut self,
        total_steps: usize,
        eval_every: usize,
        eval_batches: usize,
        checkpoint: Option<&Path>,
        checkpoint_every: usize,
    ) -> anyhow::Result<()> {
        let mut last_saved = None;
        while self.current_step() < total_steps {
            self.step()?;
            let done = self.current_step();
            if eval_every > 0 && done % eval_every == 0 {
                self.evaluate(eval_batches)?;
            }
            if let Some(path) = checkpoint {
                if checkpoint_every > 0 && done % checkpoint_every == 0 {
                    self.save(path)?;
                    last_saved = Some(done);
                }
            }
        }
        if let Some(path) = checkpoint {
            if last_saved != Some(self.current_step()) {
                self.save(path)?;
            }
        }
        Ok(())
    }

    /// Score the current weights on `batches` freshly sampled held-out
    /// batches through the forward artifact (compiled once, on first use).
    /// Evaluation draws from a seed-salted stream, so it never perturbs
    /// training determinism.
    pub fn evaluate(&mut self, batches: usize) -> anyhow::Result<EvalReport> {
        if self.forward.is_none() {
            self.forward = Some(self.runtime.compile_role_with(
                self.cfg.model,
                &self.cfg.geometry,
                Kind::Forward,
                &self.cfg.exec_options(),
            )?);
        }
        let report = eval::evaluate_with(
            self.forward.as_ref().expect("just compiled"),
            self.graph.as_ref(),
            self.sampler.as_ref(),
            &self.cfg,
            &self.weights,
            batches,
            self.cfg.seed ^ EVAL_SEED_SALT,
        )?;
        let event = EvalEvent { step: self.step, report: report.clone() };
        let mut hooks = std::mem::take(&mut self.eval_hooks);
        for hook in &mut hooks {
            hook(&event);
        }
        self.eval_hooks = hooks;
        Ok(report)
    }

    /// Write a full-state `HPGNNS01` [`Checkpoint`] (weights + Adam state
    /// + RNG cursor + sampler/graph identity) for a later
    /// [`resume`](Self::resume).
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        Checkpoint {
            step: self.step as u64,
            seed: self.cfg.seed,
            model: self.cfg.model.as_str().to_string(),
            geometry: self.geom.name.clone(),
            sampler: self.sampler.name(),
            graph: graph_fingerprint(self.graph.as_ref()),
            weights: self.weights.clone(),
            adam: self.adam.clone(),
        }
        .save(path)
    }

    /// Metrics accumulated so far (losses are indexed from the step this
    /// session started at, not the global step).
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// The current model weights.
    pub fn weights(&self) -> &WeightState {
        &self.weights
    }

    /// Next global step to execute (== total steps since the seed origin).
    pub fn current_step(&self) -> usize {
        self.step
    }

    /// The session's effective configuration (resume validates that the
    /// checkpoint's seed matches `cfg.seed`).
    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    /// Artifact compile time paid at construction (seconds).
    pub fn compile_s(&self) -> f64 {
        self.compile_s
    }

    /// Stop the producers and fold the session into a [`TrainReport`].
    pub fn finish(mut self) -> TrainReport {
        self.shutdown();
        let empty = WeightState { tensors: Vec::new() };
        TrainReport {
            metrics: std::mem::take(&mut self.metrics),
            final_weights: std::mem::replace(&mut self.weights, empty),
            compile_s: self.compile_s,
        }
    }

    /// Receive until step `k`'s batch arrives, parking out-of-order
    /// arrivals in `pending`.
    fn next_prepared(&mut self, k: usize) -> anyhow::Result<Prepared> {
        loop {
            if let Some(p) = self.pending.remove(&k) {
                return Ok(p);
            }
            let rx = self
                .rx
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("session already finished"))?;
            let (i, item) = match rx.recv_timeout(std::time::Duration::from_millis(100)) {
                Ok(pair) => pair,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    // A panicked producer strands its claimed step while
                    // the other senders stay alive (parked in the claim
                    // window), so a plain recv() would hang forever —
                    // detect the dead thread and fail instead.
                    anyhow::ensure!(
                        !self.producers.iter().any(|h| h.is_finished()),
                        "a batch producer thread terminated unexpectedly (panicked?)"
                    );
                    continue;
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("batch producers terminated unexpectedly")
                }
            };
            match item {
                Ok(p) => {
                    self.pending.insert(i, p);
                }
                Err(e) => return Err(e.context(format!("preparing batch {i}"))),
            }
        }
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.window.advanced.notify_all(); // unblocks throttled producers
        self.pending.clear();
        drop(self.rx.take()); // unblocks producers parked on send
        for h in self.producers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for TrainingSession<'_> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Producer-side batch preparation (everything the paper's host program
/// does between the sampler and the accelerator).
fn prepare_batch(
    graph: &dyn GraphAccess,
    sampler: &dyn Sampler,
    cfg: &TrainConfig,
    geom: &Geometry,
    feat_dim: usize,
    num_classes: usize,
    rng: &mut Pcg64,
) -> anyhow::Result<(PaddedBatch, Vec<f32>, IndexedBatch, StepStages)> {
    let mut stages = StepStages::default();
    let t = Timer::start();
    let mb = sampler.sample(graph, rng);
    stages.sample_s = t.secs();
    let t = Timer::start();
    let values = match &cfg.value_fn {
        Some(f) => f(graph, &mb),
        None => attach_values(graph, &mb, cfg.model),
    };
    stages.values_s = t.secs();
    let t = Timer::start();
    let indexed = index_batch(&mb, &values, cfg.layout);
    stages.layout_s = t.secs();
    let ll = mb.num_layers();
    let target_labels =
        datasets::synth_labels(&mb.layers[ll], num_classes, cfg.seed, graph.num_vertices());
    let t = Timer::start();
    let padded = pad(&indexed, &target_labels, geom, cfg.overflow)?;
    stages.pad_s = t.secs();
    // Feature rows for B^0, labels drawn from the same per-vertex stream
    // so the task is learnable.
    let t = Timer::start();
    let l0_labels =
        datasets::synth_labels(&mb.layers[0], num_classes, cfg.seed, graph.num_vertices());
    let real = datasets::synth_features(&mb.layers[0], &l0_labels, feat_dim, num_classes, cfg.seed);
    let features = inputs::pad_features(&real, mb.layers[0].len(), geom.b[0], feat_dim);
    stages.features_s = t.secs();
    Ok((padded, features, indexed, stages))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generator, Graph};
    use crate::sampler::neighbor::NeighborSampler;

    fn tiny_graph(seed: u64) -> Graph {
        let mut g = generator::with_min_degree(
            generator::rmat(400, 3200, Default::default(), seed),
            1,
            seed ^ 1,
        );
        g.feat_dim = 16;
        g.num_classes = 4;
        g
    }

    fn session(rt: &Runtime, cfg: TrainConfig) -> TrainingSession<'_> {
        TrainingSession::new(
            rt,
            Arc::new(tiny_graph(31)),
            Arc::new(NeighborSampler::new(4, vec![5, 3])),
            cfg,
        )
        .unwrap()
    }

    #[test]
    fn batch_rng_is_a_pure_function_of_seed_and_step() {
        let a: Vec<u64> = (0..4).map(|_| batch_rng(7, 3).next_u64()).collect();
        assert!(a.windows(2).all(|w| w[0] == w[1]), "not pure: {a:?}");
        assert_ne!(batch_rng(7, 3).next_u64(), batch_rng(7, 4).next_u64());
        assert_ne!(batch_rng(7, 3).next_u64(), batch_rng(8, 3).next_u64());
    }

    #[test]
    fn stepwise_control_matches_run_for() {
        let rt = Runtime::reference();
        let cfg = TrainConfig::quick(GnnModel::Gcn, "tiny", 0);
        let mut a = session(&rt, cfg.clone());
        for _ in 0..6 {
            a.step().unwrap();
        }
        let mut b = session(&rt, cfg);
        b.run_for(6).unwrap();
        assert_eq!(a.metrics().losses, b.metrics().losses);
        assert_eq!(a.current_step(), 6);
    }

    #[test]
    fn losses_are_thread_count_invariant() {
        // The per-step RNG cursor makes the batch stream independent of the
        // producer thread count and scheduling.
        let rt = Runtime::reference();
        let mut one = TrainConfig::quick(GnnModel::Gcn, "tiny", 0);
        one.sampler_threads = 1;
        let mut four = one.clone();
        four.sampler_threads = 4;
        let mut a = session(&rt, one);
        a.run_for(8).unwrap();
        let mut b = session(&rt, four);
        b.run_for(8).unwrap();
        assert_eq!(a.metrics().losses, b.metrics().losses);
    }

    #[test]
    fn step_hooks_see_consecutive_steps() {
        let rt = Runtime::reference();
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let sink = Arc::clone(&seen);
        let mut s = session(&rt, TrainConfig::quick(GnnModel::Gcn, "tiny", 0));
        s.on_step(move |r| sink.lock().unwrap().push(r.step));
        s.run_for(5).unwrap();
        assert_eq!(*seen.lock().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn evaluate_fires_hook_and_scores() {
        let rt = Runtime::reference();
        let fired = Arc::new(std::sync::Mutex::new(Vec::new()));
        let sink = Arc::clone(&fired);
        let mut s = session(&rt, TrainConfig::quick(GnnModel::Gcn, "tiny", 0));
        s.on_eval(move |ev| sink.lock().unwrap().push((ev.step, ev.report.total)));
        s.run_for(2).unwrap();
        let report = s.evaluate(2).unwrap();
        assert!(report.total > 0);
        assert_eq!(fired.lock().unwrap().as_slice(), &[(2, report.total)]);
    }

    #[test]
    fn finish_reports_accumulated_metrics() {
        let rt = Runtime::reference();
        let mut s = session(&rt, TrainConfig::quick(GnnModel::Gcn, "tiny", 0));
        s.run_for(4).unwrap();
        let report = s.finish();
        assert_eq!(report.metrics.losses.len(), 4);
        assert!(report.final_weights.l2_norm() > 0.0);
    }

    #[test]
    fn save_resume_round_trip_is_bit_exact_in_process() {
        let rt = Runtime::reference();
        let cfg = TrainConfig::quick(GnnModel::Gcn, "tiny", 0);
        let mut full = session(&rt, cfg.clone());
        full.run_for(10).unwrap();
        let want = full.metrics().losses.clone();

        let dir = std::env::temp_dir().join(format!("hpgnn-sess-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mid.ckpt");
        let mut first = session(&rt, cfg.clone());
        first.run_for(5).unwrap();
        first.save(&path).unwrap();
        drop(first);

        let mut resumed = TrainingSession::resume(
            &rt,
            Arc::new(tiny_graph(31)),
            Arc::new(NeighborSampler::new(4, vec![5, 3])),
            cfg,
            &path,
        )
        .unwrap();
        assert_eq!(resumed.current_step(), 5);
        resumed.run_for(5).unwrap();
        assert_eq!(resumed.metrics().losses, want[5..].to_vec());
    }

    #[test]
    fn step_error_poisons_the_session_instead_of_hanging() {
        let rt = Runtime::reference();
        // Budgets far beyond the tiny geometry's vertex bounds: every
        // batch fails padding, so the first step errors.
        let mut s = TrainingSession::new(
            &rt,
            Arc::new(tiny_graph(31)),
            Arc::new(NeighborSampler::new(8, vec![25, 25])),
            TrainConfig::quick(GnnModel::Gcn, "tiny", 0),
        )
        .unwrap();
        assert!(s.step().is_err());
        // A retry must fail fast, not block on a batch that never comes.
        let err = s.step().unwrap_err().to_string();
        assert!(err.contains("failed at step"), "{err}");
    }

    #[test]
    fn resume_rejects_mismatched_model_and_optimizer() {
        let rt = Runtime::reference();
        let cfg = TrainConfig::quick(GnnModel::Gcn, "tiny", 0);
        let s = session(&rt, cfg.clone());
        let dir = std::env::temp_dir().join(format!("hpgnn-sess-mm-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("gcn.ckpt");
        s.save(&path).unwrap();

        let graph = Arc::new(tiny_graph(31));
        let sampler: Arc<dyn Sampler> = Arc::new(NeighborSampler::new(4, vec![5, 3]));
        let mut sage = cfg.clone();
        sage.model = GnnModel::Sage;
        let err =
            TrainingSession::resume(&rt, Arc::clone(&graph), Arc::clone(&sampler), sage, &path)
                .unwrap_err()
                .to_string();
        assert!(err.contains("model"), "{err}");

        let mut adam = cfg;
        adam.optimizer = Optimizer::Adam;
        let err = TrainingSession::resume(&rt, graph, sampler, adam, &path)
            .unwrap_err()
            .to_string();
        assert!(err.contains("Adam"), "{err}");
    }
}
