//! The training loop (paper Algorithm 2) with sampling/execution overlap.
//!
//! Producer threads sample mini-batches, attach edge values, run the
//! layout engine (RMT/RRA), pad to the artifact geometry and synthesize
//! the feature rows; a bounded channel feeds the consumer, which executes
//! the train step on the runtime backend (pure-Rust reference by default,
//! PJRT under `--features xla`) and threads the weights through.  The
//! bounded channel is the backpressure mechanism: when the accelerator is
//! the bottleneck the producers idle (sampling fully hidden, Eq. 5), when
//! sampling is the bottleneck the consumer starves and the measured
//! iteration time shows it.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;

use super::metrics::Metrics;
use crate::accel::{self, AccelConfig, Platform, SimOptions};
use crate::graph::{datasets, Graph};
use crate::layout::pad::{pad, EdgeOverflow, PaddedBatch};
use crate::layout::{index_batch, IndexedBatch, LayoutOptions};
use crate::runtime::weights::AdamState;
use crate::runtime::{inputs, Kind, Runtime, WeightState};
use crate::sampler::values::{attach_values, GnnModel};
use crate::sampler::Sampler;
use crate::util::rng::Pcg64;
use crate::util::stats::Timer;

/// Custom Scatter-UDF hook (paper Listing 2): computes per-edge values,
/// replacing the built-in GCN/SAGE `PrepareEdges()`.  The aggregate
/// hardware template is value-agnostic (`msg.val = edge.val * feat[src]`),
/// so custom layers run on the stock artifacts.
pub type ValueFn =
    Arc<dyn Fn(&Graph, &crate::sampler::MiniBatch) -> crate::sampler::values::EdgeValues + Send + Sync>;

/// Weight-update rule (paper Algorithm 2's WeightUpdate stage).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Optimizer {
    #[default]
    Sgd,
    /// Adam with state threaded through the `adam_step` artifact.
    Adam,
}

/// Training-run configuration (the generated host program's knobs).
#[derive(Clone)]
pub struct TrainConfig {
    pub model: GnnModel,
    pub optimizer: Optimizer,
    /// Geometry name — selects the artifact (e.g. "tiny", "ns_small").
    pub geometry: String,
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    pub layout: LayoutOptions,
    pub sampler_threads: usize,
    pub overflow: EdgeOverflow,
    /// Simulate each batch on the accelerator model (Table 7's CPU-FPGA
    /// timing path); None disables.
    pub simulate: Option<(Platform, AccelConfig)>,
    pub log_every: usize,
    /// Custom Scatter UDF; None uses the model's standard edge values.
    pub value_fn: Option<ValueFn>,
}

impl std::fmt::Debug for TrainConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TrainConfig")
            .field("model", &self.model)
            .field("geometry", &self.geometry)
            .field("steps", &self.steps)
            .field("lr", &self.lr)
            .field("layout", &self.layout)
            .field("custom_values", &self.value_fn.is_some())
            .finish()
    }
}

impl TrainConfig {
    pub fn quick(model: GnnModel, geometry: &str, steps: usize) -> TrainConfig {
        TrainConfig {
            model,
            optimizer: Optimizer::Sgd,
            geometry: geometry.to_string(),
            steps,
            lr: 0.05,
            seed: 7,
            layout: LayoutOptions::all(),
            sampler_threads: 2,
            overflow: EdgeOverflow::TruncateKeepSelf,
            simulate: None,
            log_every: 0,
            value_fn: None,
        }
    }
}

/// Result of a training run.
#[derive(Debug)]
pub struct TrainReport {
    pub metrics: Metrics,
    pub final_weights: WeightState,
    /// Compile time of the artifact (once per process).
    pub compile_s: f64,
}

/// One prepared batch traveling producer -> consumer.
struct Prepared {
    padded: PaddedBatch,
    features: Vec<f32>,
    indexed: IndexedBatch,
    prep_s: f64,
}

/// Run Algorithm 2 for `cfg.steps` iterations.
pub fn train(
    runtime: &Runtime,
    graph: &Graph,
    sampler: &dyn Sampler,
    cfg: &TrainConfig,
) -> anyhow::Result<TrainReport> {
    let compile_t = Timer::start();
    let kind = match cfg.optimizer {
        Optimizer::Sgd => Kind::TrainStep,
        Optimizer::Adam => Kind::AdamStep,
    };
    let exe = runtime.compile_role(cfg.model, &cfg.geometry, kind)?;
    let compile_s = compile_t.secs();
    let spec = &exe.spec;
    let geom = spec.geometry.clone();
    anyhow::ensure!(
        geom.layers() == sampler.num_layers(),
        "sampler has {} layers, artifact geometry {} has {}",
        sampler.num_layers(),
        geom.name,
        geom.layers()
    );
    let num_classes = geom.num_classes();
    let feat_dim = geom.f[0];

    let mut weights = WeightState::init_glorot(&spec.weight_shapes, cfg.seed);
    let mut adam = (cfg.optimizer == Optimizer::Adam)
        .then(|| AdamState::zeros(&spec.weight_shapes));
    let mut metrics = Metrics::default();

    let produced = AtomicUsize::new(0);
    let (tx, rx) = mpsc::sync_channel::<anyhow::Result<Prepared>>(2 * cfg.sampler_threads.max(1));

    std::thread::scope(|scope| -> anyhow::Result<()> {
        // ---- producers: sample -> values -> layout -> pad -> features.
        for tid in 0..cfg.sampler_threads.max(1) {
            let tx = tx.clone();
            let produced = &produced;
            let geom = &geom;
            scope.spawn(move || {
                let mut rng = Pcg64::seed_from_u64(cfg.seed ^ ((0xba7c4 ^ tid as u64) << 8));
                loop {
                    let k = produced.fetch_add(1, Ordering::Relaxed);
                    if k >= cfg.steps {
                        break;
                    }
                    let t = Timer::start();
                    let item = prepare_batch(
                        graph,
                        sampler,
                        cfg,
                        geom,
                        feat_dim,
                        num_classes,
                        &mut rng,
                    )
                    .map(|(padded, features, indexed)| Prepared {
                        padded,
                        features,
                        indexed,
                        prep_s: t.secs(),
                    });
                    if tx.send(item).is_err() {
                        break; // consumer bailed
                    }
                }
            });
        }
        drop(tx);

        // ---- consumer: execute + weight threading.
        let mut step = 0usize;
        while let Ok(item) = rx.recv() {
            let iter_t = Timer::start();
            let prepared = item?;
            let exec_t = Timer::start();
            let lits = inputs::build_inputs_opt(
                spec,
                &prepared.padded,
                &prepared.features,
                &weights,
                cfg.lr,
                adam.as_ref(),
            )?;
            let outs = exe.run(&lits)?;
            let loss = outs[0]
                .scalar()
                .map_err(|e| anyhow::anyhow!("loss readback: {e}"))?;
            anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}: {loss}");
            let nparams = weights.tensors.len();
            weights.update_from(&outs[1..1 + nparams])?;
            if let Some(st) = adam.as_mut() {
                st.update_from(&outs[1 + nparams..])?;
            }
            let exec_s = exec_t.secs();

            metrics.losses.push(loss);
            metrics.t_sampling.add(prepared.prep_s);
            metrics.t_execute.add(exec_s);
            metrics.vertices.push(prepared.padded.vertices_traversed);

            if let Some((platform, accel_cfg)) = &cfg.simulate {
                let sim = accel::simulate_batch(
                    platform,
                    accel_cfg,
                    &prepared.indexed,
                    &geom.f,
                    SimOptions {
                        sage_concat: cfg.model == GnnModel::Sage,
                        ..Default::default()
                    },
                );
                metrics.t_gnn_sim.add(sim.t_gnn);
            }

            metrics.t_iteration.add(iter_t.secs());
            if cfg.log_every > 0 && step % cfg.log_every == 0 {
                log::info!(
                    "step {step}: loss {loss:.4}, exec {:.1} ms, prep {:.1} ms",
                    exec_s * 1e3,
                    prepared.prep_s * 1e3
                );
            }
            step += 1;
        }
        Ok(())
    })?;

    Ok(TrainReport { metrics, final_weights: weights, compile_s })
}

/// Producer-side batch preparation (everything the paper's host program
/// does between the sampler and the accelerator).
fn prepare_batch(
    graph: &Graph,
    sampler: &dyn Sampler,
    cfg: &TrainConfig,
    geom: &crate::layout::Geometry,
    feat_dim: usize,
    num_classes: usize,
    rng: &mut Pcg64,
) -> anyhow::Result<(PaddedBatch, Vec<f32>, IndexedBatch)> {
    let mb = sampler.sample(graph, rng);
    let values = match &cfg.value_fn {
        Some(f) => f(graph, &mb),
        None => attach_values(graph, &mb, cfg.model),
    };
    let indexed = index_batch(&mb, &values, cfg.layout);
    let ll = mb.num_layers();
    let target_labels =
        datasets::synth_labels(&mb.layers[ll], num_classes, cfg.seed, graph.num_vertices());
    let padded = pad(&indexed, &target_labels, geom, cfg.overflow)?;
    // Feature rows for B^0, labels drawn from the same per-vertex stream
    // so the task is learnable.
    let l0_labels =
        datasets::synth_labels(&mb.layers[0], num_classes, cfg.seed, graph.num_vertices());
    let real = datasets::synth_features(&mb.layers[0], &l0_labels, feat_dim, num_classes, cfg.seed);
    let features = inputs::pad_features(&real, mb.layers[0].len(), geom.b[0], feat_dim);
    Ok((padded, features, indexed))
}
