//! The training loop (paper Algorithm 2) with sampling/execution overlap.
//!
//! Producer threads sample mini-batches, attach edge values, run the
//! layout engine (RMT/RRA), pad to the artifact geometry and synthesize
//! the feature rows; a bounded channel feeds the consumer, which executes
//! the train step on the runtime backend (pure-Rust reference by default,
//! PJRT under `--features xla`) and threads the weights through.  The
//! bounded channel is the backpressure mechanism: when the accelerator is
//! the bottleneck the producers idle (sampling fully hidden, Eq. 5), when
//! sampling is the bottleneck the consumer starves and the measured
//! iteration time shows it.
//!
//! The pipeline itself lives in [`super::session::TrainingSession`];
//! [`train`] is the paper's fire-and-forget host program expressed as a
//! thin wrapper over a session (`run_for(cfg.steps)` then `finish()`).

use std::sync::Arc;

use super::metrics::Metrics;
use super::session::TrainingSession;
use crate::accel::{AccelConfig, Platform};
use crate::graph::{Graph, GraphAccess};
use crate::layout::pad::EdgeOverflow;
use crate::layout::LayoutOptions;
use crate::runtime::{Runtime, WeightState};
use crate::sampler::values::GnnModel;
use crate::sampler::Sampler;

/// Custom Scatter-UDF hook (paper Listing 2): computes per-edge values,
/// replacing the built-in GCN/SAGE `PrepareEdges()`.  The aggregate
/// hardware template is value-agnostic (`msg.val = edge.val * feat[src]`),
/// so custom layers run on the stock artifacts.
pub type ValueFn = Arc<
    dyn Fn(&dyn GraphAccess, &crate::sampler::MiniBatch) -> crate::sampler::values::EdgeValues
        + Send
        + Sync,
>;

/// Weight-update rule (paper Algorithm 2's WeightUpdate stage).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Optimizer {
    #[default]
    Sgd,
    /// Adam with state threaded through the `adam_step` artifact.
    Adam,
}

/// Training-run configuration (the generated host program's knobs).
#[derive(Clone)]
pub struct TrainConfig {
    pub model: GnnModel,
    pub optimizer: Optimizer,
    /// Geometry name — selects the artifact (e.g. "tiny", "ns_small").
    pub geometry: String,
    /// Iterations [`train`] runs; sessions ignore it (`run_for` decides).
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    pub layout: LayoutOptions,
    pub sampler_threads: usize,
    /// Worker threads for the runtime's compute kernels (the reference
    /// executor's dense/sparse kernel layer).  Purely a throughput knob:
    /// losses and weights are bit-identical at every setting, and `1`
    /// reproduces the fully sequential executor.  Defaults to all
    /// available cores.
    pub compute_threads: usize,
    pub overflow: EdgeOverflow,
    /// Simulate each batch on the accelerator model (Table 7's CPU-FPGA
    /// timing path); None disables.
    pub simulate: Option<(Platform, AccelConfig)>,
    /// Legacy progress knob, honored by [`train`] only: log every N steps
    /// (0 disables; step 0 is never logged).  Sessions use the
    /// [`on_step`](TrainingSession::on_step) hook instead.
    pub log_every: usize,
    /// Custom Scatter UDF; None uses the model's standard edge values.
    pub value_fn: Option<ValueFn>,
}

impl std::fmt::Debug for TrainConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TrainConfig")
            .field("model", &self.model)
            .field("geometry", &self.geometry)
            .field("steps", &self.steps)
            .field("lr", &self.lr)
            .field("layout", &self.layout)
            .field("custom_values", &self.value_fn.is_some())
            .finish()
    }
}

impl Default for TrainConfig {
    /// A GCN/SGD run on the built-in "tiny" geometry; set `steps` (and
    /// usually `model`/`geometry`) to taste — [`TrainConfig::quick`] does.
    fn default() -> TrainConfig {
        TrainConfig {
            model: GnnModel::Gcn,
            optimizer: Optimizer::Sgd,
            geometry: "tiny".to_string(),
            steps: 0,
            lr: 0.05,
            seed: 7,
            layout: LayoutOptions::all(),
            sampler_threads: 2,
            compute_threads: crate::util::threadpool::default_threads(),
            overflow: EdgeOverflow::TruncateKeepSelf,
            simulate: None,
            log_every: 0,
            value_fn: None,
        }
    }
}

impl TrainConfig {
    pub fn quick(model: GnnModel, geometry: &str, steps: usize) -> TrainConfig {
        TrainConfig { model, geometry: geometry.to_string(), steps, ..Default::default() }
    }

    /// Backend execution options for this config — what the session and
    /// evaluator hand to [`crate::runtime::Runtime::compile_role_with`].
    pub fn exec_options(&self) -> crate::runtime::ExecOptions {
        crate::runtime::ExecOptions { compute_threads: Some(self.compute_threads.max(1)) }
    }
}

/// Result of a training run.
#[derive(Debug)]
pub struct TrainReport {
    pub metrics: Metrics,
    pub final_weights: WeightState,
    /// Compile time of the artifact (once per process).
    pub compile_s: f64,
}

/// Run Algorithm 2 for `cfg.steps` iterations — the compat wrapper over
/// [`TrainingSession`] (`new` → `run_for` → `finish`).
///
/// Keeps the original borrowed `&Graph` signature for existing call
/// sites, which costs one graph deep-copy per call (sessions need owned
/// `Arc`s for their producer threads).  Long-lived or large-graph callers
/// should hold an `Arc<Graph>` and drive a [`TrainingSession`] directly.
pub fn train(
    runtime: &Runtime,
    graph: &Graph,
    sampler: &dyn Sampler,
    cfg: &TrainConfig,
) -> anyhow::Result<TrainReport> {
    let mut session = TrainingSession::new(
        runtime,
        Arc::new(graph.clone()),
        Arc::from(sampler.clone_box()),
        cfg.clone(),
    )?;
    // Fixed-length run: don't prefetch batches past the end.
    session.set_step_limit(cfg.steps);
    if cfg.log_every > 0 {
        let every = cfg.log_every;
        session.on_step(move |r| {
            if r.step > 0 && r.step % every == 0 {
                log::info!(
                    "step {}: loss {:.4}, exec {:.1} ms, prep {:.1} ms",
                    r.step,
                    r.loss,
                    r.exec_s * 1e3,
                    r.prep_s * 1e3
                );
            }
        });
    }
    session.run_for(cfg.steps)?;
    Ok(session.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_derives_from_default() {
        let q = TrainConfig::quick(GnnModel::Sage, "ns_small", 12);
        let d = TrainConfig::default();
        assert_eq!(q.model, GnnModel::Sage);
        assert_eq!(q.geometry, "ns_small");
        assert_eq!(q.steps, 12);
        assert_eq!(q.lr, d.lr);
        assert_eq!(q.seed, d.seed);
        assert_eq!(q.sampler_threads, d.sampler_threads);
        assert_eq!(q.optimizer, Optimizer::Sgd);
        assert!(q.simulate.is_none() && q.value_fn.is_none());
    }
}
