//! Model evaluation through the forward (inference) artifact.
//!
//! Samples held-out mini-batches, runs the AOT forward executable with the
//! trained weights, and scores argmax accuracy over the real (unmasked)
//! target vertices — the paper's accuracy claims ("same result and
//! accuracy as training in serial fashion", §2.2) are checked this way.
//!
//! The sample→pad→forward→argmax sequence itself lives in
//! [`crate::serve::infer`], shared with the serving worker pool, so the
//! evaluation and serving paths cannot drift.

use crate::graph::GraphAccess;
use crate::runtime::{Executable, Kind, Runtime, WeightState};
use crate::sampler::Sampler;
use crate::serve::infer::{self, InferOptions};
use crate::util::rng::Pcg64;

use super::trainer::TrainConfig;

/// Accuracy report over `batches` sampled evaluation batches.
#[derive(Debug, Clone)]
pub struct EvalReport {
    pub correct: usize,
    pub total: usize,
    pub batches: usize,
}

impl EvalReport {
    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.total.max(1) as f64
    }
}

/// Evaluate `weights` on freshly sampled batches (seeded independently of
/// training via `eval_seed`).  Compiles the forward artifact per call;
/// repeated evaluations (a session's `eval_every` loop) should compile
/// once and use [`evaluate_with`].
pub fn evaluate(
    runtime: &Runtime,
    graph: &dyn GraphAccess,
    sampler: &dyn Sampler,
    cfg: &TrainConfig,
    weights: &WeightState,
    batches: usize,
    eval_seed: u64,
) -> anyhow::Result<EvalReport> {
    let exe =
        runtime.compile_role_with(cfg.model, &cfg.geometry, Kind::Forward, &cfg.exec_options())?;
    evaluate_with(&exe, graph, sampler, cfg, weights, batches, eval_seed)
}

/// [`evaluate`] against an already-compiled forward [`Executable`].
pub fn evaluate_with(
    exe: &Executable,
    graph: &dyn GraphAccess,
    sampler: &dyn Sampler,
    cfg: &TrainConfig,
    weights: &WeightState,
    batches: usize,
    eval_seed: u64,
) -> anyhow::Result<EvalReport> {
    anyhow::ensure!(
        exe.spec.kind == Kind::Forward,
        "evaluate_with wants a Forward executable, got {:?}",
        exe.spec.kind
    );
    let opts = InferOptions::from_train(cfg);
    let mut rng = Pcg64::seed_from_u64(eval_seed);
    let mut correct = 0usize;
    let mut total = 0usize;
    for _ in 0..batches {
        let mb = sampler.sample(graph, &mut rng);
        let ib = infer::index_minibatch(graph, &mb, &opts);
        let inf = infer::infer_indexed(exe, graph, &opts, weights, &ib)?;
        for i in 0..inf.real_targets {
            total += 1;
            // A diverged model can emit NaN logits; `argmax` returns None
            // for those rows — count them incorrect rather than aborting
            // the whole evaluation.
            if let Some(pred) = infer::argmax(inf.row(i)) {
                correct += usize::from(pred as i32 == inf.labels[i]);
            }
        }
    }
    Ok(EvalReport { correct, total, batches })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generator, Graph};
    use crate::sampler::neighbor::NeighborSampler;
    use crate::sampler::values::GnnModel;

    fn setup() -> (Runtime, Graph, NeighborSampler, TrainConfig) {
        let mut g = generator::with_min_degree(
            generator::rmat(400, 3200, Default::default(), 5),
            1,
            6,
        );
        g.feat_dim = 16;
        g.num_classes = 4;
        let sampler = NeighborSampler::new(4, vec![5, 3]);
        let cfg = TrainConfig::quick(GnnModel::Gcn, "tiny", 0);
        (Runtime::reference(), g, sampler, cfg)
    }

    #[test]
    fn evaluate_scores_real_targets() {
        let (rt, g, sampler, cfg) = setup();
        let exe = rt.compile_role(cfg.model, &cfg.geometry, Kind::Forward).unwrap();
        let weights = WeightState::init_glorot(&exe.spec.weight_shapes, 3);
        let report = evaluate(&rt, &g, &sampler, &cfg, &weights, 2, 99).unwrap();
        assert_eq!(report.batches, 2);
        assert!(report.total > 0);
        assert!(report.correct <= report.total);
    }

    #[test]
    fn nan_logits_count_as_incorrect_instead_of_panicking() {
        let (rt, g, sampler, cfg) = setup();
        let exe = rt.compile_role(cfg.model, &cfg.geometry, Kind::Forward).unwrap();
        // NaN weights force NaN logits on every row — a diverged model.
        let mut weights = WeightState::init_glorot(&exe.spec.weight_shapes, 3);
        for (_, t) in weights.tensors.iter_mut() {
            for x in t.iter_mut() {
                *x = f32::NAN;
            }
        }
        let report =
            evaluate_with(&exe, &g, &sampler, &cfg, &weights, 2, 99).unwrap();
        assert!(report.total > 0);
        assert_eq!(report.correct, 0, "NaN rows must score as incorrect");
    }
}
