//! Training coordinator — the generated "host program" (paper Fig. 2).
//!
//! Runs Algorithm 2 with the paper's task schedule: mini-batch sampling on
//! a host thread pool, *overlapped* with accelerator execution of the
//! current batch (Eq. 5's `max(t_sampling, t_GNN)` emerges from the
//! pipeline).  Execution is the runtime backend's train step (pure-Rust
//! reference by default, AOT-compiled PJRT under `--features xla`);
//! per-batch accelerator timing optionally comes from the cycle-level
//! simulator.

pub mod eval;
pub mod metrics;
pub mod session;
pub mod trainer;

pub use eval::{evaluate, evaluate_with, EvalReport};
pub use session::{EvalEvent, StepReport, StepStages, TrainingSession};
pub use trainer::{train, TrainConfig, TrainReport};
