//! Mini-batch configuration closed forms — paper Table 2.
//!
//! The DSE engine never samples: it works from the *expected* per-layer
//! vertex and edge counts a sampling algorithm implies.  Neighbor sampling
//! has exact products; layer-wise and subgraph sampling need the graph
//! sparsity estimator κ(·), which the paper describes as "a pre-trained
//! function that estimates the graph sparsity based on sample size" —
//! [`KappaEstimator`] fits it per input graph from a handful of probe
//! subgraphs.

use crate::graph::Graph;
use crate::util::rng::Pcg64;

/// Expected per-layer batch shape (|B^l| for 0..=L, |E^l| for 1..=L).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchGeometry {
    pub b: Vec<usize>,
    pub e: Vec<usize>,
}

impl BatchGeometry {
    pub fn layers(&self) -> usize {
        self.e.len()
    }

    /// NVTPS numerator (Eq. 4).
    pub fn vertices_traversed(&self) -> usize {
        self.b.iter().sum()
    }

    /// Table 2 row 1 — neighbor sampling with target count `t` and
    /// fan-outs `ns[l-1] = NS^l` (self loops included, matching the
    /// samplers).
    pub fn neighbor(t: usize, ns: &[usize]) -> BatchGeometry {
        let ll = ns.len();
        let mut b = vec![0usize; ll + 1];
        b[ll] = t;
        for l in (0..ll).rev() {
            b[l] = b[l + 1] * (ns[l] + 1);
        }
        let e = (1..=ll).map(|l| b[l] * (ns[l - 1] + 1)).collect();
        BatchGeometry { b, e }
    }

    /// Neighbor sampling with *dedup capping*: `|B^l|` is the expected
    /// number of **unique** vertices among the `b[l+1]·(ns+1)` draws from a
    /// graph of `num_vertices` (birthday estimate `V(1 − e^{−k/V})`).
    /// Edges are not deduped — this gap between |E^l| and |B^{l-1}| is
    /// precisely what the RMT optimization exploits (paper §4.1: "|E_1| is
    /// usually larger than |B_0|").
    pub fn neighbor_capped(t: usize, ns: &[usize], num_vertices: usize) -> BatchGeometry {
        let raw = Self::neighbor(t, ns);
        let v = num_vertices as f64;
        let unique = |k: usize| -> usize {
            let k = k as f64;
            (v * (1.0 - (-k / v).exp())).round().max(1.0) as usize
        };
        let ll = ns.len();
        let mut b = vec![0usize; ll + 1];
        b[ll] = t.min(num_vertices);
        for l in (0..ll).rev() {
            b[l] = unique(b[l + 1] * (ns[l] + 1)).min(raw.b[l]);
        }
        let e = (1..=ll).map(|l| b[l] * (ns[l - 1] + 1)).collect();
        BatchGeometry { b, e }
    }

    /// Table 2 row 3 — subgraph sampling with budget `sb`:
    /// every layer `sb` vertices, `sb · κ(sb)` edges.
    pub fn subgraph(sb: usize, layers: usize, kappa: &KappaEstimator) -> BatchGeometry {
        let e_per_layer = (sb as f64 * kappa.kappa(sb)) as usize + sb;
        BatchGeometry { b: vec![sb; layers + 1], e: vec![e_per_layer; layers] }
    }

    /// Table 2 row 2 — layer-wise sampling with per-layer sizes `s`
    /// (`s[l]` for layer l, targets `s[L]`): |E^l| = S^l S^{l-1} κ(S^l)/SB.
    pub fn layerwise(s: &[usize], kappa: &KappaEstimator) -> BatchGeometry {
        assert!(s.len() >= 2);
        let b = s.to_vec();
        let e = (1..s.len())
            .map(|l| {
                let dens = kappa.kappa(s[l]) / s[l] as f64; // pairwise density
                (s[l] as f64 * s[l - 1] as f64 * dens) as usize + s[l]
            })
            .collect();
        BatchGeometry { b, e }
    }
}

/// κ(s): expected *edges per sampled vertex* in an induced subgraph of
/// size s.  Fitted as κ(s) = c · s (induced-subgraph density grows
/// linearly in s for uniform-ish sampling: each of the s vertices keeps a
/// fraction ~s/|V| of its degree) with a degree-weighted correction
/// measured from probe subgraphs.
#[derive(Debug, Clone, Copy)]
pub struct KappaEstimator {
    /// κ(s) ≈ slope · s  (+ intercept, usually ~0).
    pub slope: f64,
    pub intercept: f64,
}

impl KappaEstimator {
    /// Fit from `probes` induced subgraphs of varying size, degree-weighted
    /// like the GraphSAINT node sampler.
    pub fn fit(g: &Graph, probe_sizes: &[usize], seed: u64) -> KappaEstimator {
        use crate::sampler::subgraph::SubgraphSampler;
        use crate::sampler::Sampler;
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for (i, &s) in probe_sizes.iter().enumerate() {
            let mut sampler = SubgraphSampler::new(s.min(g.num_vertices()), 1);
            sampler.probability = crate::sampler::subgraph::NodeProbability::DegreeCapped(3.0);
            let mb = sampler.sample(g, &mut Pcg64::seed_from_u64(seed ^ i as u64));
            let edges = mb.edges[0].len().saturating_sub(mb.layers[0].len()); // minus self loops
            let sv = mb.layers[0].len() as f64;
            xs.push(sv);
            ys.push(edges as f64 / sv.max(1.0)); // κ at this size
        }
        // Least-squares line through (s, κ(s)).
        let n = xs.len() as f64;
        let mx = xs.iter().sum::<f64>() / n;
        let my = ys.iter().sum::<f64>() / n;
        let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
        let sxy: f64 = xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum();
        let slope = if sxx > 0.0 { sxy / sxx } else { 0.0 };
        let intercept = my - slope * mx;
        KappaEstimator { slope: slope.max(0.0), intercept: intercept.max(0.0) }
    }

    /// From a dataset's global statistics when no instance is materialized
    /// (paper-scale DSE): degree-weighted survival ≈ 2.5 · d̄ · s / |V|.
    pub fn from_stats(nodes: usize, edges: usize) -> KappaEstimator {
        let avg_deg = edges as f64 / nodes as f64;
        KappaEstimator { slope: 2.5 * avg_deg / nodes as f64, intercept: 0.0 }
    }

    pub fn kappa(&self, s: usize) -> f64 {
        self.intercept + self.slope * s as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;

    #[test]
    fn neighbor_matches_paper_products() {
        // Paper config: t=1024, NS=[25 (1-hop), 10 (2-hop)] -> budgets
        // ordered [NS^1, NS^2] = [10, 25].
        let g = BatchGeometry::neighbor(1024, &[10, 25]);
        assert_eq!(g.b[2], 1024);
        assert_eq!(g.b[1], 1024 * 26);
        assert_eq!(g.b[0], 1024 * 26 * 11);
        assert_eq!(g.e[1], 1024 * 26);
        assert_eq!(g.e[0], 1024 * 26 * 11);
        assert_eq!(g.vertices_traversed(), 1024 + 26624 + 292864);
    }

    #[test]
    fn subgraph_all_layers_equal() {
        let kappa = KappaEstimator { slope: 0.01, intercept: 0.0 };
        let g = BatchGeometry::subgraph(2750, 2, &kappa);
        assert_eq!(g.b, vec![2750; 3]);
        let want = (2750.0 * 0.01 * 2750.0) as usize + 2750;
        assert_eq!(g.e, vec![want; 2]);
    }

    #[test]
    fn kappa_fit_recovers_linear_density() {
        // On a uniform graph, induced edges/vertex grows ~linearly in s.
        let g = generator::uniform(3000, 60_000, true, 31);
        let est = KappaEstimator::fit(&g, &[200, 400, 800, 1600], 7);
        assert!(est.slope > 0.0, "slope {}", est.slope);
        // Predicted κ at s=1000 within 3x of a fresh measurement.
        use crate::sampler::subgraph::SubgraphSampler;
        use crate::sampler::Sampler;
        let mb = SubgraphSampler::new(1000, 1).sample(&g, &mut Pcg64::seed_from_u64(99));
        let measured = (mb.edges[0].len() - 1000) as f64 / 1000.0;
        let predicted = est.kappa(1000);
        assert!(
            predicted / measured < 3.0 && measured / predicted < 3.0,
            "predicted {predicted}, measured {measured}"
        );
    }

    #[test]
    fn kappa_from_stats_scales_with_density() {
        let sparse = KappaEstimator::from_stats(100_000, 1_000_000);
        let dense = KappaEstimator::from_stats(100_000, 10_000_000);
        assert!(dense.kappa(2750) > sparse.kappa(2750) * 5.0);
    }

    #[test]
    fn layerwise_edges_between_layers() {
        let kappa = KappaEstimator { slope: 0.02, intercept: 0.0 };
        let g = BatchGeometry::layerwise(&[400, 200, 100], &kappa);
        assert_eq!(g.b, vec![400, 200, 100]);
        assert_eq!(g.layers(), 2);
        assert!(g.e[0] > 200 && g.e[1] > 100);
    }
}
