//! Resource utilization model — paper §5.2, Eq. 10–11.
//!
//! DSPs grow linearly with the PE counts; LUTs add the `n log n` butterfly
//! routing term.  The per-PE coefficients (λ, ρ) are calibrated so the
//! paper's chosen configuration (m, n) = (256, 4) reproduces Table 5's
//! utilization on the U250 (DSP ≈ 70%, LUT ≈ 50% for NS-GCN).
//!
//! URAM holds the gather-side result banks (sized by the largest per-die
//! layer slab at the kernel's feature-tile width); BRAM holds the Weight
//! Buffer and stream FIFOs.

use crate::accel::platform::Platform;
use crate::accel::AccelConfig;

use super::batchgeom::BatchGeometry;
use super::model::ModelShape;

/// λ/ρ coefficients of Eq. 10–11 (per-die).
#[derive(Debug, Clone, Copy)]
pub struct ResourceCoefficients {
    /// DSPs per MAC unit (f32 multiply-add).
    pub lambda1: f64,
    /// DSPs per Scatter+Gather PE pair (16 f32 lanes).
    pub lambda2: f64,
    /// LUTs per MAC unit.
    pub rho1: f64,
    /// LUTs per PE pair (control + RAW resolver).
    pub rho2: f64,
    /// LUTs per butterfly port-stage (× n log2 n).
    pub rho3: f64,
}

impl Default for ResourceCoefficients {
    fn default() -> Self {
        // Calibrated against Table 5 at (m, n) = (256, 4):
        //   DSP: 8·256 + 25·4 = 2148 / 3072 ≈ 70 %
        //   LUT: 600·256 + 10000·4 + 2000·(4·2) = 209 600 / 423 000 ≈ 50 %
        ResourceCoefficients {
            lambda1: 8.0,
            lambda2: 25.0,
            rho1: 600.0,
            rho2: 10_000.0,
            rho3: 2_000.0,
        }
    }
}

/// Utilization report for one candidate configuration on one die.
#[derive(Debug, Clone, Copy, Default)]
pub struct Utilization {
    pub dsp: f64,
    pub lut: f64,
    pub uram: f64,
    pub bram: f64,
}

impl Utilization {
    pub fn fits(&self) -> bool {
        self.dsp <= 1.0 && self.lut <= 1.0 && self.uram <= 1.0 && self.bram <= 1.0
    }
}

/// Eq. 10: λ1·m + λ2·n ≤ N_DSP.
pub fn dsp_usage(c: &ResourceCoefficients, config: &AccelConfig) -> f64 {
    c.lambda1 * config.m as f64 + c.lambda2 * config.n as f64
}

/// Eq. 11: ρ1·m + ρ2·n + ρ3·n·log2(n) ≤ N_LUT.
pub fn lut_usage(c: &ResourceCoefficients, config: &AccelConfig) -> f64 {
    let n = config.n as f64;
    let logn = if config.n > 1 { (config.n as f64).log2() } else { 0.0 };
    c.rho1 * config.m as f64 + c.rho2 * n + c.rho3 * n * logn
}

/// Full per-die utilization including the memory blocks (Table 5 rows).
pub fn utilization(
    platform: &Platform,
    coeff: &ResourceCoefficients,
    config: &AccelConfig,
    geom: &BatchGeometry,
    model: &ModelShape,
) -> Utilization {
    let dies = platform.dies.max(1);
    // Result banks: biggest per-die (rows × feature-tile) slab across
    // layers, double-buffered, in URAM (288 Kb = 36 KiB blocks).
    const FEATURE_TILE: usize = 128;
    let max_slab_bytes = (1..geom.b.len())
        .map(|l| {
            let rows = geom.b[l].div_ceil(dies);
            rows * FEATURE_TILE.min(model.feat[l]) * 4
        })
        .max()
        .unwrap_or(0)
        * 2; // double buffering
    let uram_blocks = max_slab_bytes.div_ceil(36 * 1024);

    // Weight buffer + edge/feature FIFOs in BRAM (36 Kb = 4.5 KiB blocks).
    let weight_bytes: usize = (1..model.feat.len())
        .map(|l| {
            let fin = if model.sage_concat { 2 * model.feat[l - 1] } else { model.feat[l - 1] };
            fin * model.feat[l] * 4
        })
        .max()
        .unwrap_or(0);
    let fifo_bytes = config.n * 16 * 4 * 64; // per-PE stream FIFOs
    let bram_blocks = (weight_bytes + fifo_bytes).div_ceil(4608) + 2 * config.n;

    Utilization {
        dsp: dsp_usage(coeff, config) / platform.dsp_per_die as f64,
        lut: lut_usage(coeff, config) / platform.lut_per_die as f64,
        uram: uram_blocks as f64 / platform.uram_per_die as f64,
        bram: bram_blocks as f64 / platform.bram_per_die as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_setup() -> (Platform, ResourceCoefficients, BatchGeometry, ModelShape) {
        (
            Platform::alveo_u250(),
            ResourceCoefficients::default(),
            BatchGeometry::neighbor(1024, &[10, 25]),
            ModelShape { feat: vec![500, 256, 7], sage_concat: false },
        )
    }

    #[test]
    fn table5_dsp_lut_calibration() {
        let (p, c, g, m) = paper_setup();
        let u = utilization(&p, &c, &AccelConfig { n: 4, m: 256 }, &g, &m);
        // Paper Table 5, NS-GCN column: DSP 70 %, LUT 50 %.
        assert!((u.dsp - 0.70).abs() < 0.03, "dsp {}", u.dsp);
        assert!((u.lut - 0.50).abs() < 0.05, "lut {}", u.lut);
        assert!(u.fits());
    }

    #[test]
    fn ns_uses_more_uram_than_ss() {
        let (p, c, ns, m) = paper_setup();
        let kappa = super::super::batchgeom::KappaEstimator::from_stats(232_965, 11_606_919);
        let ss = BatchGeometry::subgraph(2750, 2, &kappa);
        let cfg = AccelConfig { n: 4, m: 256 };
        let u_ns = utilization(&p, &c, &cfg, &ns, &m);
        let u_ss = utilization(&p, &c, &cfg, &ss, &m);
        // Paper Table 5: URAM 34 % (NS) vs 14 % (SS-GCN).
        assert!(u_ns.uram > u_ss.uram * 1.5, "ns {} ss {}", u_ns.uram, u_ss.uram);
    }

    #[test]
    fn lut_has_nlogn_routing_term() {
        let c = ResourceCoefficients::default();
        let base = lut_usage(&c, &AccelConfig { n: 4, m: 0 });
        let double = lut_usage(&c, &AccelConfig { n: 8, m: 0 });
        // More than linear: 8/4 = 2, but routing adds n log n.
        assert!(double > base * 2.0);
    }

    #[test]
    fn oversized_config_rejected() {
        let (p, c, g, m) = paper_setup();
        let u = utilization(&p, &c, &AccelConfig { n: 64, m: 4096 }, &g, &m);
        assert!(!u.fits());
        assert!(u.dsp > 1.0);
    }

    #[test]
    fn dsp_linear_in_m_and_n() {
        let c = ResourceCoefficients::default();
        let a = dsp_usage(&c, &AccelConfig { n: 2, m: 64 });
        let b = dsp_usage(&c, &AccelConfig { n: 4, m: 128 });
        assert!((b - 2.0 * a).abs() < 1e-9);
    }
}
