//! Analytic performance model — paper §5.1, Eq. 4–9.
//!
//! Closed-form timing used by the DSE engine's exhaustive sweep (the
//! cycle-level simulator in [`crate::accel`] replays real edge streams and
//! is used to *validate* these formulas — see `rust/tests/model_vs_sim.rs`).

use crate::accel::platform::Platform;
use crate::accel::AccelConfig;
use crate::layout::LayoutOptions;

use super::batchgeom::BatchGeometry;

/// GNN-model-dependent knobs of the analytic model.
#[derive(Debug, Clone)]
pub struct ModelShape {
    /// Feature dims f^0..f^L.
    pub feat: Vec<usize>,
    /// GraphSAGE concat doubles the update fan-in.
    pub sage_concat: bool,
}

/// Analytic per-layer timing (seconds).
#[derive(Debug, Clone, Default)]
pub struct LayerEstimate {
    pub t_load: f64,
    pub t_compute: f64,
    pub t_aggregate: f64,
    pub t_update: f64,
}

impl LayerEstimate {
    pub fn time(&self) -> f64 {
        self.t_aggregate.max(self.t_update)
    }
}

/// Analytic iteration timing (Eq. 5 components).
#[derive(Debug, Clone, Default)]
pub struct Estimate {
    pub layers: Vec<LayerEstimate>,
    pub t_fp: f64,
    pub t_bp: f64,
    pub t_lc: f64,
    pub t_wu: f64,
    pub t_gnn: f64,
}

impl Estimate {
    /// Eq. 4 + Eq. 5: NVTPS with sampling overlapped.
    pub fn nvtps(&self, geom: &BatchGeometry, t_sampling: f64) -> f64 {
        geom.vertices_traversed() as f64 / self.t_gnn.max(t_sampling)
    }
}

/// Evaluate Eq. 4–9 for one (platform, config, batch-shape, model) tuple.
///
/// The per-die split follows Fig. 7: vertices and edges are divided evenly
/// over `platform.dies` kernel copies and the layer completes when the
/// slowest die finishes — even division makes that the per-die time.
pub fn estimate(
    platform: &Platform,
    config: &AccelConfig,
    geom: &BatchGeometry,
    model: &ModelShape,
    layout: LayoutOptions,
) -> Estimate {
    let ll = geom.layers();
    assert_eq!(model.feat.len(), ll + 1, "need L+1 feature dims");
    let dies = platform.dies.max(1) as f64;
    let freq = platform.freq_hz;
    let bw = platform.bw_per_channel_gbps * 1e9;
    let lanes = 16.0;

    let mut est = Estimate::default();
    for l in 1..=ll {
        let f_prev = model.feat[l - 1] as f64;
        let f_cur = model.feat[l] as f64;
        let b_prev = geom.b[l - 1] as f64 / dies;
        let b_cur = geom.b[l] as f64 / dies;
        let e_l = geom.e[l - 1] as f64 / dies;

        // Eq. 8 load: RMT dedups per-edge loads into per-vertex loads;
        // without it every edge fetches its source row.
        let rows_loaded = if layout.rmt { b_prev } else { e_l };
        // α: layer-1 reads X (random row order regardless of sort);
        // hidden layers are sequential only with renaming (RRA).
        let sequential = l > 1 && layout.rmt && layout.rra;
        let alpha = platform.alpha(f_prev * 4.0, sequential);
        // Remote-channel share through the all-to-all interconnect.
        let remote = 1.0 - 1.0 / dies;
        let eff = (1.0 - remote) + remote / platform.cross_channel_efficiency;
        let t_load = rows_loaded * f_prev * 4.0 * eff / (bw * alpha);

        // Eq. 8 compute: n scatter PEs × 16 lanes per cycle.
        let t_compute = e_l * f_prev / (config.n as f64 * lanes * freq);

        // Eq. 9 update: m MACs, DSP-double-pumped (2 MACs per kernel
        // cycle — see accel::update::DSP_PUMP).
        let f_in_upd = if model.sage_concat { 2.0 * f_prev } else { f_prev };
        let pump = crate::accel::update::DSP_PUMP as f64;
        let t_update = b_cur * f_in_upd * f_cur / (config.m as f64 * pump * freq);

        est.layers.push(LayerEstimate {
            t_load,
            t_compute,
            t_aggregate: t_load.max(t_compute),
            t_update,
        });
    }

    // Eq. 6.
    est.t_fp = est.layers.iter().map(|e| e.time()).sum();
    est.t_bp = est.layers[0].t_update
        + est.layers[1..].iter().map(|e| e.time()).sum::<f64>();

    // Host-side stages (same model as the simulator — loss over targets,
    // SGD over the weights).
    let host = &platform.host;
    let targets = geom.b[ll] as f64;
    let classes = model.feat[ll] as f64;
    est.t_lc = targets * classes * 8.0 / (0.1 * host.peak_gflops * 1e9)
        + targets * classes * 4.0 / (host.mem_bw_gbps * 1e9);
    let params: f64 = (1..=ll)
        .map(|l| {
            let fin = if model.sage_concat { 2 * model.feat[l - 1] } else { model.feat[l - 1] };
            (fin * model.feat[l] + model.feat[l]) as f64
        })
        .sum();
    est.t_wu = params * 2.0 / (0.1 * host.peak_gflops * 1e9)
        + params * 12.0 / (host.mem_bw_gbps * 1e9);

    est.t_gnn = est.t_fp + est.t_lc + est.t_bp + est.t_wu;
    est
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Platform, AccelConfig, BatchGeometry, ModelShape) {
        (
            Platform::alveo_u250(),
            AccelConfig::paper_default(),
            BatchGeometry::neighbor_capped(1024, &[10, 25], 89_250),
            ModelShape { feat: vec![500, 256, 7], sage_concat: false },
        )
    }

    #[test]
    fn estimate_composes_eq5() {
        let (p, c, g, m) = setup();
        let e = estimate(&p, &c, &g, &m, LayoutOptions::all());
        assert!((e.t_gnn - (e.t_fp + e.t_lc + e.t_bp + e.t_wu)).abs() < 1e-15);
        assert_eq!(e.layers.len(), 2);
        for l in &e.layers {
            assert!(l.t_load > 0.0 && l.t_compute > 0.0 && l.t_update > 0.0);
        }
    }

    #[test]
    fn rmt_reduces_load_time() {
        let (p, c, g, m) = setup();
        let base = estimate(&p, &c, &g, &m, LayoutOptions::none());
        let rmt = estimate(&p, &c, &g, &m, LayoutOptions { rmt: true, rra: false });
        assert!(rmt.layers[0].t_load < base.layers[0].t_load);
    }

    #[test]
    fn rra_speeds_hidden_layer_loads() {
        let (p, c, g, m) = setup();
        let rmt = estimate(&p, &c, &g, &m, LayoutOptions { rmt: true, rra: false });
        let all = estimate(&p, &c, &g, &m, LayoutOptions::all());
        // Layer 1 (input X) unchanged; layer 2 load faster with RRA.
        assert!((all.layers[0].t_load - rmt.layers[0].t_load).abs() < 1e-12);
        assert!(all.layers[1].t_load < rmt.layers[1].t_load);
    }

    #[test]
    fn nvtps_in_paper_ballpark() {
        // NS-GCN on Flickr-like dims: paper reports 16.38M NVTPS.  The
        // analytic model should land within ~3x (shape, not absolutes).
        let (p, c, g, m) = setup();
        let e = estimate(&p, &c, &g, &m, LayoutOptions::all());
        let nvtps = e.nvtps(&g, 0.0);
        assert!(
            (5.0e6..60.0e6).contains(&nvtps),
            "NVTPS {nvtps:.3e} out of plausible range"
        );
    }

    #[test]
    fn more_parallelism_helps_until_memory_bound() {
        let (p, _c, g, m) = setup();
        let lo = estimate(&p, &AccelConfig { n: 1, m: 16 }, &g, &m, LayoutOptions::all());
        let hi = estimate(&p, &AccelConfig { n: 16, m: 1024 }, &g, &m, LayoutOptions::all());
        assert!(hi.t_gnn < lo.t_gnn);
        // But load time is config-independent (memory bound floor).
        assert!((hi.layers[0].t_load - lo.layers[0].t_load).abs() < 1e-12);
    }

    #[test]
    fn sage_update_twice_gcn() {
        let (p, c, g, _) = setup();
        let gcn = ModelShape { feat: vec![500, 256, 7], sage_concat: false };
        let sage = ModelShape { feat: vec![500, 256, 7], sage_concat: true };
        let eg = estimate(&p, &c, &g, &gcn, LayoutOptions::all());
        let es = estimate(&p, &c, &g, &sage, LayoutOptions::all());
        assert!((es.layers[0].t_update / eg.layers[0].t_update - 2.0).abs() < 1e-9);
    }

    #[test]
    fn sampling_bottleneck_caps_nvtps() {
        let (p, c, g, m) = setup();
        let e = estimate(&p, &c, &g, &m, LayoutOptions::all());
        let free = e.nvtps(&g, 0.0);
        let capped = e.nvtps(&g, e.t_gnn * 4.0);
        assert!((capped - free / 4.0).abs() / free < 1e-9);
    }
}
