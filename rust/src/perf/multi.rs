//! Multi-FPGA scaling — the paper's stated future work (§8: "we plan to
//! extend our framework to multi-FPGA platforms by exploiting model
//! parallelism").
//!
//! Two strategies over `boards` identical U250-class cards attached to one
//! host:
//!
//! * **Data parallel**: each board trains a distinct mini-batch; the host
//!   all-reduces weight gradients each iteration.  Throughput scales with
//!   board count until host sampling or the all-reduce binds.
//! * **Model parallel** (the paper's §8 proposal): consecutive GNN layers
//!   are placed on consecutive boards; activations cross the inter-board
//!   link between stages.  With mini-batches pipelined, steady-state
//!   throughput is set by the slowest stage (layer time + transfer).

use crate::accel::platform::Platform;

use super::batchgeom::BatchGeometry;
use super::model::{Estimate, ModelShape};

/// Inter-board interconnect (PCIe peer-to-peer or direct serial links).
#[derive(Debug, Clone, Copy)]
pub struct MultiFpga {
    pub boards: usize,
    /// Effective board-to-board bandwidth (GB/s).
    pub link_gbps: f64,
}

impl MultiFpga {
    pub fn pcie(boards: usize) -> MultiFpga {
        MultiFpga { boards, link_gbps: 12.0 }
    }
}

/// Scaling outcome for one strategy.
#[derive(Debug, Clone)]
pub struct ScalingPoint {
    pub boards: usize,
    pub nvtps: f64,
    /// What binds at this point: "compute", "sampling", "allreduce",
    /// "host-mem" (data parallel) or "link" (model parallel).
    pub bottleneck: &'static str,
}

/// Data-parallel scaling: `single` is the one-board Eq. 4–9 estimate,
/// `t_sampling_single` the single-thread host sampling time per batch and
/// `sampler_threads` the host pool size (shared by all boards).
pub fn data_parallel(
    single: &Estimate,
    geom: &BatchGeometry,
    model: &ModelShape,
    platform: &Platform,
    fabric: MultiFpga,
    t_sampling_single: f64,
    sampler_threads: usize,
) -> ScalingPoint {
    let boards = fabric.boards.max(1) as f64;
    // All-reduce over PCIe through the host: each board ships its gradient
    // and receives averaged weights (2 transfers, tree through host RAM).
    let params: f64 = (1..model.feat.len())
        .map(|l| {
            let fin = if model.sage_concat { 2 * model.feat[l - 1] } else { model.feat[l - 1] };
            (fin * model.feat[l] + model.feat[l]) as f64
        })
        .sum();
    let t_allreduce = 2.0 * params * 4.0 * boards / (fabric.link_gbps * 1e9);
    // Host sampling must now feed `boards` batches per iteration.
    let t_sampling = t_sampling_single * boards / sampler_threads.max(1) as f64;
    let t_board = single.t_gnn + t_allreduce;
    let t_iter = t_board.max(t_sampling);
    let host_mem_bound = params * 12.0 * boards / (platform.host.mem_bw_gbps * 1e9);
    let t_iter = t_iter.max(host_mem_bound);
    let bottleneck = if t_iter <= t_board + 1e-15 {
        if t_allreduce > single.t_gnn { "allreduce" } else { "compute" }
    } else if t_sampling >= host_mem_bound {
        "sampling"
    } else {
        // Host memory bandwidth is the binding term: the all-reduce tree
        // saturates host RAM (read grad + write sum + read back per
        // board), not the PCIe links.
        "host-mem"
    };
    ScalingPoint {
        boards: fabric.boards,
        nvtps: boards * geom.vertices_traversed() as f64 / t_iter,
        bottleneck,
    }
}

/// Model-parallel scaling: layer `l` lives on board `l % boards`; with
/// pipelined mini-batches the iteration rate is set by the slowest stage
/// (its forward+backward layer time plus the activation transfer).
pub fn model_parallel(
    single: &Estimate,
    geom: &BatchGeometry,
    model: &ModelShape,
    fabric: MultiFpga,
) -> ScalingPoint {
    let boards = fabric.boards.max(1).min(single.layers.len());
    // Assign layers round-robin to boards; a stage's time is the sum of
    // its layers' (fwd + bwd) pipelined times.
    let mut stage_time = vec![0.0f64; boards];
    for (l, est) in single.layers.iter().enumerate() {
        stage_time[l % boards] += 2.0 * est.time(); // fwd + bwd
    }
    // Activation transfer between consecutive layers on different boards:
    // b[l] x f[l] activations forward + the same gradient backward.
    let mut link_time = 0.0f64;
    for l in 1..single.layers.len() {
        if boards > 1 && (l % boards) != ((l - 1) % boards) {
            let bytes = geom.b[l] as f64 * model.feat[l] as f64 * 4.0;
            link_time = link_time.max(2.0 * bytes / (fabric.link_gbps * 1e9));
        }
    }
    let slowest = stage_time.iter().cloned().fold(0.0, f64::max);
    let t_stage = slowest.max(link_time) + single.t_lc + single.t_wu;
    let bottleneck = if link_time > slowest { "link" } else { "compute" };
    ScalingPoint {
        boards: fabric.boards,
        nvtps: geom.vertices_traversed() as f64 / t_stage,
        bottleneck,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::AccelConfig;
    use crate::layout::LayoutOptions;
    use crate::perf::estimate;

    fn setup() -> (Platform, Estimate, BatchGeometry, ModelShape) {
        let p = Platform::alveo_u250();
        let geom = BatchGeometry::neighbor_capped(1024, &[10, 25], 232_965);
        let model = ModelShape { feat: vec![602, 256, 41], sage_concat: false };
        let est = estimate(&p, &AccelConfig::paper_default(), &geom, &model, LayoutOptions::all());
        (p, est, geom, model)
    }

    #[test]
    fn data_parallel_scales_until_sampling_binds() {
        let (p, est, geom, model) = setup();
        // Generous sampler pool: near-linear scaling.
        let one = data_parallel(&est, &geom, &model, &p, MultiFpga::pcie(1), 5e-3, 64);
        let four = data_parallel(&est, &geom, &model, &p, MultiFpga::pcie(4), 5e-3, 64);
        assert!(four.nvtps > one.nvtps * 3.0, "{} vs {}", four.nvtps, one.nvtps);
        // Starved sampler pool: scaling saturates and sampling is named.
        let starved = data_parallel(&est, &geom, &model, &p, MultiFpga::pcie(8), 50e-3, 1);
        assert_eq!(starved.bottleneck, "sampling");
        let starved4 = data_parallel(&est, &geom, &model, &p, MultiFpga::pcie(4), 50e-3, 1);
        assert!(
            (starved.nvtps / starved4.nvtps - 1.0).abs() < 0.05,
            "sampling-bound scaling should flatline: {} vs {}",
            starved.nvtps,
            starved4.nvtps
        );
    }

    #[test]
    fn host_memory_saturation_is_named_host_mem() {
        let (mut p, est, geom, model) = setup();
        // Starve host memory bandwidth so the all-reduce's RAM traffic —
        // not sampling, not the links — binds.
        p.host.mem_bw_gbps = 1e-3;
        let point = data_parallel(&est, &geom, &model, &p, MultiFpga::pcie(4), 5e-3, 64);
        assert_eq!(point.bottleneck, "host-mem");
        // And it is genuinely the iteration-time term: healthy host memory
        // on the same configuration is strictly faster.
        let (healthy, ..) = setup();
        let fast = data_parallel(&est, &geom, &model, &healthy, MultiFpga::pcie(4), 5e-3, 64);
        assert!(fast.nvtps > point.nvtps * 10.0, "{} vs {}", fast.nvtps, point.nvtps);
    }

    #[test]
    fn model_parallel_bounded_by_slowest_stage() {
        let (_p, est, geom, model) = setup();
        let one = model_parallel(&est, &geom, &model, MultiFpga::pcie(1));
        let two = model_parallel(&est, &geom, &model, MultiFpga::pcie(2));
        // Two stages can't beat the slowest layer: speedup <= 2 and >= 1.
        assert!(two.nvtps >= one.nvtps * 0.99);
        assert!(two.nvtps <= one.nvtps * 2.01);
        // A starved link flips the bottleneck.
        let slow_link = model_parallel(
            &est,
            &geom,
            &model,
            MultiFpga { boards: 2, link_gbps: 0.05 },
        );
        assert_eq!(slow_link.bottleneck, "link");
        assert!(slow_link.nvtps < two.nvtps);
    }

    #[test]
    fn data_parallel_beats_model_parallel_for_balanced_small_models() {
        // The standard result the paper's future-work section implies: for
        // a 2-layer GNN, data parallelism wins unless memory forces the
        // model split.
        let (p, est, geom, model) = setup();
        let dp = data_parallel(&est, &geom, &model, &p, MultiFpga::pcie(2), 5e-3, 64);
        let mp = model_parallel(&est, &geom, &model, MultiFpga::pcie(2));
        assert!(dp.nvtps > mp.nvtps);
    }
}
