//! Performance & resource models (paper §5.1–5.2).
//!
//! * [`batchgeom`] — Table 2 closed forms for |B^l| / |E^l| per sampler,
//!   with the κ(·) sparsity estimator.
//! * [`model`] — Eq. 4–9 analytic throughput model (what the DSE sweeps).
//! * [`resource`] — Eq. 10–11 DSP/LUT constraints + URAM/BRAM accounting
//!   (Table 5's utilization rows).

pub mod batchgeom;
pub mod model;
pub mod multi;
pub mod resource;

pub use batchgeom::{BatchGeometry, KappaEstimator};
pub use model::{estimate, Estimate, ModelShape};
pub use multi::{data_parallel, model_parallel, MultiFpga, ScalingPoint};
pub use resource::{utilization, ResourceCoefficients, Utilization};
