//! Comparison baselines for Tables 7 and 8.
//!
//! * [`cpu`] — the CPU-only platform: an *executed* rust implementation of
//!   mini-batch GNN training (for laptop-scale measurements) plus an
//!   analytic model of the paper's PyG/3990x baseline (for paper-scale
//!   rows).
//! * [`gpu`] — analytic CPU-GPU (A100) model: host-side sampling pipeline,
//!   kernel-launch overhead, roofline compute.  We have no GPU (DESIGN.md
//!   §2), so this row is model-only, calibrated to Table 7's published
//!   measurements.
//! * [`sota`] — GraphACT and Rubik models for Table 8, built from the
//!   specs that table publishes (bandwidth, on-chip memory, parallelism
//!   limits).
//!
//! Calibration constants are grouped in [`Calibration`] with the Table 7
//! row used to pin each one; every model is a *shape* reproduction — who
//! wins and by roughly what factor — not an absolute-number claim.

pub mod cpu;
pub mod gpu;
pub mod sota;

/// Empirical efficiency constants for the analytic baselines, each pinned
/// against a published measurement.
#[derive(Debug, Clone, Copy)]
pub struct Calibration {
    /// CPU sparse-aggregation effective-bandwidth fraction (PyG
    /// scatter_add over 2 KB rows; pinned to Table 7 FL/NS-GCN CPU row).
    pub cpu_gather_bw_eff: f64,
    /// CPU dense-matmul fraction of peak (PyG f32 on 3990x).
    pub cpu_dense_eff: f64,
    /// GPU sparse-aggregation effective-bandwidth fraction (A100 HBM).
    pub gpu_gather_bw_eff: f64,
    /// GPU dense fraction of peak.
    pub gpu_dense_eff: f64,
    /// Per-iteration framework/launch overhead on the GPU path (s).
    pub gpu_iteration_overhead: f64,
    /// Host-side sampling cost per edge, single thread (s) — PyG
    /// NeighborSampler class; dominates the GPU rows of Table 7.
    pub host_sampling_per_edge: f64,
    /// Sampler worker processes the PyG baselines use.
    pub host_sampling_workers: f64,
}

impl Default for Calibration {
    fn default() -> Self {
        Calibration {
            cpu_gather_bw_eff: 0.02,
            cpu_dense_eff: 0.008,
            gpu_gather_bw_eff: 0.05,
            gpu_dense_eff: 0.10,
            gpu_iteration_overhead: 8e-3,
            host_sampling_per_edge: 1.0e-6,
            host_sampling_workers: 4.0,
        }
    }
}
