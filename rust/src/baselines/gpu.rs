//! CPU-GPU baseline model (Table 7's middle column; A100 from Table 3).
//!
//! No GPU exists in this environment, so this row is analytic (DESIGN.md
//! §2).  The model captures the three effects the paper attributes the
//! CPU-GPU numbers to:
//!
//! 1. host-side mini-batch sampling (PyG dataloader workers) that the GPU
//!    cannot overlap away — dominates the NS rows;
//! 2. per-iteration framework/launch overhead — dominates the SS rows
//!    (small batches, Table 7 shows only 3.5–5.6x over CPU);
//! 3. aggregation's irregular memory access paying a small fraction of
//!    HBM bandwidth, exactly the overhead HP-GNN's data layout removes.
//!
//! The A100 40 GB memory capacity check reproduces Table 7's OoM entries
//! (GraphSAINT keeps the full graph + features resident for its
//! normalization/evaluation passes; AmazonProducts does not fit).

use super::Calibration;
use crate::graph::datasets::DatasetSpec;
use crate::perf::{BatchGeometry, ModelShape};

/// A100 card description (paper Table 3).
#[derive(Debug, Clone, Copy)]
pub struct GpuSpec {
    pub peak_gflops: f64,
    pub mem_bw_gbps: f64,
    pub mem_bytes: usize,
}

impl GpuSpec {
    pub fn a100() -> GpuSpec {
        GpuSpec { peak_gflops: 19_500.0, mem_bw_gbps: 1555.0, mem_bytes: 40 * (1 << 30) }
    }
}

/// Outcome of the model: throughput or the OoM marker Table 7 prints.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GpuOutcome {
    Nvtps(f64),
    OutOfMemory,
}

/// Resident bytes GraphSAINT-style training keeps on the GPU: features,
/// CSR structure, plus per-epoch full-graph intermediate activations for
/// its evaluation / normalization passes.
pub fn resident_bytes(ds: &DatasetSpec, model: &ModelShape, subgraph_sampling: bool) -> usize {
    let features = ds.nodes * ds.f0 * 4;
    let structure = ds.edges * 8 + ds.nodes * 8;
    let full_graph_eval = if subgraph_sampling {
        // Full-graph forward for eval: one activation per layer plus the
        // edge-message buffer PyG materializes for weighted aggregation.
        let acts: usize = model.feat.iter().map(|&f| ds.nodes * f * 4).sum();
        // PyG materializes one message per edge for weighted aggregation.
        let messages = ds.edges * model.feat[1] * 4;
        acts + messages
    } else {
        0
    };
    features + structure + full_graph_eval
}

/// Model one (dataset, sampler, model) cell of Table 7's CPU-GPU column.
pub fn model_nvtps(
    gpu: &GpuSpec,
    ds: &DatasetSpec,
    geom: &BatchGeometry,
    model: &ModelShape,
    subgraph_sampling: bool,
    cal: &Calibration,
) -> GpuOutcome {
    if resident_bytes(ds, model, subgraph_sampling) > gpu.mem_bytes {
        return GpuOutcome::OutOfMemory;
    }

    // (1) host sampling on the dataloader workers.
    let edges_total: f64 = geom.e.iter().map(|&e| e as f64).sum();
    let t_sampling = edges_total * cal.host_sampling_per_edge / cal.host_sampling_workers;

    // (2) + (3) device time.
    let mut t_dev = cal.gpu_iteration_overhead;
    for l in 1..=geom.layers() {
        let f_prev = model.feat[l - 1] as f64;
        let f_cur = model.feat[l] as f64;
        let fin = if model.sage_concat { 2.0 * f_prev } else { f_prev };
        let traffic = geom.e[l - 1] as f64 * f_prev * 4.0 * 2.0;
        t_dev += traffic / (gpu.mem_bw_gbps * 1e9 * cal.gpu_gather_bw_eff);
        let flops = geom.b[l] as f64 * fin * f_cur * 2.0;
        t_dev += flops / (gpu.peak_gflops * 1e9 * cal.gpu_dense_eff);
    }
    t_dev = cal.gpu_iteration_overhead + (t_dev - cal.gpu_iteration_overhead) * 2.0; // + backward

    // Sampling pipelines with device execution (PyG prefetching), so the
    // iteration takes the max of the two — same structure as Eq. 5.
    let t_iter = t_sampling.max(t_dev);
    GpuOutcome::Nvtps(geom.vertices_traversed() as f64 / t_iter)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::perf::KappaEstimator;

    fn ns_geom(ds: &DatasetSpec) -> BatchGeometry {
        BatchGeometry::neighbor_capped(1024, &[10, 25], ds.nodes)
    }

    fn shape(ds: &DatasetSpec, sage: bool) -> ModelShape {
        ModelShape { feat: vec![ds.f0, 256, ds.f2], sage_concat: sage }
    }

    #[test]
    fn ns_gcn_flickr_in_table7_ballpark() {
        // Table 7 FL/NS-GCN CPU-GPU: 2.69M NVTPS.
        let out = model_nvtps(
            &GpuSpec::a100(),
            &datasets::FLICKR,
            &ns_geom(&datasets::FLICKR),
            &shape(&datasets::FLICKR, false),
            false,
            &Calibration::default(),
        );
        match out {
            GpuOutcome::Nvtps(n) => {
                assert!((1.0e6..12.0e6).contains(&n), "GPU NVTPS {n:.3e}");
            }
            GpuOutcome::OutOfMemory => panic!("FL must fit"),
        }
    }

    #[test]
    fn amazon_subgraph_goes_oom() {
        // Table 7: SS rows on AmazonProducts are OoM on the A100.
        let ds = datasets::AMAZON_PRODUCTS;
        let kappa = KappaEstimator::from_stats(ds.nodes, ds.edges);
        let geom = BatchGeometry::subgraph(2750, 2, &kappa);
        let out = model_nvtps(
            &GpuSpec::a100(),
            &ds,
            &geom,
            &shape(&ds, false),
            true,
            &Calibration::default(),
        );
        assert_eq!(out, GpuOutcome::OutOfMemory);
        // ... but neighbor sampling (no full-graph eval) fits.
        let out_ns =
            model_nvtps(&GpuSpec::a100(), &ds, &ns_geom(&ds), &shape(&ds, false), false, &Calibration::default());
        assert!(matches!(out_ns, GpuOutcome::Nvtps(_)));
    }

    #[test]
    fn subgraph_batches_are_launch_bound() {
        // Table 7 shape: SS speedups over CPU are far below NS speedups.
        let ds = datasets::REDDIT;
        let cal = Calibration::default();
        let kappa = KappaEstimator::from_stats(ds.nodes, ds.edges);
        let ss = BatchGeometry::subgraph(2750, 2, &kappa);
        let GpuOutcome::Nvtps(ss_n) =
            model_nvtps(&GpuSpec::a100(), &ds, &ss, &shape(&ds, false), true, &cal)
        else {
            panic!("RD SS must fit")
        };
        let GpuOutcome::Nvtps(ns_n) =
            model_nvtps(&GpuSpec::a100(), &ds, &ns_geom(&ds), &shape(&ds, false), false, &cal)
        else {
            panic!("RD NS must fit")
        };
        assert!(ns_n > ss_n * 2.0, "NS {ns_n:.3e} vs SS {ss_n:.3e}");
    }
}
