//! State-of-the-art accelerator models for Table 8 (SS-SAGE workloads).
//!
//! Both rows are modeled from the specs Table 8 itself publishes, plus the
//! two architectural differences §7 credits for HP-GNN's speedup:
//!
//! * **GraphACT** (CPU-FPGA, U250-scaled): vertex features live in *host*
//!   memory and cross PCIe every batch; its Feature Aggregation Module has
//!   feature-level parallelism only (one edge at a time, vector-wide), so
//!   aggregation runs at an n=1 equivalent.  Redundancy reduction cuts the
//!   on-chip aggregation work ~35% (its reported benefit) but requires
//!   uniform edge weights (why it cannot run GCN).
//! * **Rubik** (ASIC): 1 TFLOPS / 432 GB/s but only 2 MB on-chip — the
//!   per-layer intermediates of an SS batch spill to DRAM, and without
//!   HP-GNN's layout optimizations those accesses are random.

use crate::accel::platform::Platform;
use crate::perf::{BatchGeometry, ModelShape};

/// GraphACT iteration time (s) for a subgraph-sampling batch.
///
/// GraphACT's split differs from HP-GNN's in the two ways §7 highlights:
/// the redundancy-reduced *aggregation runs on the host CPU* (its FPGA
/// holds only the dense pipeline), and vertex features live in host
/// memory, crossing PCIe each batch.
pub fn graphact_iteration_time(
    platform: &Platform,
    geom: &BatchGeometry,
    model: &ModelShape,
) -> f64 {
    let freq = platform.freq_hz;
    let host = &platform.host;
    // PCIe 3.0 x16 effective ~12 GB/s: batch features cross per iteration.
    let pcie_bw = 12e9;
    let t_pcie = geom.b[0] as f64 * model.feat[0] as f64 * 4.0 / pcie_bw;
    let mut t_layers = 0.0;
    for l in 1..=geom.layers() {
        let f_prev = model.feat[l - 1] as f64;
        let f_cur = model.feat[l] as f64;
        let fin = if model.sage_concat { 2.0 * f_prev } else { f_prev };
        // Host-side aggregation with redundancy reduction (~35% fewer
        // vector adds).  GraphACT's aggregation is hand-blocked C++ (not
        // PyG), so it sustains a much higher bandwidth fraction than the
        // Table 7 CPU baseline: 0.2 of peak, pinned against Table 8's
        // published 546.8K NVTPS.
        let effective_edges = geom.e[l - 1] as f64 * 0.65;
        let traffic = effective_edges * f_prev * 4.0 * 2.0;
        t_layers += traffic / (host.mem_bw_gbps * 1e9 * 0.2);
        // Systolic update on the FPGA (single kernel instance — GraphACT
        // does not replicate across dies).
        let macs = 1024.0;
        t_layers += geom.b[l] as f64 * fin * f_cur / (macs * freq);
    }
    t_pcie + 2.0 * t_layers // forward + backward
}

/// Rubik iteration time (s) for a subgraph-sampling batch.
pub fn rubik_iteration_time(geom: &BatchGeometry, model: &ModelShape) -> f64 {
    let peak_flops = 1.0e12;
    let bw = 432e9;
    let onchip = 2.0 * 1024.0 * 1024.0;
    let mut t = 0.0;
    for l in 1..=geom.layers() {
        let f_prev = model.feat[l - 1] as f64;
        let f_cur = model.feat[l] as f64;
        let fin = if model.sage_concat { 2.0 * f_prev } else { f_prev };
        // Aggregation traffic: per-edge gathers; intermediates spill when
        // the layer slab exceeds the 2 MB scratchpad.
        let slab = geom.b[l] as f64 * f_cur * 4.0;
        let spill_factor = if slab > onchip { 2.0 } else { 1.0 };
        let traffic = geom.e[l - 1] as f64 * f_prev * 4.0 * spill_factor;
        // Random row access without HP-GNN's layout: short effective bursts.
        let alpha = 0.25;
        t += traffic / (bw * alpha);
        let flops = (geom.e[l - 1] as f64 * f_prev + geom.b[l] as f64 * fin * f_cur) * 2.0;
        t += flops / (peak_flops * 0.5);
    }
    2.0 * t
}

pub fn graphact_nvtps(platform: &Platform, geom: &BatchGeometry, model: &ModelShape) -> f64 {
    geom.vertices_traversed() as f64 / graphact_iteration_time(platform, geom, model)
}

pub fn rubik_nvtps(geom: &BatchGeometry, model: &ModelShape) -> f64 {
    geom.vertices_traversed() as f64 / rubik_iteration_time(geom, model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::AccelConfig;
    use crate::graph::datasets;
    use crate::layout::LayoutOptions;
    use crate::perf::{estimate, KappaEstimator};

    fn ss_sage_reddit() -> (BatchGeometry, ModelShape) {
        let ds = datasets::REDDIT;
        let kappa = KappaEstimator::from_stats(ds.nodes, ds.edges);
        (
            BatchGeometry::subgraph(2750, 2, &kappa),
            ModelShape { feat: vec![ds.f0, 256, ds.f2], sage_concat: true },
        )
    }

    #[test]
    fn table8_ordering_holds() {
        // Table 8 (RD, SS-SAGE): GraphACT 546.8K < Rubik 717.0K < ours 2.43M.
        let p = Platform::alveo_u250();
        let (geom, model) = ss_sage_reddit();
        let ga = graphact_nvtps(&p, &geom, &model);
        let ru = rubik_nvtps(&geom, &model);
        let ours = estimate(&p, &AccelConfig { n: 8, m: 256 }, &geom, &model, LayoutOptions::all())
            .nvtps(&geom, 0.0);
        assert!(ga < ru, "GraphACT {ga:.3e} must trail Rubik {ru:.3e}");
        assert!(ru < ours, "Rubik {ru:.3e} must trail ours {ours:.3e}");
        // Speedup over GraphACT lands in the paper's 2–8x window (4.45x).
        let speedup = ours / ga;
        assert!((1.5..12.0).contains(&speedup), "speedup {speedup:.2}");
    }

    #[test]
    fn graphact_nvtps_order_of_magnitude() {
        // Table 8 reports 546.8K on Reddit.
        let p = Platform::alveo_u250();
        let (geom, model) = ss_sage_reddit();
        let n = graphact_nvtps(&p, &geom, &model);
        assert!((1.5e5..2.5e6).contains(&n), "GraphACT NVTPS {n:.3e}");
    }

    #[test]
    fn rubik_spills_make_it_slower_on_big_hidden_layers() {
        let (geom, _) = ss_sage_reddit();
        let small = ModelShape { feat: vec![602, 64, 41], sage_concat: true };
        let big = ModelShape { feat: vec![602, 512, 41], sage_concat: true };
        let t_small = rubik_iteration_time(&geom, &small);
        let t_big = rubik_iteration_time(&geom, &big);
        assert!(t_big > t_small);
    }
}
