//! CPU-only baseline (Table 7's first column).
//!
//! Two forms:
//! * [`execute_batch`] — a real, multi-threaded rust implementation of the
//!   mini-batch forward+backward (the computation the FPGA accelerates),
//!   measured with wall clocks.  This is what laptop-scale benches run.
//! * [`model_iteration_time`] — the analytic PyG/3990x model used at paper
//!   scale, with [`Calibration`]'s efficiency constants.

use super::Calibration;
use crate::accel::platform::HostCpu;
use crate::layout::IndexedBatch;
use crate::perf::{BatchGeometry, ModelShape};
use crate::util::threadpool;

/// Executed CPU training step (forward + backward FLOPs, f32) over an
/// indexed batch.  Returns (seconds, output checksum — the checksum both
/// prevents dead-code elimination and gives tests a determinism handle).
pub fn execute_batch(
    batch: &IndexedBatch,
    feat_dims: &[usize],
    features: &[f32],
    threads: usize,
) -> (f64, f64) {
    let ll = batch.num_layers();
    assert_eq!(feat_dims.len(), ll + 1);
    assert_eq!(features.len(), batch.layers[0].len() * feat_dims[0]);
    let t = crate::util::stats::Timer::start();

    let mut h: Vec<f32> = features.to_vec();
    let mut f_in = feat_dims[0];
    for l in 1..=ll {
        let layer = &batch.layer_edges[l - 1];
        let n_out = batch.layers[l].len();
        let f_out = feat_dims[l];

        // Aggregate: out[dst] += val * h[src] — parallel over destination
        // chunks (each chunk owns its output rows, no locks needed).
        let chunk_rows = n_out.div_ceil(threads.max(1));
        let agg: Vec<Vec<f32>> = threadpool::par_map(
            threads,
            (0..threads.max(1)).collect::<Vec<_>>(),
            |tid| {
                let lo = (tid * chunk_rows).min(n_out);
                let hi = ((tid + 1) * chunk_rows).min(n_out);
                let mut out = vec![0.0f32; (hi - lo) * f_in];
                for i in 0..layer.src.len() {
                    let d = layer.dst[i] as usize;
                    if d < lo || d >= hi {
                        continue;
                    }
                    let s = layer.src[i] as usize;
                    let v = layer.val[i];
                    let src_row = &h[s * f_in..(s + 1) * f_in];
                    let dst_row = &mut out[(d - lo) * f_in..(d - lo + 1) * f_in];
                    for k in 0..f_in {
                        dst_row[k] += v * src_row[k];
                    }
                }
                out
            },
        );
        let mut a = Vec::with_capacity(n_out * f_in);
        for part in agg {
            a.extend(part);
        }
        a.truncate(n_out * f_in);

        // Update: h = relu(a W) with a deterministic pseudo-weight (the
        // baseline measures FLOP cost, not learning).
        let mut out = vec![0.0f32; n_out * f_out];
        let rows: Vec<usize> = (0..n_out).collect();
        let results = threadpool::par_map(threads, rows, |r| {
            let mut row = vec![0.0f32; f_out];
            let arow = &a[r * f_in..(r + 1) * f_in];
            for j in 0..f_out {
                let mut acc = 0.0f32;
                for (k, &av) in arow.iter().enumerate() {
                    // w[k][j] = deterministic hash-free pattern.
                    let w = (((k * 31 + j * 17) % 13) as f32 - 6.0) * 0.05;
                    acc += av * w;
                }
                row[j] = acc.max(0.0);
            }
            row
        });
        for (r, row) in results.into_iter().enumerate() {
            out[r * f_out..(r + 1) * f_out].copy_from_slice(&row);
        }
        h = out;
        f_in = f_out;
    }

    // Backward pass costs ≈ the forward pass on CPU too; run the gradient
    // aggregation over the transposed streams to charge it.  The gradient
    // keeps the output width as a cost proxy (exact widths change per
    // layer; the FLOP count is what the baseline measures).
    let mut checksum: f64 = h.iter().map(|&x| x as f64).sum();
    let f_g = feat_dims[ll];
    let mut g = h; // (b_L × f_g) gradient seed
    for l in (1..=ll).rev() {
        let layer = &batch.layer_edges[l - 1];
        let n_in = batch.layers[l - 1].len();
        let mut out = vec![0.0f32; n_in * f_g];
        for i in 0..layer.src.len() {
            let s = layer.src[i] as usize;
            let d = layer.dst[i] as usize;
            let v = layer.val[i];
            for k in 0..f_g {
                out[s * f_g + k] += v * g[d * f_g + k];
            }
        }
        g = out;
    }
    checksum += g.iter().map(|&x| x as f64).sum::<f64>();

    (t.secs(), checksum)
}

/// Analytic PyG-on-3990x iteration time at paper scale (Table 7 CPU rows).
pub fn model_iteration_time(
    host: &HostCpu,
    geom: &BatchGeometry,
    model: &ModelShape,
    cal: &Calibration,
) -> f64 {
    let mut t = 0.0f64;
    for l in 1..=geom.layers() {
        let f_prev = model.feat[l - 1] as f64;
        let f_cur = model.feat[l] as f64;
        let fin = if model.sage_concat { 2.0 * f_prev } else { f_prev };
        // Sparse aggregation: per-edge random row gather + scatter-add.
        let traffic = geom.e[l - 1] as f64 * f_prev * 4.0 * 2.0; // read + accumulate
        t += traffic / (host.mem_bw_gbps * 1e9 * cal.cpu_gather_bw_eff);
        // Dense update.
        let flops = geom.b[l] as f64 * fin * f_cur * 2.0;
        t += flops / (host.peak_gflops * 1e9 * cal.cpu_dense_eff);
    }
    2.0 * t // backward ≈ forward
}

/// NVTPS of the analytic CPU baseline.
pub fn model_nvtps(
    host: &HostCpu,
    geom: &BatchGeometry,
    model: &ModelShape,
    cal: &Calibration,
) -> f64 {
    geom.vertices_traversed() as f64 / model_iteration_time(host, geom, model, cal)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::platform::Platform;
    use crate::graph::generator;
    use crate::layout::{index_batch, LayoutOptions};
    use crate::sampler::neighbor::NeighborSampler;
    use crate::sampler::values::{attach_values, GnnModel};
    use crate::sampler::Sampler;
    use crate::util::rng::Pcg64;

    fn batch() -> IndexedBatch {
        let g = generator::with_min_degree(
            generator::rmat(500, 5000, Default::default(), 40),
            1,
            41,
        );
        let s = NeighborSampler::new(16, vec![5, 5]);
        let mb = s.sample(&g, &mut Pcg64::seed_from_u64(42));
        let vals = attach_values(&g, &mb, GnnModel::Gcn);
        index_batch(&mb, &vals, LayoutOptions::all())
    }

    #[test]
    fn executed_baseline_runs_and_is_deterministic() {
        let b = batch();
        let feat = [32usize, 16, 4];
        let x = vec![0.1f32; b.layers[0].len() * 32];
        let (t1, c1) = execute_batch(&b, &feat, &x, 2);
        let (_t2, c2) = execute_batch(&b, &feat, &x, 4);
        assert!(t1 > 0.0);
        assert!((c1 - c2).abs() < 1e-6 * c1.abs().max(1.0), "{c1} vs {c2}");
    }

    #[test]
    fn executed_baseline_nonzero_output() {
        let b = batch();
        let feat = [8usize, 8, 4];
        let x: Vec<f32> = (0..b.layers[0].len() * 8).map(|i| (i % 7) as f32 * 0.1).collect();
        let (_, checksum) = execute_batch(&b, &feat, &x, 1);
        assert!(checksum.abs() > 0.0);
    }

    #[test]
    fn analytic_cpu_matches_table7_order_of_magnitude() {
        // Table 7 FL/NS-GCN CPU row: 265.5K NVTPS.
        let host = Platform::alveo_u250().host;
        let geom = BatchGeometry::neighbor(1024, &[10, 25]);
        let model = ModelShape { feat: vec![500, 256, 7], sage_concat: false };
        let nvtps = model_nvtps(&host, &geom, &model, &Calibration::default());
        assert!(
            (80.0e3..900.0e3).contains(&nvtps),
            "CPU NVTPS {nvtps:.3e} out of Table 7 ballpark"
        );
    }

    #[test]
    fn sage_slower_than_gcn_on_cpu() {
        let host = Platform::alveo_u250().host;
        let geom = BatchGeometry::neighbor(1024, &[10, 25]);
        let cal = Calibration::default();
        let gcn = model_nvtps(&host, &geom, &ModelShape { feat: vec![500, 256, 7], sage_concat: false }, &cal);
        let sage = model_nvtps(&host, &geom, &ModelShape { feat: vec![500, 256, 7], sage_concat: true }, &cal);
        assert!(sage < gcn);
    }
}
