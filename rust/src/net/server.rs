//! Threadpool-backed HTTP listener.
//!
//! One supervisor thread hosts a scoped [`run_jobs`] pool: job 0 owns
//! the `TcpListener` and accepts, jobs 1..=N are connection workers
//! pulling accepted sockets off a bounded channel.  The bounded channel
//! plus the OS accept backlog are the only connection buffering — the
//! pool never grows with load, it just makes clients wait to be read,
//! and the *request* queue inside `serve::Server` is what decides
//! admission (shed vs serve).
//!
//! Every socket gets a short poll-style read timeout so workers can
//! observe shutdown between requests; a whole request must still land
//! within [`Limits::read_timeout`] (enforced by the parser's wall-clock
//! budget).  Each handled request emits one structured log line:
//! `http ts=… method=… route=… status=… latency_us=… batch=…`.

use std::io::{BufRead, BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::http::{read_request, HttpError, Limits, Response};
use super::router::Router;
use crate::util::stats::Timer;
use crate::util::sync::lock_unpoisoned;
use crate::util::threadpool::run_jobs;

/// How often blocked reads wake up to check the stop flag.
const POLL: Duration = Duration::from_millis(100);

/// Listener configuration.
#[derive(Debug, Clone)]
pub struct HttpOptions {
    /// Connection worker threads (concurrent connections being read).
    pub workers: usize,
    /// Per-request parse limits.
    pub limits: Limits,
    /// Emit the per-request log line on stdout.
    pub log: bool,
}

impl Default for HttpOptions {
    fn default() -> HttpOptions {
        HttpOptions { workers: 4, limits: Limits::default(), log: true }
    }
}

/// A running HTTP listener.  Dropping it (or calling
/// [`shutdown`](HttpServer::shutdown)) stops accepting, lets in-flight
/// requests finish, and joins every thread.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    supervisor: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (`host:port`; port 0 picks an ephemeral port) and
    /// start serving `router`.
    pub fn bind(addr: &str, router: Arc<Router>, opts: HttpOptions) -> anyhow::Result<HttpServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("cannot listen on {addr}: {e}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let supervisor = std::thread::Builder::new()
            .name("hp-gnn-http".to_string())
            .spawn(move || serve_pool(listener, router, opts, stop2))?;
        Ok(HttpServer { addr: local, stop, supervisor: Some(supervisor) })
    }

    /// The bound address (with the real port when 0 was requested).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until the listener exits on its own (it never does unless
    /// the process is killed) — the `hp-gnn serve --listen` foreground.
    pub fn join(mut self) {
        if let Some(h) = self.supervisor.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting, finish in-flight requests, join all threads.
    pub fn shutdown(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        if !self.stop.swap(true, Ordering::SeqCst) {
            // Wake the blocking accept with a throwaway connection.
            let _ = TcpStream::connect(self.addr);
        }
        if let Some(h) = self.supervisor.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.halt();
    }
}

fn serve_pool(listener: TcpListener, router: Arc<Router>, opts: HttpOptions, stop: Arc<AtomicBool>) {
    let workers = opts.workers.max(1);
    let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(workers * 2);
    let conn_rx = Arc::new(Mutex::new(conn_rx));
    let mut jobs: Vec<Box<dyn FnOnce() + Send>> = Vec::with_capacity(workers + 1);
    {
        let stop = Arc::clone(&stop);
        jobs.push(Box::new(move || accept_loop(listener, conn_tx, &stop)));
    }
    for _ in 0..workers {
        let rx = Arc::clone(&conn_rx);
        let router = Arc::clone(&router);
        let stop = Arc::clone(&stop);
        let opts = opts.clone();
        jobs.push(Box::new(move || loop {
            let conn = {
                let guard = lock_unpoisoned(&rx);
                // lint:allow(C1): workers share one receiver; the lock serializes only this wait
                guard.recv()
            };
            match conn {
                Ok(stream) => handle_connection(stream, &router, &opts, &stop),
                Err(_) => return, // acceptor gone: drain complete
            }
        }));
    }
    run_jobs(workers + 1, jobs);
}

fn accept_loop(listener: TcpListener, tx: mpsc::SyncSender<TcpStream>, stop: &AtomicBool) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if stop.load(Ordering::Relaxed) {
                    return; // the wake-up connection (or a late client)
                }
                if tx.send(stream).is_err() {
                    return;
                }
            }
            Err(_) => {
                if stop.load(Ordering::Relaxed) {
                    return;
                }
                // Transient accept failure (e.g. fd pressure): back off
                // instead of spinning.
                std::thread::sleep(POLL);
            }
        }
    }
}

fn would_block(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Serve one connection until close, keep-alive end, error, or shutdown.
fn handle_connection(stream: TcpStream, router: &Router, opts: &HttpOptions, stop: &AtomicBool) {
    if stream.set_read_timeout(Some(POLL)).is_err() {
        return;
    }
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(reader_stream);
    let mut writer = BufWriter::new(stream);
    loop {
        // Idle wait for the next request's first byte, polling the stop
        // flag so shutdown does not hang on open keep-alive connections.
        loop {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            match reader.fill_buf() {
                Ok([]) => return, // peer closed cleanly
                Ok(_) => break,
                Err(e) if would_block(&e) => continue,
                Err(_) => return,
            }
        }
        let t = Timer::start();
        let (resp, keep, method, path) = match read_request(&mut reader, &opts.limits) {
            Ok(None) => return,
            Ok(Some(req)) => {
                let keep = req.keep_alive() && !stop.load(Ordering::Relaxed);
                let resp = router.dispatch(&req);
                (resp, keep, req.method, req.path)
            }
            Err(HttpError::Io(_)) => return,
            Err(e) => (e.to_response(), false, "-".to_string(), "-".to_string()),
        };
        let ok = resp.write_to(&mut writer, keep).is_ok();
        if opts.log {
            log_request(&method, &path, &resp, t.secs());
        }
        if !ok || !keep {
            return;
        }
    }
}

/// The one structured log line per request, emitted through the
/// [`crate::obs::events`] sink (which owns the reasoned wall-clock read).
fn log_request(method: &str, path: &str, resp: &Response, latency_s: f64) {
    crate::obs::events::http_request(method, path, resp.status, latency_s, resp.batch);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::client::HttpClient;
    use crate::util::json::Json;

    fn echo_router() -> Arc<Router> {
        Arc::new(
            Router::new()
                .route("GET", "/healthz", |_| {
                    Response::json(200, &Json::obj(vec![("status", Json::str("ok"))]))
                })
                .route("POST", "/echo", |req| {
                    let len = req.body.len();
                    Response::json(200, &Json::obj(vec![("bytes", Json::num(len as f64))]))
                }),
        )
    }

    fn quiet() -> HttpOptions {
        HttpOptions { log: false, ..HttpOptions::default() }
    }

    #[test]
    fn binds_ephemeral_port_serves_keep_alive_requests_and_shuts_down() {
        let srv = HttpServer::bind("127.0.0.1:0", echo_router(), quiet()).unwrap();
        let addr = srv.addr();
        assert_ne!(addr.port(), 0, "ephemeral port must be resolved");
        let mut client = HttpClient::connect(&addr.to_string()).unwrap();
        // Two requests on one connection: keep-alive works.
        for _ in 0..2 {
            let resp = client.request("GET", "/healthz", None).unwrap();
            assert_eq!(resp.status, 200);
            assert_eq!(
                resp.json().unwrap().get("status").unwrap().as_str().unwrap(),
                "ok"
            );
        }
        let resp = client
            .request("POST", "/echo", Some(&Json::obj(vec![("x", Json::num(1.0))])))
            .unwrap();
        assert_eq!(resp.status, 200);
        drop(client);
        srv.shutdown();
    }

    #[test]
    fn concurrent_connections_are_served_by_the_worker_pool() {
        let srv = HttpServer::bind(
            "127.0.0.1:0",
            echo_router(),
            HttpOptions { workers: 4, log: false, ..HttpOptions::default() },
        )
        .unwrap();
        let addr = srv.addr().to_string();
        let mut handles = Vec::new();
        for _ in 0..8 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let mut c = HttpClient::connect(&addr).unwrap();
                for _ in 0..4 {
                    let r = c.request("GET", "/healthz", None).unwrap();
                    assert_eq!(r.status, 200);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        srv.shutdown();
    }

    #[test]
    fn malformed_requests_get_diagnostic_errors_not_dead_workers() {
        use std::io::{Read, Write};
        let srv = HttpServer::bind("127.0.0.1:0", echo_router(), quiet()).unwrap();
        let addr = srv.addr();
        let mut raw = TcpStream::connect(addr).unwrap();
        raw.write_all(b"NOT A REQUEST\r\n\r\n").unwrap();
        let mut text = String::new();
        raw.read_to_string(&mut text).unwrap();
        assert!(text.starts_with("HTTP/1.1 400"), "{text}");
        assert!(text.contains("\"errors\""), "{text}");
        // The listener survives: a well-formed request still works.
        let mut client = HttpClient::connect(&addr.to_string()).unwrap();
        assert_eq!(client.request("GET", "/healthz", None).unwrap().status, 200);
        drop(client);
        srv.shutdown();
    }
}
