//! Typed route table: exact-match `(method, path)` dispatch.
//!
//! Unknown paths answer `404`, known paths with the wrong method answer
//! `405` + `Allow` — both with `Diagnostic`-shaped JSON bodies, so a
//! client poking the wrong URL gets the same error schema as a bad
//! program file.  Handlers are plain closures over `&Request`; anything
//! they capture must be `Send + Sync` because every connection worker
//! dispatches through the same table.

use super::http::{error_response, Request, Response};

type Handler = Box<dyn Fn(&Request) -> Response + Send + Sync>;

struct Route {
    method: &'static str,
    path: &'static str,
    handler: Handler,
}

/// Exact-match route table (no wildcards — the API surface is four
/// routes; introduce patterns when a route actually needs one).
#[derive(Default)]
pub struct Router {
    routes: Vec<Route>,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    /// Register a handler; builder-style.
    pub fn route(
        mut self,
        method: &'static str,
        path: &'static str,
        handler: impl Fn(&Request) -> Response + Send + Sync + 'static,
    ) -> Router {
        self.routes.push(Route { method, path, handler: Box::new(handler) });
        self
    }

    /// Dispatch a request to its handler, or a 404/405 diagnostic.
    pub fn dispatch(&self, req: &Request) -> Response {
        let mut allowed: Vec<&'static str> = Vec::new();
        for r in &self.routes {
            if r.path != req.path {
                continue;
            }
            if r.method == req.method {
                return (r.handler)(req);
            }
            allowed.push(r.method);
        }
        if allowed.is_empty() {
            let routes: Vec<String> = self
                .routes
                .iter()
                .map(|r| format!("{} {}", r.method, r.path))
                .collect();
            error_response(
                404,
                &req.path,
                "no such route",
                Some(&format!("available: {}", routes.join(", "))),
            )
        } else {
            error_response(
                405,
                &req.path,
                &format!("method {} not allowed here", req.method),
                Some(&format!("use: {}", allowed.join(", "))),
            )
            .with_header("Allow", &allowed.join(", "))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn req(method: &str, path: &str) -> Request {
        Request {
            method: method.to_string(),
            path: path.to_string(),
            version: "HTTP/1.1".to_string(),
            headers: Vec::new(),
            body: Vec::new(),
        }
    }

    fn table() -> Router {
        Router::new()
            .route("GET", "/healthz", |_| Response::json(200, &Json::str("ok")))
            .route("POST", "/v1/classify", |r| {
                Response::json(200, &Json::num(r.body.len() as f64))
            })
    }

    #[test]
    fn dispatches_on_method_and_path() {
        let router = table();
        assert_eq!(router.dispatch(&req("GET", "/healthz")).status, 200);
        assert_eq!(router.dispatch(&req("POST", "/v1/classify")).status, 200);
    }

    #[test]
    fn unknown_path_is_404_with_route_listing() {
        let resp = table().dispatch(&req("GET", "/nope"));
        assert_eq!(resp.status, 404);
        let body = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        let err = &body.get("errors").unwrap().as_arr().unwrap()[0];
        assert_eq!(err.get("path").unwrap().as_str().unwrap(), "/nope");
        assert!(err.get("hint").unwrap().as_str().unwrap().contains("GET /healthz"));
    }

    #[test]
    fn wrong_method_is_405_with_allow_header() {
        let resp = table().dispatch(&req("DELETE", "/healthz"));
        assert_eq!(resp.status, 405);
        let allow = resp
            .headers
            .iter()
            .find(|(n, _)| n == "Allow")
            .map(|(_, v)| v.clone())
            .unwrap();
        assert_eq!(allow, "GET");
    }
}
