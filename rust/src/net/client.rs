//! Minimal keep-alive HTTP/1.1 client for the load-generator bench and
//! the socket tests.  Speaks exactly the dialect [`super::server`]
//! serves: `Content-Length`-framed bodies, no chunked encoding.  Not a
//! general-purpose client and not part of the serving path — but it
//! lives in `net/`, so it obeys the same no-panic contract.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use crate::util::json::Json;

/// One keep-alive connection to an HTTP server.
pub struct HttpClient {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

/// A parsed response.
#[derive(Debug)]
pub struct ClientResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl ClientResponse {
    /// First header with this (case-insensitive) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let want = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(n, _)| *n == want)
            .map(|(_, v)| v.as_str())
    }

    /// Body parsed as JSON.
    pub fn json(&self) -> anyhow::Result<Json> {
        let text = std::str::from_utf8(&self.body)?;
        Ok(Json::parse(text)?)
    }
}

impl HttpClient {
    /// Connect to `host:port`.
    pub fn connect(addr: &str) -> anyhow::Result<HttpClient> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(HttpClient { writer: stream, reader })
    }

    /// Issue one request and read the full response.  The connection
    /// stays usable afterwards (keep-alive) unless the server closed it.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&Json>,
    ) -> anyhow::Result<ClientResponse> {
        let payload = body.map(|j| j.compact()).unwrap_or_default();
        let mut req = format!("{method} {path} HTTP/1.1\r\nHost: hp-gnn\r\n");
        if body.is_some() {
            req.push_str("Content-Type: application/json\r\n");
        }
        req.push_str(&format!("Content-Length: {}\r\n\r\n", payload.len()));
        self.writer.write_all(req.as_bytes())?;
        self.writer.write_all(payload.as_bytes())?;
        self.writer.flush()?;
        self.read_response()
    }

    fn read_line(&mut self) -> anyhow::Result<String> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        anyhow::ensure!(n > 0, "server closed the connection mid-response");
        Ok(line.trim_end_matches(['\r', '\n']).to_string())
    }

    fn read_response(&mut self) -> anyhow::Result<ClientResponse> {
        let status_line = self.read_line()?;
        let status = status_line
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse::<u16>().ok())
            .ok_or_else(|| anyhow::anyhow!("malformed status line: {status_line:?}"))?;
        let mut headers = Vec::new();
        let mut content_length = 0usize;
        loop {
            let line = self.read_line()?;
            if line.is_empty() {
                break;
            }
            let (name, value) = line
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("malformed response header: {line:?}"))?;
            let name = name.trim().to_ascii_lowercase();
            let value = value.trim().to_string();
            if name == "content-length" {
                content_length = value
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad Content-Length: {value:?}"))?;
            }
            headers.push((name, value));
        }
        let mut body = vec![0u8; content_length];
        std::io::Read::read_exact(&mut self.reader, &mut body)?;
        Ok(ClientResponse { status, headers, body })
    }
}
