//! The network serving frontend: a dependency-free HTTP/1.1 layer over
//! [`serve::Server`](crate::serve::Server).
//!
//! HP-GNN's deployment story (recommendation-style inference serving)
//! needs a front door: [`http`] is a hand-rolled, allocation-bounded
//! HTTP/1.1 parser and response writer; [`router`] is an exact-match
//! typed route table; [`server`] accepts connections over the shared
//! [`util::threadpool`](crate::util::threadpool) idiom; [`routes`] wires
//! the four-route serving API (`/v1/classify`, `/healthz`, `/metrics`,
//! `/v1/reload`); [`client`] is the matching minimal client the bench
//! and tests drive the real socket with.
//!
//! Design rules, enforced by `hp-gnn lint` contracts over this module:
//! no panics in the serving path (R1 — a malformed request must cost one
//! response, never a worker), and no raw wall-clock reads (D2 — latency
//! and deadlines go through [`util::stats::Timer`](crate::util::stats::Timer);
//! the only allowed `SystemTime` is the request log line's timestamp,
//! behind a reasoned `lint:allow`).  Admission control lives in
//! `serve::Server::try_classify`: a full request queue sheds with
//! `429 Too Many Requests` + `Retry-After` instead of queueing without
//! bound, so p99 of *accepted* requests stays flat past saturation.

pub mod client;
pub mod http;
pub mod router;
pub mod routes;
pub mod server;

pub use client::{ClientResponse, HttpClient};
pub use http::{Limits, Request, Response};
pub use router::Router;
pub use routes::api_router;
pub use server::{HttpOptions, HttpServer};
