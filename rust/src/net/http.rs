//! Hand-rolled HTTP/1.1 subset: exactly what the serving frontend needs,
//! nothing more.
//!
//! Supported: request parsing with hard size limits and a wall-clock
//! budget, `Content-Length` bodies, keep-alive (including pipelined
//! requests on one connection), fixed-length responses.  Deliberately
//! unsupported: chunked transfer encoding (`501`), upgrades, trailers,
//! HTTP/2.  The parser is allocation-bounded: a request can never make
//! the server buffer more than [`Limits::max_header_bytes`] of headers
//! or [`Limits::max_body_bytes`] of body, and a peer that trickles bytes
//! (slowloris) is cut off once [`Limits::read_timeout`] of wall time has
//! elapsed — provided the underlying socket has a short poll-style read
//! timeout set, which [`super::server::HttpServer`] arranges.
//!
//! Parse errors map to client-visible status codes ([`HttpError::status`])
//! with `api::diag::Diagnostic`-shaped JSON bodies, so a malformed
//! request never takes down a connection worker, let alone the listener.

use std::io::{BufRead, ErrorKind, Read, Write};
use std::time::Duration;

use crate::util::json::Json;
use crate::util::stats::Timer;

/// Hard per-request resource bounds.
#[derive(Debug, Clone)]
pub struct Limits {
    /// Cap on the start line + header section, bytes.
    pub max_header_bytes: usize,
    /// Cap on the declared `Content-Length`, bytes.
    pub max_body_bytes: usize,
    /// Wall-clock budget for reading one complete request once its first
    /// byte has arrived.
    pub read_timeout: Duration,
}

impl Default for Limits {
    fn default() -> Limits {
        Limits {
            max_header_bytes: 8 * 1024,
            max_body_bytes: 1024 * 1024,
            read_timeout: Duration::from_secs(10),
        }
    }
}

/// A parsed request.  Header names are lowercased at parse time.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    /// `HTTP/1.1` or `HTTP/1.0`.
    pub version: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First header with this (case-insensitive) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let want = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(n, _)| *n == want)
            .map(|(_, v)| v.as_str())
    }

    /// HTTP/1.1 defaults to keep-alive unless `Connection: close`;
    /// HTTP/1.0 defaults to close unless `Connection: keep-alive`.
    pub fn keep_alive(&self) -> bool {
        let conn = self
            .header("connection")
            .map(|v| v.to_ascii_lowercase())
            .unwrap_or_default();
        if self.version == "HTTP/1.0" {
            conn == "keep-alive"
        } else {
            conn != "close"
        }
    }

    /// Body parsed as JSON.
    pub fn json_body(&self) -> Result<Json, String> {
        let text = std::str::from_utf8(&self.body)
            .map_err(|_| "request body is not utf-8".to_string())?;
        if text.trim().is_empty() {
            return Err("request body is empty; expected a JSON object".to_string());
        }
        Json::parse(text).map_err(|e| format!("request body is not valid JSON: {e}"))
    }
}

/// Everything that can go wrong while reading one request.
#[derive(Debug)]
pub enum HttpError {
    /// Syntactically broken request (start line, headers, body framing).
    BadRequest(String),
    /// Header section over [`Limits::max_header_bytes`].
    HeadersTooLarge(String),
    /// Declared body over [`Limits::max_body_bytes`].
    BodyTooLarge(String),
    /// [`Limits::read_timeout`] elapsed mid-request.
    Timeout,
    /// A feature this server deliberately does not speak (chunked).
    Unsupported(String),
    /// Transport error; the connection is unusable.
    Io(std::io::Error),
}

impl HttpError {
    /// The status code the client sees.
    pub fn status(&self) -> u16 {
        match self {
            HttpError::BadRequest(_) => 400,
            HttpError::HeadersTooLarge(_) => 431,
            HttpError::BodyTooLarge(_) => 413,
            HttpError::Timeout => 408,
            HttpError::Unsupported(_) => 501,
            HttpError::Io(_) => 400,
        }
    }

    /// Diagnostic-shaped error response for this parse failure.
    pub fn to_response(&self) -> Response {
        let reason = match self {
            HttpError::BadRequest(m) => m.clone(),
            HttpError::HeadersTooLarge(m) => m.clone(),
            HttpError::BodyTooLarge(m) => m.clone(),
            HttpError::Timeout => "request read timed out".to_string(),
            HttpError::Unsupported(m) => m.clone(),
            HttpError::Io(e) => format!("transport error: {e}"),
        };
        error_response(self.status(), "request", &reason, None)
    }
}

fn would_block(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

fn bad(msg: impl Into<String>) -> HttpError {
    HttpError::BadRequest(msg.into())
}

/// Read one CRLF- (or LF-) terminated line, retrying short poll-timeout
/// reads until `limits.read_timeout` of wall time has passed.  `Ok(None)`
/// means the peer closed cleanly before sending anything — the normal end
/// of a keep-alive connection.  `cap` bounds the line length (remaining
/// header budget).
fn read_line<R: BufRead>(
    r: &mut R,
    t: &Timer,
    limits: &Limits,
    cap: usize,
) -> Result<Option<Vec<u8>>, HttpError> {
    let mut buf = Vec::new();
    loop {
        match r.read_until(b'\n', &mut buf) {
            Ok(0) => {
                if buf.is_empty() {
                    return Ok(None);
                }
                return Err(bad("connection closed mid-request"));
            }
            Ok(_) => {
                if buf.len() > cap {
                    return Err(HttpError::HeadersTooLarge(format!(
                        "header section exceeds {} bytes",
                        limits.max_header_bytes
                    )));
                }
                if buf.last() == Some(&b'\n') {
                    buf.pop();
                    if buf.last() == Some(&b'\r') {
                        buf.pop();
                    }
                    return Ok(Some(buf));
                }
                // Delimiter not found and not EOF: keep reading.
            }
            Err(e) if would_block(&e) => {
                if t.secs() > limits.read_timeout.as_secs_f64() {
                    return Err(HttpError::Timeout);
                }
            }
            Err(e) => return Err(HttpError::Io(e)),
        }
    }
}

fn parse_start_line(line: &[u8]) -> Result<(String, String, String), HttpError> {
    let s = std::str::from_utf8(line).map_err(|_| bad("start line is not utf-8"))?;
    let mut parts = s.split(' ');
    let (method, path, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v), None) => (m, p, v),
        _ => return Err(bad(format!("malformed start line: {s:?}"))),
    };
    let method_ok = !method.is_empty() && method.bytes().all(|b| b.is_ascii_uppercase());
    let version_ok = version == "HTTP/1.1" || version == "HTTP/1.0";
    if !method_ok || !path.starts_with('/') || !version_ok {
        return Err(bad(format!("malformed start line: {s:?}")));
    }
    Ok((method.to_string(), path.to_string(), version.to_string()))
}

fn read_body<R: BufRead>(
    r: &mut R,
    len: usize,
    t: &Timer,
    limits: &Limits,
) -> Result<Vec<u8>, HttpError> {
    let mut body = vec![0u8; len];
    let mut got = 0usize;
    while got < len {
        match r.read(&mut body[got..]) {
            Ok(0) => return Err(bad("connection closed mid-body")),
            Ok(n) => got += n,
            Err(e) if would_block(&e) => {
                if t.secs() > limits.read_timeout.as_secs_f64() {
                    return Err(HttpError::Timeout);
                }
            }
            Err(e) => return Err(HttpError::Io(e)),
        }
    }
    Ok(body)
}

/// Parse one request off the stream.  `Ok(None)` means the peer closed
/// the connection cleanly between requests (keep-alive end-of-life);
/// every other early exit is an [`HttpError`] the caller can answer
/// with [`HttpError::to_response`] (except `Io`/`Timeout`, where the
/// connection is torn down).
pub fn read_request<R: BufRead>(
    r: &mut R,
    limits: &Limits,
) -> Result<Option<Request>, HttpError> {
    let t = Timer::start();
    let mut header_budget = limits.max_header_bytes;
    let start = match read_line(r, &t, limits, header_budget)? {
        None => return Ok(None),
        Some(line) => line,
    };
    header_budget = header_budget.saturating_sub(start.len());
    let (method, path, version) = parse_start_line(&start)?;

    let mut headers = Vec::new();
    loop {
        let line = read_line(r, &t, limits, header_budget)?
            .ok_or_else(|| bad("connection closed inside headers"))?;
        if line.is_empty() {
            break;
        }
        header_budget = header_budget.saturating_sub(line.len());
        if header_budget == 0 {
            return Err(HttpError::HeadersTooLarge(format!(
                "header section exceeds {} bytes",
                limits.max_header_bytes
            )));
        }
        let text = std::str::from_utf8(&line).map_err(|_| bad("header is not utf-8"))?;
        let (name, value) = text
            .split_once(':')
            .ok_or_else(|| bad(format!("malformed header: {text:?}")))?;
        headers.push((
            name.trim().to_ascii_lowercase(),
            value.trim().to_string(),
        ));
    }

    let req = Request { method, path, version, headers, body: Vec::new() };
    if req.header("transfer-encoding").is_some() {
        return Err(HttpError::Unsupported(
            "chunked transfer encoding is not supported; send Content-Length".to_string(),
        ));
    }
    let len = match req.header("content-length") {
        None => 0,
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| bad(format!("invalid Content-Length: {v:?}")))?,
    };
    if len > limits.max_body_bytes {
        return Err(HttpError::BodyTooLarge(format!(
            "declared body of {len} bytes exceeds the {}-byte limit",
            limits.max_body_bytes
        )));
    }
    let body = if len > 0 { read_body(r, len, &t, limits)? } else { Vec::new() };
    Ok(Some(Request { body, ..req }))
}

/// Reason phrase for the status codes this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        _ => "",
    }
}

/// A fixed-length response.  `batch` is bookkeeping for the request log
/// line (vertices answered), never serialized to the wire.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    pub batch: usize,
}

impl Response {
    /// JSON response with `Content-Type: application/json`.
    pub fn json(status: u16, body: &Json) -> Response {
        let mut bytes = body.compact().into_bytes();
        bytes.push(b'\n');
        Response {
            status,
            headers: vec![("Content-Type".to_string(), "application/json".to_string())],
            body: bytes,
            batch: 0,
        }
    }

    /// Plain-text response with an explicit `Content-Type` (Prometheus
    /// exposition on `GET /metrics` is the caller).
    pub fn text(status: u16, content_type: &str, body: String) -> Response {
        Response {
            status,
            headers: vec![("Content-Type".to_string(), content_type.to_string())],
            body: body.into_bytes(),
            batch: 0,
        }
    }

    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.headers.push((name.to_string(), value.to_string()));
        self
    }

    pub fn with_batch(mut self, batch: usize) -> Response {
        self.batch = batch;
        self
    }

    /// Serialize to the wire.  `keep_alive` decides the `Connection`
    /// header; the body is always `Content-Length`-framed.
    pub fn write_to<W: Write>(&self, w: &mut W, keep_alive: bool) -> std::io::Result<()> {
        write!(w, "HTTP/1.1 {} {}\r\n", self.status, reason(self.status))?;
        write!(w, "Content-Length: {}\r\n", self.body.len())?;
        write!(
            w,
            "Connection: {}\r\n",
            if keep_alive { "keep-alive" } else { "close" }
        )?;
        for (name, value) in &self.headers {
            write!(w, "{name}: {value}\r\n")?;
        }
        w.write_all(b"\r\n")?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

/// `api::diag::Diagnostic`-shaped error payload:
/// `{"errors":[{"path":…,"reason":…,"hint":…}]}`.
pub fn error_body(path: &str, why: &str, hint: Option<&str>) -> Json {
    Json::obj(vec![(
        "errors",
        Json::arr(vec![Json::obj(vec![
            ("path", Json::str(path)),
            ("reason", Json::str(why)),
            ("hint", hint.map(Json::str).unwrap_or(Json::Null)),
        ])]),
    )])
}

/// JSON error response carrying one [`error_body`] diagnostic.
pub fn error_response(status: u16, path: &str, why: &str, hint: Option<&str>) -> Response {
    Response::json(status, &error_body(path, why, hint))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> Result<Option<Request>, HttpError> {
        read_request(&mut Cursor::new(raw.as_bytes().to_vec()), &Limits::default())
    }

    #[test]
    fn parses_a_post_with_body_and_lowercases_header_names() {
        let req = parse(
            "POST /v1/classify HTTP/1.1\r\nHost: x\r\nContent-Length: 13\r\n\r\n{\"vertex\": 3}",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/classify");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("HOST"), Some("x"), "lookup is case-insensitive");
        assert_eq!(req.body, b"{\"vertex\": 3}");
        assert!(req.keep_alive(), "HTTP/1.1 defaults to keep-alive");
        assert_eq!(req.json_body().unwrap().get("vertex").unwrap().as_usize().unwrap(), 3);
    }

    #[test]
    fn clean_eof_before_any_bytes_is_none_not_an_error() {
        assert!(parse("").unwrap().is_none());
    }

    #[test]
    fn malformed_start_lines_are_rejected_with_400() {
        for raw in [
            "GET\r\n\r\n",
            "GET /x\r\n\r\n",
            "GET /x HTTP/2\r\n\r\n",
            "get /x HTTP/1.1\r\n\r\n",
            "GET x HTTP/1.1\r\n\r\n",
            "GET /x HTTP/1.1 extra\r\n\r\n",
            "\u{7f}\u{3}binary HTTP/1.1\r\n\r\n",
        ] {
            match parse(raw) {
                Err(e) => assert_eq!(e.status(), 400, "{raw:?} -> {e:?}"),
                other => panic!("{raw:?} parsed as {other:?}"),
            }
        }
    }

    #[test]
    fn truncated_requests_are_bad_requests_not_hangs() {
        for raw in ["GET /x HT", "GET /x HTTP/1.1\r\nHost: y", "POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc"] {
            match parse(raw) {
                Err(HttpError::BadRequest(_)) => {}
                other => panic!("{raw:?} parsed as {other:?}"),
            }
        }
    }

    #[test]
    fn oversized_header_section_is_431() {
        let raw = format!("GET /x HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "a".repeat(9000));
        match parse(&raw) {
            Err(e @ HttpError::HeadersTooLarge(_)) => assert_eq!(e.status(), 431),
            other => panic!("parsed as {other:?}"),
        }
        // Many small headers trip the cumulative budget too.
        let mut raw = String::from("GET /x HTTP/1.1\r\n");
        for i in 0..600 {
            raw.push_str(&format!("X-H{i}: {}\r\n", "v".repeat(10)));
        }
        raw.push_str("\r\n");
        assert!(matches!(parse(&raw), Err(HttpError::HeadersTooLarge(_))));
    }

    #[test]
    fn oversized_declared_body_is_413_without_buffering_it() {
        let raw = format!(
            "POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            2 * 1024 * 1024
        );
        match parse(&raw) {
            Err(e @ HttpError::BodyTooLarge(_)) => assert_eq!(e.status(), 413),
            other => panic!("parsed as {other:?}"),
        }
    }

    #[test]
    fn chunked_transfer_encoding_is_501() {
        let raw = "POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n";
        match parse(raw) {
            Err(e @ HttpError::Unsupported(_)) => assert_eq!(e.status(), 501),
            other => panic!("parsed as {other:?}"),
        }
    }

    #[test]
    fn pipelined_keep_alive_requests_parse_back_to_back() {
        let raw = "POST /a HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi\
                   GET /b HTTP/1.1\r\nConnection: close\r\n\r\n";
        let mut cur = Cursor::new(raw.as_bytes().to_vec());
        let limits = Limits::default();
        let a = read_request(&mut cur, &limits).unwrap().unwrap();
        assert_eq!((a.path.as_str(), a.body.as_slice()), ("/a", &b"hi"[..]));
        assert!(a.keep_alive());
        let b = read_request(&mut cur, &limits).unwrap().unwrap();
        assert_eq!(b.path, "/b");
        assert!(!b.keep_alive(), "Connection: close must end keep-alive");
        assert!(read_request(&mut cur, &limits).unwrap().is_none());
    }

    #[test]
    fn http_1_0_defaults_to_close() {
        let req = parse("GET /x HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!req.keep_alive());
        let req = parse("GET /x HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").unwrap().unwrap();
        assert!(req.keep_alive());
    }

    #[test]
    fn text_responses_carry_the_given_content_type() {
        let resp =
            Response::text(200, "text/plain; version=0.0.4", "metric_total 1\n".to_string());
        let mut wire = Vec::new();
        resp.write_to(&mut wire, true).unwrap();
        let text = String::from_utf8(wire).unwrap();
        assert!(text.contains("Content-Type: text/plain; version=0.0.4\r\n"), "{text}");
        assert!(text.ends_with("metric_total 1\n"), "{text}");
    }

    #[test]
    fn responses_frame_with_content_length_and_connection() {
        let resp = Response::json(200, &Json::obj(vec![("ok", Json::Bool(true))]))
            .with_header("Retry-After", "1");
        let mut wire = Vec::new();
        resp.write_to(&mut wire, false).unwrap();
        let text = String::from_utf8(wire).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Connection: close\r\n"));
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.contains("Content-Type: application/json\r\n"));
        let body = text.split("\r\n\r\n").nth(1).unwrap();
        assert_eq!(body.as_bytes().len(), resp.body.len());
        Json::parse(body).unwrap();
    }

    #[test]
    fn error_payloads_are_diagnostic_shaped() {
        let resp = error_response(429, "serving.queue", "request queue is full", Some("retry"));
        let body = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        let errs = body.get("errors").unwrap().as_arr().unwrap();
        assert_eq!(errs.len(), 1);
        assert_eq!(errs[0].get("path").unwrap().as_str().unwrap(), "serving.queue");
        assert_eq!(errs[0].get("hint").unwrap().as_str().unwrap(), "retry");
    }
}
