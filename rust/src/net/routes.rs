//! The serving API: six routes over one [`serve::Server`].
//!
//! | Route               | Body                                   | Answer |
//! |---------------------|----------------------------------------|--------|
//! | `POST /v1/classify` | `{"vertex": v}` or `{"vertices": [v…]}`| `{"predictions":[{vertex,label,logits}…],"weight_version":n,"graph_version":n}` |
//! | `GET /healthz`      | —                                      | geometry, pool size, weight version, graph version, cache entries |
//! | `GET /metrics`      | —                                      | Prometheus text exposition (JSON with `Accept: application/json`) |
//! | `GET /metrics.json` | —                                      | `serve::metrics` snapshot (counters, queue depth, latency percentiles, sheds) |
//! | `POST /v1/reload`   | `{"checkpoint": "path"}`               | `{"reloaded":true,"weight_version":n}` |
//! | `POST /v1/ingest`   | `{"edges": [[u, v], …]}`               | `{"ingested":n,"graph_version":n}` |
//!
//! Classify goes through [`Server::try_classify`]: when the bounded
//! request queue is full the route sheds with `429 Too Many Requests`
//! and a `Retry-After` header instead of queueing unboundedly.  Error
//! bodies reuse the `api::diag::Diagnostic` shape
//! (`{"errors":[{path,reason,hint}]}`), so HTTP clients and program-file
//! users read the same error schema.

use std::path::Path;
use std::sync::Arc;

use super::http::{error_response, Request, Response};
use super::router::Router;
use crate::obs;
use crate::graph::Vid;
use crate::serve::{Prediction, Server};
use crate::util::json::Json;

/// Seconds a shed client should wait before retrying.  One micro-batch
/// deadline plus execution is far below a second, so 1 s is always a
/// safe (conservative) backoff to advertise.
const RETRY_AFTER_S: u32 = 1;

fn prediction_json(p: &Prediction) -> Json {
    Json::obj(vec![
        ("vertex", Json::num(p.vertex as f64)),
        (
            "label",
            p.label.map(|l| Json::num(l as f64)).unwrap_or(Json::Null),
        ),
        // f32 → f64 is exact, and the JSON writer prints the shortest
        // round-tripping decimal: served logits survive the wire
        // bit-identical.
        (
            "logits",
            Json::arr(p.logits.iter().map(|&x| Json::num(x as f64)).collect()),
        ),
    ])
}

/// Pull the vertex list out of a classify body; any shape problem
/// becomes a ready-made 400 response.
fn parse_vertices(body: &[u8]) -> Result<Vec<Vid>, Response> {
    let hint = r#"send {"vertex": id} or {"vertices": [id, ...]}"#;
    let json = match std::str::from_utf8(body).ok().and_then(|t| {
        if t.trim().is_empty() { None } else { Json::parse(t).ok() }
    }) {
        Some(j) => j,
        None => {
            return Err(error_response(
                400,
                "body",
                "request body is not a JSON object",
                Some(hint),
            ))
        }
    };
    let obj = match json.as_obj() {
        Ok(o) => o,
        Err(_) => {
            return Err(error_response(400, "body", "expected a JSON object", Some(hint)))
        }
    };
    for key in obj.keys() {
        if key != "vertex" && key != "vertices" {
            return Err(error_response(
                400,
                &format!("body.{key}"),
                "unknown key",
                Some(hint),
            ));
        }
    }
    let ids: Vec<usize> = match (json.opt("vertex"), json.opt("vertices")) {
        (Some(_), Some(_)) => {
            return Err(error_response(
                400,
                "body",
                "give either \"vertex\" or \"vertices\", not both",
                Some(hint),
            ))
        }
        (Some(v), None) => match v.as_usize() {
            Ok(id) => vec![id],
            Err(e) => {
                return Err(error_response(400, "body.vertex", &e.to_string(), Some(hint)))
            }
        },
        (None, Some(vs)) => match vs.usize_list() {
            Ok(ids) if !ids.is_empty() => ids,
            Ok(_) => {
                return Err(error_response(
                    400,
                    "body.vertices",
                    "vertex list is empty",
                    Some(hint),
                ))
            }
            Err(e) => {
                return Err(error_response(400, "body.vertices", &e.to_string(), Some(hint)))
            }
        },
        (None, None) => {
            return Err(error_response(
                400,
                "body",
                "missing \"vertex\" or \"vertices\"",
                Some(hint),
            ))
        }
    };
    let mut vertices = Vec::with_capacity(ids.len());
    for id in ids {
        match Vid::try_from(id) {
            Ok(v) => vertices.push(v),
            Err(_) => {
                return Err(error_response(
                    400,
                    "body.vertices",
                    &format!("vertex id {id} does not fit u32"),
                    Some(hint),
                ))
            }
        }
    }
    Ok(vertices)
}

fn classify(server: &Server, body: &[u8]) -> Response {
    let vertices = match parse_vertices(body) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    match server.try_classify(&vertices) {
        Ok(Some(preds)) => {
            let out = Json::obj(vec![
                (
                    "predictions",
                    Json::arr(preds.iter().map(|p| prediction_json(p)).collect()),
                ),
                ("weight_version", Json::num(server.weight_version() as f64)),
                ("graph_version", Json::num(server.graph_version() as f64)),
            ]);
            Response::json(200, &out).with_batch(vertices.len())
        }
        Ok(None) => error_response(
            429,
            "serving.queue",
            "request queue is full; load shed",
            Some("retry after the Retry-After interval, or lower the offered rate"),
        )
        .with_header("Retry-After", &RETRY_AFTER_S.to_string()),
        Err(e) => error_response(500, "serving", &format!("classification failed: {e}"), None),
    }
}

fn healthz(server: &Server) -> Response {
    Response::json(
        200,
        &Json::obj(vec![
            ("status", Json::str("ok")),
            ("geometry", Json::str(server.geometry().name.clone())),
            ("workers", Json::num(server.num_workers() as f64)),
            ("max_batch", Json::num(server.max_batch() as f64)),
            ("weight_version", Json::num(server.weight_version() as f64)),
            ("graph_version", Json::num(server.graph_version() as f64)),
            ("cache_entries", Json::num(server.cache_len() as f64)),
        ]),
    )
}

/// `GET /metrics`: Prometheus text exposition by default; the JSON
/// snapshot when the client asks for `application/json` (content
/// negotiation keeps pre-Prometheus scripts working with one header).
fn metrics(server: &Server, req: &Request) -> Response {
    let wants_json = req
        .header("accept")
        .map(|a| a.contains("application/json"))
        .unwrap_or(false);
    if wants_json {
        metrics_json(server)
    } else {
        Response::text(200, obs::prometheus::CONTENT_TYPE, server.metrics_prometheus())
    }
}

/// `GET /metrics.json`: the stable JSON snapshot, unconditionally.
fn metrics_json(server: &Server) -> Response {
    Response::json(200, &server.metrics().to_json())
}

fn reload(server: &Server, body: &[u8]) -> Response {
    let hint = r#"send {"checkpoint": "path/to/weights.bin"}"#;
    let json = match std::str::from_utf8(body).ok().and_then(|t| Json::parse(t).ok()) {
        Some(j) => j,
        None => {
            return error_response(400, "body", "request body is not a JSON object", Some(hint))
        }
    };
    let checkpoint = match json.opt("checkpoint").map(|c| c.as_str()) {
        Some(Ok(path)) => path.to_string(),
        _ => {
            return error_response(400, "body.checkpoint", "missing checkpoint path", Some(hint))
        }
    };
    match server.reload_weights(Path::new(&checkpoint)) {
        Ok(()) => Response::json(
            200,
            &Json::obj(vec![
                ("reloaded", Json::Bool(true)),
                ("checkpoint", Json::str(checkpoint)),
                ("weight_version", Json::num(server.weight_version() as f64)),
            ]),
        ),
        // The running weights are untouched on failure: rejected rollouts
        // are a conflict with the serving identity, not a server fault.
        Err(e) => error_response(
            409,
            "serving.checkpoint",
            &format!("reload rejected: {e}"),
            Some("the checkpoint must match the serving model/geometry identity"),
        ),
    }
}

/// Pull the edge list out of an ingest body; any shape problem becomes a
/// ready-made 400 response.
fn parse_edges(body: &[u8]) -> Result<Vec<(Vid, Vid)>, Response> {
    let hint = r#"send {"edges": [[src, dst], ...]}"#;
    let json = match std::str::from_utf8(body).ok().and_then(|t| {
        if t.trim().is_empty() { None } else { Json::parse(t).ok() }
    }) {
        Some(j) => j,
        None => {
            return Err(error_response(
                400,
                "body",
                "request body is not a JSON object",
                Some(hint),
            ))
        }
    };
    let obj = match json.as_obj() {
        Ok(o) => o,
        Err(_) => {
            return Err(error_response(400, "body", "expected a JSON object", Some(hint)))
        }
    };
    for key in obj.keys() {
        if key != "edges" {
            return Err(error_response(400, &format!("body.{key}"), "unknown key", Some(hint)));
        }
    }
    let list = match json.opt("edges").map(|e| e.as_arr()) {
        Some(Ok(list)) if !list.is_empty() => list,
        Some(Ok(_)) => {
            return Err(error_response(400, "body.edges", "edge list is empty", Some(hint)))
        }
        Some(Err(e)) => {
            return Err(error_response(400, "body.edges", &e.to_string(), Some(hint)))
        }
        None => return Err(error_response(400, "body", "missing \"edges\"", Some(hint))),
    };
    let mut edges = Vec::with_capacity(list.len());
    for (i, pair) in list.iter().enumerate() {
        let path = format!("body.edges[{i}]");
        let endpoints = match pair.usize_list() {
            Ok(ids) if ids.len() == 2 => ids,
            Ok(ids) => {
                return Err(error_response(
                    400,
                    &path,
                    &format!("an edge is a [src, dst] pair, got {} elements", ids.len()),
                    Some(hint),
                ))
            }
            Err(e) => return Err(error_response(400, &path, &e.to_string(), Some(hint))),
        };
        match (Vid::try_from(endpoints[0]), Vid::try_from(endpoints[1])) {
            (Ok(u), Ok(v)) => edges.push((u, v)),
            _ => {
                return Err(error_response(
                    400,
                    &path,
                    &format!(
                        "edge ({}, {}) has an endpoint that does not fit u32",
                        endpoints[0], endpoints[1]
                    ),
                    Some(hint),
                ))
            }
        }
    }
    Ok(edges)
}

/// `POST /v1/ingest`: insert edges into the served graph.  Publishes a
/// new snapshot version — in-flight micro-batches finish against the
/// snapshot they pinned; subsequent requests sample the new topology and
/// the logits cache stops answering from the old one.
fn ingest(server: &Server, body: &[u8]) -> Response {
    let edges = match parse_edges(body) {
        Ok(e) => e,
        Err(resp) => return resp,
    };
    match server.ingest(&edges) {
        Ok(version) => Response::json(
            200,
            &Json::obj(vec![
                ("ingested", Json::num(edges.len() as f64)),
                ("graph_version", Json::num(version as f64)),
            ]),
        ),
        // The graph is untouched on failure: out-of-range endpoints are a
        // client-data conflict, not a server fault.
        Err(e) => error_response(
            409,
            "body.edges",
            &format!("ingest rejected: {e}"),
            Some("edge endpoints must name vertices that exist in the served graph"),
        ),
    }
}

/// The route table for one server.
pub fn api_router(server: Arc<Server>) -> Router {
    let s_classify = Arc::clone(&server);
    let s_healthz = Arc::clone(&server);
    let s_metrics = Arc::clone(&server);
    let s_metrics_json = Arc::clone(&server);
    let s_ingest = Arc::clone(&server);
    let s_reload = server;
    Router::new()
        .route("POST", "/v1/classify", move |req| classify(&s_classify, &req.body))
        .route("GET", "/healthz", move |_| healthz(&s_healthz))
        .route("GET", "/metrics", move |req| metrics(&s_metrics, req))
        .route("GET", "/metrics.json", move |_| metrics_json(&s_metrics_json))
        .route("POST", "/v1/reload", move |req| reload(&s_reload, &req.body))
        .route("POST", "/v1/ingest", move |req| ingest(&s_ingest, &req.body))
}
