//! Whole-accelerator composition: multi-die sharding (Fig. 7), per-layer
//! aggregate/update pipeline (Eq. 6–9), and the full training-iteration
//! timing `t_GNN = t_FP + t_LC + t_BP + t_WU` (Eq. 5).
//!
//! This is the *timing twin* of the AOT-compiled HLO executable: it replays
//! the exact edge streams of a sampled (and layout-processed) mini-batch
//! through the kernel simulators and reports where the cycles go.  The
//! functional results come from PJRT; nothing here touches feature values.

use super::aggregate::{AggregateReport, AggregateSim};
use super::memory::{MemoryLedger, Pattern, Traffic};
use super::platform::Platform;
use super::update::{UpdateReport, UpdateSim};
use crate::layout::IndexedBatch;

/// Accelerator configuration chosen by the DSE engine (per die).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AccelConfig {
    /// Scatter/Gather PE pairs per die (power of two).
    pub n: usize,
    /// MAC units per die (square of a power of two).
    pub m: usize,
}

impl AccelConfig {
    /// The configuration the paper's DSE selects for most workloads
    /// (Table 5).
    pub fn paper_default() -> Self {
        AccelConfig { n: 4, m: 256 }
    }
}

/// Timing of one GNN layer on the accelerator.
#[derive(Debug, Clone, Default)]
pub struct LayerTiming {
    /// Feature/gradient load time (slowest die), seconds.
    pub t_load: f64,
    /// Aggregate compute time (slowest die), seconds.
    pub t_compute: f64,
    /// max(t_load, t_compute) — Eq. 7.
    pub t_aggregate: f64,
    /// Update kernel time incl. result write-back (slowest die), seconds.
    pub t_update: f64,
    /// Per-die kernel reports (diagnostics for the perf pass).
    pub agg_reports: Vec<AggregateReport>,
    pub upd_reports: Vec<UpdateReport>,
    /// Total DDR bytes moved for this layer.
    pub ddr_bytes: f64,
}

impl LayerTiming {
    /// Pipelined layer time: aggregation and update overlap (Eq. 6).
    pub fn time(&self) -> f64 {
        self.t_aggregate.max(self.t_update)
    }
}

/// Full training-iteration timing (Eq. 5/6).
#[derive(Debug, Clone, Default)]
pub struct GnnTiming {
    pub fp_layers: Vec<LayerTiming>,
    pub bp_layers: Vec<LayerTiming>,
    pub t_fp: f64,
    pub t_bp: f64,
    /// Host-side loss calculation / weight update.
    pub t_lc: f64,
    pub t_wu: f64,
    pub t_gnn: f64,
}

impl GnnTiming {
    /// Paper Eq. 4 with sampling overlapped (Eq. 5).
    pub fn nvtps(&self, vertices_traversed: usize, t_sampling: f64) -> f64 {
        vertices_traversed as f64 / self.t_gnn.max(t_sampling)
    }

    pub fn total_ddr_bytes(&self) -> f64 {
        self.fp_layers.iter().chain(&self.bp_layers).map(|l| l.ddr_bytes).sum()
    }
}

/// Where the input feature matrix X lives (paper §3.1 / Table 1
/// `DistributeData()`): in FPGA-local DDR for graphs that fit, or in host
/// memory with per-batch streaming for very large graphs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FeaturePlacement {
    #[default]
    FpgaLocal,
    /// "we store the vertex features in host memory and transfer the
    /// vertex features of the mini-batch to the FPGA accelerator after
    /// sampling" — layer-1 loads cross PCIe.
    HostStreamed,
}

/// Simulation knobs beyond the DSE variables.
#[derive(Debug, Clone, Copy)]
pub struct SimOptions {
    /// Feature lanes per scatter PE per cycle (paper's 16).
    pub lanes: usize,
    /// Gather accumulator pipeline depth (RAW window).
    pub raw_depth: u64,
    /// GraphSAGE concat doubles the update kernel's fan-in.
    pub sage_concat: bool,
    /// Input feature placement (DistributeData outcome).
    pub placement: FeaturePlacement,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            lanes: 16,
            raw_depth: 4,
            sage_concat: false,
            placement: FeaturePlacement::FpgaLocal,
        }
    }
}

/// Simulate one mini-batch iteration.  `feat[l]` are the layer feature
/// dims (`feat[0]` input, `feat[L]` classes), matching the geometry.
pub fn simulate_batch(
    platform: &Platform,
    config: &AccelConfig,
    batch: &IndexedBatch,
    feat: &[usize],
    opts: SimOptions,
) -> GnnTiming {
    let ll = batch.num_layers();
    assert_eq!(feat.len(), ll + 1, "need L+1 feature dims");

    let mut timing = GnnTiming::default();
    for l in 1..=ll {
        timing.fp_layers.push(simulate_layer(platform, config, batch, feat, l, false, opts));
        timing.bp_layers.push(simulate_layer(platform, config, batch, feat, l, true, opts));
    }

    // Eq. 6: FP sums pipelined layers; BP's first layer needs only the
    // weight-gradient update (no gradient aggregation below layer 1).
    timing.t_fp = timing.fp_layers.iter().map(|t| t.time()).sum();
    timing.t_bp = timing.bp_layers[0].t_update
        + timing.bp_layers[1..].iter().map(|t| t.time()).sum::<f64>();

    // Host-side stages: loss on |B^L| logits, SGD on the weights.
    let host = &platform.host;
    let targets = batch.layers[ll].len() as f64;
    let classes = feat[ll] as f64;
    let lc_flops = targets * classes * 8.0; // softmax + CE + grad seed
    timing.t_lc = lc_flops / (0.1 * host.peak_gflops * 1e9)
        + targets * classes * 4.0 / (host.mem_bw_gbps * 1e9);
    let weight_params: f64 = (1..=ll)
        .map(|l| {
            let fin = if opts.sage_concat { 2 * feat[l - 1] } else { feat[l - 1] };
            (fin * feat[l] + feat[l]) as f64
        })
        .sum();
    timing.t_wu = weight_params * 2.0 / (0.1 * host.peak_gflops * 1e9)
        + weight_params * 12.0 / (host.mem_bw_gbps * 1e9); // read w,g; write w
    timing.t_gnn = timing.t_fp + timing.t_lc + timing.t_bp + timing.t_wu;
    timing
}

/// Simulate one layer over all dies; `backward` transposes the edge
/// streams (gradients flow dst -> src), reusing the same kernels exactly
/// as the paper's reverse-direction schedule.
fn simulate_layer(
    platform: &Platform,
    config: &AccelConfig,
    batch: &IndexedBatch,
    feat: &[usize],
    l: usize,
    backward: bool,
    opts: SimOptions,
) -> LayerTiming {
    let layer = &batch.layer_edges[l - 1];
    let dies = platform.dies.max(1);
    let agg_sim = AggregateSim { n: config.n, lanes: opts.lanes, raw_depth: opts.raw_depth };
    let upd_sim = UpdateSim { m: config.m };

    // Feature width moved by aggregation: h^{l-1} forward, dL/dh^l backward.
    let f_agg = if backward { feat[l] } else { feat[l - 1] };
    // Update kernel dims (SAGE concat doubles forward fan-in).
    let (rows_layer, f_in_upd, f_out_upd) = if backward {
        (batch.layers[l].len(), feat[l], feat[l - 1])
    } else {
        let fin = if opts.sage_concat { 2 * feat[l - 1] } else { feat[l - 1] };
        (batch.layers[l].len(), fin, feat[l])
    };

    // Which side of the stream is "destination" for sharding: forward
    // shards by layer-l vertices, backward by layer-(l-1) vertices.
    let (route_key, addr_stream): (Vec<u32>, Vec<u32>) = if backward {
        // Gradient aggregation: sources are layer-l rows (accelerator-
        // written, positional addresses), destinations layer-(l-1) rows.
        // The host program prepares a *transposed* layout for the backward
        // direction when RMT is on (re-sorted by the gradient source, the
        // backward analog of sort-by-source); replaying the forward-sorted
        // stream backward would serialize the gather banks.
        if batch.opts.rmt {
            let mut order: Vec<usize> = (0..layer.src.len()).collect();
            order.sort_by_key(|&i| (layer.dst[i], layer.src[i]));
            (
                order.iter().map(|&i| layer.src[i]).collect(),
                order.iter().map(|&i| layer.dst[i]).collect(),
            )
        } else {
            (layer.src.clone(), layer.dst.clone())
        }
    } else {
        let addrs: Vec<u32> = if batch.opts.rra {
            layer.src.clone() // renamed: storage-order addresses
        } else {
            // Un-renamed: the duplicator chases global vertex ids.
            layer.src.iter().map(|&p| batch.layers[l - 1][p as usize]).collect()
        };
        (layer.dst.clone(), addrs)
    };
    let out_count = if backward { batch.layers[l - 1].len() } else { batch.layers[l].len() };

    // Fig. 7 task partitioning: output vertices evenly over dies; each
    // die's kernels consume the sub-stream routed to its vertex range.
    let part = crate::graph::partition::ChannelPartition::even(out_count.max(1), dies);
    let mut t_load: f64 = 0.0;
    let mut t_compute: f64 = 0.0;
    let mut t_update: f64 = 0.0;
    let mut ddr_bytes = 0.0;
    let mut agg_reports = Vec::with_capacity(dies);
    let mut upd_reports = Vec::with_capacity(dies);

    for die in 0..dies {
        let lo = part.bounds[die] as u32;
        let hi = part.bounds[die + 1] as u32;
        // Sub-stream for this die (order preserved — RMT/RRA sortedness
        // survives filtering).
        let mut src_d = Vec::new();
        let mut dst_d = Vec::new();
        for i in 0..route_key.len() {
            let key = route_key[i];
            if key >= lo && key < hi {
                src_d.push(addr_stream[i]);
                dst_d.push(key - lo); // bank-local row
            }
        }
        let rep = agg_sim.run(&src_d, &dst_d, f_agg);

        // Memory pattern: layer-1 forward loads hit the input feature
        // matrix X (DDR rows in global-id order -> random regardless of
        // sort, paper §5.1); hidden layers / gradients read accelerator-
        // written buffers, sequential iff RMT+RRA put the stream in
        // storage order.
        let sequential = if !backward && l == 1 {
            false
        } else {
            batch.opts.rmt && batch.opts.rra
        };
        let load_t = if !backward && l == 1 && opts.placement == FeaturePlacement::HostStreamed {
            // Host-streamed features: the host gathers the mini-batch's
            // rows and streams them over PCIe (sequential on the link,
            // one transfer per batch — paper §3.1's very-large-graph
            // mode).  The link is shared by all dies.
            rep.load_bytes * dies as f64 / (platform.pcie_gbps * 1e9)
        } else {
            let mut ledger = MemoryLedger::new();
            ledger.record(Traffic {
                label: "agg-load",
                bytes: rep.load_bytes,
                pattern: if sequential { Pattern::Sequential } else { Pattern::Random },
                access_bytes: f_agg as f64 * 4.0,
                remote_fraction: 1.0 - 1.0 / dies as f64,
            });
            ledger.transfer_time(platform)
        };

        // Update kernel on this die's row share.
        let rows_d = (hi - lo) as usize * rows_layer / out_count.max(1);
        let urep = upd_sim.run(rows_d, f_in_upd, f_out_upd);
        let mut wledger = MemoryLedger::new();
        wledger.record(Traffic {
            label: "upd-writeback",
            bytes: urep.result_bytes,
            pattern: Pattern::Sequential,
            access_bytes: f_out_upd as f64 * 4.0,
            remote_fraction: 0.0,
        });
        let write_t = wledger.transfer_time(platform);

        t_load = t_load.max(load_t);
        t_compute = t_compute.max(rep.cycles as f64 / platform.freq_hz);
        t_update = t_update.max((urep.cycles as f64 / platform.freq_hz).max(write_t));
        ddr_bytes += rep.load_bytes + urep.result_bytes;
        agg_reports.push(rep);
        upd_reports.push(urep);
    }

    LayerTiming {
        t_load,
        t_compute,
        t_aggregate: t_load.max(t_compute),
        t_update,
        agg_reports,
        upd_reports,
        ddr_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::layout::{index_batch, LayoutOptions};
    use crate::sampler::neighbor::NeighborSampler;
    use crate::sampler::values::{attach_values, GnnModel};
    use crate::sampler::Sampler;
    use crate::util::rng::Pcg64;

    fn batch(opts: LayoutOptions) -> IndexedBatch {
        let g = generator::with_min_degree(
            generator::rmat(2000, 30_000, Default::default(), 21),
            2,
            22,
        );
        let s = NeighborSampler::new(64, vec![10, 25]);
        let mb = s.sample(&g, &mut Pcg64::seed_from_u64(23));
        let vals = attach_values(&g, &mb, GnnModel::Gcn);
        index_batch(&mb, &vals, opts)
    }

    fn sim(opts: LayoutOptions) -> (GnnTiming, usize) {
        let b = batch(opts);
        let verts = b.vertices_traversed();
        let t = simulate_batch(
            &Platform::alveo_u250(),
            &AccelConfig::paper_default(),
            &b,
            &[500, 256, 7],
            SimOptions::default(),
        );
        (t, verts)
    }

    #[test]
    fn timing_components_positive_and_composed() {
        let (t, _) = sim(LayoutOptions::all());
        assert_eq!(t.fp_layers.len(), 2);
        assert!(t.t_fp > 0.0 && t.t_bp > 0.0 && t.t_lc > 0.0 && t.t_wu > 0.0);
        let want = t.t_fp + t.t_lc + t.t_bp + t.t_wu;
        assert!((t.t_gnn - want).abs() < 1e-12);
        // FP layer time is the max of its two pipelined stages.
        for l in &t.fp_layers {
            assert!((l.time() - l.t_aggregate.max(l.t_update)).abs() < 1e-15);
            assert!((l.t_aggregate - l.t_load.max(l.t_compute)).abs() < 1e-15);
        }
    }

    #[test]
    fn rmt_reduces_ddr_traffic() {
        let (base, _) = sim(LayoutOptions::none());
        let (rmt, _) = sim(LayoutOptions { rmt: true, rra: false });
        assert!(
            rmt.total_ddr_bytes() < base.total_ddr_bytes(),
            "rmt {} vs base {}",
            rmt.total_ddr_bytes(),
            base.total_ddr_bytes()
        );
    }

    #[test]
    fn rra_improves_or_preserves_throughput_over_rmt() {
        let (rmt, v) = sim(LayoutOptions { rmt: true, rra: false });
        let (all, _) = sim(LayoutOptions::all());
        let n_rmt = rmt.nvtps(v, 0.0);
        let n_all = all.nvtps(v, 0.0);
        assert!(n_all >= n_rmt * 0.99, "rmt+rra {n_all} vs rmt {n_rmt}");
    }

    #[test]
    fn optimizations_increase_nvtps_monotonically() {
        let (base, v) = sim(LayoutOptions::none());
        let (all, _) = sim(LayoutOptions::all());
        assert!(all.nvtps(v, 0.0) > base.nvtps(v, 0.0));
    }

    #[test]
    fn sampling_bottleneck_caps_throughput() {
        let (t, v) = sim(LayoutOptions::all());
        let free = t.nvtps(v, 0.0);
        let capped = t.nvtps(v, t.t_gnn * 10.0);
        assert!((capped - free / 10.0).abs() / free < 1e-9);
    }

    #[test]
    fn sage_concat_slows_update() {
        let b = batch(LayoutOptions::all());
        let p = Platform::alveo_u250();
        let c = AccelConfig::paper_default();
        let gcn = simulate_batch(&p, &c, &b, &[500, 256, 7], SimOptions::default());
        let sage = simulate_batch(
            &p,
            &c,
            &b,
            &[500, 256, 7],
            SimOptions { sage_concat: true, ..Default::default() },
        );
        let gu: f64 = gcn.fp_layers.iter().map(|l| l.t_update).sum();
        let su: f64 = sage.fp_layers.iter().map(|l| l.t_update).sum();
        assert!(su > gu * 1.5, "sage {su} vs gcn {gu}");
    }

    #[test]
    fn bigger_config_is_not_slower() {
        let b = batch(LayoutOptions::all());
        let p = Platform::alveo_u250();
        let small = simulate_batch(
            &p,
            &AccelConfig { n: 2, m: 64 },
            &b,
            &[500, 256, 7],
            SimOptions::default(),
        );
        let big = simulate_batch(
            &p,
            &AccelConfig { n: 16, m: 1024 },
            &b,
            &[500, 256, 7],
            SimOptions::default(),
        );
        assert!(big.t_gnn <= small.t_gnn);
    }
}

#[cfg(test)]
mod placement_tests {
    use super::*;
    use crate::graph::generator;
    use crate::layout::{index_batch, LayoutOptions};
    use crate::sampler::values::{attach_values, GnnModel};
    use crate::sampler::{neighbor::NeighborSampler, Sampler};
    use crate::util::rng::Pcg64;

    #[test]
    fn host_streamed_layer1_is_slower() {
        let g = generator::with_min_degree(
            generator::rmat(2000, 24_000, Default::default(), 61),
            1,
            62,
        );
        let mb = NeighborSampler::new(64, vec![10, 25]).sample(&g, &mut Pcg64::seed_from_u64(63));
        let vals = attach_values(&g, &mb, GnnModel::Gcn);
        let ib = index_batch(&mb, &vals, LayoutOptions::all());
        let p = Platform::alveo_u250();
        let c = AccelConfig::paper_default();
        let local = simulate_batch(&p, &c, &ib, &[500, 256, 7], SimOptions::default());
        let streamed = simulate_batch(
            &p,
            &c,
            &ib,
            &[500, 256, 7],
            SimOptions { placement: FeaturePlacement::HostStreamed, ..Default::default() },
        );
        // Layer-1 forward load crosses 12 GB/s PCIe instead of 77 GB/s DDR.
        assert!(
            streamed.fp_layers[0].t_load > local.fp_layers[0].t_load * 2.0,
            "streamed {} vs local {}",
            streamed.fp_layers[0].t_load,
            local.fp_layers[0].t_load
        );
        // Hidden layers unaffected (accelerator-produced buffers).
        assert!((streamed.fp_layers[1].t_load - local.fp_layers[1].t_load).abs() < 1e-12);
        assert!(streamed.t_gnn >= local.t_gnn);
    }
}
