//! Cycle-approximate simulation of the update kernel (paper Fig. 6).
//!
//! A systolic MAC array of `m` multiply-accumulate units (the paper
//! restricts `m` to squares of powers of two, i.e. a `sqrt(m) × sqrt(m)`
//! array) performs the blocked matmul `h = σ(a W + b)`:
//!
//! * W^l stays pinned in the on-chip Weight Buffer (loaded once per layer,
//!   no DDR traffic during the batch);
//! * `a^l` rows stream through the array; each (row-block, col-block) tile
//!   needs `fill + rows` cycles — fill/drain is the systolic skew;
//! * the elementwise σ is fused behind the array (no extra cycles);
//! * results go to the Result Buffer and then back to DDR (accounted by
//!   the caller's memory ledger as a sequential write).

/// Update kernel configuration (per die).
#[derive(Debug, Clone, Copy)]
pub struct UpdateSim {
    /// Total MAC units (DSE variable `m`, square of a power of two).
    pub m: usize,
}

/// DSP double-pumping factor: the DSP48 column runs at twice the 300 MHz
/// kernel clock (standard Vitis technique), so each MAC retires two
/// multiply-accumulates per kernel cycle.  Reported `cycles` are kernel
/// cycles.
pub const DSP_PUMP: u64 = 2;

#[derive(Debug, Clone, Default, PartialEq)]
pub struct UpdateReport {
    /// Total kernel-clock cycles including systolic fill/drain.
    pub cycles: u64,
    /// Ideal cycles = rows · f_in · f_out / m.
    pub ideal_cycles: u64,
    /// Bytes of weights held in the on-chip Weight Buffer.
    pub weight_bytes: usize,
    /// Result bytes written back to DDR.
    pub result_bytes: f64,
}

impl UpdateSim {
    /// Side length of the MAC array.
    pub fn array_dim(&self) -> usize {
        let dim = (self.m as f64).sqrt().round() as usize;
        assert_eq!(dim * dim, self.m, "m={} must be a perfect square", self.m);
        dim
    }

    /// Simulate `rows × f_in @ f_in × f_out`.
    pub fn run(&self, rows: usize, f_in: usize, f_out: usize) -> UpdateReport {
        let dim = self.array_dim();
        let ops = rows as u64 * f_in as u64 * f_out as u64;
        let ideal = ops.div_ceil(self.m as u64 * DSP_PUMP);
        if rows == 0 || f_in == 0 || f_out == 0 {
            return UpdateReport {
                cycles: 0,
                ideal_cycles: 0,
                weight_bytes: f_in * f_out * 4,
                result_bytes: 0.0,
            };
        }
        // Tile the weight over the array: each tile covers `dim` of f_in
        // and `dim` of f_out; rows stream through each tile pair.
        let k_tiles = f_in.div_ceil(dim) as u64;
        let n_tiles = f_out.div_ceil(dim) as u64;
        let fill = 2 * dim as u64; // systolic fill + drain skew per tile
        let cycles = n_tiles * k_tiles * (rows as u64 + fill) / DSP_PUMP;
        UpdateReport {
            cycles,
            ideal_cycles: ideal,
            weight_bytes: f_in * f_out * 4,
            result_bytes: rows as f64 * f_out as f64 * 4.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_matches_paper_formula() {
        // Paper Eq. 9: t_update = B f^l f^{l+1} / (m freq).
        let sim = UpdateSim { m: 256 };
        let r = sim.run(1024, 256, 256);
        assert_eq!(r.ideal_cycles, 1024 * 256 * 256 / 256 / DSP_PUMP);
        // Fill/drain overhead stays small for tall inputs (< 15%).
        assert!((r.cycles as f64) < r.ideal_cycles as f64 * 1.15);
    }

    #[test]
    fn array_dim_requires_square() {
        assert_eq!(UpdateSim { m: 256 }.array_dim(), 16);
        assert_eq!(UpdateSim { m: 1024 }.array_dim(), 32);
    }

    #[test]
    #[should_panic(expected = "perfect square")]
    fn non_square_m_rejected() {
        UpdateSim { m: 200 }.run(1, 1, 1);
    }

    #[test]
    fn weight_buffer_accounted() {
        let r = UpdateSim { m: 256 }.run(64, 500, 256);
        assert_eq!(r.weight_bytes, 500 * 256 * 4);
        assert_eq!(r.result_bytes, 64.0 * 256.0 * 4.0);
    }

    #[test]
    fn more_macs_fewer_cycles() {
        let small = UpdateSim { m: 64 }.run(2048, 256, 256).cycles;
        let big = UpdateSim { m: 1024 }.run(2048, 256, 256).cycles;
        assert!(big * 8 <= small, "m=1024 {big} vs m=64 {small}");
    }

    #[test]
    fn degenerate_shapes() {
        let r = UpdateSim { m: 16 }.run(0, 8, 8);
        assert_eq!(r.cycles, 0);
        let r = UpdateSim { m: 16 }.run(5, 3, 2);
        assert!(r.cycles > 0);
    }

    #[test]
    fn ragged_tiles_cost_extra() {
        let sim = UpdateSim { m: 256 };
        let exact = sim.run(1000, 256, 256); // 16 | 256
        let ragged = sim.run(1000, 257, 257); // one extra sliver tile pair
        assert!(ragged.cycles > exact.cycles);
    }
}
