//! Cycle-approximate simulator of the paper's FPGA accelerator.
//!
//! We have no Alveo U250, so the timing side of every experiment runs
//! through this simulator (the substitution is documented in DESIGN.md §2;
//! functional results run through the PJRT executable instead).  The
//! microarchitecture follows Section 4 of the paper:
//!
//! * [`aggregate`] — Fig. 5: scatter PEs, butterfly routing, RAW resolver,
//!   gather banks, feature-duplicator run-length reuse.
//! * [`update`] — Fig. 6: systolic MAC array with on-chip Weight Buffer.
//! * [`memory`] — DDR4 burst/row-activation model behind Eq. 8's α.
//! * [`device`] — Fig. 7: multi-die replication, per-layer pipelining
//!   (Eq. 6/7), host-side loss + weight-update stages (Eq. 5).
//! * [`platform`] — Table 3 / Listing 2 board descriptions.

pub mod aggregate;
pub mod device;
pub mod memory;
pub mod platform;
pub mod update;

pub use device::{simulate_batch, AccelConfig, GnnTiming, LayerTiming, SimOptions};
pub use platform::Platform;
