//! DDR memory-system model: traffic accounting and transfer-time
//! estimation under the burst/row-activation behaviour profiled by
//! Lu et al. [21] (the paper's source for α, Eq. 8).

use super::platform::Platform;

/// Access pattern of a traffic stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pattern {
    /// Streaming reads/writes — bursts amortize row activation (α ≈ 1).
    Sequential,
    /// Scattered row-granular accesses — α from access size vs penalty.
    Random,
}

/// One accounted traffic stream.
#[derive(Debug, Clone)]
pub struct Traffic {
    pub label: &'static str,
    pub bytes: f64,
    pub pattern: Pattern,
    /// Granularity of each access (feature-vector bytes for loads).
    pub access_bytes: f64,
    /// Fraction served by a remote DDR channel through the inter-die
    /// interconnect (Fig. 7), paying `cross_channel_efficiency`.
    pub remote_fraction: f64,
}

/// Per-channel memory model: accumulates streams, reports transfer time.
#[derive(Debug, Clone, Default)]
pub struct MemoryLedger {
    pub streams: Vec<Traffic>,
}

impl MemoryLedger {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, t: Traffic) {
        self.streams.push(t);
    }

    pub fn total_bytes(&self) -> f64 {
        self.streams.iter().map(|s| s.bytes).sum()
    }

    /// Transfer time over one DDR channel of `platform` (seconds).
    pub fn transfer_time(&self, platform: &Platform) -> f64 {
        let bw = platform.bw_per_channel_gbps * 1e9;
        self.streams
            .iter()
            .map(|s| {
                let alpha = platform.alpha(s.access_bytes, s.pattern == Pattern::Sequential);
                let local = s.bytes * (1.0 - s.remote_fraction);
                let remote = s.bytes * s.remote_fraction;
                (local + remote / platform.cross_channel_efficiency) / (bw * alpha)
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> Platform {
        Platform::alveo_u250()
    }

    #[test]
    fn sequential_beats_random_for_same_bytes() {
        let mk = |pattern| {
            let mut m = MemoryLedger::new();
            m.record(Traffic {
                label: "x",
                bytes: 1e9,
                pattern,
                access_bytes: 256.0,
                remote_fraction: 0.0,
            });
            m.transfer_time(&p())
        };
        assert!(mk(Pattern::Sequential) < mk(Pattern::Random) * 0.5);
    }

    #[test]
    fn sequential_time_matches_bandwidth() {
        let mut m = MemoryLedger::new();
        m.record(Traffic {
            label: "stream",
            bytes: 19.25e9,
            pattern: Pattern::Sequential,
            access_bytes: 4096.0,
            remote_fraction: 0.0,
        });
        let t = m.transfer_time(&p());
        // One channel: 19.25 GB at 19.25 GB/s * 0.95 α ≈ 1.053 s.
        assert!((t - 1.0 / 0.95).abs() < 0.01, "{t}");
    }

    #[test]
    fn remote_traffic_costs_more() {
        let mk = |remote| {
            let mut m = MemoryLedger::new();
            m.record(Traffic {
                label: "x",
                bytes: 1e9,
                pattern: Pattern::Sequential,
                access_bytes: 2048.0,
                remote_fraction: remote,
            });
            m.transfer_time(&p())
        };
        assert!(mk(1.0) > mk(0.0) * 1.2);
        assert!(mk(0.5) > mk(0.0) && mk(0.5) < mk(1.0));
    }

    #[test]
    fn streams_accumulate() {
        let mut m = MemoryLedger::new();
        for _ in 0..3 {
            m.record(Traffic {
                label: "x",
                bytes: 100.0,
                pattern: Pattern::Random,
                access_bytes: 100.0,
                remote_fraction: 0.0,
            });
        }
        assert_eq!(m.total_bytes(), 300.0);
        assert_eq!(m.streams.len(), 3);
    }
}
