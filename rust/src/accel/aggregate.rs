//! Cycle-approximate simulation of the aggregate kernel (paper Fig. 5).
//!
//! Microarchitecture modeled:
//! * `n` Scatter PEs consume one edge each per beat; a beat moves the
//!   feature vector through the PEs in `ceil(f / 16)` flit cycles (the
//!   paper's `t_compute = |E| f / (n · 16 · freq)`, Eq. 8).
//! * A radix-2 **butterfly routing network** forwards each update to gather
//!   bank `dst mod n`; two updates landing in the same bank in the same
//!   beat serialize (output-port conflict), multiplying the beat's cost.
//! * **RAW resolver**: each gather bank is a pipelined accumulator of depth
//!   `raw_depth`; a second update to the *same destination row* arriving
//!   before the first retires stalls the bank (the paper resolves RAW "by
//!   stalling").
//! * The **feature duplicator** issues one DDR feature load per *run* of
//!   equal sources; the RMT sort turns per-edge loads into per-vertex
//!   loads, which is exactly how the optimization's effect emerges here.

/// Aggregate kernel configuration (per die).
#[derive(Debug, Clone, Copy)]
pub struct AggregateSim {
    /// Scatter/Gather PE pairs (the DSE variable `n`).
    pub n: usize,
    /// Feature lanes a PE moves per cycle (paper's 16).
    pub lanes: usize,
    /// Accumulator pipeline depth in beats (RAW hazard window).
    pub raw_depth: u64,
}

impl Default for AggregateSim {
    fn default() -> Self {
        AggregateSim { n: 4, lanes: 16, raw_depth: 4 }
    }
}

/// Simulation result for one edge-stream shard.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AggregateReport {
    /// Total kernel-clock cycles including conflicts and stalls.
    pub cycles: u64,
    /// Ideal cycles (no conflicts, no stalls).
    pub ideal_cycles: u64,
    /// Extra cycles from butterfly output-port conflicts.
    pub conflict_cycles: u64,
    /// Extra cycles from RAW-resolver stalls.
    pub raw_stall_cycles: u64,
    /// Feature-vector loads issued by the duplicator (post run-length
    /// reuse).
    pub loads: u64,
    /// Bytes fetched for those loads (f32 features).
    pub load_bytes: f64,
}

impl AggregateSim {
    /// Simulate one shard.  `src_addr` is the *memory address stream* the
    /// duplicator sees (positional after RRA, global vertex id otherwise);
    /// `dst_pos` is the gather-bank routing key (always positional —
    /// on-chip banks are positionally indexed); `feat` the feature width.
    pub fn run(&self, src_addr: &[u32], dst_pos: &[u32], feat: usize) -> AggregateReport {
        assert_eq!(src_addr.len(), dst_pos.len());
        let n = self.n.max(1);
        let flits = feat.div_ceil(self.lanes).max(1) as u64;
        let num_edges = src_addr.len();

        let mut report = AggregateReport::default();
        report.ideal_cycles = (num_edges.div_ceil(n) as u64) * flits;

        // Duplicator loads: one per run of equal source addresses.
        let mut prev_src: Option<u32> = None;
        for &s in src_addr {
            if prev_src != Some(s) {
                report.loads += 1;
                prev_src = Some(s);
            }
        }
        report.load_bytes = report.loads as f64 * feat as f64 * 4.0;

        // Beat-by-beat conflict + RAW accounting.  Retire times live in a
        // flat per-destination vector (destinations are bank-local dense
        // positions) — the HashMap variant cost ~40% of simulate_batch
        // (EXPERIMENTS.md §Perf).
        let mut bank_count = vec![0u32; n];
        let max_dst = dst_pos.iter().copied().max().unwrap_or(0) as usize;
        let mut retire = vec![0u64; max_dst + 1];
        let mut now: u64 = 0; // current cycle
        for beat in dst_pos.chunks(n) {
            // Butterfly conflicts: updates to the same output port
            // serialize, so the beat takes max-multiplicity flit slots.
            for b in bank_count.iter_mut() {
                *b = 0;
            }
            let mut max_mult = 0u32;
            for &d in beat {
                let bank = (d as usize) % n;
                bank_count[bank] += 1;
                max_mult = max_mult.max(bank_count[bank]);
            }
            let beat_cost = flits * max_mult as u64;
            report.conflict_cycles += flits * (max_mult as u64 - 1);

            // RAW: any update whose destination is still in the
            // accumulator pipeline stalls until it retires.
            let mut stall = 0u64;
            for &d in beat {
                let r = retire[d as usize];
                if r > now {
                    stall = stall.max(r - now);
                }
            }
            report.raw_stall_cycles += stall;
            now += beat_cost + stall;
            for &d in beat {
                retire[d as usize] = now + self.raw_depth;
            }
        }
        report.cycles = now;
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_cycles_match_paper_formula() {
        // |E| f / (n · 16): 64 edges, f=32, n=4 -> 64/4 * 2 = 32 cycles.
        let sim = AggregateSim { n: 4, lanes: 16, raw_depth: 0 };
        // Conflict-free: each beat hits distinct banks, distinct dsts.
        let src: Vec<u32> = (0..64).collect();
        let dst: Vec<u32> = (0..64).collect();
        let r = sim.run(&src, &dst, 32);
        assert_eq!(r.ideal_cycles, 32);
        assert_eq!(r.cycles, 32);
        assert_eq!(r.conflict_cycles, 0);
        assert_eq!(r.raw_stall_cycles, 0);
    }

    #[test]
    fn same_bank_conflicts_serialize() {
        let sim = AggregateSim { n: 4, lanes: 16, raw_depth: 0 };
        // All four edges of each beat route to bank 0 (dst ≡ 0 mod 4),
        // but to *different rows* (no RAW).
        let src: Vec<u32> = (0..16).collect();
        let dst: Vec<u32> = (0..16).map(|i| i * 4).collect();
        let r = sim.run(&src, &dst, 16);
        // Each beat costs 4x flits instead of 1x.
        assert_eq!(r.cycles, r.ideal_cycles * 4);
        assert!(r.conflict_cycles > 0);
    }

    #[test]
    fn raw_hazard_stalls() {
        let sim = AggregateSim { n: 2, lanes: 16, raw_depth: 8 };
        // Every edge hits the same destination row: worst-case RAW.
        let src: Vec<u32> = (0..8).collect();
        let dst = vec![0u32; 8];
        let hazard = sim.run(&src, &dst, 16);
        let clean = sim.run(&src, &[0, 1, 2, 3, 4, 5, 6, 7], 16);
        assert!(hazard.raw_stall_cycles > 0);
        assert!(hazard.cycles > clean.cycles);
    }

    #[test]
    fn rmt_run_length_reuse_reduces_loads() {
        let sim = AggregateSim::default();
        // Sorted stream: 4 sources × 8 edges each.
        let sorted: Vec<u32> = (0..4).flat_map(|s| std::iter::repeat(s).take(8)).collect();
        // Shuffled stream: same multiset, interleaved.
        let shuffled: Vec<u32> = (0..32).map(|i| (i % 4) as u32).collect();
        let dst: Vec<u32> = (0..32).collect();
        let a = sim.run(&sorted, &dst, 64);
        let b = sim.run(&shuffled, &dst, 64);
        assert_eq!(a.loads, 4);
        assert_eq!(b.loads, 32);
        assert!(a.load_bytes < b.load_bytes);
        // Compute side identical — RMT affects traffic, not PE cycles.
        assert_eq!(a.ideal_cycles, b.ideal_cycles);
    }

    #[test]
    fn empty_stream() {
        let r = AggregateSim::default().run(&[], &[], 128);
        assert_eq!(r.cycles, 0);
        assert_eq!(r.loads, 0);
    }

    #[test]
    fn wide_features_scale_flits() {
        let sim = AggregateSim { n: 1, lanes: 16, raw_depth: 0 };
        let src = [0u32, 1];
        let dst = [0u32, 1];
        let narrow = sim.run(&src, &dst, 16);
        let wide = sim.run(&src, &dst, 160);
        assert_eq!(wide.ideal_cycles, narrow.ideal_cycles * 10);
    }

    #[test]
    fn more_pes_fewer_cycles() {
        let src: Vec<u32> = (0..1024).collect();
        let dst: Vec<u32> = (0..1024).collect();
        let c4 = AggregateSim { n: 4, lanes: 16, raw_depth: 4 }.run(&src, &dst, 256).cycles;
        let c8 = AggregateSim { n: 8, lanes: 16, raw_depth: 4 }.run(&src, &dst, 256).cycles;
        assert!(c8 < c4, "n=8 {c8} vs n=4 {c4}");
        assert!((c4 as f64 / c8 as f64) > 1.5);
    }
}
