//! Target platform descriptions (paper Table 3 and Listing 2).
//!
//! `PlatformParameters(board='xilinx-U250')` in the paper's API resolves
//! through the named-board registry ([`BOARDS`] / [`by_board`]); custom
//! boards are constructed field-by-field exactly as Listing 2 shows
//! (`SLR=4, DSP=3072, LUT=423000, URAM=320, BW=19.25`).

/// A CPU-FPGA platform: per-die FPGA resources + DDR memory system + the
/// host CPU the sampler and loss/weight-update stages run on.
#[derive(Debug, Clone, PartialEq)]
pub struct Platform {
    pub name: String,
    /// Super-logic regions (dies); kernels are replicated per die (Fig. 7).
    pub dies: usize,
    /// Resources *per die*.
    pub dsp_per_die: usize,
    pub lut_per_die: usize,
    pub uram_per_die: usize,
    pub bram_per_die: usize,
    /// One DDR channel per die (paper §5.3 assumption), GB/s each.
    pub bw_per_channel_gbps: f64,
    /// FPGA-local DDR capacity in bytes (U250: 64 GB; the paper cites
    /// boards up to 260 GB).  `DistributeData()` compares the feature
    /// matrix against this to choose placement.
    pub ddr_bytes: usize,
    /// Host link for host-streamed features (PCIe 3.0 x16 effective).
    pub pcie_gbps: f64,
    /// Kernel clock.
    pub freq_hz: f64,
    /// DDR4 burst transaction length in bytes (Lu et al. [21]).
    pub burst_bytes: usize,
    /// Extra bytes-equivalent cost of a random row activation ([21]'s
    /// profiled effective-bandwidth ratios reduce to this overhead).
    pub random_penalty_bytes: f64,
    /// Efficiency of the inter-die / cross-channel interconnect (Fig. 7's
    /// vendor-generated all-to-all network).
    pub cross_channel_efficiency: f64,
    /// Host CPU for sampling, loss calculation and weight update.
    pub host: HostCpu,
}

/// Host processor description (paper Table 3, AMD Ryzen 3990x column).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HostCpu {
    pub cores: usize,
    pub freq_hz: f64,
    pub peak_gflops: f64,
    pub mem_bw_gbps: f64,
}

impl Platform {
    /// Xilinx Alveo U250 hosted by a 64-core AMD Ryzen 3990x — the paper's
    /// evaluation platform.
    pub fn alveo_u250() -> Platform {
        Platform {
            name: "xilinx-U250".into(),
            dies: 4,
            // Listing 2: per-SLR budget exposed to the DSE engine.
            dsp_per_die: 3072,
            lut_per_die: 423_000,
            uram_per_die: 320,
            bram_per_die: 672,
            bw_per_channel_gbps: 19.25, // 77 GB/s over 4 channels
            ddr_bytes: 64 * (1usize << 30),
            pcie_gbps: 12.0,
            freq_hz: 300e6,
            burst_bytes: 64,
            // DDR4 tRC ≈ 45 ns at 19.25 GB/s ≈ 866 bytes of lost transfer
            // per random row activation.
            random_penalty_bytes: 866.0,
            cross_channel_efficiency: 0.8,
            host: HostCpu {
                cores: 64,
                freq_hz: 2.9e9,
                peak_gflops: 3700.0,
                mem_bw_gbps: 107.0,
            },
        }
    }

    /// Xilinx Alveo U280 — the paper's "boards with HBM" direction.  The
    /// performance model assumes one memory channel per die, so the HBM2
    /// stacks (≈460 GB/s aggregate, 8 GB) plus the 32 GB DDR4 flatten into
    /// three fat per-die channels and a 40 GB feature budget; the lower
    /// random-activation penalty reflects HBM's shorter rows.
    pub fn alveo_u280() -> Platform {
        Platform {
            name: "xilinx-U280".into(),
            dies: 3,
            dsp_per_die: 3008, // 9024 DSP slices over 3 SLRs
            lut_per_die: 434_000,
            uram_per_die: 320, // 960 URAM blocks over 3 SLRs
            bram_per_die: 672,
            bw_per_channel_gbps: 153.6, // 460.8 GB/s HBM2 aggregate / 3
            ddr_bytes: 40 * (1usize << 30), // 8 GB HBM + 32 GB DDR4
            pcie_gbps: 12.0,
            freq_hz: 300e6,
            burst_bytes: 64,
            // HBM2 pseudo-channel tRC ≈ 45 ns at 14.4 GB/s/pc ≈ 650 bytes.
            random_penalty_bytes: 650.0,
            cross_channel_efficiency: 0.8,
            host: HostCpu {
                cores: 64,
                freq_hz: 2.9e9,
                peak_gflops: 3700.0,
                mem_bw_gbps: 107.0,
            },
        }
    }

    /// Aggregate DDR bandwidth (GB/s).
    pub fn total_bw_gbps(&self) -> f64 {
        self.bw_per_channel_gbps * self.dies as f64
    }

    /// Effective-bandwidth ratio α for accesses of `bytes` at a time
    /// (paper Eq. 8's α, derived from [21]'s burst profiling).
    pub fn alpha(&self, bytes: f64, sequential: bool) -> f64 {
        if sequential {
            0.95 // near-1 for streaming reads (paper §5.1)
        } else {
            (bytes / (bytes + self.random_penalty_bytes)).max(0.01)
        }
    }

    /// On-chip memory per die in bytes (URAM 288Kb + BRAM 36Kb blocks).
    pub fn onchip_bytes_per_die(&self) -> usize {
        self.uram_per_die * (288 * 1024 / 8) + self.bram_per_die * (36 * 1024 / 8)
    }
}

/// The named-board registry `PlatformParameters(board=…)` resolves
/// against.  Lookup is case-insensitive; unknown-board errors should
/// enumerate [`board_names`] so users see what is available.
pub const BOARDS: &[(&str, fn() -> Platform)] = &[
    ("xilinx-U250", Platform::alveo_u250),
    ("xilinx-U280", Platform::alveo_u280),
];

/// Resolve a board name (case-insensitive) against [`BOARDS`].
pub fn by_board(name: &str) -> Option<Platform> {
    BOARDS
        .iter()
        .find(|(n, _)| n.eq_ignore_ascii_case(name))
        .map(|(_, make)| make())
}

/// Every registered board name, for "unknown board" error messages.
pub fn board_names() -> Vec<&'static str> {
    BOARDS.iter().map(|(n, _)| *n).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u250_matches_paper_listing2() {
        let p = Platform::alveo_u250();
        assert_eq!(p.dies, 4);
        assert_eq!(p.dsp_per_die, 3072);
        assert_eq!(p.lut_per_die, 423_000);
        assert_eq!(p.uram_per_die, 320);
        assert!((p.bw_per_channel_gbps - 19.25).abs() < 1e-9);
        assert!((p.total_bw_gbps() - 77.0).abs() < 1e-9);
        assert_eq!(p.freq_hz, 300e6);
    }

    #[test]
    fn alpha_sequential_near_one() {
        let p = Platform::alveo_u250();
        assert!(p.alpha(2048.0, true) > 0.9);
    }

    #[test]
    fn alpha_random_grows_with_access_size() {
        let p = Platform::alveo_u250();
        let small = p.alpha(64.0, false);
        let mid = p.alpha(1024.0, false);
        let big = p.alpha(8192.0, false);
        assert!(small < mid && mid < big);
        assert!(small > 0.0 && big < 1.0);
        // 500-float Flickr feature: ~2000 B -> α ≈ 0.7 (order of [21]).
        let fl = p.alpha(2000.0, false);
        assert!((0.5..0.85).contains(&fl), "{fl}");
    }

    #[test]
    fn onchip_memory_is_tens_of_mb() {
        // Paper Table 3 lists 54 MB on-chip for the U250 (whole board).
        let p = Platform::alveo_u250();
        let total = p.onchip_bytes_per_die() * p.dies;
        assert!((40_000_000..70_000_000).contains(&total), "{total}");
    }

    #[test]
    fn host_is_3990x_class() {
        let h = Platform::alveo_u250().host;
        assert_eq!(h.cores, 64);
        assert!((h.peak_gflops - 3700.0).abs() < 1.0);
    }

    #[test]
    fn registry_resolves_case_insensitively() {
        assert_eq!(by_board("xilinx-U250").unwrap().name, "xilinx-U250");
        assert_eq!(by_board("XILINX-u250").unwrap().name, "xilinx-U250");
        assert_eq!(by_board("xilinx-u280").unwrap().name, "xilinx-U280");
        assert!(by_board("stratix-10").is_none());
        let names = board_names();
        assert!(names.contains(&"xilinx-U250") && names.contains(&"xilinx-U280"));
        // Every registered constructor's name matches its registry key.
        for (key, make) in BOARDS {
            assert_eq!(&make().name, key, "registry key / Platform.name drift");
        }
    }

    #[test]
    fn u280_is_a_plausible_hbm_board() {
        let p = Platform::alveo_u280();
        assert_eq!(p.dies, 3);
        // HBM: much higher aggregate bandwidth than the U250's DDR4...
        assert!(p.total_bw_gbps() > Platform::alveo_u250().total_bw_gbps());
        // ...but a smaller feature-capacity budget (8 GB HBM + 32 GB DDR).
        assert!(p.ddr_bytes < Platform::alveo_u250().ddr_bytes);
        // Random accesses are cheaper than on DDR4 at equal access size.
        assert!(p.alpha(2000.0, false) > Platform::alveo_u250().alpha(2000.0, false));
    }
}
