//! Item extraction: modules, `impl` blocks, functions, loop spans.
//!
//! Walks the token stream of one scrubbed file ([`super::token`]) with a
//! brace-depth state machine and produces every function item with its
//! *qualified path* (`serve::server::Server::classify`), receiver-ness,
//! and body line span — plus two per-line attributions the whole-program
//! rules consume directly: the innermost enclosing function and the loop
//! nesting depth (for the A1 hot-path allocation rule).
//!
//! Heuristic by design (no type information): inline `mod name { … }`
//! extends the module path derived from the file's `rust/src/`-relative
//! location, `impl Trait for Type` attributes to `Type`, and a closure's
//! body attributes to the enclosing `fn` — which is exactly what the R3
//! reachability pass wants (a panic inside a worker closure belongs to
//! the thread body that runs it).

use super::source::SourceFile;
use super::token::{tokenize, Tok};

/// One `fn` item.
#[derive(Debug, Clone)]
pub struct FnItem {
    /// File the item lives in (`rust/src/`-relative).
    pub file: String,
    /// Bare name (`classify`).
    pub name: String,
    /// `module::[Type::]name` — the resolution key.
    pub qpath: String,
    /// Module path (`serve::server`), inline mods included.
    pub module: String,
    /// Enclosing `impl` type, if any (`Server`).
    pub impl_type: Option<String>,
    /// Param list mentions `self` — it is a method.
    pub has_self: bool,
    /// Inside a `#[cfg(test)] mod` body.
    pub is_test: bool,
    /// 1-based line of the `fn` keyword.
    pub start: usize,
    /// 1-based line of the body's closing `}` (inclusive).
    pub end: usize,
}

/// Parsed items plus per-line attributions for one file.
#[derive(Debug)]
pub struct FileItems {
    pub fns: Vec<FnItem>,
    /// Innermost enclosing fn (index into `fns`) per 0-based line.
    pub fn_of_line: Vec<Option<usize>>,
    /// Loop nesting depth per 0-based line (max observed on the line).
    pub loop_depth: Vec<u32>,
    /// The token stream, retained for the call-graph builder.
    pub toks: Vec<Tok>,
}

/// Module path from a `rust/src/`-relative file path: `serve/server.rs`
/// → `serve::server`, `serve/mod.rs` → `serve`, `lib.rs`/`main.rs` → ``.
pub fn module_of(rel_path: &str) -> String {
    let p = rel_path.trim_end_matches(".rs");
    let mut segs: Vec<&str> = p.split('/').filter(|s| !s.is_empty()).collect();
    if segs.last().map(|s| *s == "mod").unwrap_or(false) {
        segs.pop();
    }
    if segs.last().map(|s| *s == "lib" || *s == "main").unwrap_or(false) {
        segs.pop();
    }
    segs.join("::")
}

pub fn parse(src: &SourceFile) -> FileItems {
    let texts: Vec<String> = src.lines.iter().map(|l| l.code.clone()).collect();
    let toks = tokenize(&texts);
    let file_module = module_of(&src.rel_path);

    let mut fns: Vec<FnItem> = Vec::new();
    let mut fn_of_line: Vec<Option<usize>> = vec![None; src.lines.len()];
    let mut loop_depth: Vec<u32> = vec![0; src.lines.len()];

    let mut depth: i64 = 0;
    let mut mod_stack: Vec<(String, i64)> = Vec::new();
    let mut impl_stack: Vec<(String, i64)> = Vec::new();
    let mut fn_stack: Vec<(usize, i64)> = Vec::new();
    let mut loop_stack: Vec<i64> = Vec::new();

    let mut pending_mod: Option<String> = None;
    let mut pending_impl: Option<String> = None;
    let mut pending_fn: Option<(String, bool, usize)> = None; // (name, has_self, start line)
    let mut pending_loop = false;

    let mut i = 0usize;
    while i < toks.len() {
        let li = toks[i].line - 1;
        let before_fn = fn_stack.last().map(|&(f, _)| f);
        let before_loops = loop_stack.len() as u32;

        match toks[i].text.as_str() {
            "{" => {
                depth += 1;
                if let Some((name, has_self, start)) = pending_fn.take() {
                    let module = {
                        let mut m = file_module.clone();
                        for (inner, _) in &mod_stack {
                            if m.is_empty() {
                                m = inner.clone();
                            } else {
                                m = format!("{m}::{inner}");
                            }
                        }
                        m
                    };
                    let impl_type = impl_stack.last().map(|(t, _)| t.clone());
                    let qpath = {
                        let mut q = module.clone();
                        if let Some(t) = &impl_type {
                            if q.is_empty() {
                                q = t.clone();
                            } else {
                                q = format!("{q}::{t}");
                            }
                        }
                        if q.is_empty() {
                            name.clone()
                        } else {
                            format!("{q}::{name}")
                        }
                    };
                    let is_test = src.lines.get(start - 1).map(|l| l.is_test).unwrap_or(false);
                    fns.push(FnItem {
                        file: src.rel_path.clone(),
                        name,
                        qpath,
                        module,
                        impl_type,
                        has_self,
                        is_test,
                        start,
                        end: src.lines.len(),
                    });
                    fn_stack.push((fns.len() - 1, depth));
                } else if let Some(t) = pending_impl.take() {
                    impl_stack.push((t, depth));
                } else if let Some(m) = pending_mod.take() {
                    mod_stack.push((m, depth));
                } else if pending_loop {
                    loop_stack.push(depth);
                }
                pending_loop = false;
            }
            "}" => {
                while loop_stack.last().map(|&d| d >= depth).unwrap_or(false) {
                    loop_stack.pop();
                }
                while fn_stack.last().map(|&(_, d)| d >= depth).unwrap_or(false) {
                    let (idx, _) = fn_stack.pop().unwrap();
                    fns[idx].end = toks[i].line;
                }
                while impl_stack.last().map(|&(_, d)| d >= depth).unwrap_or(false) {
                    impl_stack.pop();
                }
                while mod_stack.last().map(|&(_, d)| d >= depth).unwrap_or(false) {
                    mod_stack.pop();
                }
                depth -= 1;
            }
            ";" => {
                pending_fn = None;
                pending_mod = None;
                pending_impl = None;
                pending_loop = false;
            }
            "mod" if toks[i].is_ident() => {
                if let Some(next) = toks.get(i + 1) {
                    if next.is_ident() {
                        pending_mod = Some(next.text.clone());
                        i += 1;
                    }
                }
            }
            "impl" if toks[i].is_ident() => {
                let (ty, consumed) = parse_impl_header(&toks, i + 1);
                pending_impl = ty;
                i += consumed;
            }
            "fn" if toks[i].is_ident() => {
                if let Some(next) = toks.get(i + 1) {
                    if next.is_ident() {
                        let name = next.text.clone();
                        let has_self = params_mention_self(&toks, i + 2);
                        pending_fn = Some((name, has_self, toks[i].line));
                        i += 1;
                    }
                }
            }
            "for" | "while" | "loop" if toks[i].is_ident() => {
                // `for<'a>` higher-ranked bounds are not loops.
                let hrtb = toks[i].text == "for"
                    && toks.get(i + 1).map(|t| t.is("<")).unwrap_or(false);
                if !hrtb && !fn_stack.is_empty() {
                    pending_loop = true;
                }
            }
            _ => {}
        }

        // Per-line attributions: a line belongs to a fn if one is live at
        // any token on it (so a header line and a closing-brace line both
        // attribute); loop depth is the max observed on the line.
        let after_fn = fn_stack.last().map(|&(f, _)| f);
        if let Some(f) = after_fn.or(before_fn) {
            fn_of_line[li] = Some(f);
        }
        let after_loops = loop_stack.len() as u32;
        loop_depth[li] = loop_depth[li].max(before_loops).max(after_loops);

        i += 1;
    }

    FileItems { fns, fn_of_line, loop_depth, toks }
}

/// Scan an `impl` header (from just after the `impl` keyword) for the
/// type name it attributes to: the last path segment outside generic
/// arguments, taking the `for Type` side when present, stopping at
/// `where`/`{`/`;`.  Returns `(type name, tokens consumed)`.
fn parse_impl_header(toks: &[Tok], from: usize) -> (Option<String>, usize) {
    let mut angle = 0i32;
    let mut name: Option<String> = None;
    let mut j = from;
    while j < toks.len() {
        let t = &toks[j];
        match t.text.as_str() {
            "<" => angle += 1,
            ">" => angle -= 1,
            "{" | ";" => break,
            "where" if t.is_ident() && angle == 0 => break,
            "for" if t.is_ident() && angle == 0 => name = None,
            _ => {
                if t.is_ident() && angle == 0 {
                    name = Some(t.text.clone());
                }
            }
        }
        j += 1;
    }
    (name, j.saturating_sub(from))
}

/// Does the parameter list starting at or after `from` mention `self`?
fn params_mention_self(toks: &[Tok], from: usize) -> bool {
    let mut j = from;
    // Skip generics on the fn itself: `fn f<T: Bound>(…)`.
    let mut angle = 0i32;
    while j < toks.len() {
        match toks[j].text.as_str() {
            "<" => angle += 1,
            ">" => angle -= 1,
            "(" if angle == 0 => break,
            "{" | ";" => return false,
            _ => {}
        }
        j += 1;
    }
    let mut paren = 0i32;
    while j < toks.len() {
        match toks[j].text.as_str() {
            "(" => paren += 1,
            ")" => {
                paren -= 1;
                if paren == 0 {
                    return false;
                }
            }
            "self" if toks[j].is_ident() => return true,
            _ => {}
        }
        j += 1;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn items(rel: &str, src: &str) -> FileItems {
        parse(&SourceFile::parse(rel, src))
    }

    #[test]
    fn module_paths_derive_from_file_location() {
        assert_eq!(module_of("serve/server.rs"), "serve::server");
        assert_eq!(module_of("serve/mod.rs"), "serve");
        assert_eq!(module_of("lib.rs"), "");
        assert_eq!(module_of("main.rs"), "");
    }

    #[test]
    fn fns_get_qualified_paths_and_spans() {
        let src = "\
pub fn free() {
    inner();
}

impl Server {
    pub fn classify(&self, v: u32) -> u32 {
        v
    }
    fn assoc() {}
}

impl std::fmt::Debug for Config {
    fn fmt(&self, f: &mut F) -> R {
        ok()
    }
}
";
        let it = items("serve/server.rs", src);
        let q: Vec<(&str, bool)> =
            it.fns.iter().map(|f| (f.qpath.as_str(), f.has_self)).collect();
        assert_eq!(
            q,
            vec![
                ("serve::server::free", false),
                ("serve::server::Server::classify", true),
                ("serve::server::Server::assoc", false),
                ("serve::server::Config::fmt", true),
            ]
        );
        assert_eq!(it.fns[0].start, 1);
        assert_eq!(it.fns[0].end, 3);
        assert_eq!(it.fns[1].end, 8);
    }

    #[test]
    fn inline_mods_and_test_mods_attribute() {
        let src = "\
mod deep {
    pub fn f() {}
}

#[cfg(test)]
mod tests {
    fn t() {}
}
";
        let it = items("util/json.rs", src);
        assert_eq!(it.fns[0].qpath, "util::json::deep::f");
        assert!(!it.fns[0].is_test);
        assert!(it.fns[1].is_test, "{:?}", it.fns[1]);
    }

    #[test]
    fn loop_depth_tracks_nesting_and_closures_attribute_to_the_fn() {
        let src = "\
fn kernel(n: usize) {
    let setup = alloc();
    for i in 0..n {
        for j in 0..n {
            work(i, j);
        }
        tail(i);
    }
    let c = |x: u32| {
        x
    };
}
";
        let it = items("runtime/kernels/k.rs", src);
        assert_eq!(it.loop_depth[1], 0, "prologue");
        assert_eq!(it.loop_depth[3], 2, "inner loop body");
        assert_eq!(it.loop_depth[6], 1, "outer loop tail");
        assert_eq!(it.loop_depth[9], 0, "closure body is not a loop");
        assert_eq!(it.fn_of_line[9], Some(0), "closure attributes to kernel");
        assert_eq!(it.fns[0].name, "kernel");
    }

    #[test]
    fn trait_decls_without_bodies_are_not_items() {
        let src = "\
trait Backend {
    fn run(&self, x: u32) -> u32;
}

fn real() {}
";
        let it = items("runtime/backend.rs", src);
        let names: Vec<&str> = it.fns.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["real"], "{names:?}");
    }

    #[test]
    fn hrtb_for_is_not_a_loop() {
        let src = "fn f(g: impl Fn(u32) -> u32) {\n    g(1);\n}\n";
        let it = items("util/x.rs", src);
        assert_eq!(it.loop_depth[1], 0);
    }
}
