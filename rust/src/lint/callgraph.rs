//! Crate-wide call graph over the parsed items of every `rust/src` file.
//!
//! Extracts call sites from the token stream (path calls `a::b::f(…)`,
//! bare calls `f(…)`, method calls `recv.m(…)`) and resolves each to an
//! in-crate function by name heuristics:
//!
//! * **path** — normalize `crate::`/`self::`/`super::`/`Self::`, then
//!   match the segment chain as a `::`-boundary suffix of a known
//!   qualified path, preferring the caller's own module.  Unmatched
//!   paths are *external* (std / vendored crates).
//! * **bare** — free functions only (Rust cannot import associated fns
//!   into bare scope): the caller's module first, else a unique
//!   crate-wide free fn; several candidates is *ambiguous*.
//! * **method** — `self.m(…)` resolves inside the caller's own impl
//!   first; other receivers consult a std-method blocklist, then a
//!   unique crate-wide `self`-taking fn of that name.
//!
//! Macros (`ident!(…)`), uppercase path tails (`Mode::Fast(…)` tuple
//! variants), keywords, and `#[cfg(test)]` lines never become calls.
//! The builder reports resolution stats (the `--json` report surfaces
//! them and CI asserts ≥ 80%), and records which `.expect(…)` sites
//! resolved to an *in-crate* method so the R3 panic scan can exempt
//! them (the JSON parser's `Parser::expect` is not `Option::expect`).

use std::collections::{BTreeMap, BTreeSet};

use super::items::{FileItems, FnItem};
use super::source::SourceFile;
use super::token::Tok;

/// Keywords that can precede `(` without being calls.
const KEYWORDS: &[&str] = &[
    "if", "else", "while", "for", "loop", "match", "return", "fn", "let", "mut", "ref",
    "move", "in", "as", "use", "pub", "impl", "trait", "struct", "enum", "mod", "where",
    "unsafe", "dyn", "break", "continue", "const", "static", "type", "crate", "super",
    "self", "Self", "await", "async",
];

/// Method names resolved as std/external without consulting the crate
/// index (only for non-`self` receivers — `self.expect(…)` still
/// resolves inside its own impl first).
const STD_METHODS: &[&str] = &[
    "clone", "into", "to_string", "to_owned", "to_vec", "as_str", "as_ref", "as_mut",
    "as_bytes", "as_slice", "unwrap", "unwrap_or", "unwrap_or_else", "unwrap_or_default",
    "expect", "ok", "err", "iter", "iter_mut", "into_iter", "len", "is_empty", "push",
    "pop", "insert", "remove", "get", "get_mut", "contains", "contains_key", "map",
    "map_err", "and_then", "or_else", "filter", "filter_map", "flat_map", "collect",
    "extend", "extend_from_slice", "join", "send", "recv", "recv_timeout", "try_recv",
    "lock", "read", "write", "flush", "write_all", "read_to_end", "read_to_string",
    "read_exact", "take", "replace", "clear", "sort", "sort_by", "sort_by_key",
    "sort_unstable", "dedup", "min", "max", "abs", "sqrt", "powi", "powf", "exp", "ln",
    "floor", "ceil", "round", "split", "splitn", "trim", "trim_start", "trim_end",
    "starts_with", "ends_with", "strip_prefix", "strip_suffix", "parse", "wait",
    "wait_timeout", "notify_all", "notify_one", "spawn", "first", "last", "chars",
    "bytes", "windows", "chunks", "chunks_exact", "fill", "copy_from_slice",
    "clone_from_slice", "swap", "reserve", "truncate", "resize", "drain", "retain",
    "position", "find", "any", "all", "count", "sum", "product", "fold", "rev", "zip",
    "enumerate", "skip", "skip_while", "take_while", "step_by", "saturating_sub",
    "saturating_add", "saturating_mul", "checked_add", "checked_sub", "checked_mul",
    "checked_div", "wrapping_add", "wrapping_mul", "rotate_left", "rotate_right",
    "to_le_bytes", "to_be_bytes", "try_into", "into_inner", "borrow", "borrow_mut",
    "next", "next_back", "peek", "peekable", "eq", "ne", "cmp", "partial_cmp", "hash",
    "fmt", "min_by", "max_by", "min_by_key", "max_by_key", "load", "store", "fetch_add",
    "fetch_sub", "fetch_max", "compare_exchange", "elapsed", "as_secs_f64", "as_millis",
    "as_micros", "duration_since", "keys", "values", "values_mut", "entry", "or_insert",
    "or_insert_with", "to_uppercase", "to_lowercase", "to_ascii_lowercase",
    "split_whitespace", "lines", "is_finite", "is_nan", "is_some", "is_none", "is_ok",
    "is_err", "mul_add", "exists", "is_file", "is_dir", "display", "extension",
    "file_name", "to_path_buf", "with_extension", "set_nonblocking", "shutdown",
    "local_addr", "peer_addr", "accept", "incoming", "connect",
];

/// How one extracted call resolved.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resolution {
    /// Resolved to `fns[idx]` (global index).
    InCrate(usize),
    /// std / vendored crate — out of scope, counts as understood.
    External,
    /// Several in-crate candidates and no tiebreak.
    Ambiguous,
}

/// Resolution statistics, surfaced in `hp-gnn lint --json` and ratcheted
/// by CI (`resolution_pct() >= 80`).
#[derive(Debug, Clone, Copy, Default)]
pub struct Stats {
    /// Non-test function items across the crate.
    pub functions: usize,
    /// Call sites extracted from non-test code.
    pub calls: usize,
    pub resolved: usize,
    pub external: usize,
    pub ambiguous: usize,
}

impl Stats {
    /// Share of call sites the graph understands (resolved or provably
    /// external), in percent.
    pub fn resolution_pct(&self) -> f64 {
        if self.calls == 0 {
            return 100.0;
        }
        100.0 * (self.resolved + self.external) as f64 / self.calls as f64
    }
}

/// The crate-wide graph: all fn items (global indices), caller→callee
/// edges with one representative call-site line, and the bookkeeping the
/// whole-program rules need.
#[derive(Debug)]
pub struct CrateGraph {
    /// Every fn item, files concatenated in input order.
    pub fns: Vec<FnItem>,
    /// Per input file, the global index of its first fn (parallel to the
    /// `build` input slice) — translates `FileItems::fn_of_line`.
    pub offsets: Vec<usize>,
    /// caller → sorted `(callee, call line)`, deduped per callee.
    pub edges: BTreeMap<usize, Vec<(usize, usize)>>,
    pub stats: Stats,
    /// `(file, line, method)` sites where a method call resolved to an
    /// in-crate fn — consumed by R3's `.expect(` exemption.
    pub in_crate_methods: BTreeSet<(String, usize, String)>,
}

impl CrateGraph {
    /// Global fn index for a 0-based line of input file `fi`, if the
    /// line sits inside a fn body.
    pub fn fn_at(&self, files: &[(SourceFile, FileItems)], fi: usize, line0: usize) -> Option<usize> {
        files[fi].1.fn_of_line.get(line0).copied().flatten().map(|l| self.offsets[fi] + l)
    }
}

struct Index {
    /// name → global indices of non-test fns.
    by_name: BTreeMap<String, Vec<usize>>,
}

pub fn build(files: &[(SourceFile, FileItems)]) -> CrateGraph {
    let mut fns: Vec<FnItem> = Vec::new();
    let mut offsets = Vec::with_capacity(files.len());
    for (_, items) in files {
        offsets.push(fns.len());
        fns.extend(items.fns.iter().cloned());
    }

    let mut by_name: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    for (gi, f) in fns.iter().enumerate() {
        if !f.is_test {
            by_name.entry(f.name.clone()).or_default().push(gi);
        }
    }
    let index = Index { by_name };

    let mut stats = Stats { functions: fns.iter().filter(|f| !f.is_test).count(), ..Stats::default() };
    let mut edge_map: BTreeMap<(usize, usize), usize> = BTreeMap::new();
    let mut in_crate_methods: BTreeSet<(String, usize, String)> = BTreeSet::new();

    for (fi, (src, items)) in files.iter().enumerate() {
        let toks = &items.toks;
        for i in 0..toks.len() {
            let t = &toks[i];
            if !t.is_ident() {
                continue;
            }
            if call_paren(toks, i).is_none() {
                continue;
            }
            let line0 = t.line - 1;
            if src.lines.get(line0).map(|l| l.is_test).unwrap_or(true) {
                continue;
            }
            let prev = i.checked_sub(1).map(|p| toks[p].text.as_str()).unwrap_or("");
            let caller_local = match items.fn_of_line.get(line0).copied().flatten() {
                Some(c) => c,
                None => continue, // call outside any fn body (const exprs)
            };
            let caller = offsets[fi] + caller_local;
            if fns[caller].is_test {
                continue;
            }

            let res = if prev == "::" {
                // Last segment of a path call: walk back to the chain
                // start and resolve the whole path.
                if starts_upper(&t.text) {
                    continue; // tuple-variant / unit-struct construction
                }
                let mut j = i;
                while j >= 2 && toks[j - 1].is("::") && toks[j - 2].is_ident() {
                    j -= 2;
                }
                if j >= 1 && (toks[j - 1].is("::") || toks[j - 1].is(".")) {
                    // `<T as Trait>::f(…)` / `Vec::<u32>::new(…)` — a
                    // qualified or generic-applied path; treated as
                    // external dispatch (documented caveat).
                    Resolution::External
                } else {
                    let segs: Vec<String> =
                        (j..=i).step_by(2).map(|k| toks[k].text.clone()).collect();
                    resolve_path(&index, &fns, &fns[caller], &segs)
                }
            } else if prev == "." {
                let self_recv = i >= 2 && toks[i - 2].is("self") && toks[i - 2].is_ident();
                resolve_method(&index, &fns, &fns[caller], &t.text, self_recv)
            } else {
                if KEYWORDS.contains(&t.text.as_str()) || starts_upper(&t.text) || prev == "fn" {
                    continue;
                }
                resolve_bare(&index, &fns, &fns[caller], &t.text)
            };

            stats.calls += 1;
            match res {
                Resolution::InCrate(callee) => {
                    stats.resolved += 1;
                    edge_map.entry((caller, callee)).or_insert(t.line);
                    if prev == "." {
                        in_crate_methods.insert((src.rel_path.clone(), t.line, t.text.clone()));
                    }
                }
                Resolution::External => stats.external += 1,
                Resolution::Ambiguous => stats.ambiguous += 1,
            }
        }
    }

    let mut edges: BTreeMap<usize, Vec<(usize, usize)>> = BTreeMap::new();
    for (&(from, to), &line) in &edge_map {
        edges.entry(from).or_default().push((to, line));
    }

    CrateGraph { fns, offsets, edges, stats, in_crate_methods }
}

fn starts_upper(s: &str) -> bool {
    s.chars().next().map(|c| c.is_ascii_uppercase()).unwrap_or(false)
}

/// Is token `i` (an ident) followed — possibly through a turbofish
/// `::<…>` — by a call `(`?  Returns the index of that `(`.
fn call_paren(toks: &[Tok], i: usize) -> Option<usize> {
    let mut j = i + 1;
    if toks.get(j).map(|t| t.is("::")).unwrap_or(false)
        && toks.get(j + 1).map(|t| t.is("<")).unwrap_or(false)
    {
        let mut angle = 0i32;
        j += 1;
        while j < toks.len() {
            match toks[j].text.as_str() {
                "<" => angle += 1,
                ">" => {
                    angle -= 1;
                    if angle == 0 {
                        j += 1;
                        break;
                    }
                }
                ";" | "{" => return None,
                _ => {}
            }
            j += 1;
        }
    }
    if toks.get(j).map(|t| t.is("(")).unwrap_or(false) {
        Some(j)
    } else {
        None
    }
}

fn resolve_path(index: &Index, fns: &[FnItem], caller: &FnItem, segs: &[String]) -> Resolution {
    // Normalize the leading segment against the caller's position.
    let mut module: Vec<String> =
        caller.module.split("::").filter(|s| !s.is_empty()).map(str::to_string).collect();
    let mut rest: &[String] = segs;
    let mut key_segs: Vec<String> = Vec::new();
    match segs[0].as_str() {
        "crate" => rest = &segs[1..],
        "self" => {
            rest = &segs[1..];
            key_segs = module;
        }
        "super" => {
            rest = segs;
            while rest.first().map(|s| s == "super").unwrap_or(false) {
                module.pop();
                rest = &rest[1..];
            }
            key_segs = module;
        }
        "Self" => {
            rest = &segs[1..];
            key_segs = module;
            if let Some(t) = &caller.impl_type {
                key_segs.push(t.clone());
            }
        }
        "std" | "core" | "alloc" => return Resolution::External,
        _ => {}
    }
    key_segs.extend(rest.iter().cloned());
    if key_segs.is_empty() {
        return Resolution::External;
    }
    let key = key_segs.join("::");
    let tail = key_segs.last().unwrap();

    let mut hits: Vec<usize> = Vec::new();
    for &gi in index.by_name.get(tail).map(|v| v.as_slice()).unwrap_or(&[]) {
        let q = &fns[gi].qpath;
        if q == &key || q.ends_with(&format!("::{key}")) {
            hits.push(gi);
        }
    }
    pick(fns, caller, hits, /* external_when_empty= */ true)
}

fn resolve_bare(index: &Index, fns: &[FnItem], caller: &FnItem, name: &str) -> Resolution {
    let free: Vec<usize> = index
        .by_name
        .get(name)
        .map(|v| v.iter().copied().filter(|&gi| fns[gi].impl_type.is_none()).collect())
        .unwrap_or_default();
    pick(fns, caller, free, true)
}

fn resolve_method(
    index: &Index,
    fns: &[FnItem],
    caller: &FnItem,
    name: &str,
    self_recv: bool,
) -> Resolution {
    if self_recv {
        if let Some(impl_type) = &caller.impl_type {
            let same_impl: Vec<usize> = index
                .by_name
                .get(name)
                .map(|v| {
                    v.iter()
                        .copied()
                        .filter(|&gi| fns[gi].impl_type.as_deref() == Some(impl_type))
                        .collect()
                })
                .unwrap_or_default();
            match same_impl.len() {
                1 => return Resolution::InCrate(same_impl[0]),
                n if n > 1 => return Resolution::Ambiguous,
                _ => {}
            }
        }
    }
    if STD_METHODS.contains(&name) {
        return Resolution::External;
    }
    let methods: Vec<usize> = index
        .by_name
        .get(name)
        .map(|v| v.iter().copied().filter(|&gi| fns[gi].has_self).collect())
        .unwrap_or_default();
    pick(fns, caller, methods, true)
}

/// Same-module preference, then uniqueness; empty resolves external
/// (std or vendored) and several candidates is ambiguous.
fn pick(fns: &[FnItem], caller: &FnItem, hits: Vec<usize>, external_when_empty: bool) -> Resolution {
    if hits.is_empty() {
        return if external_when_empty { Resolution::External } else { Resolution::Ambiguous };
    }
    if hits.len() == 1 {
        return Resolution::InCrate(hits[0]);
    }
    let local: Vec<usize> =
        hits.iter().copied().filter(|&gi| fns[gi].module == caller.module).collect();
    if local.len() == 1 {
        return Resolution::InCrate(local[0]);
    }
    Resolution::Ambiguous
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::items;

    fn graph(files: &[(&str, &str)]) -> (Vec<(SourceFile, FileItems)>, CrateGraph) {
        let parsed: Vec<(SourceFile, FileItems)> = files
            .iter()
            .map(|(rel, text)| {
                let src = SourceFile::parse(rel, text);
                let it = items::parse(&src);
                (src, it)
            })
            .collect();
        let g = build(&parsed);
        (parsed, g)
    }

    fn edge_names(g: &CrateGraph) -> Vec<(String, String)> {
        let mut out = Vec::new();
        for (&from, tos) in &g.edges {
            for &(to, _) in tos {
                out.push((g.fns[from].qpath.clone(), g.fns[to].qpath.clone()));
            }
        }
        out.sort();
        out
    }

    #[test]
    fn known_edges_resolve_across_files() {
        let (_, g) = graph(&[
            (
                "serve/server.rs",
                "impl Server {\n    pub fn classify(&self) -> u32 {\n        let p = crate::util::helper(1);\n        self.lookup(p)\n    }\n    fn lookup(&self, p: u32) -> u32 {\n        decode(p)\n    }\n}\n\nfn decode(p: u32) -> u32 {\n    p\n}\n",
            ),
            ("util/mod.rs", "pub fn helper(x: u32) -> u32 {\n    x + 1\n}\n"),
        ]);
        assert_eq!(
            edge_names(&g),
            vec![
                ("serve::server::Server::classify".into(), "serve::server::Server::lookup".into()),
                ("serve::server::Server::classify".into(), "util::helper".into()),
                ("serve::server::Server::lookup".into(), "serve::server::decode".into()),
            ]
        );
        assert_eq!(g.stats.calls, 3);
        assert_eq!(g.stats.resolved, 3);
        assert!((g.stats.resolution_pct() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn std_and_macro_and_variant_calls_do_not_make_edges() {
        let (_, g) = graph(&[(
            "a.rs",
            "fn f() -> Vec<u32> {\n    let mut v = Vec::new();\n    v.push(Some(1));\n    format!(\"{v:?}\");\n    std::mem::drop(&v);\n    v.iter().map(|x| x.unwrap()).collect()\n}\n",
        )]);
        assert!(g.edges.is_empty(), "{:?}", edge_names(&g));
        assert_eq!(g.stats.resolved, 0);
        // Everything extracted was recognizably external.
        assert_eq!(g.stats.ambiguous, 0);
        assert!(g.stats.calls > 0);
    }

    #[test]
    fn self_method_resolves_in_own_impl_and_is_recorded() {
        let (_, g) = graph(&[(
            "util/json.rs",
            "struct Parser;\nimpl Parser {\n    fn expect(&mut self, b: u8) {}\n    fn object(&mut self) {\n        self.expect(1);\n    }\n}\n",
        )]);
        assert_eq!(
            edge_names(&g),
            vec![("util::json::Parser::object".into(), "util::json::Parser::expect".into())]
        );
        assert!(g.in_crate_methods.contains(&("util/json.rs".into(), 5, "expect".into())));
    }

    #[test]
    fn duplicate_method_names_are_ambiguous_not_guessed() {
        let (_, g) = graph(&[(
            "b.rs",
            "struct A;\nstruct B;\nimpl A {\n    fn run(&self) {}\n}\nimpl B {\n    fn run(&self) {}\n}\nfn drive(x: &A) {\n    x.run();\n}\n",
        )]);
        assert!(g.edges.is_empty());
        assert_eq!(g.stats.ambiguous, 1);
    }

    #[test]
    fn test_code_is_invisible_to_the_graph() {
        let (_, g) = graph(&[(
            "c.rs",
            "fn prod() {}\n\n#[cfg(test)]\nmod tests {\n    fn helper() {\n        super::prod();\n    }\n}\n",
        )]);
        assert_eq!(g.stats.calls, 0);
        assert_eq!(g.stats.functions, 1);
    }
}
