//! Minimal SARIF 2.1.0 emitter (`hp-gnn lint --format sarif`), so CI
//! annotation tooling can ingest lint findings without knowing the
//! native JSON schema.  Only the subset consumers actually read:
//! `tool.driver.rules`, and per-result `ruleId`, `message.text`,
//! `physicalLocation`, and the stable fingerprint.

use crate::util::json::Json;

use super::{Finding, RuleId};

const SCHEMA: &str = "https://json.schemastore.org/sarif-2.1.0.json";

/// Render findings (usually the unbaselined remainder) as one SARIF run.
pub fn sarif(findings: &[Finding]) -> Json {
    let rules = RuleId::ALL
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("id", Json::str(r.id())),
                ("name", Json::str(r.name())),
                ("shortDescription", Json::obj(vec![("text", Json::str(r.hint()))])),
            ])
        })
        .collect();
    let results = findings
        .iter()
        .map(|f| {
            Json::obj(vec![
                ("ruleId", Json::str(f.rule_id_str())),
                ("level", Json::str("error")),
                ("message", Json::obj(vec![("text", Json::str(&f.reason))])),
                (
                    "locations",
                    Json::arr(vec![Json::obj(vec![(
                        "physicalLocation",
                        Json::obj(vec![
                            (
                                "artifactLocation",
                                Json::obj(vec![(
                                    "uri",
                                    Json::str(format!("rust/src/{}", f.path)),
                                )]),
                            ),
                            (
                                "region",
                                Json::obj(vec![("startLine", Json::num(f.line as f64))]),
                            ),
                        ]),
                    )])]),
                ),
                (
                    "fingerprints",
                    Json::obj(vec![("hpGnnLint/v1", Json::str(&f.fingerprint))]),
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("version", Json::str("2.1.0")),
        ("$schema", Json::str(SCHEMA)),
        (
            "runs",
            Json::arr(vec![Json::obj(vec![
                (
                    "tool",
                    Json::obj(vec![(
                        "driver",
                        Json::obj(vec![
                            ("name", Json::str("hp-gnn-lint")),
                            ("rules", Json::Arr(rules)),
                        ]),
                    )]),
                ),
                ("results", Json::Arr(results)),
            ])]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sarif_shape_is_parseable_and_complete() {
        let f = Finding {
            path: "serve/server.rs".into(),
            line: 41,
            rule: Some(RuleId::R3),
            reason: "reachable panic".into(),
            fingerprint: "deadbeefdeadbeef".into(),
        };
        let s = sarif(&[f]);
        let round = Json::parse(&s.pretty()).unwrap();
        assert_eq!(round.get("version").unwrap().as_str().unwrap(), "2.1.0");
        let runs = round.get("runs").unwrap().as_arr().unwrap();
        let results = runs[0].get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("ruleId").unwrap().as_str().unwrap(), "R3");
        let loc = &results[0].get("locations").unwrap().as_arr().unwrap()[0];
        let phys = loc.get("physicalLocation").unwrap();
        assert_eq!(
            phys.get("artifactLocation").unwrap().get("uri").unwrap().as_str().unwrap(),
            "rust/src/serve/server.rs"
        );
        assert_eq!(phys.get("region").unwrap().get("startLine").unwrap().as_f64().unwrap(), 41.0);
        let drv = runs[0].get("tool").unwrap().get("driver").unwrap();
        assert_eq!(drv.get("rules").unwrap().as_arr().unwrap().len(), RuleId::ALL.len());
    }
}
