//! Finding fingerprints and the ratchet baseline.
//!
//! A fingerprint identifies a finding *stably across edits elsewhere in
//! the file*: FNV-1a 64 over `rule|path|enclosing-fn|snippet|occurrence`
//! — deliberately **no line number**, so inserting code above a known
//! finding does not churn the baseline; `occurrence` disambiguates
//! identical snippets within the same fn (0-based, in line order).
//!
//! The baseline file (`lint_baseline.json`, repo root) is the set of
//! accepted findings.  `hp-gnn lint --baseline <file>` then fails only
//! on *fresh* findings (not in the baseline — the ratchet never admits
//! new debt) or *stale* entries (in the baseline but no longer found —
//! the debt shrank, so the file must be regenerated via
//! `make lint-baseline` to lock in the progress).

use crate::util::json::Json;

use super::Finding;

/// FNV-1a 64-bit.
pub fn fnv1a64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The fingerprint input, hashed to 16 hex chars.
pub fn fingerprint(rule: &str, path: &str, func: &str, snippet: &str, occurrence: usize) -> String {
    format!("{:016x}", fnv1a64(&format!("{rule}|{path}|{func}|{snippet}|{occurrence}")))
}

/// Compute and store the fingerprint of every finding.  `line_info`
/// maps `(path, 1-based line)` to the enclosing fn name (empty when
/// top-level) and the trimmed scrubbed snippet of the line.  Callers
/// sort findings by `(path, line)` first so occurrence indices are
/// deterministic.
pub fn assign_fingerprints<F>(findings: &mut [Finding], mut line_info: F)
where
    F: FnMut(&str, usize) -> (String, String),
{
    let mut seen: std::collections::BTreeMap<String, usize> = std::collections::BTreeMap::new();
    for f in findings.iter_mut() {
        let (func, snippet) = line_info(&f.path, f.line);
        let key = format!("{}|{}|{func}|{snippet}", f.rule_id_str(), f.path);
        let occ = seen.entry(key).or_insert(0);
        f.fingerprint = fingerprint(f.rule_id_str(), &f.path, &func, &snippet, *occ);
        *occ += 1;
    }
}

/// One accepted finding in the baseline file (rule and path ride along
/// for human review of the file; the fingerprint is the identity).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Entry {
    pub fingerprint: String,
    pub rule: String,
    pub path: String,
}

/// The accepted-findings set.
#[derive(Debug, Clone, Default)]
pub struct Baseline {
    pub entries: Vec<Entry>,
}

impl Baseline {
    pub fn from_findings(findings: &[Finding]) -> Baseline {
        Baseline {
            entries: findings
                .iter()
                .map(|f| Entry {
                    fingerprint: f.fingerprint.clone(),
                    rule: f.rule_id_str().to_string(),
                    path: f.path.clone(),
                })
                .collect(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tool", Json::str("hp-gnn-lint")),
            ("schema_version", Json::num(1.0)),
            (
                "entries",
                Json::Arr(
                    self.entries
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("fingerprint", Json::str(&e.fingerprint)),
                                ("rule", Json::str(&e.rule)),
                                ("path", Json::str(&e.path)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn parse(text: &str) -> Result<Baseline, String> {
        let j = Json::parse(text).map_err(|e| format!("baseline: {e:?}"))?;
        let entries = j
            .get("entries")
            .and_then(|e| e.as_arr())
            .map_err(|e| format!("baseline: {e:?}"))?
            .iter()
            .map(|e| {
                Ok(Entry {
                    fingerprint: e
                        .get("fingerprint")
                        .and_then(|v| v.as_str())
                        .map_err(|e| format!("baseline entry: {e:?}"))?
                        .to_string(),
                    rule: e
                        .get("rule")
                        .and_then(|v| v.as_str())
                        .map_err(|e| format!("baseline entry: {e:?}"))?
                        .to_string(),
                    path: e
                        .get("path")
                        .and_then(|v| v.as_str())
                        .map_err(|e| format!("baseline entry: {e:?}"))?
                        .to_string(),
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(Baseline { entries })
    }
}

/// The ratchet verdict: both sides must be empty to pass.
#[derive(Debug, Default)]
pub struct Delta {
    /// Indices (into the findings slice) of findings absent from the
    /// baseline — new debt, always a failure.
    pub fresh: Vec<usize>,
    /// Baseline entries no longer found — fixed debt; regenerate the
    /// baseline so the ratchet tightens.
    pub stale: Vec<Entry>,
}

impl Delta {
    pub fn is_clean(&self) -> bool {
        self.fresh.is_empty() && self.stale.is_empty()
    }
}

pub fn diff(findings: &[Finding], baseline: &Baseline) -> Delta {
    let accepted: std::collections::BTreeSet<&str> =
        baseline.entries.iter().map(|e| e.fingerprint.as_str()).collect();
    let present: std::collections::BTreeSet<&str> =
        findings.iter().map(|f| f.fingerprint.as_str()).collect();
    Delta {
        fresh: findings
            .iter()
            .enumerate()
            .filter(|(_, f)| !accepted.contains(f.fingerprint.as_str()))
            .map(|(i, _)| i)
            .collect(),
        stale: baseline
            .entries
            .iter()
            .filter(|e| !present.contains(e.fingerprint.as_str()))
            .cloned()
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::super::RuleId;
    use super::*;

    fn finding(path: &str, line: usize, rule: RuleId) -> Finding {
        Finding {
            path: path.to_string(),
            line,
            rule: Some(rule),
            reason: "r".to_string(),
            fingerprint: String::new(),
        }
    }

    #[test]
    fn fingerprints_ignore_line_numbers_but_count_occurrences() {
        let mut a = vec![finding("x.rs", 10, RuleId::R3), finding("x.rs", 90, RuleId::R3)];
        // Same fn, same snippet, different lines: only the occurrence
        // index separates them.
        assign_fingerprints(&mut a, |_, _| ("f".into(), "x.unwrap()".into()));
        assert_ne!(a[0].fingerprint, a[1].fingerprint);

        let mut b = vec![finding("x.rs", 33, RuleId::R3), finding("x.rs", 150, RuleId::R3)];
        assign_fingerprints(&mut b, |_, _| ("f".into(), "x.unwrap()".into()));
        assert_eq!(a[0].fingerprint, b[0].fingerprint, "line shifts must not churn");
        assert_eq!(a[1].fingerprint, b[1].fingerprint);
    }

    #[test]
    fn baseline_round_trips_through_json() {
        let mut f = vec![finding("a.rs", 1, RuleId::C1), finding("b.rs", 2, RuleId::A1)];
        assign_fingerprints(&mut f, |p, _| (String::new(), p.to_string()));
        let base = Baseline::from_findings(&f);
        let again = Baseline::parse(&base.to_json().pretty()).unwrap();
        assert_eq!(again.entries, base.entries);
        assert_eq!(again.entries[0].rule, "C1");
    }

    #[test]
    fn diff_separates_fresh_from_stale() {
        let mut f = vec![finding("a.rs", 1, RuleId::R3), finding("a.rs", 2, RuleId::C1)];
        assign_fingerprints(&mut f, |_, l| (String::new(), format!("line{l}")));
        let base = Baseline::from_findings(&f[..1]);

        let d = diff(&f, &base);
        assert_eq!(d.fresh, vec![1], "the C1 finding is new debt");
        assert!(d.stale.is_empty());

        let d = diff(&f[1..], &base);
        assert_eq!(d.fresh, vec![0]);
        assert_eq!(d.stale.len(), 1, "the accepted R3 finding disappeared");
        assert!(!d.is_clean());
        assert!(diff(&f[..1], &base).is_clean());
    }
}
