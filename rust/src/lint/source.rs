//! Comment/string-aware source preprocessing for the lint pass.
//!
//! [`SourceFile::parse`] turns raw Rust text into the view the rules
//! operate on: per-line *scrubbed* code (comments, string/char literals
//! and raw strings blanked to spaces, so a rule pattern can never match
//! inside prose), per-line test-block membership (`#[cfg(test)] mod`
//! bodies are skipped — test code is allowed to `unwrap()` and iterate
//! hash maps), the innermost enclosing function name per line (for
//! function-scoped contracts like `TrainingSession::drive`), and the
//! suppression pragmas.
//!
//! This is a lightweight lexer, not a parser: it tracks exactly the
//! token classes the rules need (comments, strings, braces, `fn`/`mod`
//! headers) and nothing else, so the lint subsystem stays dependency-free.

/// One `// lint:allow(rule): reason` suppression pragma.
#[derive(Debug, Clone)]
pub struct Pragma {
    /// 1-based line the pragma comment sits on.
    pub line: usize,
    /// Rule id as written (`"D1"`, `"R1"`, …).
    pub rule: String,
    /// The mandatory justification after the colon.
    pub reason: String,
    /// 1-based line the pragma suppresses: its own line when that line
    /// carries code, otherwise the next line that does.
    pub target: usize,
}

/// Per-line view after scrubbing.
#[derive(Debug, Clone)]
pub struct Line {
    /// The line with comments and string/char literal *contents* blanked
    /// to spaces (delimiters too).  Columns line up with the original.
    pub code: String,
    /// Inside a `#[cfg(test)] mod … { }` body (rules skip these lines).
    pub is_test: bool,
    /// Innermost enclosing function name at the start of this line.
    pub func: Option<String>,
}

/// A preprocessed source file ready for rule checks.
#[derive(Debug)]
pub struct SourceFile {
    /// Path the findings are reported against (repo-relative).
    pub rel_path: String,
    /// 0-indexed; line `i` of the file is `lines[i]` (report as `i + 1`).
    pub lines: Vec<Line>,
    pub pragmas: Vec<Pragma>,
    /// Malformed pragmas: `(line, what is wrong)` — e.g. an empty reason
    /// or an unknown rule id.  The engine reports these as `P1` findings.
    pub pragma_problems: Vec<(usize, String)>,
}

impl SourceFile {
    pub fn parse(rel_path: &str, text: &str) -> SourceFile {
        let (scrubbed, comments) = scrub(text);
        let code_lines: Vec<&str> = scrubbed.split('\n').collect();
        let mut lines = annotate(&code_lines);
        // `split` yields one trailing empty entry for a final newline;
        // keep `lines` aligned with the file's real line count.
        if text.ends_with('\n') && lines.len() > 1 {
            lines.pop();
        }
        let (pragmas, pragma_problems) = extract_pragmas(&comments, &lines);
        SourceFile { rel_path: rel_path.to_string(), lines, pragmas, pragma_problems }
    }
}

/// Blank comments and literal contents to spaces (newlines preserved, so
/// line/column structure survives).  Returns the scrubbed text plus every
/// line comment's text keyed by 1-based line (pragmas live there).
fn scrub(text: &str) -> (String, Vec<(usize, String)>) {
    #[derive(PartialEq)]
    enum St {
        Code,
        LineComment,
        BlockComment(u32),
        Str,
        RawStr(u32),
    }
    let b: Vec<char> = text.chars().collect();
    let mut out = String::with_capacity(text.len());
    let mut comments: Vec<(usize, String)> = Vec::new();
    let mut cur = String::new();
    let mut st = St::Code;
    let mut line = 1usize;
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            if st == St::LineComment {
                comments.push((line, std::mem::take(&mut cur)));
                st = St::Code;
            }
            out.push('\n');
            line += 1;
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                let next = b.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    st = St::LineComment;
                    cur.clear();
                    out.push_str("  ");
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::BlockComment(1);
                    out.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    st = St::Str;
                    out.push(' ');
                    i += 1;
                } else if c == 'r' && matches!(next, Some('"') | Some('#')) && !prev_is_ident(&b, i)
                {
                    // Raw string r"…", r#"…"#, … — count the hashes.
                    let mut hashes = 0u32;
                    let mut j = i + 1;
                    while b.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if b.get(j) == Some(&'"') {
                        st = St::RawStr(hashes);
                        for _ in i..=j {
                            out.push(' ');
                        }
                        i = j + 1;
                    } else {
                        out.push(c); // `r#ident` raw identifier, not a string
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal vs lifetime: a backslash or a closing
                    // quote two chars ahead means char literal.
                    if next == Some('\\') {
                        let mut j = i + 2; // skip the escaped char
                        if b.get(j).is_some() {
                            j += 1;
                        }
                        while j < b.len() && b[j] != '\'' && b[j] != '\n' {
                            j += 1;
                        }
                        for _ in i..=j.min(b.len() - 1) {
                            out.push(' ');
                        }
                        i = j + 1;
                    } else if b.get(i + 2) == Some(&'\'') {
                        out.push_str("   ");
                        i += 3;
                    } else {
                        out.push(c); // lifetime: keep (harmless to rules)
                        i += 1;
                    }
                } else {
                    out.push(c);
                    i += 1;
                }
            }
            St::LineComment => {
                cur.push(c);
                out.push(' ');
                i += 1;
            }
            St::BlockComment(depth) => {
                let next = b.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    st = St::BlockComment(depth + 1);
                    out.push_str("  ");
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    st = if depth == 1 { St::Code } else { St::BlockComment(depth - 1) };
                    out.push_str("  ");
                    i += 2;
                } else {
                    out.push(' ');
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    out.push(' ');
                    if b.get(i + 1).map(|&n| n != '\n').unwrap_or(false) {
                        out.push(' ');
                        i += 1;
                    }
                    i += 1;
                } else {
                    if c == '"' {
                        st = St::Code;
                    }
                    out.push(' ');
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                if c == '"' {
                    let closes = (1..=hashes as usize)
                        .all(|k| b.get(i + k) == Some(&'#'));
                    if closes {
                        st = St::Code;
                        for _ in 0..=hashes as usize {
                            out.push(' ');
                        }
                        i += 1 + hashes as usize;
                        continue;
                    }
                }
                out.push(' ');
                i += 1;
            }
        }
    }
    if st == St::LineComment {
        comments.push((line, cur));
    }
    (out, comments)
}

fn prev_is_ident(b: &[char], i: usize) -> bool {
    i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_')
}

/// Second pass over scrubbed lines: brace depth → test-mod membership and
/// innermost enclosing function per line.
fn annotate(code_lines: &[&str]) -> Vec<Line> {
    let mut out = Vec::with_capacity(code_lines.len());
    let mut depth: i64 = 0;
    // (name, depth of the fn body once its `{` opened)
    let mut fn_stack: Vec<(String, i64)> = Vec::new();
    let mut pending_fn: Option<String> = None;
    // Saw `#[cfg(test)]`; the next `mod`'s `{` opens a skipped body.
    let mut pending_test_attr = false;
    let mut pending_test_mod = false;
    let mut test_depth: Option<i64> = None;

    for &code in code_lines {
        out.push(Line {
            code: code.to_string(),
            is_test: test_depth.is_some(),
            func: fn_stack.last().map(|(n, _)| n.clone()),
        });
        if test_depth.is_none() && code.replace(' ', "").contains("#[cfg(test)]") {
            pending_test_attr = true;
        }
        if pending_test_attr && has_word(code, "mod") {
            pending_test_mod = true;
        }
        if let Some(name) = fn_name_on(code) {
            pending_fn = Some(name);
        }
        for ch in code.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    if pending_test_mod {
                        test_depth = Some(depth);
                        pending_test_mod = false;
                        pending_test_attr = false;
                    }
                    if let Some(name) = pending_fn.take() {
                        fn_stack.push((name, depth));
                        // A `#[cfg(test)]`-gated fn (no mod) must not leak
                        // the pending attribute onto a later module.
                        pending_test_attr = false;
                    }
                }
                '}' => {
                    if test_depth == Some(depth) {
                        test_depth = None;
                    }
                    depth -= 1;
                    while fn_stack.last().map(|&(_, d)| d > depth).unwrap_or(false) {
                        fn_stack.pop();
                    }
                }
                ';' => {
                    // `fn` in a trait decl / type position never opens a
                    // body — a `;` at the same depth cancels it.
                    pending_fn = None;
                }
                _ => {}
            }
        }
    }
    out
}

/// The *last* `fn <ident>` on a scrubbed line (the one whose `{` comes
/// next), or `None`.
fn fn_name_on(code: &str) -> Option<String> {
    let mut found = None;
    let bytes = code.as_bytes();
    let mut i = 0usize;
    while let Some(pos) = code[i..].find("fn ") {
        let at = i + pos;
        let boundary = at == 0
            || !(bytes[at - 1].is_ascii_alphanumeric() || bytes[at - 1] == b'_');
        if boundary {
            let rest = code[at + 3..].trim_start();
            let name: String = rest
                .chars()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect();
            if !name.is_empty() {
                found = Some(name);
            }
        }
        i = at + 3;
    }
    found
}

/// Whole-word occurrence test on scrubbed code.
pub fn has_word(code: &str, word: &str) -> bool {
    find_word(code, word, 0).is_some()
}

/// Position of the next whole-word occurrence of `word` at or after
/// `from`, on scrubbed code.
pub fn find_word(code: &str, word: &str, from: usize) -> Option<usize> {
    let bytes = code.as_bytes();
    let mut i = from;
    while let Some(pos) = code.get(i..).and_then(|s| s.find(word)) {
        let at = i + pos;
        let pre_ok = at == 0
            || !(bytes[at - 1].is_ascii_alphanumeric() || bytes[at - 1] == b'_');
        let end = at + word.len();
        let post_ok = end >= bytes.len()
            || !(bytes[end].is_ascii_alphanumeric() || bytes[end] == b'_');
        if pre_ok && post_ok {
            return Some(at);
        }
        i = at + word.len().max(1);
    }
    None
}

/// Parse `lint:allow(rule): reason` pragmas out of the line comments and
/// resolve each to the line it suppresses.
fn extract_pragmas(
    comments: &[(usize, String)],
    lines: &[Line],
) -> (Vec<Pragma>, Vec<(usize, String)>) {
    let mut pragmas = Vec::new();
    let mut problems = Vec::new();
    for (line, text) in comments {
        // Doc comments (`///`, `//!`) are prose — they may *mention* the
        // pragma syntax (as the lint module's own docs do) without it
        // counting.  The captured text starts after `//`, so a doc
        // comment begins with `/` or `!`.
        if matches!(text.trim_start().chars().next(), Some('/') | Some('!')) {
            continue;
        }
        let Some(at) = text.find("lint:allow") else { continue };
        let rest = &text[at + "lint:allow".len()..];
        let parsed = (|| {
            let rest = rest.strip_prefix('(')?;
            let (rule, rest) = rest.split_once(')')?;
            let reason = rest.strip_prefix(':')?.trim();
            Some((rule.trim().to_string(), reason.to_string()))
        })();
        let Some((rule, reason)) = parsed else {
            problems.push((
                *line,
                "malformed pragma: expected `lint:allow(rule): reason`".to_string(),
            ));
            continue;
        };
        if reason.is_empty() {
            problems.push((
                *line,
                format!("pragma lint:allow({rule}) has an empty reason — say why"),
            ));
            continue;
        }
        // Target: the pragma's own line if it carries code (trailing
        // comment), else the next line that does.
        let own = lines
            .get(*line - 1)
            .map(|l| !l.code.trim().is_empty())
            .unwrap_or(false);
        let target = if own {
            Some(*line)
        } else {
            (*line..lines.len())
                .find(|&i| !lines[i].code.trim().is_empty())
                .map(|i| i + 1)
        };
        match target {
            Some(target) => pragmas.push(Pragma {
                line: *line,
                rule,
                reason,
                target,
            }),
            None => problems.push((
                *line,
                format!("pragma lint:allow({rule}) targets no code line"),
            )),
        }
    }
    (pragmas, problems)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_blanked() {
        let src = "let x = \"Instant::now\"; // Instant::now\nlet c = 'x';\n/* block\nInstant::now */ let y = 1;\n";
        let f = SourceFile::parse("t.rs", src);
        assert!(!f.lines[0].code.contains("Instant"), "{:?}", f.lines[0].code);
        assert!(f.lines[0].code.contains("let x ="));
        assert!(!f.lines[1].code.contains('x') || f.lines[1].code.contains("let c"));
        assert!(!f.lines[2].code.contains("block"));
        assert!(!f.lines[3].code.contains("Instant"));
        assert!(f.lines[3].code.contains("let y = 1;"));
    }

    #[test]
    fn raw_strings_and_escapes_are_blanked() {
        let src = "let a = r#\"unwrap() \"# ;\nlet b = \"\\\" .unwrap()\";\nlet l: &'static str = \"x\";\n";
        let f = SourceFile::parse("t.rs", src);
        assert!(!f.lines[0].code.contains("unwrap"));
        assert!(f.lines[0].code.ends_with(';'));
        assert!(!f.lines[1].code.contains("unwrap"), "{:?}", f.lines[1].code);
        assert!(f.lines[2].code.contains("'static"));
    }

    #[test]
    fn char_literals_do_not_eat_the_rest_of_the_line() {
        let src = "if c == '\\n' { x.unwrap(); }\nif d == '}' { depth -= 1; }\n";
        let f = SourceFile::parse("t.rs", src);
        assert!(f.lines[0].code.contains(".unwrap()"));
        assert!(f.lines[1].code.contains("depth -= 1"));
        // The '}' literal was blanked — brace depth is not corrupted.
        assert_eq!(f.lines[1].code.matches('}').count(), 1);
    }

    #[test]
    fn cfg_test_mod_bodies_are_marked() {
        let src = "fn real() { a(); }\n#[cfg(test)]\nmod tests {\n    fn t() { b(); }\n}\nfn after() { c(); }\n";
        let f = SourceFile::parse("t.rs", src);
        assert!(!f.lines[0].is_test);
        assert!(f.lines[3].is_test, "test body must be marked");
        assert!(!f.lines[5].is_test, "code after the test mod is live again");
    }

    #[test]
    fn function_names_track_multiline_signatures_and_closures() {
        let src = "fn load_binary(\n    path: &Path,\n) -> Result<()> {\n    let f = |x: u32| {\n        x * 2\n    };\n}\nfn other() {\n    1 + 1;\n}\n";
        let f = SourceFile::parse("t.rs", src);
        assert_eq!(f.lines[4].func.as_deref(), Some("load_binary"));
        assert_eq!(f.lines[8].func.as_deref(), Some("other"));
    }

    #[test]
    fn pragmas_parse_with_rule_reason_and_target() {
        let src = "// lint:allow(D1): iteration feeds a sorted vec\nfor k in m.keys() {}\nlet x = 1; // lint:allow(R1): infallible by construction\n// lint:allow(D2):\nlet y = 2;\n// lint:allow D2 broken\n";
        let f = SourceFile::parse("t.rs", src);
        assert_eq!(f.pragmas.len(), 2);
        assert_eq!(f.pragmas[0].rule, "D1");
        assert_eq!(f.pragmas[0].target, 2, "comment-only pragma targets the next code line");
        assert_eq!(f.pragmas[1].rule, "R1");
        assert_eq!(f.pragmas[1].target, 3, "trailing pragma targets its own line");
        assert_eq!(f.pragma_problems.len(), 2, "{:?}", f.pragma_problems);
        assert!(f.pragma_problems[0].1.contains("empty reason"));
        assert!(f.pragma_problems[1].1.contains("malformed"));
    }

    #[test]
    fn doc_comments_mentioning_the_syntax_are_not_pragmas() {
        let src = "//! Suppress with `lint:allow(rule): reason`.\n/// Same: lint:allow(D1): docs only.\nfn f() {}\n";
        let f = SourceFile::parse("t.rs", src);
        assert!(f.pragmas.is_empty(), "{:?}", f.pragmas);
        assert!(f.pragma_problems.is_empty(), "{:?}", f.pragma_problems);
    }

    #[test]
    fn word_boundaries_respected() {
        assert!(has_word("let map = x;", "map"));
        assert!(!has_word("let remap = x;", "map"));
        assert!(!has_word("let mapper = x;", "map"));
        assert_eq!(find_word("self.map.keys()", "map", 0), Some(5));
    }
}
