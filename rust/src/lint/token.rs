//! Tokenizer over scrubbed source — the substrate of the whole-program
//! lint passes.
//!
//! [`tokenize`] turns the scrubbed per-line code produced by
//! [`super::source::SourceFile`] into a flat token stream: identifiers,
//! number literals, and punctuation (with `::` merged into one token,
//! since path parsing is what the item parser and call-graph builder do
//! all day).  Comments and literals were already blanked by the scrubber,
//! so a token can never come from prose.
//!
//! Deliberately *not* a full Rust lexer: lifetimes are dropped (after the
//! scrubber, a lone `'` can only start a lifetime), float/integer suffix
//! distinctions are irrelevant, and multi-char operators other than `::`
//! stay as single-char puncts — the downstream passes only ever look at
//! `. ( ) { } < > ! ; , = & #` and `::`.

/// Token classes the item parser and call-graph builder distinguish.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `foo`, `Server`).
    Ident,
    /// Number literal (`0`, `0.5f32`, `0x1f`).
    Num,
    /// Single punctuation char, or the merged `::`.
    Punct,
}

/// One token with its source position.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    /// Token text (`"fn"`, `"::"`, `"{"`).
    pub text: String,
    /// 1-based source line.
    pub line: usize,
    /// 0-based byte column of the first char on that line.
    pub col: usize,
}

impl Tok {
    pub fn is(&self, text: &str) -> bool {
        self.text == text
    }

    pub fn is_ident(&self) -> bool {
        self.kind == TokKind::Ident
    }
}

/// Tokenize scrubbed code lines (1-based line numbers follow the slice
/// order).  Lifetime quotes are skipped entirely.
pub fn tokenize(lines: &[String]) -> Vec<Tok> {
    let mut out = Vec::new();
    for (li, code) in lines.iter().enumerate() {
        let line = li + 1;
        let b: Vec<char> = code.chars().collect();
        let mut i = 0usize;
        while i < b.len() {
            let c = b[i];
            if c.is_whitespace() {
                i += 1;
                continue;
            }
            if c.is_alphabetic() || c == '_' {
                let start = i;
                while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
                out.push(Tok {
                    kind: TokKind::Ident,
                    text: b[start..i].iter().collect(),
                    line,
                    col: start,
                });
                continue;
            }
            if c.is_ascii_digit() {
                let start = i;
                while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
                // Fractional part — but `0..n` is a range, not a float.
                if i + 1 < b.len() && b[i] == '.' && b[i + 1].is_ascii_digit() {
                    i += 1;
                    while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_') {
                        i += 1;
                    }
                }
                out.push(Tok {
                    kind: TokKind::Num,
                    text: b[start..i].iter().collect(),
                    line,
                    col: start,
                });
                continue;
            }
            if c == '\'' {
                // Post-scrub, a quote can only introduce a lifetime
                // (`&'a str`): skip the quote and let the ident lex.
                i += 1;
                continue;
            }
            if c == ':' && b.get(i + 1) == Some(&':') {
                out.push(Tok { kind: TokKind::Punct, text: "::".to_string(), line, col: i });
                i += 2;
                continue;
            }
            out.push(Tok { kind: TokKind::Punct, text: c.to_string(), line, col: i });
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        let f = super::super::source::SourceFile::parse("t.rs", src);
        f.lines.iter().map(|l| l.code.clone()).collect()
    }

    #[test]
    fn idents_numbers_and_paths() {
        let toks = tokenize(&texts("let x = util::json::parse(0.5f32);\n"));
        let flat: Vec<&str> = toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(
            flat,
            vec!["let", "x", "=", "util", "::", "json", "::", "parse", "(", "0.5f32", ")", ";"]
        );
        assert_eq!(toks[9].kind, TokKind::Num);
        assert!(toks.iter().all(|t| t.line == 1));
    }

    #[test]
    fn ranges_do_not_lex_as_floats() {
        let toks = tokenize(&texts("for i in 0..n {}\n"));
        let flat: Vec<&str> = toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(flat, vec!["for", "i", "in", "0", ".", ".", "n", "{", "}"]);
    }

    #[test]
    fn lifetimes_are_dropped_and_strings_already_blank() {
        let toks = tokenize(&texts("fn f<'a>(s: &'a str) { g(\"x.y(\"); }\n"));
        let flat: Vec<&str> = toks.iter().map(|t| t.text.as_str()).collect();
        assert!(flat.contains(&"a"), "{flat:?}");
        assert!(!flat.iter().any(|t| t.contains('"')), "{flat:?}");
        // The call inside the string literal is gone; `g(` survives.
        assert!(flat.windows(2).any(|w| w == ["g", "("]), "{flat:?}");
        assert!(!flat.contains(&"y"), "{flat:?}");
    }

    #[test]
    fn columns_are_byte_accurate() {
        let toks = tokenize(&texts("  ab.cd();\n"));
        assert_eq!(toks[0].text, "ab");
        assert_eq!(toks[0].col, 2);
        assert_eq!(toks[2].text, "cd");
        assert_eq!(toks[2].col, 5);
    }
}
