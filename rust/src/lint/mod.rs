//! `hp-gnn lint` — static enforcement of the determinism and
//! serving-robustness contracts.
//!
//! The repo's load-bearing invariants — batch *k* is a pure function of
//! `(seed, k)`, kernels are bit-identical at every thread count, served
//! logits are bit-identical across worker counts and coalescing patterns,
//! a serving worker degrades per-request instead of crashing the pool —
//! are probed dynamically by the test matrix, but a finite matrix cannot
//! stop the *next* change from quietly introducing a `HashMap` iteration
//! or a wall-clock read into a determinism-critical module.  This pass
//! checks the contracts at the source level, on every `make lint` / CI
//! run.
//!
//! # Rules
//!
//! | id | name | what it forbids |
//! |----|------|-----------------|
//! | D1 | no-unordered-iteration | `HashMap`/`HashSet` iteration (order leaks into outputs) |
//! | D2 | no-wallclock | `Instant::now` / `SystemTime` in deterministic step paths |
//! | D3 | no-ad-hoc-float-reduction | float `sum`/`fold` bypassing the `kernels::` helpers |
//! | R1 | no-panic-in-serving-path | `unwrap`/`expect`/`panic!` where a request must fail soft |
//! | R2 | checked-arithmetic-in-loaders | unchecked size arithmetic on header-derived counts |
//! | R3 | no-panic-reachable-from-entrypoint | panics in fns *transitively called* from serving/training roots |
//! | C1 | lock-order | inconsistent lock acquisition order; blocking calls under a held guard |
//! | A1 | no-alloc-in-kernel-loop | allocation inside loop bodies of hot-path files |
//!
//! D1–R2 are line-level and apply only where a [`Contract`] binds them
//! (see [`CONTRACTS`]).  R3/C1/A1 are **whole-program**: the engine
//! tokenizes every file ([`token`]), parses items ([`items`]), builds a
//! crate-wide call graph ([`callgraph`] — resolution stats surface in
//! `--json`), and walks it ([`whole`]).  The scanner is
//! comment/string-aware and skips `#[cfg(test)] mod` bodies
//! ([`source`]).  Suppression requires an inline
//! `// lint:allow(rule): <reason>` pragma with a non-empty reason, and
//! a pragma that suppresses nothing is itself an error — every
//! exception stays justified and current.
//!
//! Findings reuse the [`crate::api::diag`] shape (`hp-gnn validate`'s
//! diagnostic contract): path-anchored reason + fix hint, all problems
//! reported in one pass.  `hp-gnn lint --json` emits the machine-readable
//! report, `--format sarif` the SARIF 2.1.0 form ([`sarif`]), and
//! `--baseline lint_baseline.json` engages the ratchet ([`baseline`]):
//! fail on findings not in the baseline, and fail when the baseline
//! could shrink but was not regenerated (`make lint-baseline`).

pub mod baseline;
pub mod callgraph;
pub mod items;
pub mod rules;
pub mod sarif;
pub mod source;
pub mod token;
pub mod whole;

use std::path::{Path, PathBuf};

use crate::api::diag::{Diagnostic, Diagnostics};
use crate::util::json::Json;

/// The eight contract rules.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RuleId {
    D1,
    D2,
    D3,
    R1,
    R2,
    R3,
    C1,
    A1,
}

impl RuleId {
    pub const ALL: [RuleId; 8] = [
        RuleId::D1,
        RuleId::D2,
        RuleId::D3,
        RuleId::R1,
        RuleId::R2,
        RuleId::R3,
        RuleId::C1,
        RuleId::A1,
    ];

    /// Short id as written in pragmas (`"D1"`).
    pub fn id(self) -> &'static str {
        match self {
            RuleId::D1 => "D1",
            RuleId::D2 => "D2",
            RuleId::D3 => "D3",
            RuleId::R1 => "R1",
            RuleId::R2 => "R2",
            RuleId::R3 => "R3",
            RuleId::C1 => "C1",
            RuleId::A1 => "A1",
        }
    }

    /// Human name (`"no-unordered-iteration"`).
    pub fn name(self) -> &'static str {
        match self {
            RuleId::D1 => "no-unordered-iteration",
            RuleId::D2 => "no-wallclock",
            RuleId::D3 => "no-ad-hoc-float-reduction",
            RuleId::R1 => "no-panic-in-serving-path",
            RuleId::R2 => "checked-arithmetic-in-loaders",
            RuleId::R3 => "no-panic-reachable-from-entrypoint",
            RuleId::C1 => "lock-order",
            RuleId::A1 => "no-alloc-in-kernel-loop",
        }
    }

    /// The repo-blessed fix, attached to findings as the diagnostic hint.
    pub fn hint(self) -> &'static str {
        match self {
            RuleId::D1 => {
                "use BTreeMap/BTreeSet, a Vec/VecDeque insertion ring, or sort before iterating"
            }
            RuleId::D2 => {
                "keep wall-clock reads in measurement-only code (util::stats::Timer) — \
                 step outputs must be a pure function of (seed, step)"
            }
            RuleId::D3 => {
                "reduce through the kernels:: helpers (their accumulation order is \
                 oracle-pinned), or justify with lint:allow(D3) if the value never \
                 reaches a determinism-pinned output"
            }
            RuleId::R1 => {
                "propagate with `?`/context, recover (util::sync::lock_unpoisoned), or \
                 justify provable infallibility with lint:allow(R1)"
            }
            RuleId::R2 => "use checked_add/checked_mul on header-derived sizes",
            RuleId::R3 => {
                "make the whole chain fallible (`?`/context) or recover at the callee; \
                 the printed call chain shows how the entrypoint reaches the panic — \
                 accepted legacy sites live in lint_baseline.json"
            }
            RuleId::C1 => {
                "acquire locks in one global order everywhere, and drop guards (scope \
                 or explicit drop) before send/recv/join"
            }
            RuleId::A1 => {
                "allocate once in a prologue (with_capacity) and reuse the buffer \
                 across iterations"
            }
        }
    }

    pub fn parse(s: &str) -> Option<RuleId> {
        RuleId::ALL.iter().copied().find(|r| r.id() == s)
    }
}

/// Where a bound rule applies within the file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scope {
    /// The whole file (minus `#[cfg(test)] mod` bodies).
    File,
    /// Only inside the named function (e.g. `TrainingSession::drive`).
    Function(&'static str),
}

/// One row of the contract table: rule `rule` applies to every file under
/// `prefix` (a `rust/src/`-relative path prefix), because `why`.
#[derive(Debug, Clone, Copy)]
pub struct Contract {
    pub prefix: &'static str,
    pub rule: RuleId,
    pub scope: Scope,
    pub why: &'static str,
}

/// The per-module contract table — which invariant each module owes.
pub const CONTRACTS: &[Contract] = &[
    Contract {
        prefix: "runtime/kernels/",
        rule: RuleId::D1,
        scope: Scope::File,
        why: "kernel outputs are oracle-pinned and bit-identical at every thread count",
    },
    Contract {
        prefix: "runtime/kernels/",
        rule: RuleId::D2,
        scope: Scope::File,
        why: "kernel outputs are oracle-pinned and bit-identical at every thread count",
    },
    Contract {
        prefix: "sampler/",
        rule: RuleId::D1,
        scope: Scope::File,
        why: "batch k is a pure function of (seed, k)",
    },
    Contract {
        prefix: "sampler/",
        rule: RuleId::D2,
        scope: Scope::File,
        why: "batch k is a pure function of (seed, k)",
    },
    Contract {
        prefix: "serve/",
        rule: RuleId::D1,
        scope: Scope::File,
        why: "served logits are bit-identical across worker counts and coalescing \
              patterns (cache eviction included)",
    },
    // serve/ and net/ previously owed the module-textual R1; they are
    // now covered (more precisely and transitively) by R3, whose roots
    // are the request entrypoints and detached thread bodies listed in
    // [`whole::R3_ROOT_QPATHS`] / [`whole::R3_ROOT_MODULES`]:
    // Server::classify / try_classify, the net::routes handlers,
    // TrainingSession::step, and the run_worker / run_batcher /
    // serve_pool / accept_loop thread bodies.
    Contract {
        prefix: "net/",
        rule: RuleId::D2,
        scope: Scope::File,
        why: "latency and socket deadlines go through util::stats::Timer; the \
              request-log wall-clock read lives in obs::events now, so net/ \
              itself carries no allow",
    },
    Contract {
        prefix: "obs/",
        rule: RuleId::D1,
        scope: Scope::File,
        why: "telemetry must render deterministically (BTreeMap-ordered \
              registry/exposition) — observation cannot reintroduce map-order \
              nondeterminism",
    },
    Contract {
        prefix: "obs/",
        rule: RuleId::D2,
        scope: Scope::File,
        why: "the tracer clocks through a Timer epoch; the single reasoned \
              wall-clock read in the tree is obs/events.rs's event timestamp",
    },
    Contract {
        prefix: "serve/infer.rs",
        rule: RuleId::D2,
        scope: Scope::File,
        why: "the shared inference path feeds both eval and serve — wall-clock reads \
              would un-pin served logits",
    },
    Contract {
        prefix: "serve/infer.rs",
        rule: RuleId::D3,
        scope: Scope::File,
        why: "logits post-processing must not reorder float accumulation",
    },
    Contract {
        prefix: "coordinator/session.rs",
        rule: RuleId::D1,
        scope: Scope::File,
        why: "the session's batch_rng(seed, k) purity makes resume bit-exact",
    },
    Contract {
        prefix: "coordinator/session.rs",
        rule: RuleId::D2,
        scope: Scope::File,
        why: "the session's batch_rng(seed, k) purity makes resume bit-exact",
    },
    Contract {
        prefix: "coordinator/session.rs",
        rule: RuleId::R1,
        scope: Scope::Function("drive"),
        why: "the long-running training driver reports errors; it does not crash \
              mid-run with checkpoints unwritten",
    },
    Contract {
        prefix: "graph/io.rs",
        rule: RuleId::R2,
        scope: Scope::File,
        why: "adversarial headers must fail the length check, not wrap it",
    },
    Contract {
        prefix: "graph/store/",
        rule: RuleId::R2,
        scope: Scope::File,
        why: "HPGNNG02 headers and chunk tables are attacker-controlled bytes — \
              offset/size arithmetic must fail the bounds check, not wrap it",
    },
    Contract {
        prefix: "graph/store/",
        rule: RuleId::D1,
        scope: Scope::File,
        why: "snapshot neighbor merges feed the samplers — map-order nondeterminism \
              would un-pin the batch stream and the pack/open bit-identity",
    },
    Contract {
        prefix: "runtime/reference.rs",
        rule: RuleId::D3,
        scope: Scope::File,
        why: "the reference executor is the oracle — reductions go through kernels::",
    },
    Contract {
        prefix: "runtime/executor.rs",
        rule: RuleId::D3,
        scope: Scope::File,
        why: "executor-side reductions must use the oracle-pinned kernels:: helpers",
    },
    Contract {
        prefix: "runtime/inputs.rs",
        rule: RuleId::D3,
        scope: Scope::File,
        why: "input packing feeds the kernels — no ad-hoc float accumulation",
    },
    Contract {
        prefix: "runtime/tensor.rs",
        rule: RuleId::D3,
        scope: Scope::File,
        why: "tensor utilities sit under every kernel — no ad-hoc float accumulation",
    },
    Contract {
        prefix: "runtime/weights.rs",
        rule: RuleId::D3,
        scope: Scope::File,
        why: "weight updates are part of the bit-exact train step",
    },
    Contract {
        prefix: "runtime/kernels/",
        rule: RuleId::A1,
        scope: Scope::File,
        why: "kernel loop bodies are the per-batch hot path — allocation belongs in \
              the prologue (§5.2 t_compute modeling assumes steady-state buffers)",
    },
    Contract {
        prefix: "serve/infer.rs",
        rule: RuleId::A1,
        scope: Scope::File,
        why: "the shared inference path runs per request — loop-body allocation is \
              tail latency",
    },
];

/// Rule bindings for one `rust/src/`-relative file path.
pub fn contracts_for(rel_path: &str) -> Vec<(RuleId, Scope)> {
    CONTRACTS
        .iter()
        .filter(|c| rel_path.starts_with(c.prefix))
        .map(|c| (c.rule, c.scope))
        .collect()
}

/// One lint violation (or pragma problem, when `rule` is `None`).
#[derive(Debug, Clone)]
pub struct Finding {
    /// `rust/src/`-relative path of the offending file.
    pub path: String,
    /// 1-based line.
    pub line: usize,
    /// The violated rule; `None` for pragma problems (`P1`/`P2`, which
    /// carry their id in `reason`).
    pub rule: Option<RuleId>,
    pub reason: String,
    /// Line-number-free identity for the ratchet baseline — see
    /// [`baseline::fingerprint`].  Assigned by [`analyze_files`]; empty
    /// on hand-built findings.
    pub fingerprint: String,
}

impl Finding {
    /// The rule id string, covering pragma pseudo-rules (`P1`/`P2`).
    pub fn rule_id_str(&self) -> &str {
        match self.rule {
            Some(r) => r.id(),
            None => pragma_rule_id(&self.reason),
        }
    }

    /// The finding as an [`api::diag`](crate::api::diag) diagnostic:
    /// `path:line` anchor, rule-tagged reason, per-rule fix hint.
    pub fn to_diagnostic(&self) -> Diagnostic {
        let reason = match self.rule {
            Some(r) => format!("[{} {}] {}", r.id(), r.name(), self.reason),
            None => self.reason.clone(),
        };
        Diagnostic {
            path: format!("{}:{}", self.path, self.line),
            reason,
            hint: self.rule.map(|r| r.hint().to_string()),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("path", Json::str(&self.path)),
            ("line", Json::num(self.line as f64)),
            ("rule", Json::str(self.rule_id_str())),
            (
                "name",
                match self.rule {
                    Some(r) => Json::str(r.name()),
                    None => Json::str("pragma"),
                },
            ),
            ("reason", Json::str(&self.reason)),
            (
                "hint",
                match self.rule {
                    Some(r) => Json::str(r.hint()),
                    None => Json::Null,
                },
            ),
            ("fingerprint", Json::str(&self.fingerprint)),
        ])
    }
}

/// Pragma findings encode their id (`P1`/`P2`) as the reason prefix.
fn pragma_rule_id(reason: &str) -> &'static str {
    if reason.starts_with("P1") {
        "P1"
    } else {
        "P2"
    }
}

/// Result of one lint pass.
#[derive(Debug, Default)]
pub struct Report {
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
    /// Call-graph resolution statistics from the whole-program pass.
    pub stats: callgraph::Stats,
    /// Resolved caller→callee edge count.
    pub edge_count: usize,
}

impl Report {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Findings as an [`api::diag::Diagnostics`](crate::api::diag) set —
    /// what `hp-gnn lint` prints (all problems in one pass, like
    /// `validate`).
    pub fn into_diagnostics(&self) -> Diagnostics {
        let mut d = Diagnostics::new();
        for f in &self.findings {
            let diag = f.to_diagnostic();
            match diag.hint {
                Some(h) => d.push_hint(diag.path, diag.reason, h),
                None => d.push(diag.path, diag.reason),
            }
        }
        d
    }

    /// The `--json` report (schema documented in README "Static
    /// analysis").
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tool", Json::str("hp-gnn-lint")),
            ("schema_version", Json::num(2.0)),
            ("files_scanned", Json::num(self.files_scanned as f64)),
            ("clean", Json::Bool(self.is_clean())),
            (
                "callgraph",
                Json::obj(vec![
                    ("functions", Json::num(self.stats.functions as f64)),
                    ("edges", Json::num(self.edge_count as f64)),
                    ("calls", Json::num(self.stats.calls as f64)),
                    ("resolved", Json::num(self.stats.resolved as f64)),
                    ("external", Json::num(self.stats.external as f64)),
                    ("ambiguous", Json::num(self.stats.ambiguous as f64)),
                    (
                        "resolution_pct",
                        Json::num((self.stats.resolution_pct() * 10.0).round() / 10.0),
                    ),
                ]),
            ),
            (
                "findings",
                Json::Arr(self.findings.iter().map(|f| f.to_json()).collect()),
            ),
        ])
    }
}

/// The full analysis pipeline over a set of `(rel_path, text)` inputs:
/// per-file rules, then item parsing + crate-wide call graph + the
/// whole-program rules (R3/C1/A1), then one global pragma-suppression
/// pass and fingerprint assignment.  [`lint_source`] and [`lint_tree`]
/// are thin wrappers.
pub fn analyze_files(inputs: &[(String, String)]) -> Report {
    let parsed: Vec<(source::SourceFile, items::FileItems)> = inputs
        .iter()
        .map(|(rel, text)| {
            let src = source::SourceFile::parse(rel, text);
            let it = items::parse(&src);
            (src, it)
        })
        .collect();
    let graph = callgraph::build(&parsed);

    let mut raw: Vec<Finding> = Vec::new();
    for (src, _) in &parsed {
        raw.extend(rules::file_rule_findings(src, &contracts_for(&src.rel_path)));
    }
    raw.extend(whole::r3_panic_reachability(&parsed, &graph));
    raw.extend(whole::c1_lock_order(&parsed));
    raw.extend(whole::a1_hot_path_alloc(&parsed));

    // Global pragma pass: every finding — per-file or whole-program —
    // meets its file's pragmas exactly once.
    let mut findings: Vec<Finding> = Vec::new();
    for (src, _) in &parsed {
        let mut mine: Vec<Finding> =
            raw.iter().filter(|f| f.path == src.rel_path).cloned().collect();
        rules::apply_pragmas(src, &mut mine);
        findings.extend(mine);
    }
    findings.sort_by(|a, b| (&a.path, a.line).cmp(&(&b.path, b.line)));

    let by_path: std::collections::BTreeMap<&str, &source::SourceFile> =
        parsed.iter().map(|(src, _)| (src.rel_path.as_str(), src)).collect();
    baseline::assign_fingerprints(&mut findings, |path, line| {
        match by_path.get(path).and_then(|src| src.lines.get(line - 1)) {
            Some(l) => (l.func.clone().unwrap_or_default(), l.code.trim().to_string()),
            None => (String::new(), String::new()),
        }
    });

    let edge_count = graph.edges.values().map(|v| v.len()).sum();
    Report { files_scanned: inputs.len(), findings, stats: graph.stats, edge_count }
}

/// Lint a single source text as if it lived at `rel_path` under
/// `rust/src/` — the contract table decides which per-file rules bind,
/// and the whole-program rules run over the one-file "crate".  This is
/// the unit the fixture tests drive directly.
pub fn lint_source(rel_path: &str, text: &str) -> Vec<Finding> {
    analyze_files(&[(rel_path.to_string(), text.to_string())]).findings
}

/// Lint the whole `rust/src/` tree under `repo_root`.  Every file is
/// scanned (so stray pragmas are caught even in uncontracted modules);
/// rules apply per the contract table.
pub fn lint_tree(repo_root: &Path) -> anyhow::Result<Report> {
    let src_root = repo_root.join("rust").join("src");
    anyhow::ensure!(
        src_root.is_dir(),
        "lint: {} is not a directory (run from the repo root or pass --root)",
        src_root.display()
    );
    let mut files = Vec::new();
    collect_rs(&src_root, &mut files)?;
    files.sort();
    let mut inputs = Vec::new();
    for path in files {
        let rel = path
            .strip_prefix(&src_root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        inputs.push((rel, std::fs::read_to_string(&path)?));
    }
    Ok(analyze_files(&inputs))
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contract_table_binds_the_documented_modules() {
        let kernels = contracts_for("runtime/kernels/dense.rs");
        assert!(kernels.iter().any(|(r, _)| *r == RuleId::D1));
        assert!(kernels.iter().any(|(r, _)| *r == RuleId::D2));
        assert!(kernels.iter().any(|(r, _)| *r == RuleId::A1), "kernels owe hot-path alloc");
        let serve = contracts_for("serve/server.rs");
        assert!(
            !serve.iter().any(|(r, _)| *r == RuleId::R1),
            "serve/ panics are covered transitively by R3 now, not module-textual R1"
        );
        assert!(serve.iter().any(|(r, _)| *r == RuleId::D1));
        let session = contracts_for("coordinator/session.rs");
        assert!(session
            .iter()
            .any(|(r, s)| *r == RuleId::R1 && *s == Scope::Function("drive")));
        assert!(contracts_for("graph/io.rs").iter().any(|(r, _)| *r == RuleId::R2));
        let net = contracts_for("net/http.rs");
        assert!(!net.iter().any(|(r, _)| *r == RuleId::R1), "net/ moved to R3 too");
        assert!(net.iter().any(|(r, _)| *r == RuleId::D2), "net/ owes Timer-only time");
        let infer = contracts_for("serve/infer.rs");
        assert!(infer.iter().any(|(r, _)| *r == RuleId::A1), "infer owes hot-path alloc");
        let obs = contracts_for("obs/trace.rs");
        assert!(obs.iter().any(|(r, _)| *r == RuleId::D1), "obs/ owes deterministic render");
        assert!(obs.iter().any(|(r, _)| *r == RuleId::D2), "obs/ owes Timer-only clocks");
        assert!(contracts_for("util/json.rs").is_empty(), "uncontracted module");
    }

    #[test]
    fn rule_ids_round_trip() {
        for r in RuleId::ALL {
            assert_eq!(RuleId::parse(r.id()), Some(r));
            assert!(!r.name().is_empty() && !r.hint().is_empty());
        }
        assert_eq!(RuleId::parse("Z9"), None);
    }

    #[test]
    fn findings_render_as_diag_shape_and_json() {
        let f = Finding {
            path: "serve/server.rs".into(),
            line: 41,
            rule: Some(RuleId::R1),
            reason: "`.unwrap()` can panic in the serving path".into(),
            fingerprint: "0011223344556677".into(),
        };
        let d = f.to_diagnostic();
        assert_eq!(d.path, "serve/server.rs:41");
        assert!(d.reason.starts_with("[R1 no-panic-in-serving-path]"), "{}", d.reason);
        assert!(d.hint.is_some());
        let j = f.to_json();
        assert_eq!(j.get("rule").unwrap(), &Json::str("R1"));
        assert_eq!(j.get("line").unwrap(), &Json::num(41.0));
        assert_eq!(j.get("fingerprint").unwrap(), &Json::str("0011223344556677"));

        let report = Report { files_scanned: 3, findings: vec![f], ..Report::default() };
        let j = report.to_json();
        assert_eq!(j.get("clean").unwrap(), &Json::Bool(false));
        let cg = j.get("callgraph").unwrap();
        assert_eq!(cg.get("functions").unwrap(), &Json::num(0.0));
        // Must serialize to parseable JSON.
        Json::parse(&j.pretty()).unwrap();
    }

    #[test]
    fn analyze_files_reports_callgraph_stats_and_fingerprints() {
        let report = analyze_files(&[(
            "serve/server.rs".to_string(),
            "impl Server {\n    pub fn classify(&self) -> u32 {\n        helper()\n    }\n}\n\nfn helper() -> u32 {\n    7\n}\n"
                .to_string(),
        )]);
        assert!(report.is_clean(), "{:?}", report.findings);
        assert_eq!(report.stats.functions, 2);
        assert_eq!(report.edge_count, 1);
        assert_eq!(report.stats.resolved, 1);
    }
}
