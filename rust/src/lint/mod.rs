//! `hp-gnn lint` — static enforcement of the determinism and
//! serving-robustness contracts.
//!
//! The repo's load-bearing invariants — batch *k* is a pure function of
//! `(seed, k)`, kernels are bit-identical at every thread count, served
//! logits are bit-identical across worker counts and coalescing patterns,
//! a serving worker degrades per-request instead of crashing the pool —
//! are probed dynamically by the test matrix, but a finite matrix cannot
//! stop the *next* change from quietly introducing a `HashMap` iteration
//! or a wall-clock read into a determinism-critical module.  This pass
//! checks the contracts at the source level, on every `make lint` / CI
//! run.
//!
//! # Rules
//!
//! | id | name | what it forbids |
//! |----|------|-----------------|
//! | D1 | no-unordered-iteration | `HashMap`/`HashSet` iteration (order leaks into outputs) |
//! | D2 | no-wallclock | `Instant::now` / `SystemTime` in deterministic step paths |
//! | D3 | no-ad-hoc-float-reduction | float `sum`/`fold` bypassing the `kernels::` helpers |
//! | R1 | no-panic-in-serving-path | `unwrap`/`expect`/`panic!` where a request must fail soft |
//! | R2 | checked-arithmetic-in-loaders | unchecked size arithmetic on header-derived counts |
//!
//! Each rule applies only where a [`Contract`] binds it (see
//! [`CONTRACTS`]); the scanner is comment/string-aware and skips
//! `#[cfg(test)] mod` bodies ([`source`]).  Suppression requires an
//! inline `// lint:allow(rule): <reason>` pragma with a non-empty
//! reason, and a pragma that suppresses nothing is itself an error —
//! every exception stays justified and current.
//!
//! Findings reuse the [`crate::api::diag`] shape (`hp-gnn validate`'s
//! diagnostic contract): path-anchored reason + fix hint, all problems
//! reported in one pass.  `hp-gnn lint --json` emits the machine-readable
//! report (schema in README "Static analysis").

pub mod rules;
pub mod source;

use std::path::{Path, PathBuf};

use crate::api::diag::{Diagnostic, Diagnostics};
use crate::util::json::Json;

/// The five contract rules.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RuleId {
    D1,
    D2,
    D3,
    R1,
    R2,
}

impl RuleId {
    pub const ALL: [RuleId; 5] = [RuleId::D1, RuleId::D2, RuleId::D3, RuleId::R1, RuleId::R2];

    /// Short id as written in pragmas (`"D1"`).
    pub fn id(self) -> &'static str {
        match self {
            RuleId::D1 => "D1",
            RuleId::D2 => "D2",
            RuleId::D3 => "D3",
            RuleId::R1 => "R1",
            RuleId::R2 => "R2",
        }
    }

    /// Human name (`"no-unordered-iteration"`).
    pub fn name(self) -> &'static str {
        match self {
            RuleId::D1 => "no-unordered-iteration",
            RuleId::D2 => "no-wallclock",
            RuleId::D3 => "no-ad-hoc-float-reduction",
            RuleId::R1 => "no-panic-in-serving-path",
            RuleId::R2 => "checked-arithmetic-in-loaders",
        }
    }

    /// The repo-blessed fix, attached to findings as the diagnostic hint.
    pub fn hint(self) -> &'static str {
        match self {
            RuleId::D1 => {
                "use BTreeMap/BTreeSet, a Vec/VecDeque insertion ring, or sort before iterating"
            }
            RuleId::D2 => {
                "keep wall-clock reads in measurement-only code (util::stats::Timer) — \
                 step outputs must be a pure function of (seed, step)"
            }
            RuleId::D3 => {
                "reduce through the kernels:: helpers (their accumulation order is \
                 oracle-pinned), or justify with lint:allow(D3) if the value never \
                 reaches a determinism-pinned output"
            }
            RuleId::R1 => {
                "propagate with `?`/context, recover (serve::lock_unpoisoned), or \
                 justify provable infallibility with lint:allow(R1)"
            }
            RuleId::R2 => "use checked_add/checked_mul on header-derived sizes",
        }
    }

    pub fn parse(s: &str) -> Option<RuleId> {
        RuleId::ALL.iter().copied().find(|r| r.id() == s)
    }
}

/// Where a bound rule applies within the file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scope {
    /// The whole file (minus `#[cfg(test)] mod` bodies).
    File,
    /// Only inside the named function (e.g. `TrainingSession::drive`).
    Function(&'static str),
}

/// One row of the contract table: rule `rule` applies to every file under
/// `prefix` (a `rust/src/`-relative path prefix), because `why`.
#[derive(Debug, Clone, Copy)]
pub struct Contract {
    pub prefix: &'static str,
    pub rule: RuleId,
    pub scope: Scope,
    pub why: &'static str,
}

/// The per-module contract table — which invariant each module owes.
pub const CONTRACTS: &[Contract] = &[
    Contract {
        prefix: "runtime/kernels/",
        rule: RuleId::D1,
        scope: Scope::File,
        why: "kernel outputs are oracle-pinned and bit-identical at every thread count",
    },
    Contract {
        prefix: "runtime/kernels/",
        rule: RuleId::D2,
        scope: Scope::File,
        why: "kernel outputs are oracle-pinned and bit-identical at every thread count",
    },
    Contract {
        prefix: "sampler/",
        rule: RuleId::D1,
        scope: Scope::File,
        why: "batch k is a pure function of (seed, k)",
    },
    Contract {
        prefix: "sampler/",
        rule: RuleId::D2,
        scope: Scope::File,
        why: "batch k is a pure function of (seed, k)",
    },
    Contract {
        prefix: "serve/",
        rule: RuleId::D1,
        scope: Scope::File,
        why: "served logits are bit-identical across worker counts and coalescing \
              patterns (cache eviction included)",
    },
    Contract {
        prefix: "serve/",
        rule: RuleId::R1,
        scope: Scope::File,
        why: "a serving worker degrades per-request; one bad request or poisoned lock \
              must not take down the pool",
    },
    Contract {
        prefix: "net/",
        rule: RuleId::R1,
        scope: Scope::File,
        why: "the HTTP frontend degrades per request: a malformed request or dead \
              socket costs one response, never a connection worker or the listener",
    },
    Contract {
        prefix: "net/",
        rule: RuleId::D2,
        scope: Scope::File,
        why: "latency and socket deadlines go through util::stats::Timer; raw \
              wall-clock reads need a reasoned allow (the request-log timestamp)",
    },
    Contract {
        prefix: "serve/infer.rs",
        rule: RuleId::D2,
        scope: Scope::File,
        why: "the shared inference path feeds both eval and serve — wall-clock reads \
              would un-pin served logits",
    },
    Contract {
        prefix: "serve/infer.rs",
        rule: RuleId::D3,
        scope: Scope::File,
        why: "logits post-processing must not reorder float accumulation",
    },
    Contract {
        prefix: "coordinator/session.rs",
        rule: RuleId::D1,
        scope: Scope::File,
        why: "the session's batch_rng(seed, k) purity makes resume bit-exact",
    },
    Contract {
        prefix: "coordinator/session.rs",
        rule: RuleId::D2,
        scope: Scope::File,
        why: "the session's batch_rng(seed, k) purity makes resume bit-exact",
    },
    Contract {
        prefix: "coordinator/session.rs",
        rule: RuleId::R1,
        scope: Scope::Function("drive"),
        why: "the long-running training driver reports errors; it does not crash \
              mid-run with checkpoints unwritten",
    },
    Contract {
        prefix: "graph/io.rs",
        rule: RuleId::R2,
        scope: Scope::File,
        why: "adversarial headers must fail the length check, not wrap it",
    },
    Contract {
        prefix: "runtime/reference.rs",
        rule: RuleId::D3,
        scope: Scope::File,
        why: "the reference executor is the oracle — reductions go through kernels::",
    },
    Contract {
        prefix: "runtime/executor.rs",
        rule: RuleId::D3,
        scope: Scope::File,
        why: "executor-side reductions must use the oracle-pinned kernels:: helpers",
    },
    Contract {
        prefix: "runtime/inputs.rs",
        rule: RuleId::D3,
        scope: Scope::File,
        why: "input packing feeds the kernels — no ad-hoc float accumulation",
    },
    Contract {
        prefix: "runtime/tensor.rs",
        rule: RuleId::D3,
        scope: Scope::File,
        why: "tensor utilities sit under every kernel — no ad-hoc float accumulation",
    },
    Contract {
        prefix: "runtime/weights.rs",
        rule: RuleId::D3,
        scope: Scope::File,
        why: "weight updates are part of the bit-exact train step",
    },
];

/// Rule bindings for one `rust/src/`-relative file path.
pub fn contracts_for(rel_path: &str) -> Vec<(RuleId, Scope)> {
    CONTRACTS
        .iter()
        .filter(|c| rel_path.starts_with(c.prefix))
        .map(|c| (c.rule, c.scope))
        .collect()
}

/// One lint violation (or pragma problem, when `rule` is `None`).
#[derive(Debug, Clone)]
pub struct Finding {
    /// `rust/src/`-relative path of the offending file.
    pub path: String,
    /// 1-based line.
    pub line: usize,
    /// The violated rule; `None` for pragma problems (`P1`/`P2`, which
    /// carry their id in `reason`).
    pub rule: Option<RuleId>,
    pub reason: String,
}

impl Finding {
    /// The finding as an [`api::diag`](crate::api::diag) diagnostic:
    /// `path:line` anchor, rule-tagged reason, per-rule fix hint.
    pub fn to_diagnostic(&self) -> Diagnostic {
        let reason = match self.rule {
            Some(r) => format!("[{} {}] {}", r.id(), r.name(), self.reason),
            None => self.reason.clone(),
        };
        Diagnostic {
            path: format!("{}:{}", self.path, self.line),
            reason,
            hint: self.rule.map(|r| r.hint().to_string()),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("path", Json::str(&self.path)),
            ("line", Json::num(self.line as f64)),
            (
                "rule",
                match self.rule {
                    Some(r) => Json::str(r.id()),
                    None => Json::str(pragma_rule_id(&self.reason)),
                },
            ),
            (
                "name",
                match self.rule {
                    Some(r) => Json::str(r.name()),
                    None => Json::str("pragma"),
                },
            ),
            ("reason", Json::str(&self.reason)),
            (
                "hint",
                match self.rule {
                    Some(r) => Json::str(r.hint()),
                    None => Json::Null,
                },
            ),
        ])
    }
}

/// Pragma findings encode their id (`P1`/`P2`) as the reason prefix.
fn pragma_rule_id(reason: &str) -> &'static str {
    if reason.starts_with("P1") {
        "P1"
    } else {
        "P2"
    }
}

/// Result of one lint pass.
#[derive(Debug, Default)]
pub struct Report {
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
}

impl Report {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Findings as an [`api::diag::Diagnostics`](crate::api::diag) set —
    /// what `hp-gnn lint` prints (all problems in one pass, like
    /// `validate`).
    pub fn into_diagnostics(&self) -> Diagnostics {
        let mut d = Diagnostics::new();
        for f in &self.findings {
            let diag = f.to_diagnostic();
            match diag.hint {
                Some(h) => d.push_hint(diag.path, diag.reason, h),
                None => d.push(diag.path, diag.reason),
            }
        }
        d
    }

    /// The `--json` report (schema documented in README "Static
    /// analysis").
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tool", Json::str("hp-gnn-lint")),
            ("schema_version", Json::num(1.0)),
            ("files_scanned", Json::num(self.files_scanned as f64)),
            ("clean", Json::Bool(self.is_clean())),
            (
                "findings",
                Json::Arr(self.findings.iter().map(|f| f.to_json()).collect()),
            ),
        ])
    }
}

/// Lint a single source text as if it lived at `rel_path` under
/// `rust/src/` — the contract table decides which rules bind.  This is
/// the unit the fixture tests drive directly.
pub fn lint_source(rel_path: &str, text: &str) -> Vec<Finding> {
    let src = source::SourceFile::parse(rel_path, text);
    rules::check_file(&src, &contracts_for(rel_path))
}

/// Lint the whole `rust/src/` tree under `repo_root`.  Every file is
/// scanned (so stray pragmas are caught even in uncontracted modules);
/// rules apply per the contract table.
pub fn lint_tree(repo_root: &Path) -> anyhow::Result<Report> {
    let src_root = repo_root.join("rust").join("src");
    anyhow::ensure!(
        src_root.is_dir(),
        "lint: {} is not a directory (run from the repo root or pass --root)",
        src_root.display()
    );
    let mut files = Vec::new();
    collect_rs(&src_root, &mut files)?;
    files.sort();
    let mut report = Report::default();
    for path in files {
        let rel = path
            .strip_prefix(&src_root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let text = std::fs::read_to_string(&path)?;
        report.findings.extend(lint_source(&rel, &text));
        report.files_scanned += 1;
    }
    report.findings.sort_by(|a, b| (&a.path, a.line).cmp(&(&b.path, b.line)));
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contract_table_binds_the_documented_modules() {
        let kernels = contracts_for("runtime/kernels/dense.rs");
        assert!(kernels.iter().any(|(r, _)| *r == RuleId::D1));
        assert!(kernels.iter().any(|(r, _)| *r == RuleId::D2));
        let serve = contracts_for("serve/server.rs");
        assert!(serve.iter().any(|(r, _)| *r == RuleId::R1));
        assert!(serve.iter().any(|(r, _)| *r == RuleId::D1));
        let session = contracts_for("coordinator/session.rs");
        assert!(session
            .iter()
            .any(|(r, s)| *r == RuleId::R1 && *s == Scope::Function("drive")));
        assert!(contracts_for("graph/io.rs").iter().any(|(r, _)| *r == RuleId::R2));
        let net = contracts_for("net/http.rs");
        assert!(net.iter().any(|(r, _)| *r == RuleId::R1), "net/ owes no-panic");
        assert!(net.iter().any(|(r, _)| *r == RuleId::D2), "net/ owes Timer-only time");
        assert!(contracts_for("util/json.rs").is_empty(), "uncontracted module");
    }

    #[test]
    fn rule_ids_round_trip() {
        for r in RuleId::ALL {
            assert_eq!(RuleId::parse(r.id()), Some(r));
            assert!(!r.name().is_empty() && !r.hint().is_empty());
        }
        assert_eq!(RuleId::parse("Z9"), None);
    }

    #[test]
    fn findings_render_as_diag_shape_and_json() {
        let f = Finding {
            path: "serve/server.rs".into(),
            line: 41,
            rule: Some(RuleId::R1),
            reason: "`.unwrap()` can panic in the serving path".into(),
        };
        let d = f.to_diagnostic();
        assert_eq!(d.path, "serve/server.rs:41");
        assert!(d.reason.starts_with("[R1 no-panic-in-serving-path]"), "{}", d.reason);
        assert!(d.hint.is_some());
        let j = f.to_json();
        assert_eq!(j.get("rule").unwrap(), &Json::str("R1"));
        assert_eq!(j.get("line").unwrap(), &Json::num(41.0));

        let report = Report { files_scanned: 3, findings: vec![f] };
        let j = report.to_json();
        assert_eq!(j.get("clean").unwrap(), &Json::Bool(false));
        // Must serialize to parseable JSON.
        Json::parse(&j.pretty()).unwrap();
    }
}
