//! The contract rules (D1–D3, R1–R2) and the per-file check engine.
//!
//! Every rule is a line-level pattern over the scrubbed code produced by
//! [`super::source`] — deliberately heuristic (no type information), but
//! tuned so the *blessed* idioms in this codebase never trip it:
//! membership tests (`set.contains`, `map.get`, `entry()`) are fine under
//! D1, `util::stats::Timer` is the sanctioned wall-clock wrapper under
//! D2, `checked_mul`/`checked_add` chains satisfy R2, and combinator
//! forms (`unwrap_or_else`, `map_err`, `ok_or_else`) satisfy R1.
//!
//! A finding names the rule, the line, what is wrong, and how this repo
//! fixes it.  Suppression is explicit and audited: an inline
//! `// lint:allow(rule): reason` pragma on (or directly above) the line,
//! with a non-empty reason — and a pragma that suppresses nothing is
//! itself a finding (`P2`), so stale allows cannot accumulate.

use std::collections::BTreeSet;

use super::source::{find_word, SourceFile};
use super::{Finding, RuleId, Scope};

/// Run `bindings` over one preprocessed file, apply pragma suppression,
/// and report pragma problems (`P1`) and unused pragmas (`P2`).  The
/// single-file convenience path; [`super::analyze_files`] runs the
/// per-file rules and the pragma pass separately so whole-program
/// findings (R3/C1/A1) go through the same suppression machinery.
pub fn check_file(src: &SourceFile, bindings: &[(RuleId, Scope)]) -> Vec<Finding> {
    let mut findings = file_rule_findings(src, bindings);
    apply_pragmas(src, &mut findings);
    findings.sort_by_key(|f| f.line);
    findings
}

/// The per-file (line-level) rules only — no pragma handling.
pub fn file_rule_findings(src: &SourceFile, bindings: &[(RuleId, Scope)]) -> Vec<Finding> {
    let mut findings: Vec<Finding> = Vec::new();
    for (rule, scope) in bindings {
        let emit = |line: usize, reason: String| Finding {
            path: src.rel_path.clone(),
            line,
            rule: Some(*rule),
            reason,
            fingerprint: String::new(),
        };
        match rule {
            RuleId::D1 => d1_unordered_iteration(src, scope, &emit, &mut findings),
            RuleId::D2 => d2_wallclock(src, scope, &emit, &mut findings),
            RuleId::D3 => d3_float_reduction(src, scope, &emit, &mut findings),
            RuleId::R1 => r1_panic(src, scope, &emit, &mut findings),
            RuleId::R2 => r2_unchecked_arith(src, scope, &emit, &mut findings),
            // Whole-program rules don't run per file; a contract row only
            // marks which files they bind (see super::whole).
            RuleId::R3 | RuleId::C1 | RuleId::A1 => {}
        }
    }
    findings
}

/// Pragma suppression and pragma problems for one file.  `findings`
/// holds every finding attributed to this file — per-file *and*
/// whole-program rules — so a `lint:allow(R3)` works exactly like a
/// `lint:allow(D1)`.
pub fn apply_pragmas(src: &SourceFile, findings: &mut Vec<Finding>) {
    // Pragma suppression: a finding survives unless a well-formed pragma
    // for its rule targets its line.  Every applied pragma is marked used.
    let mut used = vec![false; src.pragmas.len()];
    findings.retain(|f| {
        let rule_id = f.rule.map(|r| r.id()).unwrap_or("");
        match src
            .pragmas
            .iter()
            .position(|p| p.target == f.line && p.rule == rule_id)
        {
            Some(i) => {
                used[i] = true;
                false
            }
            None => true,
        }
    });

    for (line, what) in &src.pragma_problems {
        findings.push(Finding {
            path: src.rel_path.clone(),
            line: *line,
            rule: None,
            reason: format!("P1 bad-pragma: {what}"),
            fingerprint: String::new(),
        });
    }
    for (i, p) in src.pragmas.iter().enumerate() {
        let known = RuleId::parse(&p.rule).is_some();
        if !known {
            findings.push(Finding {
                path: src.rel_path.clone(),
                line: p.line,
                rule: None,
                reason: format!(
                    "P1 bad-pragma: unknown rule {:?} (rules: D1 D2 D3 R1 R2 R3 C1 A1)",
                    p.rule
                ),
                fingerprint: String::new(),
            });
        } else if !used[i] {
            findings.push(Finding {
                path: src.rel_path.clone(),
                line: p.line,
                rule: None,
                reason: format!(
                    "P2 unused-pragma: lint:allow({}) suppresses nothing on line {} — \
                     delete it (stale allows must not accumulate)",
                    p.rule, p.target
                ),
                fingerprint: String::new(),
            });
        }
    }
}

/// Lines the rule actually applies to: non-test and inside the scope.
fn in_scope(src: &SourceFile, idx: usize, scope: &Scope) -> bool {
    let line = &src.lines[idx];
    if line.is_test {
        return false;
    }
    match scope {
        Scope::File => true,
        Scope::Function(name) => line.func.as_deref() == Some(*name),
    }
}

/// D1: names bound to `HashMap`/`HashSet` in this file — `let` bindings
/// and struct-field declarations (the two forms this codebase uses).
fn hash_bound_names(src: &SourceFile) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for line in &src.lines {
        let code = line.code.trim_start();
        if !(code.contains("HashMap") || code.contains("HashSet")) {
            continue;
        }
        if code.starts_with("use ") {
            continue;
        }
        // `let [mut] name ... = ... HashMap/HashSet ...`
        if let Some(rest) = code.strip_prefix("let ") {
            let rest = rest.strip_prefix("mut ").unwrap_or(rest);
            let name: String = rest
                .chars()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect();
            if !name.is_empty() {
                names.insert(name);
            }
            continue;
        }
        // Field declaration: `[pub] name: … HashMap<…>,`
        if let Some((lhs, rhs)) = code.split_once(':') {
            if !(rhs.contains("HashMap") || rhs.contains("HashSet")) {
                continue;
            }
            let lhs = lhs.trim();
            let lhs = lhs.strip_prefix("pub(crate)").unwrap_or(lhs);
            let lhs = lhs.strip_prefix("pub").unwrap_or(lhs).trim();
            if !lhs.is_empty() && lhs.chars().all(|c| c.is_alphanumeric() || c == '_') {
                names.insert(lhs.to_string());
            }
        }
    }
    names
}

/// Method suffixes that iterate a hash container in arbitrary order.
const ITER_SUFFIXES: &[&str] = &[
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".into_iter()",
    ".into_keys()",
    ".into_values()",
    ".drain(",
    ".retain(",
];

fn d1_unordered_iteration(
    src: &SourceFile,
    scope: &Scope,
    emit: &dyn Fn(usize, String) -> Finding,
    out: &mut Vec<Finding>,
) {
    let names = hash_bound_names(src);
    if names.is_empty() {
        return;
    }
    for (idx, line) in src.lines.iter().enumerate() {
        if !in_scope(src, idx, scope) {
            continue;
        }
        let code = &line.code;
        for name in &names {
            // `name.iter()` / `self.name.keys()` / `name.drain(..)` …
            let mut from = 0;
            while let Some(at) = find_word(code, name, from) {
                let after = &code[at + name.len()..];
                if let Some(suffix) = ITER_SUFFIXES.iter().find(|s| after.starts_with(**s)) {
                    out.push(emit(
                        idx + 1,
                        format!(
                            "iteration over HashMap/HashSet `{name}` via `{}` — hash order \
                             is nondeterministic across runs",
                            suffix.trim_end_matches('(')
                        ),
                    ));
                    break;
                }
                from = at + name.len().max(1);
            }
            // `for x in [&[mut]] name {` — direct IntoIterator use.
            if let Some(in_at) = code.find(" in ") {
                if code.trim_start().starts_with("for ") || code.contains(" for ") {
                    let tail = code[in_at + 4..].trim_start();
                    let tail = tail.strip_prefix('&').unwrap_or(tail);
                    let tail = tail.strip_prefix("mut ").unwrap_or(tail).trim_start();
                    if let Some(rest) = tail.strip_prefix(name.as_str()) {
                        let next = rest.chars().next();
                        if matches!(next, None | Some(' ') | Some('{')) {
                            out.push(emit(
                                idx + 1,
                                format!(
                                    "`for … in {name}` iterates a HashMap/HashSet — hash \
                                     order is nondeterministic across runs"
                                ),
                            ));
                        }
                    }
                }
            }
        }
    }
}

fn d2_wallclock(
    src: &SourceFile,
    scope: &Scope,
    emit: &dyn Fn(usize, String) -> Finding,
    out: &mut Vec<Finding>,
) {
    for (idx, line) in src.lines.iter().enumerate() {
        if !in_scope(src, idx, scope) {
            continue;
        }
        let code = &line.code;
        if code.trim_start().starts_with("use ") {
            continue; // the import is not the read; the call site is
        }
        for pat in ["Instant::now", "SystemTime"] {
            if find_word(code, pat, 0).is_some() {
                out.push(emit(
                    idx + 1,
                    format!(
                        "wall-clock read `{pat}` in a deterministic step path — outputs \
                         must be a pure function of (seed, step)"
                    ),
                ));
            }
        }
    }
}

fn d3_float_reduction(
    src: &SourceFile,
    scope: &Scope,
    emit: &dyn Fn(usize, String) -> Finding,
    out: &mut Vec<Finding>,
) {
    for (idx, line) in src.lines.iter().enumerate() {
        if !in_scope(src, idx, scope) {
            continue;
        }
        let code = &line.code;
        for pat in [
            ".sum::<f32>()",
            ".sum::<f64>()",
            ".product::<f32>()",
            ".product::<f64>()",
        ] {
            if code.contains(pat) {
                out.push(emit(
                    idx + 1,
                    format!(
                        "ad-hoc float reduction `{}` — accumulation order is not pinned \
                         by the kernels:: oracle",
                        pat.trim_end_matches("()")
                    ),
                ));
            }
        }
        // `.fold(` seeded with a float literal or f32::/f64:: constant.
        let mut from = 0;
        while let Some(at) = code[from..].find(".fold(") {
            let abs = from + at;
            let arg = code[abs + ".fold(".len()..].trim_start();
            let arg = arg.strip_prefix('-').unwrap_or(arg);
            let float_seed = arg.starts_with("f32::")
                || arg.starts_with("f64::")
                || is_float_literal(arg);
            if float_seed {
                out.push(emit(
                    idx + 1,
                    "float `.fold(…)` reduction — accumulation order is not pinned by \
                     the kernels:: oracle"
                        .to_string(),
                ));
            }
            from = abs + ".fold(".len();
        }
    }
}

/// Does `s` start with a float literal (`0.0`, `1e-3`, `0f32`)?
fn is_float_literal(s: &str) -> bool {
    let mut chars = s.chars().peekable();
    let mut digits = 0;
    while chars.peek().map(|c| c.is_ascii_digit()).unwrap_or(false) {
        chars.next();
        digits += 1;
    }
    if digits == 0 {
        return false;
    }
    matches!(chars.peek(), Some('.') | Some('e') | Some('E') | Some('f'))
}

/// Macros and method calls that panic instead of returning an error.
/// Shared with R3's reachability scan ([`super::whole`]).
pub(crate) const PANIC_PATTERNS: &[(&str, &str)] = &[
    (".unwrap()", "propagate with `?`, `ok_or_else`, or recover (locks: `lock_unpoisoned`)"),
    (".expect(", "propagate with `?` and `context(…)` instead of crashing the worker"),
    ("panic!(", "return an error — one bad request must not take down the pool"),
    ("unreachable!(", "return an internal error instead"),
    ("todo!(", "serving paths must be implemented, not stubbed"),
    ("unimplemented!(", "serving paths must be implemented, not stubbed"),
];

fn r1_panic(
    src: &SourceFile,
    scope: &Scope,
    emit: &dyn Fn(usize, String) -> Finding,
    out: &mut Vec<Finding>,
) {
    for (idx, line) in src.lines.iter().enumerate() {
        if !in_scope(src, idx, scope) {
            continue;
        }
        let code = &line.code;
        for (pat, fix) in PANIC_PATTERNS {
            if code.contains(pat) {
                out.push(emit(
                    idx + 1,
                    format!("`{}` can panic in the serving path — {fix}", pat.trim_end_matches('(')),
                ));
            }
        }
    }
}

fn r2_unchecked_arith(
    src: &SourceFile,
    scope: &Scope,
    emit: &dyn Fn(usize, String) -> Finding,
    out: &mut Vec<Finding>,
) {
    for (idx, line) in src.lines.iter().enumerate() {
        if !in_scope(src, idx, scope) {
            continue;
        }
        // Only loader/parser functions handle header-derived sizes.
        let in_loader = line
            .func
            .as_deref()
            .map(|f| f.starts_with("load") || f.starts_with("read_"))
            .unwrap_or(false);
        if !in_loader {
            continue;
        }
        let code = &line.code;
        if code.contains("checked_mul") || code.contains("checked_add") {
            continue; // already the blessed form
        }
        let alloc = ["with_capacity", "vec![", ".reserve("]
            .iter()
            .any(|p| code.contains(p));
        let bytes = code.as_bytes();
        for (i, &b) in bytes.iter().enumerate() {
            let binary = i > 0
                && prev_value_token(bytes, i)
                && bytes.get(i + 1).map(|&n| n != b'=').unwrap_or(true);
            if b == b'*' && binary {
                out.push(emit(
                    idx + 1,
                    "unchecked `*` on a loader-computed size — a wrapping product \
                     defeats the length check; use `checked_mul`"
                        .to_string(),
                ));
                break;
            }
            if b == b'+' && binary && alloc {
                out.push(emit(
                    idx + 1,
                    "unchecked `+` sizing an allocation in a loader — use \
                     `checked_add` before allocating"
                        .to_string(),
                ));
                break;
            }
        }
    }
}

/// Is the nearest non-space byte before `i` something a binary operator's
/// left operand ends with (identifier, closing bracket, literal)?  A
/// `*`/`+` after `(`/`,`/`=`/operator is unary (deref, sign, generics).
fn prev_value_token(bytes: &[u8], i: usize) -> bool {
    let mut j = i;
    while j > 0 {
        j -= 1;
        let p = bytes[j];
        if p == b' ' {
            continue;
        }
        return p.is_ascii_alphanumeric() || p == b'_' || p == b')' || p == b']';
    }
    false
}

#[cfg(test)]
mod tests {
    use super::super::source::SourceFile;
    use super::super::{RuleId, Scope};
    use super::check_file;

    fn run(rel: &str, src: &str, rule: RuleId, scope: Scope) -> Vec<(usize, String)> {
        let f = SourceFile::parse(rel, src);
        check_file(&f, &[(rule, scope)])
            .into_iter()
            .map(|f| (f.line, f.reason))
            .collect()
    }

    #[test]
    fn d1_flags_iteration_but_not_membership() {
        let src = "fn f() {\n    let mut seen = HashSet::new();\n    seen.insert(1);\n    if seen.contains(&1) {}\n    for x in &seen { use_(x); }\n    let n = seen.iter().count();\n}\n";
        let hits = run("sampler/x.rs", src, RuleId::D1, Scope::File);
        let lines: Vec<usize> = hits.iter().map(|h| h.0).collect();
        assert_eq!(lines, vec![5, 6], "{hits:?}");
    }

    #[test]
    fn d1_tracks_struct_fields_and_keys() {
        let src = "struct C {\n    map: Mutex<HashMap<u32, E>>,\n}\nimpl C {\n    fn evict(&self) {\n        if let Some(k) = self.map.keys().next() {}\n        self.map.get(&3);\n    }\n}\n";
        let hits = run("serve/cache.rs", src, RuleId::D1, Scope::File);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert_eq!(hits[0].0, 6);
    }

    #[test]
    fn d2_flags_wallclock_outside_use_lines() {
        let src = "use std::time::Instant;\nfn f() {\n    let t = Instant::now();\n    let s = SystemTime::now();\n}\n";
        let hits = run("sampler/x.rs", src, RuleId::D2, Scope::File);
        let lines: Vec<usize> = hits.iter().map(|h| h.0).collect();
        assert_eq!(lines, vec![3, 4], "{hits:?}");
    }

    #[test]
    fn d3_flags_turbofish_sums_and_float_folds_only() {
        let src = "fn f(v: &[f32]) -> f32 {\n    let a: f32 = v.iter().sum::<f32>();\n    let b = v.iter().fold(0.0f32, |x, y| x + y);\n    let n = v.iter().map(|_| 1usize).fold(0, |a, b| a + b);\n    a + b + n as f32\n}\n";
        let hits = run("runtime/reference.rs", src, RuleId::D3, Scope::File);
        let lines: Vec<usize> = hits.iter().map(|h| h.0).collect();
        assert_eq!(lines, vec![2, 3], "integer fold must not be flagged: {hits:?}");
    }

    #[test]
    fn r1_flags_panics_but_not_combinators() {
        let src = "fn f() -> anyhow::Result<u32> {\n    let a = x().unwrap();\n    let b = y().expect(\"y\");\n    let c = z().unwrap_or_else(|p| p.into_inner());\n    let d = w().ok_or_else(|| anyhow::anyhow!(\"w\"))?;\n    Ok(a + b + c + d)\n}\n";
        let hits = run("serve/server.rs", src, RuleId::R1, Scope::File);
        let lines: Vec<usize> = hits.iter().map(|h| h.0).collect();
        assert_eq!(lines, vec![2, 3], "{hits:?}");
    }

    #[test]
    fn r1_function_scope_limits_to_that_fn() {
        let src = "fn other() {\n    x().unwrap();\n}\nfn drive(&mut self) {\n    y().unwrap();\n}\n";
        let hits = run("coordinator/session.rs", src, RuleId::R1, Scope::Function("drive"));
        let lines: Vec<usize> = hits.iter().map(|h| h.0).collect();
        assert_eq!(lines, vec![5], "{hits:?}");
    }

    #[test]
    fn r2_flags_bare_multiply_not_deref_or_checked() {
        let src = "fn load_binary(n: usize, e: usize) {\n    let need = n * 8;\n    let ok = e.checked_mul(4);\n    let p = *ptr;\n    let buf = Vec::with_capacity(n + 1);\n    let idx = off + 8;\n}\nfn not_a_loader(n: usize) {\n    let x = n * 8;\n}\n";
        let hits = run("graph/io.rs", src, RuleId::R2, Scope::File);
        let lines: Vec<usize> = hits.iter().map(|h| h.0).collect();
        assert_eq!(lines, vec![2, 5], "{hits:?}");
    }

    #[test]
    fn pragmas_suppress_and_unused_ones_fail() {
        let src = "fn f() {\n    let t = Instant::now(); // lint:allow(D2): measurement only, never reaches outputs\n}\n";
        let hits = run("sampler/x.rs", src, RuleId::D2, Scope::File);
        assert!(hits.is_empty(), "{hits:?}");

        let src = "fn f() {\n    let t = 1; // lint:allow(D2): nothing here trips D2\n}\n";
        let hits = run("sampler/x.rs", src, RuleId::D2, Scope::File);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert!(hits[0].1.contains("P2 unused-pragma"), "{hits:?}");
    }

    #[test]
    fn test_mod_bodies_are_exempt() {
        let src = "fn f() {}\n#[cfg(test)]\nmod tests {\n    fn t() {\n        x().unwrap();\n        let t = Instant::now();\n    }\n}\n";
        assert!(run("serve/server.rs", src, RuleId::R1, Scope::File).is_empty());
        assert!(run("serve/infer.rs", src, RuleId::D2, Scope::File).is_empty());
    }

    #[test]
    fn unknown_pragma_rule_is_a_problem() {
        let src = "fn f() {\n    let x = 1; // lint:allow(Z9): nope\n}\n";
        let hits = run("sampler/x.rs", src, RuleId::D2, Scope::File);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert!(hits[0].1.contains("unknown rule"), "{hits:?}");
    }
}
