//! Whole-program rules: R3 panic-reachability, C1 lock-order, A1
//! hot-path allocation.
//!
//! These consume the crate-wide call graph ([`super::callgraph`]) and the
//! per-file item attributions ([`super::items`]) rather than single
//! lines, so a panic three calls away from a request handler is flagged
//! at its definition site with the full call chain printed.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use super::callgraph::CrateGraph;
use super::items::FileItems;
use super::rules::PANIC_PATTERNS;
use super::source::SourceFile;
use super::{contracts_for, Finding, RuleId};

/// The serving/training entrypoints R3 walks from.  Exact qualified
/// paths; every non-test fn of a root *module* is a root too (HTTP
/// handlers in `net::routes` are dispatched reflectively through the
/// router table, so no static call edge reaches them).
pub const R3_ROOT_QPATHS: &[&str] = &[
    // Request entrypoints (CONTRACTS: one bad request must not take
    // down the pool).
    "serve::server::Server::classify",
    "serve::server::Server::try_classify",
    // Detached thread bodies — a panic here kills a worker silently.
    "serve::server::run_worker",
    "serve::batcher::run_batcher",
    "net::server::serve_pool",
    "net::server::accept_loop",
    // The long-running training loop: hours of progress lost per panic.
    "coordinator::session::TrainingSession::step",
];

/// Modules whose every non-test fn is an R3 root.
pub const R3_ROOT_MODULES: &[&str] = &["net::routes"];

/// Human-readable fn label for call chains: `Server::classify`, `decode`.
fn short(g: &CrateGraph, f: usize) -> String {
    match &g.fns[f].impl_type {
        Some(t) => format!("{t}::{}", g.fns[f].name),
        None => g.fns[f].name.clone(),
    }
}

/// R3 — no panic reachable from a serving/training entrypoint.
///
/// BFS over resolved call edges from every root; scan each reachable
/// non-test fn body for the panic patterns, printing the (shortest)
/// root → … → fn chain.  `.expect(` sites that resolved to an in-crate
/// method (the JSON parser's `Parser::expect`) are exempt.
pub fn r3_panic_reachability(files: &[(SourceFile, FileItems)], g: &CrateGraph) -> Vec<Finding> {
    let mut roots: Vec<usize> = Vec::new();
    for (gi, f) in g.fns.iter().enumerate() {
        if f.is_test {
            continue;
        }
        if R3_ROOT_QPATHS.contains(&f.qpath.as_str())
            || R3_ROOT_MODULES.contains(&f.module.as_str())
        {
            roots.push(gi);
        }
    }

    // BFS, remembering the parent that discovered each fn (shortest
    // chain back to some root).
    let mut parent: BTreeMap<usize, Option<usize>> = BTreeMap::new();
    let mut queue: VecDeque<usize> = VecDeque::new();
    for &r in &roots {
        parent.entry(r).or_insert(None);
        queue.push_back(r);
    }
    while let Some(f) = queue.pop_front() {
        if let Some(callees) = g.edges.get(&f) {
            for &(to, _) in callees {
                if !parent.contains_key(&to) && !g.fns[to].is_test {
                    parent.insert(to, Some(f));
                    queue.push_back(to);
                }
            }
        }
    }

    let file_of: BTreeMap<&str, usize> = files
        .iter()
        .enumerate()
        .map(|(i, (src, _))| (src.rel_path.as_str(), i))
        .collect();

    let mut out = Vec::new();
    for (&f, _) in &parent {
        let item = &g.fns[f];
        let Some(&fi) = file_of.get(item.file.as_str()) else { continue };
        let src = &files[fi].0;
        let chain = {
            let mut names = vec![short(g, f)];
            let mut cur = f;
            while let Some(Some(p)) = parent.get(&cur) {
                names.push(short(g, *p));
                cur = *p;
            }
            names.reverse();
            names.join(" → ")
        };
        for li in (item.start - 1)..item.end.min(src.lines.len()) {
            let line = &src.lines[li];
            if line.is_test {
                continue;
            }
            for (pat, fix) in PANIC_PATTERNS {
                if !line.code.contains(pat) {
                    continue;
                }
                if *pat == ".expect("
                    && g.in_crate_methods.contains(&(
                        item.file.clone(),
                        li + 1,
                        "expect".to_string(),
                    ))
                {
                    continue; // resolved to an in-crate method, not Option/Result::expect
                }
                out.push(Finding {
                    path: item.file.clone(),
                    line: li + 1,
                    rule: Some(RuleId::R3),
                    fingerprint: String::new(),
                    reason: format!(
                        "`{}` can panic and is reachable from a serving/training entrypoint \
                         via {chain} — {fix}",
                        pat.trim_end_matches('('),
                    ),
                });
            }
        }
    }
    out
}

/// Lock-acquisition patterns and how to pull the lock's identity out of
/// the surrounding text.
const GUARD_FNS: &[&str] = &["lock_unpoisoned(", "read_unpoisoned(", "write_unpoisoned("];
const GUARD_METHODS: &[&str] = &[".lock()", ".read()", ".write()"];
/// Calls that block while a guard is live (condvar waits are excluded:
/// they release the mutex while parked).
const BLOCKING: &[&str] = &[".send(", ".recv()", ".recv_timeout(", ".join()"];

/// One lock acquisition found on a line: `(key, column)`.
fn acquisitions(code: &str) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for pat in GUARD_METHODS {
        let mut from = 0;
        while let Some(p) = code[from..].find(pat) {
            let at = from + p;
            if let Some(key) = chain_tail_before(code, at) {
                out.push((key, at));
            }
            from = at + pat.len();
        }
    }
    for pat in GUARD_FNS {
        let mut from = 0;
        while let Some(p) = code[from..].find(pat) {
            let at = from + p;
            // Skip the helper's own `fn lock_unpoisoned(…)` definition.
            let lead = code[..at].trim_end();
            if !lead.ends_with("fn") {
                if let Some(key) = arg_tail_inside(code, at + pat.len()) {
                    out.push((key, at));
                }
            }
            from = at + pat.len();
        }
    }
    out.sort_by_key(|&(_, c)| c);
    out
}

/// Last identifier of the receiver chain ending at byte `at`
/// (`self.window.consumed.lock()` → `consumed`).
fn chain_tail_before(code: &str, at: usize) -> Option<String> {
    let b = code.as_bytes();
    let mut end = at;
    let mut start = end;
    while start > 0 {
        let c = b[start - 1];
        if c.is_ascii_alphanumeric() || c == b'_' {
            start -= 1;
        } else {
            break;
        }
    }
    if start == end {
        return None;
    }
    Some(code[start..at].to_string())
}

/// Last identifier of the first argument after byte `at`
/// (`lock_unpoisoned(&self.window.consumed)` → `consumed`).
fn arg_tail_inside(code: &str, at: usize) -> Option<String> {
    let rest = &code[at..];
    let stop = rest.find([')', ','])?;
    let arg = rest[..stop].trim().trim_start_matches('&').trim_start_matches("mut ");
    let tail = arg
        .rsplit(|c: char| !(c.is_ascii_alphanumeric() || c == '_'))
        .find(|s| !s.is_empty())?;
    // The tail may be an index (`jobs[i]` → `i`); prefer the first
    // ident of the last dot segment in that case.
    let last_seg = arg.rsplit('.').next().unwrap_or(arg);
    let first_ident: String = last_seg
        .chars()
        .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
        .collect();
    if first_ident.is_empty() {
        Some(tail.to_string())
    } else {
        Some(first_ident)
    }
}

/// C1 — consistent lock order, and no blocking call under a guard.
///
/// Tracks `let`-bound guards per function (a guard dies when its block
/// closes or it is `drop`ped), records an order edge `held → acquired`
/// for every acquisition under a held guard, flags blocking calls made
/// while holding, and reports every strongly-connected component of the
/// global order graph as a cycle.
pub fn c1_lock_order(files: &[(SourceFile, FileItems)]) -> Vec<Finding> {
    let mut out = Vec::new();
    // (from, to) → first site.
    let mut order: BTreeMap<(String, String), (String, usize)> = BTreeMap::new();

    for (src, items) in files {
        // Brace depth at the start of each line, from the token stream.
        let mut depth_start = vec![0i64; src.lines.len()];
        {
            let mut d = 0i64;
            let mut li = 0usize;
            for t in &items.toks {
                while li < t.line - 1 {
                    li += 1;
                    if li < depth_start.len() {
                        depth_start[li] = d;
                    }
                }
                match t.text.as_str() {
                    "{" => d += 1,
                    "}" => d -= 1,
                    _ => {}
                }
            }
            for slot in depth_start.iter_mut().skip(li + 1) {
                *slot = d;
            }
        }

        // guards: (name, key, bind depth), innermost last, per fn.
        let mut guards: Vec<(String, String, i64)> = Vec::new();
        let mut cur_fn: Option<usize> = None;
        for (li, line) in src.lines.iter().enumerate() {
            // Comment-only and blank lines carry no tokens, so their
            // `fn_of_line` is None — that is not a function change, and
            // clearing on it would let any interleaved comment hide a
            // held guard from the blocking check.
            let this_fn = items.fn_of_line[li];
            if this_fn.is_some() && this_fn != cur_fn {
                guards.clear(); // entered a different fn
                cur_fn = this_fn;
            }
            let in_fn = match this_fn {
                Some(f) if !items.fns[f].is_test => true,
                _ => false,
            };
            if !in_fn || line.is_test {
                continue;
            }
            let d = depth_start[li];
            guards.retain(|&(_, _, bind)| bind <= d);

            let code = line.code.as_str();
            // Explicit early release.
            if let Some(p) = super::source::find_word(code, "drop", 0) {
                if code[p + 4..].trim_start().starts_with('(') {
                    guards.retain(|(name, _, _)| !super::source::has_word(code, name));
                }
            }

            // Blocking call while holding any guard?
            for pat in BLOCKING {
                if code.contains(pat) {
                    if let Some((name, key, _)) = guards.last() {
                        out.push(Finding {
                            path: src.rel_path.clone(),
                            line: li + 1,
                            rule: Some(RuleId::C1),
                            fingerprint: String::new(),
                            reason: format!(
                                "`{}` blocks while guard `{name}` holds lock `{key}` — \
                                 release the lock before blocking (scope the guard or \
                                 `drop` it)",
                                pat.trim_end_matches('('),
                            ),
                        });
                    }
                }
            }

            let acqs = acquisitions(code);
            for (key, _) in &acqs {
                for (_, held, _) in &guards {
                    if held != key {
                        order
                            .entry((held.clone(), key.clone()))
                            .or_insert((src.rel_path.clone(), li + 1));
                    }
                }
            }
            // A `let` binding persists the first acquisition as a guard.
            if let Some((key, _)) = acqs.first() {
                let trimmed = code.trim_start();
                let is_let = trimmed.starts_with("let ")
                    || trimmed.starts_with("while let ")
                    || trimmed.starts_with("if let ");
                if is_let {
                    if let Some(name) = let_binding_name(trimmed) {
                        guards.push((name, key.clone(), d));
                    }
                }
            }
        }
    }

    // Cycles: strongly-connected components of the order graph.
    let mut nodes: BTreeSet<&String> = BTreeSet::new();
    for (from, to) in order.keys() {
        nodes.insert(from);
        nodes.insert(to);
    }
    let reach = |a: &String, b: &String| -> bool {
        let mut seen = BTreeSet::new();
        let mut stack = vec![a];
        while let Some(n) = stack.pop() {
            if !seen.insert(n.clone()) {
                continue;
            }
            for ((f, t), _) in &order {
                if f == n {
                    if t == b {
                        return true;
                    }
                    stack.push(t);
                }
            }
        }
        false
    };
    // Merge mutually-reachable pairs into components.
    let mut components: Vec<BTreeSet<String>> = Vec::new();
    for a in &nodes {
        for b in &nodes {
            if a < b && reach(a, b) && reach(b, a) {
                let pair: BTreeSet<String> = [(*a).clone(), (*b).clone()].into_iter().collect();
                if let Some(c) = components.iter_mut().find(|c| !c.is_disjoint(&pair)) {
                    c.extend(pair);
                } else {
                    components.push(pair);
                }
            }
        }
    }
    for members in components {
        let edges: Vec<(&(String, String), &(String, usize))> = order
            .iter()
            .filter(|((f, t), _)| members.contains(f) && members.contains(t))
            .collect();
        let Some((_, site)) = edges.first() else { continue };
        let listing = edges
            .iter()
            .map(|((f, t), (p, l))| format!("{f} → {t} ({p}:{l})"))
            .collect::<Vec<_>>()
            .join(", ");
        out.push(Finding {
            path: site.0.clone(),
            line: site.1,
            rule: Some(RuleId::C1),
            fingerprint: String::new(),
            reason: format!(
                "lock-order cycle among {{{}}}: {listing} — pick one global order and \
                 acquire in it everywhere",
                members.iter().cloned().collect::<Vec<_>>().join(", "),
            ),
        });
    }
    out.sort_by(|a, b| (&a.path, a.line).cmp(&(&b.path, b.line)));
    out
}

fn let_binding_name(trimmed: &str) -> Option<String> {
    let after = trimmed
        .trim_start_matches("while ")
        .trim_start_matches("if ")
        .trim_start_matches("let ");
    // Pattern bindings (`let (a, b) = …`, `let Some(g) = …`) take the
    // first lowercase-starting ident of the pattern (left of the `=`).
    let pat_part = after.split('=').next().unwrap_or(after);
    pat_part
        .split(|c: char| !(c.is_ascii_alphanumeric() || c == '_'))
        .find(|w| {
            !w.is_empty()
                && *w != "_"
                && *w != "mut"
                && *w != "ref"
                && w.chars().next().map(|c| c.is_ascii_lowercase() || c == '_').unwrap_or(false)
        })
        .map(str::to_string)
}

/// Allocation patterns A1 bans inside loop bodies of hot-path files.
/// `with_capacity` in a prologue is the blessed alternative, so it is
/// deliberately absent.
const ALLOC_PATTERNS: &[&str] = &[
    "Vec::new(",
    "vec![",
    ".to_vec()",
    "String::new(",
    ".to_string()",
    "format!(",
    "Box::new(",
    ".push(",
];

/// A1 — no allocation inside loop bodies of files contracted to it
/// (`runtime/kernels/`, `serve/infer.rs`): allocate in the prologue
/// (`with_capacity`) and reuse across iterations.
pub fn a1_hot_path_alloc(files: &[(SourceFile, FileItems)]) -> Vec<Finding> {
    let mut out = Vec::new();
    for (src, items) in files {
        let bound = contracts_for(&src.rel_path).iter().any(|(r, _)| *r == RuleId::A1);
        if !bound {
            continue;
        }
        for (li, line) in src.lines.iter().enumerate() {
            if line.is_test || items.loop_depth[li] == 0 {
                continue;
            }
            let in_prod_fn = items.fn_of_line[li]
                .map(|f| !items.fns[f].is_test)
                .unwrap_or(false);
            if !in_prod_fn {
                continue;
            }
            for pat in ALLOC_PATTERNS {
                if line.code.contains(pat) {
                    out.push(Finding {
                        path: src.rel_path.clone(),
                        line: li + 1,
                        rule: Some(RuleId::A1),
                        fingerprint: String::new(),
                        reason: format!(
                            "`{}` allocates inside a loop body on the hot path — hoist \
                             to a `with_capacity` prologue or reuse a scratch buffer",
                            pat.trim_end_matches('('),
                        ),
                    });
                }
            }
        }
    }
    out.sort_by(|a, b| (&a.path, a.line).cmp(&(&b.path, b.line)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::{callgraph, items};

    fn parsed(files: &[(&str, &str)]) -> Vec<(SourceFile, FileItems)> {
        files
            .iter()
            .map(|(rel, text)| {
                let src = SourceFile::parse(rel, text);
                let it = items::parse(&src);
                (src, it)
            })
            .collect()
    }

    #[test]
    fn r3_prints_the_call_chain() {
        let files = parsed(&[(
            "serve/server.rs",
            "impl Server {\n    pub fn classify(&self, v: u32) -> u32 {\n        self.lookup(v)\n    }\n    fn lookup(&self, v: u32) -> u32 {\n        decode(v)\n    }\n}\n\nfn decode(v: u32) -> u32 {\n    table(v).unwrap()\n}\n\nfn table(v: u32) -> Option<u32> {\n    Some(v)\n}\n",
        )]);
        let g = callgraph::build(&files);
        let f = r3_panic_reachability(&files, &g);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, Some(RuleId::R3));
        assert_eq!(f[0].line, 11);
        assert!(
            f[0].reason.contains("Server::classify → Server::lookup → decode"),
            "{}",
            f[0].reason
        );
    }

    #[test]
    fn r3_ignores_unreachable_panics_and_in_crate_expect() {
        let files = parsed(&[
            ("net/routes.rs", "fn healthz(p: &mut Parser) -> u32 {\n    p.object()\n}\n"),
            (
                "util/json.rs",
                "impl Parser {\n    fn object(&mut self) -> u32 {\n        self.expect(1)\n    }\n    fn expect(&mut self, b: u8) -> u32 {\n        b as u32\n    }\n}\n\nfn orphan() -> u32 {\n    None.unwrap()\n}\n",
            ),
        ]);
        let g = callgraph::build(&files);
        let f = r3_panic_reachability(&files, &g);
        // `orphan` is not called from any root; the `.expect(` inside
        // `object` resolved to the in-crate `Parser::expect` — both silent.
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn c1_finds_a_two_lock_cycle_once() {
        let files = parsed(&[(
            "coordinator/locks.rs",
            "fn drain(s: &S) {\n    let q = s.queue.lock();\n    let st = s.stats.lock();\n    use2(q, st);\n}\n\nfn report(s: &S) {\n    let st = s.stats.lock();\n    let q = s.queue.lock();\n    use2(q, st);\n}\n\nfn use2(a: G, b: G) {}\n",
        )]);
        let f = c1_lock_order(&files);
        let cycles: Vec<&Finding> =
            f.iter().filter(|f| f.reason.contains("lock-order cycle")).collect();
        assert_eq!(cycles.len(), 1, "{f:?}");
        assert!(cycles[0].reason.contains("queue → stats"), "{}", cycles[0].reason);
        assert!(cycles[0].reason.contains("stats → queue"), "{}", cycles[0].reason);
    }

    #[test]
    fn c1_flags_blocking_under_guard_but_not_after_scope_close() {
        let files = parsed(&[(
            "serve/x.rs",
            "fn ok(s: &S) {\n    let tx = {\n        let g = lock_unpoisoned(&s.job_tx);\n        g.clone()\n    };\n    tx.send(1);\n}\n\nfn bad(s: &S) {\n    let g = lock_unpoisoned(&s.work_rx);\n    g.recv();\n}\n",
        )]);
        let f = c1_lock_order(&files);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 11);
        assert!(f[0].reason.contains("work_rx"), "{}", f[0].reason);
    }

    #[test]
    fn a1_flags_loop_allocs_only_in_contracted_files() {
        let kernel = "pub fn gather(n: usize) -> Vec<u32> {\n    let mut out = Vec::with_capacity(n);\n    for i in 0..n {\n        let row = base(i).to_vec();\n        out.push(row[0]);\n    }\n    out\n}\n";
        let files = parsed(&[
            ("runtime/kernels/gather.rs", kernel),
            ("coordinator/free.rs", kernel),
        ]);
        let f = a1_hot_path_alloc(&files);
        assert!(f.iter().all(|x| x.path == "runtime/kernels/gather.rs"), "{f:?}");
        let lines: Vec<usize> = f.iter().map(|x| x.line).collect();
        assert_eq!(lines, vec![4, 5], "{f:?}");
        assert!(f[0].reason.contains(".to_vec"), "{}", f[0].reason);
    }
}
