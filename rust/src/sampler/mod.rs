//! Mini-batch samplers (paper §2.3) and the mini-batch IR.
//!
//! A sampling algorithm produces, per training iteration, the vertex sets
//! `B^l` (0 <= l <= L) and sampled adjacency `A_s^l` (1 <= l <= L).  The
//! host CPU runs these (flexibility is why the paper keeps sampling on the
//! CPU); the layout engine then applies RMT/RRA and padding before the
//! batch is handed to the accelerator.
//!
//! Implemented samplers:
//! * [`neighbor::NeighborSampler`] — GraphSAGE recursive neighbor sampling.
//! * [`subgraph::SubgraphSampler`] — GraphSAINT node sampler.
//! * [`layerwise::LayerwiseSampler`] — FastGCN-style importance sampling
//!   (the paper groups its computation pattern with subgraph sampling).

pub mod layerwise;
pub mod neighbor;
pub mod subgraph;
pub mod values;

use crate::graph::{GraphAccess, Vid};
use crate::util::rng::Pcg64;

/// One inter-layer edge of the sampled adjacency `A_s^l`, in global vertex
/// ids.  `src` lives in `B^{l-1}` and feeds `dst` in `B^l` (the aggregation
/// direction of Algorithm 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Edge {
    pub src: Vid,
    pub dst: Vid,
}

/// A sampled mini-batch in global ids, before layout/renaming.
///
/// `layers[l]` is `B^l` in storage order (`layers[L]` are the targets);
/// `edges[l-1]` is `A_s^l`.  Self loops `(v, v)` are included explicitly —
/// both GCN (Eq. 1) and GraphSAGE (Eq. 2) aggregate over `N(v) ∪ {v}` — so
/// every `B^l` vertex also appears in `B^{l-1}`.
#[derive(Debug, Clone)]
pub struct MiniBatch {
    pub layers: Vec<Vec<Vid>>,
    pub edges: Vec<Vec<Edge>>,
}

impl MiniBatch {
    pub fn num_layers(&self) -> usize {
        self.edges.len()
    }

    /// Σ_l |B^l| — numerator of the paper's NVTPS throughput metric.
    pub fn vertices_traversed(&self) -> usize {
        self.layers.iter().map(|l| l.len()).sum()
    }

    pub fn num_edges(&self, layer: usize) -> usize {
        self.edges[layer - 1].len()
    }

    /// Check the structural invariants every sampler must uphold.
    pub fn validate(&self, g: &dyn GraphAccess) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.layers.len() == self.edges.len() + 1,
            "need L+1 vertex sets for L edge sets"
        );
        for (l, edge_set) in self.edges.iter().enumerate() {
            let prev: std::collections::HashSet<Vid> = self.layers[l].iter().copied().collect();
            let cur: std::collections::HashSet<Vid> = self.layers[l + 1].iter().copied().collect();
            anyhow::ensure!(
                prev.len() == self.layers[l].len(),
                "duplicate vertex in B^{l}"
            );
            for e in edge_set {
                anyhow::ensure!(prev.contains(&e.src), "edge src {} not in B^{}", e.src, l);
                anyhow::ensure!(cur.contains(&e.dst), "edge dst {} not in B^{}", e.dst, l + 1);
                anyhow::ensure!(
                    e.src == e.dst || g.neighbors(e.dst).contains(&e.src),
                    "edge ({}, {}) not in input graph",
                    e.src,
                    e.dst
                );
            }
            // Aggregation needs v's own feature: self loop support.
            for &v in &self.layers[l + 1] {
                anyhow::ensure!(prev.contains(&v), "B^{} vertex {v} missing from B^{l}", l + 1);
            }
        }
        Ok(())
    }
}

/// Common sampler interface: draw one mini-batch.
pub trait Sampler: Send + Sync {
    /// Number of GNN layers the batches serve.
    fn num_layers(&self) -> usize;

    /// Clone into an owned trait object.  Training sessions hold their
    /// sampler in an `Arc` shared with the producer threads, so borrowed
    /// `&dyn Sampler` callers (the `train()` compat path) need an owned
    /// copy to hand over.
    fn clone_box(&self) -> Box<dyn Sampler>;

    /// Draw a mini-batch from `g` with the caller's RNG.  `g` is the
    /// trait surface, so the same sampler runs against an in-RAM
    /// [`crate::graph::Graph`], an out-of-core
    /// [`crate::graph::store::GraphStore`], or a pinned
    /// [`crate::graph::store::GraphSnapshot`].
    fn sample(&self, g: &dyn GraphAccess, rng: &mut Pcg64) -> MiniBatch;

    /// Target-directed sampling for inference: draw the L-layer
    /// neighborhood of the *given* target vertices instead of a random
    /// draw.  The serving subsystem uses this to answer "classify vertex
    /// v" requests.  Not every sampling algorithm supports it (subgraph
    /// sampling has no per-target expansion), so the default errors.
    fn sample_targets(
        &self,
        g: &dyn GraphAccess,
        targets: &[Vid],
        rng: &mut Pcg64,
    ) -> anyhow::Result<MiniBatch> {
        let _ = (g, targets, rng);
        anyhow::bail!(
            "sampler {} does not support target-directed (inference-time) sampling",
            self.name()
        )
    }

    /// Human-readable name for logs and tables.
    fn name(&self) -> String;

    /// Expected |B^l| per layer (paper Table 2) — drives geometry choice
    /// and the analytic performance model.
    fn expected_layer_sizes(&self, g: &dyn GraphAccess) -> Vec<usize>;

    /// Expected |E^l| per layer (paper Table 2).
    fn expected_edge_counts(&self, g: &dyn GraphAccess) -> Vec<usize>;
}

/// Dedup while preserving first-seen order (samplers use this to build
/// `B^{l-1}` so vertex order, and thus the data layout, is deterministic).
pub fn dedup_preserve_order(items: impl IntoIterator<Item = Vid>) -> Vec<Vid> {
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::new();
    for v in items {
        if seen.insert(v) {
            out.push(v);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;

    #[test]
    fn dedup_keeps_first_occurrence_order() {
        assert_eq!(dedup_preserve_order([3, 1, 3, 2, 1]), vec![3, 1, 2]);
        assert_eq!(dedup_preserve_order([] as [Vid; 0]), Vec::<Vid>::new());
    }

    #[test]
    fn vertices_traversed_sums_layers() {
        let mb = MiniBatch {
            layers: vec![vec![0, 1, 2], vec![0, 1], vec![0]],
            edges: vec![vec![], vec![]],
        };
        assert_eq!(mb.vertices_traversed(), 6);
        assert_eq!(mb.num_layers(), 2);
    }

    #[test]
    fn validate_flags_foreign_edges() {
        let g = generator::uniform(16, 60, true, 1);
        let mb = MiniBatch {
            layers: vec![vec![0, 1], vec![0]],
            edges: vec![vec![Edge { src: 9, dst: 0 }]], // 9 not in B^0
        };
        assert!(mb.validate(&g).is_err());
    }

    #[test]
    fn validate_requires_self_support() {
        let g = generator::uniform(16, 60, true, 1);
        let mb = MiniBatch {
            layers: vec![vec![1], vec![0]], // 0 not in B^0
            edges: vec![vec![]],
        };
        assert!(mb.validate(&g).is_err());
    }
}
