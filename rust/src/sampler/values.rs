//! Edge-value assignment (`PrepareEdges()` in the paper's API, Table 1).
//!
//! The sampled adjacency `A_s^l` carries per-edge values that encode the
//! model's Aggregate() semantics so the accelerator's Scatter PE can stay
//! generic (`msg.val = edge.val * feat[edge.src]`, paper Listing 2):
//!
//! * GCN (Eq. 1): `1/sqrt(D(u) D(v))` symmetric normalization with
//!   self-loop degrees (A + I convention).
//! * GraphSAGE (Eq. 2): mean coefficients `1/(|N_s(v)|+1)` per destination,
//!   self loop included — the concat branch is handled by the model.
//! * Custom UDF layers may override values arbitrarily (learnable edge
//!   weights are supported end-to-end through the `edge_dot` VJP kernel).

use super::MiniBatch;
use crate::graph::GraphAccess;

/// Which GNN-layer operator the batch will feed (decides edge values).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GnnModel {
    Gcn,
    Sage,
    /// GIN (Xu et al., the paper's third off-the-shelf model): sum
    /// aggregation with a (1 + ε)-weighted self loop feeding the update
    /// MLP.  In the aggregate-update abstraction this is the GCN hardware
    /// template with different edge values, so GIN shares the GCN AOT
    /// artifact (`artifact_key`).
    Gin,
}

/// GIN's ε (fixed, non-learnable — the common "GIN-0"-adjacent setting;
/// a learnable ε would flow through the `edge_dot` VJP kernel).
pub const GIN_EPS: f32 = 0.1;

impl GnnModel {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "gcn" => Ok(GnnModel::Gcn),
            "sage" | "graphsage" => Ok(GnnModel::Sage),
            "gin" => Ok(GnnModel::Gin),
            other => anyhow::bail!("unknown model {other:?} (want gcn|sage|gin)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            GnnModel::Gcn => "gcn",
            GnnModel::Sage => "sage",
            GnnModel::Gin => "gin",
        }
    }

    /// Which compiled-artifact family implements this model's layer
    /// operators.  GIN's computation graph is the GCN template (sum
    /// aggregate + fused MLP update); only the edge values differ, and
    /// those are runtime inputs.
    pub fn artifact_key(&self) -> &'static str {
        match self {
            GnnModel::Gcn | GnnModel::Gin => "gcn",
            GnnModel::Sage => "sage",
        }
    }
}

/// Per-layer edge values, parallel to `MiniBatch::edges`.
pub type EdgeValues = Vec<Vec<f32>>;

/// Compute edge values for `batch` under `model`.
pub fn attach_values(g: &dyn GraphAccess, batch: &MiniBatch, model: GnnModel) -> EdgeValues {
    let _sp = crate::obs::span("pipeline", "values");
    match model {
        GnnModel::Gcn => gcn_values(g, batch),
        GnnModel::Sage => sage_values(batch),
        GnnModel::Gin => gin_values(batch),
    }
}

/// GIN (Eq. of Xu et al.): a_v = (1+ε)·h_v + Σ_{u∈N(v)} h_u — neighbor
/// edges weigh 1, the self loop 1+ε.
fn gin_values(batch: &MiniBatch) -> EdgeValues {
    batch
        .edges
        .iter()
        .map(|layer| {
            layer
                .iter()
                .map(|e| if e.src == e.dst { 1.0 + GIN_EPS } else { 1.0 })
                .collect()
        })
        .collect()
}

fn gcn_values(g: &dyn GraphAccess, batch: &MiniBatch) -> EdgeValues {
    batch
        .edges
        .iter()
        .map(|layer| layer.iter().map(|e| g.gcn_norm(e.src, e.dst)).collect())
        .collect()
}

fn sage_values(batch: &MiniBatch) -> EdgeValues {
    batch
        .edges
        .iter()
        .map(|layer| {
            // Count in-batch degree per destination (self loop included in
            // the edge stream by the samplers).
            let mut count: std::collections::HashMap<u32, usize> = std::collections::HashMap::new();
            for e in layer {
                *count.entry(e.dst).or_insert(0) += 1;
            }
            layer
                .iter()
                .map(|e| 1.0f32 / count[&e.dst] as f32)
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generator, Graph};
    use crate::sampler::neighbor::NeighborSampler;
    use crate::sampler::Sampler;
    use crate::util::rng::Pcg64;

    fn setup() -> (Graph, MiniBatch) {
        let g = generator::with_min_degree(
            generator::rmat(200, 1600, Default::default(), 5),
            1,
            6,
        );
        let s = NeighborSampler::new(16, vec![4, 4]);
        let mb = s.sample(&g, &mut Pcg64::seed_from_u64(7));
        (g, mb)
    }

    #[test]
    fn sage_values_sum_to_one_per_destination() {
        let (_g, mb) = setup();
        let vals = sage_values(&mb);
        for (layer, lvals) in mb.edges.iter().zip(&vals) {
            let mut sums: std::collections::HashMap<u32, f32> = std::collections::HashMap::new();
            for (e, &v) in layer.iter().zip(lvals) {
                *sums.entry(e.dst).or_insert(0.0) += v;
            }
            for (&dst, &s) in &sums {
                assert!((s - 1.0).abs() < 1e-5, "dst {dst} sums to {s}");
            }
        }
    }

    #[test]
    fn gcn_values_match_norm_formula() {
        let (g, mb) = setup();
        let vals = gcn_values(&g, &mb);
        for (layer, lvals) in mb.edges.iter().zip(&vals) {
            for (e, &v) in layer.iter().zip(lvals) {
                let du = (g.degree(e.src) + 1) as f32;
                let dv = (g.degree(e.dst) + 1) as f32;
                assert!((v - 1.0 / (du * dv).sqrt()).abs() < 1e-6);
                assert!(v > 0.0 && v <= 1.0);
            }
        }
    }

    #[test]
    fn attach_values_dispatches() {
        let (g, mb) = setup();
        let gcn = attach_values(&g, &mb, GnnModel::Gcn);
        let sage = attach_values(&g, &mb, GnnModel::Sage);
        assert_eq!(gcn.len(), mb.edges.len());
        assert_eq!(sage.len(), mb.edges.len());
        assert_ne!(gcn[0], sage[0]);
        for (l, layer) in mb.edges.iter().enumerate() {
            assert_eq!(gcn[l].len(), layer.len());
            assert_eq!(sage[l].len(), layer.len());
        }
    }

    #[test]
    fn model_parsing() {
        assert_eq!(GnnModel::parse("GCN").unwrap(), GnnModel::Gcn);
        assert_eq!(GnnModel::parse("GraphSAGE").unwrap(), GnnModel::Sage);
        assert!(GnnModel::parse("gat").is_err());
        assert_eq!(GnnModel::Gcn.as_str(), "gcn");
    }
}
#[cfg(test)]
mod gin_tests {
    use super::*;
    use crate::graph::generator;
    use crate::sampler::{neighbor::NeighborSampler, Sampler};
    use crate::util::rng::Pcg64;

    #[test]
    fn gin_values_weight_self_loops() {
        let g = generator::with_min_degree(
            generator::rmat(150, 1200, Default::default(), 9),
            1,
            10,
        );
        let mb = NeighborSampler::new(8, vec![3]).sample(&g, &mut Pcg64::seed_from_u64(2));
        let vals = attach_values(&g, &mb, GnnModel::Gin);
        for (layer, lvals) in mb.edges.iter().zip(&vals) {
            for (e, &v) in layer.iter().zip(lvals) {
                if e.src == e.dst {
                    assert!((v - (1.0 + GIN_EPS)).abs() < 1e-6);
                } else {
                    assert_eq!(v, 1.0);
                }
            }
        }
    }

    #[test]
    fn gin_resolves_to_gcn_artifact_family() {
        assert_eq!(GnnModel::Gin.artifact_key(), "gcn");
        assert_eq!(GnnModel::Gin.as_str(), "gin");
        assert_eq!(GnnModel::parse("GIN").unwrap(), GnnModel::Gin);
    }
}
