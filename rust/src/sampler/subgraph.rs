//! GraphSAINT node sampler (paper §2.3 "Subgraph Sampling").
//!
//! Samples a budget `SB` of vertices and induces the subgraph over them;
//! all layers share the same vertex set (`B^0 = B^1 = ... = B^L`) and the
//! same induced adjacency.  GraphSAINT's node sampler draws vertices with
//! probability proportional to degree (≈ P(v) ∝ ||A_{:,v}||²); a uniform
//! mode is provided for ablations.

use super::{Edge, MiniBatch, Sampler};
use crate::graph::{GraphAccess, Vid};
use crate::util::rng::Pcg64;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum NodeProbability {
    Uniform,
    /// GraphSAINT default: importance ∝ degree.
    Degree,
    /// Importance ∝ min(degree, cap·avg_degree).  Synthetic R-MAT graphs
    /// have far heavier hubs than the real datasets they stand in for;
    /// uncapped degree weighting then yields near-clique subgraphs.  The
    /// cap tempers that artifact while keeping the degree bias (see
    /// DESIGN.md §2 substitution notes).
    DegreeCapped(f64),
}

#[derive(Debug, Clone)]
pub struct SubgraphSampler {
    pub budget: usize,
    pub num_layers: usize,
    pub probability: NodeProbability,
}

impl SubgraphSampler {
    pub fn new(budget: usize, num_layers: usize) -> Self {
        assert!(budget > 0 && num_layers > 0);
        SubgraphSampler { budget, num_layers, probability: NodeProbability::Degree }
    }

    /// Paper evaluation configuration: SB = 2750 on a 2-layer model.
    pub fn paper_default() -> Self {
        SubgraphSampler::new(2750, 2)
    }

    fn draw_vertices(&self, g: &dyn GraphAccess, rng: &mut Pcg64) -> Vec<Vid> {
        let n = g.num_vertices();
        let budget = self.budget.min(n);
        match self.probability {
            NodeProbability::Uniform => rng
                .sample_distinct(n, budget)
                .into_iter()
                .map(|v| v as Vid)
                .collect(),
            NodeProbability::Degree | NodeProbability::DegreeCapped(_) => {
                let cap = match self.probability {
                    NodeProbability::DegreeCapped(mult) => mult * g.avg_degree(),
                    _ => f64::INFINITY,
                };
                // Weighted sampling without replacement via exponential
                // clocks (Efraimidis-Spirakis): key = -ln(u)/w, keep the
                // smallest `budget` keys. O(n log k).
                let mut heap: std::collections::BinaryHeap<(ordered, Vid)> =
                    std::collections::BinaryHeap::with_capacity(budget + 1);
                for v in 0..n {
                    let w = ((g.degree(v as Vid) + 1) as f64).min(cap);
                    let key = -rng.f64().max(1e-300).ln() / w;
                    heap.push((ordered::from(key), v as Vid));
                    if heap.len() > budget {
                        heap.pop();
                    }
                }
                let mut out: Vec<Vid> = heap.into_iter().map(|(_, v)| v).collect();
                out.sort_unstable();
                out
            }
        }
    }
}

/// Total-ordered f64 wrapper for the weighted-sampling heap.
#[derive(Debug, Clone, Copy, PartialEq)]
#[allow(non_camel_case_types)]
struct ordered(f64);

impl ordered {
    fn from(x: f64) -> Self {
        assert!(!x.is_nan());
        ordered(x)
    }
}

impl Eq for ordered {}

impl PartialOrd for ordered {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for ordered {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).unwrap()
    }
}

impl Sampler for SubgraphSampler {
    fn num_layers(&self) -> usize {
        self.num_layers
    }

    fn clone_box(&self) -> Box<dyn Sampler> {
        Box::new(self.clone())
    }

    fn name(&self) -> String {
        format!("SS(budget={}, L={})", self.budget, self.num_layers)
    }

    fn sample(&self, g: &dyn GraphAccess, rng: &mut Pcg64) -> MiniBatch {
        let verts = self.draw_vertices(g, rng);
        let in_set: std::collections::HashSet<Vid> = verts.iter().copied().collect();

        // Induce the subgraph once; every layer reuses it (B^l identical).
        let mut induced: Vec<Edge> = Vec::new();
        for &v in &verts {
            induced.push(Edge { src: v, dst: v }); // self loop
            for &u in g.neighbors(v).iter() {
                // Graph self-loops would duplicate the explicit self loop.
                if u != v && in_set.contains(&u) {
                    // u -> v aggregation edge (u feeds v).
                    induced.push(Edge { src: u, dst: v });
                }
            }
        }

        MiniBatch {
            layers: vec![verts.clone(); self.num_layers + 1],
            edges: vec![induced; self.num_layers],
        }
    }

    fn expected_layer_sizes(&self, g: &dyn GraphAccess) -> Vec<usize> {
        vec![self.budget.min(g.num_vertices()); self.num_layers + 1]
    }

    /// Paper Table 2: |E^l| = SB * κ(SB) where κ estimates induced-subgraph
    /// density.  We estimate κ via the degree-weighted edge-survival
    /// probability (both endpoints sampled) — see `perf::batchgeom` for the
    /// fitted version used by the DSE engine.
    fn expected_edge_counts(&self, g: &dyn GraphAccess) -> Vec<usize> {
        let n = g.num_vertices() as f64;
        let sb = self.budget.min(g.num_vertices()) as f64;
        // Uniform-sampling survival: P(edge kept) ≈ (SB/n)². Degree-weighted
        // sampling keeps more (high-degree endpoints over-sampled); apply
        // the empirical ×2.5 skew factor of R-MAT-like graphs.
        let skew = match self.probability {
            NodeProbability::Uniform => 1.0,
            NodeProbability::DegreeCapped(_) => 1.8,
            NodeProbability::Degree => 2.5,
        };
        let kept = (g.num_edges() as f64 * (sb / n) * (sb / n) * skew) + sb; // + self loops
        vec![kept as usize; self.num_layers]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generator, Graph};

    fn graph() -> Graph {
        generator::rmat(800, 8000, Default::default(), 10)
    }

    #[test]
    fn all_layers_share_vertex_set() {
        let g = graph();
        let s = SubgraphSampler::new(100, 2);
        let mb = s.sample(&g, &mut Pcg64::seed_from_u64(1));
        mb.validate(&g).unwrap();
        assert_eq!(mb.layers[0], mb.layers[1]);
        assert_eq!(mb.layers[1], mb.layers[2]);
        assert_eq!(mb.edges[0].len(), mb.edges[1].len());
        assert_eq!(mb.layers[0].len(), 100);
    }

    #[test]
    fn induced_edges_only() {
        let g = graph();
        let s = SubgraphSampler::new(60, 1);
        let mb = s.sample(&g, &mut Pcg64::seed_from_u64(2));
        let set: std::collections::HashSet<Vid> = mb.layers[0].iter().copied().collect();
        for e in &mb.edges[0] {
            assert!(set.contains(&e.src) && set.contains(&e.dst));
        }
    }

    #[test]
    fn degree_mode_prefers_hubs() {
        let g = graph();
        let mut hub_hits = 0usize;
        let mut uni_hits = 0usize;
        // The top-degree vertex should appear much more often under Degree.
        let hub = (0..g.num_vertices() as Vid)
            .max_by_key(|&v| g.degree(v))
            .unwrap();
        for seed in 0..60 {
            let mut s = SubgraphSampler::new(40, 1);
            let mb = s.sample(&g, &mut Pcg64::seed_from_u64(seed));
            hub_hits += usize::from(mb.layers[0].contains(&hub));
            s.probability = NodeProbability::Uniform;
            let mb = s.sample(&g, &mut Pcg64::seed_from_u64(seed));
            uni_hits += usize::from(mb.layers[0].contains(&hub));
        }
        assert!(hub_hits > uni_hits, "hub {hub}: degree={hub_hits} uniform={uni_hits}");
    }

    #[test]
    fn budget_clamped_to_graph() {
        let g = generator::uniform(20, 80, true, 3);
        let s = SubgraphSampler::new(1000, 2);
        let mb = s.sample(&g, &mut Pcg64::seed_from_u64(4));
        assert_eq!(mb.layers[0].len(), 20);
        mb.validate(&g).unwrap();
    }

    #[test]
    fn deterministic_per_seed() {
        let g = graph();
        let s = SubgraphSampler::new(50, 2);
        let a = s.sample(&g, &mut Pcg64::seed_from_u64(5));
        let b = s.sample(&g, &mut Pcg64::seed_from_u64(5));
        assert_eq!(a.layers, b.layers);
    }

    #[test]
    fn expected_edges_reasonable() {
        let g = graph();
        let s = SubgraphSampler::new(200, 2);
        let expected = s.expected_edge_counts(&g)[0] as f64;
        let mut total = 0usize;
        let runs = 10;
        for seed in 0..runs {
            total += s.sample(&g, &mut Pcg64::seed_from_u64(seed)).edges[0].len();
        }
        let actual = total as f64 / runs as f64;
        assert!(
            expected / actual < 4.0 && actual / expected < 4.0,
            "expected {expected}, measured {actual}"
        );
    }
}
