//! GraphSAGE neighbor sampler (paper §2.3 "Neighbor Sampling").
//!
//! Starting from `|V^t|` uniformly chosen target vertices, recursively
//! samples up to `NS^l` neighbors per vertex per layer (uniform, without
//! replacement, capped by degree), producing
//! `|B^{l-1}| <= |B^l| * NS^l (+ self vertices)` and the sampled adjacency
//! `A_s^l` including self loops.

use super::{dedup_preserve_order, Edge, MiniBatch, Sampler};
use crate::graph::{GraphAccess, Vid};
use crate::util::rng::Pcg64;

/// Configuration mirroring the paper's
/// `Sampler('NeighborSampler', L=2, budgets=[10, 25])`: `budgets[l-1]` is
/// `NS^l`, the fan-out when expanding layer `l` vertices into layer `l-1`
/// (so `budgets.last()` applies to the targets first).
#[derive(Debug, Clone)]
pub struct NeighborSampler {
    pub num_targets: usize,
    /// `budgets[l-1] = NS^l`; length L.
    pub budgets: Vec<usize>,
}

impl NeighborSampler {
    pub fn new(num_targets: usize, budgets: Vec<usize>) -> Self {
        assert!(!budgets.is_empty(), "at least one layer");
        assert!(budgets.iter().all(|&b| b > 0), "budgets must be positive");
        NeighborSampler { num_targets, budgets }
    }

    /// The paper's evaluation configuration: |V^t|=1024, NS=[25, 10]
    /// (25 one-hop, 10 two-hop) for a 2-layer model.
    pub fn paper_default() -> Self {
        NeighborSampler::new(1024, vec![10, 25])
    }

    /// Recursive neighbor expansion of an already-chosen target set — the
    /// body shared by random training draws ([`Sampler::sample`]) and
    /// target-directed inference draws ([`Sampler::sample_targets`]).
    fn expand(&self, g: &dyn GraphAccess, targets: Vec<Vid>, rng: &mut Pcg64) -> MiniBatch {
        let _sp = crate::obs::span_with("pipeline", "sample", || {
            vec![("targets", targets.len() as f64)]
        });
        let ll = self.num_layers();
        let mut layers = vec![Vec::new(); ll + 1];
        let mut edges = vec![Vec::new(); ll];
        layers[ll] = targets;

        // Expand top-down: layer l vertices pull from layer l-1.
        for l in (1..=ll).rev() {
            let budget = self.budgets[l - 1];
            let mut frontier: Vec<Vid> = Vec::new();
            let mut edge_set: Vec<Edge> = Vec::new();
            for &v in &layers[l] {
                // Self loop first: keeps v at a deterministic place in the
                // frontier and satisfies the B^l ⊆ B^{l-1} invariant.
                frontier.push(v);
                edge_set.push(Edge { src: v, dst: v });
                let neigh = g.neighbors(v);
                if neigh.is_empty() {
                    continue;
                }
                if neigh.len() <= budget {
                    for &u in neigh.iter() {
                        // Graph self-loops would duplicate the explicit one.
                        if u != v {
                            frontier.push(u);
                            edge_set.push(Edge { src: u, dst: v });
                        }
                    }
                } else {
                    for i in rng.sample_distinct(neigh.len(), budget) {
                        let u = neigh[i];
                        if u != v {
                            frontier.push(u);
                            edge_set.push(Edge { src: u, dst: v });
                        }
                    }
                }
            }
            layers[l - 1] = dedup_preserve_order(frontier);
            edges[l - 1] = edge_set;
        }

        MiniBatch { layers, edges }
    }
}

impl Sampler for NeighborSampler {
    fn num_layers(&self) -> usize {
        self.budgets.len()
    }

    fn clone_box(&self) -> Box<dyn Sampler> {
        Box::new(self.clone())
    }

    fn name(&self) -> String {
        format!("NS(t={}, budgets={:?})", self.num_targets, self.budgets)
    }

    fn sample(&self, g: &dyn GraphAccess, rng: &mut Pcg64) -> MiniBatch {
        let n = g.num_vertices();
        let targets: Vec<Vid> = rng
            .sample_distinct(n, self.num_targets.min(n))
            .into_iter()
            .map(|v| v as Vid)
            .collect();
        self.expand(g, targets, rng)
    }

    /// Inference-time draw: expand the neighborhoods of the *given*
    /// targets with the same recursion as [`sample`](Sampler::sample).
    fn sample_targets(
        &self,
        g: &dyn GraphAccess,
        targets: &[Vid],
        rng: &mut Pcg64,
    ) -> anyhow::Result<MiniBatch> {
        anyhow::ensure!(!targets.is_empty(), "sample_targets: no target vertices");
        let mut seen = std::collections::HashSet::with_capacity(targets.len());
        for &v in targets {
            anyhow::ensure!(
                (v as usize) < g.num_vertices(),
                "target vertex {v} out of range (graph has {} vertices)",
                g.num_vertices()
            );
            anyhow::ensure!(seen.insert(v), "duplicate target vertex {v}");
        }
        Ok(self.expand(g, targets.to_vec(), rng))
    }

    /// Paper Table 2: |B^l| = |V^t| * Π_{i=l+1}^{L} NS^i  (plus the
    /// self-inclusion, which the paper folds into the budget).
    fn expected_layer_sizes(&self, g: &dyn GraphAccess) -> Vec<usize> {
        let ll = self.num_layers();
        let t = self.num_targets.min(g.num_vertices());
        let mut sizes = vec![0usize; ll + 1];
        sizes[ll] = t;
        for l in (0..ll).rev() {
            // NS^{l+1} = budgets[l]; +1 accounts for the self vertex.
            sizes[l] = sizes[l + 1] * (self.budgets[l] + 1);
        }
        sizes
    }

    /// Paper Table 2: |E^l| = |V^t| * Π_{i=l}^{L} NS^i, with self loops.
    fn expected_edge_counts(&self, g: &dyn GraphAccess) -> Vec<usize> {
        let sizes = self.expected_layer_sizes(g);
        (1..=self.num_layers())
            .map(|l| sizes[l] * (self.budgets[l - 1] + 1))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generator, Graph};
    use crate::util::prop::Runner;

    fn graph() -> Graph {
        generator::with_min_degree(generator::rmat(500, 4000, Default::default(), 1), 1, 2)
    }

    #[test]
    fn batch_satisfies_invariants() {
        let g = graph();
        let s = NeighborSampler::new(32, vec![5, 10]);
        let mut rng = Pcg64::seed_from_u64(3);
        let mb = s.sample(&g, &mut rng);
        mb.validate(&g).unwrap();
        assert_eq!(mb.layers[2].len(), 32);
        assert_eq!(mb.num_layers(), 2);
    }

    #[test]
    fn fanout_respects_budget() {
        let g = graph();
        let s = NeighborSampler::new(16, vec![3, 4]);
        let mut rng = Pcg64::seed_from_u64(4);
        let mb = s.sample(&g, &mut rng);
        // Per-target edges in top layer: self + at most 4 neighbors.
        let mut per_dst = std::collections::HashMap::new();
        for e in &mb.edges[1] {
            *per_dst.entry(e.dst).or_insert(0usize) += 1;
        }
        for (&dst, &count) in &per_dst {
            assert!(count <= 5, "target {dst} has {count} edges");
            assert!(count >= 1);
        }
        // Layer sizes bounded by the Table 2 closed form.
        let bound = s.expected_layer_sizes(&g);
        for l in 0..=2 {
            assert!(mb.layers[l].len() <= bound[l], "layer {l}");
        }
    }

    #[test]
    fn includes_self_loops() {
        let g = graph();
        let s = NeighborSampler::new(8, vec![2]);
        let mut rng = Pcg64::seed_from_u64(5);
        let mb = s.sample(&g, &mut rng);
        for &v in &mb.layers[1] {
            assert!(
                mb.edges[0].contains(&Edge { src: v, dst: v }),
                "missing self loop for {v}"
            );
        }
    }

    #[test]
    fn deterministic_under_seed() {
        let g = graph();
        let s = NeighborSampler::new(16, vec![4, 4]);
        let a = s.sample(&g, &mut Pcg64::seed_from_u64(9));
        let b = s.sample(&g, &mut Pcg64::seed_from_u64(9));
        assert_eq!(a.layers, b.layers);
        assert_eq!(a.edges, b.edges);
    }

    #[test]
    fn sample_targets_expands_the_requested_vertices() {
        let g = graph();
        let s = NeighborSampler::new(16, vec![4, 4]);
        let targets = vec![3u32, 17, 42];
        let mb = s
            .sample_targets(&g, &targets, &mut Pcg64::seed_from_u64(12))
            .unwrap();
        mb.validate(&g).unwrap();
        assert_eq!(mb.layers[2], targets);
        // Deterministic under the same RNG seed.
        let mb2 = s
            .sample_targets(&g, &targets, &mut Pcg64::seed_from_u64(12))
            .unwrap();
        assert_eq!(mb.layers, mb2.layers);
        assert_eq!(mb.edges, mb2.edges);
        // Out-of-range and duplicate targets are rejected.
        assert!(s.sample_targets(&g, &[9999], &mut Pcg64::seed_from_u64(1)).is_err());
        assert!(s.sample_targets(&g, &[3, 3], &mut Pcg64::seed_from_u64(1)).is_err());
        assert!(s.sample_targets(&g, &[], &mut Pcg64::seed_from_u64(1)).is_err());
    }

    #[test]
    fn targets_larger_than_graph_are_clamped() {
        let g = generator::uniform(10, 40, true, 6);
        let s = NeighborSampler::new(100, vec![2]);
        let mb = s.sample(&g, &mut Pcg64::seed_from_u64(7));
        assert_eq!(mb.layers[1].len(), 10);
        mb.validate(&g).unwrap();
    }

    #[test]
    fn property_invariants_across_seeds_and_shapes() {
        Runner::new(24, 0xdead).run(
            |rng| {
                let n = 50 + rng.index(400);
                let e = n * (2 + rng.index(8));
                let targets = 1 + rng.index(20);
                let depth = 1 + rng.index(3);
                let budgets: Vec<usize> = (0..depth).map(|_| 1 + rng.index(6)).collect();
                (n, e, targets, budgets, rng.next_u64())
            },
            |&(n, e, targets, ref budgets, seed)| {
                let g = generator::with_min_degree(
                    generator::uniform(n, e, true, seed),
                    1,
                    seed ^ 1,
                );
                let s = NeighborSampler::new(targets, budgets.clone());
                let mb = s.sample(&g, &mut Pcg64::seed_from_u64(seed ^ 2));
                mb.validate(&g).map_err(|e| e.to_string())?;
                let bounds = s.expected_layer_sizes(&g);
                for l in 0..mb.layers.len() {
                    if mb.layers[l].len() > bounds[l] {
                        return Err(format!(
                            "layer {l} size {} exceeds Table-2 bound {}",
                            mb.layers[l].len(),
                            bounds[l]
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
