//! Layer-wise (FastGCN-style) importance sampler.
//!
//! The paper (Table 2) models layer-wise sampling alongside subgraph
//! sampling: per layer an independent vertex set `S^l` is drawn (importance
//! ∝ degree), and `A_s^l` is the bipartite adjacency induced between
//! consecutive layers.  Self loops are added so `B^l ⊆ B^{l-1}` holds like
//! the other samplers (the union keeps aggregation well-defined).

use super::{dedup_preserve_order, Edge, MiniBatch, Sampler};
use crate::graph::{GraphAccess, Vid};
use crate::util::rng::Pcg64;

#[derive(Debug, Clone)]
pub struct LayerwiseSampler {
    pub num_targets: usize,
    /// `layer_sizes[l-1] = |S^l|` for layers 1..=L-1... sizes for layers
    /// 0..L-1 (the target layer L uses `num_targets`).
    pub layer_sizes: Vec<usize>,
}

impl LayerwiseSampler {
    pub fn new(num_targets: usize, layer_sizes: Vec<usize>) -> Self {
        assert!(!layer_sizes.is_empty());
        assert!(layer_sizes.iter().all(|&s| s > 0));
        LayerwiseSampler { num_targets, layer_sizes }
    }
}

impl Sampler for LayerwiseSampler {
    fn num_layers(&self) -> usize {
        self.layer_sizes.len()
    }

    fn clone_box(&self) -> Box<dyn Sampler> {
        Box::new(self.clone())
    }

    fn name(&self) -> String {
        format!("LW(t={}, sizes={:?})", self.num_targets, self.layer_sizes)
    }

    fn sample(&self, g: &dyn GraphAccess, rng: &mut Pcg64) -> MiniBatch {
        let ll = self.num_layers();
        let n = g.num_vertices();
        let mut layers: Vec<Vec<Vid>> = vec![Vec::new(); ll + 1];
        layers[ll] = rng
            .sample_distinct(n, self.num_targets.min(n))
            .into_iter()
            .map(|v| v as Vid)
            .collect();

        for l in (0..ll).rev() {
            // Degree-weighted independent draw for S^l ...
            let budget = self.layer_sizes[l].min(n);
            let mut drawn: Vec<Vid> = Vec::with_capacity(budget);
            let mut seen = std::collections::HashSet::new();
            while drawn.len() < budget && seen.len() < n {
                let v = rng.index(n) as Vid;
                // Degree-biased acceptance: accept with prob ∝ deg+1.
                let max_deg = 64usize;
                let p = ((g.degree(v) + 1).min(max_deg)) as f64 / max_deg as f64;
                if rng.f64() < p && seen.insert(v) {
                    drawn.push(v);
                }
                if seen.len() + drawn.len() > 4 * n {
                    break;
                }
            }
            // ... plus the upper layer itself (self-loop support).
            let mut combined = layers[l + 1].clone();
            combined.extend(drawn);
            layers[l] = dedup_preserve_order(combined);
        }

        // Induce bipartite adjacency between consecutive layers.
        let mut edges = Vec::with_capacity(ll);
        for l in 1..=ll {
            let prev: std::collections::HashSet<Vid> = layers[l - 1].iter().copied().collect();
            let mut edge_set = Vec::new();
            for &v in &layers[l] {
                edge_set.push(Edge { src: v, dst: v });
                for &u in g.neighbors(v).iter() {
                    // Skip graph self-loops; the explicit one is enough.
                    if u != v && prev.contains(&u) {
                        edge_set.push(Edge { src: u, dst: v });
                    }
                }
            }
            edges.push(edge_set);
        }

        MiniBatch { layers, edges }
    }

    fn expected_layer_sizes(&self, g: &dyn GraphAccess) -> Vec<usize> {
        let ll = self.num_layers();
        let mut sizes = vec![0usize; ll + 1];
        sizes[ll] = self.num_targets.min(g.num_vertices());
        for l in (0..ll).rev() {
            sizes[l] = (self.layer_sizes[l] + sizes[l + 1]).min(g.num_vertices());
        }
        sizes
    }

    /// Paper Table 2: |E^l| = S^l * S^{l-1} * κ(S^l).
    fn expected_edge_counts(&self, g: &dyn GraphAccess) -> Vec<usize> {
        let sizes = self.expected_layer_sizes(g);
        let n = g.num_vertices() as f64;
        (1..=self.num_layers())
            .map(|l| {
                let kappa = 2.5 * g.avg_degree() / n; // degree-weighted density
                (sizes[l] as f64 * sizes[l - 1] as f64 * kappa) as usize + sizes[l]
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generator, Graph};

    fn graph() -> Graph {
        generator::rmat(600, 6000, Default::default(), 20)
    }

    #[test]
    fn batch_valid_and_sized() {
        let g = graph();
        let s = LayerwiseSampler::new(32, vec![200, 100]);
        let mb = s.sample(&g, &mut Pcg64::seed_from_u64(1));
        mb.validate(&g).unwrap();
        assert_eq!(mb.layers[2].len(), 32);
        // Layer sizes within expected bounds.
        let bounds = s.expected_layer_sizes(&g);
        for l in 0..3 {
            assert!(mb.layers[l].len() <= bounds[l], "layer {l}");
        }
    }

    #[test]
    fn upper_layers_subset_of_lower() {
        let g = graph();
        let s = LayerwiseSampler::new(16, vec![80, 40]);
        let mb = s.sample(&g, &mut Pcg64::seed_from_u64(2));
        for l in 0..2 {
            let lower: std::collections::HashSet<Vid> = mb.layers[l].iter().copied().collect();
            for &v in &mb.layers[l + 1] {
                assert!(lower.contains(&v));
            }
        }
    }

    #[test]
    fn deterministic() {
        let g = graph();
        let s = LayerwiseSampler::new(16, vec![50]);
        let a = s.sample(&g, &mut Pcg64::seed_from_u64(3));
        let b = s.sample(&g, &mut Pcg64::seed_from_u64(3));
        assert_eq!(a.layers, b.layers);
        assert_eq!(a.edges, b.edges);
    }
}
