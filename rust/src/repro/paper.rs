//! Published numbers from the paper's evaluation section, for
//! side-by-side "paper vs ours" rows in the bench output and
//! EXPERIMENTS.md.  All throughput in NVTPS.

/// Table 6: NS-GCN layout-optimization ablation (baseline / +RMT /
/// +RMT+RRA) per dataset.
pub const TABLE6: [(&str, f64, f64, f64); 4] = [
    ("FL", 10.45e6, 11.98e6, 16.38e6),
    ("RD", 12.98e6, 16.48e6, 18.50e6),
    ("YP", 19.71e6, 22.39e6, 24.60e6),
    ("AP", 23.17e6, 27.22e6, 29.27e6),
];

/// Table 7: (workload, dataset, CPU, CPU-GPU, CPU-FPGA); CPU-GPU None =
/// out of memory.
pub const TABLE7: [(&str, &str, f64, Option<f64>, f64); 16] = [
    ("NS-GCN", "FL", 265.5e3, Some(2.69e6), 16.38e6),
    ("NS-GCN", "RD", 85.65e3, Some(7.15e6), 18.50e6),
    ("NS-GCN", "YP", 275.6e3, Some(9.36e6), 24.61e6),
    ("NS-GCN", "AP", 480.6e3, Some(13.0e6), 29.26e6),
    ("NS-SAGE", "FL", 225.2e3, Some(2.74e6), 11.84e6),
    ("NS-SAGE", "RD", 78.50e3, Some(6.90e6), 13.10e6),
    ("NS-SAGE", "YP", 266.0e3, Some(9.19e6), 18.12e6),
    ("NS-SAGE", "AP", 479.3e3, Some(13.57e6), 21.15e6),
    ("SS-GCN", "FL", 215.2e3, Some(768.3e3), 2.81e6),
    ("SS-GCN", "RD", 118.9e3, Some(536.4e3), 2.56e6),
    ("SS-GCN", "YP", 159.1e3, Some(751.0e3), 3.08e6),
    ("SS-GCN", "AP", 25.55e3, None, 1.47e6),
    ("SS-SAGE", "FL", 179.9e3, Some(626.7e3), 2.71e6),
    ("SS-SAGE", "RD", 94.72e3, Some(505.2e3), 2.43e6),
    ("SS-SAGE", "YP", 126.7e3, Some(709.7e3), 2.78e6),
    ("SS-SAGE", "AP", 17.40e3, None, 1.45e6),
];

/// Table 8: SS-SAGE comparison (dataset, GraphACT, Rubik, this work).
/// Rubik's Yelp cell is N/A in the paper.
pub const TABLE8: [(&str, f64, Option<f64>, f64); 2] = [
    ("RD", 546.8e3, Some(717.0e3), 2.43e6),
    ("YP", 769.8e3, None, 2.78e6),
];

/// Table 5: chosen (m, n) per workload.
pub const TABLE5_CONFIG: [(&str, usize, usize); 4] = [
    ("NS-GCN", 256, 4),
    ("NS-SAGE", 256, 4),
    ("SS-GCN", 256, 4),
    ("SS-SAGE", 256, 8),
];

/// Table 5: utilization percentages (LUT, DSP, URAM, BRAM) per workload.
pub const TABLE5_UTIL: [(&str, f64, f64, f64, f64); 4] = [
    ("NS-GCN", 0.50, 0.70, 0.34, 0.28),
    ("NS-SAGE", 0.54, 0.54, 0.34, 0.28),
    ("SS-GCN", 0.44, 0.70, 0.14, 0.30),
    ("SS-SAGE", 0.76, 0.82, 0.20, 0.34),
];

/// Headline averages (§6.4): speedup of CPU-FPGA over CPU and CPU-GPU.
pub const AVG_SPEEDUP_OVER_CPU: f64 = 55.67;
pub const AVG_SPEEDUP_OVER_GPU: f64 = 2.17;

#[cfg(test)]
mod tests {
    #[test]
    fn tables_are_complete() {
        assert_eq!(super::TABLE7.len(), 16);
        assert_eq!(super::TABLE6.len(), 4);
        // Per-row FPGA > GPU > CPU in the published data.
        for (_, _, cpu, gpu, fpga) in super::TABLE7 {
            if let Some(gpu) = gpu {
                assert!(fpga > gpu && gpu > cpu);
            } else {
                assert!(fpga > cpu);
            }
        }
        // Table 6 improvements are monotone.
        for (_, base, rmt, all) in super::TABLE6 {
            assert!(base < rmt && rmt < all);
        }
    }
}
