//! Experiment-reproduction harness: shared machinery for the `table*`
//! benches and the CLI so every exhibit of the paper's evaluation section
//! is regenerated the same way.
//!
//! We have no U250 (and no A100): timing rows come from the cycle-level
//! accelerator simulator fed with *real sampled edge streams* at the
//! paper's sampler parameters, on statistic-matched synthetic datasets
//! instantiated at reduced |V| (per-dataset scale factors below, chosen so
//! the biggest instance still generates in seconds).  Functional training
//! runs separately through PJRT (see `examples/train_e2e.rs`).
//! [`paper`] holds the published numbers for side-by-side printing.

pub mod paper;

use crate::accel::{simulate_batch, AccelConfig, Platform, SimOptions};
use crate::graph::{datasets::DatasetSpec, Graph};
use crate::layout::{index_batch, LayoutOptions};
use crate::sampler::values::{attach_values, GnnModel};
use crate::sampler::{neighbor::NeighborSampler, subgraph::SubgraphSampler, Sampler};
use crate::util::rng::Pcg64;
use crate::util::stats::{Summary, Timer};

/// Sampler used in the paper's evaluation (§6.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvalSampler {
    /// GraphSAGE neighbor sampler: |V^t| = 1024, NS = [25, 10].
    Ns,
    /// GraphSAINT node sampler: SB = 2750.
    Ss,
}

impl EvalSampler {
    pub fn build(&self) -> Box<dyn Sampler> {
        match self {
            EvalSampler::Ns => Box::new(NeighborSampler::paper_default()),
            EvalSampler::Ss => Box::new(SubgraphSampler::paper_default()),
        }
    }

    /// Sampler with parameters adjusted to a *scaled instance* of `ds`.
    /// NS parameters are fraction-free (fixed fan-outs) and stay as-is;
    /// the SS budget scales with the instance so the sampled *fraction*
    /// matches the paper (SB/|V|), keeping induced-subgraph density
    /// realistic.  Since subgraph cost is ~linear in SB at fixed fraction,
    /// NVTPS measured this way is an intensive metric directly comparable
    /// to the full-scale number.
    pub fn build_for(&self, g: &Graph, ds: &DatasetSpec) -> Box<dyn Sampler> {
        match self {
            EvalSampler::Ns => Box::new(NeighborSampler::paper_default()),
            EvalSampler::Ss => {
                let scale = g.num_vertices() as f64 / ds.nodes as f64;
                let budget = ((2750.0 * scale) as usize).max(64);
                let mut s = SubgraphSampler::new(budget, 2);
                // R-MAT hub correction — see NodeProbability::DegreeCapped.
                s.probability = crate::sampler::subgraph::NodeProbability::DegreeCapped(3.0);
                Box::new(s)
            }
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            EvalSampler::Ns => "NS",
            EvalSampler::Ss => "SS",
        }
    }
}

/// Per-dataset instantiation scale for simulation workloads (big enough
/// that the paper's sampler parameters behave normally, small enough to
/// generate in seconds).
pub fn sim_scale(ds: &DatasetSpec) -> f64 {
    match ds.key {
        "FL" => 0.5,
        "RD" => 0.2,
        "YP" => 0.1,
        _ => 0.03, // AP
    }
}

/// Cached scaled instance (generation is seconds for AP; reuse per bench).
pub fn scaled_instance(ds: &DatasetSpec, seed: u64) -> Graph {
    ds.scale(sim_scale(ds)).instantiate(seed)
}

/// One simulated workload measurement.
#[derive(Debug, Clone)]
pub struct WorkloadSim {
    pub nvtps: f64,
    pub t_gnn: Summary,
    /// Measured single-thread host time to sample+layout one batch.
    pub t_sampling_single: Summary,
    /// Threads needed so sampling stays hidden (Eq. 5).
    pub sampler_threads: usize,
    pub vertices_per_batch: f64,
}

/// Simulate `batches` mini-batches of (dataset instance, model, sampler)
/// through the accelerator model under `layout`.
pub fn simulate_workload(
    g: &Graph,
    ds: &DatasetSpec,
    model: GnnModel,
    sampler: EvalSampler,
    layout: LayoutOptions,
    config: &AccelConfig,
    batches: usize,
    seed: u64,
) -> WorkloadSim {
    let platform = Platform::alveo_u250();
    let s = sampler.build_for(g, ds);
    let feat = [ds.f0, 256, ds.f2];
    let mut t_gnn = Summary::new();
    let mut t_sampling = Summary::new();
    let mut verts = 0usize;
    let mut rng = Pcg64::seed_from_u64(seed);
    for _ in 0..batches.max(1) {
        let st = Timer::start();
        let mb = s.sample(g, &mut rng);
        let vals = attach_values(g, &mb, model);
        let ib = index_batch(&mb, &vals, layout);
        t_sampling.add(st.secs());
        let timing = simulate_batch(
            &platform,
            config,
            &ib,
            &feat,
            SimOptions { sage_concat: model == GnnModel::Sage, ..Default::default() },
        );
        t_gnn.add(timing.t_gnn);
        verts += ib.vertices_traversed();
    }
    let vertices_per_batch = verts as f64 / batches.max(1) as f64;
    let threads = (t_sampling.mean() / t_gnn.mean()).ceil().max(1.0) as usize;
    WorkloadSim {
        // Eq. 5 with the thread pool sized so sampling is hidden.
        nvtps: vertices_per_batch / t_gnn.mean(),
        t_gnn,
        t_sampling_single: t_sampling,
        sampler_threads: threads,
        vertices_per_batch,
    }
}

/// Fit κ on a scaled instance and rescale the slope to the full dataset
/// (κ(s) ≈ c·d̄·s/|V|, so slope scales with 1/|V| at constant average
/// degree).  `from_stats` underestimates heavy-tail induced density by
/// >10x; the fitted version tracks measurements within ~2x (see the
/// table2 bench).
pub fn fitted_kappa_fullscale(g: &Graph, ds: &DatasetSpec) -> crate::perf::KappaEstimator {
    // Probe at the *fraction-matched* sizes s_inst = s_full * scale, then
    // evaluate at scaled coordinates: kappa_full(s) = kappa_inst(s*scale),
    // i.e. slope_full = slope_inst * scale.  Evaluating the instance fit
    // directly at s_full would extrapolate 1/scale beyond the probe range.
    let scale = g.num_vertices() as f64 / ds.nodes as f64;
    let probes: Vec<usize> = [500usize, 1000, 2000, 2750]
        .iter()
        .map(|&s| ((s as f64 * scale) as usize).max(32))
        .collect();
    let fit = crate::perf::KappaEstimator::fit(g, &probes, 0xfade);
    crate::perf::KappaEstimator { slope: fit.slope * scale, intercept: fit.intercept }
}

/// The DSE configuration used for simulation rows (paper Table 5 pick).
pub fn table5_config(sampler: EvalSampler, model: GnnModel) -> AccelConfig {
    match (sampler, model) {
        (EvalSampler::Ss, GnnModel::Sage) => AccelConfig { n: 8, m: 256 },
        _ => AccelConfig { n: 4, m: 256 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;

    #[test]
    fn workload_sim_produces_sane_numbers() {
        let ds = datasets::FLICKR;
        let g = ds.scale(0.05).instantiate(1);
        let sim = simulate_workload(
            &g,
            &ds,
            GnnModel::Gcn,
            EvalSampler::Ns,
            LayoutOptions::all(),
            &AccelConfig::paper_default(),
            2,
            1,
        );
        assert!(sim.nvtps > 1e5, "NVTPS {:.3e}", sim.nvtps);
        assert!(sim.t_gnn.mean() > 0.0);
        assert!(sim.sampler_threads >= 1);
        assert!(sim.vertices_per_batch > 1000.0);
    }

    #[test]
    fn layout_ablation_ordering_on_real_streams() {
        // Table 6's property on an actual sampled stream: baseline <
        // RMT <= RMT+RRA (throughput).
        let ds = datasets::FLICKR;
        let g = ds.scale(0.05).instantiate(2);
        let cfg = AccelConfig::paper_default();
        let run = |layout| {
            simulate_workload(&g, &ds, GnnModel::Gcn, EvalSampler::Ns, layout, &cfg, 2, 3).nvtps
        };
        let base = run(LayoutOptions::none());
        let rmt = run(LayoutOptions { rmt: true, rra: false });
        let all = run(LayoutOptions::all());
        assert!(rmt > base, "RMT {rmt:.3e} <= baseline {base:.3e}");
        assert!(all >= rmt * 0.99, "RMT+RRA {all:.3e} < RMT {rmt:.3e}");
    }

    #[test]
    fn sim_scales_defined_for_all_datasets() {
        for ds in &datasets::ALL {
            let s = sim_scale(ds);
            assert!(s > 0.0 && s <= 1.0);
            // Scaled instance stays under ~5M edges (generation budget).
            assert!(((ds.edges as f64) * s) < 5.5e6, "{} too big", ds.key);
        }
    }
}
