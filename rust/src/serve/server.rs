//! The serving engine: request intake, worker pool, weight hot-swap.
//!
//! [`Server::start`] compiles one forward [`Executable`] replica per
//! worker through the runtime's [`Backend`](crate::runtime::Backend)
//! contract, spawns the micro-batcher and the worker pool, and returns a
//! [`Server`] whose [`classify`](Server::classify) answers "classify
//! vertex v" end to end: per-vertex deterministic neighborhood sampling
//! (the [`Sampler::sample_targets`] path) → per-target positional layout →
//! greedy packing into the artifact geometry → forward execution →
//! logits/argmax.  See [`super::infer`] for why served logits are
//! bit-identical across worker counts and batch coalescing patterns.
//!
//! The server holds a [`DynamicGraph`], not a frozen graph: admin edge
//! ingest ([`Server::ingest`]) publishes a new snapshot version, workers
//! pin exactly one snapshot per micro-batch (taken *before* the weights
//! read lock), and the logits cache keys on the full `(weights_version,
//! graph_version)` pair — so an ingest mid-serve can neither tear a batch
//! across topologies nor let stale-topology logits answer a fresh query.

use std::path::Path;
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Duration;

use super::batcher::{run_batcher, WorkItem};
use super::cache::LogitsCache;
use super::infer::{self, InferOptions};
use super::metrics::{MetricsSnapshot, ServeMetrics};
use super::{lock_unpoisoned, read_unpoisoned, vertex_rng, write_unpoisoned, Prediction};
use crate::coordinator::session::graph_fingerprint;
use crate::coordinator::trainer::{TrainConfig, ValueFn};
use crate::graph::store::{DynamicGraph, GraphSnapshot};
use crate::graph::{GraphAccess, Vid};
use crate::layout::pad::EdgeOverflow;
use crate::layout::{Geometry, IndexedBatch, LayoutOptions};
use crate::runtime::weights::{checkpoint_magic, CheckpointKind};
use crate::runtime::{Checkpoint, Executable, ExecOptions, Kind, Runtime, WeightState};
use crate::sampler::values::GnnModel;
use crate::sampler::Sampler;
use crate::util::stats::Timer;

/// Serving knobs (the `hp-gnn serve` flag set).
#[derive(Clone)]
pub struct ServeConfig {
    pub model: GnnModel,
    /// Artifact geometry name the forward executable is compiled for.
    pub geometry: String,
    pub layout: LayoutOptions,
    pub overflow: EdgeOverflow,
    /// Feature/label synthesis seed — must match training.
    pub seed: u64,
    /// Custom Scatter UDF; must match training for value parity.
    pub value_fn: Option<ValueFn>,
    /// Inference-time neighborhood sampling seed.  Each query vertex gets
    /// its own whitened RNG stream from `(infer_seed, v)`, making served
    /// results a pure function of the vertex — the cache's soundness and
    /// the determinism invariant both rest on this.
    pub infer_seed: u64,
    /// Executor replicas (worker threads).
    pub workers: usize,
    /// Micro-batch coalescing cap; `0` = the geometry's target-vertex
    /// capacity `b[L]`.
    pub max_batch: usize,
    /// Micro-batch deadline: a batch ships at most this long after its
    /// first request arrives.
    pub max_wait: Duration,
    /// Bound of the request queue (enqueue blocks when full).
    pub queue_depth: usize,
    /// Enable the versioned logits cache for repeat query vertices.
    pub cache: bool,
    /// Kernel threads per worker replica (workers are the parallelism
    /// axis, so each replica defaults to sequential kernels).
    pub compute_threads: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            model: GnnModel::Gcn,
            geometry: "tiny".to_string(),
            layout: LayoutOptions::all(),
            overflow: EdgeOverflow::Error,
            seed: 7,
            value_fn: None,
            infer_seed: 0x5e7e,
            workers: 2,
            max_batch: 0,
            max_wait: Duration::from_micros(200),
            queue_depth: 1024,
            cache: false,
            compute_threads: 1,
        }
    }
}

impl ServeConfig {
    /// Serving view of a training configuration: same model, geometry,
    /// layout, overflow policy, seed and edge-value UDF; serving knobs at
    /// their defaults.
    pub fn from_train(cfg: &TrainConfig) -> ServeConfig {
        ServeConfig {
            model: cfg.model,
            geometry: cfg.geometry.clone(),
            layout: cfg.layout,
            overflow: cfg.overflow,
            seed: cfg.seed,
            value_fn: cfg.value_fn.clone(),
            ..ServeConfig::default()
        }
    }

    /// Overlay a user program's `serving` section
    /// ([`ServingSpec`](crate::api::spec::ServingSpec)) on this config —
    /// how a declarative program drives `hp-gnn serve` end to end.
    pub fn apply_spec(mut self, s: &crate::api::spec::ServingSpec) -> ServeConfig {
        self.workers = s.workers.max(1);
        self.max_batch = s.max_batch;
        self.max_wait = Duration::from_micros(s.max_wait_us);
        self.queue_depth = s.queue_depth.max(1);
        self.cache = s.cache;
        self
    }
}

impl std::fmt::Debug for ServeConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServeConfig")
            .field("model", &self.model)
            .field("geometry", &self.geometry)
            .field("workers", &self.workers)
            .field("max_batch", &self.max_batch)
            .field("max_wait", &self.max_wait)
            .field("cache", &self.cache)
            .finish()
    }
}

/// Weights plus the cache version they correspond to, swapped atomically
/// on reload.
struct VersionedWeights {
    version: u64,
    weights: Arc<WeightState>,
}

/// The serving identity an `HPGNNS01` session snapshot must match.
/// Weights-only `HPGNNW01` files carry no metadata, but snapshots record
/// what they were trained with — serving them under a different sampler,
/// graph, seed, model or geometry would return confidently wrong
/// predictions, so the mismatch is rejected exactly like session resume
/// rejects it.
struct SnapshotIdentity {
    model: String,
    geometry: String,
    sampler: String,
    graph: String,
    seed: u64,
}

impl SnapshotIdentity {
    fn new(cfg: &ServeConfig, graph: &dyn GraphAccess, sampler: &dyn Sampler) -> SnapshotIdentity {
        SnapshotIdentity {
            model: cfg.model.as_str().to_string(),
            geometry: cfg.geometry.clone(),
            sampler: sampler.name(),
            graph: graph_fingerprint(graph),
            seed: cfg.seed,
        }
    }

    fn check(&self, snap: &Checkpoint) -> anyhow::Result<()> {
        anyhow::ensure!(
            snap.model == self.model,
            "checkpoint was trained with model {:?}, the server runs {:?}",
            snap.model,
            self.model
        );
        anyhow::ensure!(
            snap.geometry == self.geometry,
            "checkpoint geometry {:?} does not match serving geometry {:?}",
            snap.geometry,
            self.geometry
        );
        anyhow::ensure!(
            snap.sampler == self.sampler,
            "checkpoint was trained with sampler {:?}, the server samples with {:?}",
            snap.sampler,
            self.sampler
        );
        anyhow::ensure!(
            snap.graph == self.graph,
            "checkpoint graph {:?} does not match serving graph {:?}",
            snap.graph,
            self.graph
        );
        anyhow::ensure!(
            snap.seed == self.seed,
            "checkpoint was trained with seed {} but the server synthesizes features \
             with seed {}",
            snap.seed,
            self.seed
        );
        Ok(())
    }
}

/// Load serving weights from either checkpoint format, validating an
/// `HPGNNS01` snapshot's recorded training identity against `id` (an
/// `HPGNNW01` file has no metadata to check — shapes are still validated
/// downstream).
fn load_weights_validated(path: &Path, id: &SnapshotIdentity) -> anyhow::Result<WeightState> {
    match checkpoint_magic(path)? {
        CheckpointKind::Weights => WeightState::load(path),
        CheckpointKind::Session => {
            let snap = Checkpoint::load(path)?;
            id.check(&snap)?;
            Ok(snap.weights)
        }
    }
}

/// A live inference server.  `Sync`: share it behind an `Arc` and call
/// [`classify`](Server::classify) from any number of client threads.
pub struct Server {
    geom: Geometry,
    weight_shapes: Vec<(Vec<usize>, Vec<usize>)>,
    identity: SnapshotIdentity,
    num_workers: usize,
    max_batch: usize,
    graph: Arc<DynamicGraph>,
    weights: Arc<RwLock<VersionedWeights>>,
    cache: Arc<LogitsCache>,
    metrics: Arc<ServeMetrics>,
    job_tx: Mutex<Option<mpsc::SyncSender<WorkItem>>>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Compile the worker replicas, validate `weights` against the
    /// artifact, and bring the pipeline up.
    pub fn start(
        runtime: &Runtime,
        graph: Arc<DynamicGraph>,
        sampler: Arc<dyn Sampler>,
        cfg: ServeConfig,
        weights: WeightState,
    ) -> anyhow::Result<Server> {
        let num_workers = cfg.workers.max(1);
        let exec_opts = ExecOptions { compute_threads: Some(cfg.compute_threads.max(1)) };
        let mut exes = Vec::with_capacity(num_workers);
        for _ in 0..num_workers {
            exes.push(runtime.compile_role_with(
                cfg.model,
                &cfg.geometry,
                Kind::Forward,
                &exec_opts,
            )?);
        }
        let spec = &exes[0].spec;
        let geom = spec.geometry.clone();
        let weight_shapes = spec.weight_shapes.clone();
        let boot = graph.snapshot();
        let identity = SnapshotIdentity::new(&cfg, boot.as_ref(), sampler.as_ref());
        validate_weight_shapes(&weight_shapes, &weights)?;
        anyhow::ensure!(
            geom.layers() == sampler.num_layers(),
            "sampler has {} layers, artifact geometry {} has {}",
            sampler.num_layers(),
            geom.name,
            geom.layers()
        );

        let capacity = geom.b[geom.layers()];
        let max_batch = if cfg.max_batch == 0 { capacity } else { cfg.max_batch };
        let cache = Arc::new(LogitsCache::new(cfg.cache));
        cache.set_graph_version(boot.version());
        let metrics = Arc::new(ServeMetrics::default());
        metrics.set_graph(boot.version(), boot.bytes_mapped());
        drop(boot);
        let weights = Arc::new(RwLock::new(VersionedWeights {
            version: cache.version(),
            weights: Arc::new(weights),
        }));

        let (job_tx, job_rx) = mpsc::sync_channel::<WorkItem>(cfg.queue_depth.max(1));
        let (work_tx, work_rx) = mpsc::sync_channel::<Vec<WorkItem>>(num_workers);
        let max_wait = cfg.max_wait;
        let batcher_metrics = Arc::clone(&metrics);
        let batcher = std::thread::Builder::new()
            .name("hp-gnn-serve-batcher".to_string())
            .spawn(move || run_batcher(job_rx, work_tx, max_batch, max_wait, batcher_metrics))?;

        let opts = InferOptions {
            model: cfg.model,
            layout: cfg.layout,
            overflow: cfg.overflow,
            seed: cfg.seed,
            value_fn: cfg.value_fn.clone(),
        };
        let work_rx = Arc::new(Mutex::new(work_rx));
        let mut workers = Vec::with_capacity(num_workers);
        for (i, exe) in exes.into_iter().enumerate() {
            let ctx = WorkerCtx {
                exe,
                graph: Arc::clone(&graph),
                sampler: Arc::clone(&sampler),
                opts: opts.clone(),
                infer_seed: cfg.infer_seed,
                weights: Arc::clone(&weights),
                cache: Arc::clone(&cache),
                metrics: Arc::clone(&metrics),
                work_rx: Arc::clone(&work_rx),
            };
            workers.push(
                std::thread::Builder::new()
                    .name(format!("hp-gnn-serve-worker-{i}"))
                    .spawn(move || run_worker(ctx))?,
            );
        }

        Ok(Server {
            geom,
            weight_shapes,
            identity,
            num_workers,
            max_batch,
            graph,
            weights,
            cache,
            metrics,
            job_tx: Mutex::new(Some(job_tx)),
            batcher: Some(batcher),
            workers,
        })
    }

    /// [`start`](Server::start) with weights loaded from an `HPGNNW01` or
    /// `HPGNNS01` checkpoint.  A session snapshot's recorded training
    /// identity (model, geometry, sampler, graph, seed) must match the
    /// serving configuration, or the load is rejected.
    pub fn from_checkpoint(
        runtime: &Runtime,
        graph: Arc<DynamicGraph>,
        sampler: Arc<dyn Sampler>,
        cfg: ServeConfig,
        checkpoint: &Path,
    ) -> anyhow::Result<Server> {
        let identity =
            SnapshotIdentity::new(&cfg, graph.snapshot().as_ref(), sampler.as_ref());
        let weights = load_weights_validated(checkpoint, &identity)?;
        Server::start(runtime, graph, sampler, cfg, weights)
    }

    /// Classify a set of vertices: cache hits answer immediately, misses
    /// go through the micro-batcher, and the results come back in input
    /// order.  Blocking; call from as many threads as you like.
    pub fn classify(&self, vertices: &[Vid]) -> anyhow::Result<Vec<Arc<Prediction>>> {
        anyhow::ensure!(!vertices.is_empty(), "classify: no vertices given");
        let _sp =
            crate::obs::span_with("serve", "request", || vec![("vertices", vertices.len() as f64)]);
        let t = Timer::start();
        let tx = {
            let guard = lock_unpoisoned(&self.job_tx);
            guard
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("server is shut down"))?
                .clone()
        };
        let (reply_tx, reply_rx) = mpsc::channel();
        let mut results: Vec<Option<Arc<Prediction>>> = vec![None; vertices.len()];
        let (mut hits, mut pending) = (0usize, 0usize);
        for (idx, &vertex) in vertices.iter().enumerate() {
            if let Some(hit) = self.cache.get(vertex) {
                hits += 1;
                results[idx] = Some(hit);
            } else {
                pending += 1;
                tx.send(WorkItem { vertex, idx, reply: reply_tx.clone(), enqueued: Timer::start() })
                    .map_err(|_| anyhow::anyhow!("server request queue closed"))?;
                self.metrics.depth_add(1);
            }
        }
        drop(reply_tx);
        self.metrics.record_cache(hits, pending);
        for _ in 0..pending {
            let (idx, res) = reply_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("serving workers terminated before replying"))?;
            self.metrics.depth_sub(1);
            results[idx] = Some(res?);
        }
        self.metrics.record_request(vertices.len(), t.secs());
        // Every slot was filled by a cache hit or a counted reply above;
        // an empty one is an internal invariant break, reported as an
        // error rather than a panic (R1).
        results
            .into_iter()
            .enumerate()
            .map(|(i, slot)| {
                slot.ok_or_else(|| {
                    anyhow::anyhow!("internal: vertex slot {i} left unresolved")
                })
            })
            .collect()
    }

    /// Single-vertex convenience wrapper over [`classify`](Self::classify).
    pub fn classify_one(&self, vertex: Vid) -> anyhow::Result<Arc<Prediction>> {
        Ok(self.classify(&[vertex])?.remove(0))
    }

    /// Admission-controlled [`classify`](Self::classify): enqueue misses
    /// with `try_send` instead of blocking.  When the bounded request
    /// queue is full the request is *shed* — `Ok(None)` comes back, the
    /// shed counter ticks, and nothing waits behind an unbounded backlog
    /// (the HTTP frontend turns this into `429 Too Many Requests`).
    ///
    /// A bulk request that fills the queue partway through is still shed
    /// as a whole: the items already enqueued are drained (their results
    /// may warm the cache) and the caller gets `Ok(None)`, never a
    /// partial answer.
    pub fn try_classify(&self, vertices: &[Vid]) -> anyhow::Result<Option<Vec<Arc<Prediction>>>> {
        anyhow::ensure!(!vertices.is_empty(), "classify: no vertices given");
        let _sp =
            crate::obs::span_with("serve", "request", || vec![("vertices", vertices.len() as f64)]);
        let t = Timer::start();
        let tx = {
            let guard = lock_unpoisoned(&self.job_tx);
            guard
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("server is shut down"))?
                .clone()
        };
        let (reply_tx, reply_rx) = mpsc::channel();
        let mut results: Vec<Option<Arc<Prediction>>> = vec![None; vertices.len()];
        let (mut hits, mut pending) = (0usize, 0usize);
        let mut shed = false;
        for (idx, &vertex) in vertices.iter().enumerate() {
            if let Some(hit) = self.cache.get(vertex) {
                hits += 1;
                results[idx] = Some(hit);
                continue;
            }
            let item = WorkItem { vertex, idx, reply: reply_tx.clone(), enqueued: Timer::start() };
            match tx.try_send(item) {
                Ok(()) => {
                    pending += 1;
                    self.metrics.depth_add(1);
                }
                Err(mpsc::TrySendError::Full(_)) => {
                    shed = true;
                    break;
                }
                Err(mpsc::TrySendError::Disconnected(_)) => {
                    anyhow::bail!("server request queue closed");
                }
            }
        }
        drop(reply_tx);
        if shed {
            // Drain what was already enqueued so the depth gauge stays
            // balanced; the computed logits still populate the cache.
            for _ in 0..pending {
                if reply_rx.recv().is_ok() {
                    self.metrics.depth_sub(1);
                }
            }
            self.metrics.record_shed();
            return Ok(None);
        }
        self.metrics.record_cache(hits, pending);
        for _ in 0..pending {
            let (idx, res) = reply_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("serving workers terminated before replying"))?;
            self.metrics.depth_sub(1);
            results[idx] = Some(res?);
        }
        self.metrics.record_request(vertices.len(), t.secs());
        results
            .into_iter()
            .enumerate()
            .map(|(i, slot)| {
                slot.ok_or_else(|| {
                    anyhow::anyhow!("internal: vertex slot {i} left unresolved")
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()
            .map(Some)
    }

    /// Hot-swap the model weights from an `HPGNNW01`/`HPGNNS01` checkpoint
    /// without restarting: in-flight batches finish under the old weights
    /// (and cannot pollute the cache — their version is stale), new
    /// requests see the new model.
    pub fn reload_weights(&self, checkpoint: &Path) -> anyhow::Result<()> {
        let w = load_weights_validated(checkpoint, &self.identity)?;
        validate_weight_shapes(&self.weight_shapes, &w)?;
        let mut guard = write_unpoisoned(&self.weights);
        guard.version = self.cache.invalidate();
        guard.weights = Arc::new(w);
        Ok(())
    }

    /// Point-in-time serving metrics.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Prometheus text exposition of the live serving metrics (what
    /// `GET /metrics` returns).
    pub fn metrics_prometheus(&self) -> String {
        self.metrics.prometheus()
    }

    /// Version of the weights new requests are served under; bumps on
    /// every successful [`reload_weights`](Self::reload_weights).
    pub fn weight_version(&self) -> u64 {
        read_unpoisoned(&self.weights).version
    }

    /// Version of the graph snapshot new requests are served against;
    /// bumps on every successful [`ingest`](Self::ingest).
    pub fn graph_version(&self) -> u64 {
        self.graph.version()
    }

    /// Insert edges into the served graph (the `POST /v1/ingest` admin
    /// operation).  Publishes a new snapshot version: in-flight batches
    /// finish against the snapshot they pinned (and cannot pollute the
    /// cache — their graph version is stale), new requests sample the
    /// updated topology.  Returns the new graph version.
    pub fn ingest(&self, edges: &[(Vid, Vid)]) -> anyhow::Result<u64> {
        let version = self.graph.ingest(edges)?;
        self.cache.set_graph_version(version);
        self.metrics.record_ingest(edges.len() as u64, version, self.graph.bytes_mapped());
        Ok(version)
    }

    /// Live entries in the logits cache.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    pub fn geometry(&self) -> &Geometry {
        &self.geom
    }

    pub fn num_workers(&self) -> usize {
        self.num_workers
    }

    /// The effective micro-batch coalescing cap (a configured `0`
    /// resolves to the geometry's target-vertex capacity).
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Stop accepting requests, drain the queue, and join every thread.
    /// In-flight [`classify`](Self::classify) calls complete.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        drop(lock_unpoisoned(&self.job_tx).take());
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn validate_weight_shapes(
    weight_shapes: &[(Vec<usize>, Vec<usize>)],
    weights: &WeightState,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        weights.tensors.len() == weight_shapes.len() * 2,
        "checkpoint has {} weight tensors, artifact wants {}",
        weights.tensors.len(),
        weight_shapes.len() * 2
    );
    for (l, (wshape, bshape)) in weight_shapes.iter().enumerate() {
        anyhow::ensure!(
            &weights.tensors[2 * l].0 == wshape,
            "checkpoint w{} shape {:?} does not match artifact shape {:?}",
            l + 1,
            weights.tensors[2 * l].0,
            wshape
        );
        anyhow::ensure!(
            &weights.tensors[2 * l + 1].0 == bshape,
            "checkpoint b{} shape {:?} does not match artifact shape {:?}",
            l + 1,
            weights.tensors[2 * l + 1].0,
            bshape
        );
    }
    Ok(())
}

/// Everything one worker thread owns or shares.
struct WorkerCtx {
    exe: Executable,
    graph: Arc<DynamicGraph>,
    sampler: Arc<dyn Sampler>,
    opts: InferOptions,
    infer_seed: u64,
    weights: Arc<RwLock<VersionedWeights>>,
    cache: Arc<LogitsCache>,
    metrics: Arc<ServeMetrics>,
    work_rx: Arc<Mutex<mpsc::Receiver<Vec<WorkItem>>>>,
}

/// Worker thread body: pull coalesced batches, sample each vertex's
/// subtree, pack subtrees into the geometry, execute, reply.
fn run_worker(ctx: WorkerCtx) {
    loop {
        // Receive under the shared-receiver lock; only the *wait* is
        // serialized — execution below runs with the lock released.
        let batch = {
            let guard = lock_unpoisoned(&ctx.work_rx);
            // lint:allow(C1): the shared-receiver lock exists to serialize exactly this wait
            match guard.recv() {
                Ok(b) => b,
                Err(_) => return, // batcher gone: shutdown
            }
        };
        serve_batch(&ctx, batch);
    }
}

fn serve_batch(ctx: &WorkerCtx, batch: Vec<WorkItem>) {
    // Pin one graph snapshot for the whole micro-batch *before* reading
    // the weights: every vertex in the batch samples the same topology,
    // and a concurrent ingest cannot tear the batch across versions.
    let snapshot = ctx.graph.snapshot();
    let graph_version = snapshot.version();
    // Weights and their cache version travel together so a concurrent
    // reload can't mix old logits with the new version stamp.
    let (version, weights) = {
        let guard = read_unpoisoned(&ctx.weights);
        (guard.version, Arc::clone(&guard.weights))
    };

    // Sample + lay out each vertex's subtree independently (per-vertex
    // RNG: results don't depend on batch composition).
    let mut pieces: Vec<(WorkItem, IndexedBatch)> = Vec::with_capacity(batch.len());
    for item in batch {
        ctx.metrics.record_queue_wait(item.enqueued.secs());
        let mut rng = vertex_rng(ctx.infer_seed, item.vertex);
        match ctx
            .sampler
            .sample_targets(snapshot.as_ref(), &[item.vertex], &mut rng)
            .map(|mb| infer::index_minibatch(snapshot.as_ref(), &mb, &ctx.opts))
        {
            Ok(ib) => pieces.push((item, ib)),
            Err(e) => {
                let _ = item
                    .reply
                    .send((item.idx, Err(e.context(format!("sampling vertex {}", item.vertex)))));
            }
        }
    }

    // Greedy packing: a group of subtrees must fit the artifact geometry
    // exactly as sampled (no cross-group effects), so per-layer vertex
    // AND edge budgets bound the group.  A subtree that alone exceeds a
    // budget still forms its own group — pad() then applies the overflow
    // policy identically to how a solo request would see it.
    let ll = ctx.exe.spec.geometry.layers();
    let geom = &ctx.exe.spec.geometry;
    let mut group: Vec<(WorkItem, IndexedBatch)> = Vec::new();
    let mut used_b = vec![0usize; ll + 1];
    let mut used_e = vec![0usize; ll];
    let flush = |group: &mut Vec<(WorkItem, IndexedBatch)>,
                 used_b: &mut Vec<usize>,
                 used_e: &mut Vec<usize>| {
        if group.is_empty() {
            return;
        }
        execute_group(ctx, version, graph_version, snapshot.as_ref(), &weights, std::mem::take(group));
        used_b.iter_mut().for_each(|x| *x = 0);
        used_e.iter_mut().for_each(|x| *x = 0);
    };
    for (item, ib) in pieces {
        let fits_b = (0..=ll).all(|l| used_b[l] + ib.layers[l].len() <= geom.b[l]);
        let fits_e = (0..ll).all(|l| used_e[l] + ib.layer_edges[l].src.len() <= geom.e[l]);
        if !(fits_b && fits_e) && !group.is_empty() {
            flush(&mut group, &mut used_b, &mut used_e);
        }
        for l in 0..=ll {
            used_b[l] += ib.layers[l].len();
        }
        for l in 0..ll {
            used_e[l] += ib.layer_edges[l].src.len();
        }
        group.push((item, ib));
    }
    flush(&mut group, &mut used_b, &mut used_e);
}

/// Execute one packed group as a single forward pass against the pinned
/// graph snapshot and reply per item.
fn execute_group(
    ctx: &WorkerCtx,
    version: u64,
    graph_version: u64,
    snapshot: &GraphSnapshot,
    weights: &WeightState,
    group: Vec<(WorkItem, IndexedBatch)>,
) {
    let parts: Vec<&IndexedBatch> = group.iter().map(|(_, ib)| ib).collect();
    let merged = infer::merge_indexed(&parts);
    let sp = crate::obs::span_with("serve", "infer", || vec![("batch", group.len() as f64)]);
    let t = Timer::start();
    let result = infer::infer_indexed(&ctx.exe, snapshot, &ctx.opts, weights, &merged);
    ctx.metrics.record_batch(group.len(), t.secs());
    drop(sp);
    match result {
        Ok(inf) => {
            debug_assert_eq!(inf.real_targets, group.len());
            for (j, (item, _)) in group.into_iter().enumerate() {
                let row = inf.row(j);
                let pred = Arc::new(Prediction {
                    vertex: item.vertex,
                    label: infer::argmax(row),
                    logits: row.to_vec(),
                });
                ctx.cache.put(version, graph_version, Arc::clone(&pred));
                let _ = item.reply.send((item.idx, Ok(pred)));
            }
        }
        Err(e) => {
            let msg = format!("forward inference failed: {e:#}");
            for (item, _) in group {
                let _ = item.reply.send((item.idx, Err(anyhow::anyhow!("{msg}"))));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::sampler::neighbor::NeighborSampler;

    fn tiny_graph() -> Arc<DynamicGraph> {
        let mut g = generator::with_min_degree(
            generator::rmat(400, 3200, Default::default(), 31),
            1,
            30,
        );
        g.feat_dim = 16;
        g.num_classes = 4;
        DynamicGraph::from_graph(g)
    }

    fn start(cfg: ServeConfig) -> (Runtime, Server) {
        let rt = Runtime::reference();
        let exe = rt.compile_role(GnnModel::Gcn, "tiny", Kind::Forward).unwrap();
        let weights = WeightState::init_glorot(&exe.spec.weight_shapes, 3);
        let server = Server::start(
            &rt,
            tiny_graph(),
            Arc::new(NeighborSampler::new(4, vec![5, 3])),
            cfg,
            weights,
        )
        .unwrap();
        (rt, server)
    }

    #[test]
    fn classifies_vertices_and_reports_metrics() {
        let (_rt, server) = start(ServeConfig::default());
        let preds = server.classify(&[5, 77, 123]).unwrap();
        assert_eq!(preds.len(), 3);
        for (p, &v) in preds.iter().zip(&[5u32, 77, 123]) {
            assert_eq!(p.vertex, v);
            assert_eq!(p.logits.len(), 4);
            assert!(p.logits.iter().all(|x| x.is_finite()));
            assert!(p.label.unwrap() < 4);
        }
        let m = server.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.vertices, 3);
        assert!(m.batches >= 1);
        assert!(m.latency_p50_s().is_some());
        server.shutdown();
    }

    #[test]
    fn idle_server_metrics_do_not_panic() {
        let (_rt, server) = start(ServeConfig::default());
        let m = server.metrics();
        assert_eq!(m.requests, 0);
        assert!(m.latency_p99_s().is_none());
        m.to_json().pretty();
    }

    #[test]
    fn cache_hits_repeat_queries_and_reload_invalidates() {
        let mut cfg = ServeConfig { cache: true, ..ServeConfig::default() };
        cfg.workers = 1;
        let (_rt, server) = start(cfg);
        let a = server.classify_one(42).unwrap();
        assert_eq!(server.metrics().cache_misses, 1);
        let b = server.classify_one(42).unwrap();
        assert_eq!(server.metrics().cache_hits, 1, "second query must hit");
        assert_eq!(a.logits, b.logits);
        assert_eq!(server.cache_len(), 1);

        // Hot-swap different weights: cache must invalidate and logits
        // must change.
        let rt = Runtime::reference();
        let exe = rt.compile_role(GnnModel::Gcn, "tiny", Kind::Forward).unwrap();
        let other = WeightState::init_glorot(&exe.spec.weight_shapes, 99);
        let dir = std::env::temp_dir().join(format!("hpgnn-serve-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("other.bin");
        other.save(&path).unwrap();
        let v0 = server.weight_version();
        server.reload_weights(&path).unwrap();
        assert_eq!(server.cache_len(), 0, "reload must clear the cache");
        assert!(server.weight_version() > v0, "reload must bump the weight version");
        let c = server.classify_one(42).unwrap();
        assert_ne!(a.logits, c.logits, "new weights must change the logits");
        server.shutdown();
    }

    #[test]
    fn ingest_bumps_graph_version_and_invalidates_stale_logits() {
        let mut cfg = ServeConfig { cache: true, ..ServeConfig::default() };
        cfg.workers = 1;
        let (_rt, server) = start(cfg);
        let g0 = server.graph_version();
        let before = server.classify_one(42).unwrap();
        assert_eq!(server.cache_len(), 1);

        // Publish new topology: version bumps, the cached entry for 42
        // (computed against the old snapshot) must miss.
        let g1 = server.ingest(&[(42, 7), (42, 9), (7, 42)]).unwrap();
        assert_eq!(g1, g0 + 1);
        assert_eq!(server.graph_version(), g1);
        let m = server.metrics();
        assert_eq!(m.ingest_edges, 3);
        assert_eq!(m.graph_version, g1);
        let misses = m.cache_misses;
        let after = server.classify_one(42).unwrap();
        assert_eq!(
            server.metrics().cache_misses,
            misses + 1,
            "stale-topology entry must not answer after ingest"
        );
        // Vertex 42 gained neighbors, so its sampled subtree — and its
        // logits — change; repeat queries at the new version hit again.
        assert_ne!(before.logits, after.logits, "new topology must reach the logits");
        let again = server.classify_one(42).unwrap();
        assert_eq!(after.logits, again.logits);
        assert!(server.metrics().cache_hits >= 1);

        // Out-of-range endpoints are rejected without a version bump.
        assert!(server.ingest(&[(0, 4000)]).is_err());
        assert_eq!(server.graph_version(), g1);
        server.shutdown();
    }

    #[test]
    fn concurrent_clients_each_get_their_own_vertices() {
        let (_rt, server) = start(ServeConfig {
            workers: 4,
            max_wait: Duration::from_millis(2),
            ..ServeConfig::default()
        });
        let server = Arc::new(server);
        let mut handles = Vec::new();
        for c in 0..6u32 {
            let s = Arc::clone(&server);
            handles.push(std::thread::spawn(move || {
                let verts: Vec<Vid> = (0..8).map(|i| (c * 37 + i * 11) % 400).collect();
                let preds = s.classify(&verts).unwrap();
                for (p, &v) in preds.iter().zip(&verts) {
                    assert_eq!(p.vertex, v, "reply order scrambled");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = server.metrics();
        assert_eq!(m.requests, 6);
        assert_eq!(m.vertices, 48);
    }

    #[test]
    fn try_classify_agrees_with_classify_and_balances_the_depth_gauge() {
        let (_rt, server) = start(ServeConfig::default());
        let blocking = server.classify(&[5, 77]).unwrap();
        let admitted = server
            .try_classify(&[5, 77])
            .unwrap()
            .expect("an idle queue must admit the request");
        for (a, b) in blocking.iter().zip(&admitted) {
            assert_eq!(a.logits, b.logits, "admission path changed the answer");
        }
        let m = server.metrics();
        assert_eq!(m.shed_requests, 0);
        assert_eq!(m.queue_depth, 0, "all replies collected; gauge must be balanced");
        assert_eq!(m.requests, 2);
        server.shutdown();
    }

    #[test]
    fn snapshot_identity_mismatch_is_rejected_at_start_and_reload() {
        let rt = Runtime::reference();
        let exe = rt.compile_role(GnnModel::Gcn, "tiny", Kind::Forward).unwrap();
        let graph = tiny_graph();
        // A snapshot recorded under a *different* sampler than the server
        // would use — resume rejects this, so serving must too.
        let snap = Checkpoint {
            step: 5,
            seed: 7,
            model: "gcn".into(),
            geometry: "tiny".into(),
            sampler: "NS(t=4, budgets=[9, 9])".into(),
            graph: graph_fingerprint(graph.snapshot().as_ref()),
            weights: WeightState::init_glorot(&exe.spec.weight_shapes, 3),
            adam: None,
        };
        let dir = std::env::temp_dir().join(format!("hpgnn-serve-id-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mismatch.ckpt");
        snap.save(&path).unwrap();

        let sampler: Arc<dyn Sampler> = Arc::new(NeighborSampler::new(4, vec![5, 3]));
        let err = Server::from_checkpoint(
            &rt,
            Arc::clone(&graph),
            Arc::clone(&sampler),
            ServeConfig::default(),
            &path,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("sampler"), "{err}");

        // Reload path: a running server must reject it too.
        let (_rt2, server) = start(ServeConfig::default());
        let err = server.reload_weights(&path).unwrap_err().to_string();
        assert!(err.contains("sampler"), "{err}");
        // A matching snapshot loads fine.
        let ok = Checkpoint { sampler: "NS(t=4, budgets=[5, 3])".into(), ..snap };
        let ok_path = dir.join("match.ckpt");
        ok.save(&ok_path).unwrap();
        server.reload_weights(&ok_path).unwrap();
        server.shutdown();
    }

    #[test]
    fn rejects_mismatched_weights() {
        let rt = Runtime::reference();
        let bad = WeightState { tensors: vec![(vec![2, 2], vec![0.0; 4])] };
        let err = Server::start(
            &rt,
            tiny_graph(),
            Arc::new(NeighborSampler::new(4, vec![5, 3])),
            ServeConfig::default(),
            bad,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("weight tensors"), "{err}");
    }

    #[test]
    fn subgraph_sampler_requests_fail_per_vertex_not_per_server() {
        use crate::sampler::subgraph::SubgraphSampler;
        let rt = Runtime::reference();
        let exe = rt.compile_role(GnnModel::Gcn, "ss_small", Kind::Forward).unwrap();
        let weights = WeightState::init_glorot(&exe.spec.weight_shapes, 3);
        let mut g = generator::with_min_degree(
            generator::rmat(400, 3200, Default::default(), 31),
            1,
            30,
        );
        g.feat_dim = 500;
        g.num_classes = 7;
        let cfg = ServeConfig {
            geometry: "ss_small".to_string(),
            overflow: EdgeOverflow::TruncateKeepSelf,
            ..ServeConfig::default()
        };
        let server = Server::start(
            &rt,
            DynamicGraph::from_graph(g),
            Arc::new(SubgraphSampler::new(64, 2)),
            cfg,
            weights,
        )
        .unwrap();
        let err = format!("{:#}", server.classify_one(3).unwrap_err());
        assert!(err.contains("target-directed"), "{err}");
        server.shutdown();
    }
}
