//! Versioned per-vertex logits cache.
//!
//! Repeat query vertices skip sampling + forward execution entirely.  The
//! cache is *versioned* against the server's weight state **and** graph
//! state: every entry is stamped with the `(weights_version,
//! graph_version)` pair it was computed under.  A weight reload
//! ([`LogitsCache::invalidate`]) bumps the weight version and an edge
//! ingest ([`LogitsCache::set_graph_version`]) advances the graph
//! version — stale entries miss (and are evicted lazily), so neither
//! hot-swapping a newer checkpoint nor mutating the graph mid-serve can
//! ever answer from the old model or the old topology.
//!
//! Eviction is **deterministic FIFO** over an insertion ring: at capacity
//! the oldest *first-inserted* key still resident is evicted.  The
//! previous policy ("remove whatever `HashMap::keys().next()` yields")
//! made the evicted key depend on hasher state, so two identical runs
//! could hold different residents — exactly the class of drift the D1
//! no-unordered-iteration lint rule now rejects in `serve/`.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::{lock_unpoisoned, Prediction};
use crate::graph::Vid;

struct Entry {
    version: u64,
    graph_version: u64,
    pred: Arc<Prediction>,
}

/// Map + insertion ring, guarded together: the ring orders eviction, the
/// map answers lookups.  The map is *never iterated* (D1).
#[derive(Default)]
struct Inner {
    entries: HashMap<Vid, Entry>,
    /// Keys in first-insertion order.  May briefly hold "ghost" keys
    /// whose entry was already removed (lazy stale eviction); the
    /// eviction loop pops and skips them.
    ring: VecDeque<Vid>,
}

/// Default entry cap — a weeks-long server queried across a large vertex
/// space must not grow cache memory without bound (same rationale as the
/// metrics sample window).
pub const DEFAULT_CACHE_CAPACITY: usize = 65_536;

/// Thread-safe vertex → prediction cache with weight-version stamping and
/// deterministic FIFO eviction.
pub struct LogitsCache {
    enabled: bool,
    capacity: usize,
    version: AtomicU64,
    graph_version: AtomicU64,
    inner: Mutex<Inner>,
}

impl LogitsCache {
    pub fn new(enabled: bool) -> LogitsCache {
        Self::with_capacity(enabled, DEFAULT_CACHE_CAPACITY)
    }

    pub fn with_capacity(enabled: bool, capacity: usize) -> LogitsCache {
        LogitsCache {
            enabled,
            capacity: capacity.max(1),
            version: AtomicU64::new(0),
            graph_version: AtomicU64::new(0),
            inner: Mutex::new(Inner::default()),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// The current weight version entries must match to hit.
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// The current graph version entries must match to hit.
    pub fn graph_version(&self) -> u64 {
        self.graph_version.load(Ordering::Acquire)
    }

    /// Record that the served graph advanced to `graph_version` (an edge
    /// ingest published a new snapshot).  Entries computed against older
    /// topology become stale and miss from then on; they are evicted
    /// lazily on access, like weight-stale entries.
    pub fn set_graph_version(&self, graph_version: u64) {
        // Monotonic max: a racing older snapshot must not roll the cache
        // back to accepting entries from a superseded topology.
        self.graph_version.fetch_max(graph_version, Ordering::AcqRel);
    }

    /// Current-`(weights, graph)`-version hit for `v`, if any.  Stale
    /// entries are evicted (their ring slot becomes a ghost, skipped at
    /// eviction time).
    pub fn get(&self, v: Vid) -> Option<Arc<Prediction>> {
        if !self.enabled {
            return None;
        }
        let mut inner = lock_unpoisoned(&self.inner);
        let current = self.version.load(Ordering::Acquire);
        let current_g = self.graph_version.load(Ordering::Acquire);
        let stale = match inner.entries.get(&v) {
            Some(e) if e.version == current && e.graph_version == current_g => {
                return Some(Arc::clone(&e.pred));
            }
            Some(_) => true,
            None => false,
        };
        if stale {
            inner.entries.remove(&v);
        }
        None
    }

    /// Insert a prediction computed under weight `version` and graph
    /// `graph_version`.  Dropped when the cache has moved on in either
    /// dimension (a reload or ingest raced the computation) — a stale
    /// result must never be readable at the current version pair.  At
    /// capacity the ring's oldest resident key is evicted first:
    /// deterministic FIFO, so identical request streams leave identical
    /// residents.
    pub fn put(&self, version: u64, graph_version: u64, pred: Arc<Prediction>) {
        if !self.enabled {
            return;
        }
        let mut inner = lock_unpoisoned(&self.inner);
        if self.version.load(Ordering::Acquire) != version
            || self.graph_version.load(Ordering::Acquire) != graph_version
        {
            return;
        }
        let fresh = !inner.entries.contains_key(&pred.vertex);
        if fresh {
            while inner.entries.len() >= self.capacity {
                match inner.ring.pop_front() {
                    // Ghosts (keys already lazily evicted as stale) just
                    // pop; a resident key is the FIFO victim.
                    Some(old) => {
                        inner.entries.remove(&old);
                    }
                    None => break,
                }
            }
            inner.ring.push_back(pred.vertex);
        }
        // Re-inserting a resident key refreshes the value in place and
        // keeps its original ring position (first-insertion FIFO).
        inner.entries.insert(pred.vertex, Entry { version, graph_version, pred });
    }

    /// Bump the weight version and drop every entry (map and ring);
    /// returns the new version (what freshly-computed predictions must be
    /// stamped with).
    pub fn invalidate(&self) -> u64 {
        let mut inner = lock_unpoisoned(&self.inner);
        let v = self.version.fetch_add(1, Ordering::AcqRel) + 1;
        inner.entries.clear();
        inner.ring.clear();
        v
    }

    /// Number of live entries (any version; stale ones evict on access).
    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.inner).entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pred(v: Vid) -> Arc<Prediction> {
        Arc::new(Prediction { vertex: v, label: Some(1), logits: vec![0.0, 1.0] })
    }

    #[test]
    fn hit_after_put_at_current_version() {
        let c = LogitsCache::new(true);
        assert!(c.get(3).is_none());
        c.put(c.version(), c.graph_version(), pred(3));
        assert_eq!(c.get(3).unwrap().vertex, 3);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn invalidate_evicts_and_rejects_stale_puts() {
        let c = LogitsCache::new(true);
        let v0 = c.version();
        let g0 = c.graph_version();
        c.put(v0, g0, pred(1));
        let v1 = c.invalidate();
        assert_eq!(v1, v0 + 1);
        assert!(c.get(1).is_none(), "entry survived invalidation");
        // A computation that started before the reload finished cannot
        // publish under the new version.
        c.put(v0, g0, pred(2));
        assert!(c.get(2).is_none());
        // The new version works.
        c.put(v1, g0, pred(2));
        assert!(c.get(2).is_some());
    }

    #[test]
    fn graph_version_advance_hides_stale_topology() {
        let c = LogitsCache::new(true);
        let v = c.version();
        let g0 = c.graph_version();
        c.put(v, g0, pred(5));
        assert!(c.get(5).is_some());
        // An edge ingest published snapshot g0+1: entries computed against
        // the old topology must miss from then on.
        c.set_graph_version(g0 + 1);
        assert_eq!(c.graph_version(), g0 + 1);
        assert!(c.get(5).is_none(), "stale-topology entry served");
        // A computation that pinned the old snapshot cannot publish.
        c.put(v, g0, pred(6));
        assert!(c.get(6).is_none());
        // Fresh-snapshot results work, and the version is monotonic: a
        // racing older snapshot cannot roll it back.
        c.put(v, g0 + 1, pred(6));
        assert!(c.get(6).is_some());
        c.set_graph_version(g0);
        assert_eq!(c.graph_version(), g0 + 1);
        assert!(c.get(6).is_some());
    }

    #[test]
    fn capacity_bounds_the_entry_count() {
        let c = LogitsCache::with_capacity(true, 4);
        let v = c.version();
        let g = c.graph_version();
        for i in 0..20 {
            c.put(v, g, pred(i));
        }
        assert_eq!(c.len(), 4, "cache must not grow past its capacity");
        // Re-inserting an existing key does not evict anything.
        let resident: Vec<Vid> = (0..20).filter(|&i| c.get(i).is_some()).collect();
        assert_eq!(resident.len(), 4);
        c.put(v, g, pred(resident[0]));
        assert_eq!(c.len(), 4);
        assert!(c.get(resident[0]).is_some());
    }

    #[test]
    fn eviction_order_is_deterministic_fifo() {
        let c = LogitsCache::with_capacity(true, 3);
        let v = c.version();
        let g = c.graph_version();
        for i in [10u32, 20, 30] {
            c.put(v, g, pred(i));
        }
        // Re-inserting 10 keeps its original (oldest) ring position.
        c.put(v, g, pred(10));
        // Fourth distinct key evicts the first-inserted key: 10.
        c.put(v, g, pred(40));
        assert!(c.get(10).is_none(), "FIFO must evict the oldest insertion");
        assert!(c.get(20).is_some() && c.get(30).is_some() && c.get(40).is_some());
        // Next eviction is 20, then 30 — the full order is pinned.
        c.put(v, g, pred(50));
        assert!(c.get(20).is_none());
        assert!(c.get(30).is_some() && c.get(40).is_some() && c.get(50).is_some());
        c.put(v, g, pred(60));
        assert!(c.get(30).is_none());
        let resident: Vec<Vid> = [40u32, 50, 60]
            .iter()
            .copied()
            .filter(|&i| c.get(i).is_some())
            .collect();
        assert_eq!(resident, vec![40, 50, 60]);
    }

    #[test]
    fn disabled_cache_never_stores() {
        let c = LogitsCache::new(false);
        c.put(c.version(), c.graph_version(), pred(9));
        assert!(c.get(9).is_none());
        assert!(c.is_empty());
    }
}
