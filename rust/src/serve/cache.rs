//! Versioned per-vertex logits cache.
//!
//! Repeat query vertices skip sampling + forward execution entirely.  The
//! cache is *versioned* against the server's weight state: every entry is
//! stamped with the weight version it was computed under, and a weight
//! reload ([`LogitsCache::invalidate`]) bumps the version — stale entries
//! miss (and are evicted lazily), so hot-swapping a newer checkpoint
//! mid-serve can never answer from the old model.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::Prediction;
use crate::graph::Vid;

struct Entry {
    version: u64,
    pred: Arc<Prediction>,
}

/// Default entry cap — a weeks-long server queried across a large vertex
/// space must not grow cache memory without bound (same rationale as the
/// metrics sample window).
pub const DEFAULT_CACHE_CAPACITY: usize = 65_536;

/// Thread-safe vertex → prediction cache with weight-version stamping.
pub struct LogitsCache {
    enabled: bool,
    capacity: usize,
    version: AtomicU64,
    map: Mutex<HashMap<Vid, Entry>>,
}

impl LogitsCache {
    pub fn new(enabled: bool) -> LogitsCache {
        Self::with_capacity(enabled, DEFAULT_CACHE_CAPACITY)
    }

    pub fn with_capacity(enabled: bool, capacity: usize) -> LogitsCache {
        LogitsCache {
            enabled,
            capacity: capacity.max(1),
            version: AtomicU64::new(0),
            map: Mutex::new(HashMap::new()),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// The current weight version entries must match to hit.
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Current-version hit for `v`, if any.  Stale entries are evicted.
    pub fn get(&self, v: Vid) -> Option<Arc<Prediction>> {
        if !self.enabled {
            return None;
        }
        let mut map = self.map.lock().unwrap();
        let current = self.version.load(Ordering::Acquire);
        let stale = match map.get(&v) {
            Some(e) if e.version == current => return Some(Arc::clone(&e.pred)),
            Some(_) => true,
            None => false,
        };
        if stale {
            map.remove(&v);
        }
        None
    }

    /// Insert a prediction computed under weight `version`.  Dropped when
    /// the cache has moved on (a reload raced the computation) — a stale
    /// result must never be readable at the current version.  At capacity
    /// an arbitrary entry is evicted first (O(1); repeat-vertex workloads
    /// re-warm hot entries on their next query).
    pub fn put(&self, version: u64, pred: Arc<Prediction>) {
        if !self.enabled {
            return;
        }
        let mut map = self.map.lock().unwrap();
        if self.version.load(Ordering::Acquire) != version {
            return;
        }
        if map.len() >= self.capacity && !map.contains_key(&pred.vertex) {
            if let Some(&evict) = map.keys().next() {
                map.remove(&evict);
            }
        }
        map.insert(pred.vertex, Entry { version, pred });
    }

    /// Bump the weight version and drop every entry; returns the new
    /// version (what freshly-computed predictions must be stamped with).
    pub fn invalidate(&self) -> u64 {
        let mut map = self.map.lock().unwrap();
        let v = self.version.fetch_add(1, Ordering::AcqRel) + 1;
        map.clear();
        v
    }

    /// Number of live entries (any version; stale ones evict on access).
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pred(v: Vid) -> Arc<Prediction> {
        Arc::new(Prediction { vertex: v, label: Some(1), logits: vec![0.0, 1.0] })
    }

    #[test]
    fn hit_after_put_at_current_version() {
        let c = LogitsCache::new(true);
        assert!(c.get(3).is_none());
        c.put(c.version(), pred(3));
        assert_eq!(c.get(3).unwrap().vertex, 3);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn invalidate_evicts_and_rejects_stale_puts() {
        let c = LogitsCache::new(true);
        let v0 = c.version();
        c.put(v0, pred(1));
        let v1 = c.invalidate();
        assert_eq!(v1, v0 + 1);
        assert!(c.get(1).is_none(), "entry survived invalidation");
        // A computation that started before the reload finished cannot
        // publish under the new version.
        c.put(v0, pred(2));
        assert!(c.get(2).is_none());
        // The new version works.
        c.put(v1, pred(2));
        assert!(c.get(2).is_some());
    }

    #[test]
    fn capacity_bounds_the_entry_count() {
        let c = LogitsCache::with_capacity(true, 4);
        let v = c.version();
        for i in 0..20 {
            c.put(v, pred(i));
        }
        assert_eq!(c.len(), 4, "cache must not grow past its capacity");
        // Re-inserting an existing key does not evict anything.
        let resident: Vec<Vid> = (0..20).filter(|&i| c.get(i).is_some()).collect();
        assert_eq!(resident.len(), 4);
        c.put(v, pred(resident[0]));
        assert_eq!(c.len(), 4);
        assert!(c.get(resident[0]).is_some());
    }

    #[test]
    fn disabled_cache_never_stores() {
        let c = LogitsCache::new(false);
        c.put(c.version(), pred(9));
        assert!(c.get(9).is_none());
        assert!(c.is_empty());
    }
}
