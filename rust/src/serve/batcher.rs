//! Dynamic micro-batcher: coalesce queued requests into micro-batches.
//!
//! One batcher thread drains the bounded request queue and forms batches
//! under two limits, whichever trips first:
//!
//! * **size** — up to `max_batch` waiting vertices are coalesced (an
//!   oversized submission simply spans several batches: requests are
//!   queued per vertex, so splitting is free);
//! * **deadline** — once the first vertex of a batch is in hand, at most
//!   `max_wait` passes before the batch ships, full or not (bounds the
//!   queueing latency a lone request pays for the *possibility* of
//!   coalescing).
//!
//! `max_batch == 1` degenerates to pass-through dispatch (the load
//! generator's unbatched baseline).

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::graph::Vid;
use crate::util::stats::Timer;

use super::metrics::ServeMetrics;
use super::Prediction;

/// Reply channel of one request: `(slot index, prediction or error)`.
pub(crate) type ReplySender = mpsc::Sender<(usize, anyhow::Result<std::sync::Arc<Prediction>>)>;

/// One queued "classify vertex v" work unit.  `reply` carries the
/// requester's slot index so multi-vertex requests reassemble in order.
pub(crate) struct WorkItem {
    pub vertex: Vid,
    pub idx: usize,
    pub reply: ReplySender,
    /// Started at enqueue; the worker reads it at pickup to record the
    /// queue-wait distribution.
    pub enqueued: Timer,
}

/// Batcher thread body: runs until every request sender is gone, then
/// flushes what is queued and shuts the worker channel down by dropping
/// `tx` (which the caller moved in).
pub(crate) fn run_batcher(
    rx: mpsc::Receiver<WorkItem>,
    tx: mpsc::SyncSender<Vec<WorkItem>>,
    max_batch: usize,
    max_wait: Duration,
    metrics: Arc<ServeMetrics>,
) {
    let max_batch = max_batch.max(1);
    loop {
        // Block for the batch's first item; a closed queue means the
        // server is shutting down and everything queued was drained.
        let first = match rx.recv() {
            Ok(item) => item,
            Err(_) => return,
        };
        let sp = crate::obs::span("serve", "coalesce");
        let window = Timer::start();
        let mut batch = vec![first];
        let deadline = Instant::now() + max_wait;
        let mut disconnected = false;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(item) => batch.push(item),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        metrics.record_coalesce(window.secs());
        drop(sp);
        if tx.send(batch).is_err() {
            return; // workers are gone; nothing left to serve
        }
        if disconnected {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn items(
        n: usize,
    ) -> (Vec<WorkItem>, mpsc::Receiver<(usize, anyhow::Result<Arc<Prediction>>)>) {
        let (reply, reply_rx) = mpsc::channel();
        let v = (0..n)
            .map(|i| WorkItem {
                vertex: i as Vid,
                idx: i,
                reply: reply.clone(),
                enqueued: Timer::start(),
            })
            .collect();
        (v, reply_rx)
    }

    /// Run the batcher over a pre-filled, already-closed queue and return
    /// the batch sizes it formed.
    fn batch_sizes(n: usize, max_batch: usize, max_wait: Duration) -> Vec<usize> {
        let (tx, rx) = mpsc::sync_channel(n.max(1));
        let (work, _replies) = items(n);
        for item in work {
            tx.send(item).unwrap();
        }
        drop(tx);
        let (btx, brx) = mpsc::sync_channel(n.max(1));
        run_batcher(rx, btx, max_batch, max_wait, Arc::new(ServeMetrics::default()));
        brx.into_iter().map(|b| b.len()).collect()
    }

    #[test]
    fn oversized_submission_splits_across_batches() {
        // 10 queued vertices, capacity 4: batches of 4, 4, 2.
        assert_eq!(batch_sizes(10, 4, Duration::from_millis(50)), vec![4, 4, 2]);
    }

    #[test]
    fn max_batch_one_is_pass_through() {
        assert_eq!(batch_sizes(5, 1, Duration::from_millis(50)), vec![1; 5]);
    }

    #[test]
    fn full_queue_coalesces_into_one_batch() {
        assert_eq!(batch_sizes(7, 64, Duration::from_millis(50)), vec![7]);
    }

    #[test]
    fn deadline_ships_a_partial_batch() {
        // A live queue that stays open: the batcher must ship the lone
        // item once max_wait elapses instead of waiting for a full batch.
        let (tx, rx) = mpsc::sync_channel(4);
        let (work, _replies) = items(1);
        for item in work {
            tx.send(item).unwrap();
        }
        let (btx, brx) = mpsc::sync_channel(4);
        let h = std::thread::spawn(move || {
            run_batcher(rx, btx, 64, Duration::from_millis(10), Arc::new(ServeMetrics::default()));
        });
        let t = Instant::now();
        let batch = brx.recv().expect("batch before shutdown");
        assert_eq!(batch.len(), 1);
        assert!(t.elapsed() < Duration::from_secs(5), "deadline never fired");
        drop(tx); // close the queue so the batcher exits
        h.join().unwrap();
    }

    #[test]
    fn zero_wait_still_ships_the_first_item() {
        // max_wait = 0: every batch is whatever was instantaneously
        // available — at least the first item.
        let sizes = batch_sizes(3, 8, Duration::ZERO);
        assert_eq!(sizes.iter().sum::<usize>(), 3);
        assert!(!sizes.is_empty());
    }
}
