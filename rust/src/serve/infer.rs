//! Shared forward-inference helper: the sample → layout → pad → forward →
//! logits sequence used by *both* the evaluator
//! ([`crate::coordinator::eval::evaluate_with`]) and the serving worker
//! pool — one implementation, so eval and serve cannot drift.
//!
//! # The per-target determinism invariant
//!
//! Serving coalesces single-vertex requests into micro-batches whose
//! composition depends on arrival timing, yet served logits must be
//! bit-identical regardless of which other vertices share a batch.  The
//! invariant holds because merged batches are built as a *concatenation of
//! independently-sampled per-target subtrees* ([`merge_indexed`]): each
//! subtree occupies its own contiguous position block, every row-level
//! kernel (matmul, aggregate, self-gather) touches only rows wired to that
//! block, and the edge order within a block is fixed by the subtree's own
//! RMT/RRA layout.  Batch composition therefore changes *which rows exist*,
//! never the value or float accumulation order of any existing row — the
//! serving-path extension of the repo's kernel determinism invariant
//! (tests: `serve_parity.rs`).

use crate::coordinator::trainer::{TrainConfig, ValueFn};
use crate::graph::{datasets, GraphAccess};
use crate::layout::pad::{pad, EdgeOverflow};
use crate::layout::{index_batch, IndexedBatch, IndexedLayer, LayoutOptions};
use crate::runtime::{inputs, Executable, Kind, WeightState};
use crate::sampler::values::{attach_values, GnnModel};
use crate::sampler::MiniBatch;

/// Everything forward inference needs besides the batch itself.  Built
/// from a [`TrainConfig`] so evaluation and serving see exactly the
/// training-time edge values, layout, overflow policy and feature stream.
#[derive(Clone)]
pub struct InferOptions {
    pub model: GnnModel,
    pub layout: LayoutOptions,
    pub overflow: EdgeOverflow,
    /// Feature/label synthesis seed — must match training, or the served
    /// model sees inputs from a different distribution than it learned.
    pub seed: u64,
    /// Custom Scatter UDF; `None` uses the model's standard edge values.
    pub value_fn: Option<ValueFn>,
}

impl InferOptions {
    pub fn from_train(cfg: &TrainConfig) -> InferOptions {
        InferOptions {
            model: cfg.model,
            layout: cfg.layout,
            overflow: cfg.overflow,
            seed: cfg.seed,
            value_fn: cfg.value_fn.clone(),
        }
    }
}

impl std::fmt::Debug for InferOptions {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("InferOptions")
            .field("model", &self.model)
            .field("layout", &self.layout)
            .field("overflow", &self.overflow)
            .field("seed", &self.seed)
            .field("custom_values", &self.value_fn.is_some())
            .finish()
    }
}

/// Attach edge values and run the layout engine — the positional form of
/// a global-id mini-batch under `opts`.
pub fn index_minibatch(graph: &dyn GraphAccess, mb: &MiniBatch, opts: &InferOptions) -> IndexedBatch {
    let values = match &opts.value_fn {
        Some(f) => f(graph, mb),
        None => attach_values(graph, mb, opts.model),
    };
    index_batch(mb, &values, opts.layout)
}

/// Output of one forward execution, trimmed to the real (unpadded)
/// target vertices.
#[derive(Debug, Clone)]
pub struct Inference {
    /// Row-major `real_targets × num_classes` logits.
    pub logits: Vec<f32>,
    /// Synthetic ground-truth label per real target (what the evaluator
    /// scores against).
    pub labels: Vec<i32>,
    pub real_targets: usize,
    pub num_classes: usize,
}

impl Inference {
    /// Logits row of target `i`.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.logits[i * self.num_classes..(i + 1) * self.num_classes]
    }
}

/// Run the forward artifact over one positional batch: synthesize the
/// target labels and `B^0` feature rows (the per-vertex deterministic
/// streams training used), pad to the artifact geometry, execute, and
/// read the logits back.
pub fn infer_indexed(
    exe: &Executable,
    graph: &dyn GraphAccess,
    opts: &InferOptions,
    weights: &WeightState,
    ib: &IndexedBatch,
) -> anyhow::Result<Inference> {
    anyhow::ensure!(
        exe.spec.kind == Kind::Forward,
        "inference wants a Forward executable, got {:?}",
        exe.spec.kind
    );
    let geom = &exe.spec.geometry;
    let num_classes = geom.num_classes();
    let feat_dim = geom.f[0];
    let ll = ib.num_layers();

    let target_labels =
        datasets::synth_labels(&ib.layers[ll], num_classes, opts.seed, graph.num_vertices());
    let padded = pad(ib, &target_labels, geom, opts.overflow)?;
    let l0_labels =
        datasets::synth_labels(&ib.layers[0], num_classes, opts.seed, graph.num_vertices());
    let real =
        datasets::synth_features(&ib.layers[0], &l0_labels, feat_dim, num_classes, opts.seed);
    let features = inputs::pad_features(&real, ib.layers[0].len(), geom.b[0], feat_dim);

    let lits = inputs::build_inputs(&exe.spec, &padded, &features, weights, 0.0)?;
    let outs = exe.run(&lits)?;
    let logits = outs[0]
        .f32_data()
        .map_err(|e| anyhow::anyhow!("logits readback: {e}"))?;

    let real_targets = padded.real_b[ll];
    Ok(Inference {
        logits: logits[..real_targets * num_classes].to_vec(),
        labels: padded.labels[..real_targets].to_vec(),
        real_targets,
        num_classes,
    })
}

/// Argmax class of one logits row via a total order; `None` when the row
/// contains a NaN (a diverged model must not crash or win ties).
pub fn argmax(row: &[f32]) -> Option<usize> {
    if row.is_empty() || row.iter().any(|x| x.is_nan()) {
        return None;
    }
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
}

/// Concatenate positional batches into one, offsetting each part's
/// positions by the vertices already placed.  Part boundaries stay
/// contiguous, so every part's rows and intra-part edge order are
/// preserved verbatim — the mechanism behind the per-target determinism
/// invariant (module docs).  Accepts owned batches or references (the
/// serving hot path merges straight from borrowed subtrees, no copies).
pub fn merge_indexed<B: std::borrow::Borrow<IndexedBatch>>(parts: &[B]) -> IndexedBatch {
    assert!(!parts.is_empty(), "merge_indexed: no parts");
    let ll = parts[0].borrow().num_layers();
    let opts = parts[0].borrow().opts;
    let mut layers: Vec<Vec<crate::graph::Vid>> = vec![Vec::new(); ll + 1];
    let mut layer_edges: Vec<IndexedLayer> = (0..ll)
        .map(|_| IndexedLayer {
            src: Vec::new(),
            dst: Vec::new(),
            val: Vec::new(),
            self_idx: Vec::new(),
        })
        .collect();
    for p in parts {
        let p = p.borrow();
        assert_eq!(p.num_layers(), ll, "merge_indexed: layer-count mismatch");
        for l in 0..ll {
            let src_off = layers[l].len() as u32;
            let dst_off = layers[l + 1].len() as u32;
            let le = &p.layer_edges[l];
            layer_edges[l].src.extend(le.src.iter().map(|&x| x + src_off));
            layer_edges[l].dst.extend(le.dst.iter().map(|&x| x + dst_off));
            layer_edges[l].val.extend_from_slice(&le.val);
            layer_edges[l]
                .self_idx
                .extend(le.self_idx.iter().map(|&x| x + src_off));
        }
        for l in 0..=ll {
            layers[l].extend_from_slice(&p.layers[l]);
        }
    }
    IndexedBatch { layers, layer_edges, opts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generator, Graph};
    use crate::runtime::Runtime;
    use crate::sampler::neighbor::NeighborSampler;
    use crate::sampler::Sampler;
    use crate::util::rng::Pcg64;

    fn setup() -> (Runtime, Graph, NeighborSampler, InferOptions) {
        let mut g = generator::with_min_degree(
            generator::rmat(400, 3200, Default::default(), 5),
            1,
            6,
        );
        g.feat_dim = 16;
        g.num_classes = 4;
        let sampler = NeighborSampler::new(4, vec![5, 3]);
        let opts = InferOptions::from_train(&TrainConfig::quick(GnnModel::Gcn, "tiny", 0));
        (Runtime::reference(), g, sampler, opts)
    }

    #[test]
    fn infer_indexed_returns_one_row_per_real_target() {
        let (rt, g, sampler, opts) = setup();
        let exe = rt.compile_role(opts.model, "tiny", Kind::Forward).unwrap();
        let weights = WeightState::init_glorot(&exe.spec.weight_shapes, 3);
        let mb = sampler.sample(&g, &mut Pcg64::seed_from_u64(8));
        let ib = index_minibatch(&g, &mb, &opts);
        let inf = infer_indexed(&exe, &g, &opts, &weights, &ib).unwrap();
        assert_eq!(inf.real_targets, mb.layers[2].len());
        assert_eq!(inf.num_classes, 4);
        assert_eq!(inf.logits.len(), inf.real_targets * 4);
        assert_eq!(inf.labels.len(), inf.real_targets);
        assert!(inf.logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn merged_subtree_logits_match_solo_inference_bitwise() {
        // The serving invariant in miniature: each vertex inferred alone
        // equals the same vertex inferred inside a coalesced batch.
        let (rt, g, sampler, opts) = setup();
        let exe = rt.compile_role(opts.model, "tiny", Kind::Forward).unwrap();
        let weights = WeightState::init_glorot(&exe.spec.weight_shapes, 3);
        let verts = [7u32, 91, 230];
        let parts: Vec<IndexedBatch> = verts
            .iter()
            .map(|&v| {
                let mb = sampler
                    .sample_targets(&g, &[v], &mut crate::serve::vertex_rng(17, v))
                    .unwrap();
                index_minibatch(&g, &mb, &opts)
            })
            .collect();
        let solo: Vec<Inference> = parts
            .iter()
            .map(|p| infer_indexed(&exe, &g, &opts, &weights, p).unwrap())
            .collect();
        let merged = merge_indexed(&parts);
        let joint = infer_indexed(&exe, &g, &opts, &weights, &merged).unwrap();
        assert_eq!(joint.real_targets, verts.len());
        for (j, s) in solo.iter().enumerate() {
            assert_eq!(joint.row(j), s.row(0), "vertex {} drifted when batched", verts[j]);
        }
        // A different merge order still reproduces each row bitwise.
        let rev: Vec<IndexedBatch> = parts.iter().rev().cloned().collect();
        let joint_rev = infer_indexed(&exe, &g, &opts, &weights, &merge_indexed(&rev)).unwrap();
        for (j, s) in solo.iter().rev().enumerate() {
            assert_eq!(joint_rev.row(j), s.row(0));
        }
    }

    #[test]
    fn argmax_total_order_and_nan_handling() {
        assert_eq!(argmax(&[0.0, 3.0, 1.0]), Some(1));
        assert_eq!(argmax(&[-1.0, -5.0]), Some(0));
        assert_eq!(argmax(&[1.0, f32::NAN]), None);
        assert_eq!(argmax(&[]), None);
        // Ties resolve to the last maximal element (std `max_by`
        // semantics — the evaluator's historical behavior).
        assert_eq!(argmax(&[2.0, 2.0, 1.0]), Some(1));
    }
}
