//! Inference serving subsystem — the train→deploy half of the loop.
//!
//! Training (PRs 1–3) produces `HPGNNW01`/`HPGNNS01` checkpoints; this
//! module answers "classify vertex v" requests from them:
//!
//! ```text
//! classify(v…) ──► bounded request queue ──► micro-batcher (size/deadline)
//!                                                   │ coalesced batches
//!                       ┌───────────────────────────┴───────────┐
//!                       ▼                                       ▼
//!                worker 0 (forward Executable)  …  worker N-1 (replica)
//!                sample_targets → layout → pack → pad → forward → argmax
//!                       │                                       │
//!                       └────────► versioned logits cache ◄─────┘
//! ```
//!
//! * [`infer`] — the shared sample→pad→forward→argmax helper (also the
//!   evaluator's implementation, so eval and serve cannot drift) and the
//!   per-target determinism invariant that makes served logits
//!   bit-identical across worker counts and coalescing patterns.
//! * [`batcher`] — dynamic micro-batching: coalesce up to the geometry's
//!   target capacity or a `max_wait` deadline, whichever first; oversized
//!   submissions split across batches.
//! * [`server`] — the worker pool of per-worker forward executables,
//!   weight hot-swap, graceful shutdown.
//! * [`cache`] — versioned per-vertex logits cache, invalidated on
//!   weight reload.
//! * [`metrics`] — all-time counters, a queue-depth gauge, and bounded
//!   fixed-bucket histograms (latency/occupancy/queue-wait/coalesce) on
//!   the [`crate::obs`] registry; rendered as Prometheus text on
//!   `GET /metrics` and as the stable JSON document on `/metrics.json`.
//!
//! Entry points: [`Server::start`] /
//! [`crate::api::GeneratedDesign::server`] / the `hp-gnn serve` CLI.

pub mod batcher;
pub mod cache;
pub mod infer;
pub mod metrics;
pub mod server;

pub use metrics::{MetricsSnapshot, ServeMetrics};
pub use server::{ServeConfig, Server};

use crate::graph::Vid;
use crate::util::rng::{Pcg64, SplitMix64};

// The poison-recovering lock helpers used to live here; the training
// coordinator needs them too, so they moved to [`crate::util::sync`]
// (rationale in that module's docs).  Re-exported so the serving
// subsystem keeps its historical import path.
pub(crate) use crate::util::sync::{lock_unpoisoned, read_unpoisoned, write_unpoisoned};

/// The answer to one "classify vertex v" request.
#[derive(Debug, Clone, PartialEq)]
pub struct Prediction {
    pub vertex: Vid,
    /// Argmax class, `None` when the logits contain a NaN (diverged
    /// model) — mirrors the evaluator's NaN policy.
    pub label: Option<usize>,
    /// The raw logits row (`num_classes` entries).
    pub logits: Vec<f32>,
}

/// Inference-time sampling RNG for one query vertex: a pure function of
/// `(seed, v)`, whitened so neighboring vertex ids land in unrelated
/// streams.  Per-vertex purity is what makes served results cacheable and
/// independent of batch composition (see [`infer`]'s module docs).
pub fn vertex_rng(seed: u64, v: Vid) -> Pcg64 {
    let mix = SplitMix64 { state: (v as u64) ^ 0x94d0_49bb_1331_11eb }.next();
    Pcg64::seed_from_u64(seed ^ mix)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The poisoning-recovery behavior itself is covered where the helpers
    // now live: `util::sync::tests::lock_helpers_recover_from_poisoning`.

    #[test]
    fn vertex_rng_is_pure_and_vertex_distinct() {
        let a: Vec<u64> = (0..3).map(|_| vertex_rng(7, 42).next_u64()).collect();
        assert!(a.windows(2).all(|w| w[0] == w[1]), "not pure: {a:?}");
        assert_ne!(vertex_rng(7, 42).next_u64(), vertex_rng(7, 43).next_u64());
        assert_ne!(vertex_rng(7, 42).next_u64(), vertex_rng(8, 42).next_u64());
    }
}
