//! Per-request serving metrics: throughput counters plus latency
//! percentiles on [`Summary`].
//!
//! Distribution metrics (latency, occupancy, execution time) are kept in
//! a bounded ring of the most recent [`SAMPLE_WINDOW`] samples: a server
//! that runs for weeks must not grow its metrics memory with every
//! request, and percentile snapshots must not sort an ever-growing
//! vector.  Counters are all-time.
//!
//! An idle metrics window has no samples; percentiles come back as
//! `None` (and JSON `null`) rather than crashing the server — the reason
//! `Summary::percentile` returns `Option`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::lock_unpoisoned;
use crate::util::json::Json;
use crate::util::stats::Summary;

/// Retained samples per distribution metric (ring buffer bound).
pub const SAMPLE_WINDOW: usize = 4096;

/// Bounded sample ring: the last [`SAMPLE_WINDOW`] observations.
#[derive(Default)]
struct SampleWindow {
    buf: Vec<f64>,
    next: usize,
}

impl SampleWindow {
    fn add(&mut self, x: f64) {
        if self.buf.len() < SAMPLE_WINDOW {
            self.buf.push(x);
        } else {
            self.buf[self.next] = x;
            self.next = (self.next + 1) % SAMPLE_WINDOW;
        }
    }

    /// The window's contents as a [`Summary`] (order is irrelevant to
    /// mean/percentiles).
    fn summary(&self) -> Summary {
        let mut s = Summary::new();
        for &x in &self.buf {
            s.add(x);
        }
        s
    }
}

/// Shared mutable metrics the server and its workers update.
#[derive(Default)]
pub struct ServeMetrics {
    requests: AtomicU64,
    vertices: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    /// Executed forward micro-batches (kernel invocations).
    batches: AtomicU64,
    /// Requests rejected at admission because the bounded work queue was
    /// full (answered `429 Too Many Requests` over HTTP).
    shed: AtomicU64,
    /// Work items currently in flight: enqueued on the bounded queue or
    /// executing, reply not yet collected.  A gauge, not a counter.
    depth: AtomicU64,
    /// Per-request wall latency, seconds (enqueue → last reply).
    latency: Mutex<SampleWindow>,
    /// Real target vertices per executed micro-batch.
    occupancy: Mutex<SampleWindow>,
    /// Forward execution time per micro-batch, seconds.
    exec: Mutex<SampleWindow>,
}

impl ServeMetrics {
    pub fn record_request(&self, vertices: usize, latency_s: f64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.vertices.fetch_add(vertices as u64, Ordering::Relaxed);
        lock_unpoisoned(&self.latency).add(latency_s);
    }

    pub fn record_cache(&self, hits: usize, misses: usize) {
        self.cache_hits.fetch_add(hits as u64, Ordering::Relaxed);
        self.cache_misses.fetch_add(misses as u64, Ordering::Relaxed);
    }

    /// One request shed at admission (bounded queue full).
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// `n` work items entered the pipeline (enqueued on the queue).
    pub fn depth_add(&self, n: usize) {
        self.depth.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// `n` work items left the pipeline (replies collected).  Callers
    /// keep add/sub balanced; the gauge never goes negative.
    pub fn depth_sub(&self, n: usize) {
        self.depth.fetch_sub(n as u64, Ordering::Relaxed);
    }

    pub fn record_batch(&self, occupancy: usize, exec_s: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        lock_unpoisoned(&self.occupancy).add(occupancy as f64);
        lock_unpoisoned(&self.exec).add(exec_s);
    }

    /// Consistent point-in-time copy for reporting.  Counters are
    /// all-time; the distribution summaries cover the most recent
    /// [`SAMPLE_WINDOW`] samples of each metric.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let latency = lock_unpoisoned(&self.latency).summary();
        let occupancy = lock_unpoisoned(&self.occupancy).summary();
        let exec = lock_unpoisoned(&self.exec).summary();
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            vertices: self.vertices.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            shed_requests: self.shed.load(Ordering::Relaxed),
            queue_depth: self.depth.load(Ordering::Relaxed),
            latency,
            occupancy,
            exec,
        }
    }
}

/// Frozen metrics view with derived percentiles.  The `Summary` fields
/// cover the most recent [`SAMPLE_WINDOW`] samples of each metric.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub vertices: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub batches: u64,
    /// Requests rejected at admission (all-time counter).
    pub shed_requests: u64,
    /// In-flight work items at snapshot time (gauge).
    pub queue_depth: u64,
    pub latency: Summary,
    pub occupancy: Summary,
    pub exec: Summary,
}

fn opt_num(x: Option<f64>) -> Json {
    x.map(Json::num).unwrap_or(Json::Null)
}

impl MetricsSnapshot {
    pub fn latency_p50_s(&self) -> Option<f64> {
        self.latency.percentile(50.0)
    }

    pub fn latency_p95_s(&self) -> Option<f64> {
        self.latency.percentile(95.0)
    }

    pub fn latency_p99_s(&self) -> Option<f64> {
        self.latency.percentile(99.0)
    }

    /// Mean real targets per executed micro-batch (`None` when idle) —
    /// how well the micro-batcher is coalescing.
    pub fn mean_occupancy(&self) -> Option<f64> {
        (self.occupancy.count() > 0).then(|| self.occupancy.mean())
    }

    /// JSON dump (idle windows report `null` percentiles, never panic).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::num(self.requests as f64)),
            ("vertices", Json::num(self.vertices as f64)),
            ("cache_hits", Json::num(self.cache_hits as f64)),
            ("cache_misses", Json::num(self.cache_misses as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("shed_requests", Json::num(self.shed_requests as f64)),
            ("queue_depth", Json::num(self.queue_depth as f64)),
            (
                "latency_s",
                Json::obj(vec![
                    ("count", Json::num(self.latency.count() as f64)),
                    (
                        "mean",
                        opt_num((self.latency.count() > 0).then(|| self.latency.mean())),
                    ),
                    ("p50", opt_num(self.latency_p50_s())),
                    ("p95", opt_num(self.latency_p95_s())),
                    ("p99", opt_num(self.latency_p99_s())),
                ]),
            ),
            ("mean_batch_occupancy", opt_num(self.mean_occupancy())),
            (
                "exec_mean_s",
                opt_num((self.exec.count() > 0).then(|| self.exec.mean())),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_snapshot_reports_null_percentiles_without_panicking() {
        let m = ServeMetrics::default();
        let snap = m.snapshot();
        assert_eq!(snap.requests, 0);
        assert_eq!(snap.shed_requests, 0);
        assert_eq!(snap.queue_depth, 0);
        assert!(snap.latency_p50_s().is_none());
        assert!(snap.latency_p99_s().is_none());
        assert!(snap.mean_occupancy().is_none());
        let json = snap.to_json();
        assert!(matches!(json.get("latency_s").unwrap().get("p99").unwrap(), &Json::Null));
        // Must serialize to valid JSON (no bare NaN/inf tokens).
        Json::parse(&json.pretty()).unwrap();
    }

    #[test]
    fn distribution_window_is_bounded_but_counters_are_all_time() {
        let m = ServeMetrics::default();
        for i in 0..(SAMPLE_WINDOW + 100) {
            m.record_request(1, i as f64);
        }
        let s = m.snapshot();
        assert_eq!(s.requests as usize, SAMPLE_WINDOW + 100);
        assert_eq!(s.latency.count(), SAMPLE_WINDOW);
        // The 100 oldest samples were evicted from the ring.
        assert!(s.latency.percentile(0.0).unwrap() >= 100.0);
    }

    #[test]
    fn counters_and_percentiles_accumulate() {
        let m = ServeMetrics::default();
        for i in 0..10 {
            m.record_request(2, 0.001 * (i + 1) as f64);
        }
        m.record_cache(3, 17);
        m.record_batch(4, 0.01);
        m.record_batch(2, 0.02);
        let s = m.snapshot();
        assert_eq!(s.requests, 10);
        assert_eq!(s.vertices, 20);
        assert_eq!(s.cache_hits, 3);
        assert_eq!(s.cache_misses, 17);
        assert_eq!(s.batches, 2);
        assert_eq!(s.mean_occupancy(), Some(3.0));
        let p50 = s.latency_p50_s().unwrap();
        assert!(p50 > 0.004 && p50 < 0.007, "{p50}");
        assert!(s.latency_p99_s().unwrap() >= p50);
    }

    #[test]
    fn shed_counter_and_depth_gauge_track_admission() {
        let m = ServeMetrics::default();
        m.depth_add(5);
        m.record_shed();
        m.record_shed();
        let s = m.snapshot();
        assert_eq!(s.shed_requests, 2);
        assert_eq!(s.queue_depth, 5);
        m.depth_sub(3);
        m.depth_sub(2);
        let s = m.snapshot();
        assert_eq!(s.queue_depth, 0, "balanced add/sub returns the gauge to zero");
        assert_eq!(s.shed_requests, 2, "shed is an all-time counter");
        let json = s.to_json();
        assert_eq!(json.get("shed_requests").unwrap().as_usize().unwrap(), 2);
        assert_eq!(json.get("queue_depth").unwrap().as_usize().unwrap(), 0);
    }
}
