//! Serving telemetry on the [`crate::obs`] registry: all-time atomic
//! counters, a queue-depth gauge, and fixed-bucket histograms for the
//! latency/occupancy/timing distributions.
//!
//! Everything records lock-free on the hot path with bounded memory (the
//! histograms are fixed power-of-two bucket arrays — see
//! [`crate::obs::registry::Histogram`]); the registry renders the whole
//! set as Prometheus text exposition for `GET /metrics`, while
//! [`MetricsSnapshot::to_json`] keeps the original JSON field names for
//! `/metrics.json` and the CLI report.

use std::sync::Arc;

use crate::obs::{Counter, Gauge, Histogram, HistogramSnapshot, Registry};
use crate::util::json::Json;

/// Histogram ranges: timings in seconds from ~1us (`2^-20`) to 64s
/// (`2^6`); batch occupancy from 1 (`2^0`) to 4096 (`2^12`).
const TIME_MIN_EXP: i32 = -20;
const TIME_MAX_EXP: i32 = 6;
const OCC_MIN_EXP: i32 = 0;
const OCC_MAX_EXP: i32 = 12;

/// Live serving metrics; one instance per [`super::Server`], shared with
/// the batcher and the worker pool.
#[derive(Debug)]
pub struct ServeMetrics {
    registry: Registry,
    requests: Arc<Counter>,
    vertices: Arc<Counter>,
    cache_hits: Arc<Counter>,
    cache_misses: Arc<Counter>,
    batches: Arc<Counter>,
    shed: Arc<Counter>,
    ingest_edges: Arc<Counter>,
    depth: Arc<Gauge>,
    graph_version: Arc<Gauge>,
    graph_bytes_mapped: Arc<Gauge>,
    latency: Arc<Histogram>,
    occupancy: Arc<Histogram>,
    exec: Arc<Histogram>,
    queue_wait: Arc<Histogram>,
    coalesce: Arc<Histogram>,
}

impl Default for ServeMetrics {
    fn default() -> ServeMetrics {
        let registry = Registry::new();
        let requests =
            registry.counter("hpgnn_serve_requests_total", "Classify requests answered.");
        let vertices = registry.counter("hpgnn_serve_vertices_total", "Vertices classified.");
        let cache_hits = registry.counter("hpgnn_serve_cache_hits_total", "Logits-cache hits.");
        let cache_misses =
            registry.counter("hpgnn_serve_cache_misses_total", "Logits-cache misses.");
        let batches =
            registry.counter("hpgnn_serve_batches_total", "Coalesced micro-batches executed.");
        let shed = registry.counter(
            "hpgnn_serve_shed_requests_total",
            "Requests shed by admission control (queue full).",
        );
        let ingest_edges = registry.counter(
            "hpgnn_graph_ingest_edges_total",
            "Edges inserted into the served graph via ingest.",
        );
        let depth =
            registry.gauge("hpgnn_serve_queue_depth", "Work items currently in flight.");
        let graph_version = registry.gauge(
            "hpgnn_graph_version",
            "Snapshot version of the graph new requests are served against.",
        );
        let graph_bytes_mapped = registry.gauge(
            "hpgnn_graph_bytes_mapped",
            "Bytes of the on-disk graph store currently mapped/resident.",
        );
        let latency = registry.histogram(
            "hpgnn_serve_request_latency_seconds",
            "End-to-end classify latency.",
            TIME_MIN_EXP,
            TIME_MAX_EXP,
        );
        let occupancy = registry.histogram(
            "hpgnn_serve_batch_occupancy",
            "Work items per executed micro-batch.",
            OCC_MIN_EXP,
            OCC_MAX_EXP,
        );
        let exec = registry.histogram(
            "hpgnn_serve_batch_exec_seconds",
            "Forward-kernel execution time per micro-batch.",
            TIME_MIN_EXP,
            TIME_MAX_EXP,
        );
        let queue_wait = registry.histogram(
            "hpgnn_serve_queue_wait_seconds",
            "Work-item wait from enqueue to worker pickup.",
            TIME_MIN_EXP,
            TIME_MAX_EXP,
        );
        let coalesce = registry.histogram(
            "hpgnn_serve_coalesce_seconds",
            "Batcher coalescing window per shipped batch.",
            TIME_MIN_EXP,
            TIME_MAX_EXP,
        );
        ServeMetrics {
            registry,
            requests,
            vertices,
            cache_hits,
            cache_misses,
            batches,
            shed,
            ingest_edges,
            depth,
            graph_version,
            graph_bytes_mapped,
            latency,
            occupancy,
            exec,
            queue_wait,
            coalesce,
        }
    }
}

impl ServeMetrics {
    /// One answered classify request covering `vertices` vertices.
    pub fn record_request(&self, vertices: usize, latency_s: f64) {
        self.requests.inc();
        self.vertices.add(vertices as u64);
        self.latency.observe(latency_s);
    }

    pub fn record_cache(&self, hits: usize, misses: usize) {
        self.cache_hits.add(hits as u64);
        self.cache_misses.add(misses as u64);
    }

    /// A request refused because the bounded queue was full.
    pub fn record_shed(&self) {
        self.shed.inc();
    }

    pub fn depth_add(&self, n: usize) {
        self.depth.add(n as i64);
    }

    pub fn depth_sub(&self, n: usize) {
        self.depth.sub(n as i64);
    }

    /// One executed micro-batch: `occupancy` work items, `exec_s` kernel
    /// wall time.
    pub fn record_batch(&self, occupancy: usize, exec_s: f64) {
        self.batches.inc();
        self.occupancy.observe(occupancy as f64);
        self.exec.observe(exec_s);
    }

    /// Enqueue-to-pickup wait of one work item.
    pub fn record_queue_wait(&self, wait_s: f64) {
        self.queue_wait.observe(wait_s);
    }

    /// Coalescing window of one shipped batch (first recv to ship).
    pub fn record_coalesce(&self, window_s: f64) {
        self.coalesce.observe(window_s);
    }

    /// Initialize the graph gauges from the snapshot the server booted
    /// with (version is 0 for in-RAM graphs, the packed version for
    /// stores).
    pub fn set_graph(&self, version: u64, bytes_mapped: u64) {
        self.graph_version.set(version.min(i64::MAX as u64) as i64);
        self.graph_bytes_mapped.set(bytes_mapped.min(i64::MAX as u64) as i64);
    }

    /// One successful edge ingest: `edges` inserted, the graph advanced
    /// to `version`.
    pub fn record_ingest(&self, edges: u64, version: u64, bytes_mapped: u64) {
        self.ingest_edges.add(edges);
        self.set_graph(version, bytes_mapped);
    }

    /// Prometheus text exposition of every serving metric.
    pub fn prometheus(&self) -> String {
        self.registry.render_prometheus()
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.get(),
            vertices: self.vertices.get(),
            cache_hits: self.cache_hits.get(),
            cache_misses: self.cache_misses.get(),
            batches: self.batches.get(),
            shed_requests: self.shed.get(),
            ingest_edges: self.ingest_edges.get(),
            queue_depth: self.depth.get().max(0) as u64,
            graph_version: self.graph_version.get().max(0) as u64,
            graph_bytes_mapped: self.graph_bytes_mapped.get().max(0) as u64,
            latency: self.latency.snapshot(),
            occupancy: self.occupancy.snapshot(),
            exec: self.exec.snapshot(),
            queue_wait: self.queue_wait.snapshot(),
            coalesce: self.coalesce.snapshot(),
        }
    }
}

/// Point-in-time copy of the counters plus the full distribution
/// snapshots.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub vertices: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub batches: u64,
    pub shed_requests: u64,
    pub ingest_edges: u64,
    pub queue_depth: u64,
    pub graph_version: u64,
    pub graph_bytes_mapped: u64,
    pub latency: HistogramSnapshot,
    pub occupancy: HistogramSnapshot,
    pub exec: HistogramSnapshot,
    pub queue_wait: HistogramSnapshot,
    pub coalesce: HistogramSnapshot,
}

fn opt_num(x: Option<f64>) -> Json {
    x.map(Json::num).unwrap_or(Json::Null)
}

fn dist_json(h: &HistogramSnapshot) -> Json {
    Json::obj(vec![
        ("count", Json::num(h.count() as f64)),
        ("mean", opt_num((h.count() > 0).then(|| h.mean()))),
        ("p50", opt_num(h.percentile(50.0))),
        ("p95", opt_num(h.percentile(95.0))),
        ("p99", opt_num(h.percentile(99.0))),
    ])
}

impl MetricsSnapshot {
    pub fn latency_p50_s(&self) -> Option<f64> {
        self.latency.percentile(50.0)
    }

    pub fn latency_p95_s(&self) -> Option<f64> {
        self.latency.percentile(95.0)
    }

    pub fn latency_p99_s(&self) -> Option<f64> {
        self.latency.percentile(99.0)
    }

    pub fn mean_occupancy(&self) -> Option<f64> {
        (self.occupancy.count() > 0).then(|| self.occupancy.mean())
    }

    /// The `/metrics.json` document.  Field names are stable (clients and
    /// CI parse them); the per-stage `queue_wait_s`/`coalesce_s`
    /// distributions are additive.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::num(self.requests as f64)),
            ("vertices", Json::num(self.vertices as f64)),
            ("cache_hits", Json::num(self.cache_hits as f64)),
            ("cache_misses", Json::num(self.cache_misses as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("shed_requests", Json::num(self.shed_requests as f64)),
            ("ingest_edges", Json::num(self.ingest_edges as f64)),
            ("queue_depth", Json::num(self.queue_depth as f64)),
            ("graph_version", Json::num(self.graph_version as f64)),
            ("graph_bytes_mapped", Json::num(self.graph_bytes_mapped as f64)),
            ("latency_s", dist_json(&self.latency)),
            ("queue_wait_s", dist_json(&self.queue_wait)),
            ("coalesce_s", dist_json(&self.coalesce)),
            ("mean_batch_occupancy", opt_num(self.mean_occupancy())),
            (
                "exec_mean_s",
                opt_num((self.exec.count() > 0).then(|| self.exec.mean())),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_snapshot_reports_null_percentiles_without_panicking() {
        let m = ServeMetrics::default();
        let s = m.snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.latency_p50_s(), None);
        assert_eq!(s.mean_occupancy(), None);
        let j = s.to_json();
        assert!(matches!(j.get("mean_batch_occupancy").unwrap(), Json::Null));
        assert!(matches!(j.get("latency_s").unwrap().get("p99").unwrap(), Json::Null));
        assert_eq!(j.get("latency_s").unwrap().get("count").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn histogram_storage_is_bounded_but_counters_are_all_time() {
        let m = ServeMetrics::default();
        let width = m.snapshot().latency.counts.len();
        for i in 0..10_000 {
            m.record_request(1, (i % 100) as f64 * 1e-4);
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 10_000, "request counter is all-time");
        assert_eq!(s.latency.count(), 10_000, "histogram count is all-time");
        assert_eq!(s.latency.counts.len(), width, "bucket storage must not grow");
    }

    #[test]
    fn counters_and_percentiles_accumulate() {
        let m = ServeMetrics::default();
        for i in 1..=10 {
            m.record_request(2, i as f64 * 1e-3);
        }
        m.record_batch(4, 0.002);
        m.record_batch(6, 0.004);
        m.record_queue_wait(0.001);
        m.record_coalesce(0.0005);
        let s = m.snapshot();
        assert_eq!(s.requests, 10);
        assert_eq!(s.vertices, 20);
        assert_eq!(s.batches, 2);
        let p50 = s.latency_p50_s().unwrap();
        assert!(p50 > 0.004 && p50 < 0.007, "p50 {p50}");
        assert_eq!(s.mean_occupancy(), Some(5.0));
        assert_eq!(s.queue_wait.count(), 1);
        assert_eq!(s.coalesce.count(), 1);
        let j = s.to_json();
        assert_eq!(j.get("queue_wait_s").unwrap().get("count").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn shed_counter_and_depth_gauge_track_admission() {
        let m = ServeMetrics::default();
        m.depth_add(3);
        assert_eq!(m.snapshot().queue_depth, 3);
        m.record_shed();
        m.record_shed();
        m.depth_sub(3);
        let s = m.snapshot();
        assert_eq!(s.shed_requests, 2);
        assert_eq!(s.queue_depth, 0);
    }

    #[test]
    fn graph_metrics_track_ingest_and_store_state() {
        let m = ServeMetrics::default();
        let s = m.snapshot();
        assert_eq!((s.ingest_edges, s.graph_version, s.graph_bytes_mapped), (0, 0, 0));
        m.set_graph(3, 4096);
        m.record_ingest(7, 4, 4096);
        m.record_ingest(2, 5, 4096);
        let s = m.snapshot();
        assert_eq!(s.ingest_edges, 9, "ingest counter is cumulative");
        assert_eq!(s.graph_version, 5, "version gauge tracks the latest snapshot");
        assert_eq!(s.graph_bytes_mapped, 4096);
        let j = s.to_json();
        assert_eq!(j.get("ingest_edges").unwrap().as_usize().unwrap(), 9);
        assert_eq!(j.get("graph_version").unwrap().as_usize().unwrap(), 5);
        assert_eq!(j.get("graph_bytes_mapped").unwrap().as_usize().unwrap(), 4096);
        let text = m.prometheus();
        assert!(text.contains("# TYPE hpgnn_graph_ingest_edges_total counter\n"));
        assert!(text.contains("hpgnn_graph_ingest_edges_total 9\n"));
        assert!(text.contains("# TYPE hpgnn_graph_version gauge\n"));
        assert!(text.contains("hpgnn_graph_version 5\n"));
        assert!(text.contains("hpgnn_graph_bytes_mapped 4096\n"));
    }

    #[test]
    fn prometheus_exposition_covers_the_serving_families() {
        let m = ServeMetrics::default();
        m.record_request(3, 0.002);
        m.record_batch(3, 0.0004);
        let text = m.prometheus();
        assert!(text.contains("# TYPE hpgnn_serve_requests_total counter\n"));
        assert!(text.contains("hpgnn_serve_requests_total 1\n"));
        assert!(text.contains("hpgnn_serve_vertices_total 3\n"));
        assert!(text.contains("# TYPE hpgnn_serve_queue_depth gauge\n"));
        assert!(text.contains("# TYPE hpgnn_serve_request_latency_seconds histogram\n"));
        assert!(text.contains("hpgnn_serve_request_latency_seconds_count 1\n"));
        assert!(text.contains("hpgnn_serve_batch_occupancy_sum 3\n"));
        assert!(text.contains("hpgnn_serve_coalesce_seconds_count 0\n"));
    }
}
