//! Design space exploration engine — paper §5.3, Algorithm 4.
//!
//! Per die: derive `n_max` / `m_max` from the resource constraints
//! (Eq. 10–11), exhaustively sweep `n` over powers of two and `m` over
//! squares of powers of two (the hardware-template restrictions stated
//! under Table 5), keep the throughput-optimal feasible configuration, and
//! finally size the host sampler thread pool so `t_sampling < t_GNN`
//! (§5.1, "Modeling t_sampling").

use crate::accel::platform::Platform;
use crate::accel::AccelConfig;
use crate::layout::LayoutOptions;
use crate::perf::{estimate, BatchGeometry, ModelShape, ResourceCoefficients, Utilization};

/// Result of a DSE run.
#[derive(Debug, Clone)]
pub struct DseResult {
    pub config: AccelConfig,
    /// Analytic throughput at the chosen point (NVTPS, sampling ignored).
    pub nvtps: f64,
    pub utilization: Utilization,
    /// Analytic t_GNN at the chosen point (seconds).
    pub t_gnn: f64,
    /// Candidates evaluated (diagnostics).
    pub evaluated: usize,
    /// Sampler threads needed so sampling never bottlenecks, given the
    /// measured single-thread sampling time (None if not provided).
    pub sampler_threads: Option<usize>,
}

/// DSE inputs beyond the platform: batch shape, model shape, layout.
#[derive(Debug, Clone)]
pub struct DseProblem {
    pub geom: BatchGeometry,
    pub model: ModelShape,
    pub layout: LayoutOptions,
    pub coeff: ResourceCoefficients,
    /// Measured single-thread sampling time per batch, if known.
    pub t_sampling_single: Option<f64>,
}

/// Algorithm 4: exhaustive (n, m) sweep per die.
pub fn explore(platform: &Platform, problem: &DseProblem) -> DseResult {
    // Construct_Search_Space(): upper bounds from each constraint alone.
    let n_max = max_power_of_two(|n| {
        fits(platform, &problem.coeff, &AccelConfig { n, m: 1 }, problem)
    });
    let m_max = max_square_power_of_two(|m| {
        fits(platform, &problem.coeff, &AccelConfig { n: 1, m }, problem)
    });

    let mut best: Option<(DseResult, f64, f64)> = None; // (result, t_agg, dsp)
    let mut evaluated = 0usize;
    let mut n = 1usize;
    while n <= n_max {
        let mut dim = 1usize;
        while dim * dim <= m_max {
            let config = AccelConfig { n, m: dim * dim };
            evaluated += 1;
            if fits(platform, &problem.coeff, &config, problem) {
                let est = estimate(platform, &config, &problem.geom, &problem.model, problem.layout);
                let nvtps = est.nvtps(&problem.geom, 0.0);
                // Primary: throughput.  Ties (common when the update kernel
                // dominates Eq. 6) break toward the smallest total
                // aggregation time — extra scatter PEs absorb routing
                // conflicts the closed form can't see — and then toward
                // the cheapest resource footprint.
                let t_agg: f64 = est.layers.iter().map(|l| l.t_aggregate).sum();
                let util = crate::perf::utilization(
                    platform,
                    &problem.coeff,
                    &config,
                    &problem.geom,
                    &problem.model,
                );
                let better = match &best {
                    None => true,
                    Some((b, bt_agg, bdsp)) => {
                        let rel = (nvtps - b.nvtps) / b.nvtps.max(1e-30);
                        rel > 1e-9
                            || (rel.abs() <= 1e-9
                                && (*bt_agg - t_agg > 1e-12 * bt_agg
                                    || ((t_agg - *bt_agg).abs() <= 1e-12 * bt_agg
                                        && util.dsp < *bdsp)))
                    }
                };
                if better {
                    best = Some((
                        DseResult {
                            config,
                            nvtps,
                            utilization: util,
                            t_gnn: est.t_gnn,
                            evaluated: 0,
                            sampler_threads: None,
                        },
                        t_agg,
                        util.dsp,
                    ));
                }
            }
            dim *= 2;
        }
        n *= 2;
    }
    let best = best.map(|(r, _, _)| r);

    let mut result = best.expect("search space empty: platform cannot fit n=1, m=1");
    result.evaluated = evaluated;
    // §5.1: minimum threads with t_sampling / threads < t_GNN (linear
    // scaling assumption; the coordinator validates it empirically).
    result.sampler_threads = problem
        .t_sampling_single
        .map(|t1| (t1 / result.t_gnn).ceil().max(1.0) as usize);
    result
}

fn fits(
    platform: &Platform,
    coeff: &ResourceCoefficients,
    config: &AccelConfig,
    problem: &DseProblem,
) -> bool {
    crate::perf::utilization(platform, coeff, config, &problem.geom, &problem.model).fits()
}

fn max_power_of_two(ok: impl Fn(usize) -> bool) -> usize {
    let mut best = 1;
    let mut x = 1usize;
    while x <= 1 << 20 {
        if ok(x) {
            best = x;
        }
        x *= 2;
    }
    best
}

fn max_square_power_of_two(ok: impl Fn(usize) -> bool) -> usize {
    let mut best = 1;
    let mut dim = 1usize;
    while dim * dim <= 1 << 24 {
        if ok(dim * dim) {
            best = dim * dim;
        }
        dim *= 2;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perf::KappaEstimator;

    fn problem(geom: BatchGeometry, sage: bool, feat: Vec<usize>) -> DseProblem {
        DseProblem {
            geom,
            model: ModelShape { feat, sage_concat: sage },
            layout: LayoutOptions::all(),
            coeff: ResourceCoefficients::default(),
            t_sampling_single: None,
        }
    }

    #[test]
    fn paper_table5_ns_gcn_configuration() {
        // The paper's DSE chooses (m, n) = (256, 4) for NS-GCN on the U250.
        let p = Platform::alveo_u250();
        let geom = BatchGeometry::neighbor_capped(1024, &[10, 25], 89_250);
        let r = explore(&p, &problem(geom, false, vec![500, 256, 7]));
        assert!(r.config.n.is_power_of_two());
        let dim = (r.config.m as f64).sqrt() as usize;
        assert_eq!(dim * dim, r.config.m, "m must be a square");
        assert!(r.utilization.fits());
        // Same order as the paper's pick: a few hundred MACs, a few PEs.
        assert!(
            (64..=1024).contains(&r.config.m) && (2..=16).contains(&r.config.n),
            "chose {:?}",
            r.config
        );
        assert!(r.evaluated > 10);
    }

    #[test]
    fn chosen_config_is_argmax_over_feasible_grid() {
        let p = Platform::alveo_u250();
        let geom = BatchGeometry::neighbor(256, &[10, 25]);
        let prob = problem(geom.clone(), false, vec![500, 256, 7]);
        let r = explore(&p, &prob);
        // Re-evaluate the whole grid by hand; nothing feasible beats it.
        let mut n = 1usize;
        while n <= 64 {
            let mut dim = 1usize;
            while dim * dim <= 4096 {
                let config = AccelConfig { n, m: dim * dim };
                if fits(&p, &prob.coeff, &config, &prob) {
                    let est = estimate(&p, &config, &prob.geom, &prob.model, prob.layout);
                    assert!(
                        est.nvtps(&prob.geom, 0.0) <= r.nvtps * (1.0 + 1e-12),
                        "{config:?} beats DSE pick"
                    );
                }
                dim *= 2;
            }
            n *= 2;
        }
    }

    #[test]
    fn ss_sage_prefers_more_scatter_pes_than_ns() {
        // Table 5: SS-SAGE gets n=8 while NS workloads get n=4 — subgraph
        // batches are edge-dense relative to their vertex count, shifting
        // the bottleneck toward aggregation.
        let p = Platform::alveo_u250();
        let kappa = KappaEstimator::from_stats(232_965, 11_606_919);
        let ns = explore(&p, &problem(BatchGeometry::neighbor_capped(1024, &[10, 25], 232_965), true, vec![602, 256, 41]));
        let ss = explore(&p, &problem(BatchGeometry::subgraph(2750, 2, &kappa), true, vec![602, 256, 41]));
        assert!(
            ss.config.n >= ns.config.n,
            "ss {:?} should need at least as many PEs as ns {:?}",
            ss.config,
            ns.config
        );
    }

    #[test]
    fn sampler_thread_sizing() {
        let p = Platform::alveo_u250();
        let geom = BatchGeometry::neighbor_capped(1024, &[10, 25], 89_250);
        let mut prob = problem(geom, false, vec![500, 256, 7]);
        prob.t_sampling_single = Some(1.0); // 1 s per batch on one thread
        let r = explore(&p, &prob);
        let threads = r.sampler_threads.unwrap();
        assert!(threads >= 1);
        // threads · t_GNN must cover the single-thread sampling time.
        assert!(threads as f64 * r.t_gnn >= 1.0);
        assert!((threads - 1) as f64 * r.t_gnn < 1.0);
    }

    #[test]
    fn tiny_platform_still_yields_config() {
        let mut p = Platform::alveo_u250();
        p.dsp_per_die = 64;
        p.lut_per_die = 30_000;
        let geom = BatchGeometry::neighbor(64, &[5, 5]);
        let r = explore(&p, &problem(geom, false, vec![64, 32, 8]));
        assert!(r.utilization.fits());
        assert!(r.config.m <= 16);
    }
}
